//! End-to-end validation driver (DESIGN.md deliverable): proves every layer
//! composes on a real small workload.
//!
//!   cargo run --release --example e2e_train_compress
//!
//! 1. trains the `e2e-llama` transformer (~4 M params) from scratch for a
//!    few hundred steps on the synthetic corpus — loss curve logged;
//! 2. calibrates + factorizes (activation-aware SVD through the AOT
//!    `calibrate` executable + rust Cholesky/Jacobi);
//! 3. runs ARA allocation training at 80% and 60% targets;
//! 4. evaluates PPL on both corpora + the 7-task zero-shot suite against
//!    Dense and Uniform;
//! 5. prints the EXPERIMENTS.md block.
//!
//! ~10–20 minutes on first run (the pre-trained substrate is cached).

use ara_compress::coordinator::Pipeline;
use ara_compress::report::Table;
use ara_compress::training::{pretrain, PretrainConfig};
use ara_compress::Result;

fn main() -> Result<()> {
    let mut pl = Pipeline::new("e2e-llama")?;
    pl.scalecfg.pretrain_steps = ara_compress::config::scaled(300, 60);
    pl.scalecfg.eval_batches = ara_compress::config::scaled(8, 2);
    pl.scalecfg.zs_items = ara_compress::config::scaled(40, 10);

    // --- 1. pre-train with explicit loss-curve logging ---
    let steps = pl.scalecfg.pretrain_steps;
    let wpath = pl.paths.run_dir(&pl.cfg.name).join(format!("weights-{steps}.bin"));
    let ws = if wpath.exists() {
        println!("[e2e] using cached pre-trained weights ({wpath:?})");
        ara_compress::model::load_weights(&wpath)?
    } else {
        println!("[e2e] pre-training e2e-llama for {steps} steps…");
        let pc = PretrainConfig { steps, log_every: 10, ..Default::default() };
        let (ws, report) = pretrain(&pl.cfg, &pl.rt, &pc)?;
        println!("[e2e] loss curve:");
        for (s, l) in &report.losses {
            println!("    step {s:>4}  loss {l:.4}");
        }
        ara_compress::model::save_weights(&ws, &wpath)?;
        ws
    };
    let n_params = ara_compress::model::total_params(&pl.cfg);
    println!("[e2e] model: {} parameters", n_params);

    // --- 2. calibrate + factorize ---
    let grams = pl.grams(&ws)?;
    let fm = pl.factored(&ws, &grams)?;
    println!("[e2e] factorized {} modules", fm.factors.len());

    // --- 3 + 4. allocate and evaluate ---
    let dense = pl.evaluate_dense(&ws)?;
    let mut t = Table::new(
        "e2e — e2e-llama: Dense vs Uniform vs ARA",
        &["Config", "Wiki2", "C4", "Avg acc %", "dense mods"],
    );
    t.row(vec![
        "Dense".into(),
        format!("{:.2}", dense.wiki_ppl),
        format!("{:.2}", dense.c4_ppl),
        format!("{:.2}", dense.avg_acc),
        "-".into(),
    ]);
    for ratio in [0.8, 0.6] {
        for id in ["uniform", "ara"] {
            let plan = pl.allocate_spec(&format!("{id}@{ratio}"), &ws, &grams, &fm)?;
            let alloc = &plan.allocation;
            let row = pl.evaluate(
                &format!("{}@{:.0}%", plan.label, ratio * 100.0),
                &ws,
                &fm,
                alloc,
            )?;
            t.row(vec![
                row.method.clone(),
                format!("{:.2}", row.wiki_ppl),
                format!("{:.2}", row.c4_ppl),
                format!("{:.2}", row.avg_acc),
                format!("{}/{}", alloc.dense_count(), alloc.modules.len()),
            ]);
        }
    }
    t.print();
    println!("[e2e] record this table in EXPERIMENTS.md §End-to-end");
    Ok(())
}
