//! Quickstart: the smallest end-to-end use of the public API.
//!
//!   cargo run --release --example quickstart
//!
//! Pre-trains the micro substrate LM (cached), compresses it with Uniform
//! and with ARA at 70%, and prints the PPL comparison — about a minute on
//! first run, seconds after caching.

use ara_compress::coordinator::Pipeline;
use ara_compress::report::{f2, Table};
use ara_compress::Result;

fn main() -> Result<()> {
    let pl = Pipeline::new("micro-llama")?;

    // 1. substrate: a real (tiny) LM, trained from scratch through the AOT
    //    train_step executable (cached under runs/micro-llama/)
    let ws = pl.pretrained()?;

    // 2. activation-aware SVD: calibrate on sync4, whiten, factorize
    let grams = pl.grams(&ws)?;
    let fm = pl.factored(&ws, &grams)?;

    // 3. allocate ranks through the method registry: uniform vs ARA at a
    //    70% parameter budget — each result is a versioned CompressionPlan
    let uniform = pl.allocate_spec("uniform@0.7", &ws, &grams, &fm)?.allocation;
    let ara_plan = pl.allocate_spec("ara@0.7", &ws, &grams, &fm)?;
    println!(
        "{}: achieved {:.3}, kept {} of {} modules dense (the R≥1 guidance switch)",
        ara_plan.spec,
        ara_plan.achieved,
        ara_plan.allocation.dense_count(),
        ara_plan.allocation.modules.len()
    );
    let ara = ara_plan.allocation;

    // 4. evaluate
    let mut t = Table::new("quickstart — micro-llama @ 70%", &["Config", "Wiki2 PPL", "C4 PPL"]);
    let dense = pl.evaluate_dense(&ws)?;
    t.row(vec!["Dense".into(), f2(dense.wiki_ppl), f2(dense.c4_ppl)]);
    for (label, alloc) in [("Uniform", &uniform), ("ARA", &ara)] {
        let row = pl.evaluate(label, &ws, &fm, alloc)?;
        t.row(vec![label.into(), f2(row.wiki_ppl), f2(row.c4_ppl)]);
    }
    t.print();
    Ok(())
}
