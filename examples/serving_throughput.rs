//! Serving example: run the threaded router + dynamic batcher + decode
//! engine on a stream of generation requests and report latency/throughput.
//!
//!   cargo run --release --example serving_throughput
//!
//! Demonstrates the L3 topology: the engine (PJRT state) lives on a worker
//! thread; requests flow through the router; the batcher picks compiled
//! batch sizes; weights and KV caches stay device-resident.

use std::time::Instant;

use ara_compress::coordinator::Pipeline;
use ara_compress::data::{corpus_spec, generate_tokens};
use ara_compress::model::Allocation;
use ara_compress::serving::{DynamicBatcher, Engine, Router, ServeRequest};
use ara_compress::Result;

fn main() -> Result<()> {
    let model = "minillama-s";
    let alloc_name = "ara-80";
    let pl = Pipeline::new(model)?;
    let ws = pl.pretrained()?;
    let grams = pl.grams(&ws)?;
    let fm = pl.factored(&ws, &grams)?;
    let cfg = pl.cfg.clone();

    let alloc_path = {
        let c = pl.paths.configs.join("allocations").join(format!("{model}.{alloc_name}.json"));
        if c.exists() {
            c
        } else {
            pl.paths.artifacts.join("allocations").join(format!("{model}.{alloc_name}.json"))
        }
    };
    let alloc = Allocation::load(&alloc_path)?;

    // batcher demo over the compiled batch sizes
    let batcher = DynamicBatcher::new(cfg.decode_batches.clone());
    println!("batch plan for 11 queued requests: {:?}", batcher.plan(11));

    // the router owns the engine on its worker thread (largest batch size)
    let batch = *cfg.decode_batches.last().unwrap();
    let prefill_len = cfg.prefill_len;
    let paths = pl.paths.clone();
    let cfg2 = cfg.clone();
    let router = Router::spawn(
        move || {
            let rt = ara_compress::runtime::Runtime::new(paths.artifact_dir(&cfg2.name))
                .expect("runtime");
            let engine = Engine::new(&cfg2, &rt, &ws, &fm, &alloc, alloc_name, batch)
                .expect("engine");
            Box::new(move |prompts: &[Vec<i32>], gen_len: usize| {
                let (tokens, stats) = engine.generate(prompts, gen_len)?;
                Ok((tokens, stats.tok_per_s()))
            })
        },
        batch,
        prefill_len,
        5, // max batching wait (ms)
    );

    // fire a stream of requests and measure end-to-end latency
    let n_requests = ara_compress::config::scaled(32, 8);
    let gen_len = ara_compress::config::scaled(24, 8);
    let stream = generate_tokens(cfg.vocab, corpus_spec("synwiki"), 3, 65536);
    let t0 = Instant::now();
    let mut receivers = Vec::new();
    for i in 0..n_requests {
        let off = (i * prefill_len) % (stream.len() - prefill_len);
        receivers.push((
            Instant::now(),
            router.submit(ServeRequest {
                prompt: stream[off..off + prefill_len].to_vec(),
                gen_len,
            }),
        ));
    }
    let mut latencies = Vec::new();
    let mut tps_sum = 0.0;
    for (t_submit, rx) in receivers {
        let resp = rx.recv().expect("response");
        latencies.push(t_submit.elapsed().as_secs_f64());
        tps_sum += resp.decode_tok_per_s;
        assert_eq!(resp.tokens.len(), gen_len);
    }
    let wall = t0.elapsed().as_secs_f64();
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p50 = latencies[latencies.len() / 2];
    let p99 = latencies[(latencies.len() * 99 / 100).min(latencies.len() - 1)];
    println!(
        "served {n_requests} requests × {gen_len} tokens in {wall:.2}s \
         → {:.1} tok/s end-to-end",
        (n_requests * gen_len) as f64 / wall
    );
    println!("latency p50 {:.0} ms, p99 {:.0} ms", p50 * 1e3, p99 * 1e3);
    println!("mean engine decode throughput {:.1} tok/s", tps_sum / n_requests as f64);
    Ok(())
}
