//! Serving example: run the threaded router over the continuous-batching
//! scheduler on a stream of ragged generation requests and report
//! latency/throughput.
//!
//!   cargo run --release --example serving_throughput
//!
//! Demonstrates the L3 topology: the engine (PJRT state) lives on a worker
//! thread driving a slot-based scheduler; ragged requests flow through the
//! router and are admitted into freed slots mid-flight; the batcher plans
//! over compiled batch sizes; weights and KV caches stay device-resident.

use std::time::Instant;

use ara_compress::coordinator::Pipeline;
use ara_compress::data::{corpus_spec, generate_tokens, Rng};
use ara_compress::runtime::{resolve_alloc, Runtime};
use ara_compress::serving::{
    DynamicBatcher, Engine, FinishReason, Router, SamplingParams, ServeRequest,
};
use ara_compress::Result;

fn main() -> Result<()> {
    let model = "minillama-s";
    let alloc_name = "ara-80";
    let pl = Pipeline::new(model)?;
    let ws = pl.pretrained()?;
    let grams = pl.grams(&ws)?;
    let fm = pl.factored(&ws, &grams)?;
    let cfg = pl.cfg.clone();

    // batcher demo over the compiled batch sizes
    let batcher = DynamicBatcher::new(cfg.decode_batches.clone());
    println!("batch plan for 11 queued requests: {:?}", batcher.plan(11));

    // the router owns the engine on its worker thread (largest batch size)
    let batch = *cfg.decode_batches.last().unwrap();
    let prefill_len = cfg.prefill_len;
    let paths = pl.paths.clone();
    let cfg2 = cfg.clone();
    let router = Router::spawn(move || {
        let rt = Runtime::new(paths.artifact_dir(&cfg2.name)).expect("runtime");
        let alloc = resolve_alloc(&cfg2, &paths, alloc_name).expect("alloc");
        Engine::new(&cfg2, &rt, &ws, &fm, &alloc, alloc_name, batch).expect("engine")
    });

    // fire a stream of ragged requests and measure end-to-end latency
    let n_requests = ara_compress::config::scaled(32, 8);
    let gen_len = ara_compress::config::scaled(24, 8);
    let stream = generate_tokens(cfg.vocab, corpus_spec("synwiki"), 3, 65536);
    let mut rng = Rng::new(17);
    let t0 = Instant::now();
    let mut receivers = Vec::new();
    for i in 0..n_requests {
        let len = 1 + rng.below(prefill_len); // ragged: 1..=prefill_len
        let off = (i * prefill_len) % (stream.len() - prefill_len);
        receivers.push((
            Instant::now(),
            router
                .submit(ServeRequest {
                    prompt: stream[off..off + len].to_vec(),
                    gen_len,
                    params: SamplingParams::greedy(),
                    ..Default::default()
                })
                .expect("router worker alive"),
        ));
    }
    let mut latencies = Vec::new();
    let mut tps_last = 0.0;
    for (t_submit, rx) in receivers {
        let resp = rx.recv().expect("response");
        latencies.push(t_submit.elapsed().as_secs_f64());
        tps_last = resp.decode_tok_per_s;
        assert_eq!(resp.tokens.len(), gen_len);
        assert_eq!(resp.finish_reason, FinishReason::Stop, "no request should truncate");
    }
    let wall = t0.elapsed().as_secs_f64();
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p50 = latencies[latencies.len() / 2];
    let p99 = latencies[(latencies.len() * 99 / 100).min(latencies.len() - 1)];
    println!(
        "served {n_requests} ragged requests × {gen_len} tokens in {wall:.2}s \
         → {:.1} tok/s end-to-end",
        (n_requests * gen_len) as f64 / wall
    );
    println!("latency p50 {:.0} ms, p99 {:.0} ms", p50 * 1e3, p99 * 1e3);
    println!("scheduler engine throughput {tps_last:.1} tok/s");
    Ok(())
}
