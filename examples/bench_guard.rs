//! Bench-health guard: parse the machine-readable bench baselines
//! (`BENCH_PR2.json` … `BENCH_PR10.json`) with the in-crate JSON parser
//! and exit non-zero when a required key is missing, non-numeric,
//! non-finite — or out of range: rate/utilization keys must lie in
//! [0, 1], achieved compression ratios in (0, 1], wall-clock keys must be
//! ≥ 0, speedups (native SIMD over scalar, speculative over plain decode)
//! must be ≥ 1 in real baselines, and bespoke-bounded keys such as
//! `accepted_per_verify` must lie in [0, k]. Replaces the brittle `grep`
//! checks the CI `bench-smoke` job used to run.
//!
//!   cargo run --release --example bench_guard            # real baselines
//!   cargo run --release --example bench_guard -- --smoke # CI smoke run
//!
//! In `--smoke` mode the guard checks the `*_smoke` sections that an
//! `ARA_BENCH_SMOKE=1` bench run emits; without it, the committed real
//! baselines are checked (useful after `cargo bench` regeneration).

use ara_compress::json::{parse, Json};

struct Check {
    file: &'static str,
    section: String,
    keys: Vec<String>,
    /// Keys that must additionally lie in [0, 1] (rates, utilizations).
    unit_keys: Vec<String>,
    /// Keys that must lie in (0, 1] (achieved compression ratios).
    ratio_keys: Vec<String>,
    /// Keys that must be ≥ 0 (wall-clock durations, counts).
    pos_keys: Vec<String>,
    /// Keys that must be ≥ 1 (speedup ratios: native SIMD over scalar,
    /// speculative over plain decode — real baselines only).
    min_one_keys: Vec<String>,
    /// Keys with a bespoke inclusive upper bound: `(key, max)` must lie
    /// in [0, max] (e.g. `accepted_per_verify` ∈ [0, k]).
    bounded_keys: Vec<(String, f64)>,
}

fn required(smoke: bool) -> Vec<Check> {
    let sfx = if smoke { "_smoke" } else { "" };
    let s = |x: &str| x.to_string();
    // perf_micro: smoke runs micro-llama (last decode batch 2), real runs
    // minillama-s (last decode batch 4) — see benches/perf_micro.rs
    let pm_keys = if smoke {
        vec![
            s("matmul_64x64x64_gflops"),
            s("train_step_ms_micro-llama"),
            s("score_dense_ms"),
            s("score_masked_ms"),
            s("decode_tok_s_dense_b2"),
            s("decode_tok_s_uniform-80_b2"),
        ]
    } else {
        vec![
            s("matmul_128x128x128_gflops"),
            s("train_step_ms_minillama-s"),
            s("score_dense_ms"),
            s("score_masked_ms"),
            s("decode_tok_s_dense_b4"),
            s("decode_tok_s_ara-80_b4"),
        ]
    };
    // fig5 decode sweep: smoke covers dense/uniform-80 at the smallest
    // batch; real covers the full alloc × batch grid (spot-check corners)
    let f5_keys = if smoke {
        vec![s("dense_b1_tok_s"), s("uniform-80_b1_tok_s")]
    } else {
        vec![s("dense_b1_tok_s"), s("uniform-60_b2_tok_s"), s("ara-80_b4_tok_s")]
    };
    // scheduler trace: smoke runs uniform-80 only
    let sched_allocs: &[&str] = if smoke { &["uniform-80"] } else { &["uniform-80", "ara-80"] };
    let mut sched_keys = Vec::new();
    for a in sched_allocs {
        for m in ["req_s", "tok_s", "p50_ms", "p95_ms"] {
            sched_keys.push(format!("{a}_{m}"));
        }
    }
    // paged-pool shared-prompt workload (fig5 part d): hit rate and pool
    // utilization are fractions — enforce [0, 1] on top of finiteness
    let mut paged_keys = Vec::new();
    let mut paged_unit = Vec::new();
    for a in sched_allocs {
        paged_keys.push(format!("{a}_shared_tok_s"));
        for m in ["prefix_hit_rate", "pool_util"] {
            paged_keys.push(format!("{a}_{m}"));
            paged_unit.push(format!("{a}_{m}"));
        }
    }
    // fig_sweep (PR 5): per-spec achieved ratio ∈ (0, 1], dense count and
    // wall-ms ≥ 0. Smoke runs the micro grid; real spot-checks the
    // minillama-s Table 1/2 grid corners.
    let sweep_specs: &[&str] = if smoke {
        &["uniform@0.5", "dlp@0.5", "ara@0.5"]
    } else {
        &["uniform@0.35", "dobi@0.35", "ara@0.35", "ara@0.25"]
    };
    let mut sweep_keys = Vec::new();
    let mut sweep_ratio = Vec::new();
    let mut sweep_pos = Vec::new();
    for sp in sweep_specs {
        sweep_keys.push(format!("{sp}_achieved"));
        sweep_ratio.push(format!("{sp}_achieved"));
        for m in ["dense_count", "wall_ms"] {
            sweep_keys.push(format!("{sp}_{m}"));
            sweep_pos.push(format!("{sp}_{m}"));
        }
    }
    // simd_tiers (PR 6): per-tier matmul GFLOP/s on both micro-kernel
    // paths. Only the scalar keys are required (other tiers are
    // host-dependent; the finiteness sweep covers whatever ran). The
    // native/scalar speedup key must be ≥ 1 in real baselines — single-
    // iteration smoke timings are too noisy to gate on, so smoke only
    // requires it to exist and be finite.
    let (tier_keys, tier_min_one) = if smoke {
        (
            vec![
                s("matmul_64x64x64_scalar_gflops"),
                s("matmul_4x64x64_dot_scalar_gflops"),
                s("matmul_64x64x64_native_speedup"),
            ],
            Vec::new(),
        )
    } else {
        (
            vec![
                s("matmul_256x256x256_scalar_gflops"),
                s("matmul_4x512x512_dot_scalar_gflops"),
                s("matmul_256x256x256_native_speedup"),
            ],
            vec![s("matmul_256x256x256_native_speedup")],
        )
    };
    // fig_chaos (PR 7): per-fault-rate resilience metrics. Rates are
    // fractions in [0, 1]; goodput/latency/fault counts must be ≥ 0
    // (goodput may legitimately be 0 when every request was shed or
    // quarantined — the guard checks health, not performance).
    let chaos_rates: &[&str] = if smoke { &["r0", "r25"] } else { &["r0", "r10", "r25"] };
    let mut chaos_keys = Vec::new();
    let mut chaos_unit = Vec::new();
    let mut chaos_pos = Vec::new();
    for r in chaos_rates {
        for m in ["retry_success_rate", "shed_rate"] {
            chaos_keys.push(format!("{r}_{m}"));
            chaos_unit.push(format!("{r}_{m}"));
        }
        for m in ["goodput_tok_s", "p50_ms", "p95_ms", "decode_faults"] {
            chaos_keys.push(format!("{r}_{m}"));
            chaos_pos.push(format!("{r}_{m}"));
        }
    }
    // fig_http (PR 8): open-loop HTTP load sweep at arrival-rate
    // multiples of the calibrated service rate (smoke skips ×2). ok/shed
    // rates are fractions in [0, 1]; goodput and latencies must be ≥ 0
    // (goodput is legitimately 0 at a rate where every request shed).
    let http_rates: &[&str] = if smoke { &["x05", "x1", "x4"] } else { &["x05", "x1", "x2", "x4"] };
    let mut http_keys = Vec::new();
    let mut http_unit = Vec::new();
    let mut http_pos = Vec::new();
    for r in http_rates {
        for m in ["ok_rate", "shed_rate"] {
            http_keys.push(format!("{r}_{m}"));
            http_unit.push(format!("{r}_{m}"));
        }
        for m in ["goodput_tok_s", "p50_ms", "p99_ms"] {
            http_keys.push(format!("{r}_{m}"));
            http_pos.push(format!("{r}_{m}"));
        }
    }
    // fig_specdec (PR 9): self-speculative decoding over the compression
    // ladder. accept_rate is a fraction in [0, 1]; accepted_per_verify is
    // bounded by the draft length k; throughputs must be ≥ 0. The
    // spec/plain speedup must be ≥ 1 in real baselines only — smoke
    // timings are single-iteration noise, so smoke just requires the key
    // to exist and be finite (the bitwise-identity contract itself is
    // pinned by the bench's claims and tests/specdec.rs, not the guard).
    let spec_grid: &[(&str, usize)] = if smoke {
        &[("uniform-40", 2)]
    } else {
        &[("uniform-40", 2), ("uniform-40", 4), ("ara-40", 2), ("ara-40", 4)]
    };
    let mut spec_keys = vec![s("plain_tok_s")];
    let mut spec_unit = Vec::new();
    let mut spec_pos = vec![s("plain_tok_s")];
    let mut spec_min_one = Vec::new();
    let mut spec_bounded = Vec::new();
    for (d, k) in spec_grid {
        spec_keys.push(format!("{d}_k{k}_tok_s"));
        spec_pos.push(format!("{d}_k{k}_tok_s"));
        spec_keys.push(format!("{d}_k{k}_speedup"));
        if !smoke {
            spec_min_one.push(format!("{d}_k{k}_speedup"));
        }
        spec_keys.push(format!("{d}_k{k}_accepted_per_verify"));
        spec_bounded.push((format!("{d}_k{k}_accepted_per_verify"), *k as f64));
        spec_keys.push(format!("{d}_k{k}_accept_rate"));
        spec_unit.push(format!("{d}_k{k}_accept_rate"));
    }
    // fig_quant (PR 10): ratio × precision grid for int8 SVD factors.
    // Throughput/bytes/ppl must be ≥ 0; the int8/f32 bytes ratio must lie
    // in (0, 1] (packed int8 can never be larger than f32); `ppl_delta`
    // only needs to exist and be finite — it may legitimately be negative
    // (quantization noise can improve ppl), and the bench's own
    // `check_ppl_gate` already fails the build when it exceeds the
    // configured ARA_PPL_GATE threshold.
    let quant_specs: &[&str] = if smoke { &["ara@0.8"] } else { &["ara@0.8", "ara@0.6"] };
    let mut quant_keys = vec![s("gate_threshold")];
    let mut quant_ratio = Vec::new();
    let mut quant_pos = vec![s("gate_threshold")];
    for sp in quant_specs {
        for prec in ["f32", "int8"] {
            for m in ["tok_s", "bytes", "ppl"] {
                quant_keys.push(format!("{sp}_{prec}_{m}"));
                quant_pos.push(format!("{sp}_{prec}_{m}"));
            }
        }
        quant_keys.push(format!("{sp}_ppl_delta"));
        quant_keys.push(format!("{sp}_bytes_ratio"));
        quant_ratio.push(format!("{sp}_bytes_ratio"));
    }
    let none: Vec<String> = Vec::new();
    vec![
        Check {
            file: "BENCH_PR2.json",
            section: format!("perf_micro{sfx}"),
            keys: pm_keys,
            unit_keys: none.clone(),
            ratio_keys: none.clone(),
            pos_keys: none.clone(),
            min_one_keys: none.clone(),
            bounded_keys: Vec::new(),
        },
        Check {
            file: "BENCH_PR2.json",
            section: format!("fig5_decode_tok_s{sfx}"),
            keys: f5_keys,
            unit_keys: none.clone(),
            ratio_keys: none.clone(),
            pos_keys: none.clone(),
            min_one_keys: none.clone(),
            bounded_keys: Vec::new(),
        },
        Check {
            file: "BENCH_PR3.json",
            section: format!("fig5_sched{sfx}"),
            keys: sched_keys,
            unit_keys: none.clone(),
            ratio_keys: none.clone(),
            pos_keys: none.clone(),
            min_one_keys: none.clone(),
            bounded_keys: Vec::new(),
        },
        Check {
            file: "BENCH_PR4.json",
            section: format!("fig5_paged{sfx}"),
            keys: paged_keys,
            unit_keys: paged_unit,
            ratio_keys: none.clone(),
            pos_keys: none.clone(),
            min_one_keys: none.clone(),
            bounded_keys: Vec::new(),
        },
        Check {
            file: "BENCH_PR5.json",
            section: format!("fig_sweep{sfx}"),
            keys: sweep_keys,
            unit_keys: none.clone(),
            ratio_keys: sweep_ratio,
            pos_keys: sweep_pos,
            min_one_keys: none.clone(),
            bounded_keys: Vec::new(),
        },
        Check {
            file: "BENCH_PR6.json",
            section: format!("simd_tiers{sfx}"),
            keys: tier_keys,
            unit_keys: none.clone(),
            ratio_keys: none.clone(),
            pos_keys: none.clone(),
            min_one_keys: tier_min_one,
            bounded_keys: Vec::new(),
        },
        Check {
            file: "BENCH_PR7.json",
            section: format!("fig_chaos{sfx}"),
            keys: chaos_keys,
            unit_keys: chaos_unit,
            ratio_keys: none.clone(),
            pos_keys: chaos_pos,
            min_one_keys: none.clone(),
            bounded_keys: Vec::new(),
        },
        Check {
            file: "BENCH_PR8.json",
            section: format!("fig_http{sfx}"),
            keys: http_keys,
            unit_keys: http_unit,
            ratio_keys: none.clone(),
            pos_keys: http_pos,
            min_one_keys: none.clone(),
            bounded_keys: Vec::new(),
        },
        Check {
            file: "BENCH_PR9.json",
            section: format!("fig_specdec{sfx}"),
            keys: spec_keys,
            unit_keys: spec_unit,
            ratio_keys: none.clone(),
            pos_keys: spec_pos,
            min_one_keys: spec_min_one,
            bounded_keys: spec_bounded,
        },
        Check {
            file: "BENCH_PR10.json",
            section: format!("fig_quant{sfx}"),
            keys: quant_keys,
            unit_keys: none.clone(),
            ratio_keys: quant_ratio,
            pos_keys: quant_pos,
            min_one_keys: none,
            bounded_keys: Vec::new(),
        },
    ]
}

/// Repo-root baseline path via the crate's own root discovery
/// (`ARA_ROOT` override, else walk up to configs/models.json).
fn root_path(file: &str) -> std::path::PathBuf {
    match ara_compress::config::Paths::discover() {
        Ok(p) => p.configs.parent().map(|r| r.join(file)).unwrap_or_else(|| file.into()),
        Err(_) => file.into(),
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut failures: Vec<String> = Vec::new();
    let mut checked = 0usize;

    for check in required(smoke) {
        let path = root_path(check.file);
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) => {
                failures.push(format!("{}: unreadable ({e})", check.file));
                continue;
            }
        };
        // a NaN/Infinity ever written by a bench is not valid JSON — the
        // parse failure below catches it even for keys we don't list
        let root = match parse(&text) {
            Ok(j) => j,
            Err(e) => {
                failures.push(format!("{}: parse error ({e})", check.file));
                continue;
            }
        };
        let section = match root.get(&check.section) {
            Some(s) => s,
            None => {
                failures.push(format!("{}: missing section `{}`", check.file, check.section));
                continue;
            }
        };
        for key in &check.keys {
            checked += 1;
            match section.get(key).map(Json::as_f64) {
                None => failures.push(format!(
                    "{} [{}]: missing key `{key}`",
                    check.file, check.section
                )),
                Some(Err(e)) => failures.push(format!(
                    "{} [{}] {key}: not a number ({e})",
                    check.file, check.section
                )),
                Some(Ok(v)) if !v.is_finite() => failures.push(format!(
                    "{} [{}] {key}: non-finite value {v}",
                    check.file, check.section
                )),
                Some(Ok(v)) if check.unit_keys.contains(key) && !(0.0..=1.0).contains(&v) => {
                    failures.push(format!(
                        "{} [{}] {key}: {v} outside [0, 1]",
                        check.file, check.section
                    ))
                }
                Some(Ok(v)) if check.ratio_keys.contains(key) && (v <= 0.0 || v > 1.0) => {
                    failures.push(format!(
                        "{} [{}] {key}: {v} outside (0, 1]",
                        check.file, check.section
                    ))
                }
                Some(Ok(v)) if check.pos_keys.contains(key) && v < 0.0 => {
                    failures.push(format!(
                        "{} [{}] {key}: {v} is negative",
                        check.file, check.section
                    ))
                }
                Some(Ok(v)) if check.min_one_keys.contains(key) && v < 1.0 => {
                    failures.push(format!(
                        "{} [{}] {key}: speedup {v} below 1 (optimized path slower than baseline)",
                        check.file, check.section
                    ))
                }
                Some(Ok(v))
                    if check
                        .bounded_keys
                        .iter()
                        .any(|(k, max)| k == key && !(0.0..=*max).contains(&v)) =>
                {
                    let max = check.bounded_keys.iter().find(|(k, _)| k == key).unwrap().1;
                    failures.push(format!(
                        "{} [{}] {key}: {v} outside [0, {max}]",
                        check.file, check.section
                    ))
                }
                Some(Ok(_)) => {}
            }
        }
        // every value in a checked section must be finite, listed or not
        if let Ok(pairs) = section.as_obj() {
            for (k, v) in pairs {
                if let Ok(x) = v.as_f64() {
                    if !x.is_finite() {
                        failures.push(format!(
                            "{} [{}] {k}: non-finite value {x}",
                            check.file, check.section
                        ));
                    }
                }
            }
        }
    }

    if failures.is_empty() {
        println!("bench_guard: OK ({checked} required keys present and finite, smoke={smoke})");
    } else {
        eprintln!("bench_guard: FAILED ({} problems)", failures.len());
        for f in &failures {
            eprintln!("  - {f}");
        }
        std::process::exit(1);
    }
}
