//! Ablation playground: poke at the pieces of ARA in isolation —
//! staircase masks, guidance metric, the R=1 discontinuity — printing the
//! intermediate quantities the paper's Sec. 3 reasons about.
//!
//!   cargo run --release --example ablation_playground

use ara_compress::ara::{binary_mask, guidance_loss, guidance_metric, Staircase};
use ara_compress::coordinator::Pipeline;
use ara_compress::model::module_dims;
use ara_compress::Result;

fn main() -> Result<()> {
    let pl = Pipeline::new("micro-llama")?;
    let ws = pl.pretrained()?;
    let grams = pl.grams(&ws)?;
    let fm = pl.factored(&ws, &grams)?;
    let dims = module_dims(&pl.cfg);

    // --- 1. staircase masks under different α concentrations ---
    println!("== staircase (D=8, r=16): p = α·M ==");
    let st = Staircase::new(8, 16);
    for (label, alpha) in [
        ("uniform α", vec![0.125f64; 8]),
        ("mass on α₁ (keep little)", {
            let mut a = vec![0.0; 8];
            a[0] = 1.0;
            a
        }),
        ("mass on α_D (keep everything)", {
            let mut a = vec![0.0; 8];
            a[7] = 1.0;
            a
        }),
    ] {
        let p = st.prob_mask(&alpha);
        let pstr: Vec<String> = p.iter().map(|x| format!("{x:.2}")).collect();
        println!("  {label:<28} p = [{}]", pstr.join(" "));
    }

    // --- 2. per-module spectra and the guidance metric G_R ---
    println!("\n== guidance metric G_R vs R (Eq. 6) — first-layer modules ==");
    for d in dims.iter().take(7) {
        let f = &fm.factors[&d.name];
        let gs: Vec<String> = [0.2, 0.4, 0.6, 0.8, 1.0]
            .iter()
            .map(|&r| format!("{:.2}", guidance_metric(d, f, r)))
            .collect();
        let (lg, _) = guidance_loss(d, f, 0.8);
        println!(
            "  {:<22} G_R@[.2 .4 .6 .8 1.] = [{}]  L_g(0.8) = {:.2}",
            d.name.split("layers.0.").last().unwrap(),
            gs.join(" "),
            lg
        );
    }

    // --- 3. the R=1 parameter discontinuity ---
    println!("\n== the R=1 discontinuity (Sec. 1): params(k) around break-even ==");
    let d = &dims[0];
    let be = d.breakeven_rank();
    for k in [be.saturating_sub(2), be, be + 2, d.r_full()] {
        println!(
            "  k={k:<4} factored {} vs dense {}  ({})",
            d.factored_params(k),
            d.dense_params(),
            if d.factored_params(k) > d.dense_params() { "dense wins" } else { "factored wins" }
        );
    }

    // --- 4. mask state at a concrete probabilistic mask ---
    println!("\n== Eq. 3/4: ratio and binary mask from p ==");
    let p: Vec<f64> = (0..d.r_full()).map(|i| 1.0 / (1.0 + i as f64 * 0.2)).collect();
    let stt = binary_mask(d, &p);
    println!(
        "  {}: Σp = {:.2} → R = {:.3}, k = {}, dense = {}",
        d.name,
        p.iter().sum::<f64>(),
        stt.ratio,
        stt.k,
        stt.dense
    );
    Ok(())
}
