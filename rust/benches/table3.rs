//! Table 3: SVD+quantization hybrid vs pure quantization at equal memory
//! budget. Paper: ARA(4-bit) < Dense(3-bit) < Uniform(4-bit) in PPL at the
//! same bytes. We quantize the compressed factors (W_u, W_v) with GPTQ and
//! the dense baselines with GPTQ over the calibration Grams, report PPL +
//! avg accuracy + the real packed memory.

mod common;

use ara_compress::linalg::Mat;
use ara_compress::model::module_dims;
use ara_compress::quant::{gptq_quantize, QuantCfg};
use ara_compress::report::Table;
use ara_compress::svd::alloc_masks;
use common::{claim, pipeline};

fn main() {
    let model = "minillama-s";
    let pl = pipeline(model);
    let ws = pl.pretrained().expect("pretrain");
    let grams = pl.grams(&ws).expect("calibrate");
    let fm = pl.factored(&ws, &grams).expect("factorize");

    let q4 = QuantCfg { bits: 4, group: 32 };
    let q3 = QuantCfg { bits: 3, group: 32 };
    let dims = module_dims(&pl.cfg);

    // --- ARA @80% + 4-bit on factors ---
    let alloc = pl
        .allocate_spec("ara@0.35", &ws, &grams, &fm)
        .expect("ara alloc")
        .allocation;
    let masks = alloc_masks(&pl.cfg, &alloc);
    let mut fm_q = fm.clone();
    let mut ara_bytes = 0usize;
    for d in &dims {
        let f = fm_q.factors.get_mut(&d.name).unwrap();
        // quantize W_v with the input Gram (it faces the activations), W_u
        // with an identity Gram (its input is the whitened intermediate)
        let eye = Mat::eye(f.wv.shape[1]);
        f.wv = gptq_quantize(&f.wv, &grams[&d.name], q4).unwrap_or_else(|_| {
            gptq_quantize(&f.wv, &eye, q4).unwrap()
        });
        let eye_u = Mat::eye(f.wu.shape[1]);
        f.wu = gptq_quantize(&f.wu, &eye_u, q4).expect("gptq wu");
        let k = masks[&d.name].data.iter().filter(|&&x| x > 0.5).count();
        ara_bytes += q4.bytes(d.m, k) + q4.bytes(k, d.n);
    }
    let ara_row = pl
        .evaluate_masks("ARA(4-bit)", 0.35, &ws, &fm_q, &masks)
        .expect("eval ara q4");

    // --- Uniform @80% + 4-bit ---
    let ualloc = pl
        .allocate_spec("uniform@0.35", &ws, &grams, &fm)
        .expect("uniform")
        .allocation;
    let umasks = alloc_masks(&pl.cfg, &ualloc);
    let mut fm_u = fm.clone();
    let mut uni_bytes = 0usize;
    for d in &dims {
        let f = fm_u.factors.get_mut(&d.name).unwrap();
        let eye_u = Mat::eye(f.wu.shape[1]);
        f.wv = gptq_quantize(&f.wv, &grams[&d.name], q4).expect("gptq");
        f.wu = gptq_quantize(&f.wu, &eye_u, q4).expect("gptq");
        let k = umasks[&d.name].data.iter().filter(|&&x| x > 0.5).count();
        uni_bytes += q4.bytes(d.m, k) + q4.bytes(k, d.n);
    }
    let uni_row = pl
        .evaluate_masks("Uniform(4-bit)", 0.35, &ws, &fm_u, &umasks)
        .expect("eval uni q4");

    // --- Dense 3-bit (pure quantization at a similar byte budget) ---
    let mut ws_q = ws.clone();
    let mut dense_bytes = 0usize;
    for d in &dims {
        let w = ws_q.tensors.get(&d.name).unwrap().clone();
        let wq = gptq_quantize(&w, &grams[&d.name], q3).expect("gptq dense");
        ws_q.insert(d.name.clone(), wq);
        dense_bytes += q3.bytes(d.m, d.n);
    }
    let sc = &pl.scalecfg;
    let wiki =
        ara_compress::eval::perplexity_dense(&pl.cfg, &pl.rt, &ws_q, "synwiki", sc.eval_batches)
            .expect("ppl");
    let c4 = ara_compress::eval::perplexity_dense(&pl.cfg, &pl.rt, &ws_q, "sync4", sc.eval_batches)
        .expect("ppl");
    let zs = ara_compress::eval::zero_shot_suite(
        &pl.cfg,
        &pl.rt,
        &ara_compress::eval::Scorer::Dense { ws: &ws_q },
        sc.zs_items,
        99,
    )
    .expect("zs");

    let mut t = Table::new(
        "Table 3 — SVD+quant hybrid vs pure quant (compressible-module bytes)",
        &["Method", "Wiki2", "C4", "Avg%", "KiB"],
    );
    t.row(vec![
        "Uniform(4-bit)".into(),
        format!("{:.2}", uni_row.wiki_ppl),
        format!("{:.2}", uni_row.c4_ppl),
        format!("{:.2}", uni_row.avg_acc),
        format!("{}", uni_bytes / 1024),
    ]);
    t.row(vec![
        "Dense(3-bit)".into(),
        format!("{:.2}", wiki.ppl),
        format!("{:.2}", c4.ppl),
        format!("{:.2}", zs.average),
        format!("{}", dense_bytes / 1024),
    ]);
    t.row(vec![
        "ARA(4-bit)".into(),
        format!("{:.2}", ara_row.wiki_ppl),
        format!("{:.2}", ara_row.c4_ppl),
        format!("{:.2}", ara_row.avg_acc),
        format!("{}", ara_bytes / 1024),
    ]);
    t.print();

    claim("ARA(4-bit) wiki2 PPL ≤ Uniform(4-bit)", ara_row.wiki_ppl <= uni_row.wiki_ppl * 1.02);
    claim(
        "hybrid budgets comparable (within 2×)",
        (ara_bytes as f64 / dense_bytes as f64) < 2.0,
    );
}
