//! Table 5: mask-generation ablation — ARS (Gumbel-Sigmoid), Dobi-SVD₁
//! (tanh) and ARA (staircase) trained with the SAME objective (no L_g) on
//! the same loss surface. Paper shape: ARA ≥ Dobi > ARS at equal-or-fewer
//! epochs, demonstrating that monotonicity + global updates matter.

mod common;

use ara_compress::coordinator::MethodKind;
use ara_compress::report::Table;
use common::{claim, pipeline, push_row, table_headers};

fn main() {
    let model = "minillama-s";
    let pl = pipeline(model);
    let ws = pl.pretrained().expect("pretrain");
    let grams = pl.grams(&ws).expect("calibrate");
    let fm = pl.factored(&ws, &grams).expect("factorize");

    for ratio in [0.35, 0.25] {
        let mut t = Table::new(
            format!("Table 5 — mask ablation (no L_g) @ {:.0}%", ratio * 100.0),
            &table_headers(),
        );
        let mut results = Vec::new();
        for m in [MethodKind::Ars, MethodKind::Dobi, MethodKind::AraNoGuidance] {
            let alloc = pl.allocate(m, ratio, &ws, &grams, &fm).expect("alloc");
            let row = pl.evaluate(m.name(), &ws, &fm, &alloc).expect("eval");
            push_row(&mut t, &row);
            results.push((m, row));
        }
        t.print();

        let get = |k: MethodKind| results.iter().find(|(m, _)| *m == k).map(|(_, r)| r);
        if let (Some(ara), Some(ars)) = (get(MethodKind::AraNoGuidance), get(MethodKind::Ars)) {
            claim(
                &format!("@{ratio}: staircase mask ≤ Gumbel-Sigmoid (wiki2)"),
                ara.wiki_ppl <= ars.wiki_ppl * 1.02,
            );
        }
        if let (Some(ara), Some(dobi)) = (get(MethodKind::AraNoGuidance), get(MethodKind::Dobi)) {
            claim(
                &format!("@{ratio}: staircase mask ≤ tanh mask (c4)"),
                ara.c4_ppl <= dobi.c4_ppl * 1.05,
            );
        }
    }
}
