//! Table 5: mask-generation ablation — ARS (Gumbel-Sigmoid), Dobi-SVD₁
//! (tanh) and ARA (staircase) trained with the SAME objective (no L_g) on
//! the same loss surface. Paper shape: ARA ≥ Dobi > ARS at equal-or-fewer
//! epochs, demonstrating that monotonicity + global updates matter.

mod common;

use ara_compress::coordinator::EvalRow;
use ara_compress::report::Table;
use common::{claim, pipeline, push_row, table_headers};

fn main() {
    let model = "minillama-s";
    let pl = pipeline(model);
    let ws = pl.pretrained().expect("pretrain");
    let grams = pl.grams(&ws).expect("calibrate");
    let fm = pl.factored(&ws, &grams).expect("factorize");

    for ratio in [0.35, 0.25] {
        let mut t = Table::new(
            format!("Table 5 — mask ablation (no L_g) @ {:.0}%", ratio * 100.0),
            &table_headers(),
        );
        let mut results: Vec<(&str, EvalRow)> = Vec::new();
        for id in ["ars", "dobi", "ara-nolg"] {
            let plan = pl.allocate_spec(&format!("{id}@{ratio}"), &ws, &grams, &fm).expect("alloc");
            let row = pl.evaluate(&plan.label, &ws, &fm, &plan.allocation).expect("eval");
            push_row(&mut t, &row);
            results.push((id, row));
        }
        t.print();

        let get = |id: &str| results.iter().find(|(m, _)| *m == id).map(|(_, r)| r);
        if let (Some(ara), Some(ars)) = (get("ara-nolg"), get("ars")) {
            claim(
                &format!("@{ratio}: staircase mask ≤ Gumbel-Sigmoid (wiki2)"),
                ara.wiki_ppl <= ars.wiki_ppl * 1.02,
            );
        }
        if let (Some(ara), Some(dobi)) = (get("ara-nolg"), get("dobi")) {
            claim(
                &format!("@{ratio}: staircase mask ≤ tanh mask (c4)"),
                ara.c4_ppl <= dobi.c4_ppl * 1.05,
            );
        }
    }
}
