//! Table 7 (Appendix A.5): ablation on the staircase parameter count D.
//! Paper shape: quality saturates once D is large enough (100 ≈ 1000 >
//! 10); at our rank counts the sweep is {4, 16, 64}.

mod common;

use ara_compress::ara::{train_ara, AraConfig};
use ara_compress::report::Table;
use common::{claim, pipeline};

fn main() {
    let model = "minillama-s";
    let pl = pipeline(model);
    let ws = pl.pretrained().expect("pretrain");
    let grams = pl.grams(&ws).expect("calibrate");
    let fm = pl.factored(&ws, &grams).expect("factorize");
    let sc = pl.scalecfg.clone();

    let mut t = Table::new("Table 7 — ablation on D (staircase steps)", &["D", "Wiki2", "C4"]);
    let mut ppls = Vec::new();
    for d in [4usize, 16, 64] {
        let ac = AraConfig {
            target: 0.35,
            d,
            epochs: sc.alloc_epochs,
            samples: sc.alloc_samples,
            ..Default::default()
        };
        let (alloc, _) = train_ara(&pl.cfg, &pl.rt, &ws, &fm, &ac).expect("train");
        let row = pl.evaluate(&format!("D={d}"), &ws, &fm, &alloc).expect("eval");
        t.row(vec![format!("{d}"), format!("{:.2}", row.wiki_ppl), format!("{:.2}", row.c4_ppl)]);
        ppls.push(row.wiki_ppl);
    }
    t.print();

    claim(
        "quality saturates: D=16 within 5% of D=64",
        (ppls[1] - ppls[2]).abs() <= 0.05 * ppls[2],
    );
}
