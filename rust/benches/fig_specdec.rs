//! Self-speculative decoding sweep (DESIGN.md §8): drive the
//! continuous-batching scheduler through one deterministic greedy trace
//! three ways — plain decode, then speculative decode across a draft-plan
//! × draft-length grid (heavier compression rungs of the same ladder
//! drafting for the serving plan). Recorded per `<draft>_k<k>` combo into
//! `BENCH_PR9.json` (section `fig_specdec`): end-to-end `tok_s`, speedup
//! over the plain run, `accepted_per_verify` (∈ [0, k]) and
//! `draft_accept_rate` (∈ [0, 1]); plus the shared `plain_tok_s`
//! baseline. The claims pin the §8 contract: every speculative stream is
//! **bitwise identical** to the plain run's — speculation is a throughput
//! optimization, never a sampling change. `ARA_BENCH_SMOKE=1` shrinks the
//! grid for CI; `ARA_SPECDEC_REQS` overrides the trace length.

mod common;

use std::time::Instant;

use ara_compress::data::{corpus_spec, generate_tokens, Rng};
use ara_compress::report::Table;
use ara_compress::serving::{Request, SamplingParams, Scheduler, SpecDec};
use common::{bench_json_path_named, bench_section, claim, pipeline, record_bench_at, smoke};

struct SpecRun {
    tok_s: f64,
    accepted_per_verify: f64,
    accept_rate: f64,
    verify_passes: usize,
    streams: Vec<Vec<i32>>,
}

/// Drive the trace through `sched` (with `draft` naming the draft plan on
/// every request, or `None` for the plain path) and collect throughput,
/// acceptance telemetry, and the per-request token streams.
fn run_trace(sched: &mut Scheduler, reqs: &[Request], draft: Option<&str>) -> SpecRun {
    for r in reqs {
        sched.submit(Request { draft_spec: draft.map(str::to_string), ..r.clone() });
    }
    let t0 = Instant::now();
    let mut done = sched.run_to_completion().expect("serve loop");
    let wall = t0.elapsed().as_secs_f64().max(1e-9);
    done.sort_by_key(|c| c.id);
    let st = sched.stats();
    let tokens: usize = done.iter().map(|c| c.tokens.len()).sum();
    SpecRun {
        tok_s: tokens as f64 / wall,
        accepted_per_verify: st.accepted_per_verify(),
        accept_rate: st.draft_accept_rate(),
        verify_passes: st.verify_passes,
        streams: done.into_iter().map(|c| c.tokens).collect(),
    }
}

fn main() {
    let smoke = smoke();
    let model = "minillama-s";
    let pl = pipeline(model);
    let ws = pl.pretrained().expect("pretrain");
    let grams = pl.grams(&ws).expect("calibrate");
    let fm = pl.factored(&ws, &grams).expect("factorize");
    let bmax = *pl.cfg.decode_batches.last().unwrap();

    // deterministic greedy trace: mixed ragged prompts, generation
    // lengths long enough for several verify rounds per request
    let p = pl.cfg.prefill_len;
    let n_req = std::env::var("ARA_SPECDEC_REQS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if smoke { 6 } else { ara_compress::config::scaled(32, 12) });
    let stream = generate_tokens(pl.cfg.vocab, corpus_spec("synwiki"), 9191, 8192);
    let mut rng = Rng::new(0x59EC);
    let reqs: Vec<Request> = (0..n_req)
        .map(|_| {
            let len = 1 + rng.below(p);
            let off = rng.below(stream.len() - p);
            Request {
                prompt: stream[off..off + len].to_vec(),
                gen_len: 6 + rng.below(10),
                params: SamplingParams::greedy(),
                ..Default::default()
            }
        })
        .collect();

    // shared plain baseline (the speedup denominator, and the bitwise
    // reference every speculative combo is compared against)
    let target = pl.engine(&ws, &fm, "uniform-80", bmax).expect("target engine");
    let plain = run_trace(&mut Scheduler::new(&target), &reqs, None);

    // draft-plan × draft-length grid: heavier rungs of the same ladder
    let drafts: &[&str] = if smoke { &["uniform-40"] } else { &["uniform-40", "ara-40"] };
    let ks: &[usize] = if smoke { &[2] } else { &[2, 4] };
    let mut t = Table::new(
        format!("Fig specdec — {n_req} greedy requests, B={bmax}, target uniform-80"),
        &["Draft", "k", "tok/s", "speedup", "acc/verify", "acc rate", "verifies", "bitwise"],
    );
    t.row(vec![
        "(plain)".into(),
        "-".into(),
        format!("{:.0}", plain.tok_s),
        "1.00".into(),
        "-".into(),
        "-".into(),
        "0".into(),
        "-".into(),
    ]);
    let mut entries: Vec<(String, f64)> = vec![("plain_tok_s".into(), plain.tok_s)];
    for &spec in drafts {
        for &k in ks {
            let mut target = pl.engine(&ws, &fm, "uniform-80", bmax).expect("target engine");
            target.enable_verify(&pl.rt, k + 1).expect("verify specialization");
            let draft = pl.engine(&ws, &fm, spec, bmax).expect("draft engine");
            let sd = SpecDec::new(draft, spec, k).expect("spec dec");
            let mut sched = Scheduler::new(&target);
            sched.set_spec_dec(Some(sd)).expect("install spec dec");
            let r = run_trace(&mut sched, &reqs, Some(spec));
            let bitwise = r.streams == plain.streams;
            let speedup = r.tok_s / plain.tok_s.max(1e-9);
            t.row(vec![
                spec.into(),
                format!("{k}"),
                format!("{:.0}", r.tok_s),
                format!("{speedup:.2}"),
                format!("{:.2}", r.accepted_per_verify),
                format!("{:.2}", r.accept_rate),
                format!("{}", r.verify_passes),
                if bitwise { "yes".into() } else { "NO".into() },
            ]);
            claim(
                &format!("{spec} k={k}: streams bitwise-identical to plain decode"),
                bitwise,
            );
            claim(
                &format!("{spec} k={k}: verify rounds actually ran"),
                r.verify_passes > 0,
            );
            claim(
                &format!("{spec} k={k}: accepted_per_verify in [0, {k}]"),
                (0.0..=k as f64).contains(&r.accepted_per_verify),
            );
            entries.push((format!("{spec}_k{k}_tok_s"), r.tok_s));
            entries.push((format!("{spec}_k{k}_speedup"), speedup));
            entries.push((format!("{spec}_k{k}_accepted_per_verify"), r.accepted_per_verify));
            entries.push((format!("{spec}_k{k}_accept_rate"), r.accept_rate));
        }
    }
    t.print();
    entries.sort_by(|a, b| a.0.cmp(&b.0));
    record_bench_at(
        &bench_json_path_named("BENCH_PR9.json"),
        &bench_section("fig_specdec"),
        &entries,
    );
}
