//! Fig. 5 (Appendix A.4): decode throughput (tokens/sec) vs batch size and
//! vs generation length, ARA vs uniform at 80%/60%. Paper shape:
//! 60% > 80% > dense in throughput, and ARA ≥ uniform at equal ratio
//! (dense modules run as one matmul instead of two thin ones).
//!
//! Engines run over allocation-specialized AOT executables with
//! device-resident weights/KV caches (see serving/engine.rs). Measured
//! tokens/sec are appended to `BENCH_PR2.json` (section
//! `fig5_decode_tok_s`); the continuous-batching scheduler trace (req/s,
//! tok/s, p50/p95 latency under Poisson-ish arrivals with mixed prompt
//! lengths) is appended to `BENCH_PR3.json` (section `fig5_sched`); the
//! paged-KV shared-system-prompt workload (tok/s, prefix-cache hit rate,
//! pool utilization — part d) is appended to `BENCH_PR4.json` (section
//! `fig5_paged`). `ARA_BENCH_SMOKE=1` shrinks the sweep to a build/emit
//! check for CI; `ARA_SCHED_REQS` / `ARA_PAGED_REQS` override the trace
//! lengths.

mod common;

use std::time::Instant;

use ara_compress::coordinator::Pipeline;
use ara_compress::data::{corpus_spec, generate_tokens, Rng};
use ara_compress::report::Table;
use ara_compress::serving::{Engine, Request, SamplingParams, Scheduler};
use common::{
    bench_json_path_named, bench_section, claim, pipeline, record_bench, record_bench_at, smoke,
};

/// Drive the scheduler through a deterministic Poisson-ish arrival trace of
/// mixed-length prompts; returns (req/s, tok/s, p50 ms, p95 ms).
fn sched_trace(pl: &Pipeline, engine: &Engine, n_req: usize, seed: u64) -> (f64, f64, f64, f64) {
    let p = pl.cfg.prefill_len;
    let stream = generate_tokens(pl.cfg.vocab, corpus_spec("synwiki"), seed, 8192);
    let mut rng = Rng::new(seed ^ 0x5EED);
    // exponential inter-arrival times in units of decode steps, mean 0.5
    // (≈ 2 arrivals/step keeps the slots saturated without unbounded queues)
    let mut at = 0.0f64;
    let arrivals: Vec<(usize, Request)> = (0..n_req)
        .map(|_| {
            at += -(1.0 - rng.f64()).ln() * 0.5;
            let len = 1 + rng.below(p); // mixed ragged lengths 1..=p
            let off = rng.below(stream.len() - p);
            let gen_len = 2 + rng.below(12);
            let req = Request {
                prompt: stream[off..off + len].to_vec(),
                gen_len,
                params: SamplingParams::greedy(),
                ..Default::default()
            };
            (at.floor() as usize, req)
        })
        .collect();

    let mut sched = Scheduler::new(engine);
    let t0 = Instant::now();
    let mut next = 0usize;
    let mut step = 0usize;
    let mut latencies: Vec<f64> = Vec::with_capacity(n_req);
    while next < arrivals.len() || !sched.is_idle() {
        while next < arrivals.len() && arrivals[next].0 <= step {
            sched.submit(arrivals[next].1.clone());
            next += 1;
        }
        if !sched.is_idle() {
            for c in sched.step().expect("scheduler step") {
                latencies.push(c.latency_s);
            }
        }
        step += 1;
    }
    let wall = t0.elapsed().as_secs_f64().max(1e-9);
    if latencies.is_empty() {
        return (0.0, 0.0, 0.0, 0.0); // degenerate trace (ARA_SCHED_REQS=0)
    }
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |q: f64| latencies[((latencies.len() as f64 * q) as usize).min(latencies.len() - 1)];
    (
        n_req as f64 / wall,
        sched.stats().tokens_generated as f64 / wall,
        pct(0.50) * 1e3,
        pct(0.95) * 1e3,
    )
}

fn main() {
    let smoke = smoke();
    let model = "minillama-s";
    let pl = pipeline(model);
    let ws = pl.pretrained().expect("pretrain");
    let grams = pl.grams(&ws).expect("calibrate");
    let fm = pl.factored(&ws, &grams).expect("factorize");

    let allocs: &[&str] = if smoke {
        &["dense", "uniform-80"]
    } else {
        &["dense", "uniform-80", "uniform-60", "ara-80", "ara-60"]
    };
    let stream = generate_tokens(pl.cfg.vocab, corpus_spec("synwiki"), 77, 4096);
    let prompts = |b: usize| -> Vec<Vec<i32>> {
        (0..b)
            .map(|i| {
                let off = (i * pl.cfg.prefill_len) % (stream.len() - pl.cfg.prefill_len);
                stream[off..off + pl.cfg.prefill_len].to_vec()
            })
            .collect()
    };

    // --- (a) throughput vs batch size, gen_len fixed ---
    let gen_len = if smoke { 4 } else { ara_compress::config::scaled(32, 8) };
    let batches: Vec<usize> = if smoke {
        vec![*pl.cfg.decode_batches.first().unwrap()]
    } else {
        pl.cfg.decode_batches.clone()
    };
    let mut ta = Table::new(
        format!("Fig 5a — decode tok/s vs batch size (gen_len={gen_len})"),
        &{
            let mut h = vec!["Alloc"];
            h.extend(batches.iter().map(|b| match b {
                1 => "B=1",
                2 => "B=2",
                4 => "B=4",
                8 => "B=8",
                _ => "B=16",
            }));
            h
        },
    );
    let mut tok_s: std::collections::HashMap<(String, usize), f64> = Default::default();
    let mut entries: Vec<(String, f64)> = Vec::new();
    for alloc_name in allocs {
        let mut cells = vec![alloc_name.to_string()];
        for &b in &batches {
            let engine = pl.engine(&ws, &fm, alloc_name, b).expect("engine");
            // warmup + measure
            let _ = engine.generate(&prompts(b), 4).expect("warmup");
            let (_, stats) = engine.generate(&prompts(b), gen_len).expect("gen");
            cells.push(format!("{:.0}", stats.tok_per_s()));
            tok_s.insert((alloc_name.to_string(), b), stats.tok_per_s());
            entries.push((format!("{alloc_name}_b{b}_tok_s"), stats.tok_per_s()));
        }
        ta.row(cells);
    }
    ta.print();
    entries.sort_by(|a, b| a.0.cmp(&b.0));
    record_bench(&bench_section("fig5_decode_tok_s"), &entries);

    // --- (c) continuous-batching scheduler under a mixed-length trace ---
    let sched_allocs: &[&str] = if smoke { &["uniform-80"] } else { &["uniform-80", "ara-80"] };
    let n_req = std::env::var("ARA_SCHED_REQS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if smoke { 6 } else { ara_compress::config::scaled(48, 16) });
    let bmax = *pl.cfg.decode_batches.last().unwrap();
    let mut ts = Table::new(
        format!("Fig 5c — continuous batching, {n_req} ragged requests, B={bmax}"),
        &["Alloc", "req/s", "tok/s", "p50 ms", "p95 ms"],
    );
    let mut sched_entries: Vec<(String, f64)> = Vec::new();
    for alloc_name in sched_allocs {
        let engine = pl.engine(&ws, &fm, alloc_name, bmax).expect("engine");
        let (req_s, tps, p50, p95) = sched_trace(&pl, &engine, n_req, 1234);
        ts.row(vec![
            alloc_name.to_string(),
            format!("{req_s:.1}"),
            format!("{tps:.0}"),
            format!("{p50:.1}"),
            format!("{p95:.1}"),
        ]);
        sched_entries.push((format!("{alloc_name}_req_s"), req_s));
        sched_entries.push((format!("{alloc_name}_tok_s"), tps));
        sched_entries.push((format!("{alloc_name}_p50_ms"), p50));
        sched_entries.push((format!("{alloc_name}_p95_ms"), p95));
    }
    ts.print();
    sched_entries.sort_by(|a, b| a.0.cmp(&b.0));
    record_bench_at(
        &bench_json_path_named("BENCH_PR3.json"),
        &bench_section("fig5_sched"),
        &sched_entries,
    );

    // --- (d) paged KV pool under a shared-system-prompt workload ---
    // every request opens with the same system prompt (the full prefill
    // window); the paged scheduler prefills the shared blocks once and
    // serves the rest from the prefix cache — measured: decode tok/s,
    // prefix-cache hit rate, and pool high-water utilization.
    let paged_allocs: &[&str] = if smoke { &["uniform-80"] } else { &["uniform-80", "ara-80"] };
    let n_shared = std::env::var("ARA_PAGED_REQS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if smoke { 5 } else { ara_compress::config::scaled(32, 12) });
    let sys_prompt: Vec<i32> = stream[..pl.cfg.prefill_len].to_vec();
    let mut tp = Table::new(
        format!("Fig 5d — paged KV pool, {n_shared} shared-prompt requests, B={bmax}"),
        &["Alloc", "tok/s", "hit rate", "pool util", "prefills"],
    );
    let mut paged_entries: Vec<(String, f64)> = Vec::new();
    for alloc_name in paged_allocs {
        let engine = pl.engine(&ws, &fm, alloc_name, bmax).expect("engine");
        let mut sched = Scheduler::new(&engine);
        let mut rng = Rng::new(4321);
        // the first request registers the shared chain; one step, then the
        // fleet arrives and rides the prefix cache
        sched.submit(Request {
            prompt: sys_prompt.clone(),
            gen_len: 2 + rng.below(10),
            params: SamplingParams::greedy(),
            ..Default::default()
        });
        let t0 = Instant::now();
        sched.step().expect("scheduler step");
        for _ in 1..n_shared {
            sched.submit(Request {
                prompt: sys_prompt.clone(),
                gen_len: 2 + rng.below(10),
                params: SamplingParams::greedy(),
                ..Default::default()
            });
        }
        sched.run_to_completion().expect("drain");
        let wall = t0.elapsed().as_secs_f64().max(1e-9);
        let stats = sched.stats();
        let tok_s = stats.tokens_generated as f64 / wall;
        let hit = stats.prefix_hit_rate();
        let util = stats.pool_peak_util;
        tp.row(vec![
            alloc_name.to_string(),
            format!("{tok_s:.0}"),
            format!("{hit:.2}"),
            format!("{util:.2}"),
            format!("{}", stats.prefills),
        ]);
        paged_entries.push((format!("{alloc_name}_shared_tok_s"), tok_s));
        paged_entries.push((format!("{alloc_name}_prefix_hit_rate"), hit));
        paged_entries.push((format!("{alloc_name}_pool_util"), util));
    }
    tp.print();
    paged_entries.sort_by(|a, b| a.0.cmp(&b.0));
    record_bench_at(
        &bench_json_path_named("BENCH_PR4.json"),
        &bench_section("fig5_paged"),
        &paged_entries,
    );

    if smoke {
        println!("  [bench-smoke] fig5 check mode: sweep + claims skipped");
        return;
    }

    // --- (b) throughput vs generation length at the largest batch ---
    let lens = [8usize, 16, 32, 64];
    let mut tb = Table::new(
        format!("Fig 5b — decode tok/s vs gen length (batch={bmax})"),
        &["Alloc", "L=8", "L=16", "L=32", "L=64"],
    );
    for alloc_name in allocs {
        let engine = pl.engine(&ws, &fm, alloc_name, bmax).expect("engine");
        let _ = engine.generate(&prompts(bmax), 4).expect("warmup");
        let mut cells = vec![alloc_name.to_string()];
        for &l in &lens {
            let (_, stats) = engine.generate(&prompts(bmax), l).expect("gen");
            cells.push(format!("{:.0}", stats.tok_per_s()));
        }
        tb.row(cells);
    }
    tb.print();

    // reproduction claims at the largest batch
    let g = |a: &str| tok_s[&(a.to_string(), bmax)];
    println!(
        "  ratios @B={bmax}: ara60/ara80 = {:.2}×, ara80/uni80 = {:.2}×, ara60/uni60 = {:.2}×",
        g("ara-60") / g("ara-80"),
        g("ara-80") / g("uniform-80"),
        g("ara-60") / g("uniform-60"),
    );
    claim("60% faster than 80% (ARA)", g("ara-60") > g("ara-80"));
    claim("compressed faster than dense", g("uniform-60") > g("dense"));
    claim("ARA ≥ 0.95× uniform at equal ratio", g("ara-80") >= 0.95 * g("uniform-80"));
}
