//! Fig. 5 (Appendix A.4): decode throughput (tokens/sec) vs batch size and
//! vs generation length, ARA vs uniform at 80%/60%. Paper shape:
//! 60% > 80% > dense in throughput, and ARA ≥ uniform at equal ratio
//! (dense modules run as one matmul instead of two thin ones).
//!
//! Engines run over allocation-specialized AOT executables with
//! device-resident weights/KV caches (see serving/engine.rs). Measured
//! tokens/sec are appended to `BENCH_PR2.json` (section
//! `fig5_decode_tok_s`) so later PRs can regress against them.
//! `ARA_BENCH_SMOKE=1` shrinks the sweep to a build/emit check for CI.

mod common;

use ara_compress::data::{corpus_spec, generate_tokens};
use ara_compress::report::Table;
use ara_compress::serving::Engine;
use common::{bench_section, claim, load_alloc, pipeline, record_bench, smoke};

fn main() {
    let smoke = smoke();
    let model = "minillama-s";
    let pl = pipeline(model);
    let ws = pl.pretrained().expect("pretrain");
    let grams = pl.grams(&ws).expect("calibrate");
    let fm = pl.factored(&ws, &grams).expect("factorize");

    let allocs: &[&str] = if smoke {
        &["dense", "uniform-80"]
    } else {
        &["dense", "uniform-80", "uniform-60", "ara-80", "ara-60"]
    };
    let stream = generate_tokens(pl.cfg.vocab, corpus_spec("synwiki"), 77, 4096);
    let prompts = |b: usize| -> Vec<Vec<i32>> {
        (0..b)
            .map(|i| {
                let off = (i * pl.cfg.prefill_len) % (stream.len() - pl.cfg.prefill_len);
                stream[off..off + pl.cfg.prefill_len].to_vec()
            })
            .collect()
    };

    // --- (a) throughput vs batch size, gen_len fixed ---
    let gen_len = if smoke { 4 } else { ara_compress::config::scaled(32, 8) };
    let batches: Vec<usize> = if smoke {
        vec![*pl.cfg.decode_batches.first().unwrap()]
    } else {
        pl.cfg.decode_batches.clone()
    };
    let mut ta = Table::new(
        format!("Fig 5a — decode tok/s vs batch size (gen_len={gen_len})"),
        &{
            let mut h = vec!["Alloc"];
            h.extend(batches.iter().map(|b| match b { 1 => "B=1", 2 => "B=2", 4 => "B=4", 8 => "B=8", _ => "B=16" }));
            h
        },
    );
    let mut tok_s: std::collections::HashMap<(String, usize), f64> = Default::default();
    let mut entries: Vec<(String, f64)> = Vec::new();
    for alloc_name in allocs {
        let alloc = load_alloc(&pl, model, alloc_name);
        let mut cells = vec![alloc_name.to_string()];
        for &b in &batches {
            let engine =
                Engine::new(&pl.cfg, &pl.rt, &ws, &fm, &alloc, alloc_name, b).expect("engine");
            // warmup + measure
            let _ = engine.generate(&prompts(b), 4).expect("warmup");
            let (_, stats) = engine.generate(&prompts(b), gen_len).expect("gen");
            cells.push(format!("{:.0}", stats.tok_per_s()));
            tok_s.insert((alloc_name.to_string(), b), stats.tok_per_s());
            entries.push((format!("{alloc_name}_b{b}_tok_s"), stats.tok_per_s()));
        }
        ta.row(cells);
    }
    ta.print();
    entries.sort_by(|a, b| a.0.cmp(&b.0));
    record_bench(&bench_section("fig5_decode_tok_s"), &entries);

    if smoke {
        println!("  [bench-smoke] fig5 check mode: sweep + claims skipped");
        return;
    }

    // --- (b) throughput vs generation length at the largest batch ---
    let bmax = *batches.last().unwrap();
    let lens = [8usize, 16, 32, 64];
    let mut tb = Table::new(
        format!("Fig 5b — decode tok/s vs gen length (batch={bmax})"),
        &["Alloc", "L=8", "L=16", "L=32", "L=64"],
    );
    for alloc_name in allocs {
        let alloc = load_alloc(&pl, model, alloc_name);
        let engine =
            Engine::new(&pl.cfg, &pl.rt, &ws, &fm, &alloc, alloc_name, bmax).expect("engine");
        let _ = engine.generate(&prompts(bmax), 4).expect("warmup");
        let mut cells = vec![alloc_name.to_string()];
        for &l in &lens {
            let (_, stats) = engine.generate(&prompts(bmax), l).expect("gen");
            cells.push(format!("{:.0}", stats.tok_per_s()));
        }
        tb.row(cells);
    }
    tb.print();

    // reproduction claims at the largest batch
    let g = |a: &str| tok_s[&(a.to_string(), bmax)];
    println!(
        "  ratios @B={bmax}: ara60/ara80 = {:.2}×, ara80/uni80 = {:.2}×, ara60/uni60 = {:.2}×",
        g("ara-60") / g("ara-80"),
        g("ara-80") / g("uniform-80"),
        g("ara-60") / g("uniform-60"),
    );
    claim("60% faster than 80% (ARA)", g("ara-60") > g("ara-80"));
    claim("compressed faster than dense", g("uniform-60") > g("dense"));
    claim("ARA ≥ 0.95× uniform at equal ratio", g("ara-80") >= 0.95 * g("uniform-80"));
}
