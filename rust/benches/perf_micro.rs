//! §Perf micro-benchmarks: per-layer timing of the hot paths so the
//! optimization log in EXPERIMENTS.md §Perf is reproducible.
//!
//!  L3: decode-step latency breakdown (execute_b vs tuple-split vs argmax),
//!      executable-call overhead, feed construction.
//!  L1-proxy: score_masked wall time (the Pallas masked-lowrank kernel
//!      dominates its FLOPs) vs score_dense.
//!  Substrate: Jacobi SVD & Cholesky throughput at module shapes.

mod common;

use std::time::Instant;

use ara_compress::data::{corpus_spec, generate_tokens};
use ara_compress::linalg::{cholesky, svd, Mat};
use ara_compress::model::Allocation;
use ara_compress::serving::Engine;
use ara_compress::svd::alloc_masks;
use common::pipeline;

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    // warmup
    f();
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    println!("  {name:<44} {:>10.3} ms/iter", per * 1e3);
    per
}

fn main() {
    let model = "minillama-s";
    let pl = pipeline(model);
    let ws = pl.pretrained().expect("pretrain");
    let grams = pl.grams(&ws).expect("calibrate");
    let fm = pl.factored(&ws, &grams).expect("factorize");

    println!("== perf_micro: L3 executable-call overheads ==");
    // score executables: masked (pallas lowrank path) vs dense
    {
        use ara_compress::eval::{perplexity_dense, perplexity_masked};
        let alloc = ara_compress::baselines::uniform_alloc(&pl.cfg, 0.8);
        let masks = alloc_masks(&pl.cfg, &alloc);
        bench("score_dense (1 batch eval)", 5, || {
            perplexity_dense(&pl.cfg, &pl.rt, &ws, "synwiki", 1).unwrap();
        });
        bench("score_masked (1 batch eval, lowrank kernel)", 5, || {
            perplexity_masked(&pl.cfg, &pl.rt, &ws, &fm, &masks, "synwiki", 1).unwrap();
        });
    }

    // decode step cost per allocation
    {
        let stream = generate_tokens(pl.cfg.vocab, corpus_spec("synwiki"), 7, 2048);
        let b = *pl.cfg.decode_batches.last().unwrap();
        let prompts: Vec<Vec<i32>> = (0..b)
            .map(|i| stream[i * 16..i * 16 + pl.cfg.prefill_len].to_vec())
            .collect();
        for name in ["dense", "uniform-80", "ara-80"] {
            let path = pl
                .paths
                .artifacts
                .join("allocations")
                .join(format!("{model}.{name}.json"));
            let cfgp = pl
                .paths
                .configs
                .join("allocations")
                .join(format!("{model}.{name}.json"));
            let alloc =
                Allocation::load(if cfgp.exists() { &cfgp } else { &path }).expect("alloc");
            let engine =
                Engine::new(&pl.cfg, &pl.rt, &ws, &fm, &alloc, name, b).expect("engine");
            bench(&format!("decode 16 steps, B={b}, {name}"), 3, || {
                engine.generate(&prompts, 16).unwrap();
            });
        }
    }

    println!("== perf_micro: substrate linalg ==");
    {
        let mut rng = ara_compress::data::Rng::new(1);
        let d = pl.cfg.d_model;
        let mut a = Mat::zeros(d, d);
        for v in a.data.iter_mut() {
            *v = rng.normal();
        }
        let h = a.gram();
        bench(&format!("cholesky {d}×{d}"), 5, || {
            let mut hd = h.clone();
            for i in 0..d {
                let x = hd.at(i, i) + 1.0;
                hd.set(i, i, x);
            }
            cholesky(&hd).unwrap();
        });
        bench(&format!("jacobi svd {d}×{d}"), 2, || {
            svd(&a);
        });
        let ff = pl.cfg.d_ff;
        let mut wide = Mat::zeros(d, ff);
        for v in wide.data.iter_mut() {
            *v = rng.normal();
        }
        bench(&format!("jacobi svd {d}×{ff} (wdown shape)"), 2, || {
            svd(&wide);
        });
    }

    println!("== perf_micro: full factorization pipeline ==");
    bench("factorize all modules", 1, || {
        ara_compress::svd::factorize(&pl.cfg, &ws, &grams, 1e-3).unwrap();
    });
}
