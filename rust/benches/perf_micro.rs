//! §Perf micro-benchmarks: per-layer timing of the hot paths so the
//! optimization log is reproducible, emitting machine-readable results to
//! `BENCH_PR2.json` (see benches/common/mod.rs::record_bench).
//!
//!  Kernel: blocked/threaded matmul GFLOP/s at representative shapes.
//!  L3: train-step latency at the small/medium presets, decode-step
//!      latency + tokens/sec per allocation, score_masked vs score_dense.
//!  Substrate: Jacobi SVD & Cholesky throughput at module shapes.
//!
//! `ARA_BENCH_SMOKE=1` runs a tiny-preset check mode (CI): everything
//! builds and the JSON is emitted, no timing assertions anywhere.

mod common;

use std::collections::HashMap;
use std::time::Instant;

use ara_compress::config::{model_by_name, Paths};
use ara_compress::data::{corpus_spec, generate_tokens, Rng};
use ara_compress::kernels;
use ara_compress::linalg::{cholesky, svd, Mat};
use ara_compress::model::init_weights;
use ara_compress::runtime::{Feed, Runtime};
use ara_compress::svd::alloc_masks;
use ara_compress::tensor::IntTensor;
use common::{bench_section, pipeline, record_bench, smoke};

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    // warmup
    f();
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    println!("  {name:<44} {:>10.3} ms/iter", per * 1e3);
    per
}

/// Time one interpreted train_step at a preset (random weights/tokens —
/// pretraining is irrelevant to step latency).
fn train_step_ms(model: &str, iters: usize) -> f64 {
    let paths = Paths::discover().expect("paths");
    let cfg = model_by_name(&paths.configs, model).expect("model preset");
    let rt = Runtime::new(paths.artifact_dir(model)).expect("runtime");
    let exe = rt.load("train_step").expect("train_step");
    let ws = init_weights(&cfg, 3);
    let mut rng = Rng::new(5);
    let toks = IntTensor::from_vec(
        &[cfg.batch_train, cfg.seq_train],
        (0..cfg.batch_train * cfg.seq_train)
            .map(|_| rng.below(cfg.vocab) as i32)
            .collect(),
    );
    let tgts = toks.clone();
    let mut feeds: HashMap<&str, Feed> = HashMap::new();
    for (name, t) in &ws.tensors {
        feeds.insert(name.as_str(), Feed::F32(t));
    }
    feeds.insert("tokens", Feed::I32(&toks));
    feeds.insert("targets", Feed::I32(&tgts));
    bench(&format!("train_step {model}"), iters, || {
        exe.run(&feeds).unwrap();
    }) * 1e3
}

fn main() {
    let smoke = smoke();
    let iters = if smoke { 1 } else { 5 };
    let model = if smoke { "micro-llama" } else { "minillama-s" };
    let mut entries: Vec<(String, f64)> = Vec::new();

    println!("== perf_micro: blocked matmul kernel (ARA_THREADS={}) ==", kernels::num_threads());
    {
        let shapes: &[(usize, usize, usize)] = if smoke {
            &[(64, 64, 64)]
        } else {
            &[(128, 128, 128), (256, 256, 256), (64, 512, 512), (4, 512, 512)]
        };
        let mut rng = Rng::new(2);
        for &(m, k, n) in shapes {
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
            let mut out = vec![0.0f32; m * n];
            let per = bench(&format!("matmul {m}x{k}x{n}"), iters.max(3), || {
                out.fill(0.0);
                kernels::matmul_f32(&a, &b, m, k, n, false, false, &mut out);
            });
            let gflops = (2.0 * (m * k * n) as f64) / per / 1e9;
            println!("    -> {gflops:.2} GFLOP/s");
            entries.push((format!("matmul_{m}x{k}x{n}_gflops"), gflops));
        }
    }

    // PR 6: per-SIMD-tier kernel throughput, recorded to BENCH_PR6.json.
    // Both micro-kernel paths are swept on every tier the host can run:
    // the packed axpy path (square shape, tb=false) and the small-m dot
    // fast path (decode projection shape, tb=true). The native-vs-scalar
    // speedup on the packed shape is the tier's reason to exist — the
    // bench guard requires it ≥ 1 in real baselines.
    println!("== perf_micro: SIMD tier sweep (active: {}) ==", kernels::active_tier().name());
    {
        use ara_compress::kernels::{available_tiers, matmul_f32_tier, SimdTier};
        let mut tier_entries: Vec<(String, f64)> = Vec::new();
        let nt = kernels::num_threads();
        let (packed, dot) = if smoke { ((64, 64, 64), (4, 64, 64)) } else { ((256, 256, 256), (4, 512, 512)) };
        let mut rng = Rng::new(4);
        let mut sweep = |m: usize, k: usize, n: usize, tb: bool, rng: &mut Rng| -> Vec<(SimdTier, f64)> {
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
            let mut out = vec![0.0f32; m * n];
            let tag = if tb { "_dot" } else { "" };
            available_tiers()
                .into_iter()
                .map(|tier| {
                    let per =
                        bench(&format!("matmul {m}x{k}x{n}{tag} [{}]", tier.name()), iters.max(3), || {
                            out.fill(0.0);
                            matmul_f32_tier(tier, &a, &b, m, k, n, false, tb, &mut out, nt);
                        });
                    let gflops = (2.0 * (m * k * n) as f64) / per / 1e9;
                    tier_entries.push((format!("matmul_{m}x{k}x{n}{tag}_{}_gflops", tier.name()), gflops));
                    (tier, gflops)
                })
                .collect()
        };
        let packed_res = sweep(packed.0, packed.1, packed.2, false, &mut rng);
        sweep(dot.0, dot.1, dot.2, true, &mut rng);
        // best-first tier order: [0] is native, last is scalar
        let native = packed_res[0].1;
        let scalar = packed_res.last().unwrap().1;
        let speedup = native / scalar;
        println!("    -> native/scalar speedup {speedup:.2}x on the packed path");
        tier_entries
            .push((format!("matmul_{}x{}x{}_native_speedup", packed.0, packed.1, packed.2), speedup));
        common::record_bench_at(
            &common::bench_json_path_named("BENCH_PR6.json"),
            &bench_section("simd_tiers"),
            &tier_entries,
        );
    }

    println!("== perf_micro: train-step latency ==");
    {
        let presets: &[&str] =
            if smoke { &["micro-llama"] } else { &["minillama-s", "minillama-m"] };
        for preset in presets {
            let ms = train_step_ms(preset, iters);
            entries.push((format!("train_step_ms_{preset}"), ms));
        }
    }

    let pl = pipeline(model);
    let ws = pl.pretrained().expect("pretrain");
    let grams = pl.grams(&ws).expect("calibrate");
    let fm = pl.factored(&ws, &grams).expect("factorize");

    println!("== perf_micro: L3 executable-call overheads ==");
    // score executables: masked (pallas lowrank path) vs dense
    {
        use ara_compress::eval::{perplexity_dense, perplexity_masked};
        let alloc = ara_compress::compress::computed_alloc(&pl.cfg, "uniform-80")
            .expect("computed name")
            .expect("uniform-80");
        let masks = alloc_masks(&pl.cfg, &alloc);
        let d = bench("score_dense (1 batch eval)", iters, || {
            perplexity_dense(&pl.cfg, &pl.rt, &ws, "synwiki", 1).unwrap();
        });
        let m = bench("score_masked (1 batch eval, lowrank kernel)", iters, || {
            perplexity_masked(&pl.cfg, &pl.rt, &ws, &fm, &masks, "synwiki", 1).unwrap();
        });
        entries.push(("score_dense_ms".to_string(), d * 1e3));
        entries.push(("score_masked_ms".to_string(), m * 1e3));
    }

    // decode step cost + throughput per allocation
    {
        let stream = generate_tokens(pl.cfg.vocab, corpus_spec("synwiki"), 7, 2048);
        let b = *pl.cfg.decode_batches.last().unwrap();
        let prompts: Vec<Vec<i32>> = (0..b)
            .map(|i| stream[i * 16..i * 16 + pl.cfg.prefill_len].to_vec())
            .collect();
        for name in ["dense", "uniform-80", "ara-80"] {
            let engine = pl.engine(&ws, &fm, name, b).expect("engine");
            let per = bench(&format!("decode 16 steps, B={b}, {name}"), iters.min(3), || {
                engine.generate(&prompts, 16).unwrap();
            });
            let (_, stats) = engine.generate(&prompts, 16).expect("gen");
            entries.push((format!("decode16_ms_{name}_b{b}"), per * 1e3));
            entries.push((format!("decode_tok_s_{name}_b{b}"), stats.tok_per_s()));
        }
    }

    if !smoke {
        println!("== perf_micro: substrate linalg ==");
        let mut rng = Rng::new(1);
        let d = pl.cfg.d_model;
        let mut a = Mat::zeros(d, d);
        for v in a.data.iter_mut() {
            *v = rng.normal();
        }
        let h = a.gram();
        let c = bench(&format!("cholesky {d}×{d}"), iters, || {
            let mut hd = h.clone();
            for i in 0..d {
                let x = hd.at(i, i) + 1.0;
                hd.set(i, i, x);
            }
            cholesky(&hd).unwrap();
        });
        entries.push(("cholesky_ms".to_string(), c * 1e3));
        let s = bench(&format!("jacobi svd {d}×{d}"), 2, || {
            svd(&a);
        });
        entries.push(("jacobi_svd_ms".to_string(), s * 1e3));
        let ff = pl.cfg.d_ff;
        let mut wide = Mat::zeros(d, ff);
        for v in wide.data.iter_mut() {
            *v = rng.normal();
        }
        bench(&format!("jacobi svd {d}×{ff} (wdown shape)"), 2, || {
            svd(&wide);
        });

        println!("== perf_micro: full factorization pipeline ==");
        let f = bench("factorize all modules", 1, || {
            ara_compress::svd::factorize(&pl.cfg, &ws, &grams, 1e-3).unwrap();
        });
        entries.push(("factorize_ms".to_string(), f * 1e3));
    }

    record_bench(&bench_section("perf_micro"), &entries);
}
