//! Open-loop load sweep over the HTTP serving front end (DESIGN.md §7):
//! a loopback `HttpServer` over a tiny engine, driven at seeded
//! exponential arrival rates calibrated against the server's measured
//! service rate — ×0.5 through ×4, deliberately past saturation. Arrivals
//! fire on schedule whether or not earlier requests finished (open-loop;
//! closed-loop generators gate arrivals on completions and hide queueing
//! collapse), so past saturation the bounded admission queue fills and
//! the 429 shed path carries the overload. Recorded per rate into
//! `BENCH_PR8.json` (section `fig_http`): goodput (tokens from 200
//! responses per wall second), p50/p99 end-to-end latency, and the
//! shed (429) rate. Claims pin determinism over the wire: every 200 body
//! is byte-identical across arrival rates (greedy parity, regardless of
//! batch composition or shed pattern). `ARA_BENCH_SMOKE=1` shrinks the
//! sweep for CI; `ARA_HTTP_REQS` overrides the per-rate request count.

mod common;

use std::collections::HashMap;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use ara_compress::data::{corpus_spec, generate_tokens, Rng};
use ara_compress::json::{self, Json};
use ara_compress::report::Table;
use ara_compress::serving::http::wire::http_call;
use ara_compress::serving::{HttpCfg, HttpServer, Router, RouterCfg};
use common::{bench_json_path_named, bench_section, claim, pipeline, record_bench_at, smoke};

struct Outcome {
    idx: usize,
    status: u16,
    body: Vec<u8>,
    tokens: usize,
    latency_s: f64,
}

fn completion_json(prompt: &[i32], max_tokens: usize) -> String {
    let toks = Json::Arr(prompt.iter().map(|&t| json::n(t as f64)).collect());
    json::obj(vec![("prompt", toks), ("max_tokens", json::n(max_tokens as f64))]).dump()
}

fn token_count(body: &[u8]) -> usize {
    std::str::from_utf8(body)
        .ok()
        .and_then(|t| json::parse(t).ok())
        .and_then(|j| j.req("token_count").ok().and_then(|v| v.as_usize().ok()))
        .unwrap_or(0)
}

/// Fire `bodies` at `addr` with exponential inter-arrivals at `lambda`
/// req/s (seeded), without waiting for earlier requests — the open-loop
/// contract. Returns every request's outcome plus the sweep wall time.
fn open_loop(addr: &str, bodies: &[String], lambda: f64, seed: u64) -> (Vec<Outcome>, f64) {
    let mut rng = Rng::new(seed);
    let (tx, rx) = mpsc::channel::<Outcome>();
    let t0 = Instant::now();
    let mut at = 0.0f64;
    let mut dispatchers = Vec::with_capacity(bodies.len());
    for (idx, body) in bodies.iter().enumerate() {
        at += -(1.0 - rng.f64()).ln() / lambda;
        let wait = Duration::from_secs_f64(at).saturating_sub(t0.elapsed());
        if !wait.is_zero() {
            std::thread::sleep(wait);
        }
        let (addr, body, tx) = (addr.to_string(), body.clone(), tx.clone());
        dispatchers.push(std::thread::spawn(move || {
            let sent = Instant::now();
            let out = match http_call(&addr, "POST", "/v1/completions", Some(&body)) {
                Ok(r) => Outcome {
                    idx,
                    status: r.status,
                    tokens: token_count(&r.body),
                    body: r.body,
                    latency_s: sent.elapsed().as_secs_f64(),
                },
                Err(_) => Outcome {
                    idx,
                    status: 0,
                    tokens: 0,
                    body: Vec::new(),
                    latency_s: sent.elapsed().as_secs_f64(),
                },
            };
            let _ = tx.send(out);
        }));
    }
    drop(tx);
    let outcomes: Vec<Outcome> = rx.iter().collect();
    let wall = t0.elapsed().as_secs_f64().max(1e-9);
    for d in dispatchers {
        let _ = d.join();
    }
    (outcomes, wall)
}

fn rate_label(m: f64) -> String {
    if m < 1.0 {
        format!("x0{}", (m * 10.0).round() as usize)
    } else {
        format!("x{}", m.round() as usize)
    }
}

fn pct(sorted: &[f64], q: f64) -> f64 {
    match sorted.is_empty() {
        true => 0.0,
        false => sorted[((sorted.len() as f64 * q) as usize).min(sorted.len() - 1)],
    }
}

fn main() {
    let smoke = smoke();
    let model = "minillama-s";
    let pl = pipeline(model);
    let vocab = pl.cfg.vocab;
    let p = pl.cfg.prefill_len;
    let batch = *pl.cfg.decode_batches.last().unwrap();
    let gen_len = if smoke { 3 } else { 8 };
    let n_req = std::env::var("ARA_HTTP_REQS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if smoke { 10 } else { ara_compress::config::scaled(48, 16) });

    // small admission bound so the over-saturated rates visibly shed
    let router = Router::spawn_with(
        RouterCfg { queue_depth: 2 * batch, ..RouterCfg::default() },
        move || {
            let ws = pl.pretrained().expect("pretrain");
            let grams = pl.grams(&ws).expect("calibrate");
            let fm = pl.factored(&ws, &grams).expect("factorize");
            pl.engine(&ws, &fm, "uniform-80", batch).expect("engine")
        },
    );
    let server = HttpServer::bind("127.0.0.1:0", router, vocab, HttpCfg::from_env())
        .expect("bind loopback");
    let addr = server.local_addr().expect("local addr").to_string();
    let stop = server.shutdown_handle();
    let server = std::thread::spawn(move || server.run());

    // the same deterministic request set at every rate (ragged prompts)
    let stream = generate_tokens(vocab, corpus_spec("synwiki"), 8080, 8192);
    let mut rng = Rng::new(0x8117);
    let bodies: Vec<String> = (0..n_req)
        .map(|_| {
            let len = 1 + rng.below(p);
            let off = rng.below(stream.len() - p);
            completion_json(&stream[off..off + len], gen_len)
        })
        .collect();

    // calibrate the service rate: one full-batch closed burst, timed
    let t0 = Instant::now();
    let warm: Vec<_> = (0..batch)
        .map(|i| {
            let (addr, body) = (addr.clone(), bodies[i % bodies.len()].clone());
            std::thread::spawn(move || http_call(&addr, "POST", "/v1/completions", Some(&body)))
        })
        .collect();
    for w in warm {
        w.join().expect("warmup thread").expect("warmup call");
    }
    let mu = batch as f64 / t0.elapsed().as_secs_f64().max(1e-9);
    println!("calibrated service rate: {mu:.1} req/s (batch {batch}, gen_len {gen_len})");

    let mults: &[f64] = if smoke { &[0.5, 1.0, 4.0] } else { &[0.5, 1.0, 2.0, 4.0] };
    let mut t = Table::new(
        format!("Fig http — open-loop sweep, {n_req} req/rate, μ={mu:.1} req/s"),
        &["Rate", "λ req/s", "goodput tok/s", "ok", "shed", "p50 ms", "p99 ms"],
    );
    let mut entries: Vec<(String, f64)> = Vec::new();
    let mut ok_bodies: Vec<HashMap<usize, Vec<u8>>> = Vec::new();
    for (ri, &m) in mults.iter().enumerate() {
        let lambda = (m * mu).max(0.1);
        let (outcomes, wall) = open_loop(&addr, &bodies, lambda, 0x9E37 + ri as u64);
        assert_eq!(outcomes.len(), n_req, "every arrival must resolve");
        let mut lat: Vec<f64> = outcomes.iter().map(|o| o.latency_s).collect();
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let ok: Vec<&Outcome> = outcomes.iter().filter(|o| o.status == 200).collect();
        let shed = outcomes.iter().filter(|o| o.status == 429).count();
        let good_tokens: usize = ok.iter().map(|o| o.tokens).sum();
        let goodput = good_tokens as f64 / wall;
        let (p50, p99) = (pct(&lat, 0.50) * 1e3, pct(&lat, 0.99) * 1e3);
        let lbl = rate_label(m);
        t.row(vec![
            lbl.clone(),
            format!("{lambda:.1}"),
            format!("{goodput:.0}"),
            format!("{}", ok.len()),
            format!("{shed}"),
            format!("{p50:.1}"),
            format!("{p99:.1}"),
        ]);
        entries.push((format!("{lbl}_goodput_tok_s"), goodput));
        entries.push((format!("{lbl}_ok_rate"), ok.len() as f64 / n_req as f64));
        entries.push((format!("{lbl}_shed_rate"), shed as f64 / n_req as f64));
        entries.push((format!("{lbl}_p50_ms"), p50));
        entries.push((format!("{lbl}_p99_ms"), p99));
        ok_bodies.push(ok.into_iter().map(|o| (o.idx, o.body.clone())).collect());
    }
    t.print();
    entries.sort_by(|a, b| a.0.cmp(&b.0));
    record_bench_at(
        &bench_json_path_named("BENCH_PR8.json"),
        &bench_section("fig_http"),
        &entries,
    );

    // determinism over the wire: a request that got 200 at two different
    // arrival rates produced byte-identical bodies (greedy parity is
    // independent of batch composition and shed pattern)
    let base = &ok_bodies[0];
    for (ri, &m) in mults.iter().enumerate().skip(1) {
        let mut compared = 0usize;
        let mut bitwise = true;
        for (idx, body) in &ok_bodies[ri] {
            if let Some(b) = base.get(idx) {
                compared += 1;
                bitwise &= body == b;
            }
        }
        claim(
            &format!("{}: {compared} 200 bodies byte-identical to x05 run", rate_label(m)),
            bitwise && compared > 0,
        );
    }
    claim(
        "saturated rates shed (bounded admission engaged past μ)",
        ok_bodies.last().is_some_and(|last| last.len() < n_req),
    );

    stop.shutdown();
    server.join().expect("server thread").expect("clean server shutdown");
}
