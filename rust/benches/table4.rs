//! Table 4: ARA vs structured pruning (LLM-Pruner-, FLAP-, SliceGPT-like)
//! at 80% compression. Paper shape: ARA beats all three on PPL and average
//! accuracy; FLAP is the strongest pruner.

mod common;

use std::collections::BTreeMap;

use ara_compress::baselines::pruning::{flap, llm_pruner, slicegpt};
use ara_compress::data::{batches, corpus_spec, generate_tokens};
use ara_compress::eval::{perplexity_dense, zero_shot_suite, Scorer};
use ara_compress::report::Table;
use ara_compress::runtime::Feed;
use ara_compress::tensor::Tensor;
use common::{claim, pipeline};

fn main() {
    let model = "minillama-s";
    let pl = pipeline(model);
    let ws = pl.pretrained().expect("pretrain");
    let grams = pl.grams(&ws).expect("calibrate");
    let fm = pl.factored(&ws, &grams).expect("factorize");
    let sc = pl.scalecfg.clone();

    // gradient snapshot for LLM-Pruner importance: one train_step call
    let exe = pl.rt.load("train_step").expect("train_step");
    let stream = generate_tokens(
        pl.cfg.vocab,
        corpus_spec("sync4"),
        0xBEEF,
        pl.cfg.batch_train * (pl.cfg.seq_train + 1) + 1,
    );
    let (toks, tgts) = &batches(&stream, pl.cfg.batch_train, pl.cfg.seq_train)[0];
    let mut feeds = std::collections::HashMap::new();
    for (name, t) in &ws.tensors {
        feeds.insert(name.as_str(), Feed::F32(t));
    }
    feeds.insert("tokens", Feed::I32(toks));
    feeds.insert("targets", Feed::I32(tgts));
    let out = exe.run(&feeds).expect("grad snapshot");
    let mut grads: BTreeMap<String, Tensor> = BTreeMap::new();
    for d in ara_compress::model::module_dims(&pl.cfg) {
        grads.insert(d.name.clone(), out.tensor(&format!("grad:{}", d.name)).unwrap());
    }

    let mut t = Table::new(
        "Table 4 — vs structured pruning @ 35% (≙ paper 80%)",
        &["Method", "Wiki2", "Ratio", "Avg%"],
    );

    let dense = pl.evaluate_dense(&ws).expect("dense");
    t.row(vec!["Dense".into(), format!("{:.2}", dense.wiki_ppl), "1.00".into(),
               format!("{:.2}", dense.avg_acc)]);

    let mut pruned_rows = Vec::new();
    let pruned = [
        llm_pruner(&pl.cfg, &ws, &grads, 0.35).expect("llm-pruner"),
        flap(&pl.cfg, &ws, &grams, 0.35).expect("flap"),
        slicegpt(&pl.cfg, &ws, &grams, 0.35).expect("slicegpt"),
    ];
    for pm in &pruned {
        let wiki = perplexity_dense(&pl.cfg, &pl.rt, &pm.ws, "synwiki", sc.eval_batches)
            .expect("ppl");
        let zs = zero_shot_suite(&pl.cfg, &pl.rt, &Scorer::Dense { ws: &pm.ws }, sc.zs_items, 99)
            .expect("zs");
        t.row(vec![
            pm.method.into(),
            format!("{:.2}", wiki.ppl),
            format!("{:.2}", pm.ratio),
            format!("{:.2}", zs.average),
        ]);
        pruned_rows.push((pm.method, wiki.ppl, zs.average));
    }

    let alloc = pl
        .allocate_spec("ara@0.35", &ws, &grams, &fm)
        .expect("ara")
        .allocation;
    let ara = pl.evaluate("ARA", &ws, &fm, &alloc).expect("eval");
    t.row(vec![
        "ARA".into(),
        format!("{:.2}", ara.wiki_ppl),
        format!("{:.2}", ara.ratio),
        format!("{:.2}", ara.avg_acc),
    ]);
    t.print();

    let best_prune_ppl = pruned_rows.iter().map(|r| r.1).fold(f64::MAX, f64::min);
    claim("ARA wiki2 PPL ≤ best structured pruner", ara.wiki_ppl <= best_prune_ppl * 1.02);
}
