//! Shared helpers for the table/figure harnesses.
//!
//! Every bench is a plain-main printer (criterion is not in the offline
//! vendor set); timing series use std::time. Scale with ARA_SCALE.

#![allow(dead_code)]
use std::path::PathBuf;

use ara_compress::coordinator::{EvalRow, Pipeline};
use ara_compress::json::{self, Json};
use ara_compress::report::{f2, Table};

/// Standard Table-1-style row formatting.
pub fn push_row(t: &mut Table, r: &EvalRow) {
    let mut cells = vec![r.method.clone(), f2(r.wiki_ppl), f2(r.c4_ppl)];
    for (_, acc) in &r.task_accs {
        cells.push(format!("{acc:.1}"));
    }
    cells.push(format!("{:.2}", r.avg_acc));
    t.row(cells);
}

pub fn table_headers() -> Vec<&'static str> {
    vec![
        "Method", "Wiki2", "C4", "ARC-e", "ARC-c", "Hella", "OBQA", "Wino", "MathQA", "PIQA",
        "Avg%",
    ]
}

/// Build a pipeline, failing with a actionable message if artifacts are
/// missing.
pub fn pipeline(model: &str) -> Pipeline {
    match Pipeline::new(model) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("cannot run bench for {model}: {e}\n(hint: `make artifacts`)");
            std::process::exit(0); // treat as skip, not failure
        }
    }
}

/// Shape-check helper: print PASS/FAIL for a reproduction claim.
pub fn claim(name: &str, ok: bool) {
    println!("  [{}] {}", if ok { "PASS" } else { "WARN" }, name);
}

/// Bench smoke mode (`ARA_BENCH_SMOKE=1`, used by CI): tiny iteration
/// counts and presets, no timing assertions — only proves the harness
/// builds, runs, and emits the baseline JSON. Smoke results are written
/// to separate `*_smoke` JSON sections so they never clobber a real
/// baseline (see [`bench_section`]).
pub fn smoke() -> bool {
    match std::env::var("ARA_BENCH_SMOKE") {
        Ok(v) => !v.is_empty() && v != "0" && v != "false",
        Err(_) => false,
    }
}

/// Section name for this run: `<base>` for real baselines, `<base>_smoke`
/// for smoke runs, so check-mode numbers never overwrite the recorded
/// perf trajectory.
pub fn bench_section(base: &str) -> String {
    if smoke() {
        format!("{base}_smoke")
    } else {
        base.to_string()
    }
}

/// Resolve a repo-root bench baseline file (located by walking up to
/// `configs/models.json`, the same anchor `config::Paths` uses).
pub fn bench_json_path_named(file: &str) -> PathBuf {
    let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        if dir.join("configs").join("models.json").exists() {
            return dir.join(file);
        }
        if !dir.pop() {
            return PathBuf::from(file);
        }
    }
}

/// The PR-2 interpreter baseline path: `ARA_BENCH_OUT` if set, else
/// `BENCH_PR2.json` at the repo root.
pub fn bench_json_path() -> PathBuf {
    if let Ok(p) = std::env::var("ARA_BENCH_OUT") {
        return PathBuf::from(p);
    }
    bench_json_path_named("BENCH_PR2.json")
}

/// Merge `section` into the PR-2 bench baseline (replacing the section if
/// present, preserving everything else) so subsequent PRs have a perf
/// trajectory to regress against.
pub fn record_bench(section: &str, entries: &[(String, f64)]) {
    record_bench_at(&bench_json_path(), section, entries)
}

/// Like [`record_bench`], but into an explicit baseline file (the PR-3
/// scheduler sections live in `BENCH_PR3.json`).
pub fn record_bench_at(path: &std::path::Path, section: &str, entries: &[(String, f64)]) {
    let path = path.to_path_buf();
    // Missing file ⇒ fresh baseline; an unparsable file is NOT silently
    // replaced — that would wipe the recorded trajectory of every other
    // section.
    let mut root = match std::fs::read_to_string(&path) {
        Err(_) => Json::Obj(Vec::new()),
        Ok(s) => match json::parse(&s) {
            Ok(j) => j,
            Err(e) => {
                eprintln!(
                    "  [bench-json] refusing to overwrite unparsable {}: {e}",
                    path.display()
                );
                return;
            }
        },
    };
    let obj = Json::Obj(entries.iter().map(|(k, v)| (k.clone(), Json::Num(*v))).collect());
    if let Json::Obj(pairs) = &mut root {
        if let Some(p) = pairs.iter_mut().find(|(k, _)| k == section) {
            p.1 = obj;
        } else {
            pairs.push((section.to_string(), obj));
        }
    } else {
        root = Json::Obj(vec![(section.to_string(), obj)]);
    }
    match std::fs::write(&path, root.dump()) {
        Ok(()) => println!("  [bench-json] section `{section}` -> {}", path.display()),
        Err(e) => eprintln!("  [bench-json] cannot write {}: {e}", path.display()),
    }
}
