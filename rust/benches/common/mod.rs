//! Shared helpers for the table/figure harnesses.
//!
//! Every bench is a plain-main printer (criterion is not in the offline
//! vendor set); timing series use std::time. Scale with ARA_SCALE.

#![allow(dead_code)]
use ara_compress::coordinator::{EvalRow, Pipeline};
use ara_compress::report::{f2, Table};

/// Standard Table-1-style row formatting.
pub fn push_row(t: &mut Table, r: &EvalRow) {
    let mut cells = vec![r.method.clone(), f2(r.wiki_ppl), f2(r.c4_ppl)];
    for (_, acc) in &r.task_accs {
        cells.push(format!("{acc:.1}"));
    }
    cells.push(format!("{:.2}", r.avg_acc));
    t.row(cells);
}

pub fn table_headers() -> Vec<&'static str> {
    vec![
        "Method", "Wiki2", "C4", "ARC-e", "ARC-c", "Hella", "OBQA", "Wino", "MathQA", "PIQA",
        "Avg%",
    ]
}

/// Build a pipeline, failing with a actionable message if artifacts are
/// missing.
pub fn pipeline(model: &str) -> Pipeline {
    match Pipeline::new(model) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("cannot run bench for {model}: {e}\n(hint: `make artifacts`)");
            std::process::exit(0); // treat as skip, not failure
        }
    }
}

/// Shape-check helper: print PASS/FAIL for a reproduction claim.
pub fn claim(name: &str, ok: bool) {
    println!("  [{}] {}", if ok { "PASS" } else { "WARN" }, name);
}
