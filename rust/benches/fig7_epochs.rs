//! Fig. 7 (Appendix A.5): PPL vs training epochs at fixed samples. Paper
//! shape: clear improvement by epoch ~5 (modules flipping to dense), then
//! diminishing returns past ~10. We also print the per-epoch dense-module
//! trace that explains the knee.

mod common;

use ara_compress::ara::{train_ara, AraConfig};
use ara_compress::report::Table;
use common::{claim, pipeline};

fn main() {
    let model = "minillama-s";
    let pl = pipeline(model);
    let ws = pl.pretrained().expect("pretrain");
    let grams = pl.grams(&ws).expect("calibrate");
    let fm = pl.factored(&ws, &grams).expect("factorize");
    let sc = pl.scalecfg.clone();

    let epoch_counts = [1usize, 2, 4, 8, 12];
    let mut t = Table::new(
        "Fig 7 — PPL vs training epochs (fixed samples)",
        &["Epochs", "Wiki2", "C4", "dense modules"],
    );
    let mut ppls = Vec::new();
    for &e in &epoch_counts {
        let ac = AraConfig {
            target: 0.35,
            epochs: e,
            samples: sc.alloc_samples,
            ..Default::default()
        };
        let (alloc, trace) = train_ara(&pl.cfg, &pl.rt, &ws, &fm, &ac).expect("train");
        let row = pl.evaluate(&format!("{e}"), &ws, &fm, &alloc).expect("eval");
        t.row(vec![
            format!("{e}"),
            format!("{:.2}", row.wiki_ppl),
            format!("{:.2}", row.c4_ppl),
            format!("{}", trace.epochs.last().map(|x| x.3).unwrap_or(0)),
        ]);
        ppls.push(row.wiki_ppl);
    }
    t.print();

    let early = ppls[0] - ppls[2]; // 1 → 4 epochs
    let late = ppls[3] - ppls[4]; // 8 → 12 epochs
    println!("  early gain (1→4): {early:.3}, late gain (8→12): {late:.3}");
    claim("diminishing returns after the knee", early >= late - 0.02 * ppls[4]);
}
