//! Table 8 (Appendix A.5): sensitivity to λ₁ = λ₂ = λ. Paper shape: final
//! quality is NOT very sensitive to λ across {50, 100, 200}.

mod common;

use ara_compress::ara::{train_ara, AraConfig};
use ara_compress::report::Table;
use common::{claim, pipeline};

fn main() {
    let model = "minillama-s";
    let pl = pipeline(model);
    let ws = pl.pretrained().expect("pretrain");
    let grams = pl.grams(&ws).expect("calibrate");
    let fm = pl.factored(&ws, &grams).expect("factorize");
    let sc = pl.scalecfg.clone();

    let mut t = Table::new(
        "Table 8 — ablation on λ (λ1 = λ2)",
        &["λ", "Wiki2", "C4", "Avg%"],
    );
    let mut ppls = Vec::new();
    for lam in [50.0, 100.0, 200.0] {
        let ac = AraConfig {
            target: 0.35,
            lambda1: lam,
            lambda2: lam,
            epochs: sc.alloc_epochs,
            samples: sc.alloc_samples,
            ..Default::default()
        };
        let (alloc, _) = train_ara(&pl.cfg, &pl.rt, &ws, &fm, &ac).expect("train");
        let row = pl
            .evaluate(&format!("λ={lam}"), &ws, &fm, &alloc)
            .expect("eval");
        t.row(vec![
            format!("{lam}"),
            format!("{:.2}", row.wiki_ppl),
            format!("{:.2}", row.c4_ppl),
            format!("{:.2}", row.avg_acc),
        ]);
        ppls.push(row.wiki_ppl);
    }
    t.print();

    let maxp = ppls.iter().cloned().fold(f64::MIN, f64::max);
    let minp = ppls.iter().cloned().fold(f64::MAX, f64::min);
    claim("λ-insensitive: spread ≤ 10%", (maxp - minp) <= 0.10 * minp);
}
