//! fig_sweep: the Table 1/2 allocation grid driven through
//! `Pipeline::sweep` — every registry method × the standard operating
//! ratios, sharing one pretrain/calibrate/factorize substrate. Emits a
//! machine-readable `fig_sweep` section (per-spec achieved ratio, dense
//! count, allocation wall-ms) to `BENCH_PR5.json`, guarded by
//! `examples/bench_guard.rs` (achieved ∈ (0, 1], wall-ms ≥ 0).
//!
//! `ARA_BENCH_SMOKE=1` (CI) runs a tiny grid on the micro preset into the
//! `fig_sweep_smoke` section; the real baseline covers all seven methods
//! on minillama-s at the paper-equivalent 35%/25% points.

mod common;

use ara_compress::compress::ALL_METHOD_IDS;
use ara_compress::report::Table;
use common::{bench_json_path_named, bench_section, pipeline, record_bench_at, smoke};

fn main() {
    let t0 = std::time::Instant::now();
    let smoke = smoke();
    let model = if smoke { "micro-llama" } else { "minillama-s" };
    let specs: Vec<String> = if smoke {
        ["uniform", "dlp", "ara"].iter().map(|s| s.to_string()).collect()
    } else {
        ALL_METHOD_IDS.iter().map(|s| s.to_string()).collect()
    };
    let ratios: Vec<f64> = if smoke { vec![0.5] } else { vec![0.35, 0.25] };

    let pl = pipeline(model);
    let plans = pl.sweep(&specs, &ratios).expect("sweep");

    let mut t = Table::new(
        format!("fig_sweep — {model}, {} specs × {} ratios", specs.len(), ratios.len()),
        &["Spec", "Target", "Achieved", "Dense", "Wall ms"],
    );
    let mut entries: Vec<(String, f64)> = Vec::new();
    for p in &plans {
        t.row(vec![
            p.spec.clone(),
            format!("{:.2}", p.target),
            format!("{:.4}", p.achieved),
            format!("{}/{}", p.allocation.dense_count(), p.allocation.modules.len()),
            format!("{:.0}", p.wall_ms),
        ]);
        entries.push((format!("{}_achieved", p.spec), p.achieved));
        entries.push((format!("{}_dense_count", p.spec), p.allocation.dense_count() as f64));
        entries.push((format!("{}_wall_ms", p.spec), p.wall_ms));
    }
    t.print();

    record_bench_at(
        &bench_json_path_named("BENCH_PR5.json"),
        &bench_section("fig_sweep"),
        &entries,
    );
    println!("fig_sweep wall time: {:.1}s", t0.elapsed().as_secs_f64());
}
