//! Table 2: the larger model pair at 80% compression — scalability of the
//! method ordering (paper Table 2: ARA best on both LLaMA2-13B and
//! Qwen3-14B stand-ins; Dobi strongest baseline; STRS unstable).

mod common;

use ara_compress::compress::ALL_METHOD_IDS;
use ara_compress::coordinator::EvalRow;
use ara_compress::report::Table;
use common::{claim, pipeline, push_row, table_headers};

fn main() {
    let t0 = std::time::Instant::now();
    for model in ["minillama-m", "miniqwen-m"] {
        let pl = pipeline(model);
        let ws = pl.pretrained().expect("pretrain");
        let grams = pl.grams(&ws).expect("calibrate");
        let fm = pl.factored(&ws, &grams).expect("factorize");
        let dense = pl.evaluate_dense(&ws).expect("dense eval");

        let mut t = Table::new(format!("Table 2 — {model} @ 35% compression (≙ paper 80%)"), &table_headers());
        push_row(&mut t, &dense);
        let mut rows: Vec<(&str, EvalRow)> = Vec::new();
        for id in ALL_METHOD_IDS {
            let plan = match pl.allocate_spec(&format!("{id}@0.35"), &ws, &grams, &fm) {
                Ok(p) => p,
                Err(e) => {
                    eprintln!("  {id} failed: {e}");
                    continue;
                }
            };
            let row = pl.evaluate(&plan.label, &ws, &fm, &plan.allocation).expect("eval");
            push_row(&mut t, &row);
            rows.push((id, row));
        }
        t.print();

        let get = |id: &str| rows.iter().find(|(m, _)| *m == id).map(|(_, r)| r);
        if let (Some(ara), Some(uni)) = (get("ara"), get("uniform")) {
            claim(
                &format!("{model}: ARA wiki2 PPL ≤ Uniform"),
                ara.wiki_ppl <= uni.wiki_ppl * 1.02,
            );
        }
    }
    println!("table2 wall time: {:.1}s", t0.elapsed().as_secs_f64());
}
