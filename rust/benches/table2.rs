//! Table 2: the larger model pair at 80% compression — scalability of the
//! method ordering (paper Table 2: ARA best on both LLaMA2-13B and
//! Qwen3-14B stand-ins; Dobi strongest baseline; STRS unstable).

mod common;

use ara_compress::coordinator::{EvalRow, MethodKind, ALL_METHODS};
use ara_compress::report::Table;
use common::{claim, pipeline, push_row, table_headers};

fn main() {
    let t0 = std::time::Instant::now();
    for model in ["minillama-m", "miniqwen-m"] {
        let pl = pipeline(model);
        let ws = pl.pretrained().expect("pretrain");
        let grams = pl.grams(&ws).expect("calibrate");
        let fm = pl.factored(&ws, &grams).expect("factorize");
        let dense = pl.evaluate_dense(&ws).expect("dense eval");

        let mut t = Table::new(format!("Table 2 — {model} @ 35% compression (≙ paper 80%)"), &table_headers());
        push_row(&mut t, &dense);
        let mut rows: Vec<(MethodKind, EvalRow)> = Vec::new();
        for m in ALL_METHODS {
            let alloc = match pl.allocate(m, 0.35, &ws, &grams, &fm) {
                Ok(a) => a,
                Err(e) => {
                    eprintln!("  {} failed: {e}", m.name());
                    continue;
                }
            };
            let row = pl.evaluate(m.name(), &ws, &fm, &alloc).expect("eval");
            push_row(&mut t, &row);
            rows.push((m, row));
        }
        t.print();

        let get = |k: MethodKind| rows.iter().find(|(m, _)| *m == k).map(|(_, r)| r);
        if let (Some(ara), Some(uni)) = (get(MethodKind::Ara), get(MethodKind::Uniform)) {
            claim(
                &format!("{model}: ARA wiki2 PPL ≤ Uniform"),
                ara.wiki_ppl <= uni.wiki_ppl * 1.02,
            );
        }
    }
    println!("table2 wall time: {:.1}s", t0.elapsed().as_secs_f64());
}
