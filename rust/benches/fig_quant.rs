//! Quantized low-rank serving sweep (DESIGN.md §9): the ratio × precision
//! grid for int8 SVD factors. For each compression ratio the same `ara`
//! rank allocation is served twice — f32 factors and packed int8 factors
//! (`?quant=int8&group=32`) — and three axes are recorded per cell into
//! `BENCH_PR10.json` (section `fig_quant`): decode `tok_s` through the
//! serving engine, resident factor `bytes` (packed codes + group scales
//! for int8, 4 bytes/elem for f32), and masked-eval `ppl`. The quantized
//! cell additionally records `ppl_delta = (int8 - f32) / f32`, and the
//! **perplexity-delta quality gate** (`eval::check_ppl_gate`, threshold
//! `ARA_PPL_GATE`, default 20%) fails the build — non-zero exit — when
//! int8 degrades quality past the threshold. `ARA_BENCH_SMOKE=1` shrinks
//! the grid to one ratio for CI.

mod common;

use ara_compress::data::{corpus_spec, generate_tokens};
use ara_compress::eval::{check_ppl_gate, perplexity_masked, ppl_gate_threshold};
use ara_compress::model::{Allocation, ModuleAlloc};
use ara_compress::quant::{quantized_factors, PackedInt8, QuantScheme};
use ara_compress::report::Table;
use ara_compress::svd::{alloc_masks, FactoredModel};
use common::{bench_json_path_named, bench_section, claim, pipeline, record_bench_at, smoke};

const GROUP: usize = 32;

/// Bytes the low-rank factor weights keep resident at serve time: packed
/// int8 codes + f32 group scales when quantized, 4 bytes per element for
/// f32. Dense (uncompressed) modules are identical in both columns and
/// excluded — the grid measures what quantization changes.
fn factor_bytes(fm: &FactoredModel, alloc: &Allocation, int8: bool) -> f64 {
    let mut total = 0usize;
    for (name, ma) in &alloc.modules {
        let k = match ma {
            ModuleAlloc::Rank(k) => *k,
            ModuleAlloc::Dense => continue,
        };
        let (u, v) = fm.factors[name].truncate(k);
        total += if int8 {
            PackedInt8::quantize(&u, GROUP).bytes() + PackedInt8::quantize(&v, GROUP).bytes()
        } else {
            4 * (u.data.len() + v.data.len())
        };
    }
    total as f64
}

fn main() {
    let smoke = smoke();
    let model = "minillama-s";
    let pl = pipeline(model);
    let ws = pl.pretrained().expect("pretrain");
    let grams = pl.grams(&ws).expect("calibrate");
    let fm = pl.factored(&ws, &grams).expect("factorize");

    let ratios: &[&str] = if smoke { &["0.8"] } else { &["0.8", "0.6"] };
    let eval_b = if smoke { 1 } else { 2 };
    let thr = ppl_gate_threshold();

    let b = *pl.cfg.decode_batches.last().unwrap();
    let stream = generate_tokens(pl.cfg.vocab, corpus_spec("synwiki"), 7, 4096);
    let prompts: Vec<Vec<i32>> = (0..b)
        .map(|i| stream[i * 16..i * 16 + pl.cfg.prefill_len].to_vec())
        .collect();

    let mut t = Table::new(
        format!("Fig quant — ratio × precision grid, B={b}, gate ≤ {:.0}%", thr * 100.0),
        &["Spec", "prec", "tok/s", "factor KiB", "ppl", "Δppl %", "gate"],
    );
    let mut entries: Vec<(String, f64)> = vec![("gate_threshold".into(), thr)];
    let mut gate_failed = false;

    for r in ratios {
        let fspec = format!("ara@{r}");
        let qspec = format!("{fspec}?quant=int8&group={GROUP}");
        let fplan = pl.allocate_spec(&fspec, &ws, &grams, &fm).expect("f32 plan");
        let qplan = pl.allocate_spec(&qspec, &ws, &grams, &fm).expect("quant plan");

        // quality: masked eval over the served factor values — f32 factors
        // vs their quantize→dequantize twin (exactly what the engine's
        // packed weights decode to, pinned by tests/quant.rs)
        let fppl = perplexity_masked(
            &pl.cfg,
            &pl.rt,
            &ws,
            &fm,
            &alloc_masks(&pl.cfg, &fplan.allocation),
            "synwiki",
            eval_b,
        )
        .expect("f32 ppl")
        .ppl;
        let fq = quantized_factors(&fm, &qplan.allocation, GROUP);
        let qppl = perplexity_masked(
            &pl.cfg,
            &pl.rt,
            &ws,
            &fq,
            &alloc_masks(&pl.cfg, &qplan.allocation),
            "synwiki",
            eval_b,
        )
        .expect("quant ppl")
        .ppl;

        // bytes: what the factor weights keep resident at serve time
        let fbytes = factor_bytes(&fm, &fplan.allocation, false);
        let qbytes = factor_bytes(&fm, &qplan.allocation, true);
        claim(&format!("{fspec}: int8 factors smaller than f32"), qbytes < fbytes);

        // throughput: greedy decode through each serving engine
        let fe = pl.engine_for_plan(&ws, &fm, &fplan, b).expect("f32 engine");
        let (_, fstats) = fe.generate(&prompts, 16).expect("f32 gen");
        let qe = pl.engine_for_plan(&ws, &fm, &qplan, b).expect("quant engine");
        let (_, qstats) = qe.generate(&prompts, 16).expect("quant gen");
        claim(
            &format!("{qspec}: engine reports the int8/g{GROUP} recipe"),
            qstats.quant == Some(QuantScheme { bits: 8, group: GROUP }) && fstats.quant.is_none(),
        );

        // the quality gate: relative ppl regression past ARA_PPL_GATE
        // fails the build
        let delta = match check_ppl_gate(fppl, qppl, thr) {
            Ok(d) => {
                claim(&format!("{fspec}: ppl gate (Δ ≤ {:.0}%)", thr * 100.0), true);
                d
            }
            Err(e) => {
                eprintln!("{e}");
                claim(&format!("{fspec}: ppl gate (Δ ≤ {:.0}%)", thr * 100.0), false);
                gate_failed = true;
                (qppl - fppl) / fppl
            }
        };

        for (prec, tok_s, bytes, ppl, d) in [
            ("f32", fstats.tok_per_s(), fbytes, fppl, None),
            ("int8", qstats.tok_per_s(), qbytes, qppl, Some(delta)),
        ] {
            t.row(vec![
                fspec.clone(),
                prec.into(),
                format!("{tok_s:.0}"),
                format!("{:.1}", bytes / 1024.0),
                format!("{ppl:.3}"),
                d.map_or("-".into(), |d| format!("{:+.2}", d * 100.0)),
                d.map_or("-".into(), |d| if d <= thr { "pass".into() } else { "FAIL".into() }),
            ]);
            entries.push((format!("{fspec}_{prec}_tok_s"), tok_s));
            entries.push((format!("{fspec}_{prec}_bytes"), bytes));
            entries.push((format!("{fspec}_{prec}_ppl"), ppl));
        }
        entries.push((format!("{fspec}_ppl_delta"), delta));
        entries.push((format!("{fspec}_bytes_ratio"), qbytes / fbytes.max(1.0)));
    }

    t.print();
    entries.sort_by(|a, b| a.0.cmp(&b.0));
    record_bench_at(
        &bench_json_path_named("BENCH_PR10.json"),
        &bench_section("fig_quant"),
        &entries,
    );
    if gate_failed {
        eprintln!("fig_quant: perplexity gate failed (threshold {thr}; tune ARA_PPL_GATE)");
        std::process::exit(1);
    }
}
