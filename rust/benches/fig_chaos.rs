//! Chaos sweep for the serving resilience layer (DESIGN.md §5): drive the
//! continuous-batching scheduler through the same deterministic
//! Poisson-ish arrival trace under increasing seeded fault rates
//! (`rate@R` Bernoulli decode faults plus a pool-pressure spike and a
//! latency stall — see `serving/faults.rs`), with a bench-side admission
//! cap standing in for the router's bounded queue. Recorded per rate into
//! `BENCH_PR7.json` (section `fig_chaos`): goodput (tokens from
//! `Stop`-finished requests per wall second), retry-success rate,
//! shed rate, p50/p95 latency, and absorbed decode faults. The claims pin
//! the resilience contract: every request that finished `Stop` under
//! faults produced **bitwise** the stream of the fault-free run.
//! `ARA_BENCH_SMOKE=1` shrinks the sweep for CI; `ARA_CHAOS_REQS`
//! overrides the trace length.

mod common;

use std::collections::HashMap;
use std::time::Instant;

use ara_compress::data::{corpus_spec, generate_tokens, Rng};
use ara_compress::report::Table;
use ara_compress::serving::{
    Engine, FaultPlan, FinishReason, Request, SamplingParams, SchedCfg, Scheduler,
};
use common::{bench_json_path_named, bench_section, claim, pipeline, record_bench_at, smoke};

struct ChaosRun {
    goodput_tok_s: f64,
    retry_success_rate: f64,
    shed_rate: f64,
    p50_ms: f64,
    p95_ms: f64,
    decode_faults: usize,
    quarantined: usize,
    /// Arrival index → token stream, for requests that finished `Stop`
    /// (the bitwise-parity unit across fault rates).
    stop_streams: HashMap<usize, Vec<i32>>,
}

/// Drive one scheduler through the arrival trace under `plan`. Arrivals
/// landing while `cap` requests are already queued are shed at the bench
/// level (the router's bounded-admission stand-in) and count toward
/// `shed_rate`.
fn chaos_trace(
    engine: &Engine,
    arrivals: &[(usize, Request)],
    plan: Option<FaultPlan>,
    cap: usize,
) -> ChaosRun {
    // roomier budget than the default: the sweep's top rate hits a request
    // several times over a lifetime, and quarantines should reflect
    // genuinely unlucky requests, not an artificially tight budget
    let mut sched = Scheduler::new_with(engine, SchedCfg { retry_limit: 8 });
    sched.set_fault_plan(plan);
    let mut id2idx: HashMap<u64, usize> = HashMap::new();
    let mut done = Vec::new();
    let mut shed = 0usize;
    let mut next = 0usize;
    let mut step = 0usize;
    let t0 = Instant::now();
    while next < arrivals.len() || !sched.is_idle() {
        while next < arrivals.len() && arrivals[next].0 <= step {
            if sched.queued() >= cap {
                shed += 1;
            } else {
                let id = sched.submit(arrivals[next].1.clone());
                id2idx.insert(id, next);
            }
            next += 1;
        }
        if !sched.is_idle() {
            done.extend(sched.step().expect("chaos scheduler step"));
        }
        step += 1;
    }
    let wall = t0.elapsed().as_secs_f64().max(1e-9);
    let stats = sched.stats();

    let mut stop_streams = HashMap::new();
    let mut good_tokens = 0usize;
    let mut retried = 0usize;
    let mut retried_ok = 0usize;
    let mut latencies: Vec<f64> = Vec::with_capacity(done.len());
    for c in &done {
        latencies.push(c.latency_s);
        if c.retries > 0 {
            retried += 1;
            if c.finish_reason == FinishReason::Stop {
                retried_ok += 1;
            }
        }
        if c.finish_reason == FinishReason::Stop {
            good_tokens += c.tokens.len();
            stop_streams.insert(id2idx[&c.id], c.tokens.clone());
        }
    }
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |q: f64| match latencies.is_empty() {
        true => 0.0,
        false => latencies[((latencies.len() as f64 * q) as usize).min(latencies.len() - 1)],
    };
    ChaosRun {
        goodput_tok_s: good_tokens as f64 / wall,
        retry_success_rate: if retried == 0 { 1.0 } else { retried_ok as f64 / retried as f64 },
        shed_rate: shed as f64 / arrivals.len().max(1) as f64,
        p50_ms: pct(0.50) * 1e3,
        p95_ms: pct(0.95) * 1e3,
        decode_faults: stats.decode_faults,
        quarantined: stats.quarantined,
        stop_streams,
    }
}

fn rate_label(r: f64) -> String {
    format!("r{}", (r * 100.0).round() as usize)
}

fn main() {
    let smoke = smoke();
    let model = "minillama-s";
    let pl = pipeline(model);
    let ws = pl.pretrained().expect("pretrain");
    let grams = pl.grams(&ws).expect("calibrate");
    let fm = pl.factored(&ws, &grams).expect("factorize");
    let bmax = *pl.cfg.decode_batches.last().unwrap();
    let engine = pl.engine(&ws, &fm, "uniform-80", bmax).expect("engine");

    // the same deterministic arrival trace for every rate (mixed ragged
    // prompts, exponential inter-arrivals — the fig5 sched_trace recipe)
    let p = pl.cfg.prefill_len;
    let n_req = std::env::var("ARA_CHAOS_REQS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if smoke { 8 } else { ara_compress::config::scaled(48, 16) });
    let stream = generate_tokens(pl.cfg.vocab, corpus_spec("synwiki"), 4242, 8192);
    let mut rng = Rng::new(0xC4405);
    let mut at = 0.0f64;
    let arrivals: Vec<(usize, Request)> = (0..n_req)
        .map(|_| {
            at += -(1.0 - rng.f64()).ln() * 0.5;
            let len = 1 + rng.below(p);
            let off = rng.below(stream.len() - p);
            let req = Request {
                prompt: stream[off..off + len].to_vec(),
                gen_len: 2 + rng.below(12),
                params: SamplingParams::greedy(),
                ..Default::default()
            };
            (at.floor() as usize, req)
        })
        .collect();
    let cap = 4 * bmax; // bounded admission: queue depth before shedding

    let rates: &[f64] = if smoke { &[0.0, 0.25] } else { &[0.0, 0.1, 0.25] };
    let mut t = Table::new(
        format!("Fig chaos — {n_req} requests, B={bmax}, queue cap {cap}, seeded fault sweep"),
        &["Rate", "goodput tok/s", "retry ok", "shed", "p50 ms", "p95 ms", "faults", "quar"],
    );
    let mut entries: Vec<(String, f64)> = Vec::new();
    let mut runs: Vec<(f64, ChaosRun)> = Vec::new();
    for &r in rates {
        let plan = if r > 0.0 {
            // one pinned decode fault (so every faulty rate provably
            // injects at least once, however short the smoke trace), then
            // Bernoulli decode faults across the whole run plus one pool
            // spike and one latency stall
            let spec = format!(
                "decode@2;rate@{r}?seed=7&until=20000;spike@6?blocks=2&hold=4;stall@11?ms=2"
            );
            Some(FaultPlan::parse(&spec).expect("chaos plan"))
        } else {
            None
        };
        let run = chaos_trace(&engine, &arrivals, plan, cap);
        let lbl = rate_label(r);
        t.row(vec![
            lbl.clone(),
            format!("{:.0}", run.goodput_tok_s),
            format!("{:.2}", run.retry_success_rate),
            format!("{:.2}", run.shed_rate),
            format!("{:.1}", run.p50_ms),
            format!("{:.1}", run.p95_ms),
            format!("{}", run.decode_faults),
            format!("{}", run.quarantined),
        ]);
        entries.push((format!("{lbl}_goodput_tok_s"), run.goodput_tok_s));
        entries.push((format!("{lbl}_retry_success_rate"), run.retry_success_rate));
        entries.push((format!("{lbl}_shed_rate"), run.shed_rate));
        entries.push((format!("{lbl}_p50_ms"), run.p50_ms));
        entries.push((format!("{lbl}_p95_ms"), run.p95_ms));
        entries.push((format!("{lbl}_decode_faults"), run.decode_faults as f64));
        runs.push((r, run));
    }
    t.print();
    entries.sort_by(|a, b| a.0.cmp(&b.0));
    record_bench_at(
        &bench_json_path_named("BENCH_PR7.json"),
        &bench_section("fig_chaos"),
        &entries,
    );

    // resilience-contract claims: fault-free baseline first in `runs`
    let (_, base) = &runs[0];
    for (r, run) in &runs[1..] {
        assert!(run.decode_faults > 0, "rate {r} must have injected faults");
        let mut compared = 0usize;
        let mut bitwise = true;
        for (idx, toks) in &run.stop_streams {
            if let Some(b) = base.stop_streams.get(idx) {
                compared += 1;
                bitwise &= toks == b;
            }
        }
        claim(
            &format!(
                "rate {r}: {compared} Stop streams bitwise-identical to fault-free run"
            ),
            bitwise && compared > 0,
        );
        claim(
            &format!("rate {r}: goodput degrades gracefully (≤ fault-free)"),
            run.goodput_tok_s <= base.goodput_tok_s * 1.05,
        );
    }
}
