//! Table 6: LoRA recovery fine-tuning after ARA compression. Paper shape:
//! fine-tuning improves PPL and accuracy at both ratios, with the larger
//! gain at the harsher (60%) ratio.

mod common;

use ara_compress::lora::{lora_finetune_and_merge, LoraConfig};
use ara_compress::report::Table;
use ara_compress::svd::alloc_masks;
use common::{claim, pipeline, push_row, table_headers};

fn main() {
    let model = "minillama-s";
    let pl = pipeline(model);
    let ws = pl.pretrained().expect("pretrain");
    let grams = pl.grams(&ws).expect("calibrate");
    let fm = pl.factored(&ws, &grams).expect("factorize");

    let mut t = Table::new("Table 6 — LoRA fine-tuning after ARA", &table_headers());
    let dense = pl.evaluate_dense(&ws).expect("dense");
    push_row(&mut t, &dense);

    for ratio in [0.35, 0.25] {
        let alloc = pl
            .allocate_spec(&format!("ara@{ratio}"), &ws, &grams, &fm)
            .expect("ara")
            .allocation;
        let masks = alloc_masks(&pl.cfg, &alloc);
        let mut before = pl.evaluate(
            &format!("ARA@{:.0}%", ratio * 100.0),
            &ws,
            &fm,
            &alloc,
        )
        .expect("eval");
        push_row(&mut t, &before);

        let lc = LoraConfig {
            steps: ara_compress::config::scaled(60, 10),
            ..Default::default()
        };
        let (fm2, masks2) =
            lora_finetune_and_merge(&pl.cfg, &pl.rt, &ws, &fm, &masks, &grams, &lc)
                .expect("lora");
        let mut after = pl
            .evaluate_masks(
                &format!("  w. LoRA@{:.0}%", ratio * 100.0),
                ratio,
                &ws,
                &fm2,
                &masks2,
            )
            .expect("eval lora");
        push_row(&mut t, &after);

        claim(
            &format!("@{ratio}: LoRA improves wiki2 PPL"),
            after.wiki_ppl <= before.wiki_ppl * 1.01,
        );
        before.method.clear();
        after.method.clear();
    }
    t.print();
}
