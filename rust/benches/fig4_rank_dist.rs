//! Fig. 4: per-module retained parameter-ratio distribution after ARA
//! training at 80%, with and without L_g. Paper shape: with L_g many
//! v/gate/down modules flip to dense (ratio 1) while q/k compress hard;
//! without L_g almost nothing reaches ratio 1.

mod common;

use ara_compress::ara::{train_ara, AraConfig};
use ara_compress::model::{alloc_ratio, module_dims, ModuleAlloc};
use ara_compress::report::Table;
use common::{claim, pipeline};

fn main() {
    for model in ["minillama-s", "miniqwen-s"] {
        let pl = pipeline(model);
        let ws = pl.pretrained().expect("pretrain");
        let grams = pl.grams(&ws).expect("calibrate");
        let fm = pl.factored(&ws, &grams).expect("factorize");
        let sc = pl.scalecfg.clone();

        let mut results = Vec::new();
        for use_g in [true, false] {
            let ac = AraConfig {
                target: 0.8,
                use_guidance: use_g,
                epochs: sc.alloc_epochs,
                samples: sc.alloc_samples,
                ..Default::default()
            };
            let (alloc, trace) = train_ara(&pl.cfg, &pl.rt, &ws, &fm, &ac).expect("train");
            results.push((use_g, alloc, trace));
        }

        let dims = module_dims(&pl.cfg);
        let mut t = Table::new(
            format!("Fig 4 — per-module retained ratio, {model} @ 80%"),
            &["Module", "with L_g", "w/o L_g"],
        );
        for d in &dims {
            let cells: Vec<String> = results
                .iter()
                .map(|(_, alloc, _)| match alloc.get(&d.name) {
                    ModuleAlloc::Dense => "1.00 (dense)".to_string(),
                    ModuleAlloc::Rank(k) => format!(
                        "{:.2}",
                        d.factored_params(k) as f64 / d.dense_params() as f64
                    ),
                })
                .collect();
            t.row(vec![d.name.clone(), cells[0].clone(), cells[1].clone()]);
        }
        t.print();

        let with_g = &results[0].1;
        let without_g = &results[1].1;
        println!(
            "  dense modules: with L_g {} / without {} (of {}); achieved ratios {:.3} / {:.3}",
            with_g.dense_count(),
            without_g.dense_count(),
            dims.len(),
            alloc_ratio(&pl.cfg, with_g),
            alloc_ratio(&pl.cfg, without_g),
        );
        claim(
            &format!("{model}: L_g flips more modules to dense"),
            with_g.dense_count() >= without_g.dense_count(),
        );
        claim(
            &format!("{model}: some modules dense with L_g"),
            with_g.dense_count() > 0,
        );
    }
}
