//! Table 1: PPL + zero-shot accuracy for the small model pair across all
//! methods {Dense, Uniform, DLP, FARMS, STRS, ARS, Dobi-SVD₁, ARA}.
//!
//! Ratio mapping (DESIGN.md §2): our scaled models are over-parameterized
//! for the synthetic grammar, so the paper's 80%/60% operating points
//! (where the PPL-ratio curve bends on 7B models) correspond to ~35%/25%
//! here — the bends of our curve. Reproduction target is the *shape*:
//! mask-trained methods (ARA, Dobi) beat Uniform; layerwise heuristics
//! (DLP, FARMS) trail.
//!
//! Methods run through the registry (`compress::ALL_METHOD_IDS` specs),
//! so every row's provenance is a named spec, not an enum variant.

mod common;

use ara_compress::compress::ALL_METHOD_IDS;
use ara_compress::coordinator::EvalRow;
use ara_compress::report::Table;
use common::{claim, pipeline, push_row, table_headers};

fn main() {
    let t0 = std::time::Instant::now();
    for model in ["minillama-s", "miniqwen-s"] {
        let pl = pipeline(model);
        let ws = pl.pretrained().expect("pretrain");
        let grams = pl.grams(&ws).expect("calibrate");
        let fm = pl.factored(&ws, &grams).expect("factorize");
        let dense = pl.evaluate_dense(&ws).expect("dense eval");

        for ratio in [0.35, 0.25] {
            let mut t = Table::new(
                format!("Table 1 — {model} @ {:.0}% compression (≙ paper {}%)", ratio * 100.0, if ratio > 0.3 { 80 } else { 60 }),
                &table_headers(),
            );
            push_row(&mut t, &dense);
            let mut rows: Vec<(&str, EvalRow)> = Vec::new();
            for id in ALL_METHOD_IDS {
                let plan = match pl.allocate_spec(&format!("{id}@{ratio}"), &ws, &grams, &fm) {
                    Ok(p) => p,
                    Err(e) => {
                        eprintln!("  {id} failed: {e}");
                        continue;
                    }
                };
                let row = pl.evaluate(&plan.label, &ws, &fm, &plan.allocation).expect("eval");
                push_row(&mut t, &row);
                rows.push((id, row));
            }
            t.print();

            let get = |id: &str| rows.iter().find(|(m, _)| *m == id).map(|(_, r)| r);
            if let (Some(ara), Some(uni)) = (get("ara"), get("uniform")) {
                claim(
                    &format!("{model}@{ratio}: ARA wiki2 PPL ≤ Uniform"),
                    ara.wiki_ppl <= uni.wiki_ppl * 1.02,
                );
                claim(
                    &format!("{model}@{ratio}: ARA avg acc ≥ Uniform"),
                    ara.avg_acc >= uni.avg_acc - 1.0,
                );
            }
            if let (Some(ara), Some(dobi)) = (get("ara"), get("dobi")) {
                claim(
                    &format!("{model}@{ratio}: ARA C4 PPL ≤ Dobi-SVD1"),
                    ara.c4_ppl <= dobi.c4_ppl * 1.02,
                );
            }
        }
    }
    println!("table1 wall time: {:.1}s", t0.elapsed().as_secs_f64());
}
