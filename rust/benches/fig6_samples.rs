//! Fig. 6 (Appendix A.5): PPL vs number of calibration samples at fixed
//! epochs. Paper shape: rapid improvement up to a knee (~128–256 samples),
//! marginal gains beyond.

mod common;

use ara_compress::ara::{train_ara, AraConfig};
use ara_compress::report::Table;
use common::{claim, pipeline};

fn main() {
    let model = "minillama-s";
    let pl = pipeline(model);
    let ws = pl.pretrained().expect("pretrain");
    let grams = pl.grams(&ws).expect("calibrate");
    let fm = pl.factored(&ws, &grams).expect("factorize");
    let sc = pl.scalecfg.clone();

    let sample_counts = [8usize, 16, 32, 64, 128];
    let mut t = Table::new(
        "Fig 6 — PPL vs calibration samples (fixed epochs)",
        &["Samples", "Wiki2", "C4"],
    );
    let mut ppls = Vec::new();
    for &s in &sample_counts {
        let ac = AraConfig {
            target: 0.35,
            epochs: sc.alloc_epochs,
            samples: s,
            ..Default::default()
        };
        let (alloc, _) = train_ara(&pl.cfg, &pl.rt, &ws, &fm, &ac).expect("train");
        let row = pl.evaluate(&format!("{s}"), &ws, &fm, &alloc).expect("eval");
        t.row(vec![format!("{s}"), format!("{:.2}", row.wiki_ppl), format!("{:.2}", row.c4_ppl)]);
        ppls.push(row.wiki_ppl);
    }
    t.print();

    let early_gain = ppls[0] - ppls[2]; // 8 → 32
    let late_gain = ppls[3] - ppls[4]; // 64 → 128
    println!("  early gain (8→32): {early_gain:.3}, late gain (64→128): {late_gain:.3}");
    claim(
        "knee shape: early gains ≥ late gains",
        early_gain >= late_gain - 0.02 * ppls[4],
    );
}
