//! The decode engine: owns device-resident weight buffers for one
//! (allocation, batch-size) specialization and runs prefill + greedy decode
//! loops entirely through the backend's device-buffer path. On the default
//! CPU backend "device" buffers are host values (no copies crossing a
//! boundary); on PJRT they are real device buffers that never leave the
//! device between decode steps.

use std::collections::BTreeMap;
use std::rc::Rc;
use std::time::Instant;

use crate::config::ModelCfg;
use crate::model::{Allocation, ModuleAlloc, WeightStore};
use crate::runtime::{Backend, DeviceArg, DeviceBuffer, Exe, Feed, Runtime};
use crate::svd::FactoredModel;
use crate::tensor::{IntTensor, Tensor};
use crate::Result;

/// Generation statistics for throughput reporting (Fig. 5).
#[derive(Debug, Clone, Default)]
pub struct GenStats {
    pub prefill_s: f64,
    pub decode_s: f64,
    pub tokens_generated: usize,
    pub steps: usize,
}

impl GenStats {
    /// Decode throughput in tokens/second.
    pub fn tok_per_s(&self) -> f64 {
        self.tokens_generated as f64 / self.decode_s.max(1e-9)
    }
}

/// One (allocation, batch) specialization with device-resident weights.
pub struct Engine {
    cfg: ModelCfg,
    pub batch: usize,
    pub alloc_name: String,
    prefill: Rc<Exe>,
    decode: Rc<Exe>,
    /// Device buffers for the weight prefix, in decode-manifest order.
    dec_weights: Vec<DeviceBuffer>,
    /// Device buffers for the weight prefix, in prefill-manifest order.
    pre_weights: Vec<DeviceBuffer>,
    backend: Rc<dyn Backend>,
}

/// Materialize the host tensor for a weight input name under an allocation.
fn weight_tensor(
    name: &str,
    ws: &WeightStore,
    fm: &FactoredModel,
    alloc: &Allocation,
) -> Result<Tensor> {
    if let Some(base) = name.strip_suffix(".u") {
        let k = match alloc.get(base) {
            ModuleAlloc::Rank(k) => k,
            ModuleAlloc::Dense => return Err(crate::anyhow!("{base} is dense, no .u")),
        };
        return Ok(fm.factors[base].truncate(k).0);
    }
    if let Some(base) = name.strip_suffix(".v") {
        let k = match alloc.get(base) {
            ModuleAlloc::Rank(k) => k,
            ModuleAlloc::Dense => return Err(crate::anyhow!("{base} is dense, no .v")),
        };
        return Ok(fm.factors[base].truncate(k).1);
    }
    // dense module or aux param: straight from the weight store
    Ok(ws.get(name).clone())
}

impl Engine {
    /// Load (cached) executables and upload weights for `alloc` at batch
    /// size `b`.
    pub fn new(
        cfg: &ModelCfg,
        rt: &Runtime,
        ws: &WeightStore,
        fm: &FactoredModel,
        alloc: &Allocation,
        alloc_artifact: &str,
        batch: usize,
    ) -> Result<Engine> {
        let prefill = rt.load(&format!("prefill_{alloc_artifact}_b{batch}"))?;
        let decode = rt.load(&format!("decode_{alloc_artifact}_b{batch}"))?;

        let upload = |exe: &Rc<Exe>| -> Result<Vec<DeviceBuffer>> {
            let mut bufs = Vec::new();
            for spec in &exe.manifest().inputs {
                if spec.name == "tokens"
                    || spec.name == "lens"
                    || spec.name.starts_with("kcache")
                    || spec.name.starts_with("vcache")
                {
                    break; // weights are the manifest prefix by construction
                }
                let t = weight_tensor(&spec.name, ws, fm, alloc)?;
                if t.shape != spec.shape {
                    return Err(crate::anyhow!(
                        "{}: shape {:?} != manifest {:?} (alloc/artifact mismatch?)",
                        spec.name,
                        t.shape,
                        spec.shape
                    ));
                }
                bufs.push(rt.upload(&Feed::F32(&t))?);
            }
            Ok(bufs)
        };

        Ok(Engine {
            cfg: cfg.clone(),
            batch,
            alloc_name: alloc.name.clone(),
            dec_weights: upload(&decode)?,
            pre_weights: upload(&prefill)?,
            prefill,
            decode,
            backend: rt.backend(),
        })
    }

    /// Greedy-generate `gen_len` tokens for a batch of equal-length prompts
    /// (padded/truncated to cfg.prefill_len by the batcher).
    pub fn generate(&self, prompts: &[Vec<i32>], gen_len: usize) -> Result<(Vec<Vec<i32>>, GenStats)> {
        let b = self.batch;
        let p = self.cfg.prefill_len;
        assert_eq!(prompts.len(), b, "prompt count must equal engine batch");
        let mut stats = GenStats::default();

        // ---- prefill ----
        let t0 = Instant::now();
        let mut toks = Vec::with_capacity(b * p);
        for pr in prompts {
            assert_eq!(pr.len(), p, "prompts must be prefill_len long");
            toks.extend_from_slice(pr);
        }
        let toks = IntTensor::from_vec(&[b, p], toks);
        let tok_buf = self.backend.upload(&Feed::I32(&toks))?;
        // weights are borrowed (never copied); per-step tensors are owned
        let mut args: Vec<DeviceArg> = self.pre_weights.iter().map(DeviceArg::Ref).collect();
        args.push(DeviceArg::Own(tok_buf));
        let outs = self
            .prefill
            .run_device_args(args)
            .map_err(|e| crate::anyhow!("prefill: {e}"))?;
        stats.prefill_s = t0.elapsed().as_secs_f64();

        // outputs: [logits, kcache.0, vcache.0, ...] stay on device
        let mut outs_it = outs.into_iter();
        let logit_buf = outs_it
            .next()
            .ok_or_else(|| crate::anyhow!("prefill returned no outputs"))?;
        let mut logits = self.backend.download(&logit_buf)?;
        let mut caches: Vec<DeviceBuffer> = outs_it.collect();

        // ---- decode loop ----
        let t1 = Instant::now();
        let mut generated: Vec<Vec<i32>> = vec![Vec::with_capacity(gen_len); b];
        let mut lens_host = vec![p as i32; b];
        let vocab = self.cfg.vocab;
        for step in 0..gen_len {
            // greedy next token from last logits
            let mut next = Vec::with_capacity(b);
            for s in 0..b {
                let row = &logits.data[s * vocab..(s + 1) * vocab];
                let arg = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                next.push(arg as i32);
                generated[s].push(arg as i32);
            }
            if step + 1 == gen_len {
                break;
            }
            if lens_host[0] as usize + 1 >= self.cfg.max_decode_seq {
                break; // cache full
            }
            let tok_t = IntTensor::from_vec(&[b], next);
            let lens_t = IntTensor::from_vec(&[b], lens_host.clone());
            let tok_b = self.backend.upload(&Feed::I32(&tok_t))?;
            let lens_b = self.backend.upload(&Feed::I32(&lens_t))?;
            // weights stay borrowed across steps; caches move in owned so
            // the interpreter updates them in place (no per-layer clone)
            let mut args: Vec<DeviceArg> = self.dec_weights.iter().map(DeviceArg::Ref).collect();
            for c in caches.drain(..) {
                args.push(DeviceArg::Own(c));
            }
            args.push(DeviceArg::Own(tok_b));
            args.push(DeviceArg::Own(lens_b));
            let outs = self
                .decode
                .run_device_args(args)
                .map_err(|e| crate::anyhow!("decode step {step}: {e}"))?;
            let mut it = outs.into_iter();
            let logit_buf = it
                .next()
                .ok_or_else(|| crate::anyhow!("decode returned no outputs"))?;
            logits = self.backend.download(&logit_buf)?;
            caches = it.collect();
            for l in lens_host.iter_mut() {
                *l += 1;
            }
            stats.steps += 1;
        }
        stats.decode_s = t1.elapsed().as_secs_f64();
        stats.tokens_generated = b * generated[0].len();
        Ok((generated, stats))
    }

    pub fn config(&self) -> &ModelCfg {
        &self.cfg
    }
}

/// Masks → Allocation helper for serving (masks carry the final ranks).
#[allow(dead_code)]
pub fn alloc_from_masks(
    alloc_name: &str,
    masks: &BTreeMap<String, Tensor>,
    dims: &[crate::model::ModuleDim],
) -> Allocation {
    let mut a = Allocation::new(alloc_name);
    for d in dims {
        let k = masks[&d.name].data.iter().filter(|&&x| x > 0.5).count();
        if k >= d.r_full() {
            a.set(&d.name, ModuleAlloc::Dense);
        } else {
            a.set(&d.name, ModuleAlloc::Rank(k.max(1)));
        }
    }
    a
}
