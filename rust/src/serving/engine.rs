//! The decode engine: owns device-resident weight buffers for one
//! (allocation, batch-size) specialization and exposes the stepwise
//! serving primitives the continuous-batching scheduler is built on:
//!
//! * [`Engine::prefill_into_slots`] — run the fixed-batch prefill for a set
//!   of (slot, ragged prompt) pairs (left-padded + `lens`-masked; parked
//!   slots carry dummy prompts) and merge only those slots' KV-cache rows
//!   into the live batch caches.
//! * [`Engine::decode_step`] — one batched decode step with per-slot cache
//!   write position (`fill`) and valid-window start (`starts`) over the
//!   contiguous per-slot caches (the standalone / parity-reference path).
//! * [`Engine::decode_step_paged`] — one batched decode step over the
//!   block-paged KV pool via per-slot block tables (the scheduler's hot
//!   path; see `serving/kvpool.rs`).
//!
//! [`Engine::generate`] remains as a thin greedy wrapper over the two (the
//! benches and CLI drive it); it now accepts ragged prompts, which it
//! left-pads under the same masking contract. On the default CPU backend
//! "device" buffers are host values (no copies crossing a boundary); on
//! PJRT they are real device buffers that never leave the device between
//! decode steps.

use std::cell::Cell;
use std::collections::BTreeMap;
use std::rc::Rc;
use std::time::Instant;

use super::kvpool::KvPoolCfg;
use super::sampler::argmax;
use crate::config::ModelCfg;
use crate::model::{Allocation, ModuleAlloc, WeightStore};
use crate::quant::{PackedInt8, QuantScheme};
use crate::runtime::{Backend, DeviceArg, DeviceBuffer, Exe, Feed, Runtime, Value};
use crate::svd::FactoredModel;
use crate::tensor::{IntTensor, Tensor};
use crate::Result;

/// Why a request's generation ended. Every request terminates with exactly
/// one of these — the resilience contract (DESIGN.md §5) forbids dropped
/// reply channels as an error signal.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// Reached its requested `gen_len`.
    Stop,
    /// Hit a capacity bound first: the decode window (`max_decode_seq`) or
    /// an unrecoverable KV-pool exhaustion.
    Length,
    /// The caller's cancellation token fired; partial tokens returned.
    Cancelled,
    /// The request's step-budget deadline expired (queued or mid-decode).
    DeadlineExceeded,
    /// Shed at admission: the router's queue depth was full.
    Rejected,
    /// Quarantined after exhausting its retry budget (`retries` fault
    /// hits), or failed by an unrecoverable router/scheduler error.
    Failed { retries: u32 },
}

impl FinishReason {
    /// Whether the request ran to its natural end (`Stop`/`Length`) rather
    /// than being cut short by cancellation, deadline, shedding, or faults.
    pub fn is_natural(&self) -> bool {
        matches!(self, FinishReason::Stop | FinishReason::Length)
    }

    /// Stable wire label (DESIGN.md §7): what the HTTP layer writes as
    /// `finish_reason` — lowercase snake_case, one per variant.
    pub fn label(&self) -> &'static str {
        match self {
            FinishReason::Stop => "stop",
            FinishReason::Length => "length",
            FinishReason::Cancelled => "cancelled",
            FinishReason::DeadlineExceeded => "deadline_exceeded",
            FinishReason::Rejected => "rejected",
            FinishReason::Failed { .. } => "failed",
        }
    }
}

/// Generation statistics for throughput reporting (Fig. 5).
#[derive(Debug, Clone, Default)]
pub struct GenStats {
    pub prefill_s: f64,
    pub decode_s: f64,
    pub tokens_generated: usize,
    pub steps: usize,
    /// Per-slot finish reason — `Length` marks requests truncated by the
    /// decode window instead of silently stopping short.
    pub finish: Vec<FinishReason>,
    /// Provenance line of the [`crate::compress::CompressionPlan`] the
    /// engine was specialized from, when it was built from one — so
    /// throughput reports can name exactly what they measured.
    pub provenance: Option<String>,
    /// Active SIMD kernel tier name (`scalar`/`avx2`/`avx512`/`neon`) —
    /// throughput numbers are only comparable within one tier.
    pub simd_tier: &'static str,
    /// The quantization recipe the engine serves with (`None` = f32
    /// factors) — surfaced so throughput/quality reports name the full
    /// composed plan, not just the rank allocation.
    pub quant: Option<QuantScheme>,
    /// Self-speculative decoding (DESIGN.md §8): tokens proposed by the
    /// draft engine. Zero on plain decode.
    pub draft_tokens: usize,
    /// Draft tokens accepted by target verification (≤ `draft_tokens`).
    pub draft_accepted: usize,
    /// Batched verify passes run (`decode_verify` calls).
    pub verify_passes: usize,
}

impl GenStats {
    /// Decode throughput in tokens/second.
    pub fn tok_per_s(&self) -> f64 {
        self.tokens_generated as f64 / self.decode_s.max(1e-9)
    }

    /// Mean accepted draft tokens per verify pass — the speculative win
    /// (each pass also emits one corrected/bonus token on top of these).
    pub fn accepted_per_verify(&self) -> f64 {
        self.draft_accepted as f64 / (self.verify_passes as f64).max(1.0)
    }
}

/// One (allocation, batch) specialization with device-resident weights.
/// Carries two decode specializations: the contiguous per-slot cache graph
/// (`decode_step` — the standalone/parity reference) and the block-paged
/// pool graph (`decode_step_paged` — the scheduler's hot path).
pub struct Engine {
    cfg: ModelCfg,
    pub batch: usize,
    pub alloc_name: String,
    /// `"decode_paged_<alloc>_b<B>_<suffix>"` artifact stem pieces for
    /// [`Engine::enable_paged`] re-specialization.
    alloc_artifact: String,
    prefill: Rc<Exe>,
    decode: Rc<Exe>,
    /// The paged-pool decode specialization. `None` on backends without
    /// paged artifacts (PJRT keeps the contiguous serving path).
    paged: Option<Rc<Exe>>,
    paged_cfg: KvPoolCfg,
    /// The speculative verify specialization (`decode_verify` — scores a
    /// `(b, W)` token window in one pass). Loaded on demand by
    /// [`Engine::enable_verify`]; shares the decode weight prefix.
    verify: Option<Rc<Exe>>,
    /// Window length `W` the verify graph was compiled for (0 = none).
    verify_window: usize,
    /// Device buffers for the weight prefix, in decode-manifest order
    /// (shared with the paged decode — identical weight prefix, pinned by
    /// `runtime::programs` tests).
    dec_weights: Vec<DeviceBuffer>,
    /// Device buffers for the weight prefix, in prefill-manifest order.
    pre_weights: Vec<DeviceBuffer>,
    backend: Rc<dyn Backend>,
    /// Compression-plan provenance line (set when the engine was built
    /// from a [`crate::compress::CompressionPlan`]).
    provenance: Option<String>,
    /// The allocation's quantization recipe (`None` = f32 factors). When
    /// set, factor weights were uploaded as packed int8 and decode runs
    /// the quantized matmul path end-to-end.
    quant: Option<QuantScheme>,
    /// Test instrumentation: fail the n-th subsequent decode step once.
    fault: Cell<Option<usize>>,
    /// Test instrumentation: fail the n-th subsequent batched prefill once.
    fault_prefill: Cell<Option<usize>>,
}

/// Materialize the host tensor for a weight input name under an allocation.
fn weight_tensor(
    name: &str,
    ws: &WeightStore,
    fm: &FactoredModel,
    alloc: &Allocation,
) -> Result<Tensor> {
    if let Some(base) = name.strip_suffix(".u") {
        let k = match alloc.try_get(base)? {
            ModuleAlloc::Rank(k) => k,
            ModuleAlloc::Dense => return Err(crate::anyhow!("{base} is dense, no .u")),
        };
        return Ok(fm.factors[base].truncate(k).0);
    }
    if let Some(base) = name.strip_suffix(".v") {
        let k = match alloc.try_get(base)? {
            ModuleAlloc::Rank(k) => k,
            ModuleAlloc::Dense => return Err(crate::anyhow!("{base} is dense, no .v")),
        };
        return Ok(fm.factors[base].truncate(k).1);
    }
    // dense module or aux param: straight from the weight store
    Ok(ws.get(name).clone())
}

/// Splice the admitted slots' cache rows of `add` into `live` in place when
/// both are host f32 buffers (the CPU backend's zero-copy admission path).
/// Returns `false` when a backend round-trip is required instead.
fn splice_host_rows(
    live: &mut DeviceBuffer,
    add: &DeviceBuffer,
    batch: usize,
    new: &[(usize, &[i32])],
) -> bool {
    if let (DeviceBuffer::Host(Value::F32(base)), DeviceBuffer::Host(Value::F32(incoming))) =
        (live, add)
    {
        let row = base.data.len() / batch;
        for &(slot, _) in new {
            base.data[slot * row..(slot + 1) * row]
                .copy_from_slice(&incoming.data[slot * row..(slot + 1) * row]);
        }
        true
    } else {
        false
    }
}

/// The paged decode must share the contiguous decode's weight prefix (the
/// engine binds one buffer set to both); verify names before trusting it.
fn check_paged_prefix(decode: &Rc<Exe>, paged: &Rc<Exe>, n_weights: usize) -> Result<()> {
    let d = &decode.manifest().inputs;
    let p = &paged.manifest().inputs;
    if p.len() < n_weights {
        return Err(crate::anyhow!("paged decode manifest shorter than the weight prefix"));
    }
    for (ds, ps) in d[..n_weights].iter().zip(&p[..n_weights]) {
        if ds.name != ps.name || ds.shape != ps.shape {
            return Err(crate::anyhow!(
                "paged decode weight prefix diverges at `{}` vs `{}`",
                ds.name,
                ps.name
            ));
        }
    }
    Ok(())
}

impl Engine {
    /// Load (cached) executables and upload weights for `alloc` at batch
    /// size `b`.
    pub fn new(
        cfg: &ModelCfg,
        rt: &Runtime,
        ws: &WeightStore,
        fm: &FactoredModel,
        alloc: &Allocation,
        alloc_artifact: &str,
        batch: usize,
    ) -> Result<Engine> {
        let prefill = rt.load(&format!("prefill_{alloc_artifact}_b{batch}"))?;
        let decode = rt.load(&format!("decode_{alloc_artifact}_b{batch}"))?;
        let paged_cfg = KvPoolCfg::from_env(cfg, batch);
        // the paged graph is interpreter-built; PJRT ships no paged HLO
        // artifacts and keeps the contiguous serving path
        let paged = if rt.backend().name() == "cpu" {
            Some(rt.load(&format!(
                "decode_paged_{alloc_artifact}_b{batch}_{}",
                paged_cfg.artifact_suffix()
            ))?)
        } else {
            None
        };

        let upload = |exe: &Rc<Exe>| -> Result<Vec<DeviceBuffer>> {
            let mut bufs = Vec::new();
            for spec in &exe.manifest().inputs {
                if spec.name == "tokens"
                    || spec.name == "lens"
                    || spec.name == "starts"
                    || spec.name.starts_with("kcache")
                    || spec.name.starts_with("vcache")
                {
                    break; // weights are the manifest prefix by construction
                }
                let t = weight_tensor(&spec.name, ws, fm, alloc)?;
                if t.shape != spec.shape {
                    return Err(crate::anyhow!(
                        "{}: shape {:?} != manifest {:?} (alloc/artifact mismatch?)",
                        spec.name,
                        t.shape,
                        spec.shape
                    ));
                }
                if spec.dtype == "q8" {
                    // factor input compiled for packed int8: quantize on
                    // upload — no dequantized copy is ever resident
                    let q = alloc.quant.ok_or_else(|| {
                        crate::anyhow!(
                            "{}: manifest says q8 but allocation has no quant recipe",
                            spec.name
                        )
                    })?;
                    let pq = PackedInt8::quantize(&t, q.group);
                    bufs.push(rt.upload(&Feed::Q8(&pq))?);
                } else {
                    bufs.push(rt.upload(&Feed::F32(&t))?);
                }
            }
            Ok(bufs)
        };

        let dec_weights = upload(&decode)?;
        if let Some(p) = &paged {
            check_paged_prefix(&decode, p, dec_weights.len())?;
        }
        Ok(Engine {
            cfg: cfg.clone(),
            batch,
            alloc_name: alloc.name.clone(),
            alloc_artifact: alloc_artifact.to_string(),
            dec_weights,
            pre_weights: upload(&prefill)?,
            prefill,
            decode,
            paged,
            paged_cfg,
            verify: None,
            verify_window: 0,
            backend: rt.backend(),
            provenance: None,
            quant: alloc.quant,
            fault: Cell::new(None),
            fault_prefill: Cell::new(None),
        })
    }

    /// The quantization recipe this engine serves with (`None` = f32).
    pub fn quant(&self) -> Option<QuantScheme> {
        self.quant
    }

    /// Record the provenance line of the compression plan this engine was
    /// specialized from (`Pipeline::engine` / `Pipeline::engine_for_plan`
    /// set it when a versioned plan resolved). Threaded into
    /// [`GenStats::provenance`] so serving reports can name their plan.
    pub fn set_provenance(&mut self, line: String) {
        self.provenance = Some(line);
    }

    /// The plan provenance line, when one was recorded.
    pub fn provenance(&self) -> Option<&str> {
        self.provenance.as_deref()
    }

    /// Re-specialize the paged decode graph for an explicit pool geometry
    /// (tests pin the degenerate `block_len = max_decode_seq` config this
    /// way; production geometry comes from `ARA_KV_BLOCK`/`ARA_KV_BLOCKS`
    /// at construction). Weights are shared with the contiguous decode —
    /// no re-upload.
    pub fn enable_paged(&mut self, rt: &Runtime, pcfg: KvPoolCfg) -> Result<()> {
        let paged = rt.load(&format!(
            "decode_paged_{}_b{}_{}",
            self.alloc_artifact,
            self.batch,
            pcfg.artifact_suffix()
        ))?;
        check_paged_prefix(&self.decode, &paged, self.dec_weights.len())?;
        self.paged = Some(paged);
        self.paged_cfg = pcfg;
        Ok(())
    }

    /// The pool geometry the active paged decode graph was compiled for.
    pub fn paged_cfg(&self) -> KvPoolCfg {
        self.paged_cfg
    }

    /// Whether this engine carries a paged decode specialization (true on
    /// the CPU backend; PJRT serves through the contiguous path only).
    pub fn has_paged(&self) -> bool {
        self.paged.is_some()
    }

    /// Load the speculative verify specialization for window length
    /// `window` (= spec `k` + 1: the pending token plus `k` draft tokens)
    /// against the active pool geometry. Weights are shared with the
    /// decode graphs — no re-upload. Requires the paged path.
    pub fn enable_verify(&mut self, rt: &Runtime, window: usize) -> Result<()> {
        if self.paged.is_none() {
            return Err(crate::anyhow!("verify decode requires the paged path (cpu backend)"));
        }
        if window < 2 {
            return Err(crate::anyhow!("verify window must be ≥ 2 (got {window})"));
        }
        let verify = rt.load(&format!(
            "decode_verify_{}_b{}_{}_k{window}",
            self.alloc_artifact,
            self.batch,
            self.paged_cfg.artifact_suffix()
        ))?;
        check_paged_prefix(&self.decode, &verify, self.dec_weights.len())?;
        self.verify = Some(verify);
        self.verify_window = window;
        Ok(())
    }

    /// Whether a verify specialization is loaded.
    pub fn has_verify(&self) -> bool {
        self.verify.is_some()
    }

    /// Window length the verify graph was compiled for (0 when absent).
    pub fn verify_window(&self) -> usize {
        self.verify_window
    }

    /// Test instrumentation: make the n-th subsequent decode step (either
    /// path) fail once with a transient error, for error-recovery tests.
    #[doc(hidden)]
    pub fn inject_decode_fault(&self, after_steps: usize) {
        self.fault.set(Some(after_steps));
    }

    fn check_fault(&self) -> Result<()> {
        if let Some(n) = self.fault.get() {
            if n == 0 {
                self.fault.set(None);
                return Err(crate::anyhow!("injected decode fault (test instrumentation)"));
            }
            self.fault.set(Some(n - 1));
        }
        Ok(())
    }

    /// Test instrumentation: make the n-th subsequent batched prefill fail
    /// once with a transient error — exercises the scheduler's
    /// fault-isolated admission rollback (active slots keep decoding).
    #[doc(hidden)]
    pub fn inject_prefill_fault(&self, after_calls: usize) {
        self.fault_prefill.set(Some(after_calls));
    }

    fn check_prefill_fault(&self) -> Result<()> {
        if let Some(n) = self.fault_prefill.get() {
            if n == 0 {
                self.fault_prefill.set(None);
                return Err(crate::anyhow!("injected prefill fault (test instrumentation)"));
            }
            self.fault_prefill.set(Some(n - 1));
        }
        Ok(())
    }

    /// Number of prompt tokens the prefill window keeps: the most recent
    /// `prefill_len`, and at least one (empty prompts become a lone BOS).
    pub fn real_len(&self, prompt: &[i32]) -> usize {
        prompt.len().min(self.cfg.prefill_len).max(1)
    }

    /// Left-pad (or head-truncate) a prompt to the prefill window. Returns
    /// the padded row and the number of real tokens (`== real_len`); the
    /// real tokens occupy the rightmost slots, pads are BOS.
    pub fn pad_prompt(&self, prompt: &[i32]) -> (Vec<i32>, usize) {
        let p = self.cfg.prefill_len;
        let keep = &prompt[prompt.len().saturating_sub(p)..];
        let mut row = vec![crate::data::BOS_TOKEN; p];
        row[p - keep.len()..].copy_from_slice(keep);
        (row, self.real_len(prompt))
    }

    /// Run the fixed-batch prefill for `new` (slot, ragged prompt) pairs,
    /// parking the remaining slots on dummy prompts, and merge **only** the
    /// admitted slots' KV-cache rows into `caches` (`None` adopts the fresh
    /// caches wholesale — the initial fill). Returns one final-position
    /// logits row per entry of `new` (in order) plus the merged caches.
    ///
    /// Every row of the prefill graph is computed independently (left-pad +
    /// `lens` masking), so an admitted slot's logits and cache rows are
    /// bitwise identical to what a standalone full-batch prefill of the
    /// same prompt would produce — the scheduler's parity guarantee.
    pub fn prefill_into_slots(
        &self,
        new: &[(usize, &[i32])],
        caches: Option<Vec<DeviceBuffer>>,
    ) -> Result<(Vec<Vec<f32>>, Vec<DeviceBuffer>)> {
        // fires before any compute: the scheduler calls this with
        // `caches: None`, so a prefill fault never damages pool state
        self.check_prefill_fault()?;
        let b = self.batch;
        let p = self.cfg.prefill_len;
        let mut toks = vec![crate::data::BOS_TOKEN; b * p];
        let mut lens = vec![p as i32; b]; // parked slots: all-BOS "full" rows
        for &(slot, prompt) in new {
            assert!(slot < b, "slot {slot} out of range for batch {b}");
            let (row, n) = self.pad_prompt(prompt);
            toks[slot * p..(slot + 1) * p].copy_from_slice(&row);
            lens[slot] = n as i32;
        }
        let toks_t = IntTensor::from_vec(&[b, p], toks);
        let lens_t = IntTensor::from_vec(&[b], lens);
        // weights are borrowed (never copied); per-call tensors are owned
        let mut args: Vec<DeviceArg> = self.pre_weights.iter().map(DeviceArg::Ref).collect();
        args.push(DeviceArg::Own(self.backend.upload(&Feed::I32(&toks_t))?));
        args.push(DeviceArg::Own(self.backend.upload(&Feed::I32(&lens_t))?));
        let outs = self
            .prefill
            .run_device_args(args)
            .map_err(|e| crate::anyhow!("prefill: {e}"))?;
        let mut outs_it = outs.into_iter();
        let logit_buf = outs_it
            .next()
            .ok_or_else(|| crate::anyhow!("prefill returned no outputs"))?;
        let logits = self.backend.download(&logit_buf)?;
        let vocab = self.cfg.vocab;
        let rows: Vec<Vec<f32>> = new
            .iter()
            .map(|&(slot, _)| logits.data[slot * vocab..(slot + 1) * vocab].to_vec())
            .collect();
        let fresh: Vec<DeviceBuffer> = outs_it.collect();
        let merged = match caches {
            None => fresh,
            Some(mut old) => {
                for (live, add) in old.iter_mut().zip(&fresh) {
                    if splice_host_rows(live, add, b, new) {
                        continue; // CPU backend: spliced in place, no copies
                    }
                    // real device buffers: one download+splice+upload per
                    // cache tensor (admission only — decode stays on device)
                    let mut base = self.backend.download(live)?;
                    let incoming = self.backend.download(add)?;
                    let row = base.data.len() / b;
                    for &(slot, _) in new {
                        base.data[slot * row..(slot + 1) * row]
                            .copy_from_slice(&incoming.data[slot * row..(slot + 1) * row]);
                    }
                    *live = self.backend.upload(&Feed::F32(&base))?;
                }
                old
            }
        };
        Ok((rows, merged))
    }

    /// One decode step over the whole batch: per-slot last token, cache
    /// write position (`fill`), and valid-window start (`starts`). Caches
    /// move in owned so the backend updates them in place; returns the
    /// next-token logits `(batch, vocab)` and the updated caches.
    pub fn decode_step(
        &self,
        caches: Vec<DeviceBuffer>,
        tokens: &[i32],
        fill: &[i32],
        starts: &[i32],
    ) -> Result<(Tensor, Vec<DeviceBuffer>)> {
        self.check_fault()?;
        let b = self.batch;
        assert_eq!(tokens.len(), b, "tokens must cover every slot");
        assert_eq!(fill.len(), b, "fill must cover every slot");
        assert_eq!(starts.len(), b, "starts must cover every slot");
        let tok_t = IntTensor::from_vec(&[b], tokens.to_vec());
        let fill_t = IntTensor::from_vec(&[b], fill.to_vec());
        let st_t = IntTensor::from_vec(&[b], starts.to_vec());
        // weights stay borrowed across steps; caches move in owned so the
        // interpreter updates them in place (no per-layer clone)
        let mut args: Vec<DeviceArg> = self.dec_weights.iter().map(DeviceArg::Ref).collect();
        for c in caches {
            args.push(DeviceArg::Own(c));
        }
        args.push(DeviceArg::Own(self.backend.upload(&Feed::I32(&tok_t))?));
        args.push(DeviceArg::Own(self.backend.upload(&Feed::I32(&fill_t))?));
        args.push(DeviceArg::Own(self.backend.upload(&Feed::I32(&st_t))?));
        let outs = self
            .decode
            .run_device_args(args)
            .map_err(|e| crate::anyhow!("decode step: {e}"))?;
        let mut it = outs.into_iter();
        let logit_buf = it
            .next()
            .ok_or_else(|| crate::anyhow!("decode returned no outputs"))?;
        let logits = self.backend.download(&logit_buf)?;
        Ok((logits, it.collect()))
    }

    /// One decode step over the **block-paged KV pool** — the scheduler's
    /// hot path. `pool` moves in owned (2·layers buffers in `kpool.0,
    /// vpool.0, …` order, from [`super::KvPool::take_bufs`]) so the
    /// interpreter writes the new K/V rows in place; weights stay
    /// borrowed. Per slot: `tokens[i]` the last token, `vlens[i]` the
    /// virtual write/attend position, `rows[i]` the physical pool row the
    /// K/V lands in, `btable[i]` the block table (padded with the scratch
    /// block 0 — padded blocks are masked). Returns the next-token logits
    /// and the updated pool buffers.
    pub fn decode_step_paged(
        &self,
        pool: Vec<DeviceBuffer>,
        tokens: &[i32],
        vlens: &[i32],
        rows: &[i32],
        btable: &[i32],
    ) -> Result<(Tensor, Vec<DeviceBuffer>)> {
        self.check_fault()?;
        let paged = self
            .paged
            .as_ref()
            .ok_or_else(|| crate::anyhow!("paged decode unavailable on this backend"))?;
        let b = self.batch;
        let bps = self.paged_cfg.blocks_per_seq(&self.cfg);
        assert_eq!(tokens.len(), b, "tokens must cover every slot");
        assert_eq!(vlens.len(), b, "vlens must cover every slot");
        assert_eq!(rows.len(), b, "rows must cover every slot");
        assert_eq!(btable.len(), b * bps, "btable must be (batch, blocks_per_seq)");
        assert_eq!(pool.len(), 2 * self.cfg.n_layers, "pool buffer count");
        let tok_t = IntTensor::from_vec(&[b], tokens.to_vec());
        let len_t = IntTensor::from_vec(&[b], vlens.to_vec());
        let row_t = IntTensor::from_vec(&[b], rows.to_vec());
        let bt_t = IntTensor::from_vec(&[b, bps], btable.to_vec());
        let mut args: Vec<DeviceArg> = self.dec_weights.iter().map(DeviceArg::Ref).collect();
        for p in pool {
            args.push(DeviceArg::Own(p));
        }
        args.push(DeviceArg::Own(self.backend.upload(&Feed::I32(&tok_t))?));
        args.push(DeviceArg::Own(self.backend.upload(&Feed::I32(&len_t))?));
        args.push(DeviceArg::Own(self.backend.upload(&Feed::I32(&row_t))?));
        args.push(DeviceArg::Own(self.backend.upload(&Feed::I32(&bt_t))?));
        let outs = paged
            .run_device_args(args)
            .map_err(|e| crate::anyhow!("paged decode step: {e}"))?;
        let mut it = outs.into_iter();
        let logit_buf = it
            .next()
            .ok_or_else(|| crate::anyhow!("paged decode returned no outputs"))?;
        let logits = self.backend.download(&logit_buf)?;
        Ok((logits, it.collect()))
    }

    /// One speculative **verify** pass over the paged pool: scores a
    /// `(batch, W)` token window in one call (`W = verify_window`). Per
    /// slot, `tokens[i·W + j]` sits at virtual position `vlens[i] + j` and
    /// its K/V is written to pool row `rows[i·W + j]` (non-speculative
    /// slots point window positions ≥ 1 at scratch rows). Returns the
    /// `(batch, W, vocab)` logits — `logits[i][j]` is bitwise identical to
    /// a sequential one-token `decode_step_paged` fed the same prefix —
    /// and the updated pool buffers. Subject to the same injected-fault
    /// instrumentation as the plain decode paths.
    pub fn decode_step_verify(
        &self,
        pool: Vec<DeviceBuffer>,
        tokens: &[i32],
        vlens: &[i32],
        rows: &[i32],
        btable: &[i32],
    ) -> Result<(Tensor, Vec<DeviceBuffer>)> {
        self.check_fault()?;
        let verify = self
            .verify
            .as_ref()
            .ok_or_else(|| crate::anyhow!("verify decode not enabled on this engine"))?;
        let b = self.batch;
        let w = self.verify_window;
        let bps = self.paged_cfg.blocks_per_seq(&self.cfg);
        assert_eq!(tokens.len(), b * w, "tokens must be (batch, window)");
        assert_eq!(vlens.len(), b, "vlens must cover every slot");
        assert_eq!(rows.len(), b * w, "rows must be (batch · window)");
        assert_eq!(btable.len(), b * bps, "btable must be (batch, blocks_per_seq)");
        assert_eq!(pool.len(), 2 * self.cfg.n_layers, "pool buffer count");
        let tok_t = IntTensor::from_vec(&[b, w], tokens.to_vec());
        let len_t = IntTensor::from_vec(&[b], vlens.to_vec());
        let row_t = IntTensor::from_vec(&[b * w], rows.to_vec());
        let bt_t = IntTensor::from_vec(&[b, bps], btable.to_vec());
        let mut args: Vec<DeviceArg> = self.dec_weights.iter().map(DeviceArg::Ref).collect();
        for p in pool {
            args.push(DeviceArg::Own(p));
        }
        args.push(DeviceArg::Own(self.backend.upload(&Feed::I32(&tok_t))?));
        args.push(DeviceArg::Own(self.backend.upload(&Feed::I32(&len_t))?));
        args.push(DeviceArg::Own(self.backend.upload(&Feed::I32(&row_t))?));
        args.push(DeviceArg::Own(self.backend.upload(&Feed::I32(&bt_t))?));
        let outs = verify
            .run_device_args(args)
            .map_err(|e| crate::anyhow!("verify decode step: {e}"))?;
        let mut it = outs.into_iter();
        let logit_buf = it
            .next()
            .ok_or_else(|| crate::anyhow!("verify decode returned no outputs"))?;
        let logits = self.backend.download(&logit_buf)?;
        Ok((logits, it.collect()))
    }

    /// Greedy-generate `gen_len` tokens for a batch of prompts (one per
    /// engine slot; ragged lengths allowed — shorter prompts are left-padded
    /// and masked, longer ones keep their most recent `prefill_len` tokens).
    /// Thin wrapper over [`Engine::prefill_into_slots`] +
    /// [`Engine::decode_step`], kept for the benches and CLI.
    pub fn generate(
        &self,
        prompts: &[Vec<i32>],
        gen_len: usize,
    ) -> Result<(Vec<Vec<i32>>, GenStats)> {
        let b = self.batch;
        let p = self.cfg.prefill_len;
        assert_eq!(prompts.len(), b, "prompt count must equal engine batch");
        let mut stats = GenStats {
            provenance: self.provenance.clone(),
            simd_tier: crate::kernels::active_tier().name(),
            quant: self.quant,
            ..Default::default()
        };

        // ---- prefill ----
        let t0 = Instant::now();
        let slots: Vec<(usize, &[i32])> =
            prompts.iter().enumerate().map(|(i, pr)| (i, pr.as_slice())).collect();
        let (rows, mut caches) = self.prefill_into_slots(&slots, None)?;
        stats.prefill_s = t0.elapsed().as_secs_f64();

        // ---- decode loop ----
        let t1 = Instant::now();
        let mut generated: Vec<Vec<i32>> = vec![Vec::with_capacity(gen_len); b];
        let vocab = self.cfg.vocab;
        let starts: Vec<i32> =
            prompts.iter().map(|pr| (p - self.real_len(pr)) as i32).collect();
        let mut fill = vec![p as i32; b];
        let mut next: Vec<i32> = Vec::with_capacity(b);
        if gen_len > 0 {
            for (s, row) in rows.iter().enumerate() {
                let tok = argmax(row) as i32;
                next.push(tok);
                generated[s].push(tok);
            }
        }
        for _step in 1..gen_len {
            if fill[0] as usize + 1 >= self.cfg.max_decode_seq {
                break; // decode window full — surfaced via `finish` below
            }
            let (logits, new_caches) = self.decode_step(caches, &next, &fill, &starts)?;
            caches = new_caches;
            for f in fill.iter_mut() {
                *f += 1;
            }
            stats.steps += 1;
            next.clear();
            for (s, gen) in generated.iter_mut().enumerate() {
                let row = &logits.data[s * vocab..(s + 1) * vocab];
                let tok = argmax(row) as i32;
                next.push(tok);
                gen.push(tok);
            }
        }
        stats.decode_s = t1.elapsed().as_secs_f64();
        stats.tokens_generated = b * generated[0].len();
        stats.finish = generated
            .iter()
            .map(|g| if g.len() >= gen_len { FinishReason::Stop } else { FinishReason::Length })
            .collect();
        Ok((generated, stats))
    }

    pub fn config(&self) -> &ModelCfg {
        &self.cfg
    }
}

/// Masks → Allocation helper for serving (masks carry the final ranks).
#[allow(dead_code)]
pub fn alloc_from_masks(
    alloc_name: &str,
    masks: &BTreeMap<String, Tensor>,
    dims: &[crate::model::ModuleDim],
) -> Allocation {
    let mut a = Allocation::new(alloc_name);
    for d in dims {
        let k = masks[&d.name].data.iter().filter(|&&x| x > 0.5).count();
        if k >= d.r_full() {
            a.set(&d.name, ModuleAlloc::Dense);
        } else {
            a.set(&d.name, ModuleAlloc::Rank(k.max(1)));
        }
    }
    a
}
