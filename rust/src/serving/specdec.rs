//! Self-speculative decoding (DESIGN.md §8): a *heavier-compressed plan of
//! the same backbone* acts as the draft model. ARA's allocation registry
//! materializes `ara@0.35` and `ara@0.8` from one weight store, so the
//! draft shares the target's tokenizer, K/V geometry, and weight
//! provenance — no second checkpoint, no distillation.
//!
//! [`SpecDec`] owns the draft [`Engine`] and a **private** paged
//! [`KvPool`] (with its own prefix cache), mirroring one draft sequence
//! per target scheduler slot. Per verify round the scheduler asks it to
//! [`SpecDec::propose`] `k` greedy tokens (k sequential batched draft
//! decode steps), runs the target's one-pass `decode_verify` window, and
//! then [`SpecDec::commit`]s the accepted frontier back (rewinding past
//! rejected positions is free — rows above the frontier are masked and
//! overwritten on the next append).
//!
//! Failure policy: the draft is *advisory*. Any draft-side failure — pool
//! exhaustion, a prefill or decode fault, falling out of sync — retires
//! the affected draft slots and the requests silently continue on the
//! plain one-token path. Accepted token streams are spec-invariant
//! (bitwise identical to plain greedy decode), so fallback is always
//! correct, never a quality cliff.

use super::engine::Engine;
use super::kvpool::{KvPool, PrefixHit};
use super::sampler::argmax;
use crate::Result;

/// One draft sequence shadowing an active target slot.
struct DraftSlot {
    /// Next draft K/V write position (virtual coordinates). Between verify
    /// rounds this always equals the target request's `fill - start` — the
    /// sync invariant [`SpecDec::propose`] checks before drafting.
    fill: usize,
    /// Physical draft-pool blocks backing virtual positions, grown on
    /// demand (the draft pool is independent of the target pool).
    table: Vec<usize>,
}

/// The draft side of the self-speculative decode loop: a compressed-plan
/// [`Engine`] plus its private paged pool, one shadow sequence per target
/// scheduler slot.
pub struct SpecDec {
    draft: Engine,
    pool: KvPool,
    spec: String,
    k: usize,
    slots: Vec<Option<DraftSlot>>,
}

impl SpecDec {
    /// Wrap a draft engine (same model config and batch size as the
    /// target) proposing `k` tokens per verify round. `spec` is the
    /// registry spec the draft was allocated from (`ara@0.35`, …) —
    /// requests opt in by naming it in [`super::Request::draft_spec`].
    pub fn new(draft: Engine, spec: &str, k: usize) -> Result<SpecDec> {
        if !draft.has_paged() {
            return Err(crate::anyhow!(
                "speculative drafting requires the paged path (cpu backend)"
            ));
        }
        if k < 1 {
            return Err(crate::anyhow!("draft length k must be >= 1 (got {k})"));
        }
        let pool = KvPool::new(draft.config(), draft.paged_cfg());
        let slots = (0..draft.batch).map(|_| None).collect();
        Ok(SpecDec { pool, spec: spec.to_string(), k, slots, draft })
    }

    /// The registry spec requests must name to opt in.
    pub fn spec(&self) -> &str {
        &self.spec
    }

    /// Draft tokens proposed per verify round.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Draft engine batch size (must equal the target scheduler's).
    pub fn batch(&self) -> usize {
        self.draft.batch
    }

    /// Whether `slot` currently has a live draft sequence.
    pub fn has(&self, slot: usize) -> bool {
        self.slots[slot].is_some()
    }

    /// Live draft sequences.
    pub fn active_drafts(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Current used fraction of the draft pool's allocatable blocks.
    pub fn pool_utilization(&self) -> f64 {
        self.pool.utilization()
    }

    /// High-water used fraction of the draft pool since construction.
    pub fn pool_peak_utilization(&self) -> f64 {
        self.pool.peak_utilization()
    }

    /// Retire `slot`'s draft sequence (idempotent), releasing its blocks.
    pub fn release(&mut self, slot: usize) {
        if let Some(st) = self.slots[slot].take() {
            for b in st.table {
                self.pool.release(b);
            }
        }
    }

    /// Retire every draft sequence (scheduler recovery/abort paths). The
    /// draft pool and its prefix cache survive, so re-admitted requests
    /// can still hit cached prompt chains.
    pub fn release_all(&mut self) {
        for s in 0..self.slots.len() {
            self.release(s);
        }
    }

    /// Drop every draft slot and rebuild the draft pool after a failed
    /// draft step consumed its buffers. The affected requests silently
    /// fall back to plain decode (streams are spec-invariant).
    fn poison(&mut self) {
        for s in &mut self.slots {
            *s = None;
        }
        self.pool.reset();
    }

    /// Draft-admit freshly admitted requests: `(target slot, effective
    /// windowed prompt)` pairs. One batched draft prefill covers the
    /// draft-cache misses; fully cached prompts skip prefill through the
    /// draft pool's own prefix map. Any per-slot failure (pool exhaustion,
    /// prefill fault) skips just that slot — the request decodes plain.
    pub fn admit(&mut self, reqs: &[(usize, &[i32])]) {
        let bl = self.pool.cfg.block_len;
        let p = self.draft.config().prefill_len;
        struct Adm<'a> {
            slot: usize,
            eff: &'a [i32],
            table: Vec<usize>,
            covered: usize,
        }
        let mut misses: Vec<Adm> = Vec::new();
        for &(slot, eff) in reqs {
            // a stale draft sequence for a reused slot would be a desync
            self.release(slot);
            let n = eff.len();
            if n == 0 || n > p {
                continue;
            }
            let total = n.div_ceil(bl);
            let (mut table, covered, full) = match self.pool.lookup(eff) {
                Some(PrefixHit::Full { blocks, .. }) => (blocks, n, true),
                Some(PrefixHit::Partial { blocks, covered }) => (blocks, covered, false),
                None => (Vec::new(), 0, false),
            };
            // a fully cached prompt with a partial tail block will be
            // appended into — copy-on-write it (shared blocks are never
            // written), same contract as the target pool
            let mut ok = true;
            if full && n % bl != 0 {
                let tail = *table.last().expect("full hit implies blocks");
                match self.pool.cow_block(tail) {
                    Ok(Some(fresh)) => {
                        self.pool.release(tail);
                        *table.last_mut().unwrap() = fresh;
                    }
                    _ => ok = false,
                }
            }
            while ok && table.len() < total {
                match self.pool.alloc() {
                    Some(nb) => table.push(nb),
                    None => ok = false,
                }
            }
            if !ok {
                for blk in table {
                    self.pool.release(blk);
                }
                continue;
            }
            if full {
                self.slots[slot] = Some(DraftSlot { fill: n, table });
            } else {
                misses.push(Adm { slot, eff, table, covered });
            }
        }
        if misses.is_empty() {
            return;
        }
        let pairs: Vec<(usize, &[i32])> = misses.iter().map(|m| (m.slot, m.eff)).collect();
        let (rows, caches) = match self.draft.prefill_into_slots(&pairs, None) {
            Ok(x) => x,
            Err(_) => {
                // draft prefill fault: no draft for these slots, no harm
                for m in misses {
                    for blk in m.table {
                        self.pool.release(blk);
                    }
                }
                return;
            }
        };
        for (m, row) in misses.into_iter().zip(rows) {
            let n = m.eff.len();
            if self.pool.write_prefill(&caches, m.slot, p - n, n, m.covered, &m.table).is_err() {
                for blk in m.table {
                    self.pool.release(blk);
                }
                continue;
            }
            self.pool.register(m.eff, &m.table, &row);
            self.slots[m.slot] = Some(DraftSlot { fill: n, table: m.table });
        }
    }

    /// Propose `k` greedy draft tokens per target: `k` sequential batched
    /// draft decode steps over the draft pool. `targets` carries
    /// `(slot, pending last token, target virtual position)`; slots that
    /// are out of sync, out of draft-pool room, or hit a draft fault are
    /// retired (plain fallback) and omitted from the result.
    pub fn propose(&mut self, targets: &[(usize, i32, usize)]) -> Vec<(usize, Vec<i32>)> {
        let b = self.draft.batch;
        let bl = self.pool.cfg.block_len;
        let bps = self.pool.cfg.blocks_per_seq(self.draft.config());
        let s_virt = bps * bl;
        // (slot, next token to feed) for drafts that can run a full window
        let mut live: Vec<(usize, i32)> = Vec::new();
        for &(slot, last, vpos) in targets {
            let sync = self.slots[slot].as_ref().is_some_and(|st| st.fill == vpos);
            if !sync || vpos + self.k >= s_virt {
                self.release(slot);
                continue;
            }
            // draft blocks for write positions [vpos, vpos + k] (the last
            // one backs the post-verify catch-up feed)
            let needed = (vpos + self.k) / bl + 1;
            let mut ok = true;
            loop {
                let st = self.slots[slot].as_mut().expect("checked in sync");
                if st.table.len() >= needed {
                    break;
                }
                match self.pool.alloc() {
                    Some(nb) => st.table.push(nb),
                    None => {
                        ok = false;
                        break;
                    }
                }
            }
            if !ok {
                self.release(slot);
                continue;
            }
            live.push((slot, last));
        }
        if live.is_empty() {
            return Vec::new();
        }
        let mut out: Vec<(usize, Vec<i32>)> =
            live.iter().map(|&(s, _)| (s, Vec::with_capacity(self.k))).collect();
        let vocab = self.draft.config().vocab;
        for _round in 0..self.k {
            let mut toks = vec![crate::data::BOS_TOKEN; b];
            let mut vlens = vec![0i32; b];
            let mut rows = vec![0i32; b];
            let mut btable = vec![0i32; b * bps];
            for &(slot, feed) in &live {
                let st = self.slots[slot].as_ref().expect("live implies slot");
                toks[slot] = feed;
                vlens[slot] = st.fill as i32;
                rows[slot] = (st.table[st.fill / bl] * bl + st.fill % bl) as i32;
                for (j, &blk) in st.table.iter().enumerate() {
                    btable[slot * bps + j] = blk as i32;
                }
            }
            let Ok(bufs) = self.pool.take_bufs() else {
                self.poison();
                return Vec::new();
            };
            let step = self.draft.decode_step_paged(bufs, &toks, &vlens, &rows, &btable);
            let (logits, new_bufs) = match step {
                Ok(x) => x,
                Err(_) => {
                    // the failed step consumed the draft pool buffers —
                    // rebuild and retire every draft (plain fallback)
                    self.poison();
                    return Vec::new();
                }
            };
            self.pool.restore_bufs(new_bufs);
            for (li, (slot, feed)) in live.iter_mut().enumerate() {
                let row = &logits.data[*slot * vocab..(*slot + 1) * vocab];
                let tok = argmax(row) as i32;
                self.slots[*slot].as_mut().expect("live implies slot").fill += 1;
                out[li].1.push(tok);
                *feed = tok;
            }
        }
        out
    }

    /// Commit verify outcomes back into the draft state: per slot the new
    /// shared frontier (`new_fill` = the target's post-round `fill -
    /// start`) plus, for fully accepted windows, the last draft token
    /// whose own K/V row the draft never wrote (`catch_up`) — it is fed
    /// through one batched draft step (logits discarded) so the draft
    /// stays bitwise in sync. Rewinding past rejected positions is free:
    /// rows above the frontier are masked and overwritten on re-append.
    pub fn commit(&mut self, advances: &[(usize, usize, Option<i32>)]) {
        let feeds: Vec<(usize, i32)> = advances
            .iter()
            .filter_map(|&(s, _, c)| c.map(|t| (s, t)))
            .filter(|&(s, _)| self.slots[s].is_some())
            .collect();
        if !feeds.is_empty() {
            let b = self.draft.batch;
            let bl = self.pool.cfg.block_len;
            let bps = self.pool.cfg.blocks_per_seq(self.draft.config());
            let mut toks = vec![crate::data::BOS_TOKEN; b];
            let mut vlens = vec![0i32; b];
            let mut rows = vec![0i32; b];
            let mut btable = vec![0i32; b * bps];
            for &(slot, tok) in &feeds {
                let st = self.slots[slot].as_ref().expect("filtered on is_some");
                toks[slot] = tok;
                vlens[slot] = st.fill as i32;
                rows[slot] = (st.table[st.fill / bl] * bl + st.fill % bl) as i32;
                for (j, &blk) in st.table.iter().enumerate() {
                    btable[slot * bps + j] = blk as i32;
                }
            }
            let Ok(bufs) = self.pool.take_bufs() else {
                self.poison();
                return;
            };
            match self.draft.decode_step_paged(bufs, &toks, &vlens, &rows, &btable) {
                Ok((_logits, new_bufs)) => {
                    self.pool.restore_bufs(new_bufs);
                    for &(slot, _) in &feeds {
                        self.slots[slot].as_mut().expect("filtered on is_some").fill += 1;
                    }
                }
                Err(_) => {
                    self.poison();
                    return;
                }
            }
        }
        for &(slot, new_fill, _) in advances {
            if let Some(st) = self.slots[slot].as_mut() {
                debug_assert!(
                    new_fill <= st.fill,
                    "draft frontier moved backwards past the proposal window"
                );
                st.fill = new_fill;
            }
        }
    }
}

impl Drop for SpecDec {
    /// Debug-build leak check, mirroring the scheduler's: after retiring
    /// every draft sequence the draft pool must balance (scratch + cached
    /// chains account for every block).
    fn drop(&mut self) {
        #[cfg(debug_assertions)]
        if !std::thread::panicking() {
            self.release_all();
            if self.pool.bufs_present() {
                self.pool.assert_balanced();
            }
        }
    }
}
