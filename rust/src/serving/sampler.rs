//! Token samplers for the serve loop: greedy argmax plus temperature /
//! top-k / top-p (nucleus) sampling with a seeded per-request RNG
//! ([`crate::data::Rng`]), so every sampled continuation is reproducible
//! from its request seed alone — independent of batch composition,
//! admission order, or thread count.

use crate::data::Rng;

/// Per-request sampling configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct SamplingParams {
    /// Softmax temperature; `<= 0.0` means greedy argmax (the default).
    pub temperature: f64,
    /// Keep only the `top_k` highest-logit tokens; `0` disables the cut.
    pub top_k: usize,
    /// Nucleus cut: keep the smallest prefix of the sorted distribution
    /// with cumulative mass `>= top_p` (at least one token). `1.0`
    /// disables the cut; `0.0` degenerates to the single best token.
    pub top_p: f64,
    /// Seed for the per-request RNG stream.
    pub seed: u64,
}

impl SamplingParams {
    /// Greedy decoding (temperature 0): deterministic, seed-independent.
    pub fn greedy() -> SamplingParams {
        SamplingParams { temperature: 0.0, top_k: 0, top_p: 1.0, seed: 0 }
    }
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams::greedy()
    }
}

/// Greedy argmax over a logits row. Ties resolve to the highest index,
/// matching the engine's original `max_by` behavior so greedy outputs stay
/// stable across PRs.
pub fn argmax(row: &[f32]) -> usize {
    row.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// One request's sampler: params + its private RNG stream.
#[derive(Debug, Clone)]
pub struct Sampler {
    params: SamplingParams,
    rng: Rng,
}

impl Sampler {
    pub fn new(params: SamplingParams) -> Sampler {
        let rng = Rng::new(params.seed);
        Sampler { params, rng }
    }

    /// Draw the next token from a logits row.
    pub fn sample(&mut self, logits: &[f32]) -> i32 {
        let p = &self.params;
        if p.temperature <= 0.0 {
            return argmax(logits) as i32;
        }
        // deterministic total order: logit desc, then index desc so the
        // head of the order agrees with `argmax` on exact ties
        let mut order: Vec<usize> = (0..logits.len()).collect();
        order.sort_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap().then(b.cmp(&a)));
        let mut keep = order.len();
        if p.top_k > 0 {
            keep = keep.min(p.top_k);
        }
        keep = keep.max(1);
        // max-shifted softmax over the kept prefix, in f64
        let inv_t = 1.0 / p.temperature;
        let m = logits[order[0]] as f64;
        let mut probs: Vec<f64> = order[..keep]
            .iter()
            .map(|&i| ((logits[i] as f64 - m) * inv_t).exp())
            .collect();
        let z: f64 = probs.iter().sum();
        for q in probs.iter_mut() {
            *q /= z;
        }
        // nucleus cut: smallest prefix with cumulative mass >= top_p
        if p.top_p < 1.0 {
            let mut acc = 0.0;
            let mut cut = 1;
            for (j, &q) in probs.iter().enumerate() {
                acc += q;
                cut = j + 1;
                if acc >= p.top_p {
                    break;
                }
            }
            probs.truncate(cut);
            let z: f64 = probs.iter().sum();
            for q in probs.iter_mut() {
                *q /= z;
            }
        }
        // inverse-CDF draw
        let u = self.rng.f64();
        let mut acc = 0.0;
        for (j, &q) in probs.iter().enumerate() {
            acc += q;
            if u < acc {
                return order[j] as i32;
            }
        }
        order[probs.len() - 1] as i32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row() -> Vec<f32> {
        // deterministic pseudo-logits, several near-ties
        (0..32).map(|i| ((i * 37 % 17) as f32) * 0.3 - 1.0).collect()
    }

    #[test]
    fn greedy_matches_argmax_and_ignores_seed() {
        let r = row();
        for seed in [0u64, 7, 123] {
            let mut s = Sampler::new(SamplingParams { seed, ..SamplingParams::greedy() });
            assert_eq!(s.sample(&r), argmax(&r) as i32);
        }
    }

    #[test]
    fn seeded_sampling_is_deterministic_and_seed_sensitive() {
        let r = row();
        let params = SamplingParams { temperature: 0.9, top_k: 12, top_p: 0.95, seed: 42 };
        let mut a = Sampler::new(params.clone());
        let mut b = Sampler::new(params.clone());
        let draws_a: Vec<i32> = (0..64).map(|_| a.sample(&r)).collect();
        let draws_b: Vec<i32> = (0..64).map(|_| b.sample(&r)).collect();
        assert_eq!(draws_a, draws_b, "same seed must replay the same stream");
        let mut c = Sampler::new(SamplingParams { seed: 43, ..params });
        let draws_c: Vec<i32> = (0..64).map(|_| c.sample(&r)).collect();
        assert_ne!(draws_a, draws_c, "different seeds should diverge");
    }

    #[test]
    fn top_p_zero_degenerates_to_best_token() {
        let r = row();
        let best = argmax(&r) as i32;
        let mut s = Sampler::new(SamplingParams {
            temperature: 1.3,
            top_k: 0,
            top_p: 0.0,
            seed: 5,
        });
        for _ in 0..32 {
            assert_eq!(s.sample(&r), best);
        }
    }

    #[test]
    fn top_p_one_samples_full_support() {
        // flat logits + top_p = 1.0: every token reachable, all draws valid
        let r = vec![0.0f32; 8];
        let mut s = Sampler::new(SamplingParams {
            temperature: 1.0,
            top_k: 0,
            top_p: 1.0,
            seed: 9,
        });
        let mut seen = [false; 8];
        for _ in 0..400 {
            let t = s.sample(&r);
            assert!((0..8).contains(&t));
            seen[t as usize] = true;
        }
        assert!(seen.iter().filter(|&&x| x).count() >= 6, "flat draw too narrow: {seen:?}");
    }

    #[test]
    fn all_mass_on_one_token_always_wins() {
        let mut r = vec![-4.0f32; 16];
        r[11] = 60.0; // e^64 dwarfs the rest — nucleus is exactly {11}
        for p in [0.0, 0.5, 1.0] {
            let mut s = Sampler::new(SamplingParams {
                temperature: 1.0,
                top_k: 0,
                top_p: p,
                seed: 3,
            });
            for _ in 0..32 {
                assert_eq!(s.sample(&r), 11, "top_p={p}");
            }
        }
    }

    #[test]
    fn top_k_one_is_greedy() {
        let r = row();
        let mut s = Sampler::new(SamplingParams {
            temperature: 2.0,
            top_k: 1,
            top_p: 1.0,
            seed: 1,
        });
        for _ in 0..16 {
            assert_eq!(s.sample(&r), argmax(&r) as i32);
        }
    }
}
