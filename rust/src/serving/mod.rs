//! Batched serving engine (Appendix A.4 / Fig. 5): allocation-specialized
//! prefill + decode executables with device-resident weights and KV caches,
//! a dynamic batcher, and a threaded router front-end.
//!
//! The engine is the L3 hot path and is backend-agnostic: after
//! construction, a decode step is one `run_device_args` call — weights are
//! passed borrowed (never copied), while KV caches move in owned so the
//! backend can update them in place (real device buffers on PJRT,
//! recycled-in-place host values on the default CPU interpreter); only the
//! (batch,) token/length vectors cross the host boundary each step.

mod batcher;
mod engine;
mod router;

pub use batcher::{BatchPlan, DynamicBatcher};
pub use engine::{Engine, GenStats};
pub use router::{Router, ServeRequest, ServeResponse};
