//! Batched serving engine (Appendix A.4 / Fig. 5): allocation-specialized
//! prefill + decode executables with device-resident weights and KV caches,
//! a dynamic batcher, and a threaded router front-end.
//!
//! The engine is the L3 hot path: after construction, a decode step is one
//! `execute_b` call — weights and caches never leave the device; only the
//! (batch,) token/length vectors cross the host boundary each step.

mod batcher;
mod engine;
mod router;

pub use batcher::{BatchPlan, DynamicBatcher};
pub use engine::{Engine, GenStats};
pub use router::{Router, ServeRequest, ServeResponse};
