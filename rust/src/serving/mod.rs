//! Batched serving (Appendix A.4 / Fig. 5): allocation-specialized
//! prefill + decode executables with device-resident weights and KV caches,
//! a continuous-batching scheduler over ragged prompts, seeded samplers,
//! a dynamic batcher, and a threaded router front-end.
//!
//! The engine is the L3 hot path and is backend-agnostic: after
//! construction, a decode step is one `run_device_args` call — weights are
//! passed borrowed (never copied), while KV caches move in owned so the
//! backend can update them in place (real device buffers on PJRT,
//! recycled-in-place host values on the default CPU interpreter); only the
//! (batch,) token/length vectors cross the host boundary each step.
//!
//! On top of the stepwise engine primitives (`prefill_into_slots`,
//! `decode_step`, `decode_step_paged`), the [`Scheduler`] packs
//! arbitrary-length prompts with per-request generation lengths and
//! sampling params into the fixed-batch decode graph, admitting new
//! requests into freed slots mid-flight. Serving memory is managed by a
//! block-paged, ref-counted [`KvPool`] with prefix sharing (`kvpool.rs`):
//! admission is gated on free blocks, identical prompt prefixes share
//! physical blocks copy-on-write, and pool exhaustion preempts the
//! youngest request instead of failing — see `scheduler.rs` for the
//! slot/block-table contract and the bitwise parity guarantee against
//! [`Engine::generate`].
//!
//! The resilience layer (DESIGN.md §5) sits on top: every request ends
//! with a typed [`FinishReason`] (deadline, cancellation, shed, fault
//! quarantine included), transient prefill/decode faults are contained to
//! the affected requests and retried deterministically, the [`Router`]
//! sheds load past a configurable queue depth, and a seeded
//! [`FaultPlan`] (`faults.rs`, `ARA_FAULT_PLAN`) drives the chaos-testing
//! harness (`tests/chaos.rs`, `benches/fig_chaos.rs`).
//!
//! The wire protocol (DESIGN.md §7) is the outermost layer: `http/` is a
//! std-only HTTP/1.1 front end over the [`Router`] — OpenAI-style
//! `POST /v1/completions` with chunked per-step token streaming (with
//! keep-alive connection reuse), `GET /healthz`, and `GET /stats` —
//! mapping the typed taxonomy onto status codes (429 shed, 408 deadline,
//! 499 disconnect, 500 quarantine, 503 connection cap).
//!
//! Self-speculative decoding (DESIGN.md §8, `specdec.rs`): a
//! heavier-compressed plan of the same backbone drafts `k` greedy tokens
//! per round and the target verifies the window in one batched
//! `decode_verify` pass — up to `k + 1` tokens per step, with accepted
//! streams bitwise identical to plain greedy decode.

mod batcher;
mod engine;
mod faults;
pub mod http;
mod kvpool;
mod router;
mod sampler;
mod scheduler;
mod specdec;

pub use batcher::{BatchPlan, DynamicBatcher};
pub use engine::{Engine, FinishReason, GenStats};
pub use faults::{FaultEvent, FaultKind, FaultPlan};
pub use http::{HttpCfg, HttpServer, ShutdownHandle};
pub use kvpool::{KvPool, KvPoolCfg, PoolStats, PrefixHit};
pub use router::{Router, RouterCfg, ServeRequest, ServeResponse, WorkerStats};
pub use sampler::{argmax, Sampler, SamplingParams};
pub use scheduler::{
    CancelToken, Completion, Request, SchedCfg, SchedStats, Scheduler, NO_SLOT,
};
pub use specdec::SpecDec;
