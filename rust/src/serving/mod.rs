//! Batched serving (Appendix A.4 / Fig. 5): allocation-specialized
//! prefill + decode executables with device-resident weights and KV caches,
//! a continuous-batching scheduler over ragged prompts, seeded samplers,
//! a dynamic batcher, and a threaded router front-end.
//!
//! The engine is the L3 hot path and is backend-agnostic: after
//! construction, a decode step is one `run_device_args` call — weights are
//! passed borrowed (never copied), while KV caches move in owned so the
//! backend can update them in place (real device buffers on PJRT,
//! recycled-in-place host values on the default CPU interpreter); only the
//! (batch,) token/length vectors cross the host boundary each step.
//!
//! On top of the stepwise engine primitives (`prefill_into_slots`,
//! `decode_step`, `decode_step_paged`), the [`Scheduler`] packs
//! arbitrary-length prompts with per-request generation lengths and
//! sampling params into the fixed-batch decode graph, admitting new
//! requests into freed slots mid-flight. Serving memory is managed by a
//! block-paged, ref-counted [`KvPool`] with prefix sharing (`kvpool.rs`):
//! admission is gated on free blocks, identical prompt prefixes share
//! physical blocks copy-on-write, and pool exhaustion preempts the
//! youngest request instead of failing — see `scheduler.rs` for the
//! slot/block-table contract and the bitwise parity guarantee against
//! [`Engine::generate`].

mod batcher;
mod engine;
mod kvpool;
mod router;
mod sampler;
mod scheduler;

pub use batcher::{BatchPlan, DynamicBatcher};
pub use engine::{Engine, FinishReason, GenStats};
pub use kvpool::{KvPool, KvPoolCfg, PoolStats, PrefixHit};
pub use router::{Router, ServeRequest, ServeResponse};
pub use sampler::{argmax, Sampler, SamplingParams};
pub use scheduler::{Completion, Request, SchedStats, Scheduler};
