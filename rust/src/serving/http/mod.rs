//! Std-only HTTP/1.1 front end over the [`Router`] (DESIGN.md §7): the
//! wire protocol the serving stack speaks to the outside world.
//!
//! Endpoints:
//! - `POST /v1/completions` — OpenAI-style completion over token ids.
//!   `stream: true` answers chunked: one protocol chunk per token as the
//!   scheduler produces it (one per `decode_step` arrival), then a final
//!   chunk carrying the complete completion body — byte-identical to the
//!   non-streaming response for the same request.
//! - `GET /healthz` — liveness (answered by the accept loop's thread
//!   pool, no engine round-trip).
//! - `GET /stats` — router admission counters plus a consistent worker
//!   snapshot ([`WorkerStats`]): in-flight depth, shed count, pool
//!   utilization, prefix-hit rate, plan provenance, SIMD tier.
//! - `POST /admin/shutdown` — graceful stop: the accept loop drains
//!   connection threads, joins the router worker (running the debug-build
//!   KV leak check), and [`HttpServer::run`] returns.
//!
//! The typed [`FinishReason`](crate::serving::FinishReason) taxonomy maps
//! onto distinct statuses ([`status_for`]): 200 `stop`/`length`, 429
//! `rejected` (bounded admission — no unbounded queueing), 408
//! `deadline_exceeded`, 499 `cancelled`, 500 `failed`. Client disconnect
//! trips the request's `CancelToken`, freeing its slot and KV blocks
//! mid-flight. Validation errors name the offending field; malformed or
//! oversized bodies are refused before the scheduler is touched.
//!
//! Connections are persistent (HTTP/1.1 keep-alive): a handler serves up
//! to `ARA_HTTP_KEEPALIVE_MAX` sequential requests per connection before
//! closing, honoring the client's `Connection` header; streamed
//! completions always close after the terminal chunk. The accept loop
//! caps live connections at `ARA_HTTP_MAX_CONNS` — excess connections
//! get an immediate 503 and are dropped without touching the engine.
//!
//! Knobs: `ARA_HTTP_MAX_BODY` (body cap, bytes), `ARA_HTTP_MAX_HEADER`
//! (head cap, bytes), `ARA_HTTP_POLL_MS` (accept/stream poll interval),
//! `ARA_HTTP_MAX_TOKENS` (per-request `max_tokens` cap),
//! `ARA_HTTP_KEEPALIVE_MAX` (requests per connection),
//! `ARA_HTTP_MAX_CONNS` (live connection cap).

mod conn;
mod types;
pub mod wire;

use std::net::{SocketAddr, TcpListener};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use super::router::Router;
use crate::Result;

pub use types::{status_for, CompletionRequest, FieldError};

/// HTTP layer knobs (`ARA_HTTP_*`).
#[derive(Debug, Clone, Copy)]
pub struct HttpCfg {
    /// Request body cap in bytes (`ARA_HTTP_MAX_BODY`, default 1 MiB);
    /// larger declared bodies get 400 without being read.
    pub max_body_bytes: usize,
    /// Request head cap in bytes (`ARA_HTTP_MAX_HEADER`, default 16 KiB).
    pub max_header_bytes: usize,
    /// Accept-loop and stream poll interval (`ARA_HTTP_POLL_MS`,
    /// default 5 ms) — also the disconnect-detection granularity.
    pub poll: Duration,
    /// Per-request `max_tokens` cap (`ARA_HTTP_MAX_TOKENS`, default 4096).
    pub max_tokens_cap: usize,
    /// Requests served per connection before it is closed
    /// (`ARA_HTTP_KEEPALIVE_MAX`, default 64; 1 disables reuse).
    pub keepalive_max: usize,
    /// Live-connection cap on the accept loop (`ARA_HTTP_MAX_CONNS`,
    /// default 256): excess connections get an immediate 503.
    pub max_conns: usize,
}

impl Default for HttpCfg {
    fn default() -> HttpCfg {
        HttpCfg {
            max_body_bytes: 1 << 20,
            max_header_bytes: 16 << 10,
            poll: Duration::from_millis(5),
            max_tokens_cap: 4096,
            keepalive_max: 64,
            max_conns: 256,
        }
    }
}

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        .unwrap_or(default)
}

impl HttpCfg {
    pub fn from_env() -> HttpCfg {
        let d = HttpCfg::default();
        HttpCfg {
            max_body_bytes: env_usize("ARA_HTTP_MAX_BODY", d.max_body_bytes).max(1),
            max_header_bytes: env_usize("ARA_HTTP_MAX_HEADER", d.max_header_bytes).max(64),
            poll: Duration::from_millis(
                env_usize("ARA_HTTP_POLL_MS", d.poll.as_millis() as usize).max(1) as u64,
            ),
            max_tokens_cap: env_usize("ARA_HTTP_MAX_TOKENS", d.max_tokens_cap).max(1),
            keepalive_max: env_usize("ARA_HTTP_KEEPALIVE_MAX", d.keepalive_max).max(1),
            max_conns: env_usize("ARA_HTTP_MAX_CONNS", d.max_conns).max(1),
        }
    }
}

/// Clonable stop signal for a running [`HttpServer`] — same flag the
/// `POST /admin/shutdown` endpoint flips.
#[derive(Debug, Clone)]
pub struct ShutdownHandle(Arc<AtomicBool>);

impl ShutdownHandle {
    pub fn shutdown(&self) {
        self.0.store(true, Ordering::Release);
    }
}

/// The listener + accept loop. Owns the [`Router`] for its lifetime and
/// joins it on shutdown so the worker-side KV leak check can fail the
/// process instead of being swallowed.
pub struct HttpServer {
    listener: TcpListener,
    router: Router,
    cfg: HttpCfg,
    stop: Arc<AtomicBool>,
    vocab: usize,
}

impl HttpServer {
    /// Bind `addr` (e.g. `127.0.0.1:8080`; port 0 picks a free one —
    /// read it back via [`HttpServer::local_addr`]). `vocab` bounds
    /// prompt token ids at validation.
    pub fn bind(addr: &str, router: Router, vocab: usize, cfg: HttpCfg) -> Result<HttpServer> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| crate::anyhow!("bind {addr}: {e}"))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| crate::anyhow!("set_nonblocking: {e}"))?;
        Ok(HttpServer {
            listener,
            router,
            cfg,
            stop: Arc::new(AtomicBool::new(false)),
            vocab,
        })
    }

    pub fn local_addr(&self) -> Result<SocketAddr> {
        self.listener.local_addr().map_err(|e| crate::anyhow!("local_addr: {e}"))
    }

    pub fn shutdown_handle(&self) -> ShutdownHandle {
        ShutdownHandle(Arc::clone(&self.stop))
    }

    /// Serve until the stop flag flips (`/admin/shutdown` or a
    /// [`ShutdownHandle`]), then drain connection threads and join the
    /// router worker. `Err` when the worker panicked during teardown —
    /// in debug builds that includes a tripped KV-pool leak check.
    pub fn run(self) -> Result<()> {
        let HttpServer { listener, router, cfg, stop, vocab } = self;
        let ctx = Arc::new(conn::Ctx {
            router: Arc::new(router),
            cfg,
            stop: Arc::clone(&stop),
            vocab,
        });
        let mut workers: Vec<JoinHandle<()>> = Vec::new();
        while !stop.load(Ordering::Acquire) {
            match listener.accept() {
                Ok((mut sock, _peer)) => {
                    // accepted sockets may inherit the listener's
                    // nonblocking flag on some platforms — the handlers
                    // assume blocking I/O
                    let _ = sock.set_nonblocking(false);
                    let _ = sock.set_nodelay(true);
                    // connection cap: reap finished handlers first, then
                    // shed with an immediate 503 — no handler thread, no
                    // request read, no engine work
                    workers.retain(|w| !w.is_finished());
                    if workers.len() >= cfg.max_conns {
                        let _ = wire::write_response(
                            &mut sock,
                            503,
                            "Service Unavailable",
                            r#"{"error":{"type":"server_error","message":"connection limit reached"}}"#,
                            false,
                        );
                        continue;
                    }
                    let c = Arc::clone(&ctx);
                    workers.push(std::thread::spawn(move || conn::handle(sock, &c)));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(cfg.poll);
                }
                Err(_) => {
                    // transient accept failure (EMFILE, ECONNABORTED, …):
                    // back off and keep serving
                    std::thread::sleep(cfg.poll);
                }
            }
            // reap finished handlers so the vec doesn't grow unboundedly
            workers.retain(|w| !w.is_finished());
        }
        drop(listener);
        for w in workers {
            let _ = w.join();
        }
        // every connection thread is gone, so both Arcs are unique again:
        // unwrap and join the router, surfacing worker panics
        match Arc::try_unwrap(ctx) {
            Ok(ctx) => match Arc::try_unwrap(ctx.router) {
                Ok(router) => router.join(),
                Err(_) => Ok(()),
            },
            Err(_) => Ok(()),
        }
    }
}
