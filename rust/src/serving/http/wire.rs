//! Wire-level HTTP/1.1 framing: a bounded request reader, plain and
//! chunked response writers, and a minimal loopback client (used by
//! `tests/http.rs` and `benches/fig_http.rs` — the client preserves chunk
//! boundaries, which carry the one-chunk-per-decode-step framing the
//! streaming tests pin).
//!
//! Deliberately minimal: `Content-Length` bodies only on the way in,
//! identity or chunked on the way out. Connections are reusable
//! (HTTP/1.1 keep-alive semantics: persistent unless `Connection: close`;
//! HTTP/1.0 closes unless `Connection: keep-alive`); bytes read past the
//! current body — a pipelining client — are preserved in the caller's
//! carry buffer and consumed by the next [`read_request`] on the same
//! connection. Both caps ([`super::HttpCfg::max_header_bytes`],
//! [`super::HttpCfg::max_body_bytes`]) are enforced *before* any work is
//! scheduled, so malformed or oversized requests never touch the engine.

use std::io::{Read, Write};
use std::net::TcpStream;

/// A parsed inbound request (head + body, bounded).
#[derive(Debug)]
pub struct RawRequest {
    pub method: String,
    pub path: String,
    pub body: Vec<u8>,
    /// Whether the client allows the connection to be reused after this
    /// response (HTTP/1.1 default; overridden by a `Connection` header).
    pub keep_alive: bool,
}

/// Why [`read_request`] produced no request.
#[derive(Debug)]
pub enum WireError {
    /// Peer closed (or reset) before a full head arrived — nothing to
    /// answer.
    Closed,
    /// Unparseable request line / headers / body framing → 400.
    Malformed(String),
    /// Declared or actual size over a cap → 400, connection dropped
    /// without reading the rest.
    TooLarge(String),
}

fn header_value<'a>(head: &'a str, name: &str) -> Option<&'a str> {
    head.lines().skip(1).find_map(|l| {
        let (k, v) = l.split_once(':')?;
        k.trim().eq_ignore_ascii_case(name).then(|| v.trim())
    })
}

/// Read one request off the socket: head until `\r\n\r\n` (capped), then
/// exactly `Content-Length` body bytes (capped). The declared length is
/// checked against the cap *before* the body is read. `carry` holds bytes
/// read past the previous request's body on a reused connection — they
/// are consumed first, and any over-read past this request's body is
/// placed back for the next call.
pub fn read_request(
    stream: &mut TcpStream,
    carry: &mut Vec<u8>,
    max_header: usize,
    max_body: usize,
) -> Result<RawRequest, WireError> {
    let mut buf: Vec<u8> = std::mem::take(carry);
    let mut chunk = [0u8; 1024];
    let head_end = loop {
        if let Some(p) = find_head_end(&buf) {
            break p;
        }
        if buf.len() > max_header {
            return Err(WireError::TooLarge(format!(
                "request head exceeds {max_header} bytes"
            )));
        }
        match stream.read(&mut chunk) {
            Ok(0) => {
                return match buf.is_empty() {
                    true => Err(WireError::Closed),
                    false => Err(WireError::Malformed("truncated request head".into())),
                }
            }
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e) => return Err(WireError::Malformed(format!("read failed: {e}"))),
        }
    };
    let head = std::str::from_utf8(&buf[..head_end])
        .map_err(|_| WireError::Malformed("request head is not utf-8".into()))?
        .to_string();
    let mut line = head.lines().next().unwrap_or("").split_whitespace();
    let (method, path, version) = match (line.next(), line.next(), line.next()) {
        (Some(m), Some(p), Some(v)) => (m.to_string(), p.to_string(), v),
        _ => return Err(WireError::Malformed("bad request line".into())),
    };
    if !version.starts_with("HTTP/1.") {
        return Err(WireError::Malformed(format!("unsupported version `{version}`")));
    }
    let clen = match header_value(&head, "content-length") {
        None => 0,
        Some(v) => v
            .parse::<usize>()
            .map_err(|_| WireError::Malformed(format!("bad content-length `{v}`")))?,
    };
    if clen > max_body {
        return Err(WireError::TooLarge(format!(
            "declared body of {clen} bytes exceeds the {max_body}-byte cap"
        )));
    }
    let keep_alive = match header_value(&head, "connection") {
        Some(v) if v.eq_ignore_ascii_case("close") => false,
        Some(v) if v.eq_ignore_ascii_case("keep-alive") => true,
        // HTTP/1.1 defaults persistent; HTTP/1.0 defaults close
        _ => version == "HTTP/1.1",
    };
    let mut body = buf[head_end + 4..].to_vec();
    while body.len() < clen {
        match stream.read(&mut chunk) {
            Ok(0) => return Err(WireError::Malformed("truncated request body".into())),
            Ok(n) => body.extend_from_slice(&chunk[..n]),
            Err(e) => return Err(WireError::Malformed(format!("read failed: {e}"))),
        }
    }
    if body.len() > clen {
        // bytes past this body belong to the next pipelined request:
        // park them for the next read_request on this connection
        *carry = body.split_off(clen);
    }
    Ok(RawRequest { method, path, body, keep_alive })
}

fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

/// Write a complete identity-framed JSON response and flush. `keep`
/// selects the `Connection` header — the body is byte-identical either
/// way (the determinism contract covers bodies, not connection framing).
pub fn write_response(
    stream: &mut TcpStream,
    status: u16,
    reason: &str,
    body: &str,
    keep: bool,
) -> std::io::Result<()> {
    let conn = if keep { "keep-alive" } else { "close" };
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: {conn}\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

/// Commit a chunked 200 response: header out, status pinned. Callers
/// defer this until the first token arrives so an empty-handed
/// non-natural finish can still get its mapped status code. Streamed
/// responses always close the connection — the chunk cadence is tied to
/// the decode loop, so reuse would serialize unrelated requests behind it.
pub fn start_chunked(stream: &mut TcpStream) -> std::io::Result<()> {
    let head = "HTTP/1.1 200 OK\r\nContent-Type: application/json\r\nTransfer-Encoding: chunked\r\nConnection: close\r\n\r\n";
    stream.write_all(head.as_bytes())?;
    stream.flush()
}

/// One size-prefixed protocol chunk (the framing unit the client
/// reassembles — never split or merged by TCP segmentation).
pub fn write_chunk(stream: &mut TcpStream, data: &[u8]) -> std::io::Result<()> {
    stream.write_all(format!("{:x}\r\n", data.len()).as_bytes())?;
    stream.write_all(data)?;
    stream.write_all(b"\r\n")?;
    stream.flush()
}

/// The zero-length terminal chunk.
pub fn finish_chunked(stream: &mut TcpStream) -> std::io::Result<()> {
    stream.write_all(b"0\r\n\r\n")?;
    stream.flush()
}

// ---------- loopback client (tests + load bench) ----------

/// A client-side response: status, raw body, and — for chunked responses —
/// the protocol chunks in arrival order (`body` is their concatenation).
#[derive(Debug)]
pub struct ClientResponse {
    pub status: u16,
    pub body: Vec<u8>,
    /// `Some` iff the response was chunked; one entry per protocol chunk.
    pub chunks: Option<Vec<Vec<u8>>>,
}

/// Issue one request and read the full response (blocking).
pub fn http_call(
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&str>,
) -> crate::Result<ClientResponse> {
    let mut stream = TcpStream::connect(addr)
        .map_err(|e| crate::anyhow!("connect {addr}: {e}"))?;
    send_request(&mut stream, method, path, body)?;
    read_response(&mut stream)
}

/// Write a request head + optional body on an already-open connection
/// (`Connection: close` — the one-shot [`http_call`] path).
pub fn send_request(
    stream: &mut TcpStream,
    method: &str,
    path: &str,
    body: Option<&str>,
) -> crate::Result<()> {
    send_request_keep(stream, method, path, body, false)
}

/// [`send_request`] with an explicit `Connection` choice: `keep = true`
/// asks the server to hold the connection open for another request.
pub fn send_request_keep(
    stream: &mut TcpStream,
    method: &str,
    path: &str,
    body: Option<&str>,
    keep: bool,
) -> crate::Result<()> {
    let body = body.unwrap_or("");
    let conn = if keep { "keep-alive" } else { "close" };
    let head = format!(
        "{method} {path} HTTP/1.1\r\nHost: localhost\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: {conn}\r\n\r\n",
        body.len()
    );
    stream
        .write_all(head.as_bytes())
        .and_then(|_| stream.write_all(body.as_bytes()))
        .and_then(|_| stream.flush())
        .map_err(|e| crate::anyhow!("send {method} {path}: {e}"))
}

/// Read a full response off the socket, decoding chunked framing (chunk
/// boundaries preserved) or `Content-Length` identity bodies. Reads
/// incrementally and stops at the end of the framed response — never
/// relies on the server closing the connection, so it works on
/// keep-alive connections (issue [`send_request_keep`] again afterwards).
pub fn read_response(stream: &mut TcpStream) -> crate::Result<ClientResponse> {
    let mut buf: Vec<u8> = Vec::with_capacity(512);
    let mut chunk = [0u8; 1024];
    let mut eof = false;
    let mut fill = |buf: &mut Vec<u8>, eof: &mut bool| -> crate::Result<()> {
        match stream.read(&mut chunk) {
            Ok(0) => {
                *eof = true;
                Ok(())
            }
            Ok(n) => {
                buf.extend_from_slice(&chunk[..n]);
                Ok(())
            }
            Err(e) => Err(crate::anyhow!("read response: {e}")),
        }
    };
    let head_end = loop {
        if let Some(p) = find_head_end(&buf) {
            break p;
        }
        if eof {
            return Err(crate::anyhow!("no header terminator in response"));
        }
        fill(&mut buf, &mut eof)?;
    };
    let head = std::str::from_utf8(&buf[..head_end])
        .map_err(|_| crate::anyhow!("response head is not utf-8"))?
        .to_string();
    let status: u16 = head
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| crate::anyhow!("bad status line `{}`", head.lines().next().unwrap_or("")))?;
    let chunked = header_value(&head, "transfer-encoding")
        .is_some_and(|v| v.eq_ignore_ascii_case("chunked"));
    if !chunked {
        let clen = match header_value(&head, "content-length") {
            Some(v) => v
                .parse::<usize>()
                .map_err(|_| crate::anyhow!("bad content-length `{v}`"))?,
            None => 0,
        };
        while buf.len() < head_end + 4 + clen {
            if eof {
                return Err(crate::anyhow!("truncated response body"));
            }
            fill(&mut buf, &mut eof)?;
        }
        let body = buf[head_end + 4..head_end + 4 + clen].to_vec();
        return Ok(ClientResponse { status, body, chunks: None });
    }
    let mut chunks = Vec::new();
    let mut i = head_end + 4;
    loop {
        let line_end = loop {
            if let Some(p) = buf[i..].windows(2).position(|w| w == b"\r\n") {
                break p;
            }
            if eof {
                return Err(crate::anyhow!("truncated chunk size line"));
            }
            fill(&mut buf, &mut eof)?;
        };
        let size_str = std::str::from_utf8(&buf[i..i + line_end])
            .map_err(|_| crate::anyhow!("chunk size is not utf-8"))?
            .to_string();
        let size = usize::from_str_radix(size_str.trim(), 16)
            .map_err(|_| crate::anyhow!("bad chunk size `{size_str}`"))?;
        i += line_end + 2;
        while buf.len() < i + size + 2 {
            if eof {
                return Err(crate::anyhow!("truncated chunk body"));
            }
            fill(&mut buf, &mut eof)?;
        }
        if size == 0 {
            break;
        }
        chunks.push(buf[i..i + size].to_vec());
        i += size + 2;
    }
    let body = chunks.concat();
    Ok(ClientResponse { status, body, chunks: Some(chunks) })
}
