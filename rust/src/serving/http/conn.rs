//! Per-connection request handling: route dispatch, the completion
//! wait/stream loops, and client-disconnect detection. Each connection
//! runs on its own thread so a slow stream never blocks the accept loop,
//! and serves up to [`super::HttpCfg::keepalive_max`] requests
//! sequentially (HTTP/1.1 keep-alive) before closing; streamed
//! completions always close after the terminal chunk.
//!
//! Disconnect contract: while a completion is in flight the handler peeks
//! the socket between polls — EOF trips the request's [`CancelToken`], so
//! the scheduler frees the slot and KV blocks mid-flight, and the handler
//! still drains the typed response (the router's depth accounting relies
//! on every reply being consumed or dropped, never leaked).

use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};

use super::types::{self, CompletionRequest};
use super::wire::{self, WireError};
use super::HttpCfg;
use crate::serving::{CancelToken, Router, ServeRequest, ServeResponse};

/// Shared per-server state each connection thread gets a handle to.
pub(super) struct Ctx {
    pub router: Arc<Router>,
    pub cfg: HttpCfg,
    pub stop: Arc<AtomicBool>,
    pub vocab: usize,
}

pub(super) fn handle(mut stream: TcpStream, ctx: &Ctx) {
    let mut carry: Vec<u8> = Vec::new();
    let max = ctx.cfg.keepalive_max.max(1);
    for served in 1..=max {
        let raw = match wire::read_request(
            &mut stream,
            &mut carry,
            ctx.cfg.max_header_bytes,
            ctx.cfg.max_body_bytes,
        ) {
            Ok(r) => r,
            Err(WireError::Closed) => return,
            // malformed and oversized requests are answered without ever
            // touching the router/scheduler; framing is unrecoverable, so
            // the connection closes regardless of keep-alive
            Err(WireError::Malformed(m)) | Err(WireError::TooLarge(m)) => {
                let _ = wire::write_response(
                    &mut stream,
                    400,
                    "Bad Request",
                    &types::error_body("invalid_request_error", Some("body"), &m),
                    false,
                );
                return;
            }
        };
        // honor the client's Connection preference, capped at
        // keepalive_max requests per connection
        let keep = raw.keep_alive && served < max;
        if !dispatch(&mut stream, ctx, &raw, keep) || !keep {
            return;
        }
    }
}

/// Route one parsed request. Returns whether the connection is still
/// reusable (a streamed completion commits `Connection: close` framing,
/// so it never is).
fn dispatch(stream: &mut TcpStream, ctx: &Ctx, raw: &wire::RawRequest, keep: bool) -> bool {
    match (raw.method.as_str(), raw.path.as_str()) {
        ("GET", "/healthz") => {
            let _ =
                wire::write_response(stream, 200, "OK", r#"{"status":"ok"}"#, keep);
            true
        }
        ("GET", "/stats") => {
            match ctx.router.worker_stats() {
                Ok(ws) => {
                    let body =
                        types::stats_body(&ws, ctx.router.in_flight(), ctx.router.shed());
                    let _ = wire::write_response(stream, 200, "OK", &body, keep);
                }
                Err(e) => {
                    let _ = wire::write_response(
                        stream,
                        503,
                        "Service Unavailable",
                        &types::error_body("server_error", None, &e.to_string()),
                        keep,
                    );
                }
            }
            true
        }
        ("POST", "/admin/shutdown") => {
            ctx.stop.store(true, Ordering::Release);
            let _ = wire::write_response(
                stream,
                200,
                "OK",
                r#"{"status":"shutting_down"}"#,
                false,
            );
            false
        }
        ("POST", "/v1/completions") => completions(stream, ctx, &raw.body, keep),
        (_, "/healthz" | "/stats" | "/admin/shutdown" | "/v1/completions") => {
            let _ = wire::write_response(
                stream,
                405,
                "Method Not Allowed",
                &types::error_body(
                    "invalid_request_error",
                    None,
                    &format!("method {} not allowed on {}", raw.method, raw.path),
                ),
                keep,
            );
            true
        }
        (m, p) => {
            let _ = wire::write_response(
                stream,
                404,
                "Not Found",
                &types::error_body("not_found", None, &format!("no route `{m} {p}`")),
                keep,
            );
            true
        }
    }
}

fn completions(stream: &mut TcpStream, ctx: &Ctx, body: &[u8], keep: bool) -> bool {
    let creq = match CompletionRequest::parse(body, ctx.vocab, ctx.cfg.max_tokens_cap) {
        Ok(r) => r,
        Err(e) => {
            let _ = wire::write_response(
                stream,
                400,
                "Bad Request",
                &types::error_body("invalid_request_error", Some(&e.field), &e.message),
                keep,
            );
            return true;
        }
    };
    let cancel = CancelToken::new();
    let (stream_tx, stream_rx) = match creq.stream {
        true => {
            let (tx, rx) = mpsc::channel();
            (Some(tx), Some(rx))
        }
        false => (None, None),
    };
    let sreq = ServeRequest {
        prompt: creq.prompt,
        gen_len: creq.max_tokens,
        params: creq.params,
        deadline_steps: creq.timeout_steps,
        cancel: Some(cancel.clone()),
        stream: stream_tx,
        draft_spec: creq.draft,
    };
    let rx = match ctx.router.submit(sreq) {
        Ok(rx) => rx,
        Err(e) => {
            let _ = wire::write_response(
                stream,
                503,
                "Service Unavailable",
                &types::error_body("server_error", None, &e.to_string()),
                keep,
            );
            return true;
        }
    };
    match stream_rx {
        None => finish_plain(stream, ctx, &cancel, &rx, keep),
        Some(srx) => finish_streaming(stream, ctx, &cancel, &rx, &srx, keep),
    }
}

/// Non-streaming: block for the typed response, peeking for disconnect
/// between polls. A gone peer cancels the request but keeps waiting for
/// the response — the scheduler's completion is what frees the slot.
/// Returns whether the connection is still reusable.
fn finish_plain(
    stream: &mut TcpStream,
    ctx: &Ctx,
    cancel: &CancelToken,
    rx: &mpsc::Receiver<ServeResponse>,
    keep: bool,
) -> bool {
    let mut gone = false;
    loop {
        match rx.recv_timeout(ctx.cfg.poll) {
            Ok(resp) => {
                if !gone {
                    let (code, reason) = types::status_for(&resp.finish_reason);
                    let _ = wire::write_response(
                        stream,
                        code,
                        reason,
                        &types::completion_body(&resp),
                        keep,
                    );
                }
                return !gone;
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                if !gone && peer_gone(stream) {
                    gone = true;
                    cancel.cancel();
                }
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                if !gone {
                    let _ = wire::write_response(
                        stream,
                        500,
                        "Internal Server Error",
                        &types::error_body(
                            "server_error",
                            None,
                            "router worker exited without answering",
                        ),
                        keep,
                    );
                }
                return !gone;
            }
        }
    }
}

/// Streaming: one protocol chunk per token as it arrives from the
/// scheduler, then a final chunk carrying the complete completion body
/// (byte-identical to the non-streaming response — the reassembly
/// contract). The 200 chunked header is deferred until the first token,
/// so a request that ends non-naturally before producing anything still
/// gets its mapped status code as a plain response.
fn finish_streaming(
    stream: &mut TcpStream,
    ctx: &Ctx,
    cancel: &CancelToken,
    rx: &mpsc::Receiver<ServeResponse>,
    srx: &mpsc::Receiver<i32>,
    keep: bool,
) -> bool {
    let mut started = false;
    let mut gone = false;
    let resp = loop {
        match rx.try_recv() {
            Ok(r) => break Some(r),
            Err(mpsc::TryRecvError::Empty) => {}
            Err(mpsc::TryRecvError::Disconnected) => break None,
        }
        pump_tokens(stream, srx, cancel, &mut started, &mut gone);
        if !gone && peer_gone(stream) {
            gone = true;
            cancel.cancel();
        }
        std::thread::sleep(ctx.cfg.poll);
    };
    let Some(resp) = resp else {
        if !gone && !started {
            let _ = wire::write_response(
                stream,
                500,
                "Internal Server Error",
                &types::error_body(
                    "server_error",
                    None,
                    "router worker exited without answering",
                ),
                keep,
            );
            return true;
        }
        return false;
    };
    // the worker emits every token before it answers, so the sink is
    // fully populated by now — flush the stragglers first
    pump_tokens(stream, srx, cancel, &mut started, &mut gone);
    if gone {
        return false;
    }
    if started {
        // chunked framing committed `Connection: close` — never reuse
        let _ = wire::write_chunk(stream, types::completion_body(&resp).as_bytes());
        let _ = wire::finish_chunked(stream);
        false
    } else {
        let (code, reason) = types::status_for(&resp.finish_reason);
        let _ =
            wire::write_response(stream, code, reason, &types::completion_body(&resp), keep);
        true
    }
}

/// Drain every token currently in the sink onto the wire. A write failure
/// means the peer vanished mid-stream: flip `gone`, trip the cancel
/// token, and keep draining (tokens are consumed either way so the final
/// accounting stays consistent).
fn pump_tokens(
    stream: &mut TcpStream,
    srx: &mpsc::Receiver<i32>,
    cancel: &CancelToken,
    started: &mut bool,
    gone: &mut bool,
) {
    while let Ok(tok) = srx.try_recv() {
        if *gone {
            continue;
        }
        if !*started {
            if wire::start_chunked(stream).is_err() {
                *gone = true;
                cancel.cancel();
                continue;
            }
            *started = true;
        }
        if wire::write_chunk(stream, types::token_chunk(tok).as_bytes()).is_err() {
            *gone = true;
            cancel.cancel();
        }
    }
}

/// Has the peer closed its end? A zero-byte nonblocking peek is EOF ⇒
/// gone; `WouldBlock` (nothing to read, connection alive) and stray
/// pipelined bytes are not.
fn peer_gone(stream: &TcpStream) -> bool {
    if stream.set_nonblocking(true).is_err() {
        return true;
    }
    let mut buf = [0u8; 1];
    let gone = match stream.peek(&mut buf) {
        Ok(0) => true,
        Ok(_) => false,
        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => false,
        Err(_) => true,
    };
    let _ = stream.set_nonblocking(false);
    gone
}
