//! Request/response types for the completion endpoint: schema validation
//! that names the offending field (the `dufeutech` typed-route style), the
//! pinned `FinishReason` → HTTP status mapping, and the JSON body
//! builders. Bodies are deterministic by construction — no timestamps,
//! ids, or run-varying floats — so a greedy completion is byte-identical
//! across runs and across the streaming/non-streaming paths (the e2e
//! gate's determinism assertion).

use crate::json::{self, Json};
use crate::serving::{FinishReason, SamplingParams, ServeResponse, WorkerStats};

/// A validation failure that names the offending request field.
#[derive(Debug, Clone, PartialEq)]
pub struct FieldError {
    pub field: String,
    pub message: String,
}

fn fe(field: &str, message: impl Into<String>) -> FieldError {
    FieldError { field: field.to_string(), message: message.into() }
}

/// A validated `POST /v1/completions` request.
#[derive(Debug, Clone)]
pub struct CompletionRequest {
    pub prompt: Vec<i32>,
    pub max_tokens: usize,
    pub params: SamplingParams,
    /// Step-budget deadline (`Request::deadline_steps` on the wire).
    pub timeout_steps: Option<usize>,
    pub stream: bool,
    /// Self-speculative decoding opt-in/out (`Request::draft_spec` on the
    /// wire): a draft-plan spec such as `"ara@0.35"`, `""` to opt out of
    /// the server default, absent to inherit it.
    pub draft: Option<String>,
}

const KNOWN_FIELDS: &[&str] = &[
    "prompt",
    "max_tokens",
    "temperature",
    "top_k",
    "top_p",
    "seed",
    "timeout_steps",
    "stream",
    "draft",
];

impl CompletionRequest {
    /// Parse and validate a request body. `vocab` bounds prompt token ids;
    /// `max_tokens_cap` bounds the generation budget
    /// ([`super::HttpCfg::max_tokens_cap`]).
    pub fn parse(
        body: &[u8],
        vocab: usize,
        max_tokens_cap: usize,
    ) -> Result<CompletionRequest, FieldError> {
        let text = std::str::from_utf8(body)
            .map_err(|_| fe("body", "request body is not utf-8"))?;
        let j = json::parse(text).map_err(|e| fe("body", format!("invalid JSON: {e}")))?;
        let pairs = j
            .as_obj()
            .map_err(|_| fe("body", "top level must be a JSON object"))?;
        for (k, _) in pairs {
            if !KNOWN_FIELDS.contains(&k.as_str()) {
                return Err(fe(k, "unknown field"));
            }
        }

        let prompt = match j.get("prompt") {
            None => Vec::new(),
            Some(v) => {
                let arr = v
                    .as_arr()
                    .map_err(|_| fe("prompt", "must be an array of token ids"))?;
                let mut toks = Vec::with_capacity(arr.len());
                for t in arr {
                    let id = t.as_usize().map_err(|_| {
                        fe("prompt", "token ids must be non-negative integers")
                    })?;
                    if id >= vocab {
                        return Err(fe(
                            "prompt",
                            format!("token id {id} out of range (vocab {vocab})"),
                        ));
                    }
                    toks.push(id as i32);
                }
                toks
            }
        };

        let max_tokens = j
            .get("max_tokens")
            .ok_or_else(|| fe("max_tokens", "required"))?
            .as_usize()
            .map_err(|_| fe("max_tokens", "must be a non-negative integer"))?;
        if max_tokens > max_tokens_cap {
            return Err(fe(
                "max_tokens",
                format!("exceeds the server cap of {max_tokens_cap}"),
            ));
        }

        let mut params = SamplingParams::greedy();
        if let Some(v) = j.get("temperature") {
            let t = v.as_f64().map_err(|_| fe("temperature", "must be a number"))?;
            if !(0.0..=100.0).contains(&t) {
                return Err(fe("temperature", "must be in [0, 100]"));
            }
            params.temperature = t;
        }
        if let Some(v) = j.get("top_k") {
            params.top_k = v
                .as_usize()
                .map_err(|_| fe("top_k", "must be a non-negative integer"))?;
        }
        if let Some(v) = j.get("top_p") {
            let p = v.as_f64().map_err(|_| fe("top_p", "must be a number"))?;
            if !(p > 0.0 && p <= 1.0) {
                return Err(fe("top_p", "must be in (0, 1]"));
            }
            params.top_p = p;
        }
        if let Some(v) = j.get("seed") {
            params.seed = v
                .as_usize()
                .map_err(|_| fe("seed", "must be a non-negative integer"))?
                as u64;
        }

        let timeout_steps = match j.get("timeout_steps") {
            None => None,
            Some(v) => {
                let t = v
                    .as_usize()
                    .map_err(|_| fe("timeout_steps", "must be a non-negative integer"))?;
                if t == 0 {
                    return Err(fe("timeout_steps", "must be at least 1"));
                }
                Some(t)
            }
        };

        let stream = match j.get("stream") {
            None => false,
            Some(v) => v.as_bool().map_err(|_| fe("stream", "must be a boolean"))?,
        };

        let draft = match j.get("draft") {
            None => None,
            Some(v) => Some(
                v.as_str()
                    .map_err(|_| fe("draft", "must be a draft-plan spec string"))?
                    .to_string(),
            ),
        };

        Ok(CompletionRequest { prompt, max_tokens, params, timeout_steps, stream, draft })
    }
}

/// The pinned `FinishReason` → HTTP status mapping (DESIGN.md §7): natural
/// finishes are 200; every non-natural reason gets a distinct status so
/// load-bench and operator tooling can separate overload (429) from
/// deadline pressure (408), client disconnects (499), and quarantine
/// (500) without parsing bodies.
pub fn status_for(reason: &FinishReason) -> (u16, &'static str) {
    match reason {
        FinishReason::Stop | FinishReason::Length => (200, "OK"),
        FinishReason::Rejected => (429, "Too Many Requests"),
        FinishReason::DeadlineExceeded => (408, "Request Timeout"),
        FinishReason::Cancelled => (499, "Client Closed Request"),
        FinishReason::Failed { .. } => (500, "Internal Server Error"),
    }
}

/// The completion body — identical for the non-streaming response and the
/// final chunk of a streamed response (the reassembly contract
/// `tests/http.rs` pins).
pub fn completion_body(resp: &ServeResponse) -> String {
    let toks = Json::Arr(resp.tokens.iter().map(|&t| json::n(t as f64)).collect());
    let mut pairs = vec![
        ("object", json::s("text_completion")),
        ("finish_reason", json::s(resp.finish_reason.label())),
        ("token_count", json::n(resp.tokens.len() as f64)),
        ("tokens", toks),
        ("retries", json::n(resp.retries as f64)),
    ];
    if let Some(e) = &resp.error {
        pairs.push(("error", json::s(e.clone())));
    }
    json::obj(pairs).dump()
}

/// One streamed token chunk: `{"token":N}` + newline, one per
/// `decode_step` arrival.
pub fn token_chunk(tok: i32) -> String {
    let mut s = json::obj(vec![("token", json::n(tok as f64))]).dump();
    s.push('\n');
    s
}

/// Structured error body: `{"error":{"type","field"?,"message"}}` —
/// validation errors carry the offending field by name.
pub fn error_body(kind: &str, field: Option<&str>, message: &str) -> String {
    let mut pairs = vec![("type", json::s(kind))];
    if let Some(f) = field {
        pairs.push(("field", json::s(f)));
    }
    pairs.push(("message", json::s(message)));
    json::obj(vec![("error", json::obj(pairs))]).dump()
}

/// The `GET /stats` body: router admission counters + the worker's
/// serve-loop snapshot (pool occupancy, prefix-cache hit rate, plan
/// provenance, SIMD tier).
pub fn stats_body(ws: &WorkerStats, in_flight: usize, shed: usize) -> String {
    let s = &ws.sched;
    json::obj(vec![
        ("in_flight", json::n(in_flight as f64)),
        ("shed", json::n(shed as f64)),
        ("queued", json::n(ws.queued as f64)),
        ("active", json::n(ws.active as f64)),
        (
            "pool",
            json::obj(vec![
                ("used_blocks", json::n(ws.pool_used_blocks as f64)),
                ("utilization", json::n(ws.pool_utilization)),
                ("peak_utilization", json::n(s.pool_peak_util)),
            ]),
        ),
        ("prefix_hit_rate", json::n(ws.prefix_hit_rate)),
        (
            "provenance",
            match &ws.provenance {
                Some(p) => json::s(p.clone()),
                None => Json::Null,
            },
        ),
        // the composed compression recipe the engine serves with: quant is
        // null for pure-f32 plans, {bits, group} when factors are packed
        (
            "plan",
            json::obj(vec![
                (
                    "provenance",
                    match &ws.provenance {
                        Some(p) => json::s(p.clone()),
                        None => Json::Null,
                    },
                ),
                (
                    "quant",
                    match ws.quant {
                        Some(q) => json::obj(vec![
                            ("bits", json::n(q.bits as f64)),
                            ("group", json::n(q.group as f64)),
                        ]),
                        None => Json::Null,
                    },
                ),
            ]),
        ),
        ("simd_tier", json::s(ws.simd_tier)),
        (
            "sched",
            json::obj(vec![
                ("steps", json::n(s.steps as f64)),
                ("admitted", json::n(s.admitted as f64)),
                ("completed", json::n(s.completed as f64)),
                ("tokens_generated", json::n(s.tokens_generated as f64)),
                ("streamed", json::n(s.streamed as f64)),
                ("preemptions", json::n(s.preemptions as f64)),
                ("retries", json::n(s.retries as f64)),
                ("quarantined", json::n(s.quarantined as f64)),
                ("cancelled", json::n(s.cancelled as f64)),
                ("deadline_expired", json::n(s.deadline_expired as f64)),
                ("decode_tok_per_s", json::n(s.decode_tok_per_s())),
                ("verify_passes", json::n(s.verify_passes as f64)),
                ("draft_tokens", json::n(s.draft_tokens as f64)),
                ("draft_accepted", json::n(s.draft_accepted as f64)),
                ("accepted_per_verify", json::n(s.accepted_per_verify())),
            ]),
        ),
        (
            "draft",
            match &ws.draft_spec {
                Some(spec) => json::obj(vec![
                    ("spec", json::s(spec.clone())),
                    (
                        "pool_utilization",
                        json::n(ws.draft_pool_utilization.unwrap_or(0.0)),
                    ),
                    ("active_drafts", json::n(ws.active_drafts as f64)),
                ]),
                None => Json::Null,
            },
        ),
    ])
    .dump()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The satellite fix's contract: every non-natural reason maps to a
    /// distinct status; natural finishes are 200. Pinned value-by-value so
    /// a remap is a deliberate test edit, not an accident.
    #[test]
    fn status_mapping_is_pinned() {
        assert_eq!(status_for(&FinishReason::Stop), (200, "OK"));
        assert_eq!(status_for(&FinishReason::Length), (200, "OK"));
        assert_eq!(status_for(&FinishReason::Rejected), (429, "Too Many Requests"));
        assert_eq!(status_for(&FinishReason::DeadlineExceeded), (408, "Request Timeout"));
        assert_eq!(status_for(&FinishReason::Cancelled), (499, "Client Closed Request"));
        assert_eq!(
            status_for(&FinishReason::Failed { retries: 3 }),
            (500, "Internal Server Error")
        );
        // distinctness across the non-natural taxonomy
        let codes = [
            status_for(&FinishReason::Rejected).0,
            status_for(&FinishReason::DeadlineExceeded).0,
            status_for(&FinishReason::Cancelled).0,
            status_for(&FinishReason::Failed { retries: 0 }).0,
        ];
        for i in 0..codes.len() {
            for j in i + 1..codes.len() {
                assert_ne!(codes[i], codes[j]);
            }
        }
    }

    #[test]
    fn wire_labels_are_pinned() {
        assert_eq!(FinishReason::Stop.label(), "stop");
        assert_eq!(FinishReason::Length.label(), "length");
        assert_eq!(FinishReason::Cancelled.label(), "cancelled");
        assert_eq!(FinishReason::DeadlineExceeded.label(), "deadline_exceeded");
        assert_eq!(FinishReason::Rejected.label(), "rejected");
        assert_eq!(FinishReason::Failed { retries: 1 }.label(), "failed");
    }

    #[test]
    fn validation_names_the_offending_field() {
        let cases: &[(&str, &str)] = &[
            (r#"{"prompt":[1,2]}"#, "max_tokens"),
            (r#"{"max_tokens":4,"prompt":"hi"}"#, "prompt"),
            (r#"{"max_tokens":4,"prompt":[1,999]}"#, "prompt"),
            (r#"{"max_tokens":4,"prompt":[-3]}"#, "prompt"),
            (r#"{"max_tokens":4,"stream":"yes"}"#, "stream"),
            (r#"{"max_tokens":4,"top_p":0}"#, "top_p"),
            (r#"{"max_tokens":4,"top_p":1.5}"#, "top_p"),
            (r#"{"max_tokens":4,"temperature":-1}"#, "temperature"),
            (r#"{"max_tokens":4,"timeout_steps":0}"#, "timeout_steps"),
            (r#"{"max_tokens":4,"seed":1.5}"#, "seed"),
            (r#"{"max_tokens":9999}"#, "max_tokens"),
            (r#"{"max_tokens":4,"best_of":2}"#, "best_of"),
            (r#"{"max_tokens":4,"draft":7}"#, "draft"),
            (r#"not json"#, "body"),
            (r#"[1,2,3]"#, "body"),
        ];
        for (body, field) in cases {
            let err = CompletionRequest::parse(body.as_bytes(), 64, 128)
                .expect_err(&format!("`{body}` must fail"));
            assert_eq!(&err.field, field, "body `{body}`");
        }
    }

    #[test]
    fn valid_request_round_trips() {
        let body = r#"{"prompt":[3,1,4],"max_tokens":8,"temperature":0.7,"top_k":5,"top_p":0.9,"seed":42,"timeout_steps":100,"stream":true,"draft":"ara@0.35"}"#;
        let r = CompletionRequest::parse(body.as_bytes(), 64, 128).expect("valid");
        assert_eq!(r.prompt, vec![3, 1, 4]);
        assert_eq!(r.max_tokens, 8);
        assert_eq!(r.params.temperature, 0.7);
        assert_eq!(r.params.top_k, 5);
        assert_eq!(r.params.top_p, 0.9);
        assert_eq!(r.params.seed, 42);
        assert_eq!(r.timeout_steps, Some(100));
        assert!(r.stream);
        assert_eq!(r.draft.as_deref(), Some("ara@0.35"));
        // defaults: greedy params, no deadline, non-streaming, no draft
        let r = CompletionRequest::parse(br#"{"max_tokens":0}"#, 64, 128).expect("valid");
        assert!(r.prompt.is_empty());
        assert_eq!(r.params, SamplingParams::greedy());
        assert_eq!(r.timeout_steps, None);
        assert!(!r.stream);
        assert_eq!(r.draft, None);
        // an empty draft string is a valid explicit opt-out
        let r = CompletionRequest::parse(br#"{"max_tokens":0,"draft":""}"#, 64, 128)
            .expect("valid");
        assert_eq!(r.draft.as_deref(), Some(""));
    }

    #[test]
    fn completion_body_parses_back() {
        let resp = ServeResponse {
            tokens: vec![7, 8, 9],
            finish_reason: FinishReason::Stop,
            retries: 0,
            error: None,
            decode_tok_per_s: 123.4,
        };
        let j = json::parse(&completion_body(&resp)).expect("valid json");
        assert_eq!(j.req("finish_reason").unwrap().as_str().unwrap(), "stop");
        assert_eq!(j.req("token_count").unwrap().as_usize().unwrap(), 3);
        let toks: Vec<i32> = j
            .req("tokens")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|t| t.as_usize().unwrap() as i32)
            .collect();
        assert_eq!(toks, vec![7, 8, 9]);
        // the throughput figure is engine-wide and run-varying — it must
        // NOT appear in the body (byte-identical responses across runs)
        assert!(j.get("decode_tok_per_s").is_none());
        let chunk = token_chunk(7);
        let j = json::parse(chunk.trim()).expect("chunk json");
        assert_eq!(j.req("token").unwrap().as_usize().unwrap(), 7);
    }

    #[test]
    fn error_body_names_field() {
        let j = json::parse(&error_body("invalid_request_error", Some("max_tokens"), "required"))
            .unwrap();
        let e = j.req("error").unwrap();
        assert_eq!(e.req("field").unwrap().as_str().unwrap(), "max_tokens");
        assert_eq!(e.req("type").unwrap().as_str().unwrap(), "invalid_request_error");
    }
}
