//! Continuous-batching scheduler over a **block-paged KV pool**: a request
//! queue of ragged prompts packed into the engine's fixed-batch paged
//! decode graph through per-request *slots* and per-request *block tables*
//! (see [`super::kvpool`] for the pool/prefix-sharing contract).
//!
//! Each of the engine's `batch` slots is either **active** (owns a live
//! request, a block table into the shared pool, and a seeded sampler) or
//! **parked** (decodes a dummy token whose pool write lands in the
//! reserved scratch block). One [`Scheduler::step`]:
//!
//! 1. **Admit** — pop queued requests into free slots, gated on pool
//!    capacity: a request is admitted only when its prompt's blocks are
//!    coverable (counting prefix-cache reuse); otherwise admission stops
//!    (strict FIFO). Prompts whose effective window is fully cached skip
//!    prefill outright (first token from the cached logits row); the rest
//!    run one batched prefill whose KV is spliced into fresh pool blocks
//!    — shared full blocks are *not* rewritten (their contents are
//!    bitwise identical by the masking contract). Fresh chains are
//!    registered in the prefix map for later reuse.
//! 2. **Decode** — grow each slot's block table on demand (evicting cached
//!    chains first, then **preempting the youngest active request** —
//!    released back to the queue front, restarted deterministically — on
//!    true pool exhaustion), then one [`Engine::decode_step_paged`] over
//!    the whole batch and one sampled token per active slot. Requests
//!    that finish report a [`FinishReason`]: `Stop` (reached `gen_len`)
//!    or `Length` (decode window / unrecoverable pool bound).
//!
//! Parity: a request's token stream is **bitwise identical** to a
//! standalone [`Engine::generate`] run of the same prompt over the
//! contiguous-cache graph — regardless of batch composition, admission
//! order, block size, prefix reuse, preemption, or `ARA_THREADS` (pinned
//! by `tests/scheduler.rs`, incl. the degenerate `block_len =
//! max_decode_seq` config that reproduces the pre-paged layout exactly).

use std::collections::VecDeque;
use std::time::Instant;

use super::engine::{Engine, FinishReason};
use super::kvpool::{KvPool, PrefixHit};
use super::sampler::{Sampler, SamplingParams};
use crate::Result;

/// One queued generation request.
#[derive(Debug, Clone)]
pub struct Request {
    pub prompt: Vec<i32>,
    pub gen_len: usize,
    pub params: SamplingParams,
}

/// A finished request.
#[derive(Debug, Clone)]
pub struct Completion {
    /// Submission id (monotonically increasing per scheduler).
    pub id: u64,
    /// The engine slot the request (last) ran in.
    pub slot: usize,
    pub prompt_len: usize,
    pub tokens: Vec<i32>,
    /// `Stop`: reached `gen_len`; `Length`: truncated by the decode
    /// window or unrecoverable pool exhaustion.
    pub finish_reason: FinishReason,
    /// Submit → prefill admission, seconds (queueing delay).
    pub queued_s: f64,
    /// Submit → completion, seconds.
    pub latency_s: f64,
}

/// Aggregate serve-loop counters.
#[derive(Debug, Clone, Default)]
pub struct SchedStats {
    pub steps: usize,
    pub prefills: usize,
    pub admitted: usize,
    pub completed: usize,
    pub tokens_generated: usize,
    /// First tokens sampled from prefill (or cached-prefix) logits (subset
    /// of `tokens_generated`; excludes `gen_len = 0` admissions).
    pub prefill_sampled: usize,
    pub prefill_s: f64,
    pub decode_s: f64,
    /// Prefix-cache probes at admission (mirrors the pool's counters).
    /// These three are **per admission event**: a preempted request that
    /// restarts probes (and may hit) the cache again and is counted again.
    pub prefix_lookups: usize,
    /// Admissions that reused at least one cached block chain.
    pub prefix_hits: usize,
    /// Admissions that skipped prefill entirely (full-prompt cache hit).
    pub prefill_skipped: usize,
    /// Requests preempted (requeued) on pool exhaustion.
    pub preemptions: usize,
    /// High-water fraction of the pool's allocatable blocks in use.
    pub pool_peak_util: f64,
}

impl SchedStats {
    /// Generated tokens per second of engine time (prefill + decode).
    pub fn tok_per_s(&self) -> f64 {
        self.tokens_generated as f64 / (self.prefill_s + self.decode_s).max(1e-9)
    }

    /// Decode-loop throughput: tokens produced by decode steps per second
    /// of decode time (the first token of each request comes from its
    /// prefill logits and is excluded) — comparable to
    /// [`super::GenStats::tok_per_s`].
    pub fn decode_tok_per_s(&self) -> f64 {
        self.tokens_generated.saturating_sub(self.prefill_sampled) as f64
            / self.decode_s.max(1e-9)
    }

    /// Prefix-cache hit rate over admission lookups, in [0, 1].
    pub fn prefix_hit_rate(&self) -> f64 {
        if self.prefix_lookups == 0 {
            0.0
        } else {
            self.prefix_hits as f64 / self.prefix_lookups as f64
        }
    }
}

struct Pending {
    id: u64,
    req: Request,
    submitted: Instant,
}

struct Active {
    id: u64,
    slot: usize,
    req: Request,
    /// First valid slot in **padded** coordinates (`prefill_len - n`);
    /// kept so the decode-window guard stays step-identical to the
    /// contiguous path. Virtual (pool) position = `fill - start`.
    start: i32,
    /// Next write position in padded coordinates.
    fill: i32,
    last: i32,
    /// Physical pool blocks backing virtual positions, grown on demand.
    table: Vec<usize>,
    tokens: Vec<i32>,
    sampler: Sampler,
    submitted: Instant,
    started: Instant,
}

/// One planned admission (capacity already secured).
struct Admit {
    pending: Pending,
    slot: usize,
    /// Effective (windowed) prompt tokens — what the KV layout sees.
    eff: Vec<i32>,
    table: Vec<usize>,
    /// Virtual positions `[0, covered)` already present in shared blocks.
    covered: usize,
    /// Cached prefill logits row (full-prompt hit ⇒ prefill skipped).
    cached_logits: Option<Vec<f32>>,
}

/// The continuous-batching serve loop over one engine and its KV pool.
pub struct Scheduler<'e> {
    engine: &'e Engine,
    pool: KvPool,
    queue: VecDeque<Pending>,
    slots: Vec<Option<Active>>,
    next_id: u64,
    stats: SchedStats,
}

impl<'e> Scheduler<'e> {
    /// Build over the engine's active paged-decode specialization
    /// (geometry from `ARA_KV_BLOCK` / `ARA_KV_BLOCKS`, or whatever
    /// [`Engine::enable_paged`] pinned last).
    pub fn new(engine: &'e Engine) -> Scheduler<'e> {
        let pool = KvPool::new(engine.config(), engine.paged_cfg());
        let mut slots = Vec::with_capacity(engine.batch);
        slots.resize_with(engine.batch, || None);
        Scheduler {
            engine,
            pool,
            queue: VecDeque::new(),
            slots,
            next_id: 0,
            stats: SchedStats::default(),
        }
    }

    /// Enqueue a request; returns its completion id.
    pub fn submit(&mut self, req: Request) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back(Pending { id, req, submitted: Instant::now() });
        id
    }

    /// No queued and no in-flight requests.
    pub fn is_idle(&self) -> bool {
        self.queue.is_empty() && self.slots.iter().all(Option::is_none)
    }

    /// Requests currently decoding.
    pub fn active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Requests waiting for a slot.
    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    pub fn stats(&self) -> &SchedStats {
        &self.stats
    }

    /// Pool accounting (block refcounts, utilization, cached chains).
    pub fn pool(&self) -> &KvPool {
        &self.pool
    }

    /// One serve-loop iteration: admit into free slots (capacity-gated),
    /// then decode one token for every active slot. Returns the requests
    /// that finished.
    ///
    /// On `Err` the in-flight pool state is lost: call
    /// [`Scheduler::abort_active`] before stepping again (queued requests
    /// survive; only the active slots are aborted).
    pub fn step(&mut self) -> Result<Vec<Completion>> {
        // fail fast before any prefill work is wasted: the paged scheduler
        // needs the paged decode graph (CPU backend; PJRT serves through
        // the contiguous `Engine::generate` path only)
        if !self.engine.has_paged() {
            return Err(crate::anyhow!(
                "scheduler requires a paged decode specialization (cpu backend)"
            ));
        }
        let mut done = Vec::new();
        self.admit(&mut done)?;
        self.decode(&mut done)?;
        self.stats.steps += 1;
        self.sync_pool_stats();
        Ok(done)
    }

    /// Drive [`Scheduler::step`] until every submitted request completed.
    pub fn run_to_completion(&mut self) -> Result<Vec<Completion>> {
        let mut out = Vec::new();
        while !self.is_idle() {
            out.extend(self.step()?);
        }
        Ok(out)
    }

    fn sync_pool_stats(&mut self) {
        self.stats.prefix_lookups = self.pool.stats.prefix_lookups;
        self.stats.prefix_hits = self.pool.stats.prefix_hits;
        self.stats.pool_peak_util = self.pool.peak_utilization();
    }

    /// The prompt window the KV layout actually sees: the most recent
    /// `real_len` tokens (a lone BOS for empty prompts) — the unit prefix
    /// hashing and block accounting run over.
    fn effective_prompt(&self, prompt: &[i32]) -> Vec<i32> {
        let n = self.engine.real_len(prompt);
        if prompt.is_empty() {
            vec![crate::data::BOS_TOKEN]
        } else {
            prompt[prompt.len() - n..].to_vec()
        }
    }

    fn admit(&mut self, done: &mut Vec<Completion>) -> Result<()> {
        if self.queue.is_empty() {
            return Ok(());
        }
        let bl = self.pool.cfg.block_len;
        let mut admits: Vec<Admit> = Vec::new();
        for slot in 0..self.slots.len() {
            if self.slots[slot].is_some() {
                continue;
            }
            let Some(pending) = self.queue.pop_front() else { break };
            let eff = self.effective_prompt(&pending.req.prompt);
            let n = eff.len();
            let total_blocks = n.div_ceil(bl);
            // prefix reuse (retains returned blocks for this request)
            let (mut table, mut covered, cached_logits) = match self.pool.lookup(&eff) {
                Some(PrefixHit::Full { blocks, logits }) => (blocks, n, Some(logits)),
                Some(PrefixHit::Partial { blocks, covered }) => (blocks, covered, None),
                None => (Vec::new(), 0, None),
            };
            // a fully-cached prompt whose tail block is partial will be
            // appended into — copy-on-write it now (shared blocks are
            // never written)
            let mut ok = true;
            if cached_logits.is_some() && n % bl != 0 {
                let tail = *table.last().expect("full hit implies blocks");
                match self.pool.cow_block(tail) {
                    Ok(Some(fresh)) => {
                        self.pool.release(tail);
                        *table.last_mut().unwrap() = fresh;
                    }
                    Ok(None) => ok = false,
                    Err(e) => {
                        // pool unusable (buffers lost mid-step): roll back
                        // so the request survives in the queue
                        for b in table {
                            self.pool.release(b);
                        }
                        self.queue.push_front(pending);
                        return Err(e);
                    }
                }
            }
            // fresh blocks for the uncovered positions [covered, n)
            while ok && table.len() < total_blocks {
                match self.pool.alloc() {
                    Some(b) => table.push(b),
                    None => ok = false,
                }
            }
            if !ok {
                // pool can't cover this prompt right now: roll back and
                // stop admitting (strict FIFO — no head-of-line skips)
                for b in table {
                    self.pool.release(b);
                }
                self.queue.push_front(pending);
                break;
            }
            if cached_logits.is_some() {
                covered = n; // COW restored full coverage
                self.stats.prefill_skipped += 1;
            }
            admits.push(Admit { pending, slot, eff, table, covered, cached_logits });
        }
        if admits.is_empty() {
            return Ok(());
        }

        // one batched prefill over the admissions that missed the cache
        let t0 = Instant::now();
        let misses: Vec<(usize, &[i32])> = admits
            .iter()
            .filter(|a| a.cached_logits.is_none())
            .map(|a| (a.slot, a.pending.req.prompt.as_slice()))
            .collect();
        let mut fresh_rows: VecDeque<Vec<f32>> = VecDeque::new();
        let mut fresh_caches = Vec::new();
        if !misses.is_empty() {
            match self.engine.prefill_into_slots(&misses, None) {
                Ok((rows, caches)) => {
                    fresh_rows = rows.into();
                    fresh_caches = caches;
                    self.stats.prefills += 1;
                }
                Err(e) => {
                    // transient engine error: roll the pool back and put
                    // every popped request back at the queue front in
                    // original order — nothing was lost
                    for a in admits.into_iter().rev() {
                        for b in a.table {
                            self.pool.release(b);
                        }
                        self.queue.push_front(a.pending);
                    }
                    return Err(e);
                }
            }
        }
        self.stats.prefill_s += t0.elapsed().as_secs_f64();

        let p = self.engine.config().prefill_len;
        let mut admits: VecDeque<Admit> = admits.into();
        while let Some(a) = admits.pop_front() {
            let Admit { pending, slot, eff, table, covered, cached_logits } = a;
            let n = eff.len();
            let row = match cached_logits {
                Some(row) => row,
                None => {
                    // splice this slot's fresh KV rows into its blocks
                    // (shared blocks keep their bitwise-identical contents)
                    let row = fresh_rows.pop_front().expect("one logits row per miss");
                    if let Err(e) =
                        self.pool.write_prefill(&fresh_caches, slot, p - n, n, covered, &table)
                    {
                        // roll back this and every not-yet-placed admission
                        // so queued requests survive (already-placed slots
                        // keep running; the abort contract covers them)
                        while let Some(rest) = admits.pop_back() {
                            for b in rest.table {
                                self.pool.release(b);
                            }
                            self.queue.push_front(rest.pending);
                        }
                        for b in table {
                            self.pool.release(b);
                        }
                        self.queue.push_front(pending);
                        return Err(e);
                    }
                    self.pool.register(&eff, &table, &row);
                    row
                }
            };
            let mut act = Active {
                id: pending.id,
                slot,
                start: (p - n) as i32,
                fill: p as i32,
                last: crate::data::BOS_TOKEN,
                table,
                tokens: Vec::with_capacity(pending.req.gen_len),
                sampler: Sampler::new(pending.req.params.clone()),
                submitted: pending.submitted,
                started: t0,
                req: pending.req,
            };
            self.stats.admitted += 1;
            if act.req.gen_len == 0 {
                done.push(self.complete(act, FinishReason::Stop));
                continue;
            }
            let tok = act.sampler.sample(&row);
            act.last = tok;
            act.tokens.push(tok);
            self.stats.tokens_generated += 1;
            self.stats.prefill_sampled += 1;
            match self.finish_reason(&act) {
                Some(reason) => done.push(self.complete(act, reason)),
                None => self.slots[slot] = Some(act),
            }
        }
        Ok(())
    }

    /// Make sure `slot`'s next write position has a backing block,
    /// evicting cached chains first (inside [`KvPool::alloc`]) and
    /// preempting the youngest active request on true exhaustion. May
    /// complete (`Length`) or preempt the slot itself.
    fn ensure_block(&mut self, slot: usize, done: &mut Vec<Completion>) {
        loop {
            let Some(a) = self.slots[slot].as_ref() else { return };
            let vpos = (a.fill - a.start) as usize;
            if vpos / self.pool.cfg.block_len < a.table.len() {
                return; // capacity already present
            }
            if let Some(b) = self.pool.alloc() {
                self.slots[slot].as_mut().unwrap().table.push(b);
                return;
            }
            let youngest = (0..self.slots.len())
                .filter(|&s| self.slots[s].is_some())
                .max_by_key(|&s| self.slots[s].as_ref().unwrap().id)
                .expect("slot itself is active");
            if youngest == slot && self.active() == 1 {
                // nothing left to preempt: truncate this request
                let act = self.slots[slot].take().unwrap();
                done.push(self.complete(act, FinishReason::Length));
                return;
            }
            let victim = self.slots[youngest].take().unwrap();
            self.requeue(victim);
            if youngest == slot {
                return; // preempted ourselves; slot is parked this step
            }
        }
    }

    /// Preemption: drop the request's pool state and put it back at the
    /// queue front — it restarts from prefill with its original sampler
    /// seed, so its final token stream is unchanged (determinism).
    fn requeue(&mut self, a: Active) {
        for b in &a.table {
            self.pool.release(*b);
        }
        self.stats.preemptions += 1;
        // un-count its sampled tokens: they will be re-generated
        self.stats.tokens_generated -= a.tokens.len();
        self.stats.prefill_sampled -= 1;
        self.stats.admitted -= 1;
        self.queue.push_front(Pending { id: a.id, req: a.req, submitted: a.submitted });
    }

    fn decode(&mut self, done: &mut Vec<Completion>) -> Result<()> {
        for slot in 0..self.slots.len() {
            self.ensure_block(slot, done);
        }
        if self.slots.iter().all(Option::is_none) {
            return Ok(());
        }
        let b = self.engine.batch;
        let bl = self.pool.cfg.block_len;
        let bps = self.pool.cfg.blocks_per_seq(self.engine.config());
        // parked slots decode a dummy BOS into the scratch block (block 0,
        // row 0) over an all-scratch table — their output is discarded
        let mut toks = vec![crate::data::BOS_TOKEN; b];
        let mut vlens = vec![0i32; b];
        let mut rows = vec![0i32; b];
        let mut btable = vec![0i32; b * bps];
        for a in self.slots.iter().flatten() {
            let vpos = (a.fill - a.start) as usize;
            toks[a.slot] = a.last;
            vlens[a.slot] = vpos as i32;
            rows[a.slot] = (a.table[vpos / bl] * bl + vpos % bl) as i32;
            for (j, &blk) in a.table.iter().enumerate() {
                btable[a.slot * bps + j] = blk as i32;
            }
        }
        let t0 = Instant::now();
        let bufs = self.pool.take_bufs()?;
        let (logits, new_bufs) =
            self.engine.decode_step_paged(bufs, &toks, &vlens, &rows, &btable)?;
        self.pool.restore_bufs(new_bufs);
        self.stats.decode_s += t0.elapsed().as_secs_f64();
        let vocab = self.engine.config().vocab;
        for slot in 0..b {
            let Some(mut a) = self.slots[slot].take() else { continue };
            a.fill += 1;
            let row = &logits.data[slot * vocab..(slot + 1) * vocab];
            let tok = a.sampler.sample(row);
            a.last = tok;
            a.tokens.push(tok);
            self.stats.tokens_generated += 1;
            match self.finish_reason(&a) {
                Some(reason) => done.push(self.complete(a, reason)),
                None => self.slots[slot] = Some(a),
            }
        }
        Ok(())
    }

    /// Engine-error recovery: abort every in-flight request (their pool
    /// state is gone) but **keep the queue** — queued requests never
    /// touched the engine and can still be served. Returns the aborted
    /// request ids so a front-end can fail just those callers.
    pub fn abort_active(&mut self) -> Vec<u64> {
        let mut ids = Vec::new();
        for s in self.slots.iter_mut() {
            if let Some(a) = s.take() {
                ids.push(a.id);
            }
        }
        self.pool.reset();
        ids
    }

    /// Done when the request reached `gen_len` tokens (`Stop`) or its next
    /// decode would overrun the decode window (`Length`) — the same guard,
    /// in the same padded coordinates, as [`Engine::generate`], so
    /// early-stopped outputs stay parity-comparable.
    fn finish_reason(&self, a: &Active) -> Option<FinishReason> {
        if a.tokens.len() >= a.req.gen_len {
            Some(FinishReason::Stop)
        } else if (a.fill + 1) as usize >= self.engine.config().max_decode_seq {
            Some(FinishReason::Length)
        } else {
            None
        }
    }

    fn complete(&mut self, a: Active, finish_reason: FinishReason) -> Completion {
        for b in &a.table {
            self.pool.release(*b);
        }
        self.stats.completed += 1;
        Completion {
            id: a.id,
            slot: a.slot,
            prompt_len: a.req.prompt.len(),
            tokens: a.tokens,
            finish_reason,
            queued_s: (a.started - a.submitted).as_secs_f64(),
            latency_s: a.submitted.elapsed().as_secs_f64(),
        }
    }
}
