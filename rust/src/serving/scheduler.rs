//! Continuous-batching scheduler over a **block-paged KV pool**: a request
//! queue of ragged prompts packed into the engine's fixed-batch paged
//! decode graph through per-request *slots* and per-request *block tables*
//! (see [`super::kvpool`] for the pool/prefix-sharing contract).
//!
//! Each of the engine's `batch` slots is either **active** (owns a live
//! request, a block table into the shared pool, and a seeded sampler) or
//! **parked** (decodes a dummy token whose pool write lands in the
//! reserved scratch block). One [`Scheduler::step`]:
//!
//! 1. **Admit** — pop queued requests into free slots, gated on pool
//!    capacity: a request is admitted only when its prompt's blocks are
//!    coverable (counting prefix-cache reuse); otherwise admission stops
//!    (strict FIFO). Prompts whose effective window is fully cached skip
//!    prefill outright (first token from the cached logits row); the rest
//!    run one batched prefill whose KV is spliced into fresh pool blocks
//!    — shared full blocks are *not* rewritten (their contents are
//!    bitwise identical by the masking contract). Fresh chains are
//!    registered in the prefix map for later reuse.
//! 2. **Decode** — grow each slot's block table on demand (evicting cached
//!    chains first, then **preempting the youngest active request** —
//!    released back to the queue front, restarted deterministically — on
//!    true pool exhaustion), then one [`Engine::decode_step_paged`] over
//!    the whole batch and one sampled token per active slot. Requests
//!    that finish report a [`FinishReason`]: `Stop` (reached `gen_len`)
//!    or `Length` (decode window / unrecoverable pool bound).
//!
//! Parity: a request's token stream is **bitwise identical** to a
//! standalone [`Engine::generate`] run of the same prompt over the
//! contiguous-cache graph — regardless of batch composition, admission
//! order, block size, prefix reuse, preemption, or `ARA_THREADS` (pinned
//! by `tests/scheduler.rs`, incl. the degenerate `block_len =
//! max_decode_seq` config that reproduces the pre-paged layout exactly).
//!
//! **Resilience** (DESIGN.md §5): between decode steps the scheduler
//! checks per-request deadlines and [`CancelToken`]s (freeing the slot and
//! its blocks mid-flight), and contains faults instead of spreading them —
//! a failed prefill rolls back only the admissions that needed it, a
//! failed decode re-queues only the in-flight requests (queue front,
//! ascending id). Faulted requests retry up to [`SchedCfg::retry_limit`]
//! times and are then quarantined with `Failed { retries }`. Because a
//! retry restarts through prefill (or the prefix cache) with its original
//! sampler seed, every non-failed completion is bitwise identical to a
//! fault-free run — pinned by `tests/chaos.rs`. A seeded
//! [`FaultPlan`](super::FaultPlan) (`ARA_FAULT_PLAN`) injects
//! decode/prefill faults, pool-pressure spikes, and latency stalls
//! deterministically.
//!
//! **Self-speculative decoding** (DESIGN.md §8): when a [`SpecDec`] is
//! installed ([`Scheduler::set_spec_dec`]) and a request opts in
//! ([`Request::draft_spec`] naming its spec, greedy sampling), each decode
//! iteration drafts `k` tokens with the compressed draft engine and
//! verifies the whole window in **one** batched `decode_verify` pass,
//! emitting the longest accepted prefix plus the target's corrected/bonus
//! token — up to `k + 1` tokens per pass. Plain and speculative requests
//! coexist in one batch (plain slots ride the pass at window position 0),
//! and any draft-side failure falls back to the plain one-token step.
//! Accepted streams stay bitwise identical to plain greedy decode.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use super::engine::{Engine, FinishReason};
use super::faults::{FaultKind, FaultPlan};
use super::kvpool::{KvPool, PrefixHit};
use super::sampler::{Sampler, SamplingParams};
use super::specdec::SpecDec;
use crate::Result;

/// [`Completion::slot`] value for requests that finished without ever
/// being admitted (cancelled / deadline-expired / quarantined while
/// queued).
pub const NO_SLOT: usize = usize::MAX;

/// Cooperative cancellation handle: clone it into a [`Request`], call
/// [`CancelToken::cancel`] from any thread; the scheduler completes the
/// request with `FinishReason::Cancelled` (partial tokens included) at the
/// next step boundary and frees its slot and KV blocks.
#[derive(Debug, Clone, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    pub fn new() -> CancelToken {
        CancelToken::default()
    }

    pub fn cancel(&self) {
        self.0.store(true, Ordering::Release);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::Acquire)
    }
}

/// One queued generation request.
#[derive(Debug, Clone, Default)]
pub struct Request {
    pub prompt: Vec<i32>,
    pub gen_len: usize,
    pub params: SamplingParams,
    /// Step-budget deadline: the request must finish within this many
    /// scheduler steps of submission or it completes `DeadlineExceeded`
    /// (checked between steps, whether queued or mid-decode). `None` means
    /// no deadline.
    pub deadline_steps: Option<usize>,
    /// Cooperative cancellation (`None` means not cancellable).
    pub cancel: Option<CancelToken>,
    /// Per-token streaming sink: each sampled token is sent here the step
    /// it is produced (the HTTP layer's chunk-per-`decode_step` feed). A
    /// high-water mark rides retries/preemptions, so a restarted request
    /// — which regenerates a bitwise-identical stream — never re-sends a
    /// token already delivered. A gone receiver is ignored (disconnects
    /// are signalled through [`CancelToken`], not the sink).
    pub stream: Option<std::sync::mpsc::Sender<i32>>,
    /// Self-speculative decoding opt-in (DESIGN.md §8): the registry spec
    /// of the draft plan to propose tokens with (e.g. `ara@0.35`). Honored
    /// only when it names the spec of the scheduler's installed
    /// [`SpecDec`] **and** sampling is greedy (the bitwise-parity contract
    /// covers greedy argmax only); otherwise the request decodes plain.
    pub draft_spec: Option<String>,
}

/// Scheduler resilience knobs.
#[derive(Debug, Clone, Copy)]
pub struct SchedCfg {
    /// Fault hits a request survives before quarantine (`ARA_RETRY_LIMIT`,
    /// default 3): on the (limit+1)-th hit it completes
    /// `Failed { retries: limit }`.
    pub retry_limit: u32,
}

impl Default for SchedCfg {
    fn default() -> SchedCfg {
        SchedCfg { retry_limit: 3 }
    }
}

impl SchedCfg {
    pub fn from_env() -> SchedCfg {
        let retry_limit = std::env::var("ARA_RETRY_LIMIT")
            .ok()
            .and_then(|v| v.trim().parse::<u32>().ok())
            .unwrap_or(3);
        SchedCfg { retry_limit }
    }
}

/// A finished request.
#[derive(Debug, Clone)]
pub struct Completion {
    /// Submission id (monotonically increasing per scheduler).
    pub id: u64,
    /// The engine slot the request (last) ran in — [`NO_SLOT`] when it
    /// finished without ever being admitted.
    pub slot: usize,
    pub prompt_len: usize,
    pub tokens: Vec<i32>,
    /// How the request ended (see [`FinishReason`]); non-natural reasons
    /// carry whatever tokens were generated before the cut.
    pub finish_reason: FinishReason,
    /// Times this request was re-queued by a fault before finishing.
    pub retries: u32,
    /// Submit → prefill admission, seconds (queueing delay).
    pub queued_s: f64,
    /// Submit → completion, seconds.
    pub latency_s: f64,
}

/// Aggregate serve-loop counters.
#[derive(Debug, Clone, Default)]
pub struct SchedStats {
    pub steps: usize,
    pub prefills: usize,
    pub admitted: usize,
    pub completed: usize,
    pub tokens_generated: usize,
    /// First tokens sampled from prefill (or cached-prefix) logits (subset
    /// of `tokens_generated`; excludes `gen_len = 0` admissions).
    pub prefill_sampled: usize,
    pub prefill_s: f64,
    pub decode_s: f64,
    /// Prefix-cache probes at admission (mirrors the pool's counters).
    /// These three are **per admission event**: a preempted request that
    /// restarts probes (and may hit) the cache again and is counted again.
    pub prefix_lookups: usize,
    /// Admissions that reused at least one cached block chain.
    pub prefix_hits: usize,
    /// Admissions that skipped prefill entirely (full-prompt cache hit).
    pub prefill_skipped: usize,
    /// Requests preempted (requeued) on pool exhaustion.
    pub preemptions: usize,
    /// High-water fraction of the pool's allocatable blocks in use.
    pub pool_peak_util: f64,
    /// Decode-step faults absorbed (injected or real engine errors).
    pub decode_faults: usize,
    /// Prefill faults absorbed (admission rolled back, actives untouched).
    pub prefill_faults: usize,
    /// Fault-triggered re-queues (excludes capacity preemptions).
    pub retries: usize,
    /// Requests quarantined (`Failed`) after exhausting the retry budget.
    pub quarantined: usize,
    /// Requests completed `Cancelled`.
    pub cancelled: usize,
    /// Requests completed `DeadlineExceeded`.
    pub deadline_expired: usize,
    /// Pool rebuilds after an engine error consumed the in-flight buffers
    /// (each also drops the prefix cache).
    pub pool_resets: usize,
    /// Tokens actually delivered through per-token streaming sinks. Rides
    /// the per-request `streamed` high-water mark: a retried request
    /// regenerates its prefix but never re-sends a delivered position.
    pub streamed: usize,
    /// Batched `decode_verify` passes run (self-speculative decoding,
    /// DESIGN.md §8).
    pub verify_passes: usize,
    /// Tokens proposed by the draft engine across verify passes.
    pub draft_tokens: usize,
    /// Draft tokens accepted by target verification (≤ `draft_tokens`;
    /// each verify pass also emits one corrected/bonus token on top).
    pub draft_accepted: usize,
    /// Most recent fault message, for diagnostics on `Failed` responses.
    pub last_fault: Option<String>,
}

impl SchedStats {
    /// Generated tokens per second of engine time (prefill + decode).
    pub fn tok_per_s(&self) -> f64 {
        self.tokens_generated as f64 / (self.prefill_s + self.decode_s).max(1e-9)
    }

    /// Decode-loop throughput: tokens produced by decode steps per second
    /// of decode time (the first token of each request comes from its
    /// prefill logits and is excluded) — comparable to
    /// [`super::GenStats::tok_per_s`].
    pub fn decode_tok_per_s(&self) -> f64 {
        self.tokens_generated.saturating_sub(self.prefill_sampled) as f64
            / self.decode_s.max(1e-9)
    }

    /// Prefix-cache hit rate over admission lookups, in [0, 1].
    pub fn prefix_hit_rate(&self) -> f64 {
        if self.prefix_lookups == 0 {
            0.0
        } else {
            self.prefix_hits as f64 / self.prefix_lookups as f64
        }
    }

    /// Mean accepted draft tokens per verify pass, in [0, k] — the
    /// speculative win (comparable to [`super::GenStats::accepted_per_verify`]).
    pub fn accepted_per_verify(&self) -> f64 {
        self.draft_accepted as f64 / (self.verify_passes as f64).max(1.0)
    }

    /// Fraction of proposed draft tokens the target accepted, in [0, 1].
    pub fn draft_accept_rate(&self) -> f64 {
        if self.draft_tokens == 0 {
            0.0
        } else {
            self.draft_accepted as f64 / self.draft_tokens as f64
        }
    }
}

struct Pending {
    id: u64,
    req: Request,
    submitted: Instant,
    /// `stats.steps` at submission — the deadline clock's zero point.
    submit_step: usize,
    /// Fault hits so far (capacity preemptions don't count).
    retries: u32,
    /// Tokens already delivered to `req.stream` (high-water mark across
    /// retries: a restarted request skips re-sending this prefix).
    streamed: usize,
}

struct Active {
    id: u64,
    slot: usize,
    req: Request,
    /// First valid slot in **padded** coordinates (`prefill_len - n`);
    /// kept so the decode-window guard stays step-identical to the
    /// contiguous path. Virtual (pool) position = `fill - start`.
    start: i32,
    /// Next write position in padded coordinates.
    fill: i32,
    last: i32,
    /// Physical pool blocks backing virtual positions, grown on demand.
    table: Vec<usize>,
    tokens: Vec<i32>,
    sampler: Sampler,
    submitted: Instant,
    started: Instant,
    submit_step: usize,
    retries: u32,
    /// Tokens already delivered to `req.stream` (see [`Pending::streamed`]).
    streamed: usize,
}

/// One planned admission (capacity already secured).
struct Admit {
    pending: Pending,
    slot: usize,
    /// Effective (windowed) prompt tokens — what the KV layout sees.
    eff: Vec<i32>,
    table: Vec<usize>,
    /// Virtual positions `[0, covered)` already present in shared blocks.
    covered: usize,
    /// Cached prefill logits row (full-prompt hit ⇒ prefill skipped).
    cached_logits: Option<Vec<f32>>,
}

/// The continuous-batching serve loop over one engine and its KV pool.
pub struct Scheduler<'e> {
    engine: &'e Engine,
    pool: KvPool,
    queue: VecDeque<Pending>,
    slots: Vec<Option<Active>>,
    next_id: u64,
    stats: SchedStats,
    cfg: SchedCfg,
    /// Injected chaos schedule (`ARA_FAULT_PLAN` / [`Scheduler::set_fault_plan`]).
    plan: Option<FaultPlan>,
    /// Pool blocks held by active `spike` fault events: (release step, blocks).
    spike_holds: Vec<(usize, Vec<usize>)>,
    /// The self-speculative draft side, when installed
    /// ([`Scheduler::set_spec_dec`]).
    spec: Option<SpecDec>,
}

impl<'e> Scheduler<'e> {
    /// Build over the engine's active paged-decode specialization
    /// (geometry from `ARA_KV_BLOCK` / `ARA_KV_BLOCKS`, or whatever
    /// [`Engine::enable_paged`] pinned last). Resilience knobs come from
    /// the environment (`ARA_RETRY_LIMIT`, `ARA_FAULT_PLAN`); a malformed
    /// fault plan panics — chaos instrumentation must never half-apply.
    pub fn new(engine: &'e Engine) -> Scheduler<'e> {
        let plan = FaultPlan::from_env().expect("ARA_FAULT_PLAN must parse");
        let mut s = Scheduler::new_with(engine, SchedCfg::from_env());
        s.plan = plan;
        s
    }

    /// Build with explicit resilience knobs and no fault plan (benches and
    /// tests install plans via [`Scheduler::set_fault_plan`]).
    pub fn new_with(engine: &'e Engine, cfg: SchedCfg) -> Scheduler<'e> {
        let pool = KvPool::new(engine.config(), engine.paged_cfg());
        let mut slots = Vec::with_capacity(engine.batch);
        slots.resize_with(engine.batch, || None);
        Scheduler {
            engine,
            pool,
            queue: VecDeque::new(),
            slots,
            next_id: 0,
            stats: SchedStats::default(),
            cfg,
            plan: None,
            spike_holds: Vec::new(),
            spec: None,
        }
    }

    /// Install (or clear) the self-speculative decoder (DESIGN.md §8).
    /// Requires the target engine's verify specialization
    /// ([`Engine::enable_verify`]) with window = draft `k` + 1, and a
    /// draft engine of the same batch size.
    pub fn set_spec_dec(&mut self, spec: Option<SpecDec>) -> Result<()> {
        if let Some(sd) = &spec {
            if !self.engine.has_verify() {
                return Err(crate::anyhow!(
                    "speculative decoding needs Engine::enable_verify on the target"
                ));
            }
            if self.engine.verify_window() != sd.k() + 1 {
                return Err(crate::anyhow!(
                    "verify window {} != draft k {} + 1",
                    self.engine.verify_window(),
                    sd.k()
                ));
            }
            if sd.batch() != self.engine.batch {
                return Err(crate::anyhow!(
                    "draft batch {} != target batch {}",
                    sd.batch(),
                    self.engine.batch
                ));
            }
        }
        if let Some(old) = &mut self.spec {
            old.release_all();
        }
        self.spec = spec;
        Ok(())
    }

    /// The installed speculative decoder, if any.
    pub fn spec_dec(&self) -> Option<&SpecDec> {
        self.spec.as_ref()
    }

    /// Whether a request opts into the installed speculative decoder: it
    /// names the decoder's spec and samples greedily — the bitwise-parity
    /// contract covers greedy argmax only. Single-token requests draw
    /// their one token from prefill logits and never reach decode.
    fn spec_eligible(req: &Request, sd: &SpecDec) -> bool {
        req.params.temperature <= 0.0
            && req.gen_len > 1
            && req.draft_spec.as_deref() == Some(sd.spec())
    }

    /// Install (or clear) the chaos schedule; fires from the next step.
    pub fn set_fault_plan(&mut self, plan: Option<FaultPlan>) {
        self.plan = plan;
    }

    /// Enqueue a request; returns its completion id.
    pub fn submit(&mut self, req: Request) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back(Pending {
            id,
            req,
            submitted: Instant::now(),
            submit_step: self.stats.steps,
            retries: 0,
            streamed: 0,
        });
        id
    }

    /// No queued and no in-flight requests.
    pub fn is_idle(&self) -> bool {
        self.queue.is_empty() && self.slots.iter().all(Option::is_none)
    }

    /// Requests currently decoding.
    pub fn active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Requests waiting for a slot.
    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    pub fn stats(&self) -> &SchedStats {
        &self.stats
    }

    /// Pool accounting (block refcounts, utilization, cached chains).
    pub fn pool(&self) -> &KvPool {
        &self.pool
    }

    /// One serve-loop iteration: release expired chaos spike holds, sweep
    /// cancelled/deadline-expired requests (queued and active — slot and
    /// blocks freed mid-flight), admit into free slots (capacity-gated),
    /// then decode one token for every active slot. Returns the requests
    /// that finished.
    ///
    /// Transient faults (injected or real engine errors in prefill/decode)
    /// are absorbed here, not returned: affected requests are re-queued at
    /// the queue front with their retry count bumped, or quarantined with
    /// `Failed { retries }` past [`SchedCfg::retry_limit`]. `Err` means an
    /// unrecoverable scheduler-level problem (no paged graph, pool
    /// invariant breach) — call [`Scheduler::abort_all`] before stepping
    /// again.
    pub fn step(&mut self) -> Result<Vec<Completion>> {
        // fail fast before any prefill work is wasted: the paged scheduler
        // needs the paged decode graph (CPU backend; PJRT serves through
        // the contiguous `Engine::generate` path only)
        if !self.engine.has_paged() {
            return Err(crate::anyhow!(
                "scheduler requires a paged decode specialization (cpu backend)"
            ));
        }
        let mut done = Vec::new();
        let step_now = self.stats.steps;
        self.release_spikes(step_now);
        // consume this step's chaos events (deterministic: plan order)
        let mut fault_decode = false;
        let mut fault_prefill = false;
        let events =
            self.plan.as_mut().map(|p| p.events_at(step_now)).unwrap_or_default();
        for kind in events {
            match kind {
                FaultKind::Decode => fault_decode = true,
                FaultKind::Prefill => fault_prefill = true,
                FaultKind::Spike { blocks, hold } => {
                    // grab what the pool can spare; requests react through
                    // the normal capacity gates (admission stall, preempt)
                    let mut held = Vec::new();
                    for _ in 0..blocks {
                        match self.pool.alloc() {
                            Some(b) => held.push(b),
                            None => break,
                        }
                    }
                    if !held.is_empty() {
                        self.spike_holds.push((step_now + hold.max(1), held));
                    }
                }
                FaultKind::Stall { ms } => {
                    std::thread::sleep(std::time::Duration::from_millis(ms));
                }
            }
        }
        self.sweep_expired(&mut done);
        self.admit(&mut done, fault_prefill)?;
        if fault_decode {
            // plan-injected decode fault: fires *before* the pool buffers
            // are taken, so per-slot recovery keeps the prefix cache
            self.note_fault("injected decode fault (fault plan)");
            self.stats.decode_faults += 1;
            self.recover_actives(false, &mut done);
        } else {
            self.decode(&mut done)?;
        }
        self.stats.steps += 1;
        self.sync_pool_stats();
        Ok(done)
    }

    /// Drive [`Scheduler::step`] until every submitted request completed.
    pub fn run_to_completion(&mut self) -> Result<Vec<Completion>> {
        let mut out = Vec::new();
        while !self.is_idle() {
            out.extend(self.step()?);
        }
        Ok(out)
    }

    fn sync_pool_stats(&mut self) {
        self.stats.prefix_lookups = self.pool.stats.prefix_lookups;
        self.stats.prefix_hits = self.pool.stats.prefix_hits;
        self.stats.pool_peak_util = self.pool.peak_utilization();
    }

    /// The prompt window the KV layout actually sees: the most recent
    /// `real_len` tokens (a lone BOS for empty prompts) — the unit prefix
    /// hashing and block accounting run over.
    fn effective_prompt(&self, prompt: &[i32]) -> Vec<i32> {
        let n = self.engine.real_len(prompt);
        if prompt.is_empty() {
            vec![crate::data::BOS_TOKEN]
        } else {
            prompt[prompt.len() - n..].to_vec()
        }
    }

    /// Admit queued requests into free slots. `inject_fault` simulates a
    /// transient prefill failure (fault plan): the admissions that needed
    /// the prefill are rolled back and retried; cache-hit admissions and
    /// active slots are untouched — faults stay contained.
    fn admit(&mut self, done: &mut Vec<Completion>, inject_fault: bool) -> Result<()> {
        if self.queue.is_empty() {
            return Ok(());
        }
        let bl = self.pool.cfg.block_len;
        let mut admits: Vec<Admit> = Vec::new();
        for slot in 0..self.slots.len() {
            if self.slots[slot].is_some() {
                continue;
            }
            let Some(pending) = self.queue.pop_front() else { break };
            let eff = self.effective_prompt(&pending.req.prompt);
            let n = eff.len();
            let total_blocks = n.div_ceil(bl);
            // prefix reuse (retains returned blocks for this request)
            let (mut table, mut covered, cached_logits) = match self.pool.lookup(&eff) {
                Some(PrefixHit::Full { blocks, logits }) => (blocks, n, Some(logits)),
                Some(PrefixHit::Partial { blocks, covered }) => (blocks, covered, None),
                None => (Vec::new(), 0, None),
            };
            // a fully-cached prompt whose tail block is partial will be
            // appended into — copy-on-write it now (shared blocks are
            // never written)
            let mut ok = true;
            if cached_logits.is_some() && n % bl != 0 {
                let tail = *table.last().expect("full hit implies blocks");
                match self.pool.cow_block(tail) {
                    Ok(Some(fresh)) => {
                        self.pool.release(tail);
                        *table.last_mut().unwrap() = fresh;
                    }
                    Ok(None) => ok = false,
                    Err(e) => {
                        // pool unusable (buffers lost mid-step): roll back
                        // so the request survives in the queue
                        for b in table {
                            self.pool.release(b);
                        }
                        self.queue.push_front(pending);
                        return Err(e);
                    }
                }
            }
            // fresh blocks for the uncovered positions [covered, n)
            while ok && table.len() < total_blocks {
                match self.pool.alloc() {
                    Some(b) => table.push(b),
                    None => ok = false,
                }
            }
            if !ok {
                // pool can't cover this prompt right now: roll back and
                // stop admitting (strict FIFO — no head-of-line skips)
                for b in table {
                    self.pool.release(b);
                }
                self.queue.push_front(pending);
                break;
            }
            if cached_logits.is_some() {
                covered = n; // COW restored full coverage
                self.stats.prefill_skipped += 1;
            }
            admits.push(Admit { pending, slot, eff, table, covered, cached_logits });
        }
        if admits.is_empty() {
            return Ok(());
        }

        // one batched prefill over the admissions that missed the cache
        let t0 = Instant::now();
        let misses: Vec<(usize, &[i32])> = admits
            .iter()
            .filter(|a| a.cached_logits.is_none())
            .map(|a| (a.slot, a.pending.req.prompt.as_slice()))
            .collect();
        let mut fresh_rows: VecDeque<Vec<f32>> = VecDeque::new();
        let mut fresh_caches = Vec::new();
        if !misses.is_empty() {
            let res = if inject_fault {
                Err(crate::anyhow!("injected prefill fault (fault plan)"))
            } else {
                self.engine.prefill_into_slots(&misses, None)
            };
            match res {
                Ok((rows, caches)) => {
                    fresh_rows = rows.into();
                    fresh_caches = caches;
                    self.stats.prefills += 1;
                }
                Err(e) => {
                    // transient prefill fault: only the admissions that
                    // needed this prefill are casualties — roll back their
                    // blocks and retry/quarantine them. Cache-hit
                    // admissions never touched the engine and proceed;
                    // active slots keep decoding this very step.
                    self.note_fault(&e.to_string());
                    self.stats.prefill_faults += 1;
                    let (hits, misses_adm): (Vec<Admit>, Vec<Admit>) =
                        admits.into_iter().partition(|a| a.cached_logits.is_some());
                    // reverse order so repeated push_front restores the
                    // original relative queue order
                    for a in misses_adm.into_iter().rev() {
                        for b in a.table {
                            self.pool.release(b);
                        }
                        self.retry_or_quarantine(a.pending, Vec::new(), NO_SLOT, None, done);
                    }
                    admits = hits;
                }
            }
        }
        self.stats.prefill_s += t0.elapsed().as_secs_f64();

        let p = self.engine.config().prefill_len;
        let mut spec_admits: Vec<(usize, Vec<i32>)> = Vec::new();
        let mut admits: VecDeque<Admit> = admits.into();
        while let Some(a) = admits.pop_front() {
            let Admit { pending, slot, eff, table, covered, cached_logits } = a;
            let n = eff.len();
            let row = match cached_logits {
                Some(row) => row,
                None => {
                    // splice this slot's fresh KV rows into its blocks
                    // (shared blocks keep their bitwise-identical contents)
                    let row = fresh_rows.pop_front().expect("one logits row per miss");
                    if let Err(e) =
                        self.pool.write_prefill(&fresh_caches, slot, p - n, n, covered, &table)
                    {
                        // roll back this and every not-yet-placed admission
                        // so queued requests survive (already-placed slots
                        // keep running; the abort contract covers them)
                        while let Some(rest) = admits.pop_back() {
                            for b in rest.table {
                                self.pool.release(b);
                            }
                            self.queue.push_front(rest.pending);
                        }
                        for b in table {
                            self.pool.release(b);
                        }
                        self.queue.push_front(pending);
                        return Err(e);
                    }
                    self.pool.register(&eff, &table, &row);
                    row
                }
            };
            let mut act = Active {
                id: pending.id,
                slot,
                start: (p - n) as i32,
                fill: p as i32,
                last: crate::data::BOS_TOKEN,
                table,
                tokens: Vec::with_capacity(pending.req.gen_len),
                sampler: Sampler::new(pending.req.params.clone()),
                submitted: pending.submitted,
                started: t0,
                submit_step: pending.submit_step,
                retries: pending.retries,
                streamed: pending.streamed,
                req: pending.req,
            };
            self.stats.admitted += 1;
            if act.req.gen_len == 0 {
                done.push(self.complete(act, FinishReason::Stop));
                continue;
            }
            let tok = act.sampler.sample(&row);
            act.last = tok;
            act.tokens.push(tok);
            Self::emit_stream(&mut act, &mut self.stats);
            self.stats.tokens_generated += 1;
            self.stats.prefill_sampled += 1;
            match self.finish_reason(&act) {
                Some(reason) => done.push(self.complete(act, reason)),
                None => {
                    if self.spec.as_ref().is_some_and(|sd| Self::spec_eligible(&act.req, sd)) {
                        spec_admits.push((slot, eff));
                    }
                    self.slots[slot] = Some(act);
                }
            }
        }
        // draft-admit the speculative newcomers: one batched draft prefill
        // (through the draft pool's own prefix cache); failures silently
        // leave those requests on the plain path
        if !spec_admits.is_empty() {
            let t1 = Instant::now();
            if let Some(sd) = self.spec.as_mut() {
                let pairs: Vec<(usize, &[i32])> =
                    spec_admits.iter().map(|(s, e)| (*s, e.as_slice())).collect();
                sd.admit(&pairs);
            }
            self.stats.prefill_s += t1.elapsed().as_secs_f64();
        }
        Ok(())
    }

    /// Make sure `slot`'s next write position has a backing block,
    /// evicting cached chains first (inside [`KvPool::alloc`]) and
    /// preempting the youngest active request on true exhaustion. May
    /// complete (`Length`) or preempt the slot itself.
    fn ensure_block(&mut self, slot: usize, done: &mut Vec<Completion>) {
        loop {
            let Some(a) = self.slots[slot].as_ref() else { return };
            let vpos = (a.fill - a.start) as usize;
            if vpos / self.pool.cfg.block_len < a.table.len() {
                return; // capacity already present
            }
            if let Some(b) = self.pool.alloc() {
                self.slots[slot].as_mut().unwrap().table.push(b);
                return;
            }
            let youngest = (0..self.slots.len())
                .filter(|&s| self.slots[s].is_some())
                .max_by_key(|&s| self.slots[s].as_ref().unwrap().id)
                .expect("slot itself is active");
            if youngest == slot && self.active() == 1 {
                // nothing left to preempt: truncate this request
                let act = self.slots[slot].take().unwrap();
                done.push(self.complete(act, FinishReason::Length));
                return;
            }
            let victim = self.slots[youngest].take().unwrap();
            self.requeue(victim);
            if youngest == slot {
                return; // preempted ourselves; slot is parked this step
            }
        }
    }

    /// Preemption: drop the request's pool state and put it back at the
    /// queue front — it restarts from prefill with its original sampler
    /// seed, so its final token stream is unchanged (determinism).
    fn requeue(&mut self, a: Active) {
        if let Some(sd) = self.spec.as_mut() {
            sd.release(a.slot); // the restart re-admits through the draft cache
        }
        for b in &a.table {
            self.pool.release(*b);
        }
        self.stats.preemptions += 1;
        // un-count its sampled tokens: they will be re-generated
        self.stats.tokens_generated -= a.tokens.len();
        self.stats.prefill_sampled -= 1;
        self.stats.admitted -= 1;
        self.queue.push_front(Pending {
            id: a.id,
            req: a.req,
            submitted: a.submitted,
            submit_step: a.submit_step,
            retries: a.retries,
            streamed: a.streamed,
        });
    }

    /// Deliver newly sampled tokens to the request's streaming sink, if
    /// any. The `streamed` high-water mark makes this idempotent across
    /// retries: a restarted request regenerates a bitwise-identical
    /// prefix, so positions below the mark are skipped, never re-sent.
    fn emit_stream(a: &mut Active, stats: &mut SchedStats) {
        if let Some(sink) = &a.req.stream {
            while a.streamed < a.tokens.len() {
                let _ = sink.send(a.tokens[a.streamed]);
                a.streamed += 1;
                stats.streamed += 1;
            }
        }
    }

    fn decode(&mut self, done: &mut Vec<Completion>) -> Result<()> {
        for slot in 0..self.slots.len() {
            self.ensure_block(slot, done);
        }
        if self.slots.iter().all(Option::is_none) {
            return Ok(());
        }
        // speculative path: when any slot has a live draft, one verify
        // round replaces this step (slots without drafts ride along at
        // window position 0 — bitwise identical to their plain step)
        if self.spec.is_some() && self.engine.has_verify() && self.decode_spec(done)? {
            return Ok(());
        }
        let b = self.engine.batch;
        let bl = self.pool.cfg.block_len;
        let bps = self.pool.cfg.blocks_per_seq(self.engine.config());
        // parked slots decode a dummy BOS into the scratch block (block 0,
        // row 0) over an all-scratch table — their output is discarded
        let mut toks = vec![crate::data::BOS_TOKEN; b];
        let mut vlens = vec![0i32; b];
        let mut rows = vec![0i32; b];
        let mut btable = vec![0i32; b * bps];
        for a in self.slots.iter().flatten() {
            let vpos = (a.fill - a.start) as usize;
            toks[a.slot] = a.last;
            vlens[a.slot] = vpos as i32;
            rows[a.slot] = (a.table[vpos / bl] * bl + vpos % bl) as i32;
            for (j, &blk) in a.table.iter().enumerate() {
                btable[a.slot * bps + j] = blk as i32;
            }
        }
        let t0 = Instant::now();
        let bufs = self.pool.take_bufs()?;
        let (logits, new_bufs) =
            match self.engine.decode_step_paged(bufs, &toks, &vlens, &rows, &btable) {
                Ok(out) => out,
                Err(e) => {
                    // the failed step consumed the pool buffers: rebuild
                    // the pool (prefix cache included) and retry the
                    // in-flight requests through a fresh prefill — token
                    // streams stay bitwise identical (seeded samplers)
                    self.stats.decode_s += t0.elapsed().as_secs_f64();
                    self.note_fault(&e.to_string());
                    self.stats.decode_faults += 1;
                    self.recover_actives(true, done);
                    return Ok(());
                }
            };
        self.pool.restore_bufs(new_bufs);
        self.stats.decode_s += t0.elapsed().as_secs_f64();
        let vocab = self.engine.config().vocab;
        for slot in 0..b {
            let Some(mut a) = self.slots[slot].take() else { continue };
            a.fill += 1;
            let row = &logits.data[slot * vocab..(slot + 1) * vocab];
            let tok = a.sampler.sample(row);
            a.last = tok;
            a.tokens.push(tok);
            Self::emit_stream(&mut a, &mut self.stats);
            self.stats.tokens_generated += 1;
            match self.finish_reason(&a) {
                Some(reason) => done.push(self.complete(a, reason)),
                None => self.slots[slot] = Some(a),
            }
        }
        Ok(())
    }

    /// One speculative serve-loop iteration (DESIGN.md §8): draft `k`
    /// greedy tokens per opted-in slot, verify the whole `W = k + 1`
    /// window in one batched [`Engine::decode_step_verify`] pass, then
    /// emit the longest accepted draft prefix plus the target's
    /// corrected/bonus token — each emission walking the exact
    /// sample → stream → finish pipeline of the plain path, so streams
    /// and finish reasons stay bitwise identical. Slots without a live
    /// draft (plain requests, retired drafts, window-end) ride the same
    /// pass at window position 0. Returns `false` when no slot could
    /// propose — the caller then runs the plain one-token step.
    fn decode_spec(&mut self, done: &mut Vec<Completion>) -> Result<bool> {
        let w = self.engine.verify_window();
        let k = w - 1;
        let b = self.engine.batch;
        let bl = self.pool.cfg.block_len;
        let bps = self.pool.cfg.blocks_per_seq(self.engine.config());
        let s_virt = bps * bl;
        // which active slots can run a full window this round?
        let mut targets: Vec<(usize, i32, usize)> = Vec::new();
        let mut drops: Vec<usize> = Vec::new();
        for slot in 0..self.slots.len() {
            let Some((vpos, last)) = self.slots[slot]
                .as_ref()
                .map(|a| ((a.fill - a.start) as usize, a.last))
            else {
                continue;
            };
            if !self.spec.as_ref().is_some_and(|sd| sd.has(slot)) {
                continue;
            }
            if vpos + w > s_virt {
                // no room to write k+1 positions: this request finishes on
                // plain steps (the draft can't stay in sync through them)
                drops.push(slot);
                continue;
            }
            // target-side blocks for the whole window [vpos, vpos + k] —
            // no preemption here: on exhaustion the slot just rides plain
            let needed = (vpos + k) / bl + 1;
            let mut ok = true;
            loop {
                let a = self.slots[slot].as_mut().expect("checked active");
                if a.table.len() >= needed {
                    break;
                }
                match self.pool.alloc() {
                    Some(blk) => a.table.push(blk),
                    None => {
                        ok = false;
                        break;
                    }
                }
            }
            if !ok {
                drops.push(slot);
                continue;
            }
            targets.push((slot, last, vpos));
        }
        {
            let sd = self.spec.as_mut().expect("caller checked spec");
            for s in drops {
                sd.release(s);
            }
            if targets.is_empty() {
                return Ok(false);
            }
        }
        let t0 = Instant::now();
        let proposals = self.spec.as_mut().expect("caller checked spec").propose(&targets);
        if proposals.is_empty() {
            // draft engine faulted or every draft ran out of pool room —
            // this step falls back to the plain path
            self.stats.decode_s += t0.elapsed().as_secs_f64();
            return Ok(false);
        }
        let mut dmap: Vec<Option<&[i32]>> = vec![None; b];
        for (s, d) in &proposals {
            dmap[*s] = Some(d.as_slice());
        }
        // one verify pass over the whole batch: window position 0 is every
        // active slot's normal one-token step; positions >= 1 carry the
        // draft tokens (speculative slots) or park in the scratch row
        let mut toks = vec![crate::data::BOS_TOKEN; b * w];
        let mut vlens = vec![0i32; b];
        let mut rows = vec![0i32; b * w];
        let mut btable = vec![0i32; b * bps];
        for a in self.slots.iter().flatten() {
            let vpos = (a.fill - a.start) as usize;
            vlens[a.slot] = vpos as i32;
            toks[a.slot * w] = a.last;
            rows[a.slot * w] = (a.table[vpos / bl] * bl + vpos % bl) as i32;
            for (j, &blk) in a.table.iter().enumerate() {
                btable[a.slot * bps + j] = blk as i32;
            }
            if let Some(d) = dmap[a.slot] {
                for j in 1..w {
                    let vp = vpos + j;
                    toks[a.slot * w + j] = d[j - 1];
                    rows[a.slot * w + j] = (a.table[vp / bl] * bl + vp % bl) as i32;
                }
            }
        }
        let bufs = self.pool.take_bufs()?;
        let (logits, new_bufs) =
            match self.engine.decode_step_verify(bufs, &toks, &vlens, &rows, &btable) {
                Ok(out) => out,
                Err(e) => {
                    // same recovery as a plain decode fault: the pass
                    // consumed the pool buffers; every in-flight request
                    // retries to a bitwise-identical stream
                    self.stats.decode_s += t0.elapsed().as_secs_f64();
                    self.note_fault(&e.to_string());
                    self.stats.decode_faults += 1;
                    self.recover_actives(true, done);
                    return Ok(true);
                }
            };
        self.pool.restore_bufs(new_bufs);
        self.stats.verify_passes += 1;
        let vocab = self.engine.config().vocab;
        // draft frontier updates to apply after the walk:
        // (slot, new virtual fill, catch-up token on full acceptance)
        let mut commits: Vec<(usize, usize, Option<i32>)> = Vec::new();
        for slot in 0..b {
            let Some(mut a) = self.slots[slot].take() else { continue };
            let d = dmap[slot];
            let span = if d.is_some() { w } else { 1 };
            let mut finished = None;
            let mut accepted = 0usize;
            for j in 0..span {
                let off = (slot * w + j) * vocab;
                let row = &logits.data[off..off + vocab];
                let tok = a.sampler.sample(row);
                a.fill += 1;
                a.last = tok;
                a.tokens.push(tok);
                self.stats.tokens_generated += 1;
                // target argmax agrees with the draft: token accepted,
                // keep consuming the window. Disagreement means `tok` is
                // the correction (j < k) or the bonus token (j == k) —
                // either way the round ends with it emitted.
                let matched = d.is_some_and(|dd| j < k && tok == dd[j]);
                if matched {
                    accepted += 1;
                }
                if let Some(reason) = self.finish_reason(&a) {
                    finished = Some(reason);
                    break;
                }
                if !matched {
                    break;
                }
            }
            if let Some(dd) = d {
                self.stats.draft_tokens += dd.len();
                self.stats.draft_accepted += accepted;
                if finished.is_some() {
                    self.spec.as_mut().expect("caller checked spec").release(slot);
                } else {
                    // full acceptance leaves the last draft token's own
                    // K/V row unwritten on the draft side — feed it back
                    let catch_up = if accepted == k { Some(dd[k - 1]) } else { None };
                    commits.push((slot, (a.fill - a.start) as usize, catch_up));
                }
            }
            Self::emit_stream(&mut a, &mut self.stats);
            match finished {
                Some(reason) => done.push(self.complete(a, reason)),
                None => self.slots[slot] = Some(a),
            }
        }
        if !commits.is_empty() {
            self.spec.as_mut().expect("caller checked spec").commit(&commits);
        }
        self.stats.decode_s += t0.elapsed().as_secs_f64();
        Ok(true)
    }

    fn note_fault(&mut self, msg: &str) {
        self.stats.last_fault = Some(msg.to_string());
    }

    /// Release chaos spike holds whose step has come.
    fn release_spikes(&mut self, step: usize) {
        let mut i = 0;
        while i < self.spike_holds.len() {
            if self.spike_holds[i].0 <= step {
                let (_, held) = self.spike_holds.swap_remove(i);
                for b in held {
                    self.pool.release(b);
                }
            } else {
                i += 1;
            }
        }
    }

    /// Complete queued and active requests whose cancellation token fired
    /// or whose step-budget deadline expired — active slots free their
    /// blocks mid-flight; queued requests finish with [`NO_SLOT`]. A
    /// deadline of `k` grants `k` scheduler steps from submission.
    fn sweep_expired(&mut self, done: &mut Vec<Completion>) {
        let now_step = self.stats.steps;
        let verdict = |req: &Request, submit_step: usize| -> Option<FinishReason> {
            if req.cancel.as_ref().is_some_and(|c| c.is_cancelled()) {
                Some(FinishReason::Cancelled)
            } else if req.deadline_steps.is_some_and(|d| now_step - submit_step >= d) {
                Some(FinishReason::DeadlineExceeded)
            } else {
                None
            }
        };
        let mut i = 0;
        while i < self.queue.len() {
            match verdict(&self.queue[i].req, self.queue[i].submit_step) {
                None => i += 1,
                Some(reason) => {
                    let p = self.queue.remove(i).expect("index in bounds");
                    self.count_cut(reason);
                    self.stats.completed += 1;
                    let waited = p.submitted.elapsed().as_secs_f64();
                    done.push(Completion {
                        id: p.id,
                        slot: NO_SLOT,
                        prompt_len: p.req.prompt.len(),
                        tokens: Vec::new(),
                        finish_reason: reason,
                        retries: p.retries,
                        queued_s: waited,
                        latency_s: waited,
                    });
                }
            }
        }
        for slot in 0..self.slots.len() {
            let Some(a) = self.slots[slot].as_ref() else { continue };
            if let Some(reason) = verdict(&a.req, a.submit_step) {
                let a = self.slots[slot].take().expect("checked above");
                self.count_cut(reason);
                done.push(self.complete(a, reason));
            }
        }
    }

    fn count_cut(&mut self, reason: FinishReason) {
        match reason {
            FinishReason::Cancelled => self.stats.cancelled += 1,
            FinishReason::DeadlineExceeded => self.stats.deadline_expired += 1,
            _ => {}
        }
    }

    /// Retry bookkeeping after a fault hit this request: re-queue at the
    /// queue front while the retry budget allows, else quarantine with
    /// `Failed { retries }` (partial tokens included). `started` is `Some`
    /// for requests that were active (their per-admission stats must be
    /// un-counted on re-queue — the retry regenerates them).
    fn retry_or_quarantine(
        &mut self,
        p: Pending,
        tokens: Vec<i32>,
        slot: usize,
        started: Option<Instant>,
        done: &mut Vec<Completion>,
    ) {
        if p.retries < self.cfg.retry_limit {
            if started.is_some() {
                self.stats.tokens_generated -= tokens.len();
                self.stats.prefill_sampled -= 1;
                self.stats.admitted -= 1;
            }
            self.stats.retries += 1;
            self.queue.push_front(Pending { retries: p.retries + 1, ..p });
        } else {
            self.stats.quarantined += 1;
            self.stats.completed += 1;
            done.push(Completion {
                id: p.id,
                slot,
                prompt_len: p.req.prompt.len(),
                tokens,
                finish_reason: FinishReason::Failed { retries: p.retries },
                retries: p.retries,
                queued_s: started
                    .map(|s| (s - p.submitted).as_secs_f64())
                    .unwrap_or_else(|| p.submitted.elapsed().as_secs_f64()),
                latency_s: p.submitted.elapsed().as_secs_f64(),
            });
        }
    }

    /// Decode-fault recovery: every in-flight request is a casualty.
    /// `buffers_lost` distinguishes a real engine error (the step consumed
    /// the pool buffers — rebuild the pool, prefix cache included) from a
    /// plan-injected fault that fired before `take_bufs` (per-slot
    /// release; cached chains survive, so retries re-admit through the
    /// prefix cache). Either way the requests restart through prefill
    /// with their original sampler seeds — bitwise-identical streams.
    fn recover_actives(&mut self, buffers_lost: bool, done: &mut Vec<Completion>) {
        // every in-flight draft dies with its request; the draft pool and
        // its prefix cache survive for the retries' re-admission
        if let Some(sd) = self.spec.as_mut() {
            sd.release_all();
        }
        let mut actives: Vec<Active> =
            self.slots.iter_mut().filter_map(|s| s.take()).collect();
        if actives.is_empty() && !buffers_lost {
            return;
        }
        actives.sort_by_key(|a| a.id);
        if buffers_lost {
            self.reset_pool();
        } else {
            for a in &actives {
                for &b in &a.table {
                    self.pool.release(b);
                }
            }
        }
        // reverse id order + push_front ⇒ oldest request restarts first
        for a in actives.into_iter().rev() {
            let Active {
                id,
                req,
                submitted,
                submit_step,
                retries,
                streamed,
                tokens,
                slot,
                started,
                ..
            } = a;
            let p = Pending { id, req, submitted, submit_step, retries, streamed };
            self.retry_or_quarantine(p, tokens, slot, Some(started), done);
        }
    }

    /// Rebuild the pool after its in-flight buffers were lost; chaos spike
    /// holds die with it (their blocks no longer exist).
    fn reset_pool(&mut self) {
        self.pool.reset();
        self.spike_holds.clear();
        self.stats.pool_resets += 1;
    }

    /// Abort every in-flight request, releasing each slot's block chains
    /// via ref-counts — the prefix cache (and any queued requests) survive.
    /// The pool is rebuilt only if its buffers were genuinely lost mid-
    /// step. Returns the aborted ids so a front-end can fail just those
    /// callers.
    pub fn abort_active(&mut self) -> Vec<u64> {
        if let Some(sd) = self.spec.as_mut() {
            sd.release_all();
        }
        let actives: Vec<Active> =
            self.slots.iter_mut().filter_map(|s| s.take()).collect();
        let mut ids = Vec::new();
        for a in actives {
            for &b in &a.table {
                self.pool.release(b);
            }
            ids.push(a.id);
        }
        if !self.pool.bufs_present() {
            self.reset_pool();
        }
        ids
    }

    /// Hard abort: active slots *and* the queue, plus chaos spike holds —
    /// the router's unrecoverable-error path. Returns every aborted id.
    pub fn abort_all(&mut self) -> Vec<u64> {
        self.release_spikes(usize::MAX);
        let mut ids = self.abort_active();
        ids.extend(self.queue.drain(..).map(|p| p.id));
        ids
    }

    /// Done when the request reached `gen_len` tokens (`Stop`) or its next
    /// decode would overrun the decode window (`Length`) — the same guard,
    /// in the same padded coordinates, as [`Engine::generate`], so
    /// early-stopped outputs stay parity-comparable.
    fn finish_reason(&self, a: &Active) -> Option<FinishReason> {
        if a.tokens.len() >= a.req.gen_len {
            Some(FinishReason::Stop)
        } else if (a.fill + 1) as usize >= self.engine.config().max_decode_seq {
            Some(FinishReason::Length)
        } else {
            None
        }
    }

    fn complete(&mut self, a: Active, finish_reason: FinishReason) -> Completion {
        if let Some(sd) = self.spec.as_mut() {
            sd.release(a.slot);
        }
        for b in &a.table {
            self.pool.release(*b);
        }
        self.stats.completed += 1;
        Completion {
            id: a.id,
            slot: a.slot,
            prompt_len: a.req.prompt.len(),
            tokens: a.tokens,
            finish_reason,
            retries: a.retries,
            queued_s: (a.started - a.submitted).as_secs_f64(),
            latency_s: a.submitted.elapsed().as_secs_f64(),
        }
    }
}

impl Drop for Scheduler<'_> {
    /// Debug-build leak check: after releasing everything the loop still
    /// holds, every pool block must be accounted for by the scratch
    /// reservation or the prefix cache ([`KvPool::assert_balanced`]).
    fn drop(&mut self) {
        #[cfg(debug_assertions)]
        if !std::thread::panicking() {
            self.abort_all();
            if self.pool.bufs_present() {
                self.pool.assert_balanced();
            }
        }
    }
}
