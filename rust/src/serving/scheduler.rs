//! Continuous-batching scheduler: a request queue of ragged prompts packed
//! into the engine's fixed-batch decode graph through per-request *slots*.
//!
//! Each of the engine's `batch` slots is either **active** (owns a live
//! request, a window of the batched KV cache, and a seeded sampler) or
//! **parked** (decodes a dummy token whose cache writes land in a scratch
//! slot that the next admission overwrites). One [`Scheduler::step`]:
//!
//! 1. **Admit** — pop queued requests into free slots and run one batched
//!    prefill ([`Engine::prefill_into_slots`]) that left-pads short
//!    prompts, masks the pads, and splices only the admitted slots' cache
//!    rows into the live caches. The first token of each admitted request
//!    is sampled from its prefill logits row.
//! 2. **Decode** — one [`Engine::decode_step`] over the whole batch with
//!    per-slot `fill`/`starts` vectors, then sample one token per active
//!    slot. Requests that reach `gen_len` (or run out of cache) complete
//!    and free their slot for the next admission — requests join and leave
//!    mid-flight, vLLM-style, at static-shape scale.
//!
//! Because every graph row is computed independently of its neighbors (the
//! masking contract in `runtime/programs.rs`), a request's token sequence
//! is **bitwise identical** to a standalone [`Engine::generate`] run of
//! the same prompt — regardless of batch composition, admission order, or
//! `ARA_THREADS` (pinned by `tests/scheduler.rs`).

use std::collections::VecDeque;
use std::time::Instant;

use super::engine::Engine;
use super::sampler::{Sampler, SamplingParams};
use crate::runtime::DeviceBuffer;
use crate::Result;

/// One queued generation request.
#[derive(Debug, Clone)]
pub struct Request {
    pub prompt: Vec<i32>,
    pub gen_len: usize,
    pub params: SamplingParams,
}

/// A finished request.
#[derive(Debug, Clone)]
pub struct Completion {
    /// Submission id (monotonically increasing per scheduler).
    pub id: u64,
    /// The engine slot the request ran in.
    pub slot: usize,
    pub prompt_len: usize,
    pub tokens: Vec<i32>,
    /// Submit → prefill admission, seconds (queueing delay).
    pub queued_s: f64,
    /// Submit → completion, seconds.
    pub latency_s: f64,
}

/// Aggregate serve-loop counters.
#[derive(Debug, Clone, Default)]
pub struct SchedStats {
    pub steps: usize,
    pub prefills: usize,
    pub admitted: usize,
    pub completed: usize,
    pub tokens_generated: usize,
    /// First tokens sampled from prefill logits (subset of
    /// `tokens_generated`; excludes `gen_len = 0` admissions).
    pub prefill_sampled: usize,
    pub prefill_s: f64,
    pub decode_s: f64,
}

impl SchedStats {
    /// Generated tokens per second of engine time (prefill + decode).
    pub fn tok_per_s(&self) -> f64 {
        self.tokens_generated as f64 / (self.prefill_s + self.decode_s).max(1e-9)
    }

    /// Decode-loop throughput: tokens produced by decode steps per second
    /// of decode time (the first token of each request comes from its
    /// prefill logits and is excluded) — comparable to
    /// [`super::GenStats::tok_per_s`].
    pub fn decode_tok_per_s(&self) -> f64 {
        self.tokens_generated.saturating_sub(self.prefill_sampled) as f64
            / self.decode_s.max(1e-9)
    }
}

struct Pending {
    id: u64,
    req: Request,
    submitted: Instant,
}

struct Active {
    id: u64,
    slot: usize,
    prompt_len: usize,
    gen_len: usize,
    /// First valid cache slot: `prefill_len - real prompt len`.
    start: i32,
    /// Next cache write position.
    fill: i32,
    last: i32,
    tokens: Vec<i32>,
    sampler: Sampler,
    submitted: Instant,
    started: Instant,
}

/// The continuous-batching serve loop over one engine.
pub struct Scheduler<'e> {
    engine: &'e Engine,
    queue: VecDeque<Pending>,
    slots: Vec<Option<Active>>,
    caches: Option<Vec<DeviceBuffer>>,
    next_id: u64,
    stats: SchedStats,
}

impl<'e> Scheduler<'e> {
    pub fn new(engine: &'e Engine) -> Scheduler<'e> {
        let mut slots = Vec::with_capacity(engine.batch);
        slots.resize_with(engine.batch, || None);
        Scheduler {
            engine,
            queue: VecDeque::new(),
            slots,
            caches: None,
            next_id: 0,
            stats: SchedStats::default(),
        }
    }

    /// Enqueue a request; returns its completion id.
    pub fn submit(&mut self, req: Request) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back(Pending { id, req, submitted: Instant::now() });
        id
    }

    /// No queued and no in-flight requests.
    pub fn is_idle(&self) -> bool {
        self.queue.is_empty() && self.slots.iter().all(Option::is_none)
    }

    /// Requests currently decoding.
    pub fn active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Requests waiting for a slot.
    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    pub fn stats(&self) -> &SchedStats {
        &self.stats
    }

    /// One serve-loop iteration: admit into free slots, then decode one
    /// token for every active slot. Returns the requests that finished.
    ///
    /// On `Err` the in-flight cache state is lost: call
    /// [`Scheduler::abort_active`] before stepping again (queued requests
    /// survive; only the active slots are aborted).
    pub fn step(&mut self) -> Result<Vec<Completion>> {
        let mut done = Vec::new();
        self.admit(&mut done)?;
        self.decode(&mut done)?;
        self.stats.steps += 1;
        Ok(done)
    }

    /// Drive [`Scheduler::step`] until every submitted request completed.
    pub fn run_to_completion(&mut self) -> Result<Vec<Completion>> {
        let mut out = Vec::new();
        while !self.is_idle() {
            out.extend(self.step()?);
        }
        Ok(out)
    }

    fn admit(&mut self, done: &mut Vec<Completion>) -> Result<()> {
        if self.queue.is_empty() {
            return Ok(());
        }
        let mut admits: Vec<(usize, Pending)> = Vec::new();
        for slot in 0..self.slots.len() {
            if self.slots[slot].is_some() {
                continue;
            }
            match self.queue.pop_front() {
                Some(p) => admits.push((slot, p)),
                None => break,
            }
        }
        if admits.is_empty() {
            return Ok(());
        }
        let t0 = Instant::now();
        let pairs: Vec<(usize, &[i32])> =
            admits.iter().map(|(s, p)| (*s, p.req.prompt.as_slice())).collect();
        let (rows, merged) = match self.engine.prefill_into_slots(&pairs, self.caches.take()) {
            Ok(x) => x,
            Err(e) => {
                // transient engine error: put the popped requests back at
                // the queue front (original order) instead of losing them;
                // the live caches were consumed, so the caller must abort
                // the active slots ([`Scheduler::abort_active`])
                for (_, pending) in admits.into_iter().rev() {
                    self.queue.push_front(pending);
                }
                return Err(e);
            }
        };
        self.caches = Some(merged);
        self.stats.prefill_s += t0.elapsed().as_secs_f64();
        self.stats.prefills += 1;
        let p = self.engine.config().prefill_len;
        for ((slot, pending), row) in admits.into_iter().zip(rows) {
            let n = self.engine.real_len(&pending.req.prompt);
            let mut a = Active {
                id: pending.id,
                slot,
                prompt_len: pending.req.prompt.len(),
                gen_len: pending.req.gen_len,
                start: (p - n) as i32,
                fill: p as i32,
                last: crate::data::BOS_TOKEN,
                tokens: Vec::with_capacity(pending.req.gen_len),
                sampler: Sampler::new(pending.req.params.clone()),
                submitted: pending.submitted,
                started: t0,
            };
            self.stats.admitted += 1;
            if a.gen_len == 0 {
                done.push(self.complete(a));
                continue;
            }
            let tok = a.sampler.sample(&row);
            a.last = tok;
            a.tokens.push(tok);
            self.stats.tokens_generated += 1;
            self.stats.prefill_sampled += 1;
            if self.finished(&a) {
                done.push(self.complete(a));
            } else {
                self.slots[slot] = Some(a);
            }
        }
        Ok(())
    }

    fn decode(&mut self, done: &mut Vec<Completion>) -> Result<()> {
        if self.slots.iter().all(Option::is_none) {
            return Ok(());
        }
        let b = self.engine.batch;
        let p = self.engine.config().prefill_len;
        // parked slots decode a dummy BOS whose cache write lands at slot
        // `p` of their (dead) cache row — the next admission overwrites it
        let mut toks = vec![crate::data::BOS_TOKEN; b];
        let mut fill = vec![p as i32; b];
        let mut starts = vec![0i32; b];
        for a in self.slots.iter().flatten() {
            toks[a.slot] = a.last;
            fill[a.slot] = a.fill;
            starts[a.slot] = a.start;
        }
        let t0 = Instant::now();
        let caches = self.caches.take().expect("active slots imply live caches");
        let (logits, new_caches) = self.engine.decode_step(caches, &toks, &fill, &starts)?;
        self.caches = Some(new_caches);
        self.stats.decode_s += t0.elapsed().as_secs_f64();
        let vocab = self.engine.config().vocab;
        for slot in 0..b {
            let Some(mut a) = self.slots[slot].take() else { continue };
            a.fill += 1;
            let row = &logits.data[slot * vocab..(slot + 1) * vocab];
            let tok = a.sampler.sample(row);
            a.last = tok;
            a.tokens.push(tok);
            self.stats.tokens_generated += 1;
            if self.finished(&a) {
                done.push(self.complete(a));
            } else {
                self.slots[slot] = Some(a);
            }
        }
        Ok(())
    }

    /// Engine-error recovery: abort every in-flight request (their cache
    /// state is gone) but **keep the queue** — queued requests never
    /// touched the engine and can still be served. Returns the aborted
    /// request ids so a front-end can fail just those callers.
    pub fn abort_active(&mut self) -> Vec<u64> {
        self.caches = None;
        let mut ids = Vec::new();
        for s in self.slots.iter_mut() {
            if let Some(a) = s.take() {
                ids.push(a.id);
            }
        }
        ids
    }

    /// Done when the request reached `gen_len` tokens or its next decode
    /// would overrun the cache — the same guard as [`Engine::generate`], so
    /// early-stopped outputs stay parity-comparable.
    fn finished(&self, a: &Active) -> bool {
        a.tokens.len() >= a.gen_len || (a.fill + 1) as usize >= self.engine.config().max_decode_seq
    }

    fn complete(&mut self, a: Active) -> Completion {
        self.stats.completed += 1;
        Completion {
            id: a.id,
            slot: a.slot,
            prompt_len: a.prompt_len,
            tokens: a.tokens,
            queued_s: (a.started - a.submitted).as_secs_f64(),
            latency_s: a.submitted.elapsed().as_secs_f64(),
        }
    }
}
