//! Threaded router front-end: the engine (PJRT handles are not Sync) lives
//! on a dedicated worker thread; callers submit requests over a channel and
//! receive generated tokens over per-request reply channels. This is the
//! process topology a multi-engine deployment would shard over.

use std::sync::mpsc;
use std::thread::JoinHandle;

/// One generation request.
#[derive(Debug, Clone)]
pub struct ServeRequest {
    pub prompt: Vec<i32>,
    pub gen_len: usize,
}

/// One generation response.
#[derive(Debug, Clone)]
pub struct ServeResponse {
    pub tokens: Vec<i32>,
    pub decode_tok_per_s: f64,
}

enum Msg {
    Req(ServeRequest, mpsc::Sender<ServeResponse>),
    Shutdown,
}

/// Router handle: submit requests, receive responses.
pub struct Router {
    tx: mpsc::Sender<Msg>,
    worker: Option<JoinHandle<()>>,
}

impl Router {
    /// Spawn the engine worker. `engine_builder` runs on the worker thread
    /// (PJRT state never crosses threads) and returns a closure that
    /// generates a batch of prompt→tokens.
    pub fn spawn<F>(engine_builder: F, batch: usize, prefill_len: usize, max_wait_ms: u64) -> Router
    where
        F: FnOnce() -> Box<dyn FnMut(&[Vec<i32>], usize) -> crate::Result<(Vec<Vec<i32>>, f64)>>
            + Send
            + 'static,
    {
        let (tx, rx) = mpsc::channel::<Msg>();
        let worker = std::thread::spawn(move || {
            let mut generate = engine_builder();
            let mut queue: Vec<(ServeRequest, mpsc::Sender<ServeResponse>)> = Vec::new();
            loop {
                // block for the first request, then drain within max_wait
                match rx.recv() {
                    Ok(Msg::Req(r, reply)) => queue.push((r, reply)),
                    Ok(Msg::Shutdown) | Err(_) => break,
                }
                let deadline = std::time::Instant::now()
                    + std::time::Duration::from_millis(max_wait_ms);
                while queue.len() < batch {
                    let now = std::time::Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match rx.recv_timeout(deadline - now) {
                        Ok(Msg::Req(r, reply)) => queue.push((r, reply)),
                        Ok(Msg::Shutdown) => break,
                        Err(_) => break,
                    }
                }
                // run one padded batch
                let n = queue.len().min(batch);
                let mut prompts: Vec<Vec<i32>> = queue[..n]
                    .iter()
                    .map(|(r, _)| {
                        let mut p = r.prompt.clone();
                        p.resize(prefill_len, crate::data::BOS_TOKEN);
                        p
                    })
                    .collect();
                while prompts.len() < batch {
                    prompts.push(vec![crate::data::BOS_TOKEN; prefill_len]);
                }
                let gen_len = queue[..n].iter().map(|(r, _)| r.gen_len).max().unwrap_or(1);
                match generate(&prompts, gen_len) {
                    Ok((tokens, tps)) => {
                        for (i, (req, reply)) in queue.drain(..n).enumerate() {
                            let mut t = tokens[i].clone();
                            t.truncate(req.gen_len);
                            let _ = reply.send(ServeResponse {
                                tokens: t,
                                decode_tok_per_s: tps,
                            });
                        }
                    }
                    Err(e) => {
                        eprintln!("[router] batch failed: {e}");
                        queue.drain(..n);
                    }
                }
            }
        });
        Router { tx, worker: Some(worker) }
    }

    /// Submit a request; returns the reply receiver.
    pub fn submit(&self, req: ServeRequest) -> mpsc::Receiver<ServeResponse> {
        let (tx, rx) = mpsc::channel();
        self.tx.send(Msg::Req(req, tx)).expect("router worker gone");
        rx
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}
