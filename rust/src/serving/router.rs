//! Threaded serve-loop front-end: the engine (PJRT handles are not Sync)
//! lives on a dedicated worker thread driving a continuous-batching
//! [`Scheduler`]; callers submit ragged prompts with per-request sampling
//! params over a channel and receive generated tokens on per-request reply
//! channels. Requests arriving mid-flight are admitted into freed slots
//! between decode steps. This is the process topology a multi-engine
//! deployment would shard over.

use std::collections::HashMap;
use std::sync::mpsc;
use std::thread::JoinHandle;

use super::engine::{Engine, FinishReason};
use super::sampler::SamplingParams;
use super::scheduler::{Request, Scheduler};

/// One generation request (ragged prompt; the scheduler left-pads).
#[derive(Debug, Clone)]
pub struct ServeRequest {
    pub prompt: Vec<i32>,
    pub gen_len: usize,
    pub params: SamplingParams,
}

/// One generation response.
#[derive(Debug, Clone)]
pub struct ServeResponse {
    pub tokens: Vec<i32>,
    /// Why generation ended: `Stop` (reached `gen_len`) or `Length`
    /// (truncated by the decode window / KV-pool capacity) — KV
    /// exhaustion is surfaced, never silently swallowed.
    pub finish_reason: FinishReason,
    /// The serve loop's running decode throughput at completion time
    /// ([`super::SchedStats::decode_tok_per_s`]) — an engine-wide figure,
    /// not a per-request one.
    pub decode_tok_per_s: f64,
}

enum Msg {
    Req(ServeRequest, mpsc::Sender<ServeResponse>),
    Shutdown,
}

/// Router handle: submit requests, receive responses.
pub struct Router {
    tx: mpsc::Sender<Msg>,
    worker: Option<JoinHandle<()>>,
}

impl Router {
    /// Spawn the engine worker. `engine_builder` runs on the worker thread
    /// (PJRT state never crosses threads) and returns the engine the serve
    /// loop drives. The worker blocks when idle; while serving it polls the
    /// channel between scheduler steps, so new requests are admitted into
    /// freed slots mid-flight (continuous batching).
    pub fn spawn<F>(engine_builder: F) -> Router
    where
        F: FnOnce() -> Engine + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Msg>();
        let worker = std::thread::spawn(move || {
            let engine = engine_builder();
            let mut sched = Scheduler::new(&engine);
            let mut replies: HashMap<u64, mpsc::Sender<ServeResponse>> = HashMap::new();
            let mut shutdown = false;
            let mut failures = 0usize;
            loop {
                // drain the channel: block while idle, poll while serving
                loop {
                    let msg = if sched.is_idle() && !shutdown {
                        match rx.recv() {
                            Ok(m) => m,
                            Err(_) => {
                                shutdown = true;
                                break;
                            }
                        }
                    } else {
                        match rx.try_recv() {
                            Ok(m) => m,
                            Err(mpsc::TryRecvError::Empty) => break,
                            Err(mpsc::TryRecvError::Disconnected) => {
                                shutdown = true;
                                break;
                            }
                        }
                    };
                    match msg {
                        Msg::Req(r, reply) => {
                            let id = sched.submit(Request {
                                prompt: r.prompt,
                                gen_len: r.gen_len,
                                params: r.params,
                            });
                            replies.insert(id, reply);
                        }
                        Msg::Shutdown => shutdown = true,
                    }
                }
                if sched.is_idle() {
                    if shutdown {
                        break;
                    }
                    continue;
                }
                match sched.step() {
                    Ok(done) => {
                        failures = 0;
                        let tps = sched.stats().decode_tok_per_s();
                        for c in done {
                            if let Some(reply) = replies.remove(&c.id) {
                                let _ = reply.send(ServeResponse {
                                    tokens: c.tokens,
                                    finish_reason: c.finish_reason,
                                    decode_tok_per_s: tps,
                                });
                            }
                        }
                    }
                    Err(e) => {
                        // abort only the in-flight slots (their cache state
                        // is gone) — queued requests survive in the
                        // scheduler and are retried; dropping a reply
                        // sender fails that caller's receiver
                        eprintln!("[router] scheduler step failed: {e}");
                        for id in sched.abort_active() {
                            replies.remove(&id);
                        }
                        failures += 1;
                        if failures >= 3 {
                            eprintln!(
                                "[router] persistent engine failure, dropping {} requests",
                                replies.len()
                            );
                            replies.clear();
                            break;
                        }
                    }
                }
            }
        });
        Router { tx, worker: Some(worker) }
    }

    /// Submit a request; returns the reply receiver. If the worker has
    /// exited (persistent engine failure), the receiver's `recv()` errors
    /// instead of this call panicking.
    pub fn submit(&self, req: ServeRequest) -> mpsc::Receiver<ServeResponse> {
        let (tx, rx) = mpsc::channel();
        if self.tx.send(Msg::Req(req, tx)).is_err() {
            eprintln!("[router] worker gone, dropping request");
        }
        rx
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}
