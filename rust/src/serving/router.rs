//! Threaded serve-loop front-end: the engine (PJRT handles are not Sync)
//! lives on a dedicated worker thread driving a continuous-batching
//! [`Scheduler`]; callers submit ragged prompts with per-request sampling
//! params over a channel and receive generated tokens on per-request reply
//! channels. Requests arriving mid-flight are admitted into freed slots
//! between decode steps. This is the process topology a multi-engine
//! deployment would shard over.
//!
//! Resilience (DESIGN.md §5): admission is bounded — past
//! [`RouterCfg::queue_depth`] in-flight requests, `submit` sheds with an
//! immediate `Rejected` response instead of queueing unboundedly. Every
//! tracked request always receives a typed [`ServeResponse`]; reply
//! channels are never silently dropped. Transient engine faults are
//! absorbed by the scheduler (bounded retry + quarantine) while the worker
//! applies a capped exponential backoff between faulty steps; only an
//! unrecoverable scheduler error fails the in-flight requests — with
//! `Failed` responses carrying the cause — and the worker keeps serving.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::Duration;

use super::engine::{Engine, FinishReason};
use super::sampler::SamplingParams;
use super::scheduler::{CancelToken, Request, Scheduler};
use super::specdec::SpecDec;
use crate::Result;

/// One generation request (ragged prompt; the scheduler left-pads).
#[derive(Debug, Clone, Default)]
pub struct ServeRequest {
    pub prompt: Vec<i32>,
    pub gen_len: usize,
    pub params: SamplingParams,
    /// Optional step-budget deadline (see [`Request::deadline_steps`]).
    pub deadline_steps: Option<usize>,
    /// Optional cooperative cancellation token.
    pub cancel: Option<CancelToken>,
    /// Optional per-token streaming sink (see [`Request::stream`]): each
    /// sampled token is delivered the scheduler step it is produced, and
    /// the final [`ServeResponse`] still carries the complete stream.
    pub stream: Option<mpsc::Sender<i32>>,
    /// Optional draft-plan spec for self-speculative decoding (see
    /// [`Request::draft_spec`]). `None` inherits the worker's
    /// `ARA_DRAFT_SPEC` default; `Some("")` explicitly opts out.
    pub draft_spec: Option<String>,
}

/// One generation response. Every submitted request receives exactly one —
/// shed, cancelled, expired, and failed requests included.
#[derive(Debug, Clone)]
pub struct ServeResponse {
    pub tokens: Vec<i32>,
    /// Why generation ended — the full typed taxonomy: `Stop`/`Length`
    /// (natural), `Rejected` (shed at admission), `Cancelled`,
    /// `DeadlineExceeded`, or `Failed { retries }` (fault quarantine or
    /// unrecoverable engine error). Never a silently dropped channel.
    pub finish_reason: FinishReason,
    /// Times the request was re-queued by a transient fault before
    /// finishing.
    pub retries: u32,
    /// Failure cause, populated on `Failed` responses when known.
    pub error: Option<String>,
    /// The serve loop's running decode throughput at completion time
    /// ([`super::SchedStats::decode_tok_per_s`]) — an engine-wide figure,
    /// not a per-request one.
    pub decode_tok_per_s: f64,
}

/// Router admission and backoff knobs.
#[derive(Debug, Clone, Copy)]
pub struct RouterCfg {
    /// Max in-flight (accepted but unanswered) requests before `submit`
    /// sheds with an immediate `Rejected` (`ARA_QUEUE_DEPTH`, default 256).
    pub queue_depth: usize,
    /// Worker sleep after a step that recorded a fault; doubles per
    /// consecutive faulty step up to [`RouterCfg::backoff_cap`], resets on
    /// a clean step.
    pub backoff_base: Duration,
    pub backoff_cap: Duration,
}

impl Default for RouterCfg {
    fn default() -> RouterCfg {
        RouterCfg {
            queue_depth: 256,
            backoff_base: Duration::from_millis(5),
            backoff_cap: Duration::from_millis(500),
        }
    }
}

impl RouterCfg {
    pub fn from_env() -> RouterCfg {
        let queue_depth = std::env::var("ARA_QUEUE_DEPTH")
            .ok()
            .and_then(|v| v.trim().parse::<usize>().ok())
            .unwrap_or(256)
            .max(1);
        RouterCfg { queue_depth, ..RouterCfg::default() }
    }
}

enum Msg {
    Req(ServeRequest, mpsc::Sender<ServeResponse>),
    /// Snapshot the worker's serve-loop state (scheduler + pool + engine
    /// provenance) into the sender — the `GET /stats` round-trip.
    Stats(mpsc::Sender<WorkerStats>),
    Shutdown,
}

/// Point-in-time snapshot of the worker's serve loop, taken between
/// scheduler steps (so the counters are mutually consistent).
#[derive(Debug, Clone)]
pub struct WorkerStats {
    /// Full scheduler counters (see [`super::SchedStats`]).
    pub sched: super::SchedStats,
    /// KV-pool blocks currently allocated (scratch block included).
    pub pool_used_blocks: usize,
    /// Fraction of allocatable pool blocks in use, in [0, 1].
    pub pool_utilization: f64,
    /// Prefix-cache hit rate over admission lookups, in [0, 1].
    pub prefix_hit_rate: f64,
    /// Compression-plan provenance line baked into the engine, if any.
    pub provenance: Option<String>,
    /// Quantization recipe the engine serves with (`None` = f32 factors).
    pub quant: Option<crate::quant::QuantScheme>,
    /// SIMD dispatch tier the engine's kernels run on.
    pub simd_tier: &'static str,
    /// Requests waiting for a slot on the worker right now.
    pub queued: usize,
    /// Requests actively decoding on the worker right now.
    pub active: usize,
    /// Draft-plan spec when a speculative decoder is installed.
    pub draft_spec: Option<String>,
    /// Draft KV-pool utilization in [0, 1] when a speculative decoder is
    /// installed.
    pub draft_pool_utilization: Option<f64>,
    /// Slots with a live draft sequence right now.
    pub active_drafts: usize,
}

/// Router handle: submit requests, receive responses.
pub struct Router {
    tx: mpsc::Sender<Msg>,
    worker: Option<JoinHandle<()>>,
    cfg: RouterCfg,
    /// Accepted-but-unanswered requests (incremented at submit, decremented
    /// by the worker when a response is sent).
    depth: Arc<AtomicUsize>,
    /// Requests shed with `Rejected` at admission.
    shed: Arc<AtomicUsize>,
}

fn failed_response(error: String, tps: f64) -> ServeResponse {
    ServeResponse {
        tokens: Vec::new(),
        finish_reason: FinishReason::Failed { retries: 0 },
        retries: 0,
        error: Some(error),
        decode_tok_per_s: tps,
    }
}

impl Router {
    /// Spawn with knobs from the environment (`ARA_QUEUE_DEPTH`, …).
    pub fn spawn<F>(engine_builder: F) -> Router
    where
        F: FnOnce() -> Engine + Send + 'static,
    {
        Router::spawn_with(RouterCfg::from_env(), engine_builder)
    }

    /// Spawn the engine worker. `engine_builder` runs on the worker thread
    /// (PJRT state never crosses threads) and returns the engine the serve
    /// loop drives. The worker blocks when idle; while serving it polls the
    /// channel between scheduler steps, so new requests are admitted into
    /// freed slots mid-flight (continuous batching).
    pub fn spawn_with<F>(cfg: RouterCfg, engine_builder: F) -> Router
    where
        F: FnOnce() -> Engine + Send + 'static,
    {
        Router::spawn_with_spec(cfg, move || (engine_builder(), None))
    }

    /// Spawn the engine worker with an optional self-speculative decoder.
    /// The builder runs on the worker thread (engines are not `Send`) and
    /// returns the target engine plus an optional [`SpecDec`] holding the
    /// draft engine; installation failure (mismatched verify window, batch)
    /// is logged and the worker serves plain — the draft is advisory.
    pub fn spawn_with_spec<F>(cfg: RouterCfg, builder: F) -> Router
    where
        F: FnOnce() -> (Engine, Option<SpecDec>) + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Msg>();
        let depth = Arc::new(AtomicUsize::new(0));
        let worker_depth = Arc::clone(&depth);
        let worker = std::thread::spawn(move || {
            let (engine, spec) = builder();
            let mut sched = Scheduler::new(&engine);
            if let Some(sd) = spec {
                if let Err(e) = sched.set_spec_dec(Some(sd)) {
                    eprintln!("specdec disabled: {e}");
                }
            }
            // worker-wide default draft spec: requests that don't name a
            // draft inherit it. ARA_DRAFT_SPEC wins (empty string = no
            // default); unset falls back to the installed decoder's spec,
            // so `--draft` alone turns drafting on for every greedy request
            let default_draft = std::env::var("ARA_DRAFT_SPEC")
                .ok()
                .map(|v| v.trim().to_string())
                .or_else(|| sched.spec_dec().map(|sd| sd.spec().to_string()))
                .filter(|v| !v.is_empty());
            let mut replies: HashMap<u64, mpsc::Sender<ServeResponse>> = HashMap::new();
            let mut shutdown = false;
            let mut backoff = cfg.backoff_base;
            let answer = |id: u64,
                          resp: ServeResponse,
                          replies: &mut HashMap<u64, mpsc::Sender<ServeResponse>>| {
                worker_depth.fetch_sub(1, Ordering::SeqCst);
                if let Some(reply) = replies.remove(&id) {
                    // a send to a gone caller just drops the response; the
                    // depth slot is freed either way
                    let _ = reply.send(resp);
                }
            };
            loop {
                // drain the channel: block while idle, poll while serving
                loop {
                    let msg = if sched.is_idle() && !shutdown {
                        match rx.recv() {
                            Ok(m) => m,
                            Err(_) => {
                                shutdown = true;
                                break;
                            }
                        }
                    } else {
                        match rx.try_recv() {
                            Ok(m) => m,
                            Err(mpsc::TryRecvError::Empty) => break,
                            Err(mpsc::TryRecvError::Disconnected) => {
                                shutdown = true;
                                break;
                            }
                        }
                    };
                    match msg {
                        Msg::Req(r, reply) => {
                            // per-request draft override: absent → worker
                            // default; empty string → explicit opt-out
                            let draft_spec = r
                                .draft_spec
                                .or_else(|| default_draft.clone())
                                .filter(|v| !v.is_empty());
                            let id = sched.submit(Request {
                                prompt: r.prompt,
                                gen_len: r.gen_len,
                                params: r.params,
                                deadline_steps: r.deadline_steps,
                                cancel: r.cancel,
                                stream: r.stream,
                                draft_spec,
                            });
                            replies.insert(id, reply);
                        }
                        Msg::Stats(reply) => {
                            let _ = reply.send(WorkerStats {
                                sched: sched.stats().clone(),
                                pool_used_blocks: sched.pool().used_blocks(),
                                pool_utilization: sched.pool().utilization(),
                                prefix_hit_rate: sched.stats().prefix_hit_rate(),
                                provenance: engine.provenance().map(str::to_string),
                                quant: engine.quant(),
                                simd_tier: crate::kernels::active_tier().name(),
                                queued: sched.queued(),
                                active: sched.active(),
                                draft_spec: sched
                                    .spec_dec()
                                    .map(|sd| sd.spec().to_string()),
                                draft_pool_utilization: sched
                                    .spec_dec()
                                    .map(|sd| sd.pool_utilization()),
                                active_drafts: sched
                                    .spec_dec()
                                    .map_or(0, |sd| sd.active_drafts()),
                            });
                        }
                        Msg::Shutdown => shutdown = true,
                    }
                }
                if sched.is_idle() {
                    if shutdown {
                        break;
                    }
                    continue;
                }
                let faults_before =
                    sched.stats().decode_faults + sched.stats().prefill_faults;
                match sched.step() {
                    Ok(done) => {
                        let tps = sched.stats().decode_tok_per_s();
                        for c in done {
                            let error = match c.finish_reason {
                                FinishReason::Failed { .. } => sched.stats().last_fault.clone(),
                                _ => None,
                            };
                            answer(
                                c.id,
                                ServeResponse {
                                    tokens: c.tokens,
                                    finish_reason: c.finish_reason,
                                    retries: c.retries,
                                    error,
                                    decode_tok_per_s: tps,
                                },
                                &mut replies,
                            );
                        }
                        let faults_now =
                            sched.stats().decode_faults + sched.stats().prefill_faults;
                        if faults_now > faults_before {
                            // transient fault absorbed this step: back off
                            // before hammering a possibly-sick engine
                            std::thread::sleep(backoff);
                            backoff = (backoff * 2).min(cfg.backoff_cap);
                        } else {
                            backoff = cfg.backoff_base;
                        }
                    }
                    Err(e) => {
                        // unrecoverable scheduler error: fail every tracked
                        // request with a typed response (cause attached) —
                        // the worker itself keeps serving new submissions
                        let msg = e.to_string();
                        for id in sched.abort_all() {
                            answer(id, failed_response(msg.clone(), 0.0), &mut replies);
                        }
                        std::thread::sleep(backoff);
                        backoff = (backoff * 2).min(cfg.backoff_cap);
                    }
                }
            }
            // defensive: a reply that survived the loop (scheduler bug)
            // still gets a typed response instead of a dropped channel
            let leftover: Vec<u64> = replies.keys().copied().collect();
            for id in leftover {
                answer(
                    id,
                    failed_response("router shut down with request unserved".into(), 0.0),
                    &mut replies,
                );
            }
        });
        Router { tx, worker: Some(worker), cfg, depth, shed: Arc::new(AtomicUsize::new(0)) }
    }

    /// Submit a request. `Ok` carries the reply receiver — guaranteed to
    /// yield exactly one typed [`ServeResponse`] (an immediate `Rejected`
    /// when admission shed the request). `Err` only when the worker thread
    /// is gone (engine builder panicked / after shutdown): the request was
    /// not accepted.
    pub fn submit(&self, req: ServeRequest) -> Result<mpsc::Receiver<ServeResponse>> {
        let (tx, rx) = mpsc::channel();
        let admitted = self
            .depth
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |d| {
                (d < self.cfg.queue_depth).then_some(d + 1)
            })
            .is_ok();
        if !admitted {
            // bounded admission: shed now, with a typed response — callers
            // distinguish overload from failure without waiting
            self.shed.fetch_add(1, Ordering::SeqCst);
            let _ = tx.send(ServeResponse {
                tokens: Vec::new(),
                finish_reason: FinishReason::Rejected,
                retries: 0,
                error: None,
                decode_tok_per_s: 0.0,
            });
            return Ok(rx);
        }
        if self.tx.send(Msg::Req(req, tx)).is_err() {
            self.depth.fetch_sub(1, Ordering::SeqCst);
            return Err(crate::anyhow!(
                "router worker is gone (engine thread exited); request not accepted"
            ));
        }
        Ok(rx)
    }

    /// Accepted-but-unanswered requests right now.
    pub fn in_flight(&self) -> usize {
        self.depth.load(Ordering::SeqCst)
    }

    /// Requests shed with `Rejected` since spawn.
    pub fn shed(&self) -> usize {
        self.shed.load(Ordering::SeqCst)
    }

    /// Snapshot the worker's serve-loop state (scheduler counters, pool
    /// occupancy, engine provenance). Blocks for one channel round-trip —
    /// the worker answers between scheduler steps. `Err` when the worker
    /// is gone.
    pub fn worker_stats(&self) -> Result<WorkerStats> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Msg::Stats(tx))
            .map_err(|_| crate::anyhow!("router worker is gone (engine thread exited)"))?;
        rx.recv().map_err(|_| {
            crate::anyhow!("router worker exited without answering stats probe")
        })
    }

    /// Shut down and join the worker, surfacing a worker panic as `Err`
    /// instead of swallowing it the way `Drop` must. The debug-build KV
    /// leak check lives in the scheduler's `Drop` on the worker thread —
    /// callers that care about it (the `serve` subcommand, the e2e gate)
    /// must use `join` so a tripped check fails the process.
    pub fn join(mut self) -> Result<()> {
        let _ = self.tx.send(Msg::Shutdown);
        match self.worker.take() {
            Some(w) => w.join().map_err(|_| {
                crate::anyhow!("router worker panicked during shutdown (leak check?)")
            }),
            None => Ok(()),
        }
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}
