//! Block-paged KV-cache pool with ref-counted blocks and prefix sharing —
//! the memory manager under the continuous-batching scheduler (vLLM's
//! PagedAttention design at static-shape scale).
//!
//! Layout: per layer one K and one V **row table** of shape
//! `(num_blocks · block_len, n_kv_heads · head_dim)` — row `r` holds every
//! kv-head's vector for token slot `r % block_len` of block
//! `r / block_len`. A request owns a *block table* (ordered physical block
//! ids); its virtual token position `i` lives at pool row
//! `table[i / block_len] · block_len + i % block_len`. The paged layout
//! drops the contiguous path's left-pad: virtual slot `i` is token `i`, so
//! content-equal prompt prefixes map to bitwise-equal K/V rows and can
//! share physical blocks across requests of different lengths.
//!
//! Invariants:
//! * **Block 0 is scratch** — parked decode slots write their dummy token
//!   there; it is never allocated to a request.
//! * **Ref-counting** — a block is held once per request table entry and
//!   once per prefix-cache chain that lists it; it returns to the free
//!   list when the count reaches zero. Allocation order is deterministic
//!   (ascending ids via a LIFO free list seeded in descending order).
//! * **Copy-on-write** — shared blocks are never written. Full blocks of a
//!   cached chain are read-only by construction (decode writes land at
//!   virtual positions ≥ the prompt length, i.e. past every shared full
//!   block); a reused *partial* tail block is [`KvPool::cow_block`]-copied
//!   before the borrowing request appends into it.
//! * **Prefix map** — `hash(prefix) → block chain`, at full-block
//!   granularity, plus a full-prompt entry that also caches the prefill's
//!   final-position logits row: a request whose entire (windowed) prompt
//!   is cached skips prefill compute entirely. Entries are evicted LRU
//!   when the pool runs dry or the map outgrows its cap; token contents
//!   are stored and compared on lookup, so hash collisions degrade to
//!   misses, never to wrong reuse.
//!
//! The pool is host-resident (the default CPU backend's "device" memory is
//! host memory); the PJRT serving path keeps the contiguous caches.

use std::collections::{HashMap, VecDeque};

use crate::config::ModelCfg;
use crate::runtime::{DeviceBuffer, Value};
use crate::tensor::Tensor;
use crate::Result;

/// Pool geometry + policy for one engine specialization. Baked into the
/// `decode_paged_<alloc>_b<B>_blk<L>x<N>` artifact shape.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvPoolCfg {
    /// Token slots per block (`ARA_KV_BLOCK`; default: `prefill_len`).
    pub block_len: usize,
    /// Total blocks incl. the reserved scratch block 0 (`ARA_KV_BLOCKS`;
    /// default: `1 + (batch + 1) · ceil(max_decode_seq / block_len)`).
    pub num_blocks: usize,
    /// Reuse cached prefix chains (`ARA_KV_SHARE=0` disables; default on).
    pub prefix_sharing: bool,
}

impl KvPoolCfg {
    /// Resolve the pool geometry from the environment with model-shaped
    /// defaults: block = the prefill window, capacity = every slot at its
    /// longest sequence plus one sequence of headroom for the prefix cache.
    pub fn from_env(cfg: &ModelCfg, batch: usize) -> KvPoolCfg {
        let env = |k: &str| std::env::var(k).ok().and_then(|v| v.trim().parse::<usize>().ok());
        let block_len = env("ARA_KV_BLOCK")
            .unwrap_or(cfg.prefill_len)
            .clamp(1, cfg.max_decode_seq);
        let bps = cfg.max_decode_seq.div_ceil(block_len);
        let num_blocks = env("ARA_KV_BLOCKS").unwrap_or(1 + (batch + 1) * bps).max(2);
        let prefix_sharing = !matches!(std::env::var("ARA_KV_SHARE").as_deref(), Ok("0"));
        KvPoolCfg { block_len, num_blocks, prefix_sharing }
    }

    /// Max blocks one sequence can span (the block-table width per slot).
    pub fn blocks_per_seq(&self, cfg: &ModelCfg) -> usize {
        cfg.max_decode_seq.div_ceil(self.block_len)
    }

    /// The artifact-name suffix this geometry compiles to.
    pub fn artifact_suffix(&self) -> String {
        format!("blk{}x{}", self.block_len, self.num_blocks)
    }
}

/// A successful prefix-map lookup. Returned blocks are already retained
/// for the caller (one count per block) — release them on drop-out paths.
pub enum PrefixHit {
    /// The entire effective prompt is cached: the chain covers all
    /// `ceil(n / block_len)` blocks and `logits` is the prefill's
    /// final-position row — prefill can be skipped outright.
    Full { blocks: Vec<usize>, logits: Vec<f32> },
    /// The first `covered` tokens (a whole number of blocks) are cached.
    Partial { blocks: Vec<usize>, covered: usize },
}

struct ChainEntry {
    tokens: Vec<i32>,
    blocks: Vec<usize>,
    /// Final-position prefill logits (full-prompt entries only).
    logits: Option<Vec<f32>>,
}

/// Max cached chains before LRU eviction kicks in preemptively.
const PREFIX_CAP: usize = 64;

/// Pool-accounting counters (also surfaced through `SchedStats`).
#[derive(Debug, Clone, Copy, Default)]
pub struct PoolStats {
    pub prefix_lookups: usize,
    pub prefix_hits: usize,
    pub full_hits: usize,
    pub evictions: usize,
    pub cow_copies: usize,
}

pub struct KvPool {
    n_layers: usize,
    nkv: usize,
    dh: usize,
    pub cfg: KvPoolCfg,
    /// 2·n_layers buffers in (kpool.0, vpool.0, kpool.1, …) order; `None`
    /// while moved into a decode step (or lost to a failed one).
    bufs: Vec<Option<DeviceBuffer>>,
    refs: Vec<u32>,
    /// LIFO free list seeded descending, so allocation is ascending-id.
    free: Vec<usize>,
    prefix: HashMap<u64, ChainEntry>,
    lru: VecDeque<u64>,
    peak_used: usize,
    pub stats: PoolStats,
}

fn host_ref(buf: &DeviceBuffer) -> Result<&Tensor> {
    match buf {
        DeviceBuffer::Host(Value::F32(t)) => Ok(t),
        _ => Err(crate::anyhow!("kv pool requires host f32 buffers (cpu backend)")),
    }
}

fn host_mut(buf: &mut DeviceBuffer) -> Result<&mut Tensor> {
    match buf {
        DeviceBuffer::Host(Value::F32(t)) => Ok(t),
        _ => Err(crate::anyhow!("kv pool requires host f32 buffers (cpu backend)")),
    }
}

/// FNV-1a over a tag, the token count, and the token bytes.
fn chain_hash(tag: u64, tokens: &[i32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    let mut step = |byte: u8| {
        h ^= byte as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    };
    for b in tag.to_le_bytes() {
        step(b);
    }
    for b in (tokens.len() as u64).to_le_bytes() {
        step(b);
    }
    for t in tokens {
        for b in t.to_le_bytes() {
            step(b);
        }
    }
    h
}

const TAG_BLOCKS: u64 = 0;
const TAG_FULL: u64 = 1;

impl KvPool {
    pub fn new(cfg: &ModelCfg, pcfg: KvPoolCfg) -> KvPool {
        let rows = pcfg.num_blocks * pcfg.block_len;
        let width = cfg.n_kv_heads * cfg.head_dim();
        let mut bufs = Vec::with_capacity(2 * cfg.n_layers);
        for _ in 0..2 * cfg.n_layers {
            bufs.push(Some(DeviceBuffer::Host(Value::F32(Tensor::zeros(&[rows, width])))));
        }
        let mut refs = vec![0u32; pcfg.num_blocks];
        refs[0] = 1; // scratch block: permanently held, never allocated
        KvPool {
            n_layers: cfg.n_layers,
            nkv: cfg.n_kv_heads,
            dh: cfg.head_dim(),
            cfg: pcfg,
            bufs,
            refs,
            free: (1..pcfg.num_blocks).rev().collect(),
            prefix: HashMap::new(),
            lru: VecDeque::new(),
            peak_used: 0,
            stats: PoolStats::default(),
        }
    }

    // ---------------- block accounting ----------------

    /// Blocks currently available without evicting cached chains.
    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    /// Blocks held by requests or cached chains (scratch excluded).
    pub fn used_blocks(&self) -> usize {
        self.cfg.num_blocks - 1 - self.free.len()
    }

    /// Current used fraction of the allocatable pool, in [0, 1].
    pub fn utilization(&self) -> f64 {
        self.used_blocks() as f64 / (self.cfg.num_blocks - 1).max(1) as f64
    }

    /// High-water used fraction since construction/reset, in [0, 1].
    pub fn peak_utilization(&self) -> f64 {
        self.peak_used as f64 / (self.cfg.num_blocks - 1).max(1) as f64
    }

    pub fn ref_count(&self, block: usize) -> u32 {
        self.refs[block]
    }

    /// Cached prefix chains currently held.
    pub fn cached_chains(&self) -> usize {
        self.prefix.len()
    }

    pub fn retain(&mut self, block: usize) {
        debug_assert!(block != 0, "scratch block is not retainable");
        self.refs[block] += 1;
    }

    pub fn release(&mut self, block: usize) {
        debug_assert!(block != 0, "scratch block is not releasable");
        debug_assert!(self.refs[block] > 0, "double release of block {block}");
        self.refs[block] -= 1;
        if self.refs[block] == 0 {
            self.free.push(block);
        }
    }

    /// Allocate one block (ref count 1), evicting LRU cached chains when
    /// the free list is dry. `None` means genuinely exhausted — the
    /// scheduler preempts.
    pub fn alloc(&mut self) -> Option<usize> {
        loop {
            if let Some(b) = self.free.pop() {
                self.refs[b] = 1;
                self.peak_used = self.peak_used.max(self.used_blocks());
                return Some(b);
            }
            if !self.evict_one() {
                return None;
            }
        }
    }

    fn evict_one(&mut self) -> bool {
        while let Some(key) = self.lru.pop_front() {
            if let Some(entry) = self.prefix.remove(&key) {
                for b in entry.blocks {
                    self.release(b);
                }
                self.stats.evictions += 1;
                return true;
            }
        }
        false
    }

    // ---------------- prefix map ----------------

    /// Longest cached reuse for an effective (windowed) prompt. Retains
    /// every returned block for the caller. Misses (or sharing disabled)
    /// return `None`.
    pub fn lookup(&mut self, tokens: &[i32]) -> Option<PrefixHit> {
        if !self.cfg.prefix_sharing || tokens.is_empty() {
            return None;
        }
        self.stats.prefix_lookups += 1;
        let bl = self.cfg.block_len;
        // exact full-prompt hit first: blocks + cached logits
        let hf = chain_hash(TAG_FULL, tokens);
        if let Some(e) = self.prefix.get(&hf) {
            if e.tokens == tokens {
                if let Some(logits) = e.logits.clone() {
                    let blocks = e.blocks.clone();
                    for &b in &blocks {
                        self.retain(b);
                    }
                    self.touch(hf);
                    self.stats.prefix_hits += 1;
                    self.stats.full_hits += 1;
                    return Some(PrefixHit::Full { blocks, logits });
                }
            }
        }
        // longest full-block chain
        for j in (1..=tokens.len() / bl).rev() {
            let pfx = &tokens[..j * bl];
            let h = chain_hash(TAG_BLOCKS, pfx);
            if let Some(e) = self.prefix.get(&h) {
                if e.tokens == pfx {
                    let blocks = e.blocks.clone();
                    for &b in &blocks {
                        self.retain(b);
                    }
                    self.touch(h);
                    self.stats.prefix_hits += 1;
                    return Some(PrefixHit::Partial { blocks, covered: j * bl });
                }
            }
        }
        None
    }

    /// Register a freshly prefilled prompt's chain: one entry per
    /// full-block prefix depth plus a full-prompt entry carrying the
    /// prefill logits row. Each entry retains its blocks, so chains
    /// outlive the registering request until evicted.
    pub fn register(&mut self, tokens: &[i32], table: &[usize], logits: &[f32]) {
        if !self.cfg.prefix_sharing || tokens.is_empty() {
            return;
        }
        let bl = self.cfg.block_len;
        debug_assert_eq!(table.len(), tokens.len().div_ceil(bl), "table must cover the prompt");
        while self.prefix.len() >= PREFIX_CAP {
            if !self.evict_one() {
                break;
            }
        }
        for j in 1..=tokens.len() / bl {
            let pfx = &tokens[..j * bl];
            self.insert(chain_hash(TAG_BLOCKS, pfx), pfx, &table[..j], None);
        }
        self.insert(chain_hash(TAG_FULL, tokens), tokens, table, Some(logits.to_vec()));
    }

    fn insert(&mut self, key: u64, tokens: &[i32], blocks: &[usize], logits: Option<Vec<f32>>) {
        if self.prefix.contains_key(&key) {
            return; // first registration wins (incl. hash collisions)
        }
        for &b in blocks {
            self.retain(b);
        }
        self.prefix.insert(
            key,
            ChainEntry { tokens: tokens.to_vec(), blocks: blocks.to_vec(), logits },
        );
        self.lru.push_back(key);
    }

    fn touch(&mut self, key: u64) {
        if let Some(pos) = self.lru.iter().position(|&k| k == key) {
            self.lru.remove(pos);
            self.lru.push_back(key);
        }
    }

    // ---------------- data movement ----------------

    /// Copy-on-write: duplicate every layer's rows of `src` into a fresh
    /// block (ref 1). The caller swaps its table entry and releases its
    /// hold on `src`. `None` on exhaustion.
    pub fn cow_block(&mut self, src: usize) -> Result<Option<usize>> {
        let Some(dst) = self.alloc() else { return Ok(None) };
        let bl = self.cfg.block_len;
        let width = self.nkv * self.dh;
        for buf in &mut self.bufs {
            let t = host_mut(buf.as_mut().ok_or_else(|| {
                crate::anyhow!("kv pool buffers are checked out (mid decode step?)")
            })?)?;
            let (s, d) = (src * bl * width, dst * bl * width);
            let row = t.data[s..s + bl * width].to_vec();
            t.data[d..d + bl * width].copy_from_slice(&row);
        }
        self.stats.cow_copies += 1;
        Ok(Some(dst))
    }

    /// Splice one admitted request's prefill KV into its blocks: virtual
    /// positions `[from, n)` come from slot `slot` of the fresh prefill
    /// cache outputs (`(b, nkv, s_max, dh)` per layer, positions
    /// `pad_start + i` — the contiguous prefill's left-pad layout).
    pub fn write_prefill(
        &mut self,
        fresh: &[DeviceBuffer],
        slot: usize,
        pad_start: usize,
        n: usize,
        from: usize,
        table: &[usize],
    ) -> Result<()> {
        if fresh.len() != 2 * self.n_layers {
            return Err(crate::anyhow!(
                "expected {} prefill cache outputs, got {}",
                2 * self.n_layers,
                fresh.len()
            ));
        }
        let bl = self.cfg.block_len;
        let (nkv, dh) = (self.nkv, self.dh);
        let width = nkv * dh;
        for (l, src_buf) in fresh.iter().enumerate() {
            let src = host_ref(src_buf)?;
            let s_max = src.shape[2];
            let dst = host_mut(self.bufs[l].as_mut().ok_or_else(|| {
                crate::anyhow!("kv pool buffers are checked out (mid decode step?)")
            })?)?;
            for i in from..n {
                let blk = *table.get(i / bl).ok_or_else(|| {
                    crate::anyhow!("block table too short for prompt position {i}")
                })?;
                let prow = (blk * bl + i % bl) * width;
                for h in 0..nkv {
                    let s_off = ((slot * nkv + h) * s_max + pad_start + i) * dh;
                    dst.data[prow + h * dh..prow + (h + 1) * dh]
                        .copy_from_slice(&src.data[s_off..s_off + dh]);
                }
            }
        }
        Ok(())
    }

    /// Move the pool buffers out for one decode step (`kpool.0, vpool.0,
    /// …` order). Must be paired with [`KvPool::restore_bufs`]; a step
    /// that errors loses them — [`KvPool::reset`] rebuilds.
    pub fn take_bufs(&mut self) -> Result<Vec<DeviceBuffer>> {
        let mut out = Vec::with_capacity(self.bufs.len());
        for b in &mut self.bufs {
            out.push(b.take().ok_or_else(|| {
                crate::anyhow!("kv pool buffers already checked out (unbalanced take)")
            })?);
        }
        Ok(out)
    }

    pub fn restore_bufs(&mut self, bufs: Vec<DeviceBuffer>) {
        assert_eq!(bufs.len(), self.bufs.len(), "pool buffer count changed");
        for (slot, b) in self.bufs.iter_mut().zip(bufs) {
            *slot = Some(b);
        }
    }

    /// Whether the pool buffers are home (not checked out by a decode step
    /// and not lost to a failed one). When this is false the only way
    /// forward is [`KvPool::reset`].
    pub fn bufs_present(&self) -> bool {
        self.bufs.iter().all(Option::is_some)
    }

    /// Leak check: every block's ref count must be exactly what the prefix
    /// cache plus the scratch reservation account for (no request holds
    /// outstanding), the free list must contain exactly the zero-ref
    /// blocks, and the buffers must be home. The scheduler runs this at
    /// drop in debug builds, after draining — a failure means a
    /// completion/abort path leaked or double-released a block.
    pub fn assert_balanced(&self) {
        let mut expect = vec![0u32; self.cfg.num_blocks];
        expect[0] = 1; // scratch: permanently held
        for entry in self.prefix.values() {
            for &b in &entry.blocks {
                expect[b] += 1;
            }
        }
        for (b, (&got, &want)) in self.refs.iter().zip(&expect).enumerate() {
            assert_eq!(
                got, want,
                "kv pool unbalanced at block {b}: ref count {got}, \
                 but scratch + cached chains account for {want}"
            );
        }
        let mut free = self.free.clone();
        free.sort_unstable();
        let zero: Vec<usize> =
            (0..self.cfg.num_blocks).filter(|&b| self.refs[b] == 0).collect();
        assert_eq!(free, zero, "kv pool free list out of sync with ref counts");
        assert!(self.bufs_present(), "kv pool buffers not restored");
    }

    /// Drop every request/chain and rebuild zeroed buffers — the recovery
    /// path after an engine error consumed the in-flight pool state.
    pub fn reset(&mut self) {
        let rows = self.cfg.num_blocks * self.cfg.block_len;
        let width = self.nkv * self.dh;
        for b in &mut self.bufs {
            *b = Some(DeviceBuffer::Host(Value::F32(Tensor::zeros(&[rows, width]))));
        }
        self.refs.fill(0);
        self.refs[0] = 1;
        self.free = (1..self.cfg.num_blocks).rev().collect();
        self.prefix.clear();
        self.lru.clear();
        self.peak_used = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{model_by_name, Paths};

    fn pool(bl: usize, nb: usize, share: bool) -> KvPool {
        let paths = Paths::discover().unwrap();
        let cfg = model_by_name(&paths.configs, "micro-llama").unwrap();
        KvPool::new(&cfg, KvPoolCfg { block_len: bl, num_blocks: nb, prefix_sharing: share })
    }

    #[test]
    fn alloc_release_is_deterministic_and_scratch_reserved() {
        let mut p = pool(8, 4, false);
        assert_eq!(p.free_blocks(), 3);
        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        let c = p.alloc().unwrap();
        assert_eq!((a, b, c), (1, 2, 3), "ascending allocation order");
        assert!(p.alloc().is_none(), "pool exhausted");
        assert_eq!(p.used_blocks(), 3);
        assert!((p.utilization() - 1.0).abs() < 1e-12);
        p.release(b);
        assert_eq!(p.alloc().unwrap(), 2, "freed block comes back");
        p.retain(a);
        p.release(a);
        assert_eq!(p.free_blocks(), 0, "refcounted block stays held");
        p.release(a);
        assert_eq!(p.free_blocks(), 1);
    }

    #[test]
    fn prefix_chain_reuse_and_lru_eviction() {
        let mut p = pool(4, 6, true);
        // register a 2-block chain for an 8-token prompt
        let toks: Vec<i32> = (1..=8).collect();
        let b0 = p.alloc().unwrap();
        let b1 = p.alloc().unwrap();
        p.register(&toks, &[b0, b1], &[0.5; 4]);
        // the registering request releases its own holds
        p.release(b0);
        p.release(b1);
        assert_eq!(p.used_blocks(), 2, "cache keeps the chain alive");
        assert_eq!(p.cached_chains(), 3, "2 block-depth entries + 1 full entry");

        // exact full-prompt hit returns blocks + logits, retained
        match p.lookup(&toks).expect("full hit") {
            PrefixHit::Full { blocks, logits } => {
                assert_eq!(blocks, vec![b0, b1]);
                assert_eq!(logits, vec![0.5; 4]);
                for b in blocks {
                    p.release(b);
                }
            }
            PrefixHit::Partial { .. } => panic!("expected full hit"),
        }
        // longer prompt sharing the first block: partial hit at depth 1
        let longer: Vec<i32> = (1..=7).map(|x| if x <= 4 { x } else { 100 + x }).collect();
        match p.lookup(&longer).expect("partial hit") {
            PrefixHit::Partial { blocks, covered } => {
                assert_eq!(blocks, vec![b0]);
                assert_eq!(covered, 4);
                p.release(b0);
            }
            PrefixHit::Full { .. } => panic!("expected partial hit"),
        }
        // a different prompt misses
        assert!(p.lookup(&[9, 9, 9, 9]).is_none());
        assert_eq!(p.stats.prefix_lookups, 3);
        assert_eq!(p.stats.prefix_hits, 2);
        assert_eq!(p.stats.full_hits, 1);

        // exhaust the pool: allocation evicts cached chains to make room
        let mut got = Vec::new();
        while let Some(b) = p.alloc() {
            got.push(b);
        }
        assert_eq!(got.len(), 5, "eviction reclaimed the cached blocks");
        assert_eq!(p.cached_chains(), 0);
        assert!(p.stats.evictions > 0);
    }

    #[test]
    fn sharing_disabled_never_hits() {
        let mut p = pool(4, 4, false);
        let toks: Vec<i32> = (1..=4).collect();
        let b = p.alloc().unwrap();
        p.register(&toks, &[b], &[0.0; 2]);
        assert!(p.lookup(&toks).is_none());
        assert_eq!(p.cached_chains(), 0);
        assert_eq!(p.stats.prefix_lookups, 0);
    }

    #[test]
    fn reset_rebuilds_a_fresh_pool() {
        let mut p = pool(4, 4, true);
        let toks: Vec<i32> = (1..=4).collect();
        let b = p.alloc().unwrap();
        p.register(&toks, &[b], &[0.0; 2]);
        let taken = p.take_bufs().unwrap();
        assert!(p.take_bufs().is_err(), "double take must fail");
        drop(taken); // simulate a failed decode step losing the buffers
        p.reset();
        assert_eq!(p.free_blocks(), 3);
        assert_eq!(p.cached_chains(), 0);
        let bufs = p.take_bufs().unwrap();
        assert_eq!(bufs.len(), 2 * 2); // micro-llama: 2 layers × k/v
        p.restore_bufs(bufs);
    }

    #[test]
    fn assert_balanced_accepts_cache_holds_and_catches_leaks() {
        let mut p = pool(4, 6, true);
        p.assert_balanced(); // fresh pool is trivially balanced
        let toks: Vec<i32> = (1..=8).collect();
        let b0 = p.alloc().unwrap();
        let b1 = p.alloc().unwrap();
        p.register(&toks, &[b0, b1], &[0.5; 4]);
        p.release(b0);
        p.release(b1);
        // blocks live only through the prefix cache now: balanced
        p.assert_balanced();
        match p.lookup(&toks).expect("full hit") {
            PrefixHit::Full { blocks, .. } => {
                for b in blocks {
                    p.release(b);
                }
            }
            PrefixHit::Partial { .. } => panic!("expected full hit"),
        }
        p.assert_balanced();
    }

    #[test]
    #[should_panic(expected = "kv pool unbalanced")]
    fn assert_balanced_panics_on_leaked_request_hold() {
        let mut p = pool(4, 4, false);
        let _leaked = p.alloc().unwrap(); // never released, no chain owns it
        p.assert_balanced();
    }

    #[test]
    fn bufs_present_tracks_checkout() {
        let mut p = pool(4, 4, false);
        assert!(p.bufs_present());
        let taken = p.take_bufs().unwrap();
        assert!(!p.bufs_present());
        p.restore_bufs(taken);
        assert!(p.bufs_present());
    }

    #[test]
    fn from_env_defaults_are_sane() {
        let paths = Paths::discover().unwrap();
        let cfg = model_by_name(&paths.configs, "micro-llama").unwrap();
        let pc = KvPoolCfg::from_env(&cfg, 2);
        assert!(pc.block_len >= 1 && pc.block_len <= cfg.max_decode_seq);
        assert!(pc.num_blocks >= 2);
        // every slot must be able to reach max_decode_seq
        assert!(pc.blocks_per_seq(&cfg) * pc.block_len >= cfg.max_decode_seq);
        assert_eq!(pc.artifact_suffix(), format!("blk{}x{}", pc.block_len, pc.num_blocks));
    }
}
