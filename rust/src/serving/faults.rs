//! Seeded chaos harness for the serving resilience layer: a deterministic
//! [`FaultPlan`] schedules decode faults, prefill faults, pool-pressure
//! spikes, and latency stalls at specific scheduler steps. The scheduler
//! consumes the plan between steps (see `scheduler.rs`), so the same plan
//! against the same request trace produces the same fault sequence — and,
//! by the retry-determinism contract (DESIGN.md §5), the same final token
//! streams as a fault-free run.
//!
//! Grammar (`;`-separated events, each reusing the PR-5 `?k=v` helpers
//! from [`crate::compress::registry`]):
//!
//! ```text
//! plan    := event ( ';' event )*
//! event   := kind [ '@' arg ] [ '?' key '=' value ( '&' key '=' value )* ]
//! kind    := decode | prefill | spike | stall | rate
//! ```
//!
//! * `decode@S?count=N&every=K` — fail the decode at steps `S, S+K, …`
//!   (`N` times; defaults `count=1`, `every=1`).
//! * `prefill@S?count=N&every=K` — same, for the batched prefill.
//! * `spike@S?blocks=B&hold=H` — allocate `B` pool blocks at step `S` and
//!   hold them for `H` steps (defaults `blocks=1`, `hold=1`), simulating
//!   external memory pressure.
//! * `stall@S?ms=M` — sleep `M` ms before step `S` (default `ms=10`),
//!   simulating a latency hiccup.
//! * `rate@R?seed=X&until=T` — seeded Bernoulli decode fault with
//!   probability `R ∈ [0, 1]` at every step in `[0, T)` (defaults
//!   `seed=0`, `until=256`). Expanded to concrete steps at **parse time**
//!   with [`crate::data::Rng`], so the schedule is fully deterministic.
//!
//! Plans come from [`FaultPlan::parse`] or the `ARA_FAULT_PLAN` env knob
//! ([`FaultPlan::from_env`]); a malformed plan is a hard error naming the
//! offending event — chaos instrumentation must never half-apply.

use crate::compress::registry::{parse_query, Params};
use crate::data::Rng;
use crate::Result;

/// What an injected fault does to the step it fires on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// The batched decode step fails transiently (before the pool buffers
    /// are consumed — in-flight requests are re-queued per-slot).
    Decode,
    /// The batched prefill fails transiently (only the requests being
    /// admitted that step are affected; active slots keep decoding).
    Prefill,
    /// Hold `blocks` pool blocks for `hold` steps (pool-pressure spike).
    Spike { blocks: usize, hold: usize },
    /// Sleep `ms` milliseconds before the step (latency stall).
    Stall { ms: u64 },
}

/// One scheduled fault.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultEvent {
    /// Scheduler step index ([`super::SchedStats::steps`]) the fault
    /// fires on.
    pub step: usize,
    pub kind: FaultKind,
}

/// A deterministic fault schedule, consumed front-to-back as the
/// scheduler's step counter advances.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    /// Expanded schedule, stably sorted by step (events for the same step
    /// fire in spec order).
    events: Vec<FaultEvent>,
    next: usize,
}

/// Seed-domain tag so `rate@R?seed=X` draws an independent stream from any
/// other `Rng::new(X)` user.
const RATE_SEED_TAG: u64 = 0x6661_756c_7470_6c6e; // "faultpln"

impl FaultPlan {
    /// Parse a plan spec; errors name the offending event.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut events: Vec<FaultEvent> = Vec::new();
        for ev in spec.split(';') {
            let ev = ev.trim();
            if ev.is_empty() {
                return Err(crate::anyhow!("bad fault plan `{spec}`: empty event"));
            }
            let (head, query) = match ev.split_once('?') {
                Some((h, q)) => (h, Some(q)),
                None => (ev, None),
            };
            let (kind, arg) = match head.split_once('@') {
                Some((k, a)) => (k, Some(a)),
                None => (head, None),
            };
            let step_arg = |what: &str| -> Result<usize> {
                match arg {
                    None => Ok(0),
                    Some(a) => a.parse::<usize>().map_err(|_| {
                        crate::anyhow!(
                            "bad fault event `{ev}`: {what} `{a}` is not a non-negative integer"
                        )
                    }),
                }
            };
            let params = match query {
                Some(q) => parse_query(ev, q)?,
                None => Vec::new(),
            };
            let mut p = Params::new(ev, params);
            match kind {
                "decode" | "prefill" => {
                    let step = step_arg("step")?;
                    let count = p.usize("count")?.unwrap_or(1);
                    let every = p.usize("every")?.unwrap_or(1).max(1);
                    p.finish(&["count", "every"])?;
                    let k = if kind == "decode" { FaultKind::Decode } else { FaultKind::Prefill };
                    for i in 0..count {
                        events.push(FaultEvent { step: step + i * every, kind: k });
                    }
                }
                "spike" => {
                    let step = step_arg("step")?;
                    let blocks = p.usize("blocks")?.unwrap_or(1);
                    let hold = p.usize("hold")?.unwrap_or(1).max(1);
                    p.finish(&["blocks", "hold"])?;
                    events.push(FaultEvent { step, kind: FaultKind::Spike { blocks, hold } });
                }
                "stall" => {
                    let step = step_arg("step")?;
                    let ms = p.u64("ms")?.unwrap_or(10);
                    p.finish(&["ms"])?;
                    events.push(FaultEvent { step, kind: FaultKind::Stall { ms } });
                }
                "rate" => {
                    let r: f64 = match arg {
                        None => {
                            return Err(crate::anyhow!(
                                "bad fault event `{ev}`: `rate` needs a probability (rate@R)"
                            ))
                        }
                        Some(a) => a.parse().map_err(|_| {
                            crate::anyhow!("bad fault event `{ev}`: rate `{a}` is not a number")
                        })?,
                    };
                    if !r.is_finite() || !(0.0..=1.0).contains(&r) {
                        return Err(crate::anyhow!(
                            "bad fault event `{ev}`: rate {r} outside [0, 1]"
                        ));
                    }
                    let seed = p.u64("seed")?.unwrap_or(0);
                    let until = p.usize("until")?.unwrap_or(256);
                    p.finish(&["seed", "until"])?;
                    let mut rng = Rng::new(seed ^ RATE_SEED_TAG);
                    for step in 0..until {
                        if rng.f64() < r {
                            events.push(FaultEvent { step, kind: FaultKind::Decode });
                        }
                    }
                }
                other => {
                    return Err(crate::anyhow!(
                        "bad fault event `{ev}`: unknown kind `{other}` \
                         (known: decode, prefill, spike, stall, rate)"
                    ));
                }
            }
        }
        events.sort_by_key(|e| e.step);
        Ok(FaultPlan { events, next: 0 })
    }

    /// The plan named by `ARA_FAULT_PLAN`, if set. A malformed spec is an
    /// `Err`, never a silently-ignored knob.
    pub fn from_env() -> Result<Option<FaultPlan>> {
        match std::env::var("ARA_FAULT_PLAN") {
            Ok(s) if !s.trim().is_empty() => Self::parse(s.trim()).map(Some),
            _ => Ok(None),
        }
    }

    /// Scheduled events not yet consumed.
    pub fn remaining(&self) -> usize {
        self.events.len() - self.next
    }

    /// Pop every event scheduled at or before `step`, in schedule order.
    /// Consumption is monotone: a popped event never fires again, and
    /// events whose step was skipped (the scheduler went idle) fire on the
    /// next step taken.
    pub fn events_at(&mut self, step: usize) -> Vec<FaultKind> {
        let mut out = Vec::new();
        while self.next < self.events.len() && self.events[self.next].step <= step {
            out.push(self.events[self.next].kind);
            self.next += 1;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_expands_counts_and_sorts() {
        let mut p = FaultPlan::parse("decode@4?count=3&every=2;stall@1?ms=5;spike@4?blocks=2")
            .unwrap();
        assert_eq!(p.remaining(), 5);
        assert_eq!(p.events_at(0), vec![]);
        assert_eq!(p.events_at(1), vec![FaultKind::Stall { ms: 5 }]);
        // same-step events fire in spec order (decode listed before spike)
        assert_eq!(
            p.events_at(4),
            vec![FaultKind::Decode, FaultKind::Spike { blocks: 2, hold: 1 }]
        );
        assert_eq!(p.events_at(5), vec![]);
        assert_eq!(p.events_at(6), vec![FaultKind::Decode]);
        assert_eq!(p.events_at(100), vec![FaultKind::Decode]);
        assert_eq!(p.remaining(), 0);
    }

    #[test]
    fn skipped_steps_still_fire_their_events() {
        let mut p = FaultPlan::parse("decode@2;prefill@3").unwrap();
        // scheduler idled past steps 2 and 3: both fire on the next step
        assert_eq!(p.events_at(10), vec![FaultKind::Decode, FaultKind::Prefill]);
        assert_eq!(p.remaining(), 0);
    }

    #[test]
    fn rate_expansion_is_seeded_and_deterministic() {
        let a = FaultPlan::parse("rate@0.5?seed=7&until=64").unwrap();
        let b = FaultPlan::parse("rate@0.5?seed=7&until=64").unwrap();
        assert_eq!(a, b, "same spec must expand to the same schedule");
        assert!(a.remaining() > 0, "rate 0.5 over 64 steps fires ~32 times");
        assert!(a.remaining() < 64);
        assert_eq!(FaultPlan::parse("rate@0?until=64").unwrap().remaining(), 0);
        assert_eq!(FaultPlan::parse("rate@1?until=16").unwrap().remaining(), 16);
    }

    #[test]
    fn errors_name_the_event() {
        for bad in [
            "decode@x",
            "flaky@3",
            "rate@1.5",
            "rate@nan",
            "rate",
            "decode@3?count=x",
            "decode@3?bogus=1",
            "spike@1?blocks=2&blocks=3",
            "",
            "decode@1;;decode@2",
        ] {
            let err = FaultPlan::parse(bad).unwrap_err().to_string();
            assert!(
                err.contains("fault") || err.contains("spec"),
                "error for `{bad}` should be diagnosable: {err}"
            );
        }
        // unknown-parameter errors name the event and the allowed set
        let err = FaultPlan::parse("stall@2?mss=4").unwrap_err().to_string();
        assert!(err.contains("stall@2?mss=4"), "{err}");
        assert!(err.contains("ms"), "{err}");
    }

    #[test]
    fn defaults_are_applied() {
        let mut p = FaultPlan::parse("decode;stall@3").unwrap();
        assert_eq!(p.events_at(0), vec![FaultKind::Decode]);
        assert_eq!(p.events_at(3), vec![FaultKind::Stall { ms: 10 }]);
    }
}
