//! Dynamic batcher: groups incoming requests into fixed-size engine batches
//! (the AOT decode executables are compiled per batch size), padding partial
//! batches with dummy prompts and choosing the largest compiled batch size
//! that the queue can fill — the vLLM-style policy at static-shape scale.

/// A planned batch: request indices + padded slot count.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BatchPlan {
    /// Indices into the pending queue (missing slots are padding).
    pub requests: Vec<usize>,
    /// The engine batch size to use.
    pub batch: usize,
}

/// Batching policy over the compiled batch sizes.
#[derive(Debug, Clone)]
pub struct DynamicBatcher {
    /// Available engine batch sizes (ascending, from the model config).
    sizes: Vec<usize>,
    /// Max padding fraction tolerated before falling back to a smaller size.
    pub max_pad_frac: f64,
}

impl DynamicBatcher {
    pub fn new(mut sizes: Vec<usize>) -> DynamicBatcher {
        sizes.sort_unstable();
        assert!(!sizes.is_empty(), "need at least one compiled batch size");
        DynamicBatcher { sizes, max_pad_frac: 0.5 }
    }

    /// Plan batches for `pending` queued requests (returns plans covering
    /// all of them; the tail batch may be padded).
    pub fn plan(&self, pending: usize) -> Vec<BatchPlan> {
        let mut plans = Vec::new();
        let mut next = 0usize;
        let mut left = pending;
        while left > 0 {
            let b = self.pick(left);
            let take = left.min(b);
            plans.push(BatchPlan {
                requests: (next..next + take).collect(),
                batch: b,
            });
            next += take;
            left -= take;
        }
        plans
    }

    /// Largest compiled size fully fillable; otherwise the smallest size
    /// whose padding stays under `max_pad_frac`, otherwise the smallest.
    fn pick(&self, queued: usize) -> usize {
        if let Some(&b) = self.sizes.iter().rev().find(|&&b| b <= queued) {
            return b;
        }
        for &b in &self.sizes {
            let pad = (b - queued) as f64 / b as f64;
            if pad <= self.max_pad_frac {
                return b;
            }
        }
        self.sizes[0]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fills_largest_first() {
        let b = DynamicBatcher::new(vec![1, 2, 4]);
        let plans = b.plan(7);
        assert_eq!(plans[0].batch, 4);
        assert_eq!(plans[0].requests, vec![0, 1, 2, 3]);
        assert_eq!(plans[1].batch, 2);
        // last request: batch 1, no padding
        assert_eq!(plans[2].batch, 1);
        let covered: usize = plans.iter().map(|p| p.requests.len()).sum();
        assert_eq!(covered, 7);
    }

    #[test]
    fn pads_within_tolerance() {
        let b = DynamicBatcher::new(vec![4]);
        let plans = b.plan(3);
        assert_eq!(plans.len(), 1);
        assert_eq!(plans[0].batch, 4);
        assert_eq!(plans[0].requests.len(), 3); // one padded slot
    }

    #[test]
    fn empty_queue_no_plans() {
        let b = DynamicBatcher::new(vec![1, 2]);
        assert!(b.plan(0).is_empty());
    }

    #[test]
    fn single_request_uses_smallest() {
        let b = DynamicBatcher::new(vec![1, 2, 4]);
        let plans = b.plan(1);
        assert_eq!(plans.len(), 1);
        assert_eq!(plans[0].batch, 1);
    }
}
