//! Per-module mask state: compression ratio (Eq. 3), binary mask (Eq. 4),
//! and the R ≥ 1 → dense computation-flow switch (Eq. 8).

use crate::model::ModuleDim;
use crate::tensor::Tensor;

/// Everything derived from one module's probabilistic mask at one step.
#[derive(Debug, Clone)]
pub struct MaskState {
    /// Probabilistic mask p (Eq. 2), length r_full.
    pub p: Vec<f64>,
    /// Compression ratio R = (Σp)(m+n)/(mn) — may exceed 1 (Sec. 3.3).
    pub ratio: f64,
    /// Retained rank ⌊R·r⌋ (clamped to [1, r]); meaningful when R < 1.
    pub k: usize,
    /// R ≥ 1: the module runs (and is counted) as the dense matrix.
    pub dense: bool,
}

/// Eq. 3: R = (Σ_i p_i)(m+n)/(mn).
pub fn module_ratio(dim: &ModuleDim, p: &[f64]) -> f64 {
    let sum: f64 = p.iter().sum();
    sum * (dim.m + dim.n) as f64 / (dim.m as f64 * dim.n as f64)
}

/// Eq. 4 + Eq. 8: binary mask over the full rank; all-ones when dense.
///
/// Rank conversion: the retained rank equals the probability mass,
/// k = round(Σp) — the binary mask then stores k(m+n) parameters, exactly
/// the expected parameter count of the probabilistic mask, so R (Eq. 3) is
/// consistent between the two. (Eq. 4 as literally printed, k = ⌊R·r⌋, is
/// dimensionally inconsistent for square modules — R = 1 would retain the
/// full rank at 2× the dense parameter count; see DESIGN.md §7.)
pub fn binary_mask(dim: &ModuleDim, p: &[f64]) -> MaskState {
    let r = dim.r_full();
    assert_eq!(p.len(), r);
    let ratio = module_ratio(dim, p);
    let dense = ratio >= 1.0;
    let sum: f64 = p.iter().sum();
    let k = (sum.round() as usize).clamp(1, r);
    MaskState { p: p.to_vec(), ratio, k, dense }
}

impl MaskState {
    /// The f32 mask tensor fed to the AOT executable: all-ones in the dense
    /// regime (numerically identical to W at full rank), top-k otherwise.
    pub fn mask_tensor(&self, dim: &ModuleDim) -> Tensor {
        let r = dim.r_full();
        let mut t = Tensor::zeros(&[r]);
        let k = if self.dense { r } else { self.k };
        for i in 0..k {
            t.data[i] = 1.0;
        }
        t
    }

    /// Parameters this module contributes under Eq. 8 accounting:
    /// dense ⇒ mn, factored ⇒ k(m+n).
    pub fn params(&self, dim: &ModuleDim) -> usize {
        if self.dense {
            dim.dense_params()
        } else {
            dim.factored_params(self.k)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dim(m: usize, n: usize) -> ModuleDim {
        ModuleDim { name: "t".into(), m, n }
    }

    #[test]
    fn ratio_formula() {
        let d = dim(10, 10);
        let p = vec![1.0; 10]; // Σp = 10 ⇒ R = 10·20/100 = 2
        assert!((module_ratio(&d, &p) - 2.0).abs() < 1e-12);
        let p = vec![0.5; 10]; // R = 1
        assert!((module_ratio(&d, &p) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn full_simplex_mask_exceeds_one() {
        // with α on the simplex, p_1 = 1 … always Σp ≥ 1, and for square
        // modules R ≥ (m+n)/(mn) — the R_max > 1 range needs Σp ≥ mn/(m+n),
        // reachable since Σp can approach r > mn/(m+n).
        let d = dim(8, 8);
        let p = vec![1.0; 8];
        let st = binary_mask(&d, &p);
        assert!(st.dense);
        assert_eq!(st.mask_tensor(&d).data.iter().sum::<f32>() as usize, 8);
        assert_eq!(st.params(&d), 64);
    }

    #[test]
    fn low_ratio_masks_topk() {
        let d = dim(16, 16);
        let mut p = vec![0.0; 16];
        p[0] = 1.0;
        p[1] = 1.0; // Σp = 2 ⇒ R = 2·32/256 = 0.25, retained rank k = Σp = 2
        let st = binary_mask(&d, &p);
        assert!(!st.dense);
        assert_eq!(st.k, 2);
        let m = st.mask_tensor(&d);
        assert_eq!(&m.data[..2], &[1.0, 1.0]);
        assert!(m.data[2..].iter().all(|&x| x == 0.0));
        assert_eq!(st.params(&d), 2 * 32);
        // storage consistency: k(m+n) = R·mn
        assert!((st.params(&d) as f64 - st.ratio * 256.0).abs() < 1e-9);
    }

    #[test]
    fn k_clamped_to_at_least_one() {
        let d = dim(16, 16);
        let st = binary_mask(&d, &vec![0.0; 16]);
        assert_eq!(st.k, 1);
    }

    #[test]
    fn params_discontinuity_at_one() {
        // crossing R=1 flips to the dense branch: equal-or-cheaper storage
        // but the *exact* matrix instead of a rank-r/2 approximation — the
        // paper's non-smooth gain, expressed in quality at equal bytes.
        let d = dim(12, 12);
        let p_lo = vec![0.49; 12]; // R ≈ 0.98 → factored, k = 6
        let p_hi = vec![0.51; 12]; // R ≈ 1.02 → dense
        let lo = binary_mask(&d, &p_lo);
        let hi = binary_mask(&d, &p_hi);
        assert!(!lo.dense && hi.dense);
        assert!(hi.params(&d) <= lo.params(&d) + (d.m + d.n));
        // dense mask enables everything; factored keeps only k
        assert_eq!(hi.mask_tensor(&d).data.iter().sum::<f32>() as usize, 12);
        assert_eq!(lo.mask_tensor(&d).data.iter().sum::<f32>() as usize, lo.k);
    }
}
