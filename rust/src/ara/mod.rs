//! The paper's contribution: Adaptive Rank Allocation (Sec. 3, Alg. 1).
//!
//! * [`Staircase`] — the mapping matrix M and the monotone probabilistic
//!   mask p = α·M (Eq. 2) with its exact STE chain rule (Eq. 5);
//! * [`binary_mask`] / [`module_ratio`] — per-module compression ratio R
//!   (Eq. 3) and binary mask (Eq. 4), including the R ≥ 1 → dense switch
//!   (Eq. 8);
//! * [`guidance_metric`] / [`guidance_loss`] — the full-rank guidance
//!   metric G_R and loss L_g (Eq. 6–7) that exploits the non-smooth gain
//!   at R = 1;
//! * [`MaskGradRunner`] — shared executor of the AOT `mask_fwd_grad`
//!   graph (also used by the ARS / Dobi-SVD₁ baselines so all mask
//!   methods train through the identical loss surface);
//! * [`train_ara`] — the joint objective (Eq. 9), AdamW over the simplex
//!   vectors α, and the final proportional rescale (Alg. 1 step 26);
//! * [`rescale_to_target`] — bisection water-filling that meets the
//!   target ratio exactly while honoring the dense cap.

mod guidance;
mod masks;
mod rescale;
mod runner;
mod staircase;
mod trainer;

pub use guidance::{guidance_loss, guidance_metric};
pub use masks::{binary_mask, module_ratio, MaskState};
pub use rescale::rescale_to_target;
pub use runner::MaskGradRunner;
pub use staircase::Staircase;
pub use trainer::{train_ara, AraConfig, AraTrace};
