//! The ARA allocation trainer (Alg. 1): joint objective
//! L = L_m + λ₁·L_g + λ₂·L_c (Eq. 9) optimized over per-module simplex
//! vectors α with AdamW + simplex projection, STE through the binary masks,
//! and the final proportional rescale.

use std::collections::BTreeMap;

use super::guidance::guidance_loss;
use super::masks::binary_mask;
use super::rescale::rescale_to_target;
use super::runner::MaskGradRunner;
use super::staircase::Staircase;
use crate::config::ModelCfg;
use crate::linalg::project_simplex;
use crate::model::{Allocation, WeightStore};
use crate::runtime::Runtime;
use crate::svd::FactoredModel;
use crate::training::{AdamW, AdamWConfig};
use crate::Result;

/// Hyperparameters (paper defaults: λ₁ = λ₂ = 100, D = 100, lr = 1e-3,
/// 10 epochs × 256 samples; D and counts scale with the model size here).
#[derive(Debug, Clone)]
pub struct AraConfig {
    pub target: f64,
    pub lambda1: f64,
    pub lambda2: f64,
    pub d: usize,
    pub epochs: usize,
    pub samples: usize,
    pub lr: f64,
    pub seed: u64,
    /// Disable L_g (the Table 5 / Fig. 4(b) ablation).
    pub use_guidance: bool,
    pub corpus: String,
    pub verbose: bool,
    /// Plain projected SGD on α (preserves cross-module gradient magnitude,
    /// which AdamW's per-coordinate normalization erases — important at our
    /// scaled step counts; see EXPERIMENTS.md §Perf notes).
    pub sgd: bool,
}

impl Default for AraConfig {
    fn default() -> Self {
        AraConfig {
            target: 0.8,
            lambda1: 100.0,
            lambda2: 100.0,
            d: 16,
            epochs: 10,
            samples: 64,
            // the paper's 1e-3 is tuned for thousands of allocation steps
            // on 7B models; our scaled recipes run ~10² steps, so the α
            // step size is raised to keep total simplex movement comparable
            // (override with ARA_ALLOC_LR for ablations)
            lr: std::env::var("ARA_ALLOC_LR")
                .ok()
                .and_then(|v| v.parse().ok())
                .unwrap_or(5e-2),
            seed: 7,
            use_guidance: true,
            corpus: "sync4".to_string(),
            verbose: false,
            sgd: std::env::var("ARA_ALLOC_SGD").map(|v| v != "0").unwrap_or(true),
        }
    }
}

/// Training trace for analysis benches (Fig. 4, Fig. 7).
#[derive(Debug, Clone, Default)]
pub struct AraTrace {
    /// (epoch, mean CE loss, achieved soft ratio, dense-module count)
    pub epochs: Vec<(usize, f64, f64, usize)>,
    /// Final learned per-module ratios (pre-rescale).
    pub final_ratios: BTreeMap<String, f64>,
}

/// Run ARA allocation training; returns the final allocation + trace.
pub fn train_ara(
    cfg: &ModelCfg,
    rt: &Runtime,
    ws: &WeightStore,
    fm: &FactoredModel,
    ac: &AraConfig,
) -> Result<(Allocation, AraTrace)> {
    let runner = MaskGradRunner::new(cfg, rt, ws, fm, &ac.corpus, ac.samples, ac.seed)?;
    let dims = runner.dims.clone();
    let n_mods = dims.len();
    let total_c: f64 = dims.iter().map(|d| d.dense_params() as f64).sum();

    // per-module staircases; α starts at the uniform-equivalent rank (the
    // same operating point every baseline starts from) so the learned
    // deviation is the allocation signal, not an initialization artifact
    let stairs: Vec<Staircase> =
        dims.iter().map(|d| Staircase::new(ac.d, d.r_full())).collect();
    let mut alphas: Vec<Vec<f64>> = dims
        .iter()
        .zip(&stairs)
        .map(|(d, st)| {
            let k_init = ((ac.target * d.dense_params() as f64 / (d.m + d.n) as f64)
                .round() as usize)
                .clamp(1, d.r_full());
            st.init_alpha(k_init)
        })
        .collect();

    let mut opt = AdamW::new(AdamWConfig {
        lr: ac.lr,
        weight_decay: 0.0, // α lives on the simplex; decay would fight it
        ..Default::default()
    });

    let steps_per_epoch = runner.batches_per_epoch();
    let mut trace = AraTrace::default();

    for epoch in 0..ac.epochs {
        let mut epoch_loss = 0.0;
        for step in 0..steps_per_epoch {
            // 1. masks + ratios from current α (Eq. 2–4, 8)
            let mut masks = BTreeMap::new();
            let mut states = Vec::with_capacity(n_mods);
            for (i, d) in dims.iter().enumerate() {
                let p = stairs[i].prob_mask(&alphas[i]);
                let st = binary_mask(d, &p);
                masks.insert(d.name.clone(), st.mask_tensor(d));
                states.push(st);
            }

            // 2. CE loss + ∂L/∂mask from the AOT graph
            let (loss, dmasks) = runner.step(&masks, epoch * steps_per_epoch + step)?;
            epoch_loss += loss;

            // 3. soft achieved ratio for L_c: Σ min(R_l, 1)·mn / C_t
            let achieved: f64 = dims
                .iter()
                .zip(&states)
                .map(|(d, st)| st.ratio.min(1.0) * d.dense_params() as f64)
                .sum::<f64>()
                / total_c;
            let dlc_dach = 2.0 * (achieved - ac.target); // d(L_c)/d(achieved)

            // 4. assemble dL/dα per module and update
            opt.step();
            for (i, d) in dims.iter().enumerate() {
                let st = &states[i];
                let r = d.r_full();
                let dr_dp = (d.m + d.n) as f64 / (d.m as f64 * d.n as f64); // ∂R/∂p_i

                // CE term via STE (Eq. 5)
                let mut dp = dmasks[&d.name].clone();

                // guidance term (only while compressible, Eq. 7)
                if ac.use_guidance {
                    let (_lg, dlg_dr) = guidance_loss(d, &fm.factors[&d.name], st.ratio);
                    if dlg_dr != 0.0 {
                        let c = ac.lambda1 / n_mods as f64 * dlg_dr * dr_dp;
                        for x in dp.iter_mut() {
                            *x += c;
                        }
                    }
                }

                // compression-ratio term: ∂achieved/∂R_l = mn_l/C_t when R<1
                if st.ratio < 1.0 {
                    let c = ac.lambda2
                        * dlc_dach
                        * (d.dense_params() as f64 / total_c)
                        * dr_dp;
                    for x in dp.iter_mut() {
                        *x += c;
                    }
                }

                debug_assert_eq!(dp.len(), r);
                let dalpha = stairs[i].chain_grad(&dp);
                if ac.sgd {
                    for (a, g) in alphas[i].iter_mut().zip(&dalpha) {
                        *a -= ac.lr * g;
                    }
                } else {
                    opt.update_f64(&d.name, &mut alphas[i], &dalpha, 1.0);
                }
                project_simplex(&mut alphas[i]);
            }
        }

        // epoch summary
        let mut dense_count = 0;
        let mut achieved = 0.0;
        for (i, d) in dims.iter().enumerate() {
            let p = stairs[i].prob_mask(&alphas[i]);
            let st = binary_mask(d, &p);
            if st.dense {
                dense_count += 1;
            }
            achieved += st.ratio.min(1.0) * d.dense_params() as f64;
        }
        achieved /= total_c;
        let mean_loss = epoch_loss / steps_per_epoch as f64;
        if ac.verbose {
            eprintln!(
                "[ara {}] epoch {epoch} loss {mean_loss:.4} ratio {achieved:.3} dense {dense_count}/{n_mods}",
                cfg.name
            );
        }
        trace.epochs.push((epoch, mean_loss, achieved, dense_count));
    }

    // final ratios → proportional rescale to hit the target exactly
    let mut ratios = Vec::with_capacity(n_mods);
    for (i, d) in dims.iter().enumerate() {
        let p = stairs[i].prob_mask(&alphas[i]);
        let st = binary_mask(d, &p);
        trace.final_ratios.insert(d.name.clone(), st.ratio);
        ratios.push(st.ratio);
    }
    let alloc = rescale_to_target(
        &dims,
        &ratios,
        ac.target,
        &format!("ara-{}", (ac.target * 100.0).round() as usize),
    );
    Ok((alloc, trace))
}
