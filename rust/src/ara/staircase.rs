//! The staircase mapping matrix M ∈ {0,1}^{D×r} (Eq. 2 and Appendix A.5).
//!
//! Column i has v_i ones (bottom-aligned), v non-increasing with v_1 = D
//! and v_r = 1, so p = α·M is non-increasing whenever α ≥ 0 — the
//! monotonicity property — and ∂p_i/∂α_j = 1[j ≥ D − v_i] gives every α_j
//! a global, non-vanishing influence (the paper's fix for tanh locality).
//! M is never materialized: both the mask map and its transpose-chain are
//! O(D + r) via the v vector.

/// Staircase structure for one module: D trainable parameters over r ranks.
#[derive(Debug, Clone)]
pub struct Staircase {
    pub d: usize,
    pub r: usize,
    /// v[i] = number of ones in column i (non-increasing, v[0]=D, v[r-1]=1).
    v: Vec<usize>,
}

impl Staircase {
    pub fn new(d: usize, r: usize) -> Staircase {
        assert!(d >= 1 && r >= 1);
        let mut v = Vec::with_capacity(r);
        for i in 0..r {
            // linear descent from D to 1 across columns (every ~r/D columns
            // share a step, per Appendix A.5)
            let frac = (r - i) as f64 / r as f64;
            let vi = (frac * d as f64).ceil() as usize;
            v.push(vi.clamp(1, d));
        }
        v[0] = d;
        if r > 1 {
            v[r - 1] = 1; // r = 1 keeps v = [D]: the lone column sums all of α
        }
        // enforce non-increasing (ceil rounding can create tiny bumps)
        for i in 1..r {
            if v[i] > v[i - 1] {
                v[i] = v[i - 1];
            }
        }
        Staircase { d, r, v }
    }

    /// p = α·M: p_i = Σ_{j ≥ D−v_i} α_j (suffix sums of α).
    pub fn prob_mask(&self, alpha: &[f64]) -> Vec<f64> {
        assert_eq!(alpha.len(), self.d);
        // suffix[j] = Σ_{t ≥ j} α_t
        let mut suffix = vec![0.0; self.d + 1];
        for j in (0..self.d).rev() {
            suffix[j] = suffix[j + 1] + alpha[j];
        }
        self.v.iter().map(|&vi| suffix[self.d - vi]).collect()
    }

    /// Chain rule (Eq. 5): dL/dα_j = Σ_i 1[j ≥ D−v_i]·dL/dp_i.
    /// Column i contributes to the suffix starting at D−v_i, so we scatter
    /// into prefix-difference form and integrate.
    pub fn chain_grad(&self, dmask: &[f64]) -> Vec<f64> {
        assert_eq!(dmask.len(), self.r);
        let mut start_acc = vec![0.0; self.d + 1];
        for (i, &g) in dmask.iter().enumerate() {
            start_acc[self.d - self.v[i]] += g;
        }
        // dα_j = Σ over columns whose start ≤ j  ⇒ prefix sum
        let mut out = vec![0.0; self.d];
        let mut run = 0.0;
        for j in 0..self.d {
            run += start_acc[j];
            out[j] = run;
        }
        out
    }

    /// Column heights (for inspection / tests).
    pub fn heights(&self) -> &[usize] {
        &self.v
    }

    /// α initialization targeting retained rank `k_init`: a near-delta at
    /// the staircase step whose suffix covers exactly the first ~k columns
    /// (so p ≈ 1[i < k]), mixed with 10% uniform mass for gradient flow —
    /// the analogue of Dobi starting its boundary at the target rank.
    pub fn init_alpha(&self, k_init: usize) -> Vec<f64> {
        let k = k_init.clamp(1, self.r);
        // p_i = 1 for all i with v_i ≥ D − j*; pick j* from the height at
        // the first column we want OFF.
        let v_off = if k < self.r { self.v[k] } else { 1 };
        let jstar = (self.d - v_off).min(self.d - 1);
        let mut a = vec![0.1 / self.d as f64; self.d];
        a[jstar] += 0.9;
        let s: f64 = a.iter().sum();
        for x in a.iter_mut() {
            *x /= s;
        }
        a
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;

    #[test]
    fn boundary_heights() {
        for (d, r) in [(4, 8), (16, 48), (100, 64), (3, 3), (1, 5), (8, 1)] {
            let s = Staircase::new(d, r);
            let v = s.heights();
            assert_eq!(v[0], d);
            if r > 1 {
                assert_eq!(v[r - 1], 1);
            }
            for i in 1..r {
                assert!(v[i] <= v[i - 1], "heights must be non-increasing");
            }
        }
    }

    #[test]
    fn mask_is_monotone_for_nonneg_alpha() {
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            let d = 1 + rng.below(20);
            let r = 1 + rng.below(40);
            let s = Staircase::new(d, r);
            let mut alpha: Vec<f64> = (0..d).map(|_| rng.f64()).collect();
            let sum: f64 = alpha.iter().sum();
            alpha.iter_mut().for_each(|x| *x /= sum);
            let p = s.prob_mask(&alpha);
            for i in 1..r {
                assert!(p[i - 1] >= p[i] - 1e-12, "monotonicity violated");
            }
            // simplex α ⇒ p_1 = 1 (v_1 = D: sums all of α)
            assert!((p[0] - 1.0).abs() < 1e-9);
            assert!(p[r - 1] >= 0.0 && p[r - 1] <= 1.0);
        }
    }

    #[test]
    fn mask_matches_dense_matrix_multiply() {
        let mut rng = Rng::new(2);
        let s = Staircase::new(7, 13);
        let alpha: Vec<f64> = (0..7).map(|_| rng.f64()).collect();
        let p = s.prob_mask(&alpha);
        // dense M: M[j][i] = 1 iff j >= D - v_i
        for i in 0..13 {
            let mut want = 0.0;
            for j in 0..7 {
                if j >= 7 - s.heights()[i] {
                    want += alpha[j];
                }
            }
            assert!((p[i] - want).abs() < 1e-12);
        }
    }

    #[test]
    fn chain_grad_is_transpose_of_forward() {
        // <chain_grad(g), α> must equal <g, prob_mask(α)> for all α, g
        // (adjoint identity — the exact STE chain of Eq. 5).
        let mut rng = Rng::new(3);
        for _ in 0..30 {
            let d = 1 + rng.below(15);
            let r = 1 + rng.below(30);
            let s = Staircase::new(d, r);
            let alpha: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
            let g: Vec<f64> = (0..r).map(|_| rng.normal()).collect();
            let lhs: f64 = s.chain_grad(&g).iter().zip(&alpha).map(|(a, b)| a * b).sum();
            let rhs: f64 = g.iter().zip(&s.prob_mask(&alpha)).map(|(a, b)| a * b).sum();
            assert!((lhs - rhs).abs() < 1e-9, "adjoint identity violated");
        }
    }

    #[test]
    fn every_alpha_has_global_influence() {
        // the anti-tanh property: each α_j influences a contiguous top
        // segment of p, and α_{D-1} influences every p_i.
        let s = Staircase::new(5, 10);
        let g = vec![1.0; 10];
        let da = s.chain_grad(&g);
        assert!(da.iter().all(|&x| x > 0.0));
        // later α entries touch more columns
        for j in 1..5 {
            assert!(da[j] >= da[j - 1]);
        }
        assert_eq!(da[4], 10.0); // α_D contributes to every column
    }
}
