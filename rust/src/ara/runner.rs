//! Shared executor for mask-training methods: wraps the AOT `mask_fwd_grad`
//! executable with the calibration batches, returning (loss, ∂L/∂mask per
//! module). ARA, ARS and Dobi-SVD₁ all train through this single interface,
//! which is what makes the Table 5 mask-ablation a controlled comparison.

use std::collections::{BTreeMap, HashMap};

use crate::config::ModelCfg;
use crate::data::{batches, corpus_spec, generate_tokens};
use crate::model::{module_dims, ModuleDim, WeightStore};
use crate::runtime::{Feed, Runtime};
use crate::svd::{factored_feeds, FactoredModel};
use crate::tensor::{IntTensor, Tensor};
use crate::Result;

pub struct MaskGradRunner<'a> {
    pub cfg: &'a ModelCfg,
    pub ws: &'a WeightStore,
    pub fm: &'a FactoredModel,
    exe: std::rc::Rc<crate::runtime::Exe>,
    data: Vec<(IntTensor, IntTensor)>,
    pub dims: Vec<ModuleDim>,
}

impl<'a> MaskGradRunner<'a> {
    /// `samples` calibration sequences (paper: 256×512 tokens of C4 →
    /// scaled `sync4` batches here), seeded.
    pub fn new(
        cfg: &'a ModelCfg,
        rt: &Runtime,
        ws: &'a WeightStore,
        fm: &'a FactoredModel,
        corpus: &str,
        samples: usize,
        seed: u64,
    ) -> Result<MaskGradRunner<'a>> {
        let exe = rt.load("mask_fwd_grad")?;
        let spec = corpus_spec(corpus);
        let n_batches = samples.div_ceil(cfg.batch_eval).max(1);
        let need = n_batches * cfg.batch_eval * (cfg.seq_eval + 1) + 1;
        let stream = generate_tokens(cfg.vocab, spec, seed, need);
        let data = batches(&stream, cfg.batch_eval, cfg.seq_eval);
        Ok(MaskGradRunner { cfg, ws, fm, exe, data, dims: module_dims(cfg) })
    }

    /// Number of batches per epoch.
    pub fn batches_per_epoch(&self) -> usize {
        self.data.len()
    }

    /// One fwd+bwd over batch `idx` with the given binary/probabilistic
    /// masks. Returns (CE loss, ∂L/∂mask per module in f64).
    pub fn step(
        &self,
        masks: &BTreeMap<String, Tensor>,
        idx: usize,
    ) -> Result<(f64, BTreeMap<String, Vec<f64>>)> {
        let (toks, tgts) = &self.data[idx % self.data.len()];
        let mut feeds: HashMap<&str, Feed> = HashMap::new();
        factored_feeds(self.ws, self.fm, masks, &mut feeds);
        feeds.insert("tokens", Feed::I32(toks));
        feeds.insert("targets", Feed::I32(tgts));
        let out = self.exe.run(&feeds)?;
        let loss = out.scalar("loss")? as f64;
        let mut grads = BTreeMap::new();
        for d in &self.dims {
            let g = out.tensor(&format!("grad:mask:{}", d.name))?;
            grads.insert(d.name.clone(), g.data.iter().map(|&x| x as f64).collect());
        }
        Ok((loss, grads))
    }
}
