//! Full-rank guidance (Sec. 3.3): the metric G_R — fraction of (whitened)
//! output norm preserved at ratio R — and the loss L_g that pushes modules
//! whose compression is not "worth it" (G_R ≤ R) toward the dense matrix.

use crate::model::ModuleDim;
use crate::svd::ModuleFactors;

/// Eq. 6: G_R = (L₀ − L_R)/L₀ with L_R the truncation tail at the
/// parameter-consistent retained rank k(R) = ⌊R·mn/(m+n)⌋ (see masks.rs on
/// the Eq. 4 rank convention).
pub fn guidance_metric(dim: &ModuleDim, f: &ModuleFactors, ratio: f64) -> f64 {
    let r = dim.r_full();
    // k ≥ 1: the largest singular value is always preserved (v₁ = D)
    let k = ((ratio * dim.dense_params() as f64 / (dim.m + dim.n) as f64).floor() as usize)
        .clamp(1, r);
    let l0 = f.total_norm();
    if l0 <= 0.0 {
        return 1.0;
    }
    (l0 - f.tail_norm(k)) / l0
}

/// Eq. 7 plus its STE gradient w.r.t. R:
/// L_g = 0 if G_R > R else (1 − R); dL_g/dR = 0 or −1 respectively.
pub fn guidance_loss(dim: &ModuleDim, f: &ModuleFactors, ratio: f64) -> (f64, f64) {
    let g = guidance_metric(dim, f, ratio);
    if g > ratio {
        (0.0, 0.0)
    } else {
        ((1.0 - ratio).max(0.0), -1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn factors(sigma: Vec<f64>) -> ModuleFactors {
        let r = sigma.len();
        ModuleFactors {
            wu: Tensor::zeros(&[r, r]),
            wv: Tensor::zeros(&[r, r]),
            sigma,
        }
    }

    fn dim(r: usize) -> ModuleDim {
        ModuleDim { name: "t".into(), m: r, n: r }
    }

    #[test]
    fn metric_bounds() {
        // parameter-consistent convention: at ratio R the factorized
        // alternative retains k = R·mn/(m+n) components (= r/2 for square
        // modules at R=1) — G_R < 1 there unless the spectrum collapses,
        // which is exactly why the guidance can fire near R = 1.
        let f = factors(vec![4.0, 2.0, 1.0, 0.5]);
        let d = dim(4);
        let g1 = guidance_metric(&d, &f, 1.0);
        assert!(g1 > 0.0 && g1 <= 1.0);
        assert!(g1 < 1.0, "flat-ish square spectrum can't be fully preserved at R=1");
        let g_half = guidance_metric(&d, &f, 0.5);
        assert!(g_half > 0.0 && g_half <= g1);
    }

    #[test]
    fn metric_monotone_in_ratio() {
        let f = factors(vec![5.0, 3.0, 2.0, 1.0, 0.5, 0.1]);
        let d = dim(6);
        let mut prev = -1.0;
        for i in 0..=6 {
            let g = guidance_metric(&d, &f, i as f64 / 6.0);
            assert!(g >= prev - 1e-12);
            prev = g;
        }
    }

    #[test]
    fn fast_decay_spectrum_prefers_compression() {
        // nearly rank-1: G_R at small R is already ≈ 1 > R ⇒ no guidance
        let f = factors(vec![100.0, 0.01, 0.01, 0.01]);
        let d = dim(4);
        let (lg, dr) = guidance_loss(&d, &f, 0.25);
        assert_eq!(lg, 0.0);
        assert_eq!(dr, 0.0);
    }

    #[test]
    fn flat_spectrum_triggers_guidance() {
        // flat spectrum at R=0.5 ⇒ k=1 of 4 kept: G = 1 − √3/2 ≈ 0.134 ≤ 0.5
        // ⇒ guidance active with loss 1 − R
        let f = factors(vec![1.0, 1.0, 1.0, 1.0]);
        let d = dim(4);
        let (lg, dr) = guidance_loss(&d, &f, 0.5);
        assert!((lg - 0.5).abs() < 1e-12);
        assert_eq!(dr, -1.0);
    }

    #[test]
    fn guidance_vanishes_at_dense() {
        let f = factors(vec![1.0, 1.0, 1.0]);
        let d = dim(3);
        let (lg, _) = guidance_loss(&d, &f, 1.0);
        assert_eq!(lg, 0.0, "1 − R = 0 at the dense point");
    }
}
