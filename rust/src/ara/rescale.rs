//! Post-training proportional rescale (Alg. 1 step 26): the soft L_c
//! constraint cannot hit the target exactly, so all module ratios are
//! scaled by a common factor s — modules pushed to/past the dense point are
//! capped at mn — and s is found by bisection so the global parameter count
//! meets the target within one rank unit.

use crate::model::{Allocation, ModuleAlloc, ModuleDim};

/// Scale per-module ratios to meet `target` (global, compressible scope).
///
/// `ratios[i]` is module i's learned R (may exceed 1). Returns the final
/// allocation: `s·R ≥ 1` (or k past the break-even rank) ⇒ Dense, else
/// Rank(⌊s·R·r⌋ clamped to ≥1).
pub fn rescale_to_target(dims: &[ModuleDim], ratios: &[f64], target: f64, name: &str) -> Allocation {
    assert_eq!(dims.len(), ratios.len());
    let total: usize = dims.iter().map(|d| d.dense_params()).sum();
    let want = target * total as f64;

    let params_at = |s: f64| -> f64 {
        dims.iter()
            .zip(ratios)
            .map(|(d, &r)| module_params_at(d, s * r) as f64)
            .sum()
    };

    // params_at is monotone non-decreasing in s; bisection over s.
    let (mut lo, mut hi) = (0.0, 1.0);
    while params_at(hi) < want && hi < 1e6 {
        hi *= 2.0;
    }
    for _ in 0..80 {
        let mid = 0.5 * (lo + hi);
        if params_at(mid) < want {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    let s = 0.5 * (lo + hi);

    let mut alloc = Allocation::new(name);
    for (d, &r) in dims.iter().zip(ratios) {
        alloc.set(&d.name, decide(d, s * r));
    }
    alloc
}

fn decide(d: &ModuleDim, ratio: f64) -> ModuleAlloc {
    if ratio >= 1.0 {
        return ModuleAlloc::Dense;
    }
    // parameter-consistent rank: k(m+n) ≈ ratio·mn
    let k = ((ratio * d.dense_params() as f64 / (d.m + d.n) as f64).floor() as usize)
        .clamp(1, d.r_full());
    if d.factored_params(k) >= d.dense_params() {
        ModuleAlloc::Dense
    } else {
        ModuleAlloc::Rank(k)
    }
}

fn module_params_at(d: &ModuleDim, ratio: f64) -> usize {
    match decide(d, ratio) {
        ModuleAlloc::Dense => d.dense_params(),
        ModuleAlloc::Rank(k) => d.factored_params(k),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::alloc_params_for_dims;

    fn dims() -> Vec<ModuleDim> {
        vec![
            ModuleDim { name: "a".into(), m: 32, n: 32 },
            ModuleDim { name: "b".into(), m: 16, n: 32 },
            ModuleDim { name: "c".into(), m: 80, n: 32 },
            ModuleDim { name: "d".into(), m: 32, n: 80 },
        ]
    }

    fn achieved(dims: &[ModuleDim], alloc: &Allocation) -> f64 {
        let total: usize = dims.iter().map(|d| d.dense_params()).sum();
        alloc_params_for_dims(dims, alloc) as f64 / total as f64
    }

    #[test]
    fn hits_target_within_tolerance() {
        let dims = dims();
        for target in [0.8, 0.6, 0.4] {
            let ratios = vec![0.9, 0.5, 1.2, 0.7];
            let alloc = rescale_to_target(&dims, &ratios, target, "t");
            let got = achieved(&dims, &alloc);
            // within one rank-unit of every module
            let slack: f64 = dims
                .iter()
                .map(|d| (d.m + d.n) as f64)
                .sum::<f64>()
                / dims.iter().map(|d| d.dense_params()).sum::<usize>() as f64;
            assert!(
                (got - target).abs() <= slack + 1e-9,
                "target {target} got {got} slack {slack}"
            );
        }
    }

    #[test]
    fn preserves_relative_ordering() {
        let dims = dims();
        let ratios = vec![0.2, 0.4, 0.6, 0.8];
        let alloc = rescale_to_target(&dims, &ratios, 0.5, "t");
        let ks: Vec<f64> = dims
            .iter()
            .map(|d| match alloc.get(&d.name) {
                ModuleAlloc::Dense => 1.0,
                ModuleAlloc::Rank(k) => d.factored_params(k) as f64 / d.dense_params() as f64,
            })
            .collect();
        // module with larger learned R keeps a larger achieved ratio
        for i in 1..ks.len() {
            assert!(ks[i] >= ks[i - 1] - 0.05);
        }
    }

    #[test]
    fn dense_modules_stay_dense_when_budget_allows() {
        let dims = dims();
        // a: way past 1 ⇒ dense; generous global target
        let ratios = vec![1.5, 0.9, 0.9, 0.9];
        let alloc = rescale_to_target(&dims, &ratios, 0.95, "t");
        assert_eq!(alloc.get("a"), ModuleAlloc::Dense);
    }

    #[test]
    fn tiny_target_still_valid() {
        let dims = dims();
        let ratios = vec![1.0, 1.0, 1.0, 1.0];
        let alloc = rescale_to_target(&dims, &ratios, 0.05, "t");
        for d in &dims {
            match alloc.get(&d.name) {
                ModuleAlloc::Rank(k) => assert!(k >= 1),
                ModuleAlloc::Dense => panic!("5% target cannot keep dense modules"),
            }
        }
    }
}
