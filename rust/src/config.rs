//! Model presets and run recipes, shared with the python AOT path through
//! `configs/models.json` (parsed with the in-crate [`crate::json`] module).

use std::path::{Path, PathBuf};

use crate::json::{parse, Json};
use crate::Result;

/// One model preset (a scaled-down stand-in for the paper's LLaMA2/Qwen3
/// models — see DESIGN.md §2).
#[derive(Debug, Clone)]
pub struct ModelCfg {
    pub name: String,
    pub family: String,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub max_seq: usize,
    pub rope_theta: f64,
    pub batch_train: usize,
    pub seq_train: usize,
    pub batch_eval: usize,
    pub seq_eval: usize,
    pub lora_rank: usize,
    pub serving: bool,
    pub decode_batches: Vec<usize>,
    pub prefill_len: usize,
    pub max_decode_seq: usize,
}

impl ModelCfg {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }
    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim()
    }

    fn from_json(j: &Json) -> Result<ModelCfg> {
        Ok(ModelCfg {
            name: j.req("name")?.as_str()?.to_string(),
            family: j.req("family")?.as_str()?.to_string(),
            d_model: j.req("d_model")?.as_usize()?,
            n_layers: j.req("n_layers")?.as_usize()?,
            n_heads: j.req("n_heads")?.as_usize()?,
            n_kv_heads: j.req("n_kv_heads")?.as_usize()?,
            d_ff: j.req("d_ff")?.as_usize()?,
            vocab: j.req("vocab")?.as_usize()?,
            max_seq: j.req("max_seq")?.as_usize()?,
            rope_theta: j.req("rope_theta")?.as_f64()?,
            batch_train: j.req("batch_train")?.as_usize()?,
            seq_train: j.req("seq_train")?.as_usize()?,
            batch_eval: j.req("batch_eval")?.as_usize()?,
            seq_eval: j.req("seq_eval")?.as_usize()?,
            lora_rank: j.req("lora_rank")?.as_usize()?,
            serving: j.req("serving")?.as_bool()?,
            decode_batches: j
                .req("decode_batches")?
                .as_arr()?
                .iter()
                .map(|x| x.as_usize())
                .collect::<Result<Vec<_>>>()?,
            prefill_len: j.req("prefill_len")?.as_usize()?,
            max_decode_seq: j.req("max_decode_seq")?.as_usize()?,
        })
    }
}

/// Repository paths: where configs, artifacts and cached runs live.
#[derive(Debug, Clone)]
pub struct Paths {
    pub configs: PathBuf,
    pub artifacts: PathBuf,
    pub runs: PathBuf,
}

impl Paths {
    /// Resolve from env (`ARA_ROOT`, `ARA_ARTIFACTS`, `ARA_RUNS`) or by
    /// walking up from cwd until a `configs/models.json` is found.
    pub fn discover() -> Result<Paths> {
        let root = if let Ok(r) = std::env::var("ARA_ROOT") {
            PathBuf::from(r)
        } else {
            let mut dir = std::env::current_dir()?;
            loop {
                if dir.join("configs/models.json").exists() {
                    break dir;
                }
                if !dir.pop() {
                    return Err(crate::anyhow!(
                        "could not locate repo root (configs/models.json); set ARA_ROOT"
                    ));
                }
            }
        };
        Ok(Paths {
            configs: root.join("configs"),
            artifacts: std::env::var("ARA_ARTIFACTS")
                .map(PathBuf::from)
                .unwrap_or_else(|_| root.join("artifacts")),
            runs: std::env::var("ARA_RUNS")
                .map(PathBuf::from)
                .unwrap_or_else(|_| root.join("runs")),
        })
    }

    pub fn artifact_dir(&self, model: &str) -> PathBuf {
        self.artifacts.join(model)
    }
    pub fn run_dir(&self, model: &str) -> PathBuf {
        self.runs.join(model)
    }
}

/// Load all model presets from `configs/models.json`.
pub fn load_models(configs: &Path) -> Result<Vec<ModelCfg>> {
    let text = std::fs::read_to_string(configs.join("models.json"))?;
    let j = parse(&text)?;
    j.req("models")?
        .as_arr()?
        .iter()
        .map(ModelCfg::from_json)
        .collect()
}

/// Look up one preset by name.
pub fn model_by_name(configs: &Path, name: &str) -> Result<ModelCfg> {
    load_models(configs)?
        .into_iter()
        .find(|m| m.name == name)
        .ok_or_else(|| crate::anyhow!("unknown model preset: {name}"))
}

/// Global scale knob for benches: `ARA_SCALE=0.25` shrinks step counts and
/// sample counts of the experiment recipes (never model shapes — those are
/// baked into the AOT artifacts).
pub fn scale() -> f64 {
    std::env::var("ARA_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0)
}

/// Apply the global scale to a count with a floor.
pub fn scaled(count: usize, floor: usize) -> usize {
    ((count as f64 * scale()).round() as usize).max(floor)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_parse_and_are_consistent() {
        let paths = Paths::discover().unwrap();
        let models = load_models(&paths.configs).unwrap();
        assert!(models.len() >= 5);
        for m in &models {
            assert_eq!(m.d_model % m.n_heads, 0, "{}", m.name);
            assert_eq!(m.n_heads % m.n_kv_heads, 0, "{}", m.name);
            assert!(m.vocab > 0 && m.max_seq >= m.seq_eval);
            if m.serving {
                assert!(!m.decode_batches.is_empty());
                assert!(m.prefill_len < m.max_decode_seq);
            }
        }
    }

    #[test]
    fn lookup_by_name() {
        let paths = Paths::discover().unwrap();
        let m = model_by_name(&paths.configs, "micro-llama").unwrap();
        assert_eq!(m.family, "llama");
        assert!(model_by_name(&paths.configs, "nonexistent").is_err());
    }
}
