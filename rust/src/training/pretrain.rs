//! Pre-training driver: generates the synthetic corpus, loops the AOT
//! `train_step` executable, applies AdamW with cosine decay + grad clipping,
//! and logs the loss curve (recorded in EXPERIMENTS.md for the e2e run).

use std::collections::HashMap;

use super::adamw::{cosine_schedule, AdamW, AdamWConfig};
use crate::config::ModelCfg;
use crate::data::{batches, corpus_spec, generate_tokens, TRAIN_SEED};
use crate::model::{init_weights, WeightStore};
use crate::runtime::{Feed, Runtime};
use crate::Result;

#[derive(Debug, Clone)]
pub struct PretrainConfig {
    pub steps: usize,
    pub lr: f64,
    pub warmup: usize,
    pub grad_clip: f64,
    pub corpus: String,
    pub seed: u64,
    pub log_every: usize,
}

impl Default for PretrainConfig {
    fn default() -> Self {
        PretrainConfig {
            steps: 300,
            lr: 3e-3,
            warmup: 20,
            grad_clip: 1.0,
            corpus: "synwiki".to_string(),
            seed: 42,
            log_every: 20,
        }
    }
}

#[derive(Debug, Clone)]
pub struct PretrainReport {
    pub losses: Vec<(usize, f64)>,
    pub final_loss: f64,
    pub initial_loss: f64,
}

/// Pre-train a model from scratch; returns trained weights + loss curve.
pub fn pretrain(
    cfg: &ModelCfg,
    rt: &Runtime,
    pc: &PretrainConfig,
) -> Result<(WeightStore, PretrainReport)> {
    let exe = rt.load("train_step")?;
    let mut ws = init_weights(cfg, pc.seed);
    let mut opt = AdamW::new(AdamWConfig { lr: pc.lr, ..Default::default() });

    // enough tokens for `steps` distinct batches, cycling if short
    let spec = corpus_spec(&pc.corpus);
    let need = pc.steps * cfg.batch_train * (cfg.seq_train + 1) + 1;
    let stream = generate_tokens(cfg.vocab, spec, TRAIN_SEED ^ pc.seed, need);
    let data = batches(&stream, cfg.batch_train, cfg.seq_train);
    if data.is_empty() {
        return Err(crate::anyhow!("corpus too small for one batch"));
    }

    let mut losses = Vec::new();
    let mut initial_loss = f64::NAN;
    let weight_names: Vec<String> = ws.tensors.keys().cloned().collect();

    for step in 0..pc.steps {
        let (toks, tgts) = &data[step % data.len()];
        let mut feeds: HashMap<&str, Feed> = HashMap::new();
        for name in &weight_names {
            feeds.insert(name.as_str(), Feed::F32(ws.get(name)));
        }
        feeds.insert("tokens", Feed::I32(toks));
        feeds.insert("targets", Feed::I32(tgts));
        let out = exe.run(&feeds)?;
        let loss = out.scalar("loss")? as f64;
        if step == 0 {
            initial_loss = loss;
        }

        // collect grads, compute global norm for clipping
        let mut grads = Vec::with_capacity(weight_names.len());
        let mut sq = 0.0f64;
        for name in &weight_names {
            let g = out.tensor(&format!("grad:{name}"))?;
            sq += g.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>();
            grads.push(g);
        }
        let norm = sq.sqrt();
        let clip = if norm > pc.grad_clip { pc.grad_clip / norm } else { 1.0 };

        let lr_scale = cosine_schedule(step, pc.steps, pc.warmup);
        opt.step();
        for (name, g) in weight_names.iter().zip(&grads) {
            // norms & embeddings get no weight decay (standard practice);
            // decay is folded by zeroing it through a per-tensor lr trick:
            // we simply exclude 1-D tensors from decay by scaling grads only.
            let t = ws.get_mut(name);
            if clip != 1.0 {
                let scaled: Vec<f32> = g.data.iter().map(|&x| x * clip as f32).collect();
                opt.update_f32(name, &mut t.data, &scaled, lr_scale);
            } else {
                opt.update_f32(name, &mut t.data, &g.data, lr_scale);
            }
        }

        if step % pc.log_every == 0 || step + 1 == pc.steps {
            losses.push((step, loss));
            eprintln!("[pretrain {}] step {step:4} loss {loss:.4}", cfg.name);
        }
        if !loss.is_finite() {
            return Err(crate::anyhow!("pretrain diverged at step {step} (loss={loss})"));
        }
    }

    let final_loss = losses.last().map(|&(_, l)| l).unwrap_or(f64::NAN);
    Ok((ws, PretrainReport { losses, final_loss, initial_loss }))
}
