//! AdamW (decoupled weight decay) over named tensor collections — used for
//! pre-training (f32 weights), allocation training (f64 α vectors via the
//! scalar variant), and LoRA recovery.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct AdamWConfig {
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    pub weight_decay: f64,
}

impl Default for AdamWConfig {
    fn default() -> Self {
        AdamWConfig { lr: 1e-3, beta1: 0.9, beta2: 0.999, eps: 1e-8, weight_decay: 0.01 }
    }
}

/// AdamW state for a set of named parameter vectors.
#[derive(Debug, Default)]
pub struct AdamW {
    pub cfg: AdamWConfig,
    m: BTreeMap<String, Vec<f64>>,
    v: BTreeMap<String, Vec<f64>>,
    t: u64,
}

impl AdamW {
    pub fn new(cfg: AdamWConfig) -> AdamW {
        AdamW { cfg, m: BTreeMap::new(), v: BTreeMap::new(), t: 0 }
    }

    /// Advance the step counter (call once per optimization step, before
    /// updating the parameter groups of that step).
    pub fn step(&mut self) {
        self.t += 1;
    }

    /// Update one named f32 parameter tensor in place. `lr_scale` lets a
    /// schedule modulate the base lr per step; decay is decoupled.
    pub fn update_f32(&mut self, name: &str, param: &mut [f32], grad: &[f32], lr_scale: f64) {
        assert_eq!(param.len(), grad.len(), "{name}: grad size mismatch");
        let n = param.len();
        let m = self.m.entry(name.to_string()).or_insert_with(|| vec![0.0; n]);
        let v = self.v.entry(name.to_string()).or_insert_with(|| vec![0.0; n]);
        assert_eq!(m.len(), n);
        let c = &self.cfg;
        let t = self.t.max(1) as f64;
        let bc1 = 1.0 - c.beta1.powf(t);
        let bc2 = 1.0 - c.beta2.powf(t);
        let lr = c.lr * lr_scale;
        for i in 0..n {
            let g = grad[i] as f64;
            m[i] = c.beta1 * m[i] + (1.0 - c.beta1) * g;
            v[i] = c.beta2 * v[i] + (1.0 - c.beta2) * g * g;
            let mhat = m[i] / bc1;
            let vhat = v[i] / bc2;
            let mut p = param[i] as f64;
            p -= lr * (mhat / (vhat.sqrt() + c.eps) + c.weight_decay * p);
            param[i] = p as f32;
        }
    }

    /// f64 variant (allocation α vectors).
    pub fn update_f64(&mut self, name: &str, param: &mut [f64], grad: &[f64], lr_scale: f64) {
        assert_eq!(param.len(), grad.len(), "{name}: grad size mismatch");
        let n = param.len();
        let m = self.m.entry(name.to_string()).or_insert_with(|| vec![0.0; n]);
        let v = self.v.entry(name.to_string()).or_insert_with(|| vec![0.0; n]);
        let c = &self.cfg;
        let t = self.t.max(1) as f64;
        let bc1 = 1.0 - c.beta1.powf(t);
        let bc2 = 1.0 - c.beta2.powf(t);
        let lr = c.lr * lr_scale;
        for i in 0..n {
            let g = grad[i];
            m[i] = c.beta1 * m[i] + (1.0 - c.beta1) * g;
            v[i] = c.beta2 * v[i] + (1.0 - c.beta2) * g * g;
            let mhat = m[i] / bc1;
            let vhat = v[i] / bc2;
            param[i] -= lr * (mhat / (vhat.sqrt() + c.eps) + c.weight_decay * param[i]);
        }
    }
}

/// Cosine learning-rate schedule with linear warmup, returning a scale in
/// (0, 1] to multiply the base lr.
pub fn cosine_schedule(step: usize, total: usize, warmup: usize) -> f64 {
    if total == 0 {
        return 1.0;
    }
    if step < warmup {
        return (step + 1) as f64 / warmup.max(1) as f64;
    }
    let p = (step - warmup) as f64 / (total.saturating_sub(warmup)).max(1) as f64;
    0.5 * (1.0 + (std::f64::consts::PI * p.min(1.0)).cos()).max(0.02)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn converges_on_quadratic() {
        // minimize f(x) = Σ (x_i - i)²
        let mut opt = AdamW::new(AdamWConfig { lr: 0.1, weight_decay: 0.0, ..Default::default() });
        let mut x = vec![0.0f32; 4];
        for _ in 0..500 {
            let grad: Vec<f32> = x.iter().enumerate().map(|(i, &v)| 2.0 * (v - i as f32)).collect();
            opt.step();
            opt.update_f32("x", &mut x, &grad, 1.0);
        }
        for (i, &v) in x.iter().enumerate() {
            assert!((v - i as f32).abs() < 0.05, "x[{i}]={v}");
        }
    }

    #[test]
    fn weight_decay_shrinks_params() {
        let mut opt = AdamW::new(AdamWConfig { lr: 0.01, weight_decay: 0.5, ..Default::default() });
        let mut x = vec![1.0f32];
        for _ in 0..100 {
            opt.step();
            opt.update_f32("x", &mut x, &[0.0], 1.0);
        }
        assert!(x[0] < 0.7, "decay should shrink x: {}", x[0]);
    }

    #[test]
    fn schedule_shape() {
        assert!(cosine_schedule(0, 100, 10) < 0.2);
        assert!((cosine_schedule(10, 100, 10) - 1.0).abs() < 1e-9);
        assert!(cosine_schedule(99, 100, 10) < 0.1);
        // monotone decreasing after warmup
        let a = cosine_schedule(20, 100, 10);
        let b = cosine_schedule(60, 100, 10);
        assert!(a > b);
    }

    #[test]
    fn f64_variant_matches_f32() {
        let g = vec![0.3, -0.2];
        let mut a32 = AdamW::new(AdamWConfig::default());
        let mut a64 = AdamW::new(AdamWConfig::default());
        let mut x32 = vec![0.5f32, -0.1];
        let mut x64 = vec![0.5f64, -0.1];
        for _ in 0..10 {
            a32.step();
            a64.step();
            a32.update_f32("p", &mut x32, &[g[0] as f32, g[1] as f32], 1.0);
            a64.update_f64("p", &mut x64, &g, 1.0);
        }
        for (a, b) in x32.iter().zip(&x64) {
            assert!((*a as f64 - b).abs() < 1e-5);
        }
    }
}
