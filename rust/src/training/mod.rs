//! Substrate LM pre-training: AdamW + cosine schedule driving the AOT
//! `train_step` executable. This is how the models we compress come to
//! exist — no pre-trained checkpoints are shipped (DESIGN.md §2).

mod adamw;
mod pretrain;

pub use adamw::{AdamW, AdamWConfig};
pub use pretrain::{pretrain, PretrainConfig, PretrainReport};
