//! Structured-pruning comparators for Table 4 — simplified but faithful
//! re-implementations of the three methods' selection criteria:
//!
//! * LLM-Pruner-like: first-order Taylor importance |W ⊙ ∇W| per group;
//! * FLAP-like: activation-magnitude importance from calibration statistics
//!   (bias compensation omitted — documented substitution, DESIGN.md §2);
//! * SliceGPT-like: PCA of the residual-stream covariance, keeping top
//!   principal directions (rotation folded as a projection of each module).
//!
//! Structured groups: query-head groups (wq rows + wo cols) and MLP hidden
//! channels (wgate/wup rows + wdown cols). Pruning zeroes the group and the
//! accounting removes its parameters, so score_dense evaluates the pruned
//! network directly.

use std::collections::BTreeMap;

use crate::config::ModelCfg;
use crate::linalg::{jacobi_eigh, Mat};
use crate::model::WeightStore;
use crate::Result;

/// A pruned dense model plus its achieved parameter ratio.
pub struct PrunedModel {
    pub ws: WeightStore,
    pub ratio: f64,
    pub method: &'static str,
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum Group {
    /// (layer, head index)
    Head(usize, usize),
    /// (layer, hidden channel)
    MlpChannel(usize, usize),
}

fn group_cost(cfg: &ModelCfg, g: Group) -> usize {
    match g {
        Group::Head(..) => 2 * cfg.head_dim() * cfg.d_model, // wq rows + wo cols
        Group::MlpChannel(..) => 3 * cfg.d_model,            // gate+up rows, down col
    }
}

/// Zero one group's weights in place.
fn zero_group(cfg: &ModelCfg, ws: &mut WeightStore, g: Group) {
    let d = cfg.d_model;
    let dh = cfg.head_dim();
    match g {
        Group::Head(l, h) => {
            let wq = ws.get_mut(&format!("layers.{l}.attn.wq"));
            for r in h * dh..(h + 1) * dh {
                for c in 0..d {
                    wq.set2(r, c, 0.0);
                }
            }
            let wo = ws.get_mut(&format!("layers.{l}.attn.wo"));
            for r in 0..d {
                for c in h * dh..(h + 1) * dh {
                    wo.set2(r, c, 0.0);
                }
            }
        }
        Group::MlpChannel(l, ch) => {
            for name in ["wgate", "wup"] {
                let w = ws.get_mut(&format!("layers.{l}.mlp.{name}"));
                for c in 0..d {
                    w.set2(ch, c, 0.0);
                }
            }
            let wd = ws.get_mut(&format!("layers.{l}.mlp.wdown"));
            for r in 0..d {
                wd.set2(r, ch, 0.0);
            }
        }
    }
}

/// Remove lowest-importance groups until the compressible ratio hits target.
/// Keeps at least one head and ~10% of channels per layer (stability floor).
fn prune_to_target(
    cfg: &ModelCfg,
    ws: &WeightStore,
    mut importance: Vec<(Group, f64)>,
    target: f64,
    method: &'static str,
) -> PrunedModel {
    let total: usize = crate::model::compressible_params(cfg);
    let budget_remove = ((1.0 - target) * total as f64) as usize;
    importance.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());

    let mut removed = 0usize;
    let mut heads_left = vec![cfg.n_heads; cfg.n_layers];
    let mut chans_left = vec![cfg.d_ff; cfg.n_layers];
    let floor_ch = (cfg.d_ff / 10).max(1);
    let mut out = ws.clone();
    for (g, _) in importance {
        if removed >= budget_remove {
            break;
        }
        let ok = match g {
            Group::Head(l, _) => heads_left[l] > 1,
            Group::MlpChannel(l, _) => chans_left[l] > floor_ch,
        };
        if !ok {
            continue;
        }
        zero_group(cfg, &mut out, g);
        removed += group_cost(cfg, g);
        match g {
            Group::Head(l, _) => heads_left[l] -= 1,
            Group::MlpChannel(l, _) => chans_left[l] -= 1,
        }
    }
    PrunedModel { ws: out, ratio: 1.0 - removed as f64 / total as f64, method }
}

fn all_groups(cfg: &ModelCfg) -> Vec<Group> {
    let mut gs = Vec::new();
    for l in 0..cfg.n_layers {
        for h in 0..cfg.n_heads {
            gs.push(Group::Head(l, h));
        }
        for c in 0..cfg.d_ff {
            gs.push(Group::MlpChannel(l, c));
        }
    }
    gs
}

/// LLM-Pruner-like: |W ⊙ ∇W| summed over the group (first-order Taylor).
/// `grads` maps weight name → gradient tensor (one calib train_step).
pub fn llm_pruner(
    cfg: &ModelCfg,
    ws: &WeightStore,
    grads: &BTreeMap<String, crate::tensor::Tensor>,
    target: f64,
) -> Result<PrunedModel> {
    let d = cfg.d_model;
    let dh = cfg.head_dim();
    let mut imp = Vec::new();
    for g in all_groups(cfg) {
        let score = match g {
            Group::Head(l, h) => {
                let w = ws.get(&format!("layers.{l}.attn.wq"));
                let gr = &grads[&format!("layers.{l}.attn.wq")];
                let mut s = 0.0f64;
                for r in h * dh..(h + 1) * dh {
                    for c in 0..d {
                        s += (w.at2(r, c) * gr.at2(r, c)).abs() as f64;
                    }
                }
                s / dh as f64
            }
            Group::MlpChannel(l, ch) => {
                let mut s = 0.0f64;
                for name in ["wgate", "wup"] {
                    let w = ws.get(&format!("layers.{l}.mlp.{name}"));
                    let gr = &grads[&format!("layers.{l}.mlp.{name}")];
                    for c in 0..d {
                        s += (w.at2(ch, c) * gr.at2(ch, c)).abs() as f64;
                    }
                }
                s
            }
        };
        imp.push((g, score));
    }
    Ok(prune_to_target(cfg, ws, imp, target, "LLM-Pruner-like"))
}

/// FLAP-like: activation-energy importance from the calibration Grams.
pub fn flap(
    cfg: &ModelCfg,
    ws: &WeightStore,
    grams: &BTreeMap<String, Mat>,
    target: f64,
) -> Result<PrunedModel> {
    let dh = cfg.head_dim();
    let mut imp = Vec::new();
    for g in all_groups(cfg) {
        let score = match g {
            Group::Head(l, h) => {
                // energy of this head's slice of the wo input
                let hmat = &grams[&format!("layers.{l}.attn.wo")];
                (h * dh..(h + 1) * dh).map(|j| hmat.at(j, j)).sum::<f64>() / dh as f64
            }
            Group::MlpChannel(l, ch) => {
                let hmat = &grams[&format!("layers.{l}.mlp.wdown")];
                hmat.at(ch, ch)
            }
        };
        imp.push((g, score));
    }
    Ok(prune_to_target(cfg, ws, imp, target, "FLAP-like"))
}

/// SliceGPT-like: project every module's input onto the top-k principal
/// directions of its calibration covariance (W ← W·P·Pᵀ); parameters are
/// accounted at k/n of the dense cost (the slice that a real
/// rotated-and-sliced model would store).
pub fn slicegpt(
    cfg: &ModelCfg,
    ws: &WeightStore,
    grams: &BTreeMap<String, Mat>,
    target: f64,
) -> Result<PrunedModel> {
    let mut out = ws.clone();
    // slice fraction = target (params scale linearly with kept directions)
    for d in crate::model::module_dims(cfg) {
        let h = &grams[&d.name];
        let (_w, v) = jacobi_eigh(h);
        let keep = ((target * d.n as f64).round() as usize).clamp(1, d.n);
        // P = top-k eigenvectors (n × k): W' = W P Pᵀ
        let mut p = Mat::zeros(d.n, keep);
        for i in 0..d.n {
            for j in 0..keep {
                p.set(i, j, v.at(i, j));
            }
        }
        let w = Mat::from_f32(d.m, d.n, &out.get(&d.name).data);
        let wp = w.matmul(&p); // m×k
        let wpp = wp.matmul(&p.transpose()); // m×n
        out.get_mut(&d.name).data.copy_from_slice(
            &wpp.data.iter().map(|&x| x as f32).collect::<Vec<_>>(),
        );
    }
    Ok(PrunedModel { ws: out, ratio: target, method: "SliceGPT-like" })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{model_by_name, Paths};
    use crate::model::init_weights;

    fn setup() -> (ModelCfg, WeightStore) {
        let paths = Paths::discover().unwrap();
        let cfg = model_by_name(&paths.configs, "micro-llama").unwrap();
        let ws = init_weights(&cfg, 5);
        (cfg, ws)
    }

    fn fake_grams(cfg: &ModelCfg) -> BTreeMap<String, Mat> {
        let mut rng = crate::data::Rng::new(6);
        crate::model::module_dims(cfg)
            .into_iter()
            .map(|d| {
                let mut h = Mat::zeros(d.n, d.n);
                for i in 0..d.n {
                    h.set(i, i, 0.5 + rng.f64());
                }
                (d.name, h)
            })
            .collect()
    }

    #[test]
    fn flap_hits_ratio_and_zeroes_weights() {
        let (cfg, ws) = setup();
        let grams = fake_grams(&cfg);
        let pm = flap(&cfg, &ws, &grams, 0.8).unwrap();
        assert!(pm.ratio <= 0.82, "ratio {}", pm.ratio);
        // something actually got zeroed
        let before: f64 = ws.get("layers.0.mlp.wgate").data.iter().map(|x| x.abs() as f64).sum();
        let after: f64 = pm.ws.get("layers.0.mlp.wgate").data.iter().map(|x| x.abs() as f64).sum();
        assert!(after < before);
    }

    #[test]
    fn llm_pruner_prefers_low_saliency() {
        let (cfg, ws) = setup();
        // gradient = weights ⇒ importance ∝ w²; zero-weight channels pruned first
        let grads: BTreeMap<String, crate::tensor::Tensor> =
            ws.tensors.iter().map(|(k, v)| (k.clone(), v.clone())).collect();
        let pm = llm_pruner(&cfg, &ws, &grads, 0.8).unwrap();
        assert!(pm.ratio <= 0.82);
    }

    #[test]
    fn slicegpt_projects_weights() {
        let (cfg, ws) = setup();
        let grams = fake_grams(&cfg);
        let pm = slicegpt(&cfg, &ws, &grams, 0.8).unwrap();
        assert!((pm.ratio - 0.8).abs() < 1e-9);
        let a = ws.get("layers.0.attn.wq");
        let b = pm.ws.get("layers.0.attn.wq");
        assert_ne!(a.data, b.data);
    }
}
