//! DLP-style layerwise allocation: per-layer compression ratios driven by
//! an outlier statistic (activation-scaled weight magnitudes vs. the layer
//! median — the median replacement is DLP's robustness tweak over OWL).
//! Outlier-rich layers are deemed important and keep more parameters.
//! Allocation is at transformer-layer granularity (the paper's point about
//! these general methods: no intra-layer, SVD-aware refinement).

use std::collections::BTreeMap;

use crate::config::ModelCfg;
use crate::linalg::Mat;
use crate::model::{module_dims, Allocation, ModuleAlloc, WeightStore};

/// DLP's parameter set (the registry's `dlp` method; DESIGN.md §4).
#[derive(Debug, Clone)]
pub struct DlpConfig {
    /// Bound on the layerwise deviation from the mean ratio (paper: 0.15;
    /// spec override: `dlp@R?tail=0.2`).
    pub tail: f64,
}

impl Default for DlpConfig {
    fn default() -> Self {
        DlpConfig { tail: 0.15 }
    }
}

/// `alpha` bounds the layerwise deviation from the mean ratio (paper: 0.15).
pub fn dlp_alloc(
    cfg: &ModelCfg,
    ws: &WeightStore,
    grams: &BTreeMap<String, Mat>,
    target: f64,
    alpha: f64,
) -> Allocation {
    let dims = module_dims(cfg);

    // outlier score per layer: fraction of |W_ij|·√H_jj above 5× median
    let mut scores = vec![0.0f64; cfg.n_layers];
    for layer in 0..cfg.n_layers {
        let prefix = format!("layers.{layer}.");
        let mut vals: Vec<f64> = Vec::new();
        for d in dims.iter().filter(|d| d.name.starts_with(&prefix)) {
            let w = ws.get(&d.name);
            let h = &grams[&d.name];
            for i in 0..d.m {
                for j in 0..d.n {
                    let scale = h.at(j, j).max(0.0).sqrt();
                    vals.push((w.at2(i, j).abs() as f64) * scale);
                }
            }
        }
        let mut sorted = vals.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = sorted[sorted.len() / 2].max(1e-12);
        let outliers = vals.iter().filter(|&&v| v > 5.0 * median).count();
        scores[layer] = outliers as f64 / vals.len() as f64;
    }

    // normalize scores → per-layer ratio target ± alpha
    let mean = scores.iter().sum::<f64>() / scores.len() as f64;
    let spread = scores
        .iter()
        .map(|s| (s - mean).abs())
        .fold(0.0f64, f64::max)
        .max(1e-9);
    let layer_ratio: Vec<f64> = scores
        .iter()
        .map(|s| (target + alpha * (s - mean) / spread).clamp(0.05, 0.98))
        .collect();

    // renormalize so the global budget is met exactly (weighted by params)
    let weights: Vec<f64> = (0..cfg.n_layers)
        .map(|l| {
            let prefix = format!("layers.{l}.");
            dims.iter()
                .filter(|d| d.name.starts_with(&prefix))
                .map(|d| d.dense_params() as f64)
                .sum()
        })
        .collect();
    let got: f64 = layer_ratio.iter().zip(&weights).map(|(r, w)| r * w).sum::<f64>()
        / weights.iter().sum::<f64>();
    let fix = target / got;

    let mut alloc = Allocation::new(format!("dlp-{}", (target * 100.0).round() as usize));
    for d in &dims {
        let layer: usize = d.name.split('.').nth(1).unwrap().parse().unwrap();
        let ratio = (layer_ratio[layer] * fix).clamp(0.02, 0.98);
        let k = ((ratio * d.dense_params() as f64 / (d.m + d.n) as f64).floor() as usize)
            .clamp(1, d.r_full());
        alloc.set(&d.name, ModuleAlloc::Rank(k));
    }
    alloc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{model_by_name, Paths};
    use crate::data::Rng;
    use crate::model::{alloc_ratio, init_weights};

    #[test]
    fn meets_budget_and_varies_by_layer() {
        let paths = Paths::discover().unwrap();
        let cfg = model_by_name(&paths.configs, "minillama-s").unwrap();
        let ws = init_weights(&cfg, 3);
        let mut rng = Rng::new(4);
        let mut grams = BTreeMap::new();
        for d in module_dims(&cfg) {
            let mut h = Mat::zeros(d.n, d.n);
            for i in 0..d.n {
                h.set(i, i, 1.0 + rng.f64());
            }
            grams.insert(d.name.clone(), h);
        }
        let a = dlp_alloc(&cfg, &ws, &grams, 0.8, 0.15);
        let got = alloc_ratio(&cfg, &a);
        assert!((got - 0.8).abs() < 0.08, "got {got}");
    }
}
