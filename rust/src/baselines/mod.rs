//! Every comparison method from the paper's evaluation:
//!
//! * [`uniform`]  — SVD-LLM-style uniform per-module ratio (the "Uniform" row);
//! * [`strs`]     — Sensitivity-based Truncation Rank Searching (ASVD);
//! * [`ars`]      — Gumbel-Sigmoid mask training (no monotonicity);
//! * [`dobi`]     — Dobi-SVD₁ tanh-mask training (monotone, local updates);
//! * [`dlp`]      — outlier-based layerwise ratio allocation;
//! * [`farms`]    — heavy-tailed ESD (Hill estimator) layerwise allocation;
//! * [`pruning`]  — structured-pruning comparators for Table 4.
//!
//! All methods emit a [`crate::model::Allocation`] normalized to the target
//! budget through the same rescale as ARA, so comparisons are controlled.

mod ars;
mod dlp;
mod dobi;
mod farms;
pub mod pruning;
mod strs;
mod uniform;

pub use ars::{ars_alloc, ArsConfig};
pub use dlp::dlp_alloc;
pub use dobi::{dobi_alloc, DobiConfig};
pub use farms::farms_alloc;
pub use strs::{strs_alloc, StrsConfig};
pub use uniform::uniform_alloc;
