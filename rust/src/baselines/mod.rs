//! Every comparison method from the paper's evaluation:
//!
//! * [`uniform_alloc`] — SVD-LLM-style uniform per-module ratio (the "Uniform" row);
//! * [`strs_alloc`]    — Sensitivity-based Truncation Rank Searching (ASVD);
//! * [`ars_alloc`]     — Gumbel-Sigmoid mask training (no monotonicity);
//! * [`dobi_alloc`]    — Dobi-SVD₁ tanh-mask training (monotone, local updates);
//! * [`dlp_alloc`]     — outlier-based layerwise ratio allocation;
//! * [`farms_alloc`]   — heavy-tailed ESD (Hill estimator) layerwise allocation;
//! * [`pruning`]       — structured-pruning comparators for Table 4.
//!
//! All methods emit a [`crate::model::Allocation`] normalized to the target
//! budget through the same rescale as ARA, so comparisons are controlled.
//! Callers go through the unified registry (`crate::compress`) — these free
//! functions are the implementations behind its [`crate::compress::AllocMethod`]
//! impls, not an entry point.

mod ars;
mod dlp;
mod dobi;
mod farms;
pub mod pruning;
mod strs;
mod uniform;

pub use ars::{ars_alloc, ArsConfig};
pub use dlp::{dlp_alloc, DlpConfig};
pub use dobi::{dobi_alloc, DobiConfig};
pub use farms::{farms_alloc, FarmsConfig};
pub use strs::{strs_alloc, StrsConfig};
pub use uniform::uniform_alloc;
