//! Uniform allocation (the paper's "Uniform" baseline = SVD-LLM): every
//! module gets the same parameter ratio; no allocation intelligence.

use crate::config::ModelCfg;
use crate::model::{module_dims, Allocation, ModuleAlloc};

/// k_l = ⌊target·mn/(m+n)⌋, clamped to [1, r_full].
pub fn uniform_alloc(cfg: &ModelCfg, target: f64) -> Allocation {
    let mut alloc = Allocation::new(format!("uniform-{}", (target * 100.0).round() as usize));
    for d in module_dims(cfg) {
        let k = ((target * d.dense_params() as f64 / (d.m + d.n) as f64).floor() as usize)
            .clamp(1, d.r_full());
        alloc.set(&d.name, ModuleAlloc::Rank(k));
    }
    alloc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{model_by_name, Paths};
    use crate::model::alloc_ratio;

    #[test]
    fn achieves_target_approximately() {
        let paths = Paths::discover().unwrap();
        let cfg = model_by_name(&paths.configs, "minillama-s").unwrap();
        for target in [0.8, 0.6, 0.3] {
            let a = uniform_alloc(&cfg, target);
            let got = alloc_ratio(&cfg, &a);
            assert!((got - target).abs() < 0.05, "target {target} got {got}");
        }
    }

    #[test]
    fn never_dense_never_zero() {
        let paths = Paths::discover().unwrap();
        let cfg = model_by_name(&paths.configs, "micro-llama").unwrap();
        let a = uniform_alloc(&cfg, 0.8);
        for (_, m) in &a.modules {
            match m {
                ModuleAlloc::Rank(k) => assert!(*k >= 1),
                ModuleAlloc::Dense => panic!("uniform never keeps dense"),
            }
        }
    }
}
