//! FARMS-style allocation: heavy-tailed self-regularization theory. The
//! Hill estimator of each layer's empirical spectral density (eigenvalues
//! λ = σ² of the weight matrices, aspect-ratio-normalized by using the
//! module SVD spectra directly) estimates training quality: small α =
//! heavy tail = well-trained ⇒ keep more; large α = light tail =
//! under-trained ⇒ compress harder. `eps` bounds the deviation (paper: 0.3).

use crate::config::ModelCfg;
use crate::model::{module_dims, Allocation, ModuleAlloc};
use crate::svd::FactoredModel;

/// FARMS' parameter set (the registry's `farms` method; DESIGN.md §4).
#[derive(Debug, Clone)]
pub struct FarmsConfig {
    /// Bound on the layerwise deviation, relative to the target (paper:
    /// 0.3; spec override: `farms@R?eps=0.2`).
    pub eps: f64,
}

impl Default for FarmsConfig {
    fn default() -> Self {
        FarmsConfig { eps: 0.3 }
    }
}

/// Hill estimator over the top half of the spectrum:
/// α = 1 + k / Σ_{i<k} ln(λᵢ/λ_k).
pub fn hill_alpha(sigma: &[f64]) -> f64 {
    let lambdas: Vec<f64> = sigma.iter().map(|s| (s * s).max(1e-300)).collect();
    let k = (lambdas.len() / 2).max(1);
    let lk = lambdas[k - 1];
    let mut s = 0.0;
    for l in lambdas.iter().take(k) {
        s += (l / lk).ln();
    }
    if s <= 1e-12 {
        return 10.0; // degenerate flat spectrum ⇒ treat as very light tail
    }
    1.0 + k as f64 / s
}

pub fn farms_alloc(cfg: &ModelCfg, fm: &FactoredModel, target: f64, eps: f64) -> Allocation {
    let dims = module_dims(cfg);

    // per-layer α: average of the layer's module spectra
    let mut alphas = vec![0.0f64; cfg.n_layers];
    for layer in 0..cfg.n_layers {
        let prefix = format!("layers.{layer}.");
        let mods: Vec<_> = dims.iter().filter(|d| d.name.starts_with(&prefix)).collect();
        let sum: f64 = mods
            .iter()
            .map(|d| hill_alpha(&fm.factors[&d.name].sigma))
            .sum();
        alphas[layer] = sum / mods.len() as f64;
    }

    let mean = alphas.iter().sum::<f64>() / alphas.len() as f64;
    let spread = alphas
        .iter()
        .map(|a| (a - mean).abs())
        .fold(0.0f64, f64::max)
        .max(1e-9);
    // larger α ⇒ under-trained ⇒ lower ratio (compress harder)
    let layer_ratio: Vec<f64> = alphas
        .iter()
        .map(|a| (target - eps * target * (a - mean) / spread).clamp(0.05, 0.98))
        .collect();

    let weights: Vec<f64> = (0..cfg.n_layers)
        .map(|l| {
            let prefix = format!("layers.{l}.");
            dims.iter()
                .filter(|d| d.name.starts_with(&prefix))
                .map(|d| d.dense_params() as f64)
                .sum()
        })
        .collect();
    let got: f64 = layer_ratio.iter().zip(&weights).map(|(r, w)| r * w).sum::<f64>()
        / weights.iter().sum::<f64>();
    let fix = target / got;

    let mut alloc = Allocation::new(format!("farms-{}", (target * 100.0).round() as usize));
    for d in &dims {
        let layer: usize = d.name.split('.').nth(1).unwrap().parse().unwrap();
        let ratio = (layer_ratio[layer] * fix).clamp(0.02, 0.98);
        let k = ((ratio * d.dense_params() as f64 / (d.m + d.n) as f64).floor() as usize)
            .clamp(1, d.r_full());
        alloc.set(&d.name, ModuleAlloc::Rank(k));
    }
    alloc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hill_alpha_orders_tail_heaviness() {
        // power-law-ish decaying spectrum ⇒ heavier tail ⇒ smaller α than
        // a near-flat spectrum
        let heavy: Vec<f64> = (1..=64).map(|i| 10.0 / (i as f64).powf(1.2)).collect();
        let light: Vec<f64> = (1..=64).map(|i| 10.0 / (1.0 + 0.01 * i as f64)).collect();
        assert!(hill_alpha(&heavy) < hill_alpha(&light));
    }

    #[test]
    fn hill_alpha_handles_degenerate() {
        assert!(hill_alpha(&[1.0, 1.0, 1.0, 1.0]).is_finite());
        assert!(hill_alpha(&[5.0]).is_finite());
    }
}
