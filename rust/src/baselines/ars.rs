//! ARS (Adaptive Rank Selection): per-singular-value Gumbel-Sigmoid mask
//! training. Each rank index gets an independent logit θᵢ; the training
//! mask is σ((θᵢ + g)/τ) with Gumbel noise g. No monotonicity is enforced —
//! exactly the deficiency Fig. 1(b) illustrates — so the learned masks can
//! scatter across the spectrum and convergence is slow (Table 5).

use std::collections::BTreeMap;

use crate::ara::{rescale_to_target, MaskGradRunner};
use crate::config::ModelCfg;
use crate::data::Rng;
use crate::model::{module_dims, Allocation};
use crate::training::{AdamW, AdamWConfig};
use crate::tensor::Tensor;
use crate::Result;

#[derive(Debug, Clone)]
pub struct ArsConfig {
    pub target: f64,
    pub lambda: f64,
    pub temperature: f64,
    pub epochs: usize,
    pub lr: f64,
    /// Gumbel-noise RNG seed (the method's own randomness).
    pub seed: u64,
    /// Seed of the shared [`MaskGradRunner`] data stream.
    pub data_seed: u64,
}

impl Default for ArsConfig {
    fn default() -> Self {
        ArsConfig {
            target: 0.8,
            lambda: 100.0,
            temperature: 0.4,
            epochs: 10,
            lr: 5e-2,
            seed: 11,
            data_seed: 4,
        }
    }
}

fn sigmoid(x: f64) -> f64 {
    1.0 / (1.0 + (-x).exp())
}

/// Train Gumbel-Sigmoid masks; final ranks from the expected retained mass.
pub fn ars_alloc(
    cfg: &ModelCfg,
    runner: &MaskGradRunner,
    ac: &ArsConfig,
) -> Result<Allocation> {
    let dims = module_dims(cfg);
    let total_c: f64 = dims.iter().map(|d| d.dense_params() as f64).sum();
    let mut rng = Rng::new(ac.seed);
    // logits start mildly positive: masks begin near-keep
    let mut thetas: Vec<Vec<f64>> = dims.iter().map(|d| vec![1.0; d.r_full()]).collect();
    let mut opt = AdamW::new(AdamWConfig { lr: ac.lr, weight_decay: 0.0, ..Default::default() });

    let steps = runner.batches_per_epoch();
    for epoch in 0..ac.epochs {
        for step in 0..steps {
            // sample soft masks with Gumbel noise
            let mut masks = BTreeMap::new();
            let mut soft: Vec<Vec<f64>> = Vec::with_capacity(dims.len());
            for (i, d) in dims.iter().enumerate() {
                let m: Vec<f64> = thetas[i]
                    .iter()
                    .map(|&t| {
                        let u = rng.f64().clamp(1e-9, 1.0 - 1e-9);
                        let g = -(-(u.ln())).ln(); // Gumbel(0,1)
                        sigmoid((t + g) / ac.temperature)
                    })
                    .collect();
                masks.insert(
                    d.name.clone(),
                    Tensor::from_vec(&[d.r_full()], m.iter().map(|&x| x as f32).collect()),
                );
                soft.push(m);
            }

            let (_loss, dmasks) = runner.step(&masks, epoch * steps + step)?;

            // ratio penalty: (Σ_l R_l·mn_l/C_t − target)², R_l from soft mask
            let achieved: f64 = dims
                .iter()
                .zip(&soft)
                .map(|(d, m)| {
                    let r = m.iter().sum::<f64>() * (d.m + d.n) as f64
                        / (d.m as f64 * d.n as f64);
                    r.min(1.0) * d.dense_params() as f64
                })
                .sum::<f64>()
                / total_c;
            let dpen = 2.0 * (achieved - ac.target) * ac.lambda;

            opt.step();
            for (i, d) in dims.iter().enumerate() {
                let dm = &dmasks[&d.name];
                let drdm = (d.m + d.n) as f64 / (d.m as f64 * d.n as f64);
                let grad: Vec<f64> = thetas[i]
                    .iter()
                    .zip(dm)
                    .zip(&soft[i])
                    .map(|((_t, &g_ce), &s)| {
                        let dsig = s * (1.0 - s) / ac.temperature;
                        (g_ce + dpen * (d.dense_params() as f64 / total_c) * drdm) * dsig
                    })
                    .collect();
                opt.update_f64(&d.name, &mut thetas[i], &grad, 1.0);
            }
        }
    }

    // final per-module ratio from expected retained mass Σσ(θ)
    let ratios: Vec<f64> = dims
        .iter()
        .zip(&thetas)
        .map(|(d, th)| {
            let keep: f64 = th.iter().map(|&t| sigmoid(t)).sum();
            keep * (d.m + d.n) as f64 / (d.m as f64 * d.n as f64)
        })
        .collect();
    Ok(rescale_to_target(
        &dims,
        &ratios,
        ac.target,
        &format!("ars-{}", (ac.target * 100.0).round() as usize),
    ))
}
