//! Dobi-SVD₁: tanh-parameterized truncation boundary. Each module has one
//! trainable scalar b with mask mᵢ = 0.5·tanh(β(b − i)) + 0.5 — monotone by
//! construction (Fig. 1(c)) but with gradients concentrated around i ≈ b:
//! the "local update" weakness ARA's staircase fixes. Trains on the same
//! loss surface as ARA via MaskGradRunner.

use std::collections::BTreeMap;

use crate::ara::{rescale_to_target, MaskGradRunner};
use crate::config::ModelCfg;
use crate::model::{module_dims, Allocation};
use crate::tensor::Tensor;
use crate::training::{AdamW, AdamWConfig};
use crate::Result;

#[derive(Debug, Clone)]
pub struct DobiConfig {
    pub target: f64,
    pub lambda: f64,
    /// tanh sharpness β (paper's Dobi baseline: α=200 on 4096 ranks ⇒ keep
    /// the same *relative* sharpness at our rank counts).
    pub beta: f64,
    pub epochs: usize,
    pub lr: f64,
    /// Seed of the shared [`MaskGradRunner`] data stream.
    pub data_seed: u64,
}

impl Default for DobiConfig {
    fn default() -> Self {
        DobiConfig { target: 0.8, lambda: 100.0, beta: 0.5, epochs: 20, lr: 2.0, data_seed: 5 }
    }
}

/// Train per-module truncation boundaries.
pub fn dobi_alloc(
    cfg: &ModelCfg,
    runner: &MaskGradRunner,
    dc: &DobiConfig,
) -> Result<Allocation> {
    let dims = module_dims(cfg);
    let total_c: f64 = dims.iter().map(|d| d.dense_params() as f64).sum();
    // boundary starts at the target rank position
    let mut bs: Vec<f64> = dims
        .iter()
        .map(|d| dc.target * d.dense_params() as f64 / (d.m + d.n) as f64)
        .collect();
    let mut opt = AdamW::new(AdamWConfig { lr: dc.lr, weight_decay: 0.0, ..Default::default() });

    let steps = runner.batches_per_epoch();
    for epoch in 0..dc.epochs {
        for step in 0..steps {
            let mut masks = BTreeMap::new();
            let mut soft: Vec<Vec<f64>> = Vec::with_capacity(dims.len());
            for (i, d) in dims.iter().enumerate() {
                let m: Vec<f64> = (0..d.r_full())
                    .map(|j| 0.5 * (dc.beta * (bs[i] - j as f64)).tanh() + 0.5)
                    .collect();
                masks.insert(
                    d.name.clone(),
                    Tensor::from_vec(&[d.r_full()], m.iter().map(|&x| x as f32).collect()),
                );
                soft.push(m);
            }

            let (_loss, dmasks) = runner.step(&masks, epoch * steps + step)?;

            let achieved: f64 = dims
                .iter()
                .zip(&soft)
                .map(|(d, m)| {
                    let r = m.iter().sum::<f64>() * (d.m + d.n) as f64
                        / (d.m as f64 * d.n as f64);
                    r.min(1.0) * d.dense_params() as f64
                })
                .sum::<f64>()
                / total_c;
            let dpen = 2.0 * (achieved - dc.target) * dc.lambda;

            opt.step();
            for (i, d) in dims.iter().enumerate() {
                let dm = &dmasks[&d.name];
                let drdm = (d.m + d.n) as f64 / (d.m as f64 * d.n as f64);
                // dm_j/db = 0.5·β·sech²(β(b−j)) — sharply peaked at j≈b
                let mut g = 0.0;
                for (j, &gm) in dm.iter().enumerate() {
                    let t = (dc.beta * (bs[i] - j as f64)).tanh();
                    let dsig = 0.5 * dc.beta * (1.0 - t * t);
                    let gtot = gm + dpen * (d.dense_params() as f64 / total_c) * drdm;
                    g += gtot * dsig;
                }
                let mut b = [bs[i]];
                opt.update_f64(&d.name, &mut b, &[g], 1.0);
                bs[i] = b[0].clamp(1.0, d.r_full() as f64);
            }
        }
    }

    let ratios: Vec<f64> = dims
        .iter()
        .zip(&bs)
        .map(|(d, &b)| b * (d.m + d.n) as f64 / (d.m as f64 * d.n as f64))
        .collect();
    Ok(rescale_to_target(
        &dims,
        &ratios,
        dc.target,
        &format!("dobi-{}", (dc.target * 100.0).round() as usize),
    ))
}
