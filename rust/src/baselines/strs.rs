//! STRS (Sensitivity-based Truncation Rank Searching, from ASVD): probe
//! each module independently at a discrete set of ratios, record the loss
//! increase, then pick the per-module ratio whose sensitivity stays under a
//! uniform threshold — the threshold itself found by bisection against the
//! global budget. Inter-module dependencies are ignored by construction
//! (the paper's criticism; it shows in the Qwen rows of Table 1).

use std::collections::BTreeMap;

use crate::ara::MaskGradRunner;
use crate::config::ModelCfg;
use crate::model::{module_dims, Allocation, ModuleAlloc};
use crate::svd::FactoredModel;
use crate::tensor::Tensor;
use crate::Result;

#[derive(Debug, Clone)]
pub struct StrsConfig {
    /// Candidate per-module parameter ratios (paper: {0.1, …, 0.9}).
    pub ratios: Vec<f64>,
    /// Probe batches per measurement.
    pub probe_batches: usize,
    /// Seed of the shared [`MaskGradRunner`] data stream (the calibration
    /// batch order the sensitivity probes see).
    pub data_seed: u64,
}

impl Default for StrsConfig {
    fn default() -> Self {
        StrsConfig {
            ratios: (1..=9).map(|i| i as f64 / 10.0).collect(),
            probe_batches: 1,
            data_seed: 3,
        }
    }
}

/// Run the STRS search. `runner` supplies the shared loss surface.
pub fn strs_alloc(
    cfg: &ModelCfg,
    runner: &MaskGradRunner,
    _fm: &FactoredModel,
    target: f64,
    sc: &StrsConfig,
) -> Result<Allocation> {
    let dims = module_dims(cfg);

    // all-ones masks = every module at full quality
    let full_masks: BTreeMap<String, Tensor> = dims
        .iter()
        .map(|d| (d.name.clone(), Tensor::ones(&[d.r_full()])))
        .collect();
    let base_loss = probe(runner, &full_masks, sc.probe_batches)?;

    // sensitivity[l][j] = loss increase when ONLY module l is truncated to
    // ratios[j]
    let mut sens: Vec<Vec<f64>> = Vec::with_capacity(dims.len());
    for d in &dims {
        let mut per_ratio = Vec::with_capacity(sc.ratios.len());
        for &rho in &sc.ratios {
            let k = ((rho * d.dense_params() as f64 / (d.m + d.n) as f64).floor() as usize)
                .clamp(1, d.r_full());
            let mut masks = full_masks.clone();
            let mut t = Tensor::zeros(&[d.r_full()]);
            for i in 0..k {
                t.data[i] = 1.0;
            }
            masks.insert(d.name.clone(), t);
            let loss = probe(runner, &masks, sc.probe_batches)?;
            per_ratio.push((loss - base_loss).max(0.0));
        }
        sens.push(per_ratio);
    }

    // bisection on the sensitivity threshold τ: each module takes the
    // SMALLEST ratio with sensitivity ≤ τ; params(τ) is monotone in τ.
    let total: usize = dims.iter().map(|d| d.dense_params()).sum();
    let want = target * total as f64;
    let pick = |tau: f64| -> Vec<usize> {
        sens.iter()
            .map(|per| {
                per.iter().position(|&s| s <= tau).unwrap_or(per.len() - 1)
            })
            .collect()
    };
    let params_of = |choice: &[usize]| -> f64 {
        dims.iter()
            .zip(choice)
            .map(|(d, &j)| {
                let k = ((sc.ratios[j] * d.dense_params() as f64 / (d.m + d.n) as f64)
                    .floor() as usize)
                    .clamp(1, d.r_full());
                d.factored_params(k) as f64
            })
            .sum()
    };

    let max_sens = sens
        .iter()
        .flat_map(|p| p.iter().copied())
        .fold(0.0f64, f64::max)
        .max(1e-9);
    let (mut lo, mut hi) = (0.0f64, max_sens);
    for _ in 0..50 {
        let mid = 0.5 * (lo + hi);
        if params_of(&pick(mid)) > want {
            // too many params kept ⇒ need a harsher (higher) threshold
            lo = mid;
        } else {
            hi = mid;
        }
    }
    let choice = pick(hi);

    let mut alloc = Allocation::new(format!("strs-{}", (target * 100.0).round() as usize));
    for (d, &j) in dims.iter().zip(&choice) {
        let k = ((sc.ratios[j] * d.dense_params() as f64 / (d.m + d.n) as f64).floor() as usize)
            .clamp(1, d.r_full());
        alloc.set(&d.name, ModuleAlloc::Rank(k));
    }
    Ok(alloc)
}

fn probe(
    runner: &MaskGradRunner,
    masks: &BTreeMap<String, Tensor>,
    batches: usize,
) -> Result<f64> {
    let mut sum = 0.0;
    for i in 0..batches.max(1) {
        let (loss, _) = runner.step(masks, i)?;
        sum += loss;
    }
    Ok(sum / batches.max(1) as f64)
}
