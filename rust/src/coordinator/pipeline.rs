//! The staged experiment pipeline with disk caching of the expensive
//! stages (pre-trained weights under runs/<model>/), so the 12 bench
//! harnesses share substrate work instead of repeating it.

use std::collections::BTreeMap;

use crate::ara::{train_ara, AraConfig, MaskGradRunner};
use crate::baselines::{
    ars_alloc, dlp_alloc, dobi_alloc, farms_alloc, strs_alloc, uniform_alloc, ArsConfig,
    DobiConfig, StrsConfig,
};
use crate::config::{model_by_name, scaled, ModelCfg, Paths};
use crate::eval::zeroshot::Scorer;
use crate::eval::{perplexity_masked, zero_shot_suite};
use crate::linalg::Mat;
use crate::model::{alloc_ratio, Allocation, WeightStore};
use crate::runtime::Runtime;
use crate::serving::Engine;
use crate::svd::{alloc_masks, calibrate, factorize, FactoredModel};
use crate::training::{pretrain, PretrainConfig};
use crate::Result;

/// Experiment-scale knobs (all counts, no shapes) with bench defaults.
#[derive(Debug, Clone)]
pub struct RunScale {
    pub pretrain_steps: usize,
    pub calib_batches: usize,
    pub alloc_samples: usize,
    pub alloc_epochs: usize,
    pub eval_batches: usize,
    pub zs_items: usize,
}

impl Default for RunScale {
    fn default() -> Self {
        // scaled by ARA_SCALE (config::scaled)
        RunScale {
            // NOT scaled by ARA_SCALE: the pre-trained substrate is cached
            // on disk and shared by every harness regardless of scale
            // (override with ARA_PRETRAIN_STEPS)
            pretrain_steps: std::env::var("ARA_PRETRAIN_STEPS")
                .ok()
                .and_then(|v| v.parse().ok())
                .unwrap_or(1200),
            calib_batches: scaled(8, 2),
            alloc_samples: scaled(96, 16),
            alloc_epochs: scaled(10, 3),
            eval_batches: scaled(6, 2),
            zs_items: scaled(24, 8),
        }
    }
}

/// All allocation methods of Table 1/2.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MethodKind {
    Uniform,
    Dlp,
    Farms,
    Strs,
    Ars,
    Dobi,
    Ara,
    /// ARA without the guidance loss (Table 5 / Fig. 4b ablation).
    AraNoGuidance,
}

pub const ALL_METHODS: [MethodKind; 7] = [
    MethodKind::Uniform,
    MethodKind::Dlp,
    MethodKind::Farms,
    MethodKind::Strs,
    MethodKind::Ars,
    MethodKind::Dobi,
    MethodKind::Ara,
];

impl MethodKind {
    pub fn name(&self) -> &'static str {
        match self {
            MethodKind::Uniform => "Uniform",
            MethodKind::Dlp => "DLP",
            MethodKind::Farms => "FARMS",
            MethodKind::Strs => "STRS",
            MethodKind::Ars => "ARS",
            MethodKind::Dobi => "Dobi-SVD1",
            MethodKind::Ara => "ARA",
            MethodKind::AraNoGuidance => "ARA(noLg)",
        }
    }
}

/// One evaluated configuration: the Table 1 row.
#[derive(Debug, Clone)]
pub struct EvalRow {
    pub method: String,
    pub ratio: f64,
    pub wiki_ppl: f64,
    pub c4_ppl: f64,
    pub task_accs: Vec<(&'static str, f64)>,
    pub avg_acc: f64,
}

/// The coordinator: one model's runtime + cached substrate state.
pub struct Pipeline {
    pub cfg: ModelCfg,
    pub rt: Runtime,
    pub paths: Paths,
    pub scalecfg: RunScale,
}

impl Pipeline {
    pub fn new(model: &str) -> Result<Pipeline> {
        let paths = Paths::discover()?;
        let cfg = model_by_name(&paths.configs, model)?;
        let rt = Runtime::new(paths.artifact_dir(model))?;
        Ok(Pipeline { cfg, rt, paths, scalecfg: RunScale::default() })
    }

    /// Pre-trained weights (disk-cached under runs/<model>/weights-<steps>.bin).
    pub fn pretrained(&self) -> Result<WeightStore> {
        let steps = self.scalecfg.pretrain_steps;
        let path = self.paths.run_dir(&self.cfg.name).join(format!("weights-{steps}.bin"));
        if path.exists() {
            return crate::model::load_weights(&path);
        }
        let pc = PretrainConfig { steps, ..Default::default() };
        let (ws, report) = pretrain(&self.cfg, &self.rt, &pc)?;
        eprintln!(
            "[pipeline {}] pretrained {} steps: loss {:.3} → {:.3}",
            self.cfg.name, steps, report.initial_loss, report.final_loss
        );
        crate::model::save_weights(&ws, &path)?;
        Ok(ws)
    }

    pub fn grams(&self, ws: &WeightStore) -> Result<BTreeMap<String, Mat>> {
        calibrate(&self.cfg, &self.rt, ws, "sync4", self.scalecfg.calib_batches, 0xCAFE)
    }

    pub fn factored(
        &self,
        ws: &WeightStore,
        grams: &BTreeMap<String, Mat>,
    ) -> Result<FactoredModel> {
        factorize(&self.cfg, ws, grams, 1e-3)
    }

    /// Build an allocation-specialized serving [`Engine`] at batch size
    /// `batch`, resolving `alloc_name` with the same precedence as the
    /// artifact builders (configs/allocations → artifacts/allocations →
    /// computed `dense` / `uniform-R` / `ara-R`). This is the front door
    /// the serving benches and the continuous-batching scheduler share.
    pub fn engine(
        &self,
        ws: &WeightStore,
        fm: &FactoredModel,
        alloc_name: &str,
        batch: usize,
    ) -> Result<Engine> {
        let alloc = crate::runtime::resolve_alloc(&self.cfg, &self.paths, alloc_name)?;
        Engine::new(&self.cfg, &self.rt, ws, fm, &alloc, alloc_name, batch)
    }

    /// Run one allocation method at `target`.
    #[allow(clippy::too_many_arguments)]
    pub fn allocate(
        &self,
        method: MethodKind,
        target: f64,
        ws: &WeightStore,
        grams: &BTreeMap<String, Mat>,
        fm: &FactoredModel,
    ) -> Result<Allocation> {
        let sc = &self.scalecfg;
        match method {
            MethodKind::Uniform => Ok(uniform_alloc(&self.cfg, target)),
            MethodKind::Dlp => Ok(dlp_alloc(&self.cfg, ws, grams, target, 0.15)),
            MethodKind::Farms => Ok(farms_alloc(&self.cfg, fm, target, 0.3)),
            MethodKind::Strs => {
                let runner =
                    MaskGradRunner::new(&self.cfg, &self.rt, ws, fm, "sync4", sc.alloc_samples, 3)?;
                strs_alloc(&self.cfg, &runner, fm, target, &StrsConfig::default())
            }
            MethodKind::Ars => {
                let runner =
                    MaskGradRunner::new(&self.cfg, &self.rt, ws, fm, "sync4", sc.alloc_samples, 4)?;
                let ac = ArsConfig { target, epochs: sc.alloc_epochs, ..Default::default() };
                ars_alloc(&self.cfg, &runner, &ac)
            }
            MethodKind::Dobi => {
                let runner =
                    MaskGradRunner::new(&self.cfg, &self.rt, ws, fm, "sync4", sc.alloc_samples, 5)?;
                let dc = DobiConfig { target, epochs: sc.alloc_epochs * 2, ..Default::default() };
                dobi_alloc(&self.cfg, &runner, &dc)
            }
            MethodKind::Ara | MethodKind::AraNoGuidance => {
                let ac = AraConfig {
                    target,
                    epochs: sc.alloc_epochs,
                    samples: sc.alloc_samples,
                    use_guidance: method == MethodKind::Ara,
                    ..Default::default()
                };
                let (alloc, _) = train_ara(&self.cfg, &self.rt, ws, fm, &ac)?;
                Ok(alloc)
            }
        }
    }

    /// Evaluate a compressed configuration into a table row.
    pub fn evaluate(
        &self,
        label: &str,
        ws: &WeightStore,
        fm: &FactoredModel,
        alloc: &Allocation,
    ) -> Result<EvalRow> {
        let masks = alloc_masks(&self.cfg, alloc);
        self.evaluate_masks(label, alloc_ratio(&self.cfg, alloc), ws, fm, &masks)
    }

    /// Evaluate with explicit masks (LoRA-merged models etc.).
    pub fn evaluate_masks(
        &self,
        label: &str,
        ratio: f64,
        ws: &WeightStore,
        fm: &FactoredModel,
        masks: &BTreeMap<String, crate::tensor::Tensor>,
    ) -> Result<EvalRow> {
        let sc = &self.scalecfg;
        let wiki = perplexity_masked(&self.cfg, &self.rt, ws, fm, masks, "synwiki", sc.eval_batches)?;
        let c4 = perplexity_masked(&self.cfg, &self.rt, ws, fm, masks, "sync4", sc.eval_batches)?;
        let zs = zero_shot_suite(
            &self.cfg,
            &self.rt,
            &Scorer::Masked { ws, fm, masks },
            sc.zs_items,
            99,
        )?;
        Ok(EvalRow {
            method: label.to_string(),
            ratio,
            wiki_ppl: wiki.ppl,
            c4_ppl: c4.ppl,
            task_accs: zs.tasks,
            avg_acc: zs.average,
        })
    }

    /// Evaluate the *dense* model (the "Dense" reference row).
    pub fn evaluate_dense(&self, ws: &WeightStore) -> Result<EvalRow> {
        let sc = &self.scalecfg;
        let wiki =
            crate::eval::perplexity_dense(&self.cfg, &self.rt, ws, "synwiki", sc.eval_batches)?;
        let c4 = crate::eval::perplexity_dense(&self.cfg, &self.rt, ws, "sync4", sc.eval_batches)?;
        let zs = zero_shot_suite(&self.cfg, &self.rt, &Scorer::Dense { ws }, sc.zs_items, 99)?;
        Ok(EvalRow {
            method: "Dense".to_string(),
            ratio: 1.0,
            wiki_ppl: wiki.ppl,
            c4_ppl: c4.ppl,
            task_accs: zs.tasks,
            avg_acc: zs.average,
        })
    }
}
