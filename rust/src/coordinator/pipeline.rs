//! The staged experiment pipeline with disk caching of the expensive
//! stages (pre-trained weights under runs/<model>/), so the 12 bench
//! harnesses share substrate work instead of repeating it.
//!
//! Allocation routes through the unified method registry
//! (`compress::registry`): [`Pipeline::allocate_spec`] turns a spec like
//! `ara@0.8?epochs=5` into a versioned [`CompressionPlan`], and
//! [`Pipeline::sweep`] drives whole spec × ratio grids over the shared
//! calibration cache. The old `MethodKind` entry point survives as a
//! deprecated shim for one release.

use std::collections::BTreeMap;
use std::time::Instant;

use crate::compress::{registry, AllocCtx, CompressionPlan, PlanScale, RunScale};
use crate::config::{model_by_name, ModelCfg, Paths};
use crate::eval::zeroshot::Scorer;
use crate::eval::{perplexity_masked, zero_shot_suite};
use crate::linalg::Mat;
use crate::model::{alloc_ratio, Allocation, WeightStore};
use crate::runtime::Runtime;
use crate::serving::Engine;
use crate::svd::{alloc_masks, calibrate, factorize, FactoredModel};
use crate::training::{pretrain, PretrainConfig};
use crate::Result;

/// One evaluated configuration: the Table 1 row.
#[derive(Debug, Clone)]
pub struct EvalRow {
    pub method: String,
    pub ratio: f64,
    pub wiki_ppl: f64,
    pub c4_ppl: f64,
    pub task_accs: Vec<(&'static str, f64)>,
    pub avg_acc: f64,
}

/// The coordinator: one model's runtime + cached substrate state.
pub struct Pipeline {
    pub cfg: ModelCfg,
    pub rt: Runtime,
    pub paths: Paths,
    pub scalecfg: RunScale,
}

impl Pipeline {
    pub fn new(model: &str) -> Result<Pipeline> {
        let paths = Paths::discover()?;
        let cfg = model_by_name(&paths.configs, model)?;
        let rt = Runtime::new(paths.artifact_dir(model))?;
        Ok(Pipeline { cfg, rt, paths, scalecfg: RunScale::default() })
    }

    /// Pre-trained weights (disk-cached under runs/<model>/weights-<steps>.bin).
    pub fn pretrained(&self) -> Result<WeightStore> {
        let steps = self.scalecfg.pretrain_steps;
        let path = self.paths.run_dir(&self.cfg.name).join(format!("weights-{steps}.bin"));
        if path.exists() {
            return crate::model::load_weights(&path);
        }
        let pc = PretrainConfig { steps, ..Default::default() };
        let (ws, report) = pretrain(&self.cfg, &self.rt, &pc)?;
        eprintln!(
            "[pipeline {}] pretrained {} steps: loss {:.3} → {:.3}",
            self.cfg.name, steps, report.initial_loss, report.final_loss
        );
        crate::model::save_weights(&ws, &path)?;
        Ok(ws)
    }

    pub fn grams(&self, ws: &WeightStore) -> Result<BTreeMap<String, Mat>> {
        calibrate(&self.cfg, &self.rt, ws, "sync4", self.scalecfg.calib_batches, 0xCAFE)
    }

    pub fn factored(
        &self,
        ws: &WeightStore,
        grams: &BTreeMap<String, Mat>,
    ) -> Result<FactoredModel> {
        factorize(&self.cfg, ws, grams, 1e-3)
    }

    /// The borrowed substrate bundle every [`crate::compress::AllocMethod`]
    /// consumes.
    pub fn alloc_ctx<'a>(
        &'a self,
        ws: &'a WeightStore,
        grams: &'a BTreeMap<String, Mat>,
        fm: &'a FactoredModel,
    ) -> AllocCtx<'a> {
        AllocCtx {
            cfg: &self.cfg,
            rt: &self.rt,
            ws,
            grams,
            fm,
            scale: &self.scalecfg,
        }
    }

    /// Run the allocation method a spec names (`ara@0.8`,
    /// `dobi@0.75?epochs=20`, …) and wrap the result in a versioned
    /// [`CompressionPlan`] recording spec, achieved ratio, seed, scale
    /// knobs, and wall time. Unknown methods/parameters fail with the
    /// spec named; a spec without an `@target` is an error here.
    pub fn allocate_spec(
        &self,
        spec: &str,
        ws: &WeightStore,
        grams: &BTreeMap<String, Mat>,
        fm: &FactoredModel,
    ) -> Result<CompressionPlan> {
        let (parsed, method) = registry::method_for(spec)?;
        let target = parsed.target.ok_or_else(|| {
            crate::anyhow!(
                "spec `{spec}` has no target ratio (expected `{}@<ratio>`)",
                parsed.method
            )
        })?;
        let ctx = self.alloc_ctx(ws, grams, fm);
        let t0 = Instant::now();
        let mut allocation = method.allocate(&ctx, target)?;
        // Compose the spec's quant recipe (`?quant=int8&group=32`) onto the
        // allocation and rename it so the runtime's executable cache never
        // conflates a quantized variant with its f32 sibling.
        if let Some(q) = registry::quant_params(&parsed)? {
            allocation.quant = Some(q);
            allocation.name = format!("{}-q{}g{}", allocation.name, q.bits, q.group);
        }
        Ok(CompressionPlan {
            schema_version: crate::compress::PLAN_SCHEMA_VERSION,
            spec: parsed.canonical(),
            method: method.id().to_string(),
            label: method.label().to_string(),
            target,
            achieved: alloc_ratio(&self.cfg, &allocation),
            seed: method.seed(),
            // effective budget (spec overrides included), not the raw
            // RunScale defaults — provenance must match what actually ran
            scale: method.budget(&self.scalecfg),
            wall_ms: t0.elapsed().as_secs_f64() * 1e3,
            allocation,
        })
    }

    /// Drive a spec × ratio grid (the Table 1/2 shape) through the shared
    /// substrate: pretrain/calibrate/factorize run **once** (disk- and
    /// in-process-cached) and every cell reuses them. Specs carrying an
    /// explicit `@target` run once as-is; bare specs (`ara`, `dlp?tail=0.2`)
    /// are crossed with every entry of `ratios`. Returns one plan per cell,
    /// in grid order.
    pub fn sweep(&self, specs: &[String], ratios: &[f64]) -> Result<Vec<CompressionPlan>> {
        let ws = self.pretrained()?;
        let grams = self.grams(&ws)?;
        let fm = self.factored(&ws, &grams)?;
        let mut plans = Vec::new();
        for spec in specs {
            let parsed = registry::MethodSpec::parse(spec)?;
            registry::build_method(&parsed)?; // fail fast, before any training
            let cells: Vec<String> = if parsed.target.is_some() {
                vec![parsed.canonical()]
            } else {
                ratios.iter().map(|r| parsed.with_target(*r).canonical()).collect()
            };
            for cell in cells {
                let plan = self.allocate_spec(&cell, &ws, &grams, &fm)?;
                eprintln!(
                    "[sweep {}] {}: achieved {:.4}, dense {}/{}, {:.0} ms",
                    self.cfg.name,
                    plan.spec,
                    plan.achieved,
                    plan.allocation.dense_count(),
                    plan.allocation.modules.len(),
                    plan.wall_ms
                );
                plans.push(plan);
            }
        }
        Ok(plans)
    }

    /// Build an allocation-specialized serving [`Engine`] at batch size
    /// `batch`, resolving `alloc_name` with the same precedence as the
    /// artifact builders (configs/allocations → artifacts/allocations →
    /// computed `dense` / `uniform-R` / `ara-R`). Both [`CompressionPlan`]
    /// files and legacy bare-`Allocation` files resolve; a plan's
    /// provenance is threaded into the engine's serving stats. This is the
    /// front door the serving benches and the scheduler share.
    pub fn engine(
        &self,
        ws: &WeightStore,
        fm: &FactoredModel,
        alloc_name: &str,
        batch: usize,
    ) -> Result<Engine> {
        let plan = crate::runtime::resolve_plan(&self.cfg, &self.paths, alloc_name)?;
        let mut engine =
            Engine::new(&self.cfg, &self.rt, ws, fm, &plan.allocation, alloc_name, batch)?;
        if plan.provenanced() {
            engine.set_provenance(plan.provenance_line());
        }
        Ok(engine)
    }

    /// Build a serving [`Engine`] directly from a [`CompressionPlan`]: the
    /// plan is published under `artifacts/allocations/` (so the artifact
    /// builders resolve the identical allocation) and its provenance is
    /// threaded into the engine.
    pub fn engine_for_plan(
        &self,
        ws: &WeightStore,
        fm: &FactoredModel,
        plan: &CompressionPlan,
        batch: usize,
    ) -> Result<Engine> {
        let name = plan.allocation.name.clone();
        let path = self
            .paths
            .artifacts
            .join("allocations")
            .join(format!("{}.{}.json", self.cfg.name, name));
        plan.save(&path)?;
        let mut engine = Engine::new(&self.cfg, &self.rt, ws, fm, &plan.allocation, &name, batch)?;
        if plan.provenanced() {
            engine.set_provenance(plan.provenance_line());
        }
        Ok(engine)
    }

    /// Run one allocation method at `target` (legacy enum entry point).
    #[deprecated(note = "use Pipeline::allocate_spec with a registry spec (`ara@0.8`)")]
    #[allow(deprecated)]
    pub fn allocate(
        &self,
        method: crate::compress::MethodKind,
        target: f64,
        ws: &WeightStore,
        grams: &BTreeMap<String, Mat>,
        fm: &FactoredModel,
    ) -> Result<Allocation> {
        self.allocate_spec(&format!("{}@{target}", method.spec_id()), ws, grams, fm)
            .map(|p| p.allocation)
    }

    /// Evaluate a compressed configuration into a table row.
    pub fn evaluate(
        &self,
        label: &str,
        ws: &WeightStore,
        fm: &FactoredModel,
        alloc: &Allocation,
    ) -> Result<EvalRow> {
        let masks = alloc_masks(&self.cfg, alloc);
        self.evaluate_masks(label, alloc_ratio(&self.cfg, alloc), ws, fm, &masks)
    }

    /// Evaluate with explicit masks (LoRA-merged models etc.).
    pub fn evaluate_masks(
        &self,
        label: &str,
        ratio: f64,
        ws: &WeightStore,
        fm: &FactoredModel,
        masks: &BTreeMap<String, crate::tensor::Tensor>,
    ) -> Result<EvalRow> {
        let sc = &self.scalecfg;
        let wiki = perplexity_masked(&self.cfg, &self.rt, ws, fm, masks, "synwiki", sc.eval_batches)?;
        let c4 = perplexity_masked(&self.cfg, &self.rt, ws, fm, masks, "sync4", sc.eval_batches)?;
        let zs = zero_shot_suite(
            &self.cfg,
            &self.rt,
            &Scorer::Masked { ws, fm, masks },
            sc.zs_items,
            99,
        )?;
        Ok(EvalRow {
            method: label.to_string(),
            ratio,
            wiki_ppl: wiki.ppl,
            c4_ppl: c4.ppl,
            task_accs: zs.tasks,
            avg_acc: zs.average,
        })
    }

    /// Evaluate the *dense* model (the "Dense" reference row).
    pub fn evaluate_dense(&self, ws: &WeightStore) -> Result<EvalRow> {
        let sc = &self.scalecfg;
        let wiki =
            crate::eval::perplexity_dense(&self.cfg, &self.rt, ws, "synwiki", sc.eval_batches)?;
        let c4 = crate::eval::perplexity_dense(&self.cfg, &self.rt, ws, "sync4", sc.eval_batches)?;
        let zs = zero_shot_suite(&self.cfg, &self.rt, &Scorer::Dense { ws }, sc.zs_items, 99)?;
        Ok(EvalRow {
            method: "Dense".to_string(),
            ratio: 1.0,
            wiki_ppl: wiki.ppl,
            c4_ppl: c4.ppl,
            task_accs: zs.tasks,
            avg_acc: zs.average,
        })
    }
}
