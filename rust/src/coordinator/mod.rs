//! Experiment coordinator: the staged pipeline every table/figure harness
//! drives — pretrain (disk-cached) → calibrate → factorize → allocate
//! (any registry method spec) → evaluate. The method registry itself
//! lives in [`crate::compress`]; the legacy `MethodKind` surface is
//! re-exported here as a deprecated shim for one release.

mod pipeline;

pub use pipeline::{EvalRow, Pipeline};

pub use crate::compress::RunScale;
#[allow(deprecated)]
pub use crate::compress::{MethodKind, ALL_METHODS};
