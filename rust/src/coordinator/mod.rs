//! Experiment coordinator: the staged pipeline every table/figure harness
//! drives — pretrain (disk-cached) → calibrate → factorize → allocate
//! (any method) → evaluate — plus the method registry.

mod pipeline;

pub use pipeline::{EvalRow, MethodKind, Pipeline, RunScale, ALL_METHODS};
