//! Explicit-SIMD tier for the f32 matmul micro-kernels, selected once per
//! process by runtime CPU-feature detection (the squirrel-json idiom: a
//! portable scalar reference plus per-ISA `#[target_feature]` modules, with
//! unsafe confined to the intrinsics bodies).
//!
//! Three micro-kernels are dispatched, matching the inner loops of
//! [`crate::kernels`]:
//!
//! * [`axpy`] — `out[j] += a * b[j]`, the j-contiguous inner loop of the
//!   packed kernel. Elementwise multiply-then-add (never a fused
//!   multiply-add), so the result is bitwise-identical at **any** vector
//!   width: every tier agrees with scalar bit-for-bit.
//! * [`dot`] — the small-m fast-path dot product, defined by an explicit
//!   **8-virtual-lane contract**: 8 independent partial sums over full
//!   8-element chunks, a fixed 3-level reduction tree
//!   (`s[l] = acc[l] + acc[l+4]`, `t0 = s0 + s2`, `t1 = s1 + s3`,
//!   `total = t0 + t1`), then a sequential scalar tail. Every tier
//!   implements this exact schedule — AVX2 with one 8-lane register,
//!   NEON with two 4-lane registers, AVX-512 by reusing the 8-lane AVX2
//!   kernel (16 lanes would change the reduction shape) — so the dot is
//!   also bitwise-identical across tiers.
//! * [`dot_q8`] — the int8×f32-accumulate dot of the quantized serving
//!   path: identical schedule to [`dot`], with each weight dequantized
//!   inline as `code as f32 * scale` (two separate multiplies per
//!   element). Because the conversion is exact and the accumulation
//!   order is the f32 contract's, `dot_q8(x, q, s, g)` is bitwise-equal
//!   to `dot(x, dequant(q, s, g))` on every tier.
//!
//! Tier choice: best available by default, forcible with `ARA_SIMD`
//! (`scalar` | `avx2` | `avx512` | `neon` | `native`). Forcing a tier the
//! CPU lacks warns on stderr and falls back to the best available one.
//! The AVX-512 module additionally needs the `avx512` cargo feature (its
//! intrinsics require a recent stable toolchain).

use std::sync::OnceLock;

#[cfg(target_arch = "x86_64")]
pub mod avx2;
#[cfg(all(target_arch = "x86_64", feature = "avx512"))]
pub mod avx512;
#[cfg(target_arch = "aarch64")]
pub mod neon;
pub mod scalar;

/// One ISA tier. All variants exist on every target so `ARA_SIMD` parsing
/// and tier naming are portable; availability is a runtime property.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimdTier {
    Scalar,
    Avx2,
    Avx512,
    Neon,
}

impl SimdTier {
    /// Stable lowercase name (env values, bench keys, stats).
    pub fn name(self) -> &'static str {
        match self {
            SimdTier::Scalar => "scalar",
            SimdTier::Avx2 => "avx2",
            SimdTier::Avx512 => "avx512",
            SimdTier::Neon => "neon",
        }
    }

    fn parse(s: &str) -> Option<SimdTier> {
        match s {
            "scalar" => Some(SimdTier::Scalar),
            "avx2" => Some(SimdTier::Avx2),
            "avx512" => Some(SimdTier::Avx512),
            "neon" => Some(SimdTier::Neon),
            _ => None,
        }
    }

    /// Can this tier run on the current CPU (and build)?
    pub fn is_available(self) -> bool {
        match self {
            SimdTier::Scalar => true,
            SimdTier::Avx2 => {
                #[cfg(target_arch = "x86_64")]
                {
                    std::arch::is_x86_feature_detected!("avx2")
                }
                #[cfg(not(target_arch = "x86_64"))]
                {
                    false
                }
            }
            SimdTier::Avx512 => {
                // axpy needs avx512f; the dot delegates to the AVX2 kernel,
                // so the tier requires both. Gated behind the `avx512`
                // cargo feature until the intrinsics baseline is everywhere.
                #[cfg(all(target_arch = "x86_64", feature = "avx512"))]
                {
                    std::arch::is_x86_feature_detected!("avx512f")
                        && std::arch::is_x86_feature_detected!("avx2")
                }
                #[cfg(not(all(target_arch = "x86_64", feature = "avx512")))]
                {
                    false
                }
            }
            SimdTier::Neon => cfg!(target_arch = "aarch64"),
        }
    }
}

/// Every tier runnable on this CPU, best first; `Scalar` is always last.
/// Parity tests and `perf_micro` enumerate this instead of mutating
/// `ARA_SIMD` (the active tier is latched once per process).
pub fn available_tiers() -> Vec<SimdTier> {
    let mut tiers = Vec::with_capacity(4);
    for t in [SimdTier::Avx512, SimdTier::Avx2, SimdTier::Neon] {
        if t.is_available() {
            tiers.push(t);
        }
    }
    tiers.push(SimdTier::Scalar);
    tiers
}

/// The process-wide tier: `ARA_SIMD` if set (warning + best-available
/// fallback when the named tier can't run here), else the best available.
/// Latched on first use, like `ARA_THREADS`.
pub fn active_tier() -> SimdTier {
    static TIER: OnceLock<SimdTier> = OnceLock::new();
    *TIER.get_or_init(|| {
        let best = available_tiers()[0];
        let Ok(raw) = std::env::var("ARA_SIMD") else {
            return best;
        };
        let s = raw.trim().to_ascii_lowercase();
        if s.is_empty() || s == "native" || s == "auto" {
            return best;
        }
        match SimdTier::parse(&s) {
            Some(t) if t.is_available() => t,
            Some(t) => {
                eprintln!(
                    "ARA_SIMD={}: tier `{}` not available on this CPU/build, using `{}`",
                    raw,
                    t.name(),
                    best.name()
                );
                best
            }
            None => {
                eprintln!(
                    "ARA_SIMD={raw}: unknown tier (expected scalar|avx2|avx512|neon|native), \
                     using `{}`",
                    best.name()
                );
                best
            }
        }
    })
}

/// `out[j] += a * b[j]` over `min(out.len(), b.len())` elements on `tier`.
///
/// The caller decides the `a == 0.0` skip (zero-rank row elision) *before*
/// dispatch, so skipping is tier-independent and NaN rows in `b` are
/// elided identically on every tier.
#[inline]
pub fn axpy(tier: SimdTier, out: &mut [f32], b: &[f32], a: f32) {
    match tier {
        #[cfg(all(target_arch = "x86_64", feature = "avx512"))]
        // SAFETY: dispatch reaches this arm only when `active_tier`/the
        // caller verified `is_available()`, i.e. avx512f is present.
        SimdTier::Avx512 => unsafe { avx512::axpy(out, b, a) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as above — Avx2 is only selected when avx2 is detected.
        SimdTier::Avx2 => unsafe { avx2::axpy(out, b, a) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: neon is a baseline feature of aarch64.
        SimdTier::Neon => unsafe { neon::axpy(out, b, a) },
        _ => scalar::axpy(out, b, a),
    }
}

/// Dot product of `x`/`y` under the 8-virtual-lane contract on `tier`.
/// AVX-512 reuses the AVX2 kernel: the contract is defined in 8-lane
/// chunks, and widening to 16 lanes would change the reduction order.
#[inline]
pub fn dot(tier: SimdTier, x: &[f32], y: &[f32]) -> f32 {
    match tier {
        #[cfg(all(target_arch = "x86_64", feature = "avx512"))]
        // SAFETY: Avx512 availability requires avx2 detection (see
        // `is_available`), which is what the AVX2 kernel needs.
        SimdTier::Avx512 => unsafe { avx2::dot(x, y) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx2 is only selected when avx2 is detected.
        SimdTier::Avx2 => unsafe { avx2::dot(x, y) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: neon is a baseline feature of aarch64.
        SimdTier::Neon => unsafe { neon::dot(x, y) },
        _ => scalar::dot(x, y),
    }
}

/// Int8 dot product with inline per-group dequantization on `tier`:
/// `Σ x[i] · (q[i] as f32 * scales[i / group])` under the 8-virtual-lane
/// contract. Bitwise-equal to [`dot`] over the dequantized weights on
/// every tier; AVX-512 reuses the AVX2 kernel for the same reason [`dot`]
/// does.
#[inline]
pub fn dot_q8(tier: SimdTier, x: &[f32], q: &[i8], scales: &[f32], group: usize) -> f32 {
    match tier {
        #[cfg(all(target_arch = "x86_64", feature = "avx512"))]
        // SAFETY: Avx512 availability requires avx2 detection (see
        // `is_available`), which is what the AVX2 kernel needs.
        SimdTier::Avx512 => unsafe { avx2::dot_q8(x, q, scales, group) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx2 is only selected when avx2 is detected.
        SimdTier::Avx2 => unsafe { avx2::dot_q8(x, q, scales, group) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: neon is a baseline feature of aarch64.
        SimdTier::Neon => unsafe { neon::dot_q8(x, q, scales, group) },
        _ => scalar::dot_q8(x, q, scales, group),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tier_names_round_trip_through_parse() {
        for t in [SimdTier::Scalar, SimdTier::Avx2, SimdTier::Avx512, SimdTier::Neon] {
            assert_eq!(SimdTier::parse(t.name()), Some(t));
        }
        assert_eq!(SimdTier::parse("sse9"), None);
        assert_eq!(SimdTier::parse("native"), None); // handled before parse
    }

    #[test]
    fn available_tiers_ends_with_scalar_and_is_runnable() {
        let tiers = available_tiers();
        assert_eq!(*tiers.last().unwrap(), SimdTier::Scalar);
        for t in &tiers {
            assert!(t.is_available(), "listed tier {} not available", t.name());
        }
        // best-first: scalar appears exactly once, at the end
        assert_eq!(tiers.iter().filter(|&&t| t == SimdTier::Scalar).count(), 1);
    }

    #[test]
    fn active_tier_is_among_available() {
        assert!(available_tiers().contains(&active_tier()));
    }

    #[test]
    fn scalar_dot_follows_the_8_lane_contract() {
        // hand-evaluate the contract on an 11-element input: one full
        // 8-chunk through the tree, then a 3-element sequential tail
        let x: Vec<f32> = (1..=11).map(|i| i as f32 * 0.5).collect();
        let y: Vec<f32> = (1..=11).map(|i| 1.0 / i as f32).collect();
        let mut acc = [0.0f32; 8];
        for l in 0..8 {
            acc[l] += x[l] * y[l];
        }
        let (s0, s1, s2, s3) =
            (acc[0] + acc[4], acc[1] + acc[5], acc[2] + acc[6], acc[3] + acc[7]);
        let mut want = (s0 + s2) + (s1 + s3);
        for i in 8..11 {
            want += x[i] * y[i];
        }
        assert_eq!(scalar::dot(&x, &y).to_bits(), want.to_bits());
    }

    #[test]
    fn scalar_dot_q8_matches_dot_over_dequant_bitwise() {
        // 19 elements, group 5: chunks cross group boundaries, tail is odd
        let x: Vec<f32> = (0..19).map(|i| (i as f32 * 0.7).cos()).collect();
        let q: Vec<i8> = (0..19).map(|i| ((i * 53 % 255) as i32 - 127) as i8).collect();
        let scales: Vec<f32> = (0..4).map(|g| 0.01 + g as f32 * 0.003).collect();
        let y: Vec<f32> = (0..19).map(|i| q[i] as f32 * scales[i / 5]).collect();
        assert_eq!(scalar::dot_q8(&x, &q, &scales, 5).to_bits(), scalar::dot(&x, &y).to_bits());
    }

    #[test]
    fn scalar_axpy_matches_plain_loop_bitwise() {
        let b: Vec<f32> = (0..13).map(|i| (i as f32).sin()).collect();
        let mut out = vec![0.25f32; 13];
        let mut want = out.clone();
        scalar::axpy(&mut out, &b, 1.5);
        for (o, &bv) in want.iter_mut().zip(&b) {
            *o += 1.5 * bv;
        }
        assert_eq!(
            out.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            want.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }
}
