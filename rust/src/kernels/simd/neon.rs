//! NEON micro-kernels (aarch64 baseline). The dot implements the shared
//! 8-virtual-lane contract with two 4-lane accumulators: `acc0` holds
//! virtual lanes 0..4, `acc1` lanes 4..8, and `vaddq(acc0, acc1)` is
//! exactly the contract's `s[l] = acc[l] + acc[l+4]` step.

use std::arch::aarch64::*;

/// `out[j] += a * b[j]` over the zipped length, 4 lanes at a time with a
/// scalar tail. `vmulq` + `vaddq` (no fused multiply-add), matching
/// scalar bitwise.
///
/// # Safety
/// NEON is a baseline aarch64 feature; callers reach this only on aarch64.
#[target_feature(enable = "neon")]
pub unsafe fn axpy(out: &mut [f32], b: &[f32], a: f32) {
    let n = out.len().min(b.len());
    let av = vdupq_n_f32(a);
    let mut j = 0;
    while j + 4 <= n {
        let ov = vld1q_f32(out.as_ptr().add(j));
        let bv = vld1q_f32(b.as_ptr().add(j));
        vst1q_f32(out.as_mut_ptr().add(j), vaddq_f32(ov, vmulq_f32(av, bv)));
        j += 4;
    }
    while j < n {
        *out.get_unchecked_mut(j) += a * *b.get_unchecked(j);
        j += 1;
    }
}

/// Dot product under the 8-virtual-lane contract: two q-register
/// accumulators per 8-chunk, `s = vaddq(acc0, acc1)`, then the fixed
/// `(s0+s2) + (s1+s3)` tree via lane extraction; sequential scalar tail.
///
/// # Safety
/// NEON is a baseline aarch64 feature; callers reach this only on aarch64.
#[target_feature(enable = "neon")]
pub unsafe fn dot(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len(), "dot operand lengths");
    let n = x.len();
    let chunks = n / 8;
    let mut acc0 = vdupq_n_f32(0.0); // virtual lanes 0..4
    let mut acc1 = vdupq_n_f32(0.0); // virtual lanes 4..8
    for c in 0..chunks {
        let x0 = vld1q_f32(x.as_ptr().add(c * 8));
        let x1 = vld1q_f32(x.as_ptr().add(c * 8 + 4));
        let y0 = vld1q_f32(y.as_ptr().add(c * 8));
        let y1 = vld1q_f32(y.as_ptr().add(c * 8 + 4));
        acc0 = vaddq_f32(acc0, vmulq_f32(x0, y0));
        acc1 = vaddq_f32(acc1, vmulq_f32(x1, y1));
    }
    let s = vaddq_f32(acc0, acc1); // s[l] = acc[l] + acc[l+4]
    let t0 = vgetq_lane_f32::<0>(s) + vgetq_lane_f32::<2>(s);
    let t1 = vgetq_lane_f32::<1>(s) + vgetq_lane_f32::<3>(s);
    let mut total = t0 + t1;
    for i in chunks * 8..n {
        total += *x.get_unchecked(i) * *y.get_unchecked(i);
    }
    total
}

/// Int8 dot under the 8-virtual-lane contract with inline dequantization:
/// per 8-chunk, widen 8 codes (`sxtl` to i16, then to i32, then
/// `scvtf` — exact), multiply each 4-lane half by its scale vector, and
/// accumulate with `vaddq(acc, vmulq(x, y))` like the f32 dot. Scale
/// vectors are splats when the chunk sits inside one group, else built
/// per-lane on the stack (only at group boundaries). Two separate
/// multiplies per element — bitwise-equal to scalar.
///
/// # Safety
/// NEON is a baseline aarch64 feature; callers reach this only on aarch64.
#[target_feature(enable = "neon")]
pub unsafe fn dot_q8(x: &[f32], q: &[i8], scales: &[f32], group: usize) -> f32 {
    debug_assert_eq!(x.len(), q.len(), "dot_q8 operand lengths");
    let n = x.len();
    let chunks = n / 8;
    let mut acc0 = vdupq_n_f32(0.0); // virtual lanes 0..4
    let mut acc1 = vdupq_n_f32(0.0); // virtual lanes 4..8
    for c in 0..chunks {
        let base = c * 8;
        let codes = vld1_s8(q.as_ptr().add(base)); // 8 × i8
        let wide = vmovl_s8(codes); // 8 × i16
        let q0 = vcvtq_f32_s32(vmovl_s16(vget_low_s16(wide))); // lanes 0..4
        let q1 = vcvtq_f32_s32(vmovl_s16(vget_high_s16(wide))); // lanes 4..8
        let (s0, s1) = if base / group == (base + 7) / group {
            let sv = vdupq_n_f32(*scales.get_unchecked(base / group));
            (sv, sv)
        } else {
            let mut s = [0.0f32; 8];
            for (l, sl) in s.iter_mut().enumerate() {
                *sl = *scales.get_unchecked((base + l) / group);
            }
            (vld1q_f32(s.as_ptr()), vld1q_f32(s.as_ptr().add(4)))
        };
        let y0 = vmulq_f32(q0, s0);
        let y1 = vmulq_f32(q1, s1);
        let x0 = vld1q_f32(x.as_ptr().add(base));
        let x1 = vld1q_f32(x.as_ptr().add(base + 4));
        acc0 = vaddq_f32(acc0, vmulq_f32(x0, y0));
        acc1 = vaddq_f32(acc1, vmulq_f32(x1, y1));
    }
    let s = vaddq_f32(acc0, acc1); // s[l] = acc[l] + acc[l+4]
    let t0 = vgetq_lane_f32::<0>(s) + vgetq_lane_f32::<2>(s);
    let t1 = vgetq_lane_f32::<1>(s) + vgetq_lane_f32::<3>(s);
    let mut total = t0 + t1;
    for i in chunks * 8..n {
        let y = *q.get_unchecked(i) as f32 * *scales.get_unchecked(i / group);
        total += *x.get_unchecked(i) * y;
    }
    total
}
