//! Portable reference implementations of the two dispatched micro-kernels.
//! These are the semantic ground truth: every SIMD tier must match them
//! **bitwise** (see the parity tests in `tests/simd_parity.rs`).

/// `out[j] += a * b[j]` over the zipped length. Multiply-then-add — never
/// a fused multiply-add — so wider tiers reproduce it exactly.
#[inline]
pub fn axpy(out: &mut [f32], b: &[f32], a: f32) {
    for (o, &bv) in out.iter_mut().zip(b) {
        *o += a * bv;
    }
}

/// Dot product under the shared 8-virtual-lane contract: 8 independent
/// partial sums over full 8-element chunks, the fixed reduction tree
/// `s[l] = acc[l] + acc[l+4]; t0 = s0 + s2; t1 = s1 + s3; t0 + t1`, then a
/// sequential scalar tail over the remainder.
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len(), "dot operand lengths");
    let n = x.len();
    let chunks = n / 8;
    let mut acc = [0.0f32; 8];
    for c in 0..chunks {
        let xb = &x[c * 8..c * 8 + 8];
        let yb = &y[c * 8..c * 8 + 8];
        for l in 0..8 {
            acc[l] += xb[l] * yb[l];
        }
    }
    // the tree mirrors extractf128+add / movehl+add / shuffle+add_ss
    let s0 = acc[0] + acc[4];
    let s1 = acc[1] + acc[5];
    let s2 = acc[2] + acc[6];
    let s3 = acc[3] + acc[7];
    let mut total = (s0 + s2) + (s1 + s3);
    for i in chunks * 8..n {
        total += x[i] * y[i];
    }
    total
}
