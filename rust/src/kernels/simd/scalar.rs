//! Portable reference implementations of the dispatched micro-kernels.
//! These are the semantic ground truth: every SIMD tier must match them
//! **bitwise** (see the parity tests in `tests/simd_parity.rs`).

/// `out[j] += a * b[j]` over the zipped length. Multiply-then-add — never
/// a fused multiply-add — so wider tiers reproduce it exactly.
#[inline]
pub fn axpy(out: &mut [f32], b: &[f32], a: f32) {
    for (o, &bv) in out.iter_mut().zip(b) {
        *o += a * bv;
    }
}

/// Dot product under the shared 8-virtual-lane contract: 8 independent
/// partial sums over full 8-element chunks, the fixed reduction tree
/// `s[l] = acc[l] + acc[l+4]; t0 = s0 + s2; t1 = s1 + s3; t0 + t1`, then a
/// sequential scalar tail over the remainder.
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len(), "dot operand lengths");
    let n = x.len();
    let chunks = n / 8;
    let mut acc = [0.0f32; 8];
    for c in 0..chunks {
        let xb = &x[c * 8..c * 8 + 8];
        let yb = &y[c * 8..c * 8 + 8];
        for l in 0..8 {
            acc[l] += xb[l] * yb[l];
        }
    }
    // the tree mirrors extractf128+add / movehl+add / shuffle+add_ss
    let s0 = acc[0] + acc[4];
    let s1 = acc[1] + acc[5];
    let s2 = acc[2] + acc[6];
    let s3 = acc[3] + acc[7];
    let mut total = (s0 + s2) + (s1 + s3);
    for i in chunks * 8..n {
        total += x[i] * y[i];
    }
    total
}

/// Int8 dot product under the same 8-virtual-lane contract as [`dot`]:
/// each code is dequantized inline — `y = q[i] as f32 * scales[i / group]`
/// (two separate multiplies, never folded) — and accumulated exactly like
/// the f32 dot. By construction this is **bitwise-equal** to
/// `dot(x, dequant(q, scales, group))`, which is what makes packed serving
/// bitwise-faithful to the f32 reference path.
#[inline]
pub fn dot_q8(x: &[f32], q: &[i8], scales: &[f32], group: usize) -> f32 {
    debug_assert_eq!(x.len(), q.len(), "dot_q8 operand lengths");
    let n = x.len();
    let chunks = n / 8;
    let mut acc = [0.0f32; 8];
    for c in 0..chunks {
        let base = c * 8;
        let xb = &x[base..base + 8];
        let qb = &q[base..base + 8];
        for l in 0..8 {
            let y = qb[l] as f32 * scales[(base + l) / group];
            acc[l] += xb[l] * y;
        }
    }
    let s0 = acc[0] + acc[4];
    let s1 = acc[1] + acc[5];
    let s2 = acc[2] + acc[6];
    let s3 = acc[3] + acc[7];
    let mut total = (s0 + s2) + (s1 + s3);
    for i in chunks * 8..n {
        total += x[i] * (q[i] as f32 * scales[i / group]);
    }
    total
}
