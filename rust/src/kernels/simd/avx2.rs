//! AVX2 micro-kernels. Bitwise-equal to [`super::scalar`]: the axpy is
//! elementwise (width-invariant by construction) and the dot implements
//! the 8-virtual-lane contract with one 256-bit accumulator whose
//! reduction tree is exactly the scalar one.

use std::arch::x86_64::*;

/// `out[j] += a * b[j]` over the zipped length, 8 lanes at a time with a
/// scalar tail. `vmulps` + `vaddps` (no FMA), matching scalar bitwise.
///
/// # Safety
/// Caller must have verified `avx2` via `is_x86_feature_detected!`.
#[target_feature(enable = "avx2")]
pub unsafe fn axpy(out: &mut [f32], b: &[f32], a: f32) {
    let n = out.len().min(b.len());
    let av = _mm256_set1_ps(a);
    let mut j = 0;
    while j + 8 <= n {
        let ov = _mm256_loadu_ps(out.as_ptr().add(j));
        let bv = _mm256_loadu_ps(b.as_ptr().add(j));
        _mm256_storeu_ps(out.as_mut_ptr().add(j), _mm256_add_ps(ov, _mm256_mul_ps(av, bv)));
        j += 8;
    }
    while j < n {
        *out.get_unchecked_mut(j) += a * *b.get_unchecked(j);
        j += 1;
    }
}

/// Dot product under the 8-virtual-lane contract: one ymm accumulator
/// (`vaddps(acc, vmulps(x, y))` per chunk), reduced by
/// `vextractf128`+`vaddps` (s[l] = acc[l] + acc[l+4]),
/// `vmovhlps`+`vaddps` (t0 = s0+s2, t1 = s1+s3), and a final
/// `vshufps`+`vaddss` (t0 + t1); sequential scalar tail.
///
/// # Safety
/// Caller must have verified `avx2` via `is_x86_feature_detected!`.
#[target_feature(enable = "avx2")]
pub unsafe fn dot(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len(), "dot operand lengths");
    let n = x.len();
    let chunks = n / 8;
    let mut acc = _mm256_setzero_ps();
    for c in 0..chunks {
        let xv = _mm256_loadu_ps(x.as_ptr().add(c * 8));
        let yv = _mm256_loadu_ps(y.as_ptr().add(c * 8));
        acc = _mm256_add_ps(acc, _mm256_mul_ps(xv, yv));
    }
    let lo = _mm256_castps256_ps128(acc); // acc[0..4]
    let hi = _mm256_extractf128_ps(acc, 1); // acc[4..8]
    let s = _mm_add_ps(lo, hi); // s[l] = acc[l] + acc[l+4]
    let sh = _mm_movehl_ps(s, s); // [s2, s3, s2, s3]
    let t = _mm_add_ps(s, sh); // [s0+s2, s1+s3, ..]
    let tsh = _mm_shuffle_ps(t, t, 0b01); // lane 0 = t[1]
    let mut total = _mm_cvtss_f32(_mm_add_ss(t, tsh)); // t0 + t1
    for i in chunks * 8..n {
        total += *x.get_unchecked(i) * *y.get_unchecked(i);
    }
    total
}

/// Int8 dot under the 8-virtual-lane contract with inline dequantization:
/// per 8-chunk, sign-extend 8 codes (`vpmovsxbd` + `vcvtdq2ps` — exact),
/// multiply by the scale vector, then `vaddps(acc, vmulps(x, y))` like the
/// f32 dot. The scale vector is a splat when the chunk sits inside one
/// group, else built per-lane on the stack (only at group boundaries).
/// Two separate multiplies per element — bitwise-equal to scalar.
///
/// # Safety
/// Caller must have verified `avx2` via `is_x86_feature_detected!`.
#[target_feature(enable = "avx2")]
pub unsafe fn dot_q8(x: &[f32], q: &[i8], scales: &[f32], group: usize) -> f32 {
    debug_assert_eq!(x.len(), q.len(), "dot_q8 operand lengths");
    let n = x.len();
    let chunks = n / 8;
    let mut acc = _mm256_setzero_ps();
    for c in 0..chunks {
        let base = c * 8;
        let codes = _mm_loadl_epi64(q.as_ptr().add(base) as *const __m128i);
        let qf = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(codes));
        let sv = if base / group == (base + 7) / group {
            _mm256_set1_ps(*scales.get_unchecked(base / group))
        } else {
            let mut s = [0.0f32; 8];
            for (l, sl) in s.iter_mut().enumerate() {
                *sl = *scales.get_unchecked((base + l) / group);
            }
            _mm256_loadu_ps(s.as_ptr())
        };
        let yv = _mm256_mul_ps(qf, sv);
        let xv = _mm256_loadu_ps(x.as_ptr().add(base));
        acc = _mm256_add_ps(acc, _mm256_mul_ps(xv, yv));
    }
    let lo = _mm256_castps256_ps128(acc); // acc[0..4]
    let hi = _mm256_extractf128_ps(acc, 1); // acc[4..8]
    let s = _mm_add_ps(lo, hi); // s[l] = acc[l] + acc[l+4]
    let sh = _mm_movehl_ps(s, s); // [s2, s3, s2, s3]
    let t = _mm_add_ps(s, sh); // [s0+s2, s1+s3, ..]
    let tsh = _mm_shuffle_ps(t, t, 0b01); // lane 0 = t[1]
    let mut total = _mm_cvtss_f32(_mm_add_ss(t, tsh)); // t0 + t1
    for i in chunks * 8..n {
        let y = *q.get_unchecked(i) as f32 * *scales.get_unchecked(i / group);
        total += *x.get_unchecked(i) * y;
    }
    total
}
