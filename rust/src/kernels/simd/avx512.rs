//! AVX-512 axpy micro-kernel (the dot stays on the 8-lane AVX2 kernel —
//! see the contract in [`super`]). Elementwise multiply-then-add, so the
//! 16-lane width is bitwise-invisible next to scalar/AVX2.
//!
//! Compiled only with the `avx512` cargo feature: the `_mm512_*` f32
//! intrinsics need a recent stable toolchain, and the default build must
//! keep working on older ones.

use std::arch::x86_64::*;

/// `out[j] += a * b[j]` over the zipped length, 16 lanes at a time with a
/// scalar tail. `vmulps` + `vaddps` on zmm (no FMA), matching scalar
/// bitwise.
///
/// # Safety
/// Caller must have verified `avx512f` via `is_x86_feature_detected!`.
#[target_feature(enable = "avx512f")]
pub unsafe fn axpy(out: &mut [f32], b: &[f32], a: f32) {
    let n = out.len().min(b.len());
    let av = _mm512_set1_ps(a);
    let mut j = 0;
    while j + 16 <= n {
        let ov = _mm512_loadu_ps(out.as_ptr().add(j));
        let bv = _mm512_loadu_ps(b.as_ptr().add(j));
        _mm512_storeu_ps(out.as_mut_ptr().add(j), _mm512_add_ps(ov, _mm512_mul_ps(av, bv)));
        j += 16;
    }
    while j < n {
        *out.get_unchecked_mut(j) += a * *b.get_unchecked(j);
        j += 1;
    }
}
