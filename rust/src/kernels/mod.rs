//! Cache-blocked, multi-threaded matmul kernels shared by the interpreter
//! hot path ([`crate::runtime`]), the host-side [`crate::tensor::Tensor`]
//! math, and the f64 [`crate::linalg::Mat`] routines that dominate SVD
//! whitening/calibration time.
//!
//! Design (see DESIGN.md §3 "Performance"):
//! * **Transpose normalization** — all four `(ta, tb)` flag combinations are
//!   reduced to one packed layout: `A` as row-major `(m, k)`, `B` as
//!   row-major `(k, n)` panels (copies happen only when a flag is set). A
//!   small-`m` fast path keeps `B` in its `(n, k)` layout and runs a
//!   k-innermost dot micro-kernel instead, so decode-shaped matmuls
//!   (`m` = batch) never pay a pack.
//! * **Blocking** — the packed kernel walks `k` in `KC`-sized panels with a
//!   j-contiguous axpy inner loop, keeping the active `B` panel and the
//!   output row hot in cache.
//! * **SIMD tiers** — the f32 axpy and dot inner loops dispatch to an
//!   explicit-intrinsics tier ([`simd`]): AVX2 / AVX-512 (with the
//!   `avx512` cargo feature) on x86-64, NEON on aarch64, a portable
//!   scalar reference everywhere. The tier is picked at runtime from CPU
//!   features, forcible with `ARA_SIMD`. Every tier is **bitwise-equal**
//!   to scalar: axpy is elementwise multiply-then-add (width-invariant),
//!   and the dot follows a fixed 8-virtual-lane reduction contract. The
//!   f64 kernels (SVD path, not serving-hot) stay on plain scalar loops.
//! * **Threading** — work is split over disjoint output row (or column)
//!   ranges with `std::thread::scope`; the thread count comes from
//!   `std::thread::available_parallelism` with an `ARA_THREADS` override,
//!   gated so small problems stay single-threaded.
//! * **Determinism** — each output element is produced by exactly one
//!   thread, and the per-element accumulation order (ascending `k`, plus
//!   the fixed dot reduction tree) does not depend on panel size,
//!   chunking, the thread count, or the SIMD tier, so results are
//!   **bitwise identical** for any `ARA_THREADS` and any `ARA_SIMD`.

pub mod simd;

pub use simd::{active_tier, available_tiers, SimdTier};

use std::sync::OnceLock;

/// Worker thread budget: `ARA_THREADS` if set (≥ 1), else
/// `std::thread::available_parallelism`. Cached for the process lifetime.
pub fn num_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        let from_env = std::env::var("ARA_THREADS")
            .ok()
            .and_then(|s| s.trim().parse::<usize>().ok())
            .filter(|&n| n >= 1);
        from_env.unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        })
    })
}

/// Threads worth spawning for a problem of `flops` floating ops: one thread
/// per ~2 MFLOP so std::thread spawn cost stays well under the work itself.
fn threads_for(flops: usize) -> usize {
    let nt = num_threads();
    if nt <= 1 {
        return 1;
    }
    nt.min((flops / 2_000_000).max(1))
}

/// k-panel size for the packed axpy kernel (f32: 32 KiB of B panel at
/// n=64; the panel is reused across every output row of the chunk).
const KC: usize = 128;

// ---------------------------------------------------------------------------
// f32 kernels: tier-dispatched inner loops
// ---------------------------------------------------------------------------

/// Pack op(A) to row-major (m,k); copies only when `ta` is set.
fn pack_a_f32<'a>(a: &'a [f32], m: usize, k: usize, ta: bool, buf: &'a mut Vec<f32>) -> &'a [f32] {
    if !ta {
        return a;
    }
    buf.resize(m * k, 0.0);
    // A is stored (k,m); read rows sequentially, scatter to columns.
    for kk in 0..k {
        let arow = &a[kk * m..(kk + 1) * m];
        for (i, &v) in arow.iter().enumerate() {
            buf[i * k + kk] = v;
        }
    }
    buf
}

/// Pack op(B) to row-major (k,n); copies only when `tb` is set.
fn pack_b_f32<'a>(b: &'a [f32], k: usize, n: usize, tb: bool, buf: &'a mut Vec<f32>) -> &'a [f32] {
    if !tb {
        return b;
    }
    buf.resize(k * n, 0.0);
    // B is stored (n,k); read rows sequentially, scatter to columns.
    for j in 0..n {
        let brow = &b[j * k..(j + 1) * k];
        for (kk, &v) in brow.iter().enumerate() {
            buf[kk * n + j] = v;
        }
    }
    buf
}

/// Output rows [i0, i0+rows) of A(m,k)·B(k,n) into `out` (len rows·n,
/// pre-zeroed), walking k in KC panels with a j-contiguous axpy dispatched
/// to `tier`. Per-element accumulation is ascending-k regardless of
/// panelling, and the zero-rank row skip happens here — before dispatch —
/// so it is identical on every tier.
fn mm_rows_f32(
    tier: SimdTier,
    a: &[f32],
    b: &[f32],
    k: usize,
    n: usize,
    i0: usize,
    rows: usize,
    out: &mut [f32],
) {
    let mut k0 = 0;
    while k0 < k {
        let kc = KC.min(k - k0);
        for i in 0..rows {
            let abase = (i0 + i) * k + k0;
            let arow = &a[abase..abase + kc];
            let orow = &mut out[i * n..(i + 1) * n];
            for (kk, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let bbase = (k0 + kk) * n;
                simd::axpy(tier, orow, &b[bbase..bbase + n], av);
            }
        }
        k0 += kc;
    }
}

/// Dot micro-kernel over Bᵀ rows: out[i·os + j] = A row (i0+i) · Bᵀ row
/// (j0+j), for the (ta=false, tb=true) small-m fast path, dispatched to
/// `tier`. Overwrites its outputs (no pre-zero needed).
#[allow(clippy::too_many_arguments)]
fn mm_dot_f32(
    tier: SimdTier,
    a: &[f32],
    bt: &[f32],
    k: usize,
    i0: usize,
    rows: usize,
    j0: usize,
    cols: usize,
    os: usize,
    out: &mut [f32],
) {
    for i in 0..rows {
        let arow = &a[(i0 + i) * k..(i0 + i) * k + k];
        for j in 0..cols {
            let brow = &bt[(j0 + j) * k..(j0 + j) * k + k];
            out[i * os + j] = simd::dot(tier, arow, brow);
        }
    }
}

/// C = op(A)·op(B) with logical shapes (m,k)·(k,n) → `out` (len m·n,
/// **pre-zeroed** by the caller) on an explicit SIMD `tier` and thread
/// budget `nt`. `ta`/`tb` mark transposed storage ((k,m) / (n,k)
/// respectively). Runs on up to `nt` threads over disjoint output regions;
/// bitwise-deterministic for any `nt` and — by the tier contract — any
/// `tier`. Parity tests and per-tier benches call this directly; everything
/// else goes through [`matmul_f32`], which uses the process-wide tier.
#[allow(clippy::too_many_arguments)]
pub fn matmul_f32_tier(
    tier: SimdTier,
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    ta: bool,
    tb: bool,
    out: &mut [f32],
    nt: usize,
) {
    debug_assert_eq!(out.len(), m * n, "matmul out buffer size");
    if m == 0 || n == 0 {
        return;
    }
    // Small-m transposed-B fast path: contiguous dot rows, no pack.
    if tb && !ta && m < 8 {
        let nt = nt.clamp(1, n);
        if nt <= 1 {
            mm_dot_f32(tier, a, b, k, 0, m, 0, n, n, out);
        } else {
            // Split columns; threads fill private (m × jw) tiles that
            // are copied back sequentially (copy cost is 1/k of the
            // dot work, and out need not be split non-contiguously).
            let cols_per = n.div_ceil(nt);
            let tiles: Vec<(usize, usize, Vec<f32>)> = std::thread::scope(|s| {
                let mut handles = Vec::new();
                let mut j0 = 0;
                while j0 < n {
                    let jw = cols_per.min(n - j0);
                    handles.push(s.spawn(move || {
                        let mut tile = vec![0.0; m * jw];
                        mm_dot_f32(tier, a, b, k, 0, m, j0, jw, jw, &mut tile);
                        (j0, jw, tile)
                    }));
                    j0 += jw;
                }
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
            for (j0, jw, tile) in tiles {
                for i in 0..m {
                    out[i * n + j0..i * n + j0 + jw].copy_from_slice(&tile[i * jw..(i + 1) * jw]);
                }
            }
        }
        return;
    }
    // General path: normalize to packed (m,k)·(k,n), blocked axpy.
    let mut abuf = Vec::new();
    let mut bbuf = Vec::new();
    let an = pack_a_f32(a, m, k, ta, &mut abuf);
    let bn = pack_b_f32(b, k, n, tb, &mut bbuf);
    let nt = nt.clamp(1, m);
    if nt <= 1 {
        mm_rows_f32(tier, an, bn, k, n, 0, m, out);
        return;
    }
    let rows_per = m.div_ceil(nt);
    std::thread::scope(|s| {
        for (ci, chunk) in out.chunks_mut(rows_per * n).enumerate() {
            s.spawn(move || {
                let rows = chunk.len() / n;
                mm_rows_f32(tier, an, bn, k, n, ci * rows_per, rows, chunk);
            });
        }
    });
}

/// [`matmul_f32_tier`] on the process-wide [`active_tier`] with an explicit
/// thread budget (determinism tests).
#[allow(clippy::too_many_arguments)]
pub fn matmul_f32_nt(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    ta: bool,
    tb: bool,
    out: &mut [f32],
    nt: usize,
) {
    matmul_f32_tier(active_tier(), a, b, m, k, n, ta, tb, out, nt);
}

/// The `_nt` kernel with the thread count picked from the problem size and
/// the `ARA_THREADS` / `available_parallelism` budget.
#[allow(clippy::too_many_arguments)]
pub fn matmul_f32(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    ta: bool,
    tb: bool,
    out: &mut [f32],
) {
    matmul_f32_nt(a, b, m, k, n, ta, tb, out, threads_for(2 * m * k * n));
}

// ---------------------------------------------------------------------------
// int8 kernels (quantized serving path): dot-style, weights stay packed
// ---------------------------------------------------------------------------

/// Flat span [e0, e0+out.len()) of the (m·n)-element output of
/// A(m,k) · Wᵀ where W is packed int8 with stored shape (n, k): element
/// `e = i·n + j` is `dot_q8(A row i, W row j)`. Each element is one
/// independent dot with a fixed internal schedule, so any flat split is
/// bitwise-deterministic. Overwrites its outputs (no pre-zero needed).
fn mm_flat_q8(
    tier: SimdTier,
    a: &[f32],
    w: &crate::quant::PackedInt8,
    k: usize,
    n: usize,
    e0: usize,
    out: &mut [f32],
) {
    let gpr = w.groups_per_row();
    for (off, o) in out.iter_mut().enumerate() {
        let e = e0 + off;
        let (i, j) = (e / n, e % n);
        let arow = &a[i * k..i * k + k];
        let qrow = &w.data[j * k..j * k + k];
        let srow = &w.scales[j * gpr..(j + 1) * gpr];
        *o = simd::dot_q8(tier, arow, qrow, srow, w.group);
    }
}

/// C = A · Wᵀ with A row-major (m, k) and W packed int8, stored shape
/// (n, k) — the serving layout for both SVD factors, with quantization
/// groups along the dot dimension. `out` (len m·n) is overwritten. Runs on
/// up to `nt` threads over disjoint flat output spans; each element is an
/// independent [`simd::dot_q8`] with a fixed accumulation schedule, so the
/// result is **bitwise identical** for any `nt` and any `tier` — and
/// bitwise-equal to [`matmul_f32_tier`] (tb = true) over the dequantized
/// weights.
pub fn matmul_q8_tier(
    tier: SimdTier,
    a: &[f32],
    w: &crate::quant::PackedInt8,
    m: usize,
    out: &mut [f32],
    nt: usize,
) {
    let (n, k) = (w.shape[0], w.shape[1]);
    debug_assert_eq!(a.len(), m * k, "matmul_q8 A buffer size");
    debug_assert_eq!(out.len(), m * n, "matmul_q8 out buffer size");
    if m == 0 || n == 0 {
        return;
    }
    let total = m * n;
    let nt = nt.clamp(1, total);
    if nt <= 1 {
        mm_flat_q8(tier, a, w, k, n, 0, out);
        return;
    }
    let per = total.div_ceil(nt);
    std::thread::scope(|s| {
        for (ci, chunk) in out.chunks_mut(per).enumerate() {
            s.spawn(move || mm_flat_q8(tier, a, w, k, n, ci * per, chunk));
        }
    });
}

/// [`matmul_q8_tier`] on the process-wide [`active_tier`] with the thread
/// count picked from the problem size (one flop each for the inline
/// dequant multiply and the accumulate multiply-add).
pub fn matmul_q8(a: &[f32], w: &crate::quant::PackedInt8, m: usize, out: &mut [f32]) {
    let (n, k) = (w.shape[0], w.shape[1]);
    matmul_q8_tier(active_tier(), a, w, m, out, threads_for(3 * m * k * n));
}

// ---------------------------------------------------------------------------
// f64 kernels (SVD/whitening path): plain scalar loops, no tier dispatch
// ---------------------------------------------------------------------------

macro_rules! mm_impl {
    ($mm:ident, $mm_nt:ident, $rows_fn:ident, $dot_fn:ident, $pack_a:ident, $pack_b:ident, $ty:ty) => {
        /// Pack op(A) to row-major (m,k); copies only when `ta` is set.
        fn $pack_a<'a>(a: &'a [$ty], m: usize, k: usize, ta: bool, buf: &'a mut Vec<$ty>) -> &'a [$ty] {
            if !ta {
                return a;
            }
            buf.resize(m * k, 0.0);
            // A is stored (k,m); read rows sequentially, scatter to columns.
            for kk in 0..k {
                let arow = &a[kk * m..(kk + 1) * m];
                for (i, &v) in arow.iter().enumerate() {
                    buf[i * k + kk] = v;
                }
            }
            buf
        }

        /// Pack op(B) to row-major (k,n); copies only when `tb` is set.
        fn $pack_b<'a>(b: &'a [$ty], k: usize, n: usize, tb: bool, buf: &'a mut Vec<$ty>) -> &'a [$ty] {
            if !tb {
                return b;
            }
            buf.resize(k * n, 0.0);
            // B is stored (n,k); read rows sequentially, scatter to columns.
            for j in 0..n {
                let brow = &b[j * k..(j + 1) * k];
                for (kk, &v) in brow.iter().enumerate() {
                    buf[kk * n + j] = v;
                }
            }
            buf
        }

        /// Output rows [i0, i0+rows) of A(m,k)·B(k,n) into `out` (len rows·n,
        /// pre-zeroed), walking k in KC panels with a j-contiguous axpy.
        /// Per-element accumulation is ascending-k regardless of panelling.
        fn $rows_fn(a: &[$ty], b: &[$ty], k: usize, n: usize, i0: usize, rows: usize, out: &mut [$ty]) {
            let mut k0 = 0;
            while k0 < k {
                let kc = KC.min(k - k0);
                for i in 0..rows {
                    let abase = (i0 + i) * k + k0;
                    let arow = &a[abase..abase + kc];
                    let orow = &mut out[i * n..(i + 1) * n];
                    for (kk, &av) in arow.iter().enumerate() {
                        if av == 0.0 {
                            continue;
                        }
                        let brow = &b[(k0 + kk) * n..(k0 + kk + 1) * n];
                        for (o, &bv) in orow.iter_mut().zip(brow) {
                            *o += av * bv;
                        }
                    }
                }
                k0 += kc;
            }
        }

        /// Dot micro-kernel over Bᵀ rows: out[i·os + j] = A row (i0+i) ·
        /// Bᵀ row (j0+j), for the (ta=false, tb=true) small-m fast path.
        /// Overwrites its outputs (no pre-zero needed).
        #[allow(clippy::too_many_arguments)]
        fn $dot_fn(
            a: &[$ty],
            bt: &[$ty],
            k: usize,
            i0: usize,
            rows: usize,
            j0: usize,
            cols: usize,
            os: usize,
            out: &mut [$ty],
        ) {
            for i in 0..rows {
                let arow = &a[(i0 + i) * k..(i0 + i) * k + k];
                for j in 0..cols {
                    let brow = &bt[(j0 + j) * k..(j0 + j) * k + k];
                    let mut acc: $ty = 0.0;
                    for (&x, &y) in arow.iter().zip(brow) {
                        acc += x * y;
                    }
                    out[i * os + j] = acc;
                }
            }
        }

        /// C = op(A)·op(B) with logical shapes (m,k)·(k,n) → `out` (len m·n,
        /// **pre-zeroed** by the caller). `ta`/`tb` mark transposed storage
        /// ((k,m) / (n,k) respectively). Runs on up to `nt` threads over
        /// disjoint output regions; bitwise-deterministic for any `nt`.
        #[allow(clippy::too_many_arguments)]
        pub fn $mm_nt(
            a: &[$ty],
            b: &[$ty],
            m: usize,
            k: usize,
            n: usize,
            ta: bool,
            tb: bool,
            out: &mut [$ty],
            nt: usize,
        ) {
            debug_assert_eq!(out.len(), m * n, "matmul out buffer size");
            if m == 0 || n == 0 {
                return;
            }
            // Small-m transposed-B fast path: contiguous dot rows, no pack.
            if tb && !ta && m < 8 {
                let nt = nt.clamp(1, n);
                if nt <= 1 {
                    $dot_fn(a, b, k, 0, m, 0, n, n, out);
                } else {
                    // Split columns; threads fill private (m × jw) tiles that
                    // are copied back sequentially (copy cost is 1/k of the
                    // dot work, and out need not be split non-contiguously).
                    let cols_per = n.div_ceil(nt);
                    let tiles: Vec<(usize, usize, Vec<$ty>)> = std::thread::scope(|s| {
                        let mut handles = Vec::new();
                        let mut j0 = 0;
                        while j0 < n {
                            let jw = cols_per.min(n - j0);
                            handles.push(s.spawn(move || {
                                let mut tile = vec![0.0; m * jw];
                                $dot_fn(a, b, k, 0, m, j0, jw, jw, &mut tile);
                                (j0, jw, tile)
                            }));
                            j0 += jw;
                        }
                        handles.into_iter().map(|h| h.join().unwrap()).collect()
                    });
                    for (j0, jw, tile) in tiles {
                        for i in 0..m {
                            out[i * n + j0..i * n + j0 + jw]
                                .copy_from_slice(&tile[i * jw..(i + 1) * jw]);
                        }
                    }
                }
                return;
            }
            // General path: normalize to packed (m,k)·(k,n), blocked axpy.
            let mut abuf = Vec::new();
            let mut bbuf = Vec::new();
            let an = $pack_a(a, m, k, ta, &mut abuf);
            let bn = $pack_b(b, k, n, tb, &mut bbuf);
            let nt = nt.clamp(1, m);
            if nt <= 1 {
                $rows_fn(an, bn, k, n, 0, m, out);
                return;
            }
            let rows_per = m.div_ceil(nt);
            std::thread::scope(|s| {
                for (ci, chunk) in out.chunks_mut(rows_per * n).enumerate() {
                    s.spawn(move || {
                        let rows = chunk.len() / n;
                        $rows_fn(an, bn, k, n, ci * rows_per, rows, chunk);
                    });
                }
            });
        }

        /// The `_nt` kernel with the thread count picked from the problem
        /// size and the `ARA_THREADS` / `available_parallelism` budget.
        #[allow(clippy::too_many_arguments)]
        pub fn $mm(a: &[$ty], b: &[$ty], m: usize, k: usize, n: usize, ta: bool, tb: bool, out: &mut [$ty]) {
            $mm_nt(a, b, m, k, n, ta, tb, out, threads_for(2 * m * k * n));
        }
    };
}

mm_impl!(matmul_f64, matmul_f64_nt, mm_rows_f64, mm_dot_f64, pack_a_f64, pack_b_f64, f64);

/// Batched C[i] = op(A[i])·op(B[i]) over the leading dim of (bs,·,·)
/// tensors into `out` (len bs·m·n, **pre-zeroed**). Parallelizes over the
/// batch dimension; each slice runs the sequential 2-D kernel, so results
/// are bitwise-deterministic for any thread count.
#[allow(clippy::too_many_arguments)]
pub fn bmm_f32(
    a: &[f32],
    b: &[f32],
    bs: usize,
    m: usize,
    k: usize,
    n: usize,
    ta: bool,
    tb: bool,
    out: &mut [f32],
) {
    bmm_f32_nt(a, b, bs, m, k, n, ta, tb, out, threads_for(2 * bs * m * k * n));
}

/// `bmm_f32` with an explicit thread budget (determinism tests).
#[allow(clippy::too_many_arguments)]
pub fn bmm_f32_nt(
    a: &[f32],
    b: &[f32],
    bs: usize,
    m: usize,
    k: usize,
    n: usize,
    ta: bool,
    tb: bool,
    out: &mut [f32],
    nt: usize,
) {
    bmm_f32_tier(active_tier(), a, b, bs, m, k, n, ta, tb, out, nt);
}

/// `bmm_f32` on an explicit SIMD `tier` and thread budget (parity tests,
/// per-tier benches).
#[allow(clippy::too_many_arguments)]
pub fn bmm_f32_tier(
    tier: SimdTier,
    a: &[f32],
    b: &[f32],
    bs: usize,
    m: usize,
    k: usize,
    n: usize,
    ta: bool,
    tb: bool,
    out: &mut [f32],
    nt: usize,
) {
    debug_assert_eq!(out.len(), bs * m * n, "bmm out buffer size");
    if bs == 0 || m * n == 0 {
        return;
    }
    let (sa, sb, so) = (m * k, k * n, m * n);
    let nt = nt.clamp(1, bs);
    if nt <= 1 {
        for i in 0..bs {
            matmul_f32_tier(
                tier,
                &a[i * sa..(i + 1) * sa],
                &b[i * sb..(i + 1) * sb],
                m,
                k,
                n,
                ta,
                tb,
                &mut out[i * so..(i + 1) * so],
                1,
            );
        }
        return;
    }
    let per = bs.div_ceil(nt);
    std::thread::scope(|s| {
        for (ci, chunk) in out.chunks_mut(per * so).enumerate() {
            s.spawn(move || {
                for (x, oc) in chunk.chunks_mut(so).enumerate() {
                    let i = ci * per + x;
                    matmul_f32_tier(
                        tier,
                        &a[i * sa..(i + 1) * sa],
                        &b[i * sb..(i + 1) * sb],
                        m,
                        k,
                        n,
                        ta,
                        tb,
                        oc,
                        1,
                    );
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The pre-PR naive reference: the exact loop nests the interpreter
    /// shipped with, kept here to pin the blocked kernel against.
    #[allow(clippy::too_many_arguments)]
    fn naive(
        a: &[f32],
        b: &[f32],
        m: usize,
        k: usize,
        n: usize,
        ta: bool,
        tb: bool,
        out: &mut [f32],
    ) {
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f32;
                for kk in 0..k {
                    let av = if ta { a[kk * m + i] } else { a[i * k + kk] };
                    let bv = if tb { b[j * k + kk] } else { b[kk * n + j] };
                    acc += av * bv;
                }
                out[i * n + j] = acc;
            }
        }
    }

    fn fill(len: usize, seed: u64) -> Vec<f32> {
        // LCG so tests are deterministic without any RNG dependency
        let mut s = seed.wrapping_mul(6364136223846793005).wrapping_add(1);
        (0..len)
            .map(|_| {
                s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                ((s >> 33) as f32 / (1u64 << 31) as f32) - 0.5
            })
            .collect()
    }

    fn assert_close(got: &[f32], want: &[f32], tag: &str) {
        assert_eq!(got.len(), want.len(), "{tag}: length");
        for (i, (g, w)) in got.iter().zip(want).enumerate() {
            let tol = 1e-5f32.max(w.abs() * 1e-5);
            assert!((g - w).abs() <= tol, "{tag}: elem {i}: got {g}, want {w}");
        }
    }

    #[test]
    fn blocked_matches_naive_on_odd_shapes() {
        // non-multiple-of-tile dims on every flag combo, including m/n/k = 1
        let shapes = [(3, 7, 5), (1, 13, 9), (17, 1, 4), (5, 150, 3), (9, 37, 1), (13, 257, 11)];
        for &(m, k, n) in &shapes {
            for &(ta, tb) in &[(false, false), (true, false), (false, true), (true, true)] {
                let a = fill(m * k, (m * 31 + k * 7 + ta as usize) as u64);
                let b = fill(k * n, (k * 17 + n * 3 + tb as usize) as u64);
                let mut want = vec![0.0; m * n];
                naive(&a, &b, m, k, n, ta, tb, &mut want);
                let mut got = vec![0.0; m * n];
                matmul_f32(&a, &b, m, k, n, ta, tb, &mut got);
                assert_close(&got, &want, &format!("mm {m}x{k}x{n} ta={ta} tb={tb}"));
            }
        }
    }

    #[test]
    fn batched_matches_per_slice_naive() {
        let (bs, m, k, n) = (3, 4, 9, 5);
        for &(ta, tb) in &[(false, false), (false, true), (true, false), (true, true)] {
            let a = fill(bs * m * k, 5);
            let b = fill(bs * k * n, 6);
            let mut want = vec![0.0; bs * m * n];
            for i in 0..bs {
                naive(
                    &a[i * m * k..(i + 1) * m * k],
                    &b[i * k * n..(i + 1) * k * n],
                    m,
                    k,
                    n,
                    ta,
                    tb,
                    &mut want[i * m * n..(i + 1) * m * n],
                );
            }
            let mut got = vec![0.0; bs * m * n];
            bmm_f32(&a, &b, bs, m, k, n, ta, tb, &mut got);
            assert_close(&got, &want, &format!("bmm ta={ta} tb={tb}"));
        }
    }

    #[test]
    fn thread_count_is_bitwise_invisible() {
        // ARA_THREADS=1 vs ARA_THREADS=4 must agree bit-for-bit; the env var
        // feeds the same `nt` parameter exercised explicitly here.
        for &(m, k, n) in &[(37, 53, 29), (2, 301, 511), (64, 64, 64)] {
            for &(ta, tb) in &[(false, false), (false, true), (true, false), (true, true)] {
                let a = fill(m * k, 11);
                let b = fill(k * n, 12);
                let mut one = vec![0.0; m * n];
                matmul_f32_nt(&a, &b, m, k, n, ta, tb, &mut one, 1);
                let mut four = vec![0.0; m * n];
                matmul_f32_nt(&a, &b, m, k, n, ta, tb, &mut four, 4);
                assert!(
                    one.iter().zip(&four).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "threaded result differs bitwise at {m}x{k}x{n} ta={ta} tb={tb}"
                );
            }
        }
        let (bs, m, k, n) = (5, 3, 40, 17);
        let a = fill(bs * m * k, 13);
        let b = fill(bs * k * n, 14);
        let mut one = vec![0.0; bs * m * n];
        bmm_f32_nt(&a, &b, bs, m, k, n, false, true, &mut one, 1);
        let mut four = vec![0.0; bs * m * n];
        bmm_f32_nt(&a, &b, bs, m, k, n, false, true, &mut four, 4);
        assert!(one.iter().zip(&four).all(|(x, y)| x.to_bits() == y.to_bits()));
    }

    #[test]
    fn every_available_tier_matches_scalar_bitwise() {
        // the full parity matrix lives in tests/simd_parity.rs; this is the
        // in-crate smoke check over one odd shape per kernel path
        let (m, k, n) = (5, 137, 33);
        let a = fill(m * k, 91);
        for tier in available_tiers() {
            for &tb in &[false, true] {
                // tb=true takes the dot fast path (m < 8), tb=false the axpy path
                let b = fill(k * n, 92 + tb as u64);
                let mut scalar = vec![0.0; m * n];
                matmul_f32_tier(SimdTier::Scalar, &a, &b, m, k, n, false, tb, &mut scalar, 1);
                let mut tiered = vec![0.0; m * n];
                matmul_f32_tier(tier, &a, &b, m, k, n, false, tb, &mut tiered, 1);
                assert!(
                    scalar.iter().zip(&tiered).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "tier {} differs from scalar at {m}x{k}x{n} tb={tb}",
                    tier.name()
                );
            }
        }
    }

    #[test]
    fn f64_kernel_matches_f32_reference_shape() {
        let (m, k, n) = (6, 11, 7);
        let a32 = fill(m * k, 21);
        let b32 = fill(k * n, 22);
        let a: Vec<f64> = a32.iter().map(|&x| x as f64).collect();
        let b: Vec<f64> = b32.iter().map(|&x| x as f64).collect();
        let mut want32 = vec![0.0f32; m * n];
        naive(&a32, &b32, m, k, n, false, false, &mut want32);
        let mut got = vec![0.0f64; m * n];
        matmul_f64(&a, &b, m, k, n, false, false, &mut got);
        for (g, w) in got.iter().zip(&want32) {
            assert!((g - *w as f64).abs() < 1e-4, "f64 kernel diverged: {g} vs {w}");
        }
    }

    #[test]
    fn zero_k_leaves_zeros() {
        let mut out = vec![0.0f32; 6];
        matmul_f32(&[], &[], 2, 0, 3, false, false, &mut out);
        assert_eq!(out, vec![0.0; 6]);
        let mut out = vec![0.0f32; 6];
        matmul_f32(&[], &[], 2, 0, 3, false, true, &mut out);
        assert_eq!(out, vec![0.0; 6]);
    }
}
