//! Module topology — MUST mirror python/compile/model.py exactly (names,
//! shapes, ordering); a unit test in runtime/manifest.rs cross-checks this
//! against the AOT manifests.

use crate::config::ModelCfg;

/// One compressible linear module: applied as `y = x · Wᵀ`, `W: (m, n)`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModuleDim {
    pub name: String,
    pub m: usize,
    pub n: usize,
}

impl ModuleDim {
    /// Full rank of the masked-SVD parameterization.
    pub fn r_full(&self) -> usize {
        self.m.min(self.n)
    }
    /// Dense parameter count.
    pub fn dense_params(&self) -> usize {
        self.m * self.n
    }
    /// Factored parameter count at rank k.
    pub fn factored_params(&self, k: usize) -> usize {
        k * (self.m + self.n)
    }
    /// Rank above which the factorization stores more than the dense matrix
    /// (the paper's R=1 discontinuity): smallest k with k(m+n) > mn.
    pub fn breakeven_rank(&self) -> usize {
        self.m * self.n / (self.m + self.n)
    }
}

/// The seven compressible modules per layer, in python order.
pub fn module_dims(cfg: &ModelCfg) -> Vec<ModuleDim> {
    let d = cfg.d_model;
    let ff = cfg.d_ff;
    let kvd = cfg.kv_dim();
    let mut out = Vec::with_capacity(cfg.n_layers * 7);
    for i in 0..cfg.n_layers {
        let p = format!("layers.{i}.");
        for (suffix, m, n) in [
            ("attn.wq", d, d),
            ("attn.wk", kvd, d),
            ("attn.wv", kvd, d),
            ("attn.wo", d, d),
            ("mlp.wgate", ff, d),
            ("mlp.wup", ff, d),
            ("mlp.wdown", d, ff),
        ] {
            out.push(ModuleDim { name: format!("{p}{suffix}"), m, n });
        }
    }
    out
}

/// Non-compressible parameters (embeddings, norms, head), in python order.
pub fn aux_param_shapes(cfg: &ModelCfg) -> Vec<(String, Vec<usize>)> {
    let d = cfg.d_model;
    let dh = cfg.head_dim();
    let mut out = vec![("embed".to_string(), vec![cfg.vocab, d])];
    for i in 0..cfg.n_layers {
        let p = format!("layers.{i}.");
        out.push((format!("{p}ln1"), vec![d]));
        out.push((format!("{p}ln2"), vec![d]));
        if cfg.family == "qwen" {
            out.push((format!("{p}qnorm"), vec![dh]));
            out.push((format!("{p}knorm"), vec![dh]));
        }
    }
    out.push(("norm_f".to_string(), vec![d]));
    out.push(("head".to_string(), vec![cfg.vocab, d]));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{model_by_name, Paths};

    fn cfg(name: &str) -> ModelCfg {
        let paths = Paths::discover().unwrap();
        model_by_name(&paths.configs, name).unwrap()
    }

    #[test]
    fn seven_modules_per_layer() {
        let c = cfg("micro-llama");
        let dims = module_dims(&c);
        assert_eq!(dims.len(), 7 * c.n_layers);
        assert_eq!(dims[0].name, "layers.0.attn.wq");
        assert_eq!(dims[0].m, c.d_model);
    }

    #[test]
    fn qwen_has_qk_norms_and_gqa_shapes() {
        let c = cfg("miniqwen-s");
        let aux = aux_param_shapes(&c);
        assert!(aux.iter().any(|(n, _)| n == "layers.0.qnorm"));
        let dims = module_dims(&c);
        let wk = dims.iter().find(|d| d.name == "layers.0.attn.wk").unwrap();
        assert_eq!(wk.m, c.kv_dim());
        assert!(wk.m < c.d_model, "GQA must shrink kv projections");
    }

    #[test]
    fn breakeven_rank_is_the_r1_discontinuity() {
        let md = ModuleDim { name: "x".into(), m: 100, n: 60 };
        let k = md.breakeven_rank();
        assert!(md.factored_params(k) <= md.dense_params());
        assert!(md.factored_params(k + 1) > md.dense_params());
        // and full rank always overshoots for m≠n
        assert!(md.factored_params(md.r_full()) > md.dense_params());
    }
}
