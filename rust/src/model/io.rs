//! Weight (de)serialization: a small self-describing binary format
//! (`ARAW1`: count, then per tensor name/ndim/dims/f32 data, little-endian).
//! Used to cache pre-trained substrate models under runs/<model>/.

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use super::weights::WeightStore;
use crate::tensor::Tensor;
use crate::Result;

const MAGIC: &[u8; 5] = b"ARAW1";

pub fn save_weights(ws: &WeightStore, path: &Path) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    w.write_all(MAGIC)?;
    w.write_all(&(ws.tensors.len() as u32).to_le_bytes())?;
    for (name, t) in &ws.tensors {
        let nb = name.as_bytes();
        w.write_all(&(nb.len() as u32).to_le_bytes())?;
        w.write_all(nb)?;
        w.write_all(&(t.shape.len() as u32).to_le_bytes())?;
        for &d in &t.shape {
            w.write_all(&(d as u64).to_le_bytes())?;
        }
        for &x in &t.data {
            w.write_all(&x.to_le_bytes())?;
        }
    }
    Ok(())
}

pub fn load_weights(path: &Path) -> Result<WeightStore> {
    let mut r = BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 5];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(crate::anyhow!("{path:?}: bad magic (not an ARAW1 file)"));
    }
    let count = read_u32(&mut r)? as usize;
    let mut ws = WeightStore::default();
    for _ in 0..count {
        let name_len = read_u32(&mut r)? as usize;
        let mut name = vec![0u8; name_len];
        r.read_exact(&mut name)?;
        let ndim = read_u32(&mut r)? as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            let mut b = [0u8; 8];
            r.read_exact(&mut b)?;
            shape.push(u64::from_le_bytes(b) as usize);
        }
        let numel: usize = shape.iter().product();
        let mut bytes = vec![0u8; numel * 4];
        r.read_exact(&mut bytes)?;
        let data = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        ws.insert(String::from_utf8(name)?, Tensor::from_vec(&shape, data));
    }
    Ok(ws)
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut ws = WeightStore::default();
        ws.insert("a.b", Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]));
        ws.insert("c", Tensor::from_vec(&[4], vec![-1., 0., 1.5, 2.5]));
        let dir = std::env::temp_dir().join("ara_io_test");
        let path = dir.join("w.bin");
        save_weights(&ws, &path).unwrap();
        let back = load_weights(&path).unwrap();
        assert_eq!(back.tensors.len(), 2);
        assert_eq!(back.get("a.b"), ws.get("a.b"));
        assert_eq!(back.get("c"), ws.get("c"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("ara_io_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, b"NOTAWEIGHTFILE").unwrap();
        assert!(load_weights(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
