//! Weight storage: an ordered name → tensor map holding either the dense or
//! the factored parameterization, plus seeded initialization for
//! pre-training from scratch.

use std::collections::BTreeMap;

use super::topology::{aux_param_shapes, module_dims};
use crate::config::ModelCfg;
use crate::data::Rng;
use crate::tensor::Tensor;

/// Ordered weight map (BTreeMap: deterministic iteration for hashing/io).
#[derive(Debug, Clone, Default)]
pub struct WeightStore {
    pub tensors: BTreeMap<String, Tensor>,
}

impl WeightStore {
    pub fn get(&self, name: &str) -> &Tensor {
        self.tensors
            .get(name)
            .unwrap_or_else(|| panic!("missing weight tensor: {name}"))
    }

    pub fn get_mut(&mut self, name: &str) -> &mut Tensor {
        self.tensors
            .get_mut(name)
            .unwrap_or_else(|| panic!("missing weight tensor: {name}"))
    }

    pub fn insert(&mut self, name: impl Into<String>, t: Tensor) {
        self.tensors.insert(name.into(), t);
    }

    pub fn contains(&self, name: &str) -> bool {
        self.tensors.contains_key(name)
    }

    pub fn numel(&self) -> usize {
        self.tensors.values().map(|t| t.numel()).sum()
    }
}

/// Initialize dense weights for pre-training: N(0, 0.02²) matrices with
/// 1/√(2L) scaling on residual-output projections (GPT-2 style), unit norms.
pub fn init_weights(cfg: &ModelCfg, seed: u64) -> WeightStore {
    let mut rng = Rng::new(seed);
    let mut ws = WeightStore::default();
    let resid_scale = 1.0 / ((2 * cfg.n_layers) as f64).sqrt();

    for (name, shape) in aux_param_shapes(cfg) {
        let t = if shape.len() == 1 {
            Tensor::ones(&shape)
        } else {
            random_tensor(&mut rng, &shape, 0.02)
        };
        ws.insert(name, t);
    }
    for d in module_dims(cfg) {
        let scale = if d.name.ends_with(".wo") || d.name.ends_with(".wdown") {
            0.02 * resid_scale
        } else {
            0.02
        };
        ws.insert(d.name.clone(), random_tensor(&mut rng, &[d.m, d.n], scale));
    }
    ws
}

fn random_tensor(rng: &mut Rng, shape: &[usize], std: f64) -> Tensor {
    let n: usize = shape.iter().product();
    let data = (0..n).map(|_| (rng.normal() * std) as f32).collect();
    Tensor::from_vec(shape, data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{model_by_name, Paths};
    use crate::model::total_params;

    fn cfg() -> ModelCfg {
        let paths = Paths::discover().unwrap();
        model_by_name(&paths.configs, "micro-llama").unwrap()
    }

    #[test]
    fn init_covers_full_topology() {
        let c = cfg();
        let ws = init_weights(&c, 1);
        assert_eq!(ws.numel(), total_params(&c));
        assert!(ws.contains("embed"));
        assert!(ws.contains("layers.0.attn.wq"));
        assert!(ws.contains("norm_f"));
    }

    #[test]
    fn init_is_seeded() {
        let c = cfg();
        let a = init_weights(&c, 7);
        let b = init_weights(&c, 7);
        assert_eq!(a.get("embed").data, b.get("embed").data);
        let c2 = init_weights(&c, 8);
        assert_ne!(a.get("embed").data, c2.get("embed").data);
    }

    #[test]
    fn norms_initialized_to_one() {
        let c = cfg();
        let ws = init_weights(&c, 1);
        assert!(ws.get("layers.0.ln1").data.iter().all(|&x| x == 1.0));
    }
}
