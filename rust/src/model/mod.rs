//! Model state: topology (mirrors python/compile/model.py), weight storage,
//! rank allocations, parameter accounting, and (de)serialization.

mod alloc;
mod io;
mod params;
mod topology;
mod weights;

pub use alloc::{Allocation, ModuleAlloc};
pub use io::{load_weights, save_weights};
pub use params::{
    alloc_params, alloc_params_for_dims, alloc_ratio, compressible_params, module_params,
    total_params,
};
pub use topology::{aux_param_shapes, module_dims, ModuleDim};
pub use weights::{init_weights, WeightStore};
