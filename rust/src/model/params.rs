//! Parameter accounting — where the paper's R = 1 discontinuity lives.
//!
//! The compression ratio (paper Sec. 4.1) is the compressed parameter count
//! of the *compressible* linear modules divided by their dense count;
//! embeddings/norms/head are excluded from both sides (they are never
//! compressed), matching the per-module ratio definition of Eq. 3.

use super::alloc::{Allocation, ModuleAlloc};
use super::topology::{aux_param_shapes, module_dims, ModuleDim};
use crate::config::ModelCfg;

/// Parameters of one module under a decision — `min` is NOT applied here:
/// a Rank(k) choice really stores k(m+n) floats even when wasteful. The
/// allocator is responsible for flipping to Dense (that's the point of the
/// paper's guidance loss).
pub fn module_params(dim: &ModuleDim, a: ModuleAlloc) -> usize {
    match a {
        ModuleAlloc::Dense => dim.dense_params(),
        ModuleAlloc::Rank(k) => dim.factored_params(k),
    }
}

/// Dense parameter count of all compressible modules.
pub fn compressible_params(cfg: &ModelCfg) -> usize {
    module_dims(cfg).iter().map(|d| d.dense_params()).sum()
}

/// Total model parameters (aux + compressible, dense form).
pub fn total_params(cfg: &ModelCfg) -> usize {
    let aux: usize = aux_param_shapes(cfg)
        .iter()
        .map(|(_, s)| s.iter().product::<usize>())
        .sum();
    aux + compressible_params(cfg)
}

/// Parameters stored by an allocation over the compressible modules.
pub fn alloc_params(cfg: &ModelCfg, alloc: &Allocation) -> usize {
    alloc_params_for_dims(&module_dims(cfg), alloc)
}

/// Same, over an explicit module list (used by rescale and tests).
pub fn alloc_params_for_dims(dims: &[ModuleDim], alloc: &Allocation) -> usize {
    dims.iter().map(|d| module_params(d, alloc.get(&d.name))).sum()
}

/// Achieved compression ratio of an allocation (compressible scope).
pub fn alloc_ratio(cfg: &ModelCfg, alloc: &Allocation) -> f64 {
    alloc_params(cfg, alloc) as f64 / compressible_params(cfg) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{model_by_name, Paths};

    fn cfg() -> ModelCfg {
        let paths = Paths::discover().unwrap();
        model_by_name(&paths.configs, "micro-llama").unwrap()
    }

    #[test]
    fn dense_allocation_has_ratio_one() {
        let c = cfg();
        let mut a = Allocation::new("dense");
        for d in module_dims(&c) {
            a.set(&d.name, ModuleAlloc::Dense);
        }
        assert!((alloc_ratio(&c, &a) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn factored_full_rank_exceeds_dense() {
        // the R_max > 1 property that motivates the guidance loss
        let c = cfg();
        let mut a = Allocation::new("full-rank-factored");
        for d in module_dims(&c) {
            a.set(&d.name, ModuleAlloc::Rank(d.r_full()));
        }
        assert!(alloc_ratio(&c, &a) > 1.0);
    }

    #[test]
    fn ratio_is_monotone_in_rank() {
        let c = cfg();
        let dims = module_dims(&c);
        let mut prev = 0.0;
        for k in [1, 4, 8, 16] {
            let mut a = Allocation::new("k");
            for d in &dims {
                a.set(&d.name, ModuleAlloc::Rank(k.min(d.r_full())));
            }
            let r = alloc_ratio(&c, &a);
            assert!(r > prev);
            prev = r;
        }
    }

    #[test]
    fn total_includes_embeddings() {
        let c = cfg();
        assert!(total_params(&c) > compressible_params(&c));
    }
}
