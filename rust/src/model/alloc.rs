//! Rank allocations — the *output* of every allocation method (ARA and all
//! baselines) and the *input* to evaluation, serving specialization, and
//! parameter accounting. Serialized to the JSON schema shared with
//! python/compile/aot.py.

use std::collections::BTreeMap;
use std::path::Path;

use crate::json::{self, Json};
use crate::quant::QuantScheme;
use crate::Result;

/// Per-module decision: keep the dense matrix (the R ≥ 1 branch of Eq. 8)
/// or factorize at rank k.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModuleAlloc {
    Dense,
    Rank(usize),
}

/// A full allocation: module name → decision (BTreeMap for stable output).
#[derive(Debug, Clone, PartialEq)]
pub struct Allocation {
    pub name: String,
    pub modules: BTreeMap<String, ModuleAlloc>,
    /// Weight-quantization recipe for the factored modules, when the plan
    /// composes SVD with quantization (`?quant=int8`). `None` = pure f32.
    /// Carried here so graph specialization and engine upload — which
    /// resolve allocations by name from disk — see the recipe without any
    /// side-channel plumbing.
    pub quant: Option<QuantScheme>,
}

impl Allocation {
    pub fn new(name: impl Into<String>) -> Allocation {
        Allocation { name: name.into(), modules: BTreeMap::new(), quant: None }
    }

    pub fn set(&mut self, module: &str, a: ModuleAlloc) {
        self.modules.insert(module.to_string(), a);
    }

    /// Fallible lookup with the module name in the error — the form every
    /// caller that can propagate a [`crate::error::Error`] should use.
    pub fn try_get(&self, module: &str) -> Result<ModuleAlloc> {
        self.modules.get(module).copied().ok_or_else(|| {
            crate::anyhow!(
                "allocation `{}` has no entry for module `{module}` ({} modules present)",
                self.name,
                self.modules.len()
            )
        })
    }

    /// Infallible lookup for contexts that already validated the allocation
    /// (graph builders after `validate_alloc`); panics with the module name
    /// instead of the old opaque `BTreeMap` index panic.
    pub fn get(&self, module: &str) -> ModuleAlloc {
        self.try_get(module).unwrap_or_else(|e| panic!("{e}"))
    }

    pub fn to_json(&self) -> String {
        let mods = Json::Obj(
            self.modules
                .iter()
                .map(|(k, v)| {
                    let mj = match v {
                        ModuleAlloc::Dense => json::obj(vec![("dense", Json::Bool(true))]),
                        ModuleAlloc::Rank(r) => json::obj(vec![
                            ("dense", Json::Bool(false)),
                            ("rank", json::n(*r as f64)),
                        ]),
                    };
                    (k.clone(), mj)
                })
                .collect(),
        );
        let mut fields = vec![("name", json::s(&self.name)), ("modules", mods)];
        // optional: emitted only for quantized plans, so legacy readers
        // (and aot.py) keep parsing pure-f32 allocations unchanged
        if let Some(q) = &self.quant {
            fields.push((
                "quant",
                json::obj(vec![
                    ("bits", json::n(q.bits as f64)),
                    ("group", json::n(q.group as f64)),
                ]),
            ));
        }
        json::obj(fields).dump()
    }

    pub fn from_json(text: &str) -> Result<Allocation> {
        let j = json::parse(text)?;
        let mut modules = BTreeMap::new();
        for (k, v) in j.req("modules")?.as_obj()? {
            let a = if v.req("dense")?.as_bool()? {
                ModuleAlloc::Dense
            } else {
                ModuleAlloc::Rank(
                    v.get("rank")
                        .ok_or_else(|| crate::anyhow!("module {k}: dense=false requires rank"))?
                        .as_usize()?,
                )
            };
            modules.insert(k.clone(), a);
        }
        let quant = match j.get("quant") {
            Some(Json::Null) | None => None,
            Some(q) => Some(QuantScheme {
                bits: q.req("bits")?.as_usize()? as u32,
                group: q.req("group")?.as_usize()?,
            }),
        };
        Ok(Allocation { name: j.req("name")?.as_str()?.to_string(), modules, quant })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json())?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Allocation> {
        Allocation::from_json(
            &std::fs::read_to_string(path).map_err(|e| crate::anyhow!("read {path:?}: {e}"))?,
        )
    }

    /// Count of modules kept dense (the Fig. 4 headline statistic).
    pub fn dense_count(&self) -> usize {
        self.modules.values().filter(|a| matches!(a, ModuleAlloc::Dense)).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip() {
        let mut a = Allocation::new("test-80");
        a.set("layers.0.attn.wq", ModuleAlloc::Rank(12));
        a.set("layers.0.attn.wv", ModuleAlloc::Dense);
        let b = Allocation::from_json(&a.to_json()).unwrap();
        assert_eq!(a, b);
        assert_eq!(b.dense_count(), 1);
    }

    #[test]
    fn python_schema_compat() {
        // must parse the exact shape aot.py writes
        let text = r#"{"name": "uniform-80", "modules": {
            "layers.0.attn.wq": {"dense": false, "rank": 19},
            "layers.0.mlp.wdown": {"dense": true}}}"#;
        let a = Allocation::from_json(text).unwrap();
        assert_eq!(a.get("layers.0.attn.wq"), ModuleAlloc::Rank(19));
        assert_eq!(a.get("layers.0.mlp.wdown"), ModuleAlloc::Dense);
    }

    #[test]
    fn quant_recipe_round_trips_and_is_optional() {
        let mut a = Allocation::new("uniform-80-q8g32");
        a.set("layers.0.attn.wq", ModuleAlloc::Rank(12));
        a.quant = Some(QuantScheme { bits: 8, group: 32 });
        let b = Allocation::from_json(&a.to_json()).unwrap();
        assert_eq!(a, b);
        // legacy files (no "quant" key) parse with quant = None
        let legacy = r#"{"name": "x", "modules": {"m": {"dense": true}}}"#;
        assert_eq!(Allocation::from_json(legacy).unwrap().quant, None);
        // and a pure-f32 allocation does not emit the key at all
        let f32_alloc = Allocation::new("plain");
        assert!(!f32_alloc.to_json().contains("quant"));
    }

    #[test]
    fn rejects_missing_rank() {
        let text = r#"{"name": "x", "modules": {"m": {"dense": false}}}"#;
        assert!(Allocation::from_json(text).is_err());
    }

    #[test]
    fn try_get_names_the_missing_module() {
        let mut a = Allocation::new("partial");
        a.set("layers.0.attn.wq", ModuleAlloc::Rank(4));
        assert_eq!(a.try_get("layers.0.attn.wq").unwrap(), ModuleAlloc::Rank(4));
        let err = a.try_get("layers.9.mlp.wup").unwrap_err().to_string();
        assert!(err.contains("layers.9.mlp.wup"), "{err}");
        assert!(err.contains("partial"), "{err}");
    }
}
