//! Dense numerical linear algebra, implemented from scratch (no BLAS/LAPACK
//! dependency): Cholesky factorization, triangular inversion, a cyclic
//! Jacobi symmetric eigensolver, and the SVD built on top of it. Everything
//! runs in f64 — these routines execute once per module during the
//! compression pipeline, not on the request path, so robustness beats speed.

mod cholesky;
mod eig;
mod simplex;
mod svd;

pub use cholesky::{cholesky, invert_lower_triangular};
pub use eig::jacobi_eigh;
pub use simplex::project_simplex;
pub use svd::{svd, Svd};

/// A dense f64 matrix in row-major order (internal to linalg and svd).
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> Mat {
        assert_eq!(rows * cols, data.len());
        Mat { rows, cols, data: data.iter().map(|&x| x as f64).collect() }
    }

    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&x| x as f32).collect()
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        let c = self.cols;
        self.data[i * c + j] = v;
    }

    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul inner dim");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Mat::zeros(m, n);
        crate::kernels::matmul_f64(&self.data, &other.data, m, k, n, false, false, &mut out.data);
        out
    }

    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        out
    }

    /// Gram matrix AᵀA (cols × cols), exploiting symmetry.
    pub fn gram(&self) -> Mat {
        let (m, n) = (self.rows, self.cols);
        let mut g = Mat::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                let mut s = 0.0;
                for r in 0..m {
                    s += self.data[r * n + i] * self.data[r * n + j];
                }
                g.set(i, j, s);
                g.set(j, i, s);
            }
        }
        g
    }

    /// Outer Gram AAᵀ (rows × rows), exploiting symmetry.
    pub fn gram_outer(&self) -> Mat {
        let (m, n) = (self.rows, self.cols);
        let mut g = Mat::zeros(m, m);
        for i in 0..m {
            let ri = &self.data[i * n..(i + 1) * n];
            for j in i..m {
                let rj = &self.data[j * n..(j + 1) * n];
                let s: f64 = ri.iter().zip(rj).map(|(a, b)| a * b).sum();
                g.set(i, j, s);
                g.set(j, i, s);
            }
        }
        g
    }

    pub fn fro(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gram_matches_explicit() {
        let a = Mat { rows: 3, cols: 2, data: vec![1., 2., 3., 4., 5., 6.] };
        let g = a.gram();
        let g2 = a.transpose().matmul(&a);
        for (x, y) in g.data.iter().zip(&g2.data) {
            assert!((x - y).abs() < 1e-12);
        }
        let go = a.gram_outer();
        let go2 = a.matmul(&a.transpose());
        for (x, y) in go.data.iter().zip(&go2.data) {
            assert!((x - y).abs() < 1e-12);
        }
    }
}
