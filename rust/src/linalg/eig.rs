//! Cyclic Jacobi eigensolver for symmetric matrices — the workhorse behind
//! the SVD (via the Gram matrix of the smaller side) and the SliceGPT-like
//! pruning baseline (PCA of activation covariance).

use super::Mat;

/// Eigendecomposition of a symmetric matrix: `a = V · diag(w) · Vᵀ`.
///
/// Returns `(w, v)` with eigenvalues sorted descending and eigenvectors as
/// *columns* of `v`. Cyclic Jacobi with a convergence threshold on the
/// off-diagonal Frobenius mass; O(n³) per sweep, typically 6–12 sweeps.
pub fn jacobi_eigh(a: &Mat) -> (Vec<f64>, Mat) {
    let n = a.rows;
    assert_eq!(a.rows, a.cols);
    let mut m = a.clone();
    let mut v = Mat::eye(n);
    let max_sweeps = 64;

    for _sweep in 0..max_sweeps {
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m.at(i, j) * m.at(i, j);
            }
        }
        let scale = m.fro().max(1e-300);
        if off.sqrt() <= 1e-13 * scale {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m.at(p, q);
                if apq.abs() <= 1e-300 {
                    continue;
                }
                let app = m.at(p, p);
                let aqq = m.at(q, q);
                let tau = (aqq - app) / (2.0 * apq);
                let t = if tau >= 0.0 {
                    1.0 / (tau + (1.0 + tau * tau).sqrt())
                } else {
                    -1.0 / (-tau + (1.0 + tau * tau).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;

                // rows/cols p and q of m
                for k in 0..n {
                    let mkp = m.at(k, p);
                    let mkq = m.at(k, q);
                    m.set(k, p, c * mkp - s * mkq);
                    m.set(k, q, s * mkp + c * mkq);
                }
                for k in 0..n {
                    let mpk = m.at(p, k);
                    let mqk = m.at(q, k);
                    m.set(p, k, c * mpk - s * mqk);
                    m.set(q, k, s * mpk + c * mqk);
                }
                for k in 0..n {
                    let vkp = v.at(k, p);
                    let vkq = v.at(k, q);
                    v.set(k, p, c * vkp - s * vkq);
                    v.set(k, q, s * vkp + c * vkq);
                }
            }
        }
    }

    let mut order: Vec<usize> = (0..n).collect();
    let w: Vec<f64> = (0..n).map(|i| m.at(i, i)).collect();
    order.sort_by(|&a, &b| w[b].partial_cmp(&w[a]).unwrap());
    let w_sorted: Vec<f64> = order.iter().map(|&i| w[i]).collect();
    let mut v_sorted = Mat::zeros(n, n);
    for (new_col, &old_col) in order.iter().enumerate() {
        for r in 0..n {
            v_sorted.set(r, new_col, v.at(r, old_col));
        }
    }
    (w_sorted, v_sorted)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn random_sym(n: usize, seed: u64) -> Mat {
        let mut state = seed | 1;
        let mut next = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            (state as f64 / u64::MAX as f64) - 0.5
        };
        let mut a = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let x = next();
                a.set(i, j, x);
                a.set(j, i, x);
            }
        }
        a
    }

    #[test]
    fn reconstructs_matrix() {
        for n in [1, 2, 4, 12, 33] {
            let a = random_sym(n, 3 + n as u64);
            let (w, v) = jacobi_eigh(&a);
            // A ≈ V diag(w) Vᵀ
            let mut vd = v.clone();
            for i in 0..n {
                for j in 0..n {
                    let x = vd.at(i, j) * w[j];
                    vd.set(i, j, x);
                }
            }
            let back = vd.matmul(&v.transpose());
            for (x, y) in back.data.iter().zip(&a.data) {
                assert!((x - y).abs() < 1e-9, "n={n}");
            }
        }
    }

    #[test]
    fn eigenvectors_orthonormal() {
        let a = random_sym(16, 99);
        let (_, v) = jacobi_eigh(&a);
        let vtv = v.transpose().matmul(&v);
        for i in 0..16 {
            for j in 0..16 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((vtv.at(i, j) - want).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn eigenvalues_sorted_descending() {
        let a = random_sym(20, 5);
        let (w, _) = jacobi_eigh(&a);
        for i in 1..w.len() {
            assert!(w[i - 1] >= w[i] - 1e-12);
        }
    }

    #[test]
    fn diagonal_matrix_exact() {
        let mut a = Mat::zeros(3, 3);
        a.set(0, 0, 2.0);
        a.set(1, 1, -1.0);
        a.set(2, 2, 5.0);
        let (w, _) = jacobi_eigh(&a);
        assert!((w[0] - 5.0).abs() < 1e-12);
        assert!((w[1] - 2.0).abs() < 1e-12);
        assert!((w[2] + 1.0).abs() < 1e-12);
    }
}
