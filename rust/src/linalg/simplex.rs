//! Euclidean projection onto the probability simplex — keeps the ARA
//! trainable vectors α on Δ^D after every AdamW step (Sec. 3.2 requires
//! α ≥ 0, Σα = 1 so that p = αM is a valid monotone probability mask).

/// Project `v` onto the probability simplex `{x : x ≥ 0, Σx = 1}` in place.
///
/// Held/Wolfe/Crowder algorithm: sort descending, find the pivot, shift.
/// O(D log D).
pub fn project_simplex(v: &mut [f64]) {
    let d = v.len();
    assert!(d > 0);
    let mut sorted: Vec<f64> = v.to_vec();
    sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let mut cum = 0.0;
    let mut theta = 0.0;
    let mut found = false;
    for (i, &u) in sorted.iter().enumerate() {
        cum += u;
        let t = (cum - 1.0) / (i + 1) as f64;
        if u - t > 0.0 {
            theta = t;
            found = true;
        } else {
            break;
        }
    }
    if !found {
        // all mass on the largest coordinate
        theta = sorted[0] - 1.0;
    }
    for x in v.iter_mut() {
        *x = (*x - theta).max(0.0);
    }
    // guard against accumulated fp drift
    let s: f64 = v.iter().sum();
    if s > 0.0 {
        for x in v.iter_mut() {
            *x /= s;
        }
    } else {
        let u = 1.0 / d as f64;
        for x in v.iter_mut() {
            *x = u;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_on_simplex(v: &[f64]) {
        let s: f64 = v.iter().sum();
        assert!((s - 1.0).abs() < 1e-9, "sum={s}");
        for &x in v {
            assert!(x >= 0.0);
        }
    }

    #[test]
    fn already_on_simplex_unchanged() {
        let mut v = vec![0.25, 0.25, 0.25, 0.25];
        project_simplex(&mut v);
        for &x in &v {
            assert!((x - 0.25).abs() < 1e-12);
        }
    }

    #[test]
    fn negative_entries_clipped() {
        let mut v = vec![1.5, -0.5, 0.2];
        project_simplex(&mut v);
        assert_on_simplex(&v);
        assert_eq!(v[1], 0.0);
    }

    #[test]
    fn preserves_order() {
        let mut v = vec![0.9, 0.5, 0.1, -2.0];
        project_simplex(&mut v);
        assert_on_simplex(&v);
        for i in 1..v.len() {
            assert!(v[i - 1] >= v[i]);
        }
    }

    #[test]
    fn large_uniform_input() {
        let mut v = vec![100.0; 64];
        project_simplex(&mut v);
        assert_on_simplex(&v);
        for &x in &v {
            assert!((x - 1.0 / 64.0).abs() < 1e-9);
        }
    }

    #[test]
    fn projection_is_idempotent() {
        let mut v = vec![3.0, -1.0, 0.5, 0.25, 7.0];
        project_simplex(&mut v);
        let once = v.clone();
        project_simplex(&mut v);
        for (a, b) in v.iter().zip(&once) {
            assert!((a - b).abs() < 1e-9);
        }
    }
}
