//! Cholesky factorization and lower-triangular inversion — the whitening
//! half of the activation-aware SVD (Sec. 3.1: H = S·Sᵀ, W_v uses S⁻¹).

use super::Mat;
use crate::Result;

/// Cholesky factor `S` (lower triangular) of a symmetric PD matrix `H = S·Sᵀ`.
///
/// Fails on non-positive pivots; callers are expected to dampen `H`
/// (`H + εI`) first — the calibration pipeline does (svd/calib.rs).
pub fn cholesky(h: &Mat) -> Result<Mat> {
    let n = h.rows;
    assert_eq!(h.rows, h.cols);
    let mut s = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = h.at(i, j);
            for k in 0..j {
                sum -= s.at(i, k) * s.at(j, k);
            }
            if i == j {
                if sum <= 0.0 {
                    return Err(crate::anyhow!(
                        "cholesky: non-positive pivot {sum:.3e} at {i} (dampen H)"
                    ));
                }
                s.set(i, j, sum.sqrt());
            } else {
                s.set(i, j, sum / s.at(j, j));
            }
        }
    }
    Ok(s)
}

/// Invert a lower-triangular matrix by forward substitution.
pub fn invert_lower_triangular(l: &Mat) -> Result<Mat> {
    let n = l.rows;
    assert_eq!(l.rows, l.cols);
    let mut inv = Mat::zeros(n, n);
    for col in 0..n {
        // solve L x = e_col
        for i in col..n {
            let mut sum = if i == col { 1.0 } else { 0.0 };
            for k in col..i {
                sum -= l.at(i, k) * inv.at(k, col);
            }
            let d = l.at(i, i);
            if d == 0.0 {
                return Err(crate::anyhow!("singular triangular matrix at {i}"));
            }
            inv.set(i, col, sum / d);
        }
    }
    Ok(inv)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn random_spd(n: usize, seed: u64) -> Mat {
        let mut state = seed | 1;
        let mut next = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            (state as f64 / u64::MAX as f64) - 0.5
        };
        let mut a = Mat::zeros(n, n);
        for v in a.data.iter_mut() {
            *v = next();
        }
        let mut h = a.gram(); // AᵀA is PSD
        for i in 0..n {
            let d = h.at(i, i) + 0.5;
            h.set(i, i, d); // make strictly PD
        }
        h
    }

    #[test]
    fn cholesky_reconstructs() {
        for n in [1, 2, 5, 17] {
            let h = random_spd(n, 42 + n as u64);
            let s = cholesky(&h).unwrap();
            let back = s.matmul(&s.transpose());
            for (x, y) in back.data.iter().zip(&h.data) {
                assert!((x - y).abs() < 1e-9, "n={n}");
            }
            // strictly lower triangular above diagonal must be zero
            for i in 0..n {
                for j in (i + 1)..n {
                    assert_eq!(s.at(i, j), 0.0);
                }
            }
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let mut h = Mat::eye(3);
        h.set(2, 2, -1.0);
        assert!(cholesky(&h).is_err());
    }

    #[test]
    fn triangular_inverse() {
        for n in [1, 3, 9] {
            let h = random_spd(n, 7 + n as u64);
            let s = cholesky(&h).unwrap();
            let si = invert_lower_triangular(&s).unwrap();
            let prod = s.matmul(&si);
            for i in 0..n {
                for j in 0..n {
                    let want = if i == j { 1.0 } else { 0.0 };
                    assert!((prod.at(i, j) - want).abs() < 1e-9);
                }
            }
        }
    }
}
