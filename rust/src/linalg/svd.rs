//! Singular value decomposition via the symmetric eigendecomposition of the
//! Gram matrix of the smaller side — robust and O(min(m,n)³) for the module
//! shapes this repo factorizes (≤ a few hundred).

use super::{jacobi_eigh, Mat};

/// Thin SVD `a = U · diag(s) · Vᵀ`, `U: m×r`, `Vᵀ: r×n`, `r = min(m, n)`,
/// singular values descending.
#[derive(Debug, Clone)]
pub struct Svd {
    pub u: Mat,
    pub s: Vec<f64>,
    pub vt: Mat,
}

/// Compute the thin SVD of `a` (m×n).
///
/// If m ≤ n: eigendecompose A·Aᵀ → U, then Vᵀ = Σ⁺·Uᵀ·A; otherwise the
/// transpose route. Singular vectors for near-zero singular values are
/// completed deterministically so U/V stay full column rank (they get a
/// zero row in Vᵀ — harmless for truncation use).
pub fn svd(a: &Mat) -> Svd {
    let (m, n) = (a.rows, a.cols);
    if m <= n {
        let g = a.gram_outer(); // A Aᵀ, m×m
        let (w, u) = jacobi_eigh(&g);
        let s: Vec<f64> = w.iter().map(|&x| x.max(0.0).sqrt()).collect();
        // Vᵀ = Σ⁺ Uᵀ A  (r×n)
        let uta = u.transpose().matmul(a);
        let mut vt = Mat::zeros(m, n);
        for i in 0..m {
            let inv = if s[i] > 1e-12 * s[0].max(1e-300) { 1.0 / s[i] } else { 0.0 };
            for j in 0..n {
                vt.set(i, j, uta.at(i, j) * inv);
            }
        }
        Svd { u, s, vt }
    } else {
        let at = a.transpose();
        let sv = svd(&at); // at = U' Σ V'ᵀ  ⇒  a = V' Σ U'ᵀ
        Svd { u: sv.vt.transpose(), s: sv.s, vt: sv.u.transpose() }
    }
}

impl Svd {
    /// Truncation loss √(Σ_{i≥k} σ_i²) — Eq. (1) tail, used by G_R (Eq. 6).
    pub fn tail_norm(&self, k: usize) -> f64 {
        self.s[k.min(self.s.len())..].iter().map(|x| x * x).sum::<f64>().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn random_mat(m: usize, n: usize, seed: u64) -> Mat {
        let mut state = seed | 1;
        let mut next = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            (state as f64 / u64::MAX as f64) - 0.5
        };
        let mut a = Mat::zeros(m, n);
        for v in a.data.iter_mut() {
            *v = next();
        }
        a
    }

    fn check_reconstruction(m: usize, n: usize, seed: u64) {
        let a = random_mat(m, n, seed);
        let d = svd(&a);
        let r = m.min(n);
        assert_eq!(d.u.rows, m);
        assert_eq!(d.u.cols, r);
        assert_eq!(d.vt.rows, r);
        assert_eq!(d.vt.cols, n);
        let mut us = d.u.clone();
        for i in 0..m {
            for j in 0..r {
                let x = us.at(i, j) * d.s[j];
                us.set(i, j, x);
            }
        }
        let back = us.matmul(&d.vt);
        for (x, y) in back.data.iter().zip(&a.data) {
            assert!((x - y).abs() < 1e-8, "m={m} n={n}");
        }
        for i in 1..r {
            assert!(d.s[i - 1] >= d.s[i] - 1e-12);
        }
    }

    #[test]
    fn reconstructs_wide_tall_square() {
        check_reconstruction(6, 6, 1);
        check_reconstruction(4, 11, 2);
        check_reconstruction(11, 4, 3);
        check_reconstruction(1, 7, 4);
        check_reconstruction(23, 17, 5);
    }

    #[test]
    fn rank_deficient() {
        // a = outer(u, v) has rank 1
        let m = 8;
        let n = 5;
        let mut a = Mat::zeros(m, n);
        for i in 0..m {
            for j in 0..n {
                a.set(i, j, (i + 1) as f64 * (j as f64 - 2.0));
            }
        }
        let d = svd(&a);
        assert!(d.s[0] > 1.0);
        for &s in &d.s[1..] {
            assert!(s < 1e-8 * d.s[0]);
        }
    }

    #[test]
    fn eckart_young_truncation_is_optimal_direction() {
        // truncating to k keeps the largest σ: tail_norm must be the exact
        // Frobenius error of the rank-k reconstruction.
        let a = random_mat(10, 7, 9);
        let d = svd(&a);
        for k in 0..=7 {
            let mut us = Mat::zeros(10, k);
            for i in 0..10 {
                for j in 0..k {
                    us.set(i, j, d.u.at(i, j) * d.s[j]);
                }
            }
            let mut vt = Mat::zeros(k, 7);
            for i in 0..k {
                for j in 0..7 {
                    vt.set(i, j, d.vt.at(i, j));
                }
            }
            let back = us.matmul(&vt);
            let mut err = 0.0;
            for (x, y) in back.data.iter().zip(&a.data) {
                err += (x - y) * (x - y);
            }
            assert!((err.sqrt() - d.tail_norm(k)).abs() < 1e-8, "k={k}");
        }
    }
}
