//! In-crate error substrate (the offline vendor set has no `anyhow`): a
//! string-typed error with a format-macro constructor, mirroring the
//! `anyhow!` / `Result` surface the rest of the crate was written against.
//!
//! `Error` deliberately does NOT implement `std::error::Error`; that keeps
//! the blanket `From<E: std::error::Error>` conversion below coherent, so
//! `?` works on `io::Error`, `Utf8Error`, parse errors, etc. — the same
//! trick `anyhow` itself uses.

use std::fmt;

/// A human-readable error message carried up the pipeline.
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error(e.to_string())
    }
}

/// Crate-wide result type (drop-in for the previous `anyhow::Result`).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string — drop-in for `anyhow!`.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::error::Error(format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        Ok(std::fs::read_to_string("/definitely/not/a/real/path/xyz")?)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!e.0.is_empty());
    }

    #[test]
    fn macro_formats() {
        let e = crate::anyhow!("bad {} at {}", "thing", 7);
        assert_eq!(e.to_string(), "bad thing at 7");
    }
}
