//! ara-compress: a reproduction of "ARA: Adaptive Rank Allocation for
//! Efficient Large Language Model SVD Compression" (2025) as a three-layer
//! Rust + JAX + Pallas system.
//!
//! Layer map (see DESIGN.md):
//! * L3 (this crate) — every runtime loop: LM pre-training, calibration,
//!   SVD factorization, allocation training (ARA + all baselines),
//!   evaluation, quantization, LoRA recovery, and a batched serving engine.
//! * L2/L1 (python/compile, build time only) — JAX transformer families and
//!   Pallas kernels, AOT-lowered to HLO text consumed by [`runtime`].
//!
//! The public API is organized bottom-up: substrates ([`tensor`], [`kernels`],
//! [`linalg`], [`data`], [`model`], [`runtime`]), the compression stack ([`svd`],
//! [`ara`], [`baselines`], [`compress`] — the unified method registry and
//! plan artifacts — [`quant`], [`lora`]), and the harnesses
//! ([`training`], [`eval`], [`serving`], [`coordinator`], [`report`]).

pub mod ara;
pub mod baselines;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod eval;
pub mod json;
pub mod kernels;
pub mod linalg;
pub mod lora;
pub mod model;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod serving;
pub mod svd;
pub mod tensor;
pub mod training;

pub use error::{Error, Result};
