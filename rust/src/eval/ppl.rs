//! Perplexity: exp(mean NLL) over held-out eval streams, via the AOT
//! `score_dense` / `score_masked` executables.

use std::collections::{BTreeMap, HashMap};

use crate::config::ModelCfg;
use crate::data::{batches, corpus_spec, generate_tokens, EVAL_SEED};
use crate::model::WeightStore;
use crate::runtime::{Feed, Runtime};
use crate::svd::{factored_feeds, FactoredModel};
use crate::tensor::Tensor;
use crate::Result;

#[derive(Debug, Clone)]
pub struct PplReport {
    pub corpus: String,
    pub ppl: f64,
    pub mean_nll: f64,
    pub tokens: usize,
}

fn eval_batches(cfg: &ModelCfg, corpus: &str, n_batches: usize) -> Vec<(crate::tensor::IntTensor, crate::tensor::IntTensor)> {
    let spec = corpus_spec(corpus);
    let need = n_batches * cfg.batch_eval * (cfg.seq_eval + 1) + 1;
    let stream = generate_tokens(cfg.vocab, spec, EVAL_SEED, need);
    batches(&stream, cfg.batch_eval, cfg.seq_eval)
}

/// PPL of the dense model.
pub fn perplexity_dense(
    cfg: &ModelCfg,
    rt: &Runtime,
    ws: &WeightStore,
    corpus: &str,
    n_batches: usize,
) -> Result<PplReport> {
    let exe = rt.load("score_dense")?;
    let data = eval_batches(cfg, corpus, n_batches);
    let mut sum = 0.0;
    let mut count = 0usize;
    for (toks, tgts) in data.iter().take(n_batches) {
        let mut feeds: HashMap<&str, Feed> = HashMap::new();
        for (name, t) in &ws.tensors {
            feeds.insert(name.as_str(), Feed::F32(t));
        }
        feeds.insert("tokens", Feed::I32(toks));
        feeds.insert("targets", Feed::I32(tgts));
        let out = exe.run(&feeds)?;
        let nll = out.tensor("nll")?;
        sum += nll.data.iter().map(|&x| x as f64).sum::<f64>();
        count += nll.data.len();
    }
    finish(corpus, sum, count)
}

/// PPL of a compressed model (factored weights + binary masks).
pub fn perplexity_masked(
    cfg: &ModelCfg,
    rt: &Runtime,
    ws: &WeightStore,
    fm: &FactoredModel,
    masks: &BTreeMap<String, Tensor>,
    corpus: &str,
    n_batches: usize,
) -> Result<PplReport> {
    let exe = rt.load("score_masked")?;
    let data = eval_batches(cfg, corpus, n_batches);
    let mut sum = 0.0;
    let mut count = 0usize;
    for (toks, tgts) in data.iter().take(n_batches) {
        let mut feeds: HashMap<&str, Feed> = HashMap::new();
        factored_feeds(ws, fm, masks, &mut feeds);
        feeds.insert("tokens", Feed::I32(toks));
        feeds.insert("targets", Feed::I32(tgts));
        let out = exe.run(&feeds)?;
        let nll = out.tensor("nll")?;
        sum += nll.data.iter().map(|&x| x as f64).sum::<f64>();
        count += nll.data.len();
    }
    finish(corpus, sum, count)
}

fn finish(corpus: &str, sum: f64, count: usize) -> Result<PplReport> {
    if count == 0 {
        return Err(crate::anyhow!("no eval batches"));
    }
    let mean = sum / count as f64;
    Ok(PplReport { corpus: corpus.to_string(), ppl: mean.exp(), mean_nll: mean, tokens: count })
}
