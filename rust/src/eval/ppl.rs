//! Perplexity: exp(mean NLL) over held-out eval streams, via the AOT
//! `score_dense` / `score_masked` executables.

use std::collections::{BTreeMap, HashMap};

use crate::config::ModelCfg;
use crate::data::{batches, corpus_spec, generate_tokens, EVAL_SEED};
use crate::model::WeightStore;
use crate::runtime::{Feed, Runtime};
use crate::svd::{factored_feeds, FactoredModel};
use crate::tensor::Tensor;
use crate::Result;

#[derive(Debug, Clone)]
pub struct PplReport {
    pub corpus: String,
    pub ppl: f64,
    pub mean_nll: f64,
    pub tokens: usize,
}

fn eval_batches(cfg: &ModelCfg, corpus: &str, n_batches: usize) -> Vec<(crate::tensor::IntTensor, crate::tensor::IntTensor)> {
    let spec = corpus_spec(corpus);
    let need = n_batches * cfg.batch_eval * (cfg.seq_eval + 1) + 1;
    let stream = generate_tokens(cfg.vocab, spec, EVAL_SEED, need);
    batches(&stream, cfg.batch_eval, cfg.seq_eval)
}

/// PPL of the dense model.
pub fn perplexity_dense(
    cfg: &ModelCfg,
    rt: &Runtime,
    ws: &WeightStore,
    corpus: &str,
    n_batches: usize,
) -> Result<PplReport> {
    let exe = rt.load("score_dense")?;
    let data = eval_batches(cfg, corpus, n_batches);
    let mut sum = 0.0;
    let mut count = 0usize;
    for (toks, tgts) in data.iter().take(n_batches) {
        let mut feeds: HashMap<&str, Feed> = HashMap::new();
        for (name, t) in &ws.tensors {
            feeds.insert(name.as_str(), Feed::F32(t));
        }
        feeds.insert("tokens", Feed::I32(toks));
        feeds.insert("targets", Feed::I32(tgts));
        let out = exe.run(&feeds)?;
        let nll = out.tensor("nll")?;
        sum += nll.data.iter().map(|&x| x as f64).sum::<f64>();
        count += nll.data.len();
    }
    finish(corpus, sum, count)
}

/// PPL of a compressed model (factored weights + binary masks).
pub fn perplexity_masked(
    cfg: &ModelCfg,
    rt: &Runtime,
    ws: &WeightStore,
    fm: &FactoredModel,
    masks: &BTreeMap<String, Tensor>,
    corpus: &str,
    n_batches: usize,
) -> Result<PplReport> {
    let exe = rt.load("score_masked")?;
    let data = eval_batches(cfg, corpus, n_batches);
    let mut sum = 0.0;
    let mut count = 0usize;
    for (toks, tgts) in data.iter().take(n_batches) {
        let mut feeds: HashMap<&str, Feed> = HashMap::new();
        factored_feeds(ws, fm, masks, &mut feeds);
        feeds.insert("tokens", Feed::I32(toks));
        feeds.insert("targets", Feed::I32(tgts));
        let out = exe.run(&feeds)?;
        let nll = out.tensor("nll")?;
        sum += nll.data.iter().map(|&x| x as f64).sum::<f64>();
        count += nll.data.len();
    }
    finish(corpus, sum, count)
}

fn finish(corpus: &str, sum: f64, count: usize) -> Result<PplReport> {
    if count == 0 {
        return Err(crate::anyhow!("no eval batches"));
    }
    let mean = sum / count as f64;
    Ok(PplReport { corpus: corpus.to_string(), ppl: mean.exp(), mean_nll: mean, tokens: count })
}

/// Max tolerated **relative** perplexity increase of a quantized plan over
/// its f32 sibling (`ARA_PPL_GATE`, default 0.2 = 20%). The quality gate
/// the `fig_quant` bench enforces (DESIGN.md §9).
pub fn ppl_gate_threshold() -> f64 {
    std::env::var("ARA_PPL_GATE")
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|t: &f64| t.is_finite() && *t >= 0.0)
        .unwrap_or(0.2)
}

/// The perplexity-delta quality gate: returns the relative ppl increase
/// `(quant - f32) / f32`, or an error naming both perplexities when the
/// quantized plan degrades quality past `threshold`. Non-finite inputs
/// always fail — a NaN ppl must never pass a quality gate.
pub fn check_ppl_gate(f32_ppl: f64, quant_ppl: f64, threshold: f64) -> Result<f64> {
    if !f32_ppl.is_finite() || !quant_ppl.is_finite() || f32_ppl <= 0.0 {
        return Err(crate::anyhow!(
            "ppl gate: non-finite perplexities (f32 {f32_ppl}, quant {quant_ppl})"
        ));
    }
    let delta = (quant_ppl - f32_ppl) / f32_ppl;
    if delta > threshold {
        return Err(crate::anyhow!(
            "ppl gate FAILED: quantized ppl {quant_ppl:.4} exceeds f32 ppl {f32_ppl:.4} \
             by {:.1}% (> {:.1}% allowed; tune ARA_PPL_GATE)",
            delta * 100.0,
            threshold * 100.0
        ));
    }
    Ok(delta)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gate_passes_small_delta_and_fails_large() {
        let d = check_ppl_gate(10.0, 10.5, 0.2).unwrap();
        assert!((d - 0.05).abs() < 1e-12);
        // quantization can even improve ppl — negative delta passes
        assert!(check_ppl_gate(10.0, 9.0, 0.2).unwrap() < 0.0);
        let err = check_ppl_gate(10.0, 13.0, 0.2).unwrap_err().to_string();
        assert!(err.contains("ppl gate FAILED"), "{err}");
        assert!(check_ppl_gate(f64::NAN, 10.0, 0.2).is_err());
        assert!(check_ppl_gate(10.0, f64::INFINITY, 0.2).is_err());
    }

    #[test]
    fn gate_threshold_reads_env_with_default() {
        // no poking at the real env from tests that may run in parallel:
        // just pin the default
        if std::env::var("ARA_PPL_GATE").is_err() {
            assert_eq!(ppl_gate_threshold(), 0.2);
        }
    }
}
