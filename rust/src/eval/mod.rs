//! Evaluation harness: perplexity on the synthetic corpora and the
//! LM-eval-harness-style zero-shot suite.

mod ppl;
pub mod zeroshot;

pub use ppl::{
    check_ppl_gate, perplexity_dense, perplexity_masked, ppl_gate_threshold, PplReport,
};
pub use zeroshot::{zero_shot_suite, Scorer, ZeroShotReport};
