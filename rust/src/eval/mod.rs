//! Evaluation harness: perplexity on the synthetic corpora and the
//! LM-eval-harness-style zero-shot suite.

mod ppl;
pub mod zeroshot;

pub use ppl::{perplexity_dense, perplexity_masked, PplReport};
pub use zeroshot::{zero_shot_suite, Scorer, ZeroShotReport};
