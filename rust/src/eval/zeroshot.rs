//! Zero-shot evaluation, LM-eval-harness style: each multiple-choice item is
//! scored by the average per-token log-probability of every choice
//! continuation given the context; the highest-scoring choice wins.

use std::collections::{BTreeMap, HashMap};

use crate::config::ModelCfg;
use crate::data::{generate_task, task_names, Grammar, TaskItem, ALL_TASKS};
use crate::model::WeightStore;
use crate::runtime::{Exe, Feed, Runtime};
use crate::svd::{factored_feeds, FactoredModel};
use crate::tensor::{IntTensor, Tensor};
use crate::Result;

#[derive(Debug, Clone)]
pub struct ZeroShotReport {
    /// (task name, accuracy %)
    pub tasks: Vec<(&'static str, f64)>,
    pub average: f64,
}

/// Which parameterization to score with.
pub enum Scorer<'a> {
    Dense { ws: &'a WeightStore },
    Masked { ws: &'a WeightStore, fm: &'a FactoredModel, masks: &'a BTreeMap<String, Tensor> },
}

impl<'a> Scorer<'a> {
    fn exe(&self, rt: &Runtime) -> Result<std::rc::Rc<Exe>> {
        match self {
            Scorer::Dense { .. } => rt.load("score_dense"),
            Scorer::Masked { .. } => rt.load("score_masked"),
        }
    }

    fn feeds<'b>(&'b self, feeds: &mut HashMap<&'b str, Feed<'b>>) {
        match self {
            Scorer::Dense { ws } => {
                for (name, t) in &ws.tensors {
                    feeds.insert(name.as_str(), Feed::F32(t));
                }
            }
            Scorer::Masked { ws, fm, masks } => factored_feeds(ws, fm, masks, feeds),
        }
    }
}

/// One scoring row: a (ctx ‖ choice) sequence with the choice span marked.
struct Row {
    tokens: Vec<i32>,
    targets: Vec<i32>,
    span: (usize, usize), // [start, end) positions whose NLL counts
    item: usize,
    choice: usize,
}

fn build_rows(items: &[TaskItem], seq: usize) -> Vec<Row> {
    let mut rows = Vec::new();
    for (ii, it) in items.iter().enumerate() {
        for (ci, ch) in it.choices.iter().enumerate() {
            let mut s = Vec::with_capacity(it.ctx.len() + ch.len() + 1);
            s.push(crate::data::BOS_TOKEN);
            s.extend_from_slice(&it.ctx);
            s.extend_from_slice(ch);
            if s.len() > seq + 1 {
                let cut = s.len() - (seq + 1);
                s.drain(1..1 + cut); // keep BOS, trim oldest context
            }
            let start = s.len() - 1 - ch.len();
            let end = s.len() - 1;
            let mut tokens: Vec<i32> = s[..s.len() - 1].to_vec();
            let mut targets: Vec<i32> = s[1..].to_vec();
            tokens.resize(seq, crate::data::BOS_TOKEN);
            targets.resize(seq, crate::data::BOS_TOKEN);
            rows.push(Row { tokens, targets, span: (start, end), item: ii, choice: ci });
        }
    }
    rows
}

/// Run the full 7-task suite; returns per-task accuracy + macro average.
pub fn zero_shot_suite(
    cfg: &ModelCfg,
    rt: &Runtime,
    scorer: &Scorer,
    items_per_task: usize,
    seed: u64,
) -> Result<ZeroShotReport> {
    let exe = scorer.exe(rt)?;
    let g = Grammar::new(cfg.vocab, 4, 0.0, 77);
    let (b, t) = (cfg.batch_eval, cfg.seq_eval);

    let mut tasks = Vec::new();
    let mut total = 0.0;
    for kind in ALL_TASKS {
        let items = generate_task(kind, &g, seed, items_per_task);
        let rows = build_rows(&items, t);

        // score per (item, choice): average logprob over the choice span
        let mut scores: Vec<Vec<f64>> =
            items.iter().map(|it| vec![f64::NEG_INFINITY; it.choices.len()]).collect();
        for chunk in rows.chunks(b) {
            let mut toks = Vec::with_capacity(b * t);
            let mut tgts = Vec::with_capacity(b * t);
            for r in chunk {
                toks.extend_from_slice(&r.tokens);
                tgts.extend_from_slice(&r.targets);
            }
            // pad the final partial batch by repeating the last row
            while toks.len() < b * t {
                toks.extend_from_slice(&chunk.last().unwrap().tokens);
                tgts.extend_from_slice(&chunk.last().unwrap().targets);
            }
            let toks = IntTensor::from_vec(&[b, t], toks);
            let tgts = IntTensor::from_vec(&[b, t], tgts);
            let mut feeds: HashMap<&str, Feed> = HashMap::new();
            scorer.feeds(&mut feeds);
            feeds.insert("tokens", Feed::I32(&toks));
            feeds.insert("targets", Feed::I32(&tgts));
            let out = exe.run(&feeds)?;
            let nll = out.tensor("nll")?;
            for (ri, r) in chunk.iter().enumerate() {
                let (s, e) = r.span;
                let span_nll: f64 = (s..e)
                    .map(|p| nll.data[ri * t + p] as f64)
                    .sum::<f64>();
                scores[r.item][r.choice] = -(span_nll / (e - s).max(1) as f64);
            }
        }

        let mut correct = 0usize;
        for (it, sc) in items.iter().zip(&scores) {
            let best = sc
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if best == it.answer {
                correct += 1;
            }
        }
        let acc = 100.0 * correct as f64 / items.len() as f64;
        total += acc;
        tasks.push((task_names(kind), acc));
    }
    let average = total / ALL_TASKS.len() as f64;
    Ok(ZeroShotReport { tasks, average })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::TaskKind;

    #[test]
    fn rows_mark_choice_span() {
        let items = vec![TaskItem {
            ctx: vec![5, 6, 7],
            choices: vec![vec![8], vec![9, 10]],
            answer: 0,
        }];
        let rows = build_rows(&items, 16);
        assert_eq!(rows.len(), 2);
        // row 0: seq = BOS 5 6 7 8 → targets span predicts token 8
        let r = &rows[0];
        assert_eq!(r.targets[r.span.0], 8);
        let r = &rows[1];
        assert_eq!(r.targets[r.span.0], 9);
        assert_eq!(r.targets[r.span.1 - 1], 10);
    }

    #[test]
    fn rows_trim_long_contexts() {
        let items = vec![TaskItem {
            ctx: (0..40).collect(),
            choices: vec![vec![50]],
            answer: 0,
        }];
        let rows = build_rows(&items, 16);
        assert_eq!(rows[0].tokens.len(), 16);
        assert_eq!(rows[0].targets[rows[0].span.0], 50);
    }

    #[test]
    fn suite_covers_all_tasks() {
        let g = Grammar::new(256, 4, 0.0, 77);
        for kind in ALL_TASKS {
            let items = generate_task(kind, &g, 1, 4);
            let rows = build_rows(&items, 32);
            assert!(rows.len() >= items.len() * 2);
        }
        let _ = TaskKind::ArcEasy;
    }
}
