//! Round-to-nearest baseline quantizer: group-wise symmetric, no Hessian.

use super::{quant_dequant, QuantCfg};
use crate::tensor::Tensor;

/// Quantize-dequantize a (m, n) weight matrix in place-copy.
pub fn rtn_quantize(w: &Tensor, qc: QuantCfg) -> Tensor {
    let (m, n) = (w.shape[0], w.shape[1]);
    let mut out = w.clone();
    for r in 0..m {
        let row = &mut out.data[r * n..(r + 1) * n];
        for chunk in row.chunks_mut(qc.group) {
            quant_dequant(chunk, qc.bits);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;

    #[test]
    fn lower_bits_more_error() {
        let mut rng = Rng::new(1);
        let w = Tensor::from_vec(
            &[16, 64],
            (0..16 * 64).map(|_| rng.normal() as f32).collect(),
        );
        let e4 = super::super::mse(&w, &rtn_quantize(&w, QuantCfg { bits: 4, group: 32 }));
        let e3 = super::super::mse(&w, &rtn_quantize(&w, QuantCfg { bits: 3, group: 32 }));
        let e8 = super::super::mse(&w, &rtn_quantize(&w, QuantCfg { bits: 8, group: 32 }));
        assert!(e8 < e4 && e4 < e3);
    }
}
