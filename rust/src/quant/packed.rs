//! Packed int8 weight storage for the quantized serving path.
//!
//! [`PackedInt8`] holds a 2-D weight matrix as row-major `i8` codes plus
//! per-row, per-group symmetric f32 scales, with groups running along the
//! **columns** (the dot dimension of the serving matmuls — both SVD factors
//! are consumed as `x · Wᵀ` with the stored layout `(rows_out, k_in)`).
//! Dequantization of one element is exactly `code as f32 * scale`, and the
//! int8 matmul kernel ([`crate::kernels::matmul_q8`]) evaluates that very
//! expression inline under the f32 dot's 8-virtual-lane contract, so
//! serving from packed weights is **bitwise identical** to dequantizing and
//! serving f32 — the quality gate can measure quantization loss on the f32
//! eval path and the number is exact for the served engine.

use crate::model::alloc::{Allocation, ModuleAlloc};
use crate::svd::FactoredModel;
use crate::tensor::Tensor;

/// A quantization recipe attached to a compression plan: `bits` per weight
/// code and `group` columns per scale. Only `bits == 8` has a packed
/// serving path today; the registry rejects anything else.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QuantScheme {
    pub bits: u32,
    pub group: usize,
}

/// A 2-D matrix stored as row-major int8 codes + per-(row, column-group)
/// symmetric f32 scales. `shape = [rows, cols]`; groups tile the columns,
/// so row `r` has `cols.div_ceil(group)` scales.
#[derive(Debug, Clone, PartialEq)]
pub struct PackedInt8 {
    pub shape: [usize; 2],
    pub group: usize,
    pub data: Vec<i8>,
    pub scales: Vec<f32>,
}

impl PackedInt8 {
    /// Scales per row.
    pub fn groups_per_row(&self) -> usize {
        self.shape[1].div_ceil(self.group)
    }

    /// Symmetric per-group round-to-nearest quantization of a 2-D tensor.
    /// Per group: `scale = amax / 127`, `code = round(v / scale)` clamped to
    /// `[-127, 127]` (the symmetric range — `-128` is never emitted, so
    /// `code * scale` round-trips the group maximum exactly). An all-zero
    /// group stores `scale = 0` and zero codes.
    pub fn quantize(t: &Tensor, group: usize) -> PackedInt8 {
        assert_eq!(t.shape.len(), 2, "PackedInt8 quantizes 2-D tensors");
        assert!(group > 0, "quantization group must be positive");
        let (rows, cols) = (t.shape[0], t.shape[1]);
        let gpr = cols.div_ceil(group);
        let mut data = vec![0i8; rows * cols];
        let mut scales = vec![0.0f32; rows * gpr];
        for r in 0..rows {
            let src = &t.data[r * cols..(r + 1) * cols];
            for g in 0..gpr {
                let c0 = g * group;
                let c1 = (c0 + group).min(cols);
                let amax = src[c0..c1].iter().fold(0.0f32, |a, &v| a.max(v.abs()));
                if amax == 0.0 {
                    continue; // scale 0, codes 0
                }
                let scale = amax / 127.0;
                scales[r * gpr + g] = scale;
                for c in c0..c1 {
                    let q = (src[c] / scale).round().clamp(-127.0, 127.0);
                    data[r * cols + c] = q as i8;
                }
            }
        }
        PackedInt8 { shape: [rows, cols], group, data, scales }
    }

    /// Dequantize one element — the canonical expression (`code * scale`)
    /// that the int8 kernels evaluate inline.
    #[inline]
    pub fn dequant_at(&self, r: usize, c: usize) -> f32 {
        let cols = self.shape[1];
        self.data[r * cols + c] as f32 * self.scales[r * self.groups_per_row() + c / self.group]
    }

    /// Dequantize the whole matrix to an f32 tensor.
    pub fn dequant(&self) -> Tensor {
        let (rows, cols) = (self.shape[0], self.shape[1]);
        let gpr = self.groups_per_row();
        let mut out = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                out.push(self.data[r * cols + c] as f32 * self.scales[r * gpr + c / self.group]);
            }
        }
        Tensor::from_vec(&self.shape, out)
    }

    /// Resident bytes: one byte per code plus four per scale. This is the
    /// real storage the serving engine holds — not an accounting fiction.
    pub fn bytes(&self) -> usize {
        self.data.len() + 4 * self.scales.len()
    }
}

/// The factored model **as the quantized engine serves it**: every
/// `Rank(k)` module's truncated factors are quantize-dequantized in place
/// (first `k` columns of `wu`, first `k` rows of `wv`; ranks beyond `k`
/// are masked out by the eval path anyway), dense modules untouched.
/// Because the int8 kernel is bitwise-equal to dequant-then-f32, running
/// the f32 eval on this model measures the served quality exactly.
pub fn quantized_factors(fm: &FactoredModel, alloc: &Allocation, group: usize) -> FactoredModel {
    let mut out = fm.clone();
    for (name, mf) in out.factors.iter_mut() {
        let k = match alloc.modules.get(name) {
            Some(ModuleAlloc::Rank(k)) => *k,
            _ => continue,
        };
        let (u, v) = mf.truncate(k);
        let qu = PackedInt8::quantize(&u, group).dequant();
        let qv = PackedInt8::quantize(&v, group).dequant();
        let (m, r_full) = (mf.wu.shape[0], mf.wu.shape[1]);
        for i in 0..m {
            mf.wu.data[i * r_full..i * r_full + k].copy_from_slice(&qu.data[i * k..(i + 1) * k]);
        }
        let n = mf.wv.shape[1];
        mf.wv.data[..k * n].copy_from_slice(&qv.data);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_is_within_half_step() {
        let vals: Vec<f32> = (0..96).map(|i| ((i * 37 % 192) as f32 - 96.0) / 13.0).collect();
        let t = Tensor::from_vec(&[4, 24], vals);
        let p = PackedInt8::quantize(&t, 8);
        let d = p.dequant();
        assert_eq!(d.shape, t.shape);
        for (r, chunk) in t.data.chunks(24).enumerate() {
            for g in 0..3 {
                let seg = &chunk[g * 8..(g + 1) * 8];
                let amax = seg.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
                let step = amax / 127.0;
                for (c, &v) in seg.iter().enumerate() {
                    let got = d.at2(r, g * 8 + c);
                    assert!(
                        (got - v).abs() <= 0.5 * step + 1e-6,
                        "({r},{c}) {got} vs {v}, step {step}"
                    );
                }
            }
        }
    }

    #[test]
    fn group_max_round_trips_exactly() {
        // the symmetric range maps the group amax to code ±127 exactly
        let t = Tensor::from_vec(&[1, 4], vec![0.5, -2.0, 1.0, 0.25]);
        let p = PackedInt8::quantize(&t, 4);
        assert_eq!(p.dequant_at(0, 1), -2.0);
    }

    #[test]
    fn non_multiple_group_and_zero_group() {
        // cols = 7, group = 3 → groups of 3, 3, 1; second row all zeros
        let t = Tensor::from_vec(
            &[2, 7],
            vec![1.0, -1.0, 0.5, 2.0, 0.0, -2.0, 4.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0],
        );
        let p = PackedInt8::quantize(&t, 3);
        assert_eq!(p.groups_per_row(), 3);
        assert_eq!(p.scales.len(), 6);
        assert_eq!(p.dequant_at(0, 6), 4.0); // singleton tail group is exact
        for c in 0..7 {
            assert_eq!(p.dequant_at(1, c), 0.0);
        }
        assert_eq!(p.scales[3..6], [0.0, 0.0, 0.0]);
    }

    #[test]
    fn bytes_counts_codes_and_scales() {
        let t = Tensor::from_vec(&[3, 10], vec![1.0; 30]);
        let p = PackedInt8::quantize(&t, 4);
        // 30 codes + 3 rows × ceil(10/4)=3 scales × 4 bytes
        assert_eq!(p.bytes(), 30 + 4 * 9);
    }
}
