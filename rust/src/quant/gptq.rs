//! GPTQ: Hessian-aware one-shot quantization (Frantar et al. 2022).
//!
//! Column-ordered greedy quantization with error feedback: after quantizing
//! column j, the induced error is propagated into the not-yet-quantized
//! columns through the inverse-Hessian row, minimizing the layer output MSE
//! ‖WX − ŴX‖². We use the Cholesky formulation on H⁻¹ like the reference
//! implementation, with diagonal damping.

use super::QuantCfg;
use crate::linalg::{cholesky, invert_lower_triangular, Mat};
use crate::tensor::Tensor;
use crate::Result;

/// Quantize W (m, n) given the input Gram H = ΣXXᵀ (n, n).
pub fn gptq_quantize(w: &Tensor, h: &Mat, qc: QuantCfg) -> Result<Tensor> {
    let (m, n) = (w.shape[0], w.shape[1]);
    assert_eq!(h.rows, n);

    // damped H⁻¹ = (L Lᵀ)⁻¹; then its Cholesky Uᵀ gives the update rows
    let mean_diag = (0..n).map(|i| h.at(i, i)).sum::<f64>() / n as f64;
    let mut hd = h.clone();
    for i in 0..n {
        let v = hd.at(i, i) + 0.01 * mean_diag.max(1e-10);
        hd.set(i, i, v);
    }
    let l = cholesky(&hd)?;
    let li = invert_lower_triangular(&l)?;
    let hinv = li.transpose().matmul(&li); // H⁻¹
    // Cholesky of H⁻¹ (upper form): H⁻¹ = C Cᵀ with C lower; we need the
    // GPTQ recurrence d_j = C[j][j], row_j = C[j][j..]
    let c = cholesky(&hinv)?; // lower triangular: H⁻¹ = c · cᵀ
    // GPTQ uses U from H⁻¹ = Uᵀ U (upper). cᵀ is upper with U = cᵀ.

    let qmax = ((1i32 << (qc.bits - 1)) - 1) as f32;
    // Sequential per-column quantization with error feedback: quantize
    // column j from the error-compensated value, then push e/d_jj times the
    // j-th inverse-Hessian Cholesky column into the remaining columns.
    let mut out = w.clone();
    for r in 0..m {
        let src = &w.data[r * n..(r + 1) * n];
        let row = &mut out.data[r * n..(r + 1) * n];
        let mut work: Vec<f32> = src.to_vec();
        for j in 0..n {
            let g0 = (j / qc.group) * qc.group;
            let g1 = (g0 + qc.group).min(n);
            let amax = src[g0..g1].iter().fold(0.0f32, |a, &b| a.max(b.abs()));
            let scale = if amax == 0.0 { 1.0 } else { amax / qmax };
            let wj = work[j];
            let q = (wj / scale).round().clamp(-qmax - 1.0, qmax) * scale;
            row[j] = q;
            let e = (wj - q) as f64;
            let djj = c.at(j, j);
            if djj.abs() > 1e-12 {
                for k in (j + 1)..n {
                    work[k] -= (e * c.at(k, j) / djj) as f32;
                }
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;
    use crate::quant::{mse, rtn_quantize};

    /// GPTQ must beat RTN on ‖WX − ŴX‖² for correlated inputs.
    #[test]
    fn beats_rtn_on_output_mse() {
        let mut rng = Rng::new(2);
        let (m, n, t) = (24, 32, 128);
        let w = Tensor::from_vec(&[m, n], (0..m * n).map(|_| rng.normal() as f32).collect());
        // correlated activations: x = A z with random mixing A
        let a: Vec<f64> = (0..n * n).map(|_| rng.normal() * 0.4).collect();
        let mut xs = Vec::with_capacity(t);
        for _ in 0..t {
            let z: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let x: Vec<f64> = (0..n)
                .map(|i| (0..n).map(|k| a[i * n + k] * z[k]).sum::<f64>() + z[i])
                .collect();
            xs.push(x);
        }
        let mut h = Mat::zeros(n, n);
        for x in &xs {
            for i in 0..n {
                for j in 0..n {
                    h.data[i * n + j] += x[i] * x[j];
                }
            }
        }
        let qc = QuantCfg { bits: 3, group: 16 };
        let wg = gptq_quantize(&w, &h, qc).unwrap();
        let wr = rtn_quantize(&w, qc);

        let out_mse = |wq: &Tensor| -> f64 {
            let mut s = 0.0;
            for x in &xs {
                for r in 0..m {
                    let mut y0 = 0.0f64;
                    let mut y1 = 0.0f64;
                    for c in 0..n {
                        y0 += w.at2(r, c) as f64 * x[c];
                        y1 += wq.at2(r, c) as f64 * x[c];
                    }
                    s += (y0 - y1) * (y0 - y1);
                }
            }
            s
        };
        let eg = out_mse(&wg);
        let er = out_mse(&wr);
        assert!(
            eg < er,
            "GPTQ output MSE {eg:.4} must beat RTN {er:.4}"
        );
    }

    #[test]
    fn weight_mse_is_bounded() {
        let mut rng = Rng::new(3);
        let w = Tensor::from_vec(&[8, 16], (0..128).map(|_| rng.normal() as f32).collect());
        let mut h = Mat::eye(16);
        for i in 0..16 {
            h.set(i, i, 1.0 + rng.f64());
        }
        let wq = gptq_quantize(&w, &h, QuantCfg { bits: 8, group: 16 }).unwrap();
        assert!(mse(&w, &wq) < 1e-3);
    }
}
