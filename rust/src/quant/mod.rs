//! Weight quantization: the Table-3 substrate (GPTQ — Hessian-aware,
//! column-by-column with error feedback — and round-to-nearest, both
//! group-wise symmetric) plus the **packed int8 serving path**.
//!
//! The packed-serving contract ([`packed`], DESIGN.md §9): SVD factor
//! matrices are stored as real row-major `i8` codes with per-(row,
//! column-group) symmetric f32 scales, and the interpreter executes them
//! through a dedicated int8×f32-accumulate matmul op — no dequant
//! round-trip, and the resident bytes ([`PackedInt8::bytes`]) are the
//! bytes actually held. The kernel dequantizes each code inline
//! (`code as f32 * scale`) under the f32 dot's 8-virtual-lane contract,
//! so packed execution is bitwise-identical to dequantizing to f32 and
//! running the float kernels — across SIMD tiers and `ARA_THREADS`.
//!
//! The older GPTQ/RTN substrate still quantize-dequantizes to f32 (it
//! exists to *measure* codecs, not to serve them); only the registry's
//! `?quant=int8` recipe reaches the packed path.

mod gptq;
pub mod packed;
mod rtn;

pub use gptq::gptq_quantize;
pub use packed::{quantized_factors, PackedInt8, QuantScheme};
pub use rtn::rtn_quantize;

use crate::tensor::Tensor;

/// Quantization settings: `bits` per weight, `group` columns per scale.
#[derive(Debug, Clone, Copy)]
pub struct QuantCfg {
    pub bits: u32,
    pub group: usize,
}

impl Default for QuantCfg {
    fn default() -> Self {
        QuantCfg { bits: 4, group: 32 }
    }
}

impl QuantCfg {
    /// Bytes to store a quantized (m, n) matrix: packed ints + f16 scales.
    pub fn bytes(&self, m: usize, n: usize) -> usize {
        let ints = (m * n * self.bits as usize).div_ceil(8);
        let groups = m * n.div_ceil(self.group);
        ints + 2 * groups
    }
}

/// Symmetric per-group quantize/dequantize of one row segment.
pub(crate) fn quant_dequant(vals: &mut [f32], bits: u32) {
    let qmax = ((1i32 << (bits - 1)) - 1) as f32;
    let amax = vals.iter().fold(0.0f32, |a, &b| a.max(b.abs()));
    if amax == 0.0 {
        return;
    }
    let scale = amax / qmax;
    for v in vals.iter_mut() {
        let q = (*v / scale).round().clamp(-qmax - 1.0, qmax);
        *v = q * scale;
    }
}

/// Mean-squared quantization error (for tests/reporting).
pub fn mse(a: &Tensor, b: &Tensor) -> f64 {
    a.data
        .iter()
        .zip(&b.data)
        .map(|(&x, &y)| ((x - y) as f64).powi(2))
        .sum::<f64>()
        / a.data.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_accounting() {
        let q = QuantCfg { bits: 4, group: 32 };
        // 64×64 at 4 bits = 2048 bytes ints + 2·(64·2) scales
        assert_eq!(q.bytes(64, 64), 64 * 64 / 2 + 2 * 64 * 2);
        let q3 = QuantCfg { bits: 3, group: 32 };
        assert!(q3.bytes(64, 64) < q.bytes(64, 64));
    }

    #[test]
    fn quant_dequant_bounded_error() {
        let mut v: Vec<f32> = (0..64).map(|i| (i as f32 - 32.0) / 7.0).collect();
        let orig = v.clone();
        quant_dequant(&mut v, 4);
        let step = orig.iter().fold(0.0f32, |a, &b| a.max(b.abs())) / 7.0;
        for (a, b) in v.iter().zip(&orig) {
            assert!((a - b).abs() <= 0.5 * step + 1e-6);
        }
    }
}
