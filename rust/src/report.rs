//! Table/figure formatting: fixed-width text tables matching the paper's
//! row/column structure, printed by the bench harnesses and examples.

/// A simple fixed-width table printer.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Table {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| {
            let mut line = String::new();
            for i in 0..ncols {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:width$}", cells[i], width = widths[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push_str(&format!("{}\n", "-".repeat(widths.iter().sum::<usize>() + 2 * (ncols - 1))));
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Format helpers shared by benches.
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}
pub fn f1(x: f64) -> String {
    format!("{x:.1}")
}
pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("Test", &["Method", "PPL"]);
        t.row(vec!["ARA".into(), "6.42".into()]);
        t.row(vec!["Uniform".into(), "8.38".into()]);
        let s = t.render();
        assert!(s.contains("== Test =="));
        assert!(s.contains("ARA"));
        let lines: Vec<&str> = s.lines().collect();
        assert!(lines.len() >= 5);
    }

    #[test]
    #[should_panic]
    fn rejects_ragged_rows() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
