//! `ara` — the leader CLI: drives the full compression pipeline over the
//! AOT artifacts. Python never runs here; `make artifacts` must have been
//! executed once beforehand.
//!
//! Argument parsing is hand-rolled (the offline vendor set has no clap):
//! `ara <subcommand> [--key value]…`. Each subcommand validates its flag
//! set — an unknown or duplicated flag errors with that subcommand's
//! usage line instead of being silently ignored.
//!
//! Allocation methods are addressed by **registry spec**
//! (`method@ratio[?key=val&…]`, e.g. `ara@0.8`, `dobi@0.75?epochs=20`);
//! see DESIGN.md §4 for the grammar and the per-method parameter sets.

use std::collections::HashMap;

use ara_compress::compress::ALL_METHOD_IDS;
use ara_compress::config::Paths;
use ara_compress::coordinator::Pipeline;
use ara_compress::report::{f2, Table};
use ara_compress::Result;

/// One subcommand's contract: its usage line and its allowed flag set.
struct SubCmd {
    name: &'static str,
    usage: &'static str,
    flags: &'static [&'static str],
}

const SUBCOMMANDS: &[SubCmd] = &[
    SubCmd {
        name: "pretrain",
        usage: "pretrain --model M [--steps N]              pre-train the substrate LM (cached)",
        flags: &["model", "steps"],
    },
    SubCmd {
        name: "compress",
        usage: "compress --model M --spec S [--out PATH]    run a method spec (e.g. ara@0.8);\n          [--method X --ratio R]              --out writes a CompressionPlan JSON",
        flags: &["model", "spec", "method", "ratio", "out"],
    },
    SubCmd {
        name: "eval",
        usage: "eval      --model M --spec S                PPL + zero-shot vs dense",
        flags: &["model", "spec", "method", "ratio"],
    },
    SubCmd {
        name: "sweep",
        usage: "sweep     --model M [--specs a,b,…] [--ratios r1,r2,…]   method × ratio grid",
        flags: &["model", "specs", "ratios"],
    },
    SubCmd {
        name: "serve",
        usage: "serve     --model M --alloc A --batch B     continuous-batching generation demo\n          [--gen-len N] [--requests N]\n          [--addr HOST --port P]              HTTP front end (POST /v1/completions)\n          [--draft SPEC]                      self-speculative decoding draft plan\n                                              (e.g. ara@0.35; default ARA_DRAFT_SPEC)",
        flags: &["model", "alloc", "batch", "gen-len", "requests", "addr", "port", "draft"],
    },
    SubCmd {
        name: "info",
        usage: "info                                        list presets and artifacts",
        flags: &[],
    },
];

fn usage() -> String {
    let mut s = String::from(
        "ara — Adaptive Rank Allocation for SVD LLM compression\n\n\
         USAGE: ara <command> [--flag value]...\n\nCOMMANDS:\n",
    );
    for sc in SUBCOMMANDS {
        s.push_str("  ");
        s.push_str(sc.usage);
        s.push('\n');
    }
    s.push_str(
        "\nMETHOD SPECS: method@ratio[?key=val&key=val]   (ratio in (0,1])\n  methods: ",
    );
    s.push_str(&ALL_METHOD_IDS.join(" "));
    s.push_str(" ara-nolg\n  examples: ara@0.8   dobi@0.75?epochs=20   dlp@0.8?tail=0.15\n");
    s
}

/// Tiny flag parser: `--key value` pairs, validated against one
/// subcommand's allowed set. Unknown and duplicate flags are errors that
/// name the subcommand and print its usage line.
struct Args {
    flags: HashMap<String, String>,
}

impl Args {
    fn parse(sub: &SubCmd, argv: &[String]) -> Result<Args> {
        let mut flags = HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let k = argv[i]
                .strip_prefix("--")
                .ok_or_else(|| ara_compress::anyhow!("expected --flag, got {}", argv[i]))?;
            if !sub.flags.contains(&k) {
                return Err(ara_compress::anyhow!(
                    "unknown flag --{k} for `{}`\nusage: {}",
                    sub.name,
                    sub.usage
                ));
            }
            let v = argv
                .get(i + 1)
                .ok_or_else(|| ara_compress::anyhow!("--{k} needs a value"))?;
            if flags.insert(k.to_string(), v.clone()).is_some() {
                return Err(ara_compress::anyhow!(
                    "duplicate flag --{k} for `{}`\nusage: {}",
                    sub.name,
                    sub.usage
                ));
            }
            i += 2;
        }
        Ok(Args { flags })
    }

    fn get(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| ara_compress::anyhow!("--{key}: bad number {v}")),
        }
    }

    /// The method spec for `compress`/`eval`: `--spec` wins; otherwise the
    /// legacy `--method X --ratio R` pair is assembled into `X@R` (X may
    /// itself already carry `@`/`?` parts).
    fn spec(&self) -> String {
        if let Some(s) = self.flags.get("spec") {
            return s.clone();
        }
        let method = self.get("method", "ara");
        if method.contains('@') {
            method
        } else {
            format!("{method}@{}", self.get("ratio", "0.8"))
        }
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() || argv[0] == "--help" || argv[0] == "-h" {
        print!("{}", usage());
        return;
    }
    let cmd = argv[0].clone();
    let Some(sub) = SUBCOMMANDS.iter().find(|s| s.name == cmd) else {
        eprintln!("error: unknown command `{cmd}`\n{}", usage());
        std::process::exit(2);
    };
    let args = match Args::parse(sub, &argv[1..]) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = run(&cmd, &args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run(cmd: &str, args: &Args) -> Result<()> {
    match cmd {
        "pretrain" => {
            let model = args.get("model", "minillama-s");
            let mut pl = Pipeline::new(&model)?;
            if let Some(s) = args.flags.get("steps") {
                pl.scalecfg.pretrain_steps = s.parse()?;
            }
            let ws = pl.pretrained()?;
            println!("pretrained {} ({} tensors)", model, ws.tensors.len());
        }
        "compress" => {
            let model = args.get("model", "minillama-s");
            let spec = args.spec();
            let pl = Pipeline::new(&model)?;
            let ws = pl.pretrained()?;
            let grams = pl.grams(&ws)?;
            let fm = pl.factored(&ws, &grams)?;
            let plan = pl.allocate_spec(&spec, &ws, &grams, &fm)?;
            println!(
                "{}: achieved ratio {:.4} (target {:.2}), dense modules {}/{}, {:.0} ms",
                plan.spec,
                plan.achieved,
                plan.target,
                plan.allocation.dense_count(),
                plan.allocation.modules.len(),
                plan.wall_ms
            );
            for (name, a) in &plan.allocation.modules {
                println!("  {name}: {a:?}");
            }
            if let Some(path) = args.flags.get("out") {
                let path = std::path::PathBuf::from(path);
                plan.save(&path)?;
                println!(
                    "wrote plan {path:?} (schema v{}) — re-run `make artifacts` \
                     to specialize serving",
                    plan.schema_version
                );
            }
        }
        "eval" => {
            let model = args.get("model", "minillama-s");
            let spec = args.spec();
            let pl = Pipeline::new(&model)?;
            let ws = pl.pretrained()?;
            let grams = pl.grams(&ws)?;
            let fm = pl.factored(&ws, &grams)?;
            let dense = pl.evaluate_dense(&ws)?;
            let plan = pl.allocate_spec(&spec, &ws, &grams, &fm)?;
            let row = pl.evaluate(&plan.label, &ws, &fm, &plan.allocation)?;
            let mut t = Table::new(
                format!("{model} @ {:.0}%", plan.target * 100.0),
                &["Method", "Wiki2 PPL", "C4 PPL", "Avg acc %"],
            );
            for r in [&dense, &row] {
                t.row(vec![r.method.clone(), f2(r.wiki_ppl), f2(r.c4_ppl), f2(r.avg_acc)]);
            }
            t.print();
        }
        "sweep" => {
            let model = args.get("model", "minillama-s");
            let specs: Vec<String> = args
                .get("specs", &ALL_METHOD_IDS.join(","))
                .split(',')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect();
            let ratios: Vec<f64> = args
                .get("ratios", "0.35,0.25")
                .split(',')
                .map(|r| {
                    r.trim()
                        .parse::<f64>()
                        .map_err(|_| ara_compress::anyhow!("--ratios: bad number `{r}`"))
                })
                .collect::<Result<_>>()?;
            let pl = Pipeline::new(&model)?;
            let plans = pl.sweep(&specs, &ratios)?;
            let mut t = Table::new(
                format!("sweep — {model} ({} cells)", plans.len()),
                &["Spec", "Target", "Achieved", "Dense", "Wall ms"],
            );
            for p in &plans {
                t.row(vec![
                    p.spec.clone(),
                    format!("{:.2}", p.target),
                    format!("{:.4}", p.achieved),
                    format!("{}/{}", p.allocation.dense_count(), p.allocation.modules.len()),
                    format!("{:.0}", p.wall_ms),
                ]);
            }
            t.print();
        }
        "serve" => {
            let model = args.get("model", "minillama-s");
            let alloc = args.get("alloc", "uniform-80");
            let batch = args.get_usize("batch", 4)?;
            // --draft wins; otherwise the ARA_DRAFT_SPEC env default.
            // An empty value disables drafting explicitly.
            let draft = args
                .flags
                .get("draft")
                .cloned()
                .or_else(|| std::env::var("ARA_DRAFT_SPEC").ok())
                .map(|v| v.trim().to_string())
                .filter(|v| !v.is_empty());
            match args.flags.get("port") {
                Some(p) => {
                    let port: u16 = p
                        .parse()
                        .map_err(|_| ara_compress::anyhow!("--port: bad port `{p}`"))?;
                    for k in ["gen-len", "requests"] {
                        if args.flags.contains_key(k) {
                            return Err(ara_compress::anyhow!(
                                "--{k} has no effect with --port (HTTP clients set \
                                 per-request lengths)\nusage: {}",
                                sub_usage("serve")
                            ));
                        }
                    }
                    http_serve(
                        &model,
                        &alloc,
                        batch,
                        &args.get("addr", "127.0.0.1"),
                        port,
                        draft,
                    )?;
                }
                None => {
                    if args.flags.contains_key("addr") {
                        return Err(ara_compress::anyhow!(
                            "--addr requires --port\nusage: {}",
                            sub_usage("serve")
                        ));
                    }
                    serve(
                        &model,
                        &alloc,
                        batch,
                        args.get_usize("gen-len", 32)?,
                        args.get_usize("requests", 16)?,
                        draft,
                    )?;
                }
            }
        }
        "info" => {
            let paths = Paths::discover()?;
            for m in ara_compress::config::load_models(&paths.configs)? {
                let adir = paths.artifact_dir(&m.name);
                let n = std::fs::read_dir(&adir)
                    .map(|d| d.filter(|e| e.is_ok()).count() / 2)
                    .unwrap_or(0);
                println!(
                    "{:<14} {:<6} d={} L={} vocab={} serving={} artifacts={}",
                    m.name, m.family, m.d_model, m.n_layers, m.vocab, m.serving, n
                );
            }
        }
        other => {
            return Err(ara_compress::anyhow!("unknown command `{other}`\n{}", usage()));
        }
    }
    Ok(())
}

fn sub_usage(name: &str) -> &'static str {
    SUBCOMMANDS.iter().find(|s| s.name == name).map(|s| s.usage).unwrap_or("")
}

/// Build the self-speculative draft decoder for `serve` (DESIGN.md §8):
/// resolve the draft spec — a registry spec like `ara@0.35` (allocated on
/// the spot), or a precomputed allocation name like `uniform-40` — into an
/// engine at the target's batch size, and arm the target's verify window
/// for `ARA_SPEC_K` draft tokens per round (default 4). The draft is
/// advisory: callers report any error here and keep serving plain.
fn build_spec_dec(
    pl: &Pipeline,
    ws: &ara_compress::model::WeightStore,
    grams: &std::collections::BTreeMap<String, ara_compress::linalg::Mat>,
    fm: &ara_compress::svd::FactoredModel,
    target: &mut ara_compress::serving::Engine,
    spec: &str,
    batch: usize,
) -> Result<ara_compress::serving::SpecDec> {
    let k = std::env::var("ARA_SPEC_K")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        .unwrap_or(4)
        .max(1);
    let draft = if spec.contains('@') {
        let plan = pl.allocate_spec(spec, ws, grams, fm)?;
        pl.engine_for_plan(ws, fm, &plan, batch)?
    } else {
        pl.engine(ws, fm, spec, batch)?
    };
    target.enable_verify(&pl.rt, k + 1)?;
    ara_compress::serving::SpecDec::new(draft, spec, k)
}

/// HTTP serving mode (`serve --port P`, DESIGN.md §7): the engine builds
/// on the router's worker thread (PJRT state never crosses threads) while
/// the listener binds immediately — `GET /healthz` answers during warmup,
/// and submissions queue until the worker drains them. Runs until
/// `POST /admin/shutdown`; a worker panic during teardown (debug-build KV
/// leak check included) propagates as a nonzero exit.
fn http_serve(
    model: &str,
    alloc_name: &str,
    batch: usize,
    addr: &str,
    port: u16,
    draft: Option<String>,
) -> Result<()> {
    use ara_compress::serving::{HttpCfg, HttpServer, Router, RouterCfg};

    let vocab = Pipeline::new(model)?.cfg.vocab;
    let (m, a) = (model.to_string(), alloc_name.to_string());
    let router = Router::spawn_with_spec(RouterCfg::from_env(), move || {
        let pl = Pipeline::new(&m).expect("pipeline");
        let ws = pl.pretrained().expect("pretrain");
        let grams = pl.grams(&ws).expect("calibrate");
        let fm = pl.factored(&ws, &grams).expect("factorize");
        let mut engine = pl.engine(&ws, &fm, &a, batch).expect("engine");
        let spec = draft.and_then(|spec| {
            match build_spec_dec(&pl, &ws, &grams, &fm, &mut engine, &spec, batch) {
                Ok(sd) => {
                    println!("speculative draft `{spec}` armed (k = {})", sd.k());
                    Some(sd)
                }
                Err(e) => {
                    eprintln!("draft `{spec}` disabled, serving plain: {e}");
                    None
                }
            }
        });
        (engine, spec)
    });
    let server = HttpServer::bind(&format!("{addr}:{port}"), router, vocab, HttpCfg::from_env())?;
    let bound = server.local_addr()?;
    println!(
        "listening on http://{bound} — POST /v1/completions, GET /healthz, \
         GET /stats, POST /admin/shutdown"
    );
    server.run()
}

/// Continuous-batching serve demo: submits `requests` ragged prompts to
/// the paged-pool [`Scheduler`](ara_compress::serving::Scheduler), prints
/// each request's [`FinishReason`](ara_compress::serving::FinishReason)
/// (`Stop` vs `Length` — KV exhaustion is visible, never swallowed), and
/// closes with the prefix-hit-rate / pool-utilization summary. Falls back
/// to batched `Engine::generate` on backends without a paged decode
/// specialization (PJRT).
fn serve(
    model: &str,
    alloc_name: &str,
    batch: usize,
    gen_len: usize,
    requests: usize,
    draft: Option<String>,
) -> Result<()> {
    use ara_compress::data::{corpus_spec, generate_tokens};
    use ara_compress::serving::{Request, SamplingParams, Scheduler};

    let pl = Pipeline::new(model)?;
    let ws = pl.pretrained()?;
    let grams = pl.grams(&ws)?;
    let fm = pl.factored(&ws, &grams)?;
    let mut engine = pl.engine(&ws, &fm, alloc_name, batch)?;
    if let Some(p) = engine.provenance() {
        println!("serving {p}");
    }
    // arm the verify window before the scheduler borrows the engine;
    // failures are reported and the demo serves plain
    let spec_dec = match &draft {
        Some(spec) if engine.has_paged() => {
            match build_spec_dec(&pl, &ws, &grams, &fm, &mut engine, spec, batch) {
                Ok(sd) => {
                    println!("speculative draft `{spec}` armed (k = {})", sd.k());
                    Some(sd)
                }
                Err(e) => {
                    eprintln!("draft `{spec}` disabled, serving plain: {e}");
                    None
                }
            }
        }
        _ => None,
    };

    let p = pl.cfg.prefill_len;
    let stream =
        generate_tokens(pl.cfg.vocab, corpus_spec("synwiki"), 55, (requests + batch + 1) * p);
    // ragged prompt lengths exercise the left-pad masking contract
    let prompts: Vec<Vec<i32>> = (0..requests)
        .map(|i| {
            let len = p - (i % 3).min(p.saturating_sub(1));
            let off = (i * p) % (stream.len() - p);
            stream[off..off + len].to_vec()
        })
        .collect();

    if !engine.has_paged() {
        // contiguous fallback (PJRT): batched greedy generate; the fixed
        // batch is padded with wrap-around prompts, but only the `n`
        // genuinely-submitted requests of each round are reported
        let mut done = 0;
        while done < requests {
            let batch_prompts: Vec<Vec<i32>> = (0..batch)
                .map(|i| prompts[(done + i) % requests].clone())
                .collect();
            let (tokens, stats) = engine.generate(&batch_prompts, gen_len)?;
            let n = batch.min(requests - done);
            for s in 0..n {
                println!(
                    "req {:>3}: {} tokens, finish={:?}",
                    done + s,
                    tokens[s].len(),
                    stats.finish[s]
                );
            }
            done += n;
            println!("  decode {:.1} tok/s", stats.tok_per_s());
        }
        return Ok(());
    }

    let mut sched = Scheduler::new(&engine);
    let draft_spec = spec_dec.as_ref().map(|sd| sd.spec().to_string());
    if let Some(sd) = spec_dec {
        sched.set_spec_dec(Some(sd))?;
    }
    for prompt in prompts {
        sched.submit(Request {
            prompt,
            gen_len,
            params: SamplingParams::greedy(),
            draft_spec: draft_spec.clone(),
            ..Default::default()
        });
    }
    while !sched.is_idle() {
        for c in sched.step()? {
            println!(
                "req {:>3}: {} prompt + {} generated tokens, finish={:?}, latency {:.1} ms",
                c.id,
                c.prompt_len,
                c.tokens.len(),
                c.finish_reason,
                c.latency_s * 1e3
            );
        }
    }
    let st = sched.stats();
    println!(
        "served {} requests in {} steps: {:.1} tok/s decode ({:.1} tok/s end-to-end)",
        st.completed,
        st.steps,
        st.decode_tok_per_s(),
        st.tok_per_s()
    );
    println!(
        "kv pool: prefix-hit-rate {:.2} ({} lookups, {} hits, {} prefills skipped), \
         peak utilization {:.2}, preemptions {}",
        st.prefix_hit_rate(),
        st.prefix_lookups,
        st.prefix_hits,
        st.prefill_skipped,
        st.pool_peak_util,
        st.preemptions
    );
    if st.verify_passes > 0 {
        println!(
            "specdec: {} verify passes, {}/{} draft tokens accepted \
             ({:.2} accepted/verify, accept rate {:.2})",
            st.verify_passes,
            st.draft_accepted,
            st.draft_tokens,
            st.accepted_per_verify(),
            st.draft_accept_rate()
        );
    }
    Ok(())
}
