//! `ara` — the leader CLI: drives the full compression pipeline over the
//! AOT artifacts. Python never runs here; `make artifacts` must have been
//! executed once beforehand.
//!
//! Argument parsing is hand-rolled (the offline vendor set has no clap):
//! `ara <subcommand> [--key value]…`.

use std::collections::HashMap;

use ara_compress::config::Paths;
use ara_compress::coordinator::{MethodKind, Pipeline};
use ara_compress::model::{alloc_ratio, Allocation};
use ara_compress::report::{f2, Table};
use ara_compress::Result;

/// Tiny flag parser: `--key value` pairs after the subcommand.
struct Args {
    flags: HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Args> {
        let mut flags = HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let k = argv[i]
                .strip_prefix("--")
                .ok_or_else(|| ara_compress::anyhow!("expected --flag, got {}", argv[i]))?;
            let v = argv
                .get(i + 1)
                .ok_or_else(|| ara_compress::anyhow!("--{k} needs a value"))?;
            flags.insert(k.to_string(), v.clone());
            i += 2;
        }
        Ok(Args { flags })
    }

    fn get(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| ara_compress::anyhow!("--{key}: bad number {v}")),
        }
    }

    fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| ara_compress::anyhow!("--{key}: bad number {v}")),
        }
    }
}

const USAGE: &str = "\
ara — Adaptive Rank Allocation for SVD LLM compression

USAGE: ara <command> [--flag value]...

COMMANDS:
  pretrain  --model M [--steps N]           pre-train the substrate LM (cached)
  compress  --model M --method X --ratio R  run an allocation method
            [--out PATH]                    write allocation JSON for aot.py
  eval      --model M --method X --ratio R  PPL + zero-shot vs dense
  serve     --model M --alloc A --batch B   batched generation demo
            [--gen-len N] [--requests N]
  info                                      list presets and artifacts

METHODS: uniform dlp farms strs ars dobi ara ara-nolg
";

fn parse_method(s: &str) -> Result<MethodKind> {
    Ok(match s.to_lowercase().as_str() {
        "uniform" => MethodKind::Uniform,
        "dlp" => MethodKind::Dlp,
        "farms" => MethodKind::Farms,
        "strs" => MethodKind::Strs,
        "ars" => MethodKind::Ars,
        "dobi" | "dobi-svd1" => MethodKind::Dobi,
        "ara" => MethodKind::Ara,
        "ara-nolg" => MethodKind::AraNoGuidance,
        other => return Err(ara_compress::anyhow!("unknown method {other}")),
    })
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() || argv[0] == "--help" || argv[0] == "-h" {
        print!("{USAGE}");
        return;
    }
    let cmd = argv[0].clone();
    let args = match Args::parse(&argv[1..]) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = run(&cmd, &args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run(cmd: &str, args: &Args) -> Result<()> {
    match cmd {
        "pretrain" => {
            let model = args.get("model", "minillama-s");
            let mut pl = Pipeline::new(&model)?;
            if let Some(s) = args.flags.get("steps") {
                pl.scalecfg.pretrain_steps = s.parse()?;
            }
            let ws = pl.pretrained()?;
            println!("pretrained {} ({} tensors)", model, ws.tensors.len());
        }
        "compress" => {
            let model = args.get("model", "minillama-s");
            let method = parse_method(&args.get("method", "ara"))?;
            let ratio = args.get_f64("ratio", 0.8)?;
            let pl = Pipeline::new(&model)?;
            let ws = pl.pretrained()?;
            let grams = pl.grams(&ws)?;
            let fm = pl.factored(&ws, &grams)?;
            let alloc = pl.allocate(method, ratio, &ws, &grams, &fm)?;
            println!(
                "{}: achieved ratio {:.4}, dense modules {}/{}",
                alloc.name,
                alloc_ratio(&pl.cfg, &alloc),
                alloc.dense_count(),
                alloc.modules.len()
            );
            for (name, a) in &alloc.modules {
                println!("  {name}: {a:?}");
            }
            if let Some(path) = args.flags.get("out") {
                let path = std::path::PathBuf::from(path);
                alloc.save(&path)?;
                println!("wrote {path:?} — re-run `make artifacts` to specialize serving");
            }
        }
        "eval" => {
            let model = args.get("model", "minillama-s");
            let method = parse_method(&args.get("method", "ara"))?;
            let ratio = args.get_f64("ratio", 0.8)?;
            let pl = Pipeline::new(&model)?;
            let ws = pl.pretrained()?;
            let grams = pl.grams(&ws)?;
            let fm = pl.factored(&ws, &grams)?;
            let dense = pl.evaluate_dense(&ws)?;
            let alloc = pl.allocate(method, ratio, &ws, &grams, &fm)?;
            let row = pl.evaluate(method.name(), &ws, &fm, &alloc)?;
            let mut t = Table::new(
                format!("{model} @ {:.0}%", ratio * 100.0),
                &["Method", "Wiki2 PPL", "C4 PPL", "Avg acc %"],
            );
            for r in [&dense, &row] {
                t.row(vec![r.method.clone(), f2(r.wiki_ppl), f2(r.c4_ppl), f2(r.avg_acc)]);
            }
            t.print();
        }
        "serve" => {
            serve(
                &args.get("model", "minillama-s"),
                &args.get("alloc", "uniform-80"),
                args.get_usize("batch", 4)?,
                args.get_usize("gen-len", 32)?,
                args.get_usize("requests", 16)?,
            )?;
        }
        "info" => {
            let paths = Paths::discover()?;
            for m in ara_compress::config::load_models(&paths.configs)? {
                let adir = paths.artifact_dir(&m.name);
                let n = std::fs::read_dir(&adir)
                    .map(|d| d.filter(|e| e.is_ok()).count() / 2)
                    .unwrap_or(0);
                println!(
                    "{:<14} {:<6} d={} L={} vocab={} serving={} artifacts={}",
                    m.name, m.family, m.d_model, m.n_layers, m.vocab, m.serving, n
                );
            }
        }
        other => {
            return Err(ara_compress::anyhow!("unknown command `{other}`\n{USAGE}"));
        }
    }
    Ok(())
}

fn serve(model: &str, alloc_name: &str, batch: usize, gen_len: usize, requests: usize) -> Result<()> {
    use ara_compress::data::{corpus_spec, generate_tokens};
    use ara_compress::serving::Engine;

    let pl = Pipeline::new(model)?;
    let ws = pl.pretrained()?;
    let grams = pl.grams(&ws)?;
    let fm = pl.factored(&ws, &grams)?;

    // allocation must match what the serving artifacts were specialized to
    let cfg_path = pl
        .paths
        .configs
        .join("allocations")
        .join(format!("{model}.{alloc_name}.json"));
    let art_path = pl
        .paths
        .artifacts
        .join("allocations")
        .join(format!("{model}.{alloc_name}.json"));
    let alloc = if cfg_path.exists() {
        Allocation::load(&cfg_path)?
    } else {
        Allocation::load(&art_path)?
    };

    let engine = Engine::new(&pl.cfg, &pl.rt, &ws, &fm, &alloc, alloc_name, batch)?;
    let stream = generate_tokens(
        pl.cfg.vocab,
        corpus_spec("synwiki"),
        55,
        (requests + batch) * pl.cfg.prefill_len,
    );
    let mut done = 0;
    let mut total_tps = 0.0;
    let mut rounds = 0;
    while done < requests {
        let mut prompts = Vec::with_capacity(batch);
        for i in 0..batch {
            let off = ((done + i) * pl.cfg.prefill_len) % (stream.len() - pl.cfg.prefill_len);
            prompts.push(stream[off..off + pl.cfg.prefill_len].to_vec());
        }
        let (tokens, stats) = engine.generate(&prompts, gen_len)?;
        done += batch;
        rounds += 1;
        total_tps += stats.tok_per_s();
        println!(
            "batch {rounds}: {} seqs × {} tokens, decode {:.1} tok/s (first seq: {:?}…)",
            batch,
            tokens[0].len(),
            stats.tok_per_s(),
            &tokens[0][..tokens[0].len().min(8)]
        );
    }
    println!(
        "served {done} requests, mean decode throughput {:.1} tok/s",
        total_tps / rounds as f64
    );
    Ok(())
}
