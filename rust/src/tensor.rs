//! Host-side tensors: the lingua franca between the substrates and the PJRT
//! runtime. Deliberately minimal — heavy compute goes through the AOT HLO
//! executables; these types cover weight management, calibration statistics
//! and glue math.

/// A dense f32 tensor in row-major order.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn ones(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![1.0; shape.iter().product()] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn filled(shape: &[usize], v: f32) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![v; shape.iter().product()] }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// 2-D accessor (row-major). Debug-asserted; hot paths index directly.
    #[inline]
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.shape.len(), 2);
        self.data[i * self.shape[1] + j]
    }

    #[inline]
    pub fn set2(&mut self, i: usize, j: usize, v: f32) {
        debug_assert_eq!(self.shape.len(), 2);
        let c = self.shape[1];
        self.data[i * c + j] = v;
    }

    /// Matrix rows/cols for 2-D tensors.
    pub fn rows(&self) -> usize {
        self.shape[0]
    }
    pub fn cols(&self) -> usize {
        self.shape[1]
    }

    /// C = A · B for 2-D tensors (blocked/threaded kernel, f32).
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        let (m, k) = (self.shape[0], self.shape[1]);
        let (k2, n) = (other.shape[0], other.shape[1]);
        assert_eq!(k, k2, "matmul inner dim");
        let mut out = vec![0.0f32; m * n];
        crate::kernels::matmul_f32(&self.data, &other.data, m, k, n, false, false, &mut out);
        Tensor::from_vec(&[m, n], out)
    }

    pub fn transpose2(&self) -> Tensor {
        let (m, n) = (self.shape[0], self.shape[1]);
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                out[j * m + i] = self.data[i * n + j];
            }
        }
        Tensor::from_vec(&[n, m], out)
    }

    /// Frobenius norm.
    pub fn fro(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    /// Take the leading `k` rows of a 2-D tensor.
    pub fn top_rows(&self, k: usize) -> Tensor {
        let n = self.shape[1];
        Tensor::from_vec(&[k, n], self.data[..k * n].to_vec())
    }

    /// Take the leading `k` columns of a 2-D tensor.
    pub fn left_cols(&self, k: usize) -> Tensor {
        let (m, n) = (self.shape[0], self.shape[1]);
        let mut out = Vec::with_capacity(m * k);
        for i in 0..m {
            out.extend_from_slice(&self.data[i * n..i * n + k]);
        }
        Tensor::from_vec(&[m, k], out)
    }
}

/// A dense i32 tensor (token ids, lengths).
#[derive(Debug, Clone, PartialEq)]
pub struct IntTensor {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

impl IntTensor {
    pub fn from_vec(shape: &[usize], data: Vec<i32>) -> IntTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        IntTensor { shape: shape.to_vec(), data }
    }
    pub fn zeros(shape: &[usize]) -> IntTensor {
        IntTensor { shape: shape.to_vec(), data: vec![0; shape.iter().product()] }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let mut eye = Tensor::zeros(&[3, 3]);
        for i in 0..3 {
            eye.set2(i, i, 1.0);
        }
        assert_eq!(a.matmul(&eye), a);
    }

    #[test]
    fn matmul_known() {
        let a = Tensor::from_vec(&[2, 2], vec![1., 2., 3., 4.]);
        let b = Tensor::from_vec(&[2, 2], vec![5., 6., 7., 8.]);
        assert_eq!(a.matmul(&b).data, vec![19., 22., 43., 50.]);
    }

    #[test]
    fn transpose_roundtrip() {
        let a = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(a.transpose2().transpose2(), a);
    }

    #[test]
    fn slicing() {
        let a = Tensor::from_vec(&[3, 2], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(a.top_rows(2).data, vec![1., 2., 3., 4.]);
        assert_eq!(a.left_cols(1).data, vec![1., 3., 5.]);
        assert_eq!(a.left_cols(1).shape, vec![3, 1]);
    }

    #[test]
    fn matmul_degenerate_shapes() {
        // row vector × matrix, matrix × column vector, outer product
        let row = Tensor::from_vec(&[1, 3], vec![1., 2., 3.]);
        let m = Tensor::from_vec(&[3, 2], vec![1., 0., 0., 1., 1., 1.]);
        assert_eq!(row.matmul(&m).shape, vec![1, 2]);
        assert_eq!(row.matmul(&m).data, vec![4., 5.]);
        let col = Tensor::from_vec(&[2, 1], vec![2., 3.]);
        let out = col.matmul(&Tensor::from_vec(&[1, 2], vec![5., 7.]));
        assert_eq!(out.shape, vec![2, 2]);
        assert_eq!(out.data, vec![10., 14., 15., 21.]);
    }

    #[test]
    fn matmul_skips_zero_rows_correctly() {
        // the a==0 fast path must not corrupt accumulation
        let a = Tensor::from_vec(&[2, 3], vec![0., 0., 0., 1., 2., 0.]);
        let b = Tensor::from_vec(&[3, 2], vec![1., 2., 3., 4., 5., 6.]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![0., 0., 7., 10.]);
    }

    #[test]
    fn scalar_and_empty_tensors() {
        // rank-0 (scalar) and zero-size tensors are well-formed
        let s = Tensor::from_vec(&[], vec![42.0]);
        assert_eq!(s.numel(), 1);
        let e = Tensor::zeros(&[0]);
        assert_eq!(e.numel(), 0);
        let z = Tensor::filled(&[2, 2], 3.0);
        assert_eq!(z.fro(), 6.0);
    }

    #[test]
    fn int_tensor_construction() {
        let t = IntTensor::from_vec(&[2, 2], vec![1, 2, 3, 4]);
        assert_eq!(t.shape, vec![2, 2]);
        assert_eq!(IntTensor::zeros(&[3]).data, vec![0, 0, 0]);
    }
}
