//! Minimal JSON substrate (the offline build has no serde): a recursive-
//! descent parser to a [`Json`] value tree plus a compact serializer.
//! Covers everything the repo exchanges with the python AOT layer —
//! configs, artifact manifests, allocation files.

use crate::Result;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    // ---------- accessors ----------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| crate::anyhow!("missing key `{key}`"))
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(crate::anyhow!("expected string, got {other:?}")),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            other => Err(crate::anyhow!("expected number, got {other:?}")),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            return Err(crate::anyhow!("expected non-negative integer, got {x}"));
        }
        Ok(x as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => Err(crate::anyhow!("expected bool, got {other:?}")),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            other => Err(crate::anyhow!("expected array, got {other:?}")),
        }
    }

    pub fn as_obj(&self) -> Result<&[(String, Json)]> {
        match self {
            Json::Obj(v) => Ok(v),
            other => Err(crate::anyhow!("expected object, got {other:?}")),
        }
    }

    // ---------- serializer ----------

    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json> {
    let bytes = text.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != bytes.len() {
        return Err(crate::anyhow!("trailing garbage at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| crate::anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            return Err(crate::anyhow!(
                "expected `{}` at byte {}, found `{}`",
                c as char,
                self.i,
                self.peek()? as char
            ));
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(crate::anyhow!("bad literal at byte {}", self.i))
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            pairs.push((k, v));
            self.ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(pairs));
                }
                c => return Err(crate::anyhow!("expected , or }} found `{}`", c as char)),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => return Err(crate::anyhow!("expected , or ] found `{}`", c as char)),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(crate::anyhow!("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| crate::anyhow!("bad \\u escape {hex}"))?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        c => return Err(crate::anyhow!("bad escape \\{}", c as char)),
                    }
                }
                c => {
                    // multi-byte UTF-8: copy raw bytes until a boundary
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c);
                        let end = start + len;
                        if end > self.b.len() {
                            return Err(crate::anyhow!("truncated utf8"));
                        }
                        s.push_str(std::str::from_utf8(&self.b[start..end])?);
                        self.i = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| crate::anyhow!("bad number `{text}` at byte {start}"))
    }
}

fn utf8_len(first: u8) -> usize {
    if first >= 0xF0 {
        4
    } else if first >= 0xE0 {
        3
    } else {
        2
    }
}

// ---------- builders ----------

pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn s(x: impl Into<String>) -> Json {
    Json::Str(x.into())
}

pub fn n(x: f64) -> Json {
    Json::Num(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let t = r#"{"name": "x", "inputs": [{"name": "a", "shape": [2, 3], "dtype": "f32"}], "outputs": ["loss"]}"#;
        let j = parse(t).unwrap();
        assert_eq!(j.req("name").unwrap().as_str().unwrap(), "x");
        let ins = j.req("inputs").unwrap().as_arr().unwrap();
        assert_eq!(ins[0].req("shape").unwrap().as_arr().unwrap()[1].as_usize().unwrap(), 3);
        assert_eq!(j.req("outputs").unwrap().as_arr().unwrap()[0].as_str().unwrap(), "loss");
    }

    #[test]
    fn roundtrip() {
        let v = obj(vec![
            ("a", n(1.0)),
            ("b", Json::Arr(vec![n(2.5), Json::Bool(true), Json::Null])),
            ("c", s("hi \"there\"\n")),
        ]);
        let text = v.dump();
        let back = parse(&text).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn numbers() {
        assert_eq!(parse("-3.5e2").unwrap().as_f64().unwrap(), -350.0);
        assert_eq!(parse("42").unwrap().as_usize().unwrap(), 42);
        assert!(parse("1.5").unwrap().as_usize().is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{}extra").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn unicode_strings() {
        let j = parse(r#""δ₁ ≥ δ₂ A""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "δ₁ ≥ δ₂ A");
    }

    #[test]
    fn nested_python_output() {
        // exactly what aot.py json.dump emits (indent=1)
        let t = "{\n \"name\": \"uniform-80\",\n \"modules\": {\n  \"layers.0.attn.wq\": {\n   \"dense\": false,\n   \"rank\": 19\n  }\n }\n}";
        let j = parse(t).unwrap();
        let m = j.req("modules").unwrap();
        let q = m.req("layers.0.attn.wq").unwrap();
        assert!(!q.req("dense").unwrap().as_bool().unwrap());
        assert_eq!(q.req("rank").unwrap().as_usize().unwrap(), 19);
    }
}
