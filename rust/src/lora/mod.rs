//! LoRA recovery fine-tuning (Table 6): train low-rank adapters A, B on the
//! compressed model through the AOT `lora_step` executable, then merge.
//!
//! Merge strategy: for a factored module at rank k with k + lr ≤ r_full,
//! the adapter is written into the *masked-out* rank slots — columns
//! [k, k+lr) of W_u take B, rows [k, k+lr) of W_v take A, and their mask
//! bits flip to 1. This is exact (the masked slots contribute 0 before the
//! merge) and costs no new executable. Dense modules (R ≥ 1) fold W += B·A
//! directly and are re-factorized through their calibration Gram.

use std::collections::{BTreeMap, HashMap};

use crate::config::ModelCfg;
use crate::data::{batches, corpus_spec, generate_tokens, Rng};
use crate::linalg::Mat;
use crate::model::{module_dims, WeightStore};
use crate::runtime::{Feed, Runtime};
use crate::svd::{factored_feeds, factorize_module, FactoredModel};
use crate::tensor::Tensor;
use crate::training::{AdamW, AdamWConfig};
use crate::Result;

#[derive(Debug, Clone)]
pub struct LoraConfig {
    pub steps: usize,
    pub lr: f64,
    pub corpus: String,
    pub seed: u64,
}

impl Default for LoraConfig {
    fn default() -> Self {
        LoraConfig { steps: 40, lr: 1e-3, corpus: "synwiki".to_string(), seed: 21 }
    }
}

/// Fine-tune adapters and merge them; returns the updated factored model
/// and masks (mask bits for merged slots are enabled).
pub fn lora_finetune_and_merge(
    cfg: &ModelCfg,
    rt: &Runtime,
    ws: &WeightStore,
    fm: &FactoredModel,
    masks: &BTreeMap<String, Tensor>,
    grams: &BTreeMap<String, Mat>,
    lc: &LoraConfig,
) -> Result<(FactoredModel, BTreeMap<String, Tensor>)> {
    let exe = rt.load("lora_step")?;
    let dims = module_dims(cfg);
    let lr_rank = cfg.lora_rank;
    let mut rng = Rng::new(lc.seed);

    // A ~ N(0, 0.02²), B = 0 (standard LoRA init)
    let mut loras: BTreeMap<String, (Tensor, Tensor)> = BTreeMap::new();
    for d in &dims {
        let a = Tensor::from_vec(
            &[lr_rank, d.n],
            (0..lr_rank * d.n).map(|_| (rng.normal() * 0.02) as f32).collect(),
        );
        let b = Tensor::zeros(&[d.m, lr_rank]);
        loras.insert(d.name.clone(), (a, b));
    }

    let spec = corpus_spec(&lc.corpus);
    let need = lc.steps * cfg.batch_train * (cfg.seq_train + 1) + 1;
    let stream = generate_tokens(cfg.vocab, spec, 0x10A_u64 ^ lc.seed, need);
    let data = batches(&stream, cfg.batch_train, cfg.seq_train);
    let mut opt = AdamW::new(AdamWConfig { lr: lc.lr, weight_decay: 0.0, ..Default::default() });

    for step in 0..lc.steps {
        let (toks, tgts) = &data[step % data.len()];
        let mut feeds: HashMap<&str, Feed> = HashMap::new();
        factored_feeds(ws, fm, masks, &mut feeds);
        for (name, (a, b)) in &loras {
            feeds.insert(crate::svd::intern_key(format!("lora_a:{name}")), Feed::F32(a));
            feeds.insert(crate::svd::intern_key(format!("lora_b:{name}")), Feed::F32(b));
        }
        feeds.insert("tokens", Feed::I32(toks));
        feeds.insert("targets", Feed::I32(tgts));
        let out = exe.run(&feeds)?;
        opt.step();
        for d in &dims {
            let ga = out.tensor(&format!("grad:lora_a:{}", d.name))?;
            let gb = out.tensor(&format!("grad:lora_b:{}", d.name))?;
            let (a, b) = loras.get_mut(&d.name).unwrap();
            opt.update_f32(&format!("a:{}", d.name), &mut a.data, &ga.data, 1.0);
            opt.update_f32(&format!("b:{}", d.name), &mut b.data, &gb.data, 1.0);
        }
    }

    // merge
    let mut fm2 = fm.clone();
    let mut masks2 = masks.clone();
    for d in &dims {
        let (a, b) = &loras[&d.name];
        let mask = masks2.get_mut(&d.name).unwrap();
        let k = mask.data.iter().filter(|&&x| x > 0.5).count();
        let r = d.r_full();
        let f = fm2.factors.get_mut(&d.name).unwrap();
        if k + lr_rank <= r {
            // write B into W_u columns [k, k+lr), A into W_v rows [k, k+lr)
            for row in 0..d.m {
                for j in 0..lr_rank {
                    f.wu.set2(row, k + j, b.at2(row, j));
                }
            }
            for j in 0..lr_rank {
                for col in 0..d.n {
                    f.wv.set2(k + j, col, a.at2(j, col));
                }
            }
            for j in 0..lr_rank {
                mask.data[k + j] = 1.0;
            }
        } else {
            // dense-regime module: fold W + BA and re-factorize
            let w = f.wu.matmul(&f.wv); // (m, n) ≈ W (all-ones mask)
            let ba = b.matmul(a);
            let mut wnew = w.clone();
            for i in 0..wnew.data.len() {
                wnew.data[i] += ba.data[i];
            }
            *f = factorize_module(&wnew, &grams[&d.name], 1e-4)?;
            // dense modules keep the all-ones mask
            for x in mask.data.iter_mut() {
                *x = 1.0;
            }
        }
    }
    Ok((fm2, masks2))
}

