//! Versioned [`CompressionPlan`] artifacts — the unit serving consumes.
//!
//! A plan wraps the raw [`Allocation`] with the provenance the ROADMAP's
//! scenario sweeps need: which method spec produced it, at what target,
//! what it actually achieved, which seed and scale knobs were in effect,
//! and how long allocation took. The JSON schema is mirrored by
//! `python/compile/plans.py` (imported by `aot.py`), and
//! `runtime::resolve_alloc` accepts **both** plan files and legacy
//! bare-`Allocation` files, so pre-PR-5 allocation JSONs keep resolving.

use std::path::Path;

use crate::json::{self, Json};
use crate::model::Allocation;
use crate::Result;

/// Current plan schema version. Version `0` is reserved for plans
/// synthesized from legacy bare-`Allocation` files or computed serving
/// fallbacks — they carry no recorded provenance. Version `2` added the
/// optional top-level `quant` recipe (mirroring `allocation.quant`);
/// version-1 files load unchanged with `quant = null`.
pub const PLAN_SCHEMA_VERSION: u32 = 2;

/// The **effective** sample/epoch budget a mask-trained allocation ran
/// with — [`crate::compress::RunScale`] defaults with any spec overrides
/// applied (see `AllocMethod::budget`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PlanScale {
    pub alloc_samples: usize,
    pub alloc_epochs: usize,
}

/// A rank allocation plus the provenance needed to reproduce it.
#[derive(Debug, Clone, PartialEq)]
pub struct CompressionPlan {
    /// [`PLAN_SCHEMA_VERSION`] for freshly produced plans; `0` marks a
    /// legacy/computed plan with no recorded provenance.
    pub schema_version: u32,
    /// Canonical method spec (`ara@0.8?epochs=5`) that produced this plan.
    pub spec: String,
    /// Registry method id (`ara`), or `legacy` / `computed`.
    pub method: String,
    /// Display label for tables (`ARA`, `Dobi-SVD1`, …).
    pub label: String,
    /// Requested parameter ratio.
    pub target: f64,
    /// Achieved parameter ratio (`model::alloc_ratio`).
    pub achieved: f64,
    /// The method's RNG seed, when it has one (mask-trained methods).
    pub seed: Option<u64>,
    pub scale: PlanScale,
    /// Allocation wall time in milliseconds.
    pub wall_ms: f64,
    pub allocation: Allocation,
}

impl CompressionPlan {
    /// Wrap a bare [`Allocation`] (legacy file or computed serving
    /// fallback) as an unprovenanced plan.
    pub fn legacy(method: &str, allocation: Allocation, achieved: f64) -> CompressionPlan {
        CompressionPlan {
            schema_version: 0,
            spec: allocation.name.clone(),
            method: method.to_string(),
            label: allocation.name.clone(),
            target: achieved,
            achieved,
            seed: None,
            scale: PlanScale { alloc_samples: 0, alloc_epochs: 0 },
            wall_ms: 0.0,
            allocation,
        }
    }

    /// Does this plan carry recorded provenance (vs a legacy wrap)?
    pub fn provenanced(&self) -> bool {
        self.schema_version >= 1
    }

    /// The composed quantization recipe (carried by the allocation).
    pub fn quant(&self) -> Option<crate::quant::QuantScheme> {
        self.allocation.quant
    }

    /// One-line provenance summary for serving stats / CLI output. Names
    /// the quant recipe when the plan composes one.
    pub fn provenance_line(&self) -> String {
        let quant = match self.allocation.quant {
            Some(q) => format!(", int{}/g{}", q.bits, q.group),
            None => String::new(),
        };
        format!(
            "plan {} (schema v{}, achieved {:.4}, seed {}, {:.0} ms{quant})",
            self.spec,
            self.schema_version,
            self.achieved,
            self.seed.map_or("-".to_string(), |s| s.to_string()),
            self.wall_ms
        )
    }

    pub fn to_json(&self) -> String {
        let alloc = json::parse(&self.allocation.to_json()).expect("allocation JSON is valid");
        let quant = match &self.allocation.quant {
            Some(q) => json::obj(vec![
                ("bits", json::n(q.bits as f64)),
                ("group", json::n(q.group as f64)),
            ]),
            None => Json::Null,
        };
        json::obj(vec![
            ("schema_version", json::n(self.schema_version as f64)),
            ("spec", json::s(&self.spec)),
            ("method", json::s(&self.method)),
            ("label", json::s(&self.label)),
            ("target", json::n(self.target)),
            ("achieved", json::n(self.achieved)),
            ("seed", self.seed.map_or(Json::Null, |s| json::n(s as f64))),
            ("quant", quant),
            (
                "scale",
                json::obj(vec![
                    ("alloc_samples", json::n(self.scale.alloc_samples as f64)),
                    ("alloc_epochs", json::n(self.scale.alloc_epochs as f64)),
                ]),
            ),
            ("wall_ms", json::n(self.wall_ms)),
            ("allocation", alloc),
        ])
        .dump()
    }

    /// Parse a plan **or** a legacy bare-`Allocation` document (detected by
    /// the absence of `schema_version`); newer schema versions are
    /// rejected by name instead of being misread.
    pub fn from_json(text: &str) -> Result<CompressionPlan> {
        let j = json::parse(text)?;
        if j.get("schema_version").is_none() {
            // legacy bare-Allocation file: {"name": ..., "modules": {...}}
            let alloc = Allocation::from_json(text)?;
            return Ok(CompressionPlan::legacy("legacy", alloc, f64::NAN));
        }
        let version = j.req("schema_version")?.as_usize()? as u32;
        if version > PLAN_SCHEMA_VERSION {
            return Err(crate::anyhow!(
                "compression plan schema_version {version} is newer than supported \
                 {PLAN_SCHEMA_VERSION} — upgrade this binary"
            ));
        }
        let seed = match j.req("seed")? {
            Json::Null => None,
            s => Some(s.as_usize()? as u64),
        };
        let scale = j.req("scale")?;
        let mut allocation = Allocation::from_json(&j.req("allocation")?.dump())?;
        // v2 mirrors the recipe at the top level; backfill hand-written
        // files whose allocation object omits it.
        if allocation.quant.is_none() {
            match j.get("quant") {
                None | Some(Json::Null) => {}
                Some(q) => {
                    allocation.quant = Some(crate::quant::QuantScheme {
                        bits: q.req("bits")?.as_usize()? as u32,
                        group: q.req("group")?.as_usize()?,
                    });
                }
            }
        }
        Ok(CompressionPlan {
            schema_version: version,
            spec: j.req("spec")?.as_str()?.to_string(),
            method: j.req("method")?.as_str()?.to_string(),
            label: j.req("label")?.as_str()?.to_string(),
            target: j.req("target")?.as_f64()?,
            achieved: j.req("achieved")?.as_f64()?,
            seed,
            scale: PlanScale {
                alloc_samples: scale.req("alloc_samples")?.as_usize()?,
                alloc_epochs: scale.req("alloc_epochs")?.as_usize()?,
            },
            wall_ms: j.req("wall_ms")?.as_f64()?,
            allocation,
        })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json())?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<CompressionPlan> {
        CompressionPlan::from_json(
            &std::fs::read_to_string(path).map_err(|e| crate::anyhow!("read {path:?}: {e}"))?,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModuleAlloc;

    fn sample_plan() -> CompressionPlan {
        let mut a = Allocation::new("ara-80");
        a.set("layers.0.attn.wq", ModuleAlloc::Rank(7));
        a.set("layers.0.attn.wv", ModuleAlloc::Dense);
        CompressionPlan {
            schema_version: PLAN_SCHEMA_VERSION,
            spec: "ara@0.8?epochs=5".to_string(),
            method: "ara".to_string(),
            label: "ARA".to_string(),
            target: 0.8,
            achieved: 0.7931,
            seed: Some(7),
            scale: PlanScale { alloc_samples: 96, alloc_epochs: 5 },
            wall_ms: 1234.5,
            allocation: a,
        }
    }

    #[test]
    fn plan_json_roundtrip() {
        let p = sample_plan();
        let q = CompressionPlan::from_json(&p.to_json()).unwrap();
        assert_eq!(p, q);
    }

    #[test]
    fn seedless_plan_roundtrips_null_seed() {
        let mut p = sample_plan();
        p.seed = None;
        let q = CompressionPlan::from_json(&p.to_json()).unwrap();
        assert_eq!(q.seed, None);
        assert_eq!(p, q);
    }

    #[test]
    fn legacy_bare_allocation_loads_as_unprovenanced_plan() {
        let mut a = Allocation::new("uniform-80");
        a.set("layers.0.attn.wq", ModuleAlloc::Rank(3));
        let p = CompressionPlan::from_json(&a.to_json()).unwrap();
        assert!(!p.provenanced());
        assert_eq!(p.method, "legacy");
        assert_eq!(p.allocation, a);
    }

    #[test]
    fn quantized_plan_roundtrips_and_names_recipe() {
        let mut p = sample_plan();
        p.allocation.quant = Some(crate::quant::QuantScheme { bits: 8, group: 32 });
        let text = p.to_json();
        assert!(text.contains("\"quant\""), "{text}");
        let q = CompressionPlan::from_json(&text).unwrap();
        assert_eq!(p, q);
        assert_eq!(q.quant(), Some(crate::quant::QuantScheme { bits: 8, group: 32 }));
        assert!(q.provenance_line().contains("int8/g32"), "{}", q.provenance_line());
    }

    /// Drop `key` from the top level of an object document.
    fn without_key(text: &str, key: &str) -> String {
        let mut j = json::parse(text).unwrap();
        if let Json::Obj(pairs) = &mut j {
            pairs.retain(|(k, _)| k != key);
        }
        j.dump()
    }

    #[test]
    fn v1_plan_without_quant_loads_with_none() {
        // a v1-era file: no top-level quant key, no allocation.quant key
        let mut j = json::parse(&sample_plan().to_json()).unwrap();
        if let Json::Obj(pairs) = &mut j {
            pairs.retain(|(k, _)| k != "quant");
            for (k, v) in pairs.iter_mut() {
                if k == "schema_version" {
                    *v = json::n(1.0);
                }
            }
        }
        let q = CompressionPlan::from_json(&j.dump()).unwrap();
        assert_eq!(q.schema_version, 1);
        assert_eq!(q.quant(), None);
        assert!(!q.provenance_line().contains("int8"));
    }

    #[test]
    fn top_level_quant_backfills_bare_allocation_object() {
        // hand-written v2 file where only the top level names the recipe
        let mut p = sample_plan();
        p.allocation.quant = Some(crate::quant::QuantScheme { bits: 8, group: 16 });
        let mut j = json::parse(&p.to_json()).unwrap();
        if let Json::Obj(pairs) = &mut j {
            for (k, v) in pairs.iter_mut() {
                if k == "allocation" {
                    *v = json::parse(&without_key(&v.dump(), "quant")).unwrap();
                }
            }
        }
        let q = CompressionPlan::from_json(&j.dump()).unwrap();
        assert_eq!(q.quant(), Some(crate::quant::QuantScheme { bits: 8, group: 16 }));
    }

    #[test]
    fn newer_schema_version_is_rejected_by_name() {
        let mut p = sample_plan();
        p.schema_version = PLAN_SCHEMA_VERSION + 1;
        let err = CompressionPlan::from_json(&p.to_json()).unwrap_err().to_string();
        assert!(err.contains("schema_version"), "{err}");
    }
}
