//! String-addressable method registry: parses method specs like
//! `ara@0.8`, `dobi@0.75?epochs=20`, or `dlp@0.8?tail=0.15` into boxed
//! [`AllocMethod`]s. Unknown methods, unknown parameters, malformed
//! values, and out-of-range targets all fail with the offending **spec
//! named in the error**, so a typo in a sweep grid or CLI invocation is
//! diagnosable from the message alone.
//!
//! Grammar (DESIGN.md §4):
//!
//! ```text
//! spec    := method [ '@' target ] [ '?' params ]
//! method  := one of ALL_METHOD_IDS (plus aliases: dobi-svd1 → dobi)
//! target  := parameter ratio in (0, 1]
//! params  := key '=' value ( '&' key '=' value )*
//! ```

use crate::Result;

use super::methods::{Ara, Ars, Dlp, Dobi, Farms, Strs, Uniform};
use super::AllocMethod;

/// Canonical ids of the Table 1/2 comparison set, in paper row order.
/// (`ara-nolg`, the Table 5 ablation, is registered but not part of the
/// standard grid.)
pub const ALL_METHOD_IDS: [&str; 7] = ["uniform", "dlp", "farms", "strs", "ars", "dobi", "ara"];

/// A parsed method spec: method id, optional target ratio, raw parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct MethodSpec {
    pub method: String,
    pub target: Option<f64>,
    pub params: Vec<(String, String)>,
}

impl MethodSpec {
    /// Parse `method[@target][?k=v[&k=v]*]`; errors name the full spec.
    pub fn parse(spec: &str) -> Result<MethodSpec> {
        let bad = |why: &str| crate::anyhow!("bad method spec `{spec}`: {why}");
        let (head, query) = match spec.split_once('?') {
            Some((h, q)) => (h, Some(q)),
            None => (spec, None),
        };
        let (method, target) = match head.split_once('@') {
            None => (head, None),
            Some((m, t)) => {
                let r: f64 = t
                    .parse()
                    .map_err(|_| bad(&format!("target `{t}` is not a number")))?;
                // NB: the finiteness check also rejects `NaN`, which every
                // plain comparison would wave through
                if !r.is_finite() || r <= 0.0 || r > 1.0 {
                    return Err(bad(&format!("target {r} outside (0, 1]")));
                }
                (m, Some(r))
            }
        };
        if method.is_empty() {
            return Err(bad("empty method name"));
        }
        // method ids are case-insensitive (the pre-registry CLI lowercased
        // its `--method` argument; keep that contract)
        let method = method.to_lowercase();
        let params = match query {
            Some(q) => parse_query(spec, q)?,
            None => Vec::new(),
        };
        Ok(MethodSpec { method, target, params })
    }

    /// The canonical spec string (method@target?k=v&…), used as the plan's
    /// recorded provenance and as bench JSON keys.
    pub fn canonical(&self) -> String {
        let mut s = self.method.clone();
        if let Some(t) = self.target {
            s.push_str(&format!("@{t}"));
        }
        for (i, (k, v)) in self.params.iter().enumerate() {
            s.push(if i == 0 { '?' } else { '&' });
            s.push_str(&format!("{k}={v}"));
        }
        s
    }

    /// A copy of this spec with the target replaced (sweep grids).
    pub fn with_target(&self, target: f64) -> MethodSpec {
        MethodSpec { target: Some(target), ..self.clone() }
    }
}

/// Parse a `key=value&key=value` query segment with empty/duplicate checks;
/// errors name `spec` (the full string the query was cut from). Shared by
/// [`MethodSpec::parse`] and the fault-plan grammar
/// ([`crate::serving::FaultPlan`]), which reuses the `?k=v` syntax.
pub(crate) fn parse_query(spec: &str, q: &str) -> Result<Vec<(String, String)>> {
    let bad = |why: &str| crate::anyhow!("bad spec `{spec}`: {why}");
    let mut params = Vec::new();
    for kv in q.split('&') {
        let (k, v) = kv
            .split_once('=')
            .ok_or_else(|| bad(&format!("parameter `{kv}` is not key=value")))?;
        if k.is_empty() || v.is_empty() {
            return Err(bad(&format!("parameter `{kv}` has an empty key or value")));
        }
        if params.iter().any(|(pk, _)| pk == k) {
            return Err(bad(&format!("duplicate parameter `{k}`")));
        }
        params.push((k.to_string(), v.to_string()));
    }
    Ok(params)
}

/// Typed parameter extraction with errors that name the spec.
pub(crate) struct Params<'s> {
    spec: &'s str,
    left: Vec<(String, String)>,
}

impl<'s> Params<'s> {
    pub(crate) fn new(spec: &'s str, params: Vec<(String, String)>) -> Params<'s> {
        Params { spec, left: params }
    }

    pub(crate) fn take(&mut self, key: &str) -> Option<String> {
        let i = self.left.iter().position(|(k, _)| k == key)?;
        Some(self.left.remove(i).1)
    }

    pub(crate) fn parsed<T: std::str::FromStr>(
        &mut self,
        key: &str,
        what: &str,
    ) -> Result<Option<T>> {
        match self.take(key) {
            None => Ok(None),
            Some(v) => v.parse::<T>().map(Some).map_err(|_| {
                crate::anyhow!("spec `{}`: parameter `{key}={v}` is not {what}", self.spec)
            }),
        }
    }

    pub(crate) fn f64(&mut self, key: &str) -> Result<Option<f64>> {
        self.parsed(key, "a number")
    }
    pub(crate) fn usize(&mut self, key: &str) -> Result<Option<usize>> {
        self.parsed(key, "a non-negative integer")
    }
    pub(crate) fn u64(&mut self, key: &str) -> Result<Option<u64>> {
        self.parsed(key, "a non-negative integer")
    }
    pub(crate) fn bool(&mut self, key: &str) -> Result<Option<bool>> {
        match self.take(key) {
            None => Ok(None),
            Some(v) => match v.as_str() {
                "1" | "true" => Ok(Some(true)),
                "0" | "false" => Ok(Some(false)),
                _ => Err(crate::anyhow!(
                    "spec `{}`: parameter `{key}={v}` is not a bool (0/1/true/false)",
                    self.spec
                )),
            },
        }
    }

    /// Every parameter must have been consumed; leftovers are unknown.
    pub(crate) fn finish(self, allowed: &[&str]) -> Result<()> {
        if let Some((k, _)) = self.left.first() {
            return Err(crate::anyhow!(
                "unknown parameter `{k}` for `{}` in spec `{}` (allowed: {})",
                self.spec.split(['@', '?']).next().unwrap_or(self.spec),
                self.spec,
                if allowed.is_empty() { "none".to_string() } else { allowed.join(", ") }
            ));
        }
        Ok(())
    }
}

/// The quantization recipe of a spec, from its method-agnostic `quant` /
/// `group` params: `?quant=int8[&group=N]` → `Some(QuantScheme)` (group
/// defaults to 32), `?quant=none` or absent → `None`. Every method
/// composes with quantization, so these params are validated here rather
/// than per-method; errors name the spec.
pub fn quant_params(spec: &MethodSpec) -> Result<Option<crate::quant::QuantScheme>> {
    let canonical = spec.canonical();
    let mut p = Params::new(&canonical, spec.params.clone());
    let quant = p.take("quant");
    let group = p.usize("group")?;
    // remaining params belong to the method; build_method validates them
    match quant.as_deref() {
        None => {
            if group.is_some() {
                return Err(crate::anyhow!(
                    "spec `{canonical}`: `group` requires `quant=int8`"
                ));
            }
            Ok(None)
        }
        Some("none") => {
            if group.is_some() {
                return Err(crate::anyhow!(
                    "spec `{canonical}`: `group` requires `quant=int8`"
                ));
            }
            Ok(None)
        }
        Some("int8") => {
            let group = group.unwrap_or(32);
            if group == 0 {
                return Err(crate::anyhow!("spec `{canonical}`: `group` must be positive"));
            }
            Ok(Some(crate::quant::QuantScheme { bits: 8, group }))
        }
        Some(other) => Err(crate::anyhow!(
            "spec `{canonical}`: unknown quantization `{other}` (expected int8 or none)"
        )),
    }
}

/// Build the boxed method a parsed spec names, applying its parameters.
pub fn build_method(spec: &MethodSpec) -> Result<Box<dyn AllocMethod>> {
    let canonical = spec.canonical();
    let mut p = Params::new(&canonical, spec.params.clone());
    // quant/group are method-agnostic (validated by `quant_params`); strip
    // them before per-method validation so every method accepts them
    quant_params(spec)?;
    p.take("quant");
    p.take("group");
    let method: Box<dyn AllocMethod> = match spec.method.as_str() {
        "uniform" => {
            p.finish(&[])?;
            Box::new(Uniform)
        }
        "dlp" => {
            let mut m = Dlp::default();
            if let Some(t) = p.f64("tail")? {
                m.cfg.tail = t;
            }
            p.finish(&["tail"])?;
            Box::new(m)
        }
        "farms" => {
            let mut m = Farms::default();
            if let Some(e) = p.f64("eps")? {
                m.cfg.eps = e;
            }
            p.finish(&["eps"])?;
            Box::new(m)
        }
        "strs" => {
            let mut m = Strs::default();
            if let Some(s) = p.u64("seed")? {
                m.cfg.data_seed = s;
            }
            if let Some(b) = p.usize("probe_batches")? {
                m.cfg.probe_batches = b;
            }
            p.finish(&["seed", "probe_batches"])?;
            Box::new(m)
        }
        "ars" => {
            let mut m = Ars::default();
            m.epochs = p.usize("epochs")?;
            if let Some(v) = p.f64("lambda")? {
                m.cfg.lambda = v;
            }
            if let Some(v) = p.f64("temperature")? {
                m.cfg.temperature = v;
            }
            if let Some(v) = p.f64("lr")? {
                m.cfg.lr = v;
            }
            if let Some(v) = p.u64("seed")? {
                m.cfg.seed = v;
            }
            if let Some(v) = p.u64("data_seed")? {
                m.cfg.data_seed = v;
            }
            p.finish(&["epochs", "lambda", "temperature", "lr", "seed", "data_seed"])?;
            Box::new(m)
        }
        "dobi" | "dobi-svd1" => {
            let mut m = Dobi::default();
            m.epochs = p.usize("epochs")?;
            if let Some(v) = p.f64("lambda")? {
                m.cfg.lambda = v;
            }
            if let Some(v) = p.f64("beta")? {
                m.cfg.beta = v;
            }
            if let Some(v) = p.f64("lr")? {
                m.cfg.lr = v;
            }
            if let Some(v) = p.u64("data_seed")? {
                m.cfg.data_seed = v;
            }
            p.finish(&["epochs", "lambda", "beta", "lr", "data_seed"])?;
            Box::new(m)
        }
        "ara" | "ara-nolg" => {
            let mut m = Ara::default();
            m.cfg.use_guidance = spec.method == "ara";
            m.epochs = p.usize("epochs")?;
            m.samples = p.usize("samples")?;
            if let Some(v) = p.f64("lambda1")? {
                m.cfg.lambda1 = v;
            }
            if let Some(v) = p.f64("lambda2")? {
                m.cfg.lambda2 = v;
            }
            if let Some(v) = p.usize("d")? {
                m.cfg.d = v;
            }
            if let Some(v) = p.f64("lr")? {
                m.cfg.lr = v;
            }
            if let Some(v) = p.u64("seed")? {
                m.cfg.seed = v;
            }
            match (spec.method.as_str(), p.bool("guidance")?) {
                ("ara", Some(g)) => m.cfg.use_guidance = g,
                ("ara-nolg", Some(_)) => {
                    return Err(crate::anyhow!(
                        "spec `{canonical}`: `guidance` is only valid on `ara` \
                         (`ara-nolg` pins it off)"
                    ));
                }
                _ => {}
            }
            p.finish(&["epochs", "samples", "lambda1", "lambda2", "d", "lr", "seed", "guidance"])?;
            Box::new(m)
        }
        other => {
            return Err(crate::anyhow!(
                "unknown method `{other}` in spec `{canonical}` (known: {}, ara-nolg)",
                ALL_METHOD_IDS.join(", ")
            ));
        }
    };
    Ok(method)
}

/// Parse a spec string and build its method in one step.
pub fn method_for(spec: &str) -> Result<(MethodSpec, Box<dyn AllocMethod>)> {
    let parsed = MethodSpec::parse(spec)?;
    let method = build_method(&parsed)?;
    Ok((parsed, method))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_plain_and_parameterized_specs() {
        let s = MethodSpec::parse("ara@0.8").unwrap();
        assert_eq!(s.method, "ara");
        assert_eq!(s.target, Some(0.8));
        assert!(s.params.is_empty());
        assert_eq!(s.canonical(), "ara@0.8");

        let s = MethodSpec::parse("dobi@0.75?epochs=20&lr=1.5").unwrap();
        assert_eq!(s.target, Some(0.75));
        assert_eq!(s.params.len(), 2);
        assert_eq!(s.canonical(), "dobi@0.75?epochs=20&lr=1.5");

        let s = MethodSpec::parse("uniform").unwrap();
        assert_eq!(s.target, None);

        // method ids are case-insensitive (legacy CLI contract)
        let s = MethodSpec::parse("ARA@0.8").unwrap();
        assert_eq!(s.method, "ara");
        assert_eq!(s.canonical(), "ara@0.8");
        assert!(method_for("Dobi-SVD1@0.5").is_ok());
    }

    #[test]
    fn non_finite_targets_are_rejected() {
        for bad in ["ara@NaN", "ara@inf", "ara@-inf"] {
            let err = MethodSpec::parse(bad).unwrap_err().to_string();
            assert!(err.contains("outside (0, 1]"), "`{bad}` must be rejected: {err}");
        }
    }

    #[test]
    fn errors_name_the_spec() {
        for bad in ["nosuch@0.8", "ara@1.8", "ara@x", "dlp@0.8?tail", "@0.5", "ara@0.5?k=1&k=2"] {
            let err = MethodSpec::parse(bad)
                .map_err(|e| e.to_string())
                .and_then(|s| build_method(&s).map(|_| ()).map_err(|e| e.to_string()))
                .unwrap_err();
            assert!(err.contains(bad), "error for `{bad}` should name it: {err}");
        }
    }

    #[test]
    fn unknown_parameter_names_spec_and_allowed_set() {
        let err = method_for("ara@0.8?bogus=1").unwrap_err().to_string();
        assert!(err.contains("bogus"), "{err}");
        assert!(err.contains("ara@0.8?bogus=1"), "{err}");
        assert!(err.contains("epochs"), "should list allowed params: {err}");
    }

    #[test]
    fn every_canonical_id_builds() {
        for id in ALL_METHOD_IDS {
            let (_, m) = method_for(&format!("{id}@0.5")).unwrap();
            assert_eq!(m.id(), id);
        }
        let (_, m) = method_for("ara-nolg@0.5").unwrap();
        assert_eq!(m.id(), "ara-nolg");
        let (_, m) = method_for("dobi-svd1@0.5").unwrap();
        assert_eq!(m.id(), "dobi");
    }

    #[test]
    fn quant_params_parse_and_compose_with_every_method() {
        use crate::quant::QuantScheme;
        let s = MethodSpec::parse("ara@0.8?quant=int8").unwrap();
        assert_eq!(quant_params(&s).unwrap(), Some(QuantScheme { bits: 8, group: 32 }));
        assert!(build_method(&s).is_ok(), "quant must compose with ara");
        let s = MethodSpec::parse("uniform@0.8?quant=int8&group=16").unwrap();
        assert_eq!(quant_params(&s).unwrap().unwrap().group, 16);
        assert!(build_method(&s).is_ok(), "quant must compose with uniform");
        assert_eq!(s.canonical(), "uniform@0.8?quant=int8&group=16");
        // explicit f32
        let s = MethodSpec::parse("ara@0.8?quant=none").unwrap();
        assert_eq!(quant_params(&s).unwrap(), None);
        // invalid recipes are named in errors
        assert!(quant_params(&MethodSpec::parse("ara@0.8?quant=int4").unwrap()).is_err());
        assert!(quant_params(&MethodSpec::parse("ara@0.8?group=32").unwrap()).is_err());
        assert!(quant_params(&MethodSpec::parse("ara@0.8?quant=int8&group=0").unwrap()).is_err());
        // build_method validates quant before stripping it
        assert!(build_method(&MethodSpec::parse("uniform@0.8?quant=int4").unwrap()).is_err());
    }

    #[test]
    fn parameters_reach_the_config() {
        let (_, m) = method_for("dlp@0.8?tail=0.15").unwrap();
        assert_eq!(m.id(), "dlp");
        let (_, m) = method_for("ara@0.8?guidance=0").unwrap();
        // guidance=0 flips the id to the ablation
        assert_eq!(m.id(), "ara-nolg");
        assert!(method_for("strs@0.8?seed=9").is_ok());
        assert!(method_for("uniform@0.8?x=1").is_err());
    }
}
