//! [`AllocMethod`] implementations for every allocation strategy in the
//! repo — ARA itself (plus its no-guidance ablation) and each paper
//! baseline — with the parameter defaults that used to live inline in
//! `Pipeline::allocate` (DLP tail 0.15, FARMS eps 0.3, runner data seeds
//! 3/4/5) now visible on the methods' config structs and overridable
//! through the spec grammar (`dlp@0.8?tail=0.2`; see [`super::registry`]).
//!
//! Methods trained on the shared mask-gradient loss surface (STRS, ARS,
//! Dobi-SVD₁, ARA) default their epoch/sample budgets from the pipeline's
//! [`super::RunScale`] — an explicit spec parameter pins them instead.

use crate::ara::{train_ara, AraConfig, MaskGradRunner};
use crate::baselines::{
    ars_alloc, dlp_alloc, dobi_alloc, farms_alloc, strs_alloc, uniform_alloc, ArsConfig,
    DlpConfig, DobiConfig, FarmsConfig, StrsConfig,
};
use crate::config::ModelCfg;
use crate::model::{module_dims, Allocation, ModuleAlloc, ModuleDim};
use crate::Result;

use super::{AllocCtx, AllocMethod};

/// The calibration corpus every mask-trained method probes (unchanged
/// from the pre-registry pipeline; ARA's own corpus comes from
/// [`AraConfig::corpus`]).
const MASK_CORPUS: &str = "sync4";

// ---------------------------------------------------------------------------
// Uniform
// ---------------------------------------------------------------------------

/// SVD-LLM-style uniform allocation (no parameters).
#[derive(Debug, Clone, Default)]
pub struct Uniform;

impl AllocMethod for Uniform {
    fn id(&self) -> &str {
        "uniform"
    }
    fn label(&self) -> &str {
        "Uniform"
    }
    fn allocate(&self, ctx: &AllocCtx, target: f64) -> Result<Allocation> {
        Ok(uniform_alloc(ctx.cfg, target))
    }
}

// ---------------------------------------------------------------------------
// DLP
// ---------------------------------------------------------------------------

/// Outlier-driven layerwise allocation.
#[derive(Debug, Clone, Default)]
pub struct Dlp {
    pub cfg: DlpConfig,
}

impl AllocMethod for Dlp {
    fn id(&self) -> &str {
        "dlp"
    }
    fn label(&self) -> &str {
        "DLP"
    }
    fn allocate(&self, ctx: &AllocCtx, target: f64) -> Result<Allocation> {
        Ok(dlp_alloc(ctx.cfg, ctx.ws, ctx.grams, target, self.cfg.tail))
    }
}

// ---------------------------------------------------------------------------
// FARMS
// ---------------------------------------------------------------------------

/// Heavy-tailed ESD (Hill estimator) layerwise allocation.
#[derive(Debug, Clone, Default)]
pub struct Farms {
    pub cfg: FarmsConfig,
}

impl AllocMethod for Farms {
    fn id(&self) -> &str {
        "farms"
    }
    fn label(&self) -> &str {
        "FARMS"
    }
    fn allocate(&self, ctx: &AllocCtx, target: f64) -> Result<Allocation> {
        Ok(farms_alloc(ctx.cfg, ctx.fm, target, self.cfg.eps))
    }
}

// ---------------------------------------------------------------------------
// STRS
// ---------------------------------------------------------------------------

/// Sensitivity-based Truncation Rank Searching (ASVD).
#[derive(Debug, Clone, Default)]
pub struct Strs {
    pub cfg: StrsConfig,
}

impl AllocMethod for Strs {
    fn id(&self) -> &str {
        "strs"
    }
    fn label(&self) -> &str {
        "STRS"
    }
    fn seed(&self) -> Option<u64> {
        Some(self.cfg.data_seed)
    }
    fn allocate(&self, ctx: &AllocCtx, target: f64) -> Result<Allocation> {
        let runner = MaskGradRunner::new(
            ctx.cfg,
            ctx.rt,
            ctx.ws,
            ctx.fm,
            MASK_CORPUS,
            ctx.scale.alloc_samples,
            self.cfg.data_seed,
        )?;
        strs_alloc(ctx.cfg, &runner, ctx.fm, target, &self.cfg)
    }
}

// ---------------------------------------------------------------------------
// ARS
// ---------------------------------------------------------------------------

/// Gumbel-Sigmoid mask training (no monotonicity).
#[derive(Debug, Clone, Default)]
pub struct Ars {
    pub cfg: ArsConfig,
    /// Override for the epoch budget; defaults to `RunScale::alloc_epochs`.
    pub epochs: Option<usize>,
}

impl AllocMethod for Ars {
    fn id(&self) -> &str {
        "ars"
    }
    fn label(&self) -> &str {
        "ARS"
    }
    fn seed(&self) -> Option<u64> {
        Some(self.cfg.seed)
    }
    fn budget(&self, scale: &super::RunScale) -> super::PlanScale {
        super::PlanScale {
            alloc_samples: scale.alloc_samples,
            alloc_epochs: self.epochs.unwrap_or(scale.alloc_epochs),
        }
    }
    fn allocate(&self, ctx: &AllocCtx, target: f64) -> Result<Allocation> {
        let runner = MaskGradRunner::new(
            ctx.cfg,
            ctx.rt,
            ctx.ws,
            ctx.fm,
            MASK_CORPUS,
            ctx.scale.alloc_samples,
            self.cfg.data_seed,
        )?;
        let mut ac = self.cfg.clone();
        ac.target = target;
        ac.epochs = self.epochs.unwrap_or(ctx.scale.alloc_epochs);
        ars_alloc(ctx.cfg, &runner, &ac)
    }
}

// ---------------------------------------------------------------------------
// Dobi-SVD₁
// ---------------------------------------------------------------------------

/// tanh-boundary mask training (monotone, local updates).
#[derive(Debug, Clone, Default)]
pub struct Dobi {
    pub cfg: DobiConfig,
    /// Override for the epoch budget; defaults to `2 × alloc_epochs` (the
    /// pre-registry pipeline's compensation for Dobi's slow local updates).
    pub epochs: Option<usize>,
}

impl AllocMethod for Dobi {
    fn id(&self) -> &str {
        "dobi"
    }
    fn label(&self) -> &str {
        "Dobi-SVD1"
    }
    fn seed(&self) -> Option<u64> {
        Some(self.cfg.data_seed)
    }
    fn budget(&self, scale: &super::RunScale) -> super::PlanScale {
        super::PlanScale {
            alloc_samples: scale.alloc_samples,
            alloc_epochs: self.epochs.unwrap_or(scale.alloc_epochs * 2),
        }
    }
    fn allocate(&self, ctx: &AllocCtx, target: f64) -> Result<Allocation> {
        let runner = MaskGradRunner::new(
            ctx.cfg,
            ctx.rt,
            ctx.ws,
            ctx.fm,
            MASK_CORPUS,
            ctx.scale.alloc_samples,
            self.cfg.data_seed,
        )?;
        let mut dc = self.cfg.clone();
        dc.target = target;
        dc.epochs = self.epochs.unwrap_or(ctx.scale.alloc_epochs * 2);
        dobi_alloc(ctx.cfg, &runner, &dc)
    }
}

// ---------------------------------------------------------------------------
// ARA (and the no-guidance ablation)
// ---------------------------------------------------------------------------

/// The paper's staircase-mask allocation training (Alg. 1). With
/// `cfg.use_guidance == false` this is the Table 5 / Fig. 4(b) ablation,
/// registered separately as `ara-nolg`.
#[derive(Debug, Clone, Default)]
pub struct Ara {
    pub cfg: AraConfig,
    /// Override for the epoch budget; defaults to `RunScale::alloc_epochs`.
    pub epochs: Option<usize>,
    /// Override for the sample budget; defaults to `RunScale::alloc_samples`.
    pub samples: Option<usize>,
}

impl AllocMethod for Ara {
    fn id(&self) -> &str {
        if self.cfg.use_guidance {
            "ara"
        } else {
            "ara-nolg"
        }
    }
    fn label(&self) -> &str {
        if self.cfg.use_guidance {
            "ARA"
        } else {
            "ARA(noLg)"
        }
    }
    fn seed(&self) -> Option<u64> {
        Some(self.cfg.seed)
    }
    fn budget(&self, scale: &super::RunScale) -> super::PlanScale {
        super::PlanScale {
            alloc_samples: self.samples.unwrap_or(scale.alloc_samples),
            alloc_epochs: self.epochs.unwrap_or(scale.alloc_epochs),
        }
    }
    fn allocate(&self, ctx: &AllocCtx, target: f64) -> Result<Allocation> {
        let mut ac = self.cfg.clone();
        ac.target = target;
        ac.epochs = self.epochs.unwrap_or(ctx.scale.alloc_epochs);
        ac.samples = self.samples.unwrap_or(ctx.scale.alloc_samples);
        let (alloc, _) = train_ara(ctx.cfg, ctx.rt, ctx.ws, ctx.fm, &ac)?;
        Ok(alloc)
    }
}

// ---------------------------------------------------------------------------
// Data-free computed allocations (serving fallbacks)
// ---------------------------------------------------------------------------

/// Resolve the *computed* serving allocation names — `dense`,
/// `uniform-<pct>`, `ara-<pct>` (paper-shaped heuristic) — that need no
/// calibration data. `None` means the name is not a computed form (the
/// caller falls through to its not-found error).
pub fn computed_alloc(cfg: &ModelCfg, name: &str) -> Option<Result<Allocation>> {
    let pct_ratio = |pct: &str| -> Result<f64> {
        pct.parse::<f64>()
            .map_err(|_| crate::anyhow!("bad allocation name `{name}`"))
            .map(|p| p / 100.0)
    };
    if name == "dense" {
        let mut a = Allocation::new("dense");
        for d in module_dims(cfg) {
            a.set(&d.name, ModuleAlloc::Dense);
        }
        Some(Ok(a))
    } else if let Some(pct) = name.strip_prefix("uniform-") {
        Some(pct_ratio(pct).map(|r| uniform_alloc(cfg, r)))
    } else if let Some(pct) = name.strip_prefix("ara-") {
        Some(pct_ratio(pct).map(|r| heuristic_ara_alloc(cfg, r)))
    } else {
        None
    }
}

/// Paper-shaped fallback (Fig. 4 structure): keep v/down dense where the
/// budget allows, compress q/k hardest — port of aot.py:heuristic_ara_alloc.
pub fn heuristic_ara_alloc(cfg: &ModelCfg, ratio: f64) -> Allocation {
    let dims = module_dims(cfg);
    let total: f64 = dims.iter().map(|d| d.dense_params() as f64).sum();
    let budget = ratio * total;
    let weight = |name: &str| -> f64 {
        match name.rsplit('.').next().unwrap_or("") {
            "wq" | "wk" => 0.45,
            "wv" | "wdown" => 1.0,
            "wo" | "wup" => 0.9,
            "wgate" => 1.1,
            _ => 1.0,
        }
    };

    let mut dense_set: Vec<String> = Vec::new();
    let prefer: Vec<&ModuleDim> = dims
        .iter()
        .filter(|d| d.name.ends_with(".wv") || d.name.ends_with(".wdown"))
        .collect();
    for cand in prefer {
        let used: f64 = dims
            .iter()
            .filter(|d| dense_set.contains(&d.name))
            .map(|d| d.dense_params() as f64)
            .sum();
        let min_rest: f64 = dims
            .iter()
            .filter(|d| !dense_set.contains(&d.name) && d.name != cand.name)
            .map(|d| (d.m + d.n) as f64)
            .sum();
        if used + cand.dense_params() as f64 + min_rest <= budget {
            dense_set.push(cand.name.clone());
        }
    }

    let used: f64 = dims
        .iter()
        .filter(|d| dense_set.contains(&d.name))
        .map(|d| d.dense_params() as f64)
        .sum();
    let wsum: f64 = dims
        .iter()
        .filter(|d| !dense_set.contains(&d.name))
        .map(|d| weight(&d.name) * d.dense_params() as f64)
        .sum::<f64>()
        .max(1.0);

    let mut alloc = Allocation::new(format!("ara-{}", (ratio * 100.0).round() as usize));
    for d in &dims {
        if dense_set.contains(&d.name) {
            alloc.set(&d.name, ModuleAlloc::Dense);
            continue;
        }
        let share = (budget - used) * weight(&d.name) * d.dense_params() as f64 / wsum;
        let k = ((share / (d.m + d.n) as f64) as usize).clamp(1, d.r_full());
        alloc.set(&d.name, ModuleAlloc::Rank(k));
    }
    alloc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{model_by_name, Paths};

    fn cfg(name: &str) -> ModelCfg {
        let paths = Paths::discover().unwrap();
        model_by_name(&paths.configs, name).unwrap()
    }

    #[test]
    fn heuristic_alloc_meets_budget_and_prefers_v_down() {
        let c = cfg("minillama-s");
        let dims = module_dims(&c);
        for ratio in [0.8, 0.6] {
            let a = heuristic_ara_alloc(&c, ratio);
            let got = crate::model::alloc_ratio(&c, &a);
            assert!(got <= ratio + 0.05, "heuristic overshoots: {got} vs target {ratio}");
            for d in &dims {
                if let ModuleAlloc::Rank(k) = a.get(&d.name) {
                    assert!(k >= 1 && k <= d.r_full());
                }
            }
        }
        // at a generous budget some v/down modules stay dense
        let a = heuristic_ara_alloc(&c, 0.8);
        assert!(a.dense_count() > 0, "expected dense v/down under 0.8 budget");
    }

    #[test]
    fn computed_alloc_covers_the_serving_names() {
        let c = cfg("micro-llama");
        let dense = computed_alloc(&c, "dense").unwrap().unwrap();
        assert_eq!(dense.dense_count(), dense.modules.len());
        let uni = computed_alloc(&c, "uniform-80").unwrap().unwrap();
        assert_eq!(uni.name, "uniform-80");
        let ara = computed_alloc(&c, "ara-60").unwrap().unwrap();
        assert_eq!(ara.name, "ara-60");
        assert!(computed_alloc(&c, "uniform-xx").unwrap().is_err());
        assert!(computed_alloc(&c, "somefile").is_none());
    }
}
