//! The unified compression-method API (PR 5).
//!
//! Three public surfaces replace the old hardwired `match` in
//! `Pipeline::allocate`:
//!
//! * [`AllocMethod`] + [`AllocCtx`] — every allocation strategy (ARA and
//!   all baselines, [`methods`]) behind one trait, with the substrate
//!   bundle (`ModelCfg`/`Runtime`/`WeightStore`/grams/`FactoredModel`/
//!   [`RunScale`]) passed as one context instead of six arguments;
//! * [`registry`] — string-addressable method specs (`ara@0.8`,
//!   `dobi@0.75?epochs=20`) parsed into boxed methods, with unknown
//!   methods/parameters failing by spec name;
//! * [`CompressionPlan`] ([`plan`]) — the versioned artifact wrapping an
//!   `Allocation` with its provenance (spec, target/achieved ratio, seed,
//!   scale knobs, timing); serving resolves plans, legacy bare-allocation
//!   JSON stays loadable.
//!
//! The old `MethodKind`-based entry points survive one release as thin
//! deprecated shims (see `coordinator::Pipeline::allocate`) so parity can
//! be pinned before deletion.

pub mod methods;
pub mod plan;
pub mod registry;

use std::collections::BTreeMap;

use crate::config::{scaled, ModelCfg};
use crate::linalg::Mat;
use crate::model::{Allocation, WeightStore};
use crate::runtime::Runtime;
use crate::svd::FactoredModel;
use crate::Result;

pub use methods::{computed_alloc, heuristic_ara_alloc};
pub use plan::{CompressionPlan, PlanScale, PLAN_SCHEMA_VERSION};
pub use registry::{build_method, method_for, quant_params, MethodSpec, ALL_METHOD_IDS};

/// Experiment-scale knobs (all counts, no shapes) with bench defaults.
#[derive(Debug, Clone)]
pub struct RunScale {
    pub pretrain_steps: usize,
    pub calib_batches: usize,
    pub alloc_samples: usize,
    pub alloc_epochs: usize,
    pub eval_batches: usize,
    pub zs_items: usize,
}

impl Default for RunScale {
    fn default() -> Self {
        // scaled by ARA_SCALE (config::scaled)
        RunScale {
            // NOT scaled by ARA_SCALE: the pre-trained substrate is cached
            // on disk and shared by every harness regardless of scale
            // (override with ARA_PRETRAIN_STEPS)
            pretrain_steps: std::env::var("ARA_PRETRAIN_STEPS")
                .ok()
                .and_then(|v| v.parse().ok())
                .unwrap_or(1200),
            calib_batches: scaled(8, 2),
            alloc_samples: scaled(96, 16),
            alloc_epochs: scaled(10, 3),
            eval_batches: scaled(6, 2),
            zs_items: scaled(24, 8),
        }
    }
}

/// Everything an allocation method may consume, bundled: the model
/// preset, the runtime (for mask-gradient training), the dense weights,
/// the calibration Grams, the whitened factorization, and the experiment
/// scale. Borrowed — building a ctx is free.
pub struct AllocCtx<'a> {
    pub cfg: &'a ModelCfg,
    pub rt: &'a Runtime,
    pub ws: &'a WeightStore,
    pub grams: &'a BTreeMap<String, Mat>,
    pub fm: &'a FactoredModel,
    pub scale: &'a RunScale,
}

/// One allocation strategy: maps a target parameter ratio to a rank
/// [`Allocation`] over the shared substrate. Implementations live in
/// [`methods`]; instances are built from specs by [`registry`].
pub trait AllocMethod {
    /// Canonical registry id (`ara`, `dlp`, …) — the spec's method field.
    fn id(&self) -> &str;

    /// Display label for tables (`ARA`, `Dobi-SVD1`, …).
    fn label(&self) -> &str {
        self.id()
    }

    /// The method's RNG seed, when it has one (recorded in the plan).
    fn seed(&self) -> Option<u64> {
        None
    }

    /// The **effective** sample/epoch budget this method trains with under
    /// `scale` — spec overrides included — recorded in the plan so its
    /// provenance never contradicts what actually ran.
    fn budget(&self, scale: &RunScale) -> plan::PlanScale {
        plan::PlanScale { alloc_samples: scale.alloc_samples, alloc_epochs: scale.alloc_epochs }
    }

    /// Run the method at `target` over the bundled substrate.
    fn allocate(&self, ctx: &AllocCtx, target: f64) -> Result<Allocation>;
}

/// All allocation methods of Table 1/2 (legacy enum; the registry's
/// string ids are the supported surface).
#[deprecated(note = "use compress::registry method specs (`ara@0.8`) instead")]
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MethodKind {
    Uniform,
    Dlp,
    Farms,
    Strs,
    Ars,
    Dobi,
    Ara,
    /// ARA without the guidance loss (Table 5 / Fig. 4b ablation).
    AraNoGuidance,
}

#[allow(deprecated)]
#[deprecated(note = "use compress::ALL_METHOD_IDS instead")]
pub const ALL_METHODS: [MethodKind; 7] = [
    MethodKind::Uniform,
    MethodKind::Dlp,
    MethodKind::Farms,
    MethodKind::Strs,
    MethodKind::Ars,
    MethodKind::Dobi,
    MethodKind::Ara,
];

#[allow(deprecated)]
impl MethodKind {
    pub fn name(&self) -> &'static str {
        match self {
            MethodKind::Uniform => "Uniform",
            MethodKind::Dlp => "DLP",
            MethodKind::Farms => "FARMS",
            MethodKind::Strs => "STRS",
            MethodKind::Ars => "ARS",
            MethodKind::Dobi => "Dobi-SVD1",
            MethodKind::Ara => "ARA",
            MethodKind::AraNoGuidance => "ARA(noLg)",
        }
    }

    /// The registry id this kind maps to (the shim's bridge).
    pub fn spec_id(&self) -> &'static str {
        match self {
            MethodKind::Uniform => "uniform",
            MethodKind::Dlp => "dlp",
            MethodKind::Farms => "farms",
            MethodKind::Strs => "strs",
            MethodKind::Ars => "ars",
            MethodKind::Dobi => "dobi",
            MethodKind::Ara => "ara",
            MethodKind::AraNoGuidance => "ara-nolg",
        }
    }
}
