//! Corpus generation: two domains standing in for the paper's WikiText-2 and
//! C4 (DESIGN.md §2) plus the batching used by training / calibration / PPL.

use super::{Grammar, Rng};
use crate::tensor::IntTensor;

/// Seeds: train stream and eval stream are disjoint but same-distribution.
pub const TRAIN_SEED: u64 = 1001;
pub const EVAL_SEED: u64 = 9009;

/// A corpus domain: topic count + noise rate over the shared grammar.
#[derive(Debug, Clone, Copy)]
pub struct CorpusSpec {
    pub name: &'static str,
    pub n_topics: usize,
    pub noise: f64,
    /// Markov topic-switch probability between sentences.
    pub drift: f64,
}

/// `synwiki` — clean, few topics (WikiText-2 stand-in).
/// `sync4`   — broad, noisy (C4 stand-in).
pub fn corpus_spec(name: &str) -> CorpusSpec {
    match name {
        "synwiki" => CorpusSpec { name: "synwiki", n_topics: 4, noise: 0.02, drift: 0.1 },
        "sync4" => CorpusSpec { name: "sync4", n_topics: 8, noise: 0.18, drift: 0.3 },
        other => panic!("unknown corpus {other}"),
    }
}

/// Generate a token stream of exactly `len` tokens from the given domain.
///
/// The fact table is shared across domains (seeded only by vocab size) so a
/// model trained on one domain can answer fact queries in the other — the
/// same transfer the paper's zero-shot tasks measure.
pub fn generate_tokens(vocab_size: usize, spec: CorpusSpec, seed: u64, len: usize) -> Vec<i32> {
    let g = Grammar::new(vocab_size, spec.n_topics, spec.noise, 77);
    let mut rng = Rng::new(seed ^ (spec.name.len() as u64) << 32 ^ spec.n_topics as u64);
    let mut out = Vec::with_capacity(len + 16);
    let mut topic = rng.below(spec.n_topics);
    out.push(super::grammar::BOS);
    while out.len() < len {
        if rng.f64() < spec.drift {
            topic = rng.below(spec.n_topics);
        }
        g.sentence(&mut rng, topic, &mut out);
    }
    out.truncate(len);
    out
}

/// Cut a token stream into next-token-prediction batches of shape
/// `(batch, seq)`: tokens[i..i+seq] → targets tokens[i+1..i+seq+1].
pub fn batches(stream: &[i32], batch: usize, seq: usize) -> Vec<(IntTensor, IntTensor)> {
    let window = seq + 1;
    let n_windows = stream.len() / window;
    let n_batches = n_windows / batch;
    let mut out = Vec::with_capacity(n_batches);
    for b in 0..n_batches {
        let mut toks = Vec::with_capacity(batch * seq);
        let mut tgts = Vec::with_capacity(batch * seq);
        for s in 0..batch {
            let off = (b * batch + s) * window;
            toks.extend_from_slice(&stream[off..off + seq]);
            tgts.extend_from_slice(&stream[off + 1..off + seq + 1]);
        }
        out.push((
            IntTensor::from_vec(&[batch, seq], toks),
            IntTensor::from_vec(&[batch, seq], tgts),
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_deterministic_and_exact_len() {
        let spec = corpus_spec("synwiki");
        let a = generate_tokens(256, spec, 5, 1000);
        let b = generate_tokens(256, spec, 5, 1000);
        assert_eq!(a, b);
        assert_eq!(a.len(), 1000);
        let c = generate_tokens(256, spec, 6, 1000);
        assert_ne!(a, c);
    }

    #[test]
    fn domains_differ() {
        let a = generate_tokens(256, corpus_spec("synwiki"), 5, 2000);
        let b = generate_tokens(256, corpus_spec("sync4"), 5, 2000);
        assert_ne!(a, b);
        // sync4 should use a broader effective vocabulary (more noise/filler)
        let uniq = |v: &[i32]| {
            let mut s = v.to_vec();
            s.sort();
            s.dedup();
            s.len()
        };
        assert!(uniq(&b) >= uniq(&a));
    }

    #[test]
    fn batches_are_shifted_views() {
        let stream: Vec<i32> = (0..100).collect();
        let bs = batches(&stream, 2, 7);
        assert!(!bs.is_empty());
        for (toks, tgts) in &bs {
            assert_eq!(toks.shape, vec![2, 7]);
            for i in 0..toks.data.len() {
                assert_eq!(tgts.data[i], toks.data[i] + 1);
            }
        }
    }

    #[test]
    fn batches_disjoint_across_index() {
        let stream: Vec<i32> = (0..1000).collect();
        let bs = batches(&stream, 2, 9);
        let first_of = |b: &IntTensor| b.data[0];
        assert_ne!(first_of(&bs[0].0), first_of(&bs[1].0));
    }
}
