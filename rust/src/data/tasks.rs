//! The zero-shot task suite: seven synthetic multiple-choice tasks mirroring
//! the paper's ARC-e/ARC-c/HellaSwag/OBQA/WinoGrande/MathQA/PIQA in spirit —
//! each is answerable from corpus statistics, so accuracy is monotone in
//! model quality and the paper's *relative* method ordering reproduces.
//!
//! Scoring (eval/zeroshot.rs) follows LM-eval-harness: average per-token
//! log-prob of each choice continuation given the context; argmax wins.

use super::grammar::{Grammar, DIGIT0, EQ, PERIOD, PLUS, REL};
use super::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskKind {
    /// Choose a noun continuation (wrong classes as distractors).
    ArcEasy,
    /// Choose the same-topic noun among other-topic nouns (longer context).
    ArcChallenge,
    /// Choose the grammatical sentence continuation vs shuffled variants.
    Hella,
    /// Fact lookup: NAME REL → correct OBJ.
    Obqa,
    /// Number agreement: plural subject → plural verb form.
    Wino,
    /// Digit arithmetic: a + b = ?
    MathQa,
    /// Adjective-noun topical plausibility (2 choices).
    Piqa,
}

pub const ALL_TASKS: [TaskKind; 7] = [
    TaskKind::ArcEasy,
    TaskKind::ArcChallenge,
    TaskKind::Hella,
    TaskKind::Obqa,
    TaskKind::Wino,
    TaskKind::MathQa,
    TaskKind::Piqa,
];

pub fn task_names(kind: TaskKind) -> &'static str {
    match kind {
        TaskKind::ArcEasy => "ARC-e",
        TaskKind::ArcChallenge => "ARC-c",
        TaskKind::Hella => "Hella",
        TaskKind::Obqa => "OBQA",
        TaskKind::Wino => "Wino",
        TaskKind::MathQa => "MathQA",
        TaskKind::Piqa => "PIQA",
    }
}

/// One multiple-choice item.
#[derive(Debug, Clone)]
pub struct TaskItem {
    pub ctx: Vec<i32>,
    pub choices: Vec<Vec<i32>>,
    pub answer: usize,
}

/// Generate `n` items of a task over the grammar (seeded).
pub fn generate_task(kind: TaskKind, g: &Grammar, seed: u64, n: usize) -> Vec<TaskItem> {
    let mut rng = Rng::new(seed ^ (kind as u64).wrapping_mul(0xABCD_1234));
    (0..n).map(|_| item(kind, g, &mut rng)).collect()
}

fn shuffled_with_answer(rng: &mut Rng, correct: Vec<i32>, distractors: Vec<Vec<i32>>) -> (Vec<Vec<i32>>, usize) {
    let mut all = vec![correct];
    all.extend(distractors);
    let mut idx: Vec<usize> = (0..all.len()).collect();
    rng.shuffle(&mut idx);
    let answer = idx.iter().position(|&i| i == 0).unwrap();
    let choices = idx.into_iter().map(|i| all[i].clone()).collect();
    (choices, answer)
}

fn item(kind: TaskKind, g: &Grammar, rng: &mut Rng) -> TaskItem {
    let v = &g.vocab;
    match kind {
        TaskKind::ArcEasy => {
            // ctx: DET_sg NOUN_sg VERB_sg DET_sg → next should be a noun.
            let topic = rng.below(g.n_topics);
            let s = g.topic_word(rng, topic, v.n_nouns);
            let vb = g.topic_word(rng, topic, v.n_verbs);
            let o = g.topic_word(rng, topic, v.n_nouns);
            let ctx = vec![v.det_sg(0), v.noun_sg(s), v.verb_sg(vb), v.det_sg(0)];
            let correct = vec![v.noun_sg(o)];
            let distractors = vec![
                vec![v.verb_sg(g.topic_word(rng, topic, v.n_verbs))],
                vec![v.adj(g.topic_word(rng, topic, v.n_adjs))],
                vec![v.det_pl(rng.below(2))],
            ];
            let (choices, answer) = shuffled_with_answer(rng, correct, distractors);
            TaskItem { ctx, choices, answer }
        }
        TaskKind::ArcChallenge => {
            // same-class distractors from other topics; 2-sentence context.
            let topic = rng.below(g.n_topics);
            let mut ctx = vec![];
            g.sentence(rng, topic, &mut ctx);
            ctx.extend([v.det_sg(0), v.noun_sg(g.topic_word(rng, topic, v.n_nouns)),
                        v.verb_sg(g.topic_word(rng, topic, v.n_verbs)), v.det_sg(0)]);
            let correct = vec![v.noun_sg(g.topic_word(rng, topic, v.n_nouns))];
            let mut distractors = vec![];
            for k in 1..4 {
                let other = (topic + k) % g.n_topics.max(2);
                distractors.push(vec![v.noun_sg(g.topic_word(rng, other, v.n_nouns))]);
            }
            let (choices, answer) = shuffled_with_answer(rng, correct, distractors);
            TaskItem { ctx, choices, answer }
        }
        TaskKind::Hella => {
            // continuation: correct = DET NOUN VERB PERIOD; distractors are
            // ungrammatical permutations of the same tokens.
            let topic = rng.below(g.n_topics);
            let mut ctx = vec![];
            g.sentence(rng, topic, &mut ctx);
            g.sentence(rng, topic, &mut ctx);
            let det = v.det_sg(rng.below(2));
            let noun = v.noun_sg(g.topic_word(rng, topic, v.n_nouns));
            let verb = v.verb_sg(g.topic_word(rng, topic, v.n_verbs));
            let correct = vec![det, noun, verb, PERIOD];
            let distractors = vec![
                vec![verb, det, noun, PERIOD],
                vec![noun, verb, det, PERIOD],
                vec![verb, noun, det, PERIOD],
            ];
            let (choices, answer) = shuffled_with_answer(rng, correct, distractors);
            TaskItem { ctx, choices, answer }
        }
        TaskKind::Obqa => {
            let i = rng.below(v.n_names);
            let ctx = vec![v.name(i), REL];
            let correct = vec![v.obj(g.facts[i])];
            let mut distractors = vec![];
            let mut used = vec![g.facts[i]];
            while distractors.len() < 3 {
                let o = rng.below(v.n_objs);
                if !used.contains(&o) {
                    used.push(o);
                    distractors.push(vec![v.obj(o)]);
                }
                if v.n_objs <= 4 {
                    break;
                }
            }
            let (choices, answer) = shuffled_with_answer(rng, correct, distractors);
            TaskItem { ctx, choices, answer }
        }
        TaskKind::Wino => {
            let topic = rng.below(g.n_topics);
            let s = g.topic_word(rng, topic, v.n_nouns);
            let vb = g.topic_word(rng, topic, v.n_verbs);
            let plural = rng.f64() < 0.5;
            let (ctx, correct, wrong) = if plural {
                (vec![v.det_pl(0), v.noun_pl(s)], vec![v.verb_pl(vb)], vec![v.verb_sg(vb)])
            } else {
                (vec![v.det_sg(0), v.noun_sg(s)], vec![v.verb_sg(vb)], vec![v.verb_pl(vb)])
            };
            let (choices, answer) = shuffled_with_answer(rng, correct, vec![wrong]);
            TaskItem { ctx, choices, answer }
        }
        TaskKind::MathQa => {
            let a = rng.below(10);
            let b = rng.below(10);
            let ctx = vec![DIGIT0 + a as i32, PLUS, DIGIT0 + b as i32, EQ];
            let correct = vec![v.digit((a + b) % 10)];
            let mut distractors = vec![];
            let mut used = vec![(a + b) % 10];
            while distractors.len() < 3 {
                let d = rng.below(10);
                if !used.contains(&d) {
                    used.push(d);
                    distractors.push(vec![v.digit(d)]);
                }
            }
            let (choices, answer) = shuffled_with_answer(rng, correct, distractors);
            TaskItem { ctx, choices, answer }
        }
        TaskKind::Piqa => {
            let topic = rng.below(g.n_topics);
            let a = g.topic_word(rng, topic, v.n_adjs);
            let ctx = vec![v.det_sg(0), v.adj(a)];
            let correct = vec![v.noun_sg(g.topic_word(rng, topic, v.n_nouns))];
            let other = (topic + 1 + rng.below(g.n_topics.max(2) - 1)) % g.n_topics.max(2);
            let distractor = vec![v.noun_sg(g.topic_word(rng, other, v.n_nouns))];
            let (choices, answer) = shuffled_with_answer(rng, correct, vec![distractor]);
            TaskItem { ctx, choices, answer }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grammar() -> Grammar {
        Grammar::new(256, 4, 0.0, 77)
    }

    #[test]
    fn all_tasks_generate_valid_items() {
        let g = grammar();
        for kind in ALL_TASKS {
            let items = generate_task(kind, &g, 9, 40);
            assert_eq!(items.len(), 40);
            for it in &items {
                assert!(it.answer < it.choices.len());
                assert!(it.choices.len() >= 2);
                assert!(!it.ctx.is_empty());
                for ch in &it.choices {
                    assert!(!ch.is_empty());
                    for &t in ch.iter().chain(it.ctx.iter()) {
                        assert!((t as usize) < g.vocab.size);
                    }
                }
            }
        }
    }

    #[test]
    fn answers_not_constant() {
        // shuffling must distribute the answer position
        let g = grammar();
        for kind in ALL_TASKS {
            let items = generate_task(kind, &g, 3, 60);
            let first = items[0].answer;
            assert!(items.iter().any(|i| i.answer != first), "{kind:?}");
        }
    }

    #[test]
    fn obqa_answer_matches_fact_table() {
        let g = grammar();
        let items = generate_task(TaskKind::Obqa, &g, 5, 50);
        for it in &items {
            let name_tok = it.ctx[0];
            let i = (0..g.vocab.n_names)
                .find(|&i| g.vocab.name(i) == name_tok)
                .unwrap();
            assert_eq!(it.choices[it.answer], vec![g.vocab.obj(g.facts[i])]);
        }
    }

    #[test]
    fn mathqa_answer_is_mod10_sum() {
        let g = grammar();
        for it in generate_task(TaskKind::MathQa, &g, 6, 50) {
            let a = it.ctx[0] - DIGIT0;
            let b = it.ctx[2] - DIGIT0;
            assert_eq!(it.choices[it.answer][0], DIGIT0 + (a + b) % 10);
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let g = grammar();
        let a = generate_task(TaskKind::Hella, &g, 11, 10);
        let b = generate_task(TaskKind::Hella, &g, 11, 10);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.ctx, y.ctx);
            assert_eq!(x.answer, y.answer);
        }
    }
}
