//! Synthetic data substrate: corpora standing in for WikiText-2 / C4 and a
//! seven-task zero-shot suite standing in for the paper's LM-eval-harness
//! benchmarks (DESIGN.md §2 documents the substitution).
//!
//! Everything is deterministic given a seed, so every experiment in
//! EXPERIMENTS.md reproduces bit-for-bit.

mod corpus;
pub(crate) mod grammar;
mod rng;
mod tasks;

pub use corpus::{batches, corpus_spec, generate_tokens, CorpusSpec, EVAL_SEED, TRAIN_SEED};
pub use grammar::{Grammar, Vocab, BOS as BOS_TOKEN, PERIOD as PERIOD_TOKEN};
pub use rng::Rng;
pub use tasks::{generate_task, task_names, TaskItem, TaskKind, ALL_TASKS};
