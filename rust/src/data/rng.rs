//! Small deterministic PRNG (xorshift64*) — no external crates, stable
//! across platforms, seedable per experiment stage.

#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        Rng { state: seed.wrapping_mul(0x9E3779B97F4A7C15) | 1 }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Zipf-ish rank sampler over [0, n): P(k) ∝ 1/(k+2), cheap inverse-CDF.
    pub fn zipf(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // rejection-free: sample u, walk harmonic cdf lazily for small n
        let h: f64 = (0..n).map(|k| 1.0 / (k + 2) as f64).sum();
        let mut u = self.f64() * h;
        for k in 0..n {
            u -= 1.0 / (k + 2) as f64;
            if u <= 0.0 {
                return k;
            }
        }
        n - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(2);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn zipf_prefers_low_ranks() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 8];
        for _ in 0..8000 {
            counts[r.zipf(8)] += 1;
        }
        assert!(counts[0] > counts[7]);
        assert!(counts[0] > counts[3]);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(4);
        let n = 20000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }
}
