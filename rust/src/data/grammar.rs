//! The synthetic language: a topic-structured probabilistic grammar with
//! number agreement, embedded facts, and digit arithmetic. Rich enough that
//! (a) a small LM trained on it shows the qualitative singular-value
//! structure the paper exploits, and (b) the seven zero-shot tasks
//! (tasks.rs) are answerable from corpus statistics.

use super::Rng;

/// Partition of the token id space into word classes.
///
/// Layout (for vocab size V):
///   0 BOS · 1 PERIOD · 2..12 digits · 12 PLUS · 13 EQ · 14 REL
///   then DET_SG ×2, DET_PL ×2, then nouns (sg+pl paired), verbs (sg+pl
///   paired), adjectives, names, objects, and filler/noise.
#[derive(Debug, Clone)]
pub struct Vocab {
    pub size: usize,
    pub n_nouns: usize,
    pub n_verbs: usize,
    pub n_adjs: usize,
    pub n_names: usize,
    pub n_objs: usize,
    noun_sg0: usize,
    noun_pl0: usize,
    verb_sg0: usize,
    verb_pl0: usize,
    adj0: usize,
    name0: usize,
    obj0: usize,
    filler0: usize,
}

pub const BOS: i32 = 0;
pub const PERIOD: i32 = 1;
pub const DIGIT0: i32 = 2;
pub const PLUS: i32 = 12;
pub const EQ: i32 = 13;
pub const REL: i32 = 14; // the "has/is-linked-to" relation verb for facts
const DET_SG0: usize = 15; // 2 singular determiners
const DET_PL0: usize = 17; // 2 plural determiners
const CLASSES0: usize = 19;

impl Vocab {
    pub fn new(size: usize) -> Vocab {
        assert!(size >= 64, "vocab too small for the grammar");
        let free = size - CLASSES0;
        let n_nouns = free * 20 / 100 / 2; // sg+pl pairs
        let n_verbs = free * 14 / 100 / 2;
        let n_adjs = free * 12 / 100;
        let n_names = free * 18 / 100;
        let n_objs = free * 18 / 100;
        let noun_sg0 = CLASSES0;
        let noun_pl0 = noun_sg0 + n_nouns;
        let verb_sg0 = noun_pl0 + n_nouns;
        let verb_pl0 = verb_sg0 + n_verbs;
        let adj0 = verb_pl0 + n_verbs;
        let name0 = adj0 + n_adjs;
        let obj0 = name0 + n_names;
        let filler0 = obj0 + n_objs;
        Vocab {
            size,
            n_nouns,
            n_verbs,
            n_adjs,
            n_names,
            n_objs,
            noun_sg0,
            noun_pl0,
            verb_sg0,
            verb_pl0,
            adj0,
            name0,
            obj0,
            filler0,
        }
    }

    pub fn noun_sg(&self, i: usize) -> i32 {
        (self.noun_sg0 + i % self.n_nouns) as i32
    }
    pub fn noun_pl(&self, i: usize) -> i32 {
        (self.noun_pl0 + i % self.n_nouns) as i32
    }
    pub fn verb_sg(&self, i: usize) -> i32 {
        (self.verb_sg0 + i % self.n_verbs) as i32
    }
    pub fn verb_pl(&self, i: usize) -> i32 {
        (self.verb_pl0 + i % self.n_verbs) as i32
    }
    pub fn adj(&self, i: usize) -> i32 {
        (self.adj0 + i % self.n_adjs) as i32
    }
    pub fn name(&self, i: usize) -> i32 {
        (self.name0 + i % self.n_names) as i32
    }
    pub fn obj(&self, i: usize) -> i32 {
        (self.obj0 + i % self.n_objs) as i32
    }
    pub fn det_sg(&self, i: usize) -> i32 {
        (DET_SG0 + i % 2) as i32
    }
    pub fn det_pl(&self, i: usize) -> i32 {
        (DET_PL0 + i % 2) as i32
    }
    pub fn digit(&self, d: usize) -> i32 {
        DIGIT0 + (d % 10) as i32
    }
    pub fn filler(&self, rng: &mut Rng) -> i32 {
        if self.filler0 >= self.size {
            self.noun_sg(rng.below(self.n_nouns))
        } else {
            (self.filler0 + rng.below(self.size - self.filler0)) as i32
        }
    }

    /// Is `t` a singular noun token?
    pub fn is_noun_sg(&self, t: i32) -> bool {
        (t as usize) >= self.noun_sg0 && (t as usize) < self.noun_pl0
    }
}

/// Grammar = vocab + topic structure + fact table.
#[derive(Debug, Clone)]
pub struct Grammar {
    pub vocab: Vocab,
    pub n_topics: usize,
    pub noise: f64,
    /// facts[i] = object index associated with name i (the OBQA knowledge).
    pub facts: Vec<usize>,
}

impl Grammar {
    pub fn new(vocab_size: usize, n_topics: usize, noise: f64, seed: u64) -> Grammar {
        let vocab = Vocab::new(vocab_size);
        let mut rng = Rng::new(seed ^ 0xFAC7);
        let facts = (0..vocab.n_names).map(|_| rng.below(vocab.n_objs)).collect();
        Grammar { vocab, n_topics, noise, facts }
    }

    /// Topic-local index helper: topic t draws word indices from the slice
    /// [t·cls/T, (t+1)·cls/T) of its class, with Zipf weighting inside.
    pub fn topic_word(&self, rng: &mut Rng, topic: usize, class_size: usize) -> usize {
        let per = (class_size / self.n_topics).max(1);
        let base = (topic * per) % class_size;
        (base + rng.zipf(per)) % class_size
    }

    /// Emit one sentence for `topic` into `out`. Template mix:
    /// 50% agreement statement, 28% fact, 10% arithmetic, 12% adjective
    /// statement. Noise tokens are injected with prob `self.noise`.
    pub fn sentence(&self, rng: &mut Rng, topic: usize, out: &mut Vec<i32>) {
        let v = &self.vocab;
        let roll = rng.f64();
        if roll < 0.50 {
            // DET NOUN VERB DET NOUN .   with number agreement on subject
            let plural = rng.f64() < 0.4;
            let s = self.topic_word(rng, topic, v.n_nouns);
            let vb = self.topic_word(rng, topic, v.n_verbs);
            let o = self.topic_word(rng, topic, v.n_nouns);
            if plural {
                out.push(v.det_pl(rng.below(2)));
                out.push(v.noun_pl(s));
                out.push(v.verb_pl(vb));
            } else {
                out.push(v.det_sg(rng.below(2)));
                out.push(v.noun_sg(s));
                out.push(v.verb_sg(vb));
            }
            out.push(v.det_sg(rng.below(2)));
            out.push(v.noun_sg(o));
        } else if roll < 0.78 {
            // NAME REL OBJ .   (the fact table — the knowledge load)
            let i = self.topic_word(rng, topic, v.n_names);
            out.push(v.name(i));
            out.push(REL);
            out.push(v.obj(self.facts[i]));
        } else if roll < 0.88 {
            // DIG + DIG = DIG .
            let a = rng.below(10);
            let b = rng.below(10);
            out.push(v.digit(a));
            out.push(PLUS);
            out.push(v.digit(b));
            out.push(EQ);
            out.push(v.digit((a + b) % 10));
        } else {
            // DET ADJ NOUN VERB .  — adjective co-occurs with same-topic noun
            let plural = rng.f64() < 0.3;
            let a = self.topic_word(rng, topic, v.n_adjs);
            let s = self.topic_word(rng, topic, v.n_nouns);
            let vb = self.topic_word(rng, topic, v.n_verbs);
            if plural {
                out.push(v.det_pl(rng.below(2)));
                out.push(v.adj(a));
                out.push(v.noun_pl(s));
                out.push(v.verb_pl(vb));
            } else {
                out.push(v.det_sg(rng.below(2)));
                out.push(v.adj(a));
                out.push(v.noun_sg(s));
                out.push(v.verb_sg(vb));
            }
        }
        if self.noise > 0.0 && rng.f64() < self.noise {
            out.push(v.filler(rng));
        }
        out.push(PERIOD);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vocab_partition_fits() {
        for size in [64, 256, 1024, 2048] {
            let v = Vocab::new(size);
            assert!(v.filler0 <= v.size);
            assert!(v.n_nouns > 0 && v.n_verbs > 0 && v.n_adjs > 0);
            // classes must not overlap: check boundary tokens
            assert!(v.noun_pl(v.n_nouns - 1) < v.verb_sg(0));
            assert!(v.verb_pl(v.n_verbs - 1) < v.adj(0));
            assert!(v.adj(v.n_adjs - 1) < v.name(0));
            assert!(v.name(v.n_names - 1) < v.obj(0));
        }
    }

    #[test]
    fn sentences_in_vocab_range_and_end_with_period() {
        let g = Grammar::new(256, 4, 0.1, 1);
        let mut rng = Rng::new(2);
        for topic in 0..4 {
            for _ in 0..200 {
                let mut s = vec![];
                g.sentence(&mut rng, topic, &mut s);
                assert_eq!(*s.last().unwrap(), PERIOD);
                for &t in &s {
                    assert!((t as usize) < g.vocab.size, "token {t} out of range");
                }
            }
        }
    }

    #[test]
    fn arithmetic_is_consistent() {
        let g = Grammar::new(256, 2, 0.0, 3);
        let mut rng = Rng::new(5);
        let mut checked = 0;
        for _ in 0..2000 {
            let mut s = vec![];
            g.sentence(&mut rng, 0, &mut s);
            if s.len() >= 6 && s[1] == PLUS {
                let a = (s[0] - DIGIT0) as usize;
                let b = (s[2] - DIGIT0) as usize;
                assert_eq!(s[3], EQ);
                assert_eq!(s[4], g.vocab.digit((a + b) % 10));
                checked += 1;
            }
        }
        assert!(checked > 50, "arithmetic template rarely sampled");
    }

    #[test]
    fn facts_are_stable_per_seed() {
        let g1 = Grammar::new(512, 4, 0.0, 42);
        let g2 = Grammar::new(512, 4, 0.0, 42);
        assert_eq!(g1.facts, g2.facts);
        let g3 = Grammar::new(512, 4, 0.0, 43);
        assert_ne!(g1.facts, g3.facts);
    }
}
