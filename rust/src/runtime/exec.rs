//! Backend-neutral execution types: named feeds, named outputs, the
//! [`Executable`] trait every backend implements, and the [`DeviceBuffer`]
//! handle used by the serving hot path to keep weights and KV caches
//! resident on the executing device between steps.
//!
//! Inputs are always bound **by name** through the artifact manifest —
//! never by guessed position.

use std::collections::HashMap;

use super::manifest::Manifest;
use crate::quant::PackedInt8;
use crate::tensor::{IntTensor, Tensor};
use crate::Result;

/// A named input: host tensors borrowed from the caller.
pub enum Feed<'a> {
    F32(&'a Tensor),
    I32(&'a IntTensor),
    /// Packed int8 weights (quantized serving path) — weights only, never
    /// activations.
    Q8(&'a PackedInt8),
}

impl Feed<'_> {
    pub fn shape(&self) -> &[usize] {
        match self {
            Feed::F32(t) => &t.shape,
            Feed::I32(t) => &t.shape,
            Feed::Q8(t) => &t.shape,
        }
    }

    pub fn dtype_name(&self) -> &'static str {
        match self {
            Feed::F32(_) => "f32",
            Feed::I32(_) => "i32",
            Feed::Q8(_) => "q8",
        }
    }
}

/// An owned runtime value (host memory).
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    F32(Tensor),
    I32(IntTensor),
    /// Packed int8 weights; stays packed through upload and execution.
    Q8(PackedInt8),
}

impl Value {
    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32(t) => &t.shape,
            Value::I32(t) => &t.shape,
            Value::Q8(t) => &t.shape,
        }
    }

    /// View as an f32 tensor, converting i32 values (mirrors how the PJRT
    /// path converts S32 output literals) and dequantizing packed int8.
    pub fn to_f32_tensor(&self) -> Tensor {
        match self {
            Value::F32(t) => t.clone(),
            Value::I32(t) => Tensor::from_vec(
                &t.shape,
                t.data.iter().map(|&x| x as f32).collect(),
            ),
            Value::Q8(t) => t.dequant(),
        }
    }

    pub fn as_feed(&self) -> Feed<'_> {
        match self {
            Value::F32(t) => Feed::F32(t),
            Value::I32(t) => Feed::I32(t),
            Value::Q8(t) => Feed::Q8(t),
        }
    }
}

/// A backend-owned device-resident value. On the default CPU backend
/// "device" memory *is* host memory, so this wraps a [`Value`] directly —
/// no copies, no tuple splitting. The PJRT variant wraps a real device
/// buffer handle.
pub enum DeviceBuffer {
    Host(Value),
    #[cfg(feature = "pjrt")]
    Pjrt(xla::PjRtBuffer),
}

/// One device argument for [`Executable::run_device_args`]: borrowed for
/// buffers the caller retains across calls (weights), owned for per-step
/// buffers the backend may consume or mutate in place (KV caches, tokens).
pub enum DeviceArg<'a> {
    Ref(&'a DeviceBuffer),
    Own(DeviceBuffer),
}

impl DeviceArg<'_> {
    pub fn buffer(&self) -> &DeviceBuffer {
        match self {
            DeviceArg::Ref(b) => b,
            DeviceArg::Own(b) => b,
        }
    }
}

/// Named outputs of one execution (host values).
pub struct Outputs {
    pub(crate) names: Vec<String>,
    pub(crate) values: Vec<Value>,
}

impl Outputs {
    pub fn new(names: Vec<String>, values: Vec<Value>) -> Outputs {
        Outputs { names, values }
    }

    pub fn tensor(&self, name: &str) -> Result<Tensor> {
        let idx = self
            .names
            .iter()
            .position(|n| n == name)
            .ok_or_else(|| crate::anyhow!("no output named {name}"))?;
        Ok(self.values[idx].to_f32_tensor())
    }

    /// Scalar output accessor; errors (instead of panicking) when the
    /// output tensor is empty.
    pub fn scalar(&self, name: &str) -> Result<f32> {
        let t = self.tensor(name)?;
        t.data.first().copied().ok_or_else(|| {
            crate::anyhow!("output `{name}` is empty (shape {:?}), no scalar to read", t.shape)
        })
    }

    pub fn names(&self) -> &[String] {
        &self.names
    }
}

/// One loaded artifact on some backend: name-bound host execution plus the
/// device-resident path used by the serving engine.
pub trait Executable {
    /// The artifact's input/output contract.
    fn manifest(&self) -> &Manifest;

    /// Execute with host tensors, binding inputs by manifest name.
    fn run(&self, feeds: &HashMap<&str, Feed>) -> Result<Outputs>;

    /// Execute with device-resident buffers supplied in manifest input
    /// order. Returns exactly one buffer per manifest output (backends
    /// normalize tuple-rooted results internally); outputs stay on device.
    fn run_device(&self, args: &[&DeviceBuffer]) -> Result<Vec<DeviceBuffer>>;

    /// Like [`Executable::run_device`], but arguments may be passed owned
    /// so the backend can recycle their storage in place (the CPU
    /// interpreter mutates owned KV caches instead of cloning them).
    /// Defaults to borrowing everything, which every backend supports.
    fn run_device_args(&self, args: Vec<DeviceArg>) -> Result<Vec<DeviceBuffer>> {
        let refs: Vec<&DeviceBuffer> = args.iter().map(|a| a.buffer()).collect();
        self.run_device(&refs)
    }
}

/// The concrete executable handle call sites hold (`Rc<Exe>`): a thin
/// wrapper over a backend's [`Executable`] with inherent forwarding
/// methods, so consumers never depend on having the trait in scope.
pub struct Exe {
    inner: Box<dyn Executable>,
}

impl Exe {
    pub fn new(inner: Box<dyn Executable>) -> Exe {
        Exe { inner }
    }

    /// The artifact's input/output contract.
    pub fn manifest(&self) -> &Manifest {
        self.inner.manifest()
    }

    /// Execute with host tensors, binding inputs by manifest name.
    pub fn run(&self, feeds: &HashMap<&str, Feed>) -> Result<Outputs> {
        self.inner.run(feeds)
    }

    /// Execute with device-resident buffers in manifest input order.
    pub fn run_device(&self, args: &[&DeviceBuffer]) -> Result<Vec<DeviceBuffer>> {
        self.inner.run_device(args)
    }

    /// Execute with mixed borrowed/owned device buffers; owned buffers may
    /// be consumed and recycled in place by the backend.
    pub fn run_device_args(&self, args: Vec<DeviceArg>) -> Result<Vec<DeviceBuffer>> {
        self.inner.run_device_args(args)
    }
}

/// Validate a feed against a manifest spec (shared by backends).
pub fn check_feed(feed: &Feed, spec: &super::manifest::TensorSpec) -> Result<()> {
    if feed.shape() != spec.shape.as_slice() {
        return Err(crate::anyhow!(
            "input {}: shape {:?} != manifest {:?}",
            spec.name,
            feed.shape(),
            spec.shape
        ));
    }
    if feed.dtype_name() != spec.dtype {
        return Err(crate::anyhow!(
            "input {}: dtype {} != manifest {}",
            spec.name,
            feed.dtype_name(),
            spec.dtype
        ));
    }
    Ok(())
}
