//! Executable wrapper: name-bound execution of a compiled artifact, with
//! both a literal path (convenient, copies host↔device each call) and a
//! device-resident buffer path (`run_buffers`) used by the serving engine to
//! keep weights and KV caches on device across decode steps.

use std::collections::HashMap;

use super::manifest::Manifest;
use crate::tensor::{IntTensor, Tensor};
use crate::Result;

/// One compiled artifact + its manifest.
pub struct Exe {
    pub exe: xla::PjRtLoadedExecutable,
    pub manifest: Manifest,
}

/// A named input: host tensors borrowed from the caller.
pub enum Feed<'a> {
    F32(&'a Tensor),
    I32(&'a IntTensor),
}

/// Named outputs of one execution (host literals).
pub struct Outputs {
    names: Vec<String>,
    literals: Vec<xla::Literal>,
}

impl Outputs {
    pub fn tensor(&self, name: &str) -> Result<Tensor> {
        let idx = self
            .names
            .iter()
            .position(|n| n == name)
            .ok_or_else(|| crate::anyhow!("no output named {name}"))?;
        literal_to_tensor(&self.literals[idx])
    }

    pub fn scalar(&self, name: &str) -> Result<f32> {
        let t = self.tensor(name)?;
        Ok(t.data[0])
    }

    pub fn names(&self) -> &[String] {
        &self.names
    }
}

impl Exe {
    /// Execute with host tensors, binding inputs by manifest name.
    pub fn run(&self, feeds: &HashMap<&str, Feed>) -> Result<Outputs> {
        let mut args: Vec<xla::Literal> = Vec::with_capacity(self.manifest.inputs.len());
        for spec in &self.manifest.inputs {
            let feed = feeds.get(spec.name.as_str()).ok_or_else(|| {
                crate::anyhow!("missing input `{}` for {}", spec.name, self.manifest.name)
            })?;
            args.push(feed_to_literal(feed, &spec.shape, &spec.dtype, &spec.name)?);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&args)
            .map_err(|e| crate::anyhow!("execute {}: {e}", self.manifest.name))?;
        let replica = &result[0];
        let expected = self.manifest.outputs.len();
        // PJRT either untuples multi-output roots into separate buffers or
        // hands back one tuple buffer; accept both.
        let literals: Vec<xla::Literal> = if replica.len() == expected {
            let mut v = Vec::with_capacity(expected);
            for b in replica {
                v.push(b.to_literal_sync().map_err(|e| crate::anyhow!("fetch: {e}"))?);
            }
            v
        } else if replica.len() == 1 {
            let lit = replica[0]
                .to_literal_sync()
                .map_err(|e| crate::anyhow!("fetch: {e}"))?;
            if expected == 1 {
                vec![lit]
            } else {
                lit.to_tuple().map_err(|e| crate::anyhow!("untuple: {e}"))?
            }
        } else {
            return Err(crate::anyhow!(
                "{}: expected {} outputs, got {} buffers",
                self.manifest.name,
                expected,
                replica.len()
            ));
        };
        if literals.len() != expected {
            return Err(crate::anyhow!(
                "{}: expected {} outputs, got {}",
                self.manifest.name,
                expected,
                literals.len()
            ));
        }
        Ok(Outputs { names: self.manifest.outputs.clone(), literals })
    }

    /// Execute with device-resident buffers (serving hot path). The caller
    /// supplies borrowed buffers in manifest order; outputs stay on device.
    pub fn run_buffers_ref(&self, args: &[&xla::PjRtBuffer]) -> Result<Vec<xla::PjRtBuffer>> {
        if args.len() != self.manifest.inputs.len() {
            return Err(crate::anyhow!(
                "{}: expected {} buffer args, got {}",
                self.manifest.name,
                self.manifest.inputs.len(),
                args.len()
            ));
        }
        let mut result = self
            .exe
            .execute_b::<&xla::PjRtBuffer>(args)
            .map_err(|e| crate::anyhow!("execute_b {}: {e}", self.manifest.name))?;
        Ok(result.swap_remove(0))
    }
}

fn feed_to_literal(feed: &Feed, shape: &[usize], dtype: &str, name: &str) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    match (feed, dtype) {
        (Feed::F32(t), "f32") => {
            if t.shape != shape {
                return Err(crate::anyhow!(
                    "input {name}: shape {:?} != manifest {:?}",
                    t.shape,
                    shape
                ));
            }
            xla::Literal::vec1(&t.data)
                .reshape(&dims)
                .map_err(|e| crate::anyhow!("reshape {name}: {e}"))
        }
        (Feed::I32(t), "i32") => {
            if t.shape != shape {
                return Err(crate::anyhow!(
                    "input {name}: shape {:?} != manifest {:?}",
                    t.shape,
                    shape
                ));
            }
            xla::Literal::vec1(&t.data)
                .reshape(&dims)
                .map_err(|e| crate::anyhow!("reshape {name}: {e}"))
        }
        _ => Err(crate::anyhow!("input {name}: dtype mismatch (manifest {dtype})")),
    }
}

/// Convert a host literal to a Tensor (f32; i32 outputs are converted).
pub fn literal_to_tensor(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape().map_err(|e| crate::anyhow!("shape: {e}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let ty = lit.ty().map_err(|e| crate::anyhow!("ty: {e}"))?;
    let data: Vec<f32> = match ty {
        xla::ElementType::F32 => lit.to_vec::<f32>().map_err(|e| crate::anyhow!("{e}"))?,
        xla::ElementType::S32 => lit
            .to_vec::<i32>()
            .map_err(|e| crate::anyhow!("{e}"))?
            .into_iter()
            .map(|x| x as f32)
            .collect(),
        other => return Err(crate::anyhow!("unsupported output dtype {other:?}")),
    };
    Ok(Tensor::from_vec(&dims, data))
}

/// Normalize executable outputs to one device buffer per manifest output.
///
/// This build's XLA wrapper always tuples multi-output roots into a single
/// buffer; on the CPU plugin "device" memory is host memory, so the
/// decompose + re-upload is a memcpy, not a transfer (measured in §Perf).
pub fn split_output_buffers(
    client: &xla::PjRtClient,
    outs: Vec<xla::PjRtBuffer>,
    expected: usize,
) -> Result<Vec<xla::PjRtBuffer>> {
    if outs.len() == expected {
        return Ok(outs);
    }
    if outs.len() == 1 && expected > 1 {
        let lit = outs[0]
            .to_literal_sync()
            .map_err(|e| crate::anyhow!("fetch tuple: {e}"))?;
        let parts = lit.to_tuple().map_err(|e| crate::anyhow!("untuple: {e}"))?;
        if parts.len() != expected {
            return Err(crate::anyhow!("tuple arity {} != {expected}", parts.len()));
        }
        // buffer_from_host_literal is an async transfer with no await in
        // this wrapper (UAF once the literal drops); go through the
        // synchronous-copy host-buffer path instead.
        return parts
            .into_iter()
            .map(|p| {
                let t = literal_to_tensor(&p)?;
                feed_to_buffer(client, &Feed::F32(&t))
            })
            .collect();
    }
    Err(crate::anyhow!("got {} output buffers, expected {expected}", outs.len()))
}

/// Upload a host feed to a device buffer (serving setup path).
pub fn feed_to_buffer(
    client: &xla::PjRtClient,
    feed: &Feed,
) -> Result<xla::PjRtBuffer> {
    match feed {
        Feed::F32(t) => client
            .buffer_from_host_buffer(&t.data, &t.shape, None)
            .map_err(|e| crate::anyhow!("upload: {e}")),
        Feed::I32(t) => client
            .buffer_from_host_buffer(&t.data, &t.shape, None)
            .map_err(|e| crate::anyhow!("upload: {e}")),
    }
}

/// Download a device buffer to a host Tensor.
pub fn buffer_to_tensor(buf: &xla::PjRtBuffer) -> Result<Tensor> {
    let lit = buf.to_literal_sync().map_err(|e| crate::anyhow!("fetch: {e}"))?;
    literal_to_tensor(&lit)
}
