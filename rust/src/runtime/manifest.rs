//! Artifact manifests: the name/shape/dtype contract between the python AOT
//! exporter and the rust runtime.

use std::path::Path;

use crate::json::parse;
use crate::Result;

#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String, // "f32" | "i32" | "q8" (packed int8 weights)
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub name: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<String>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| crate::anyhow!("read {path:?}: {e}"))?;
        let j = parse(&text)?;
        let inputs = j
            .req("inputs")?
            .as_arr()?
            .iter()
            .map(|i| {
                Ok(TensorSpec {
                    name: i.req("name")?.as_str()?.to_string(),
                    shape: i
                        .req("shape")?
                        .as_arr()?
                        .iter()
                        .map(|d| d.as_usize())
                        .collect::<Result<Vec<_>>>()?,
                    dtype: i.req("dtype")?.as_str()?.to_string(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let outputs = j
            .req("outputs")?
            .as_arr()?
            .iter()
            .map(|o| Ok(o.as_str()?.to_string()))
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest { name: j.req("name")?.as_str()?.to_string(), inputs, outputs })
    }

    pub fn input(&self, name: &str) -> Option<&TensorSpec> {
        self.inputs.iter().find(|s| s.name == name)
    }

    pub fn output_index(&self, name: &str) -> Option<usize> {
        self.outputs.iter().position(|o| o == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Paths;
    use crate::model::{aux_param_shapes, module_dims};

    /// Golden-file parse: the exact JSON shape `aot.py:export` writes.
    #[test]
    fn loads_golden_manifest_file() {
        let dir = std::env::temp_dir().join("ara_manifest_golden");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("toy.manifest.json");
        std::fs::write(
            &path,
            "{\n \"name\": \"toy\",\n \"inputs\": [\n  {\n   \"name\": \"w\",\n   \"shape\": [4, 2],\n   \"dtype\": \"f32\"\n  },\n  {\n   \"name\": \"tokens\",\n   \"shape\": [1, 8],\n   \"dtype\": \"i32\"\n  }\n ],\n \"outputs\": [\"loss\", \"grad:w\"]\n}",
        )
        .unwrap();
        let man = Manifest::load(&path).unwrap();
        assert_eq!(man.name, "toy");
        assert_eq!(man.inputs.len(), 2);
        assert_eq!(man.input("w").unwrap().shape, vec![4, 2]);
        assert_eq!(man.input("tokens").unwrap().dtype, "i32");
        assert!(man.input("nope").is_none());
        assert_eq!(man.output_index("grad:w"), Some(1));
        assert_eq!(man.output_index("nope"), None);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_rejects_malformed_manifests() {
        let dir = std::env::temp_dir().join("ara_manifest_bad");
        std::fs::create_dir_all(&dir).unwrap();
        for (fname, text) in [
            ("no_inputs.json", r#"{"name": "x", "outputs": []}"#),
            ("bad_shape.json", r#"{"name": "x", "inputs": [{"name": "a", "shape": [1.5], "dtype": "f32"}], "outputs": []}"#),
            ("not_json.json", "not json at all"),
        ] {
            let p = dir.join(fname);
            std::fs::write(&p, text).unwrap();
            assert!(Manifest::load(&p).is_err(), "{fname} should fail");
        }
        assert!(Manifest::load(&dir.join("missing.json")).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Cross-check: the rust topology must match the python-exported
    /// manifest exactly (names AND shapes) — this is the contract test that
    /// catches any drift between model/topology.rs and compile/model.py.
    #[test]
    fn topology_matches_aot_manifest() {
        let paths = Paths::discover().unwrap();
        let man_path = paths
            .artifact_dir("micro-llama")
            .join("train_step.manifest.json");
        if !man_path.exists() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        }
        let man = Manifest::load(&man_path).unwrap();
        let cfg = crate::config::model_by_name(&paths.configs, "micro-llama").unwrap();

        for (name, shape) in aux_param_shapes(&cfg) {
            let spec = man.input(&name).unwrap_or_else(|| panic!("missing {name}"));
            assert_eq!(spec.shape, shape, "{name}");
            assert_eq!(spec.dtype, "f32");
        }
        for d in module_dims(&cfg) {
            let spec = man.input(&d.name).unwrap();
            assert_eq!(spec.shape, vec![d.m, d.n], "{}", d.name);
        }
        let toks = man.input("tokens").unwrap();
        assert_eq!(toks.dtype, "i32");
        assert_eq!(toks.shape, vec![cfg.batch_train, cfg.seq_train]);
        assert_eq!(man.outputs[0], "loss");
    }

    #[test]
    fn factored_manifest_has_masks() {
        let paths = Paths::discover().unwrap();
        let man_path = paths
            .artifact_dir("micro-llama")
            .join("mask_fwd_grad.manifest.json");
        if !man_path.exists() {
            return;
        }
        let man = Manifest::load(&man_path).unwrap();
        let cfg = crate::config::model_by_name(&paths.configs, "micro-llama").unwrap();
        for d in module_dims(&cfg) {
            let u = man.input(&format!("{}.u", d.name)).unwrap();
            assert_eq!(u.shape, vec![d.m, d.r_full()]);
            let v = man.input(&format!("{}.v", d.name)).unwrap();
            assert_eq!(v.shape, vec![d.r_full(), d.n]);
            let m = man.input(&format!("mask:{}", d.name)).unwrap();
            assert_eq!(m.shape, vec![d.r_full()]);
            assert_eq!(
                man.output_index(&format!("grad:mask:{}", d.name)).is_some(),
                true
            );
        }
    }
}
