//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py`, compiles them once on the CPU PJRT client, and
//! executes them from the rust hot paths. Inputs are bound **by name**
//! through the artifact manifest — never by guessed position.

mod exec;
mod manifest;

pub use exec::{
    buffer_to_tensor, feed_to_buffer, literal_to_tensor, split_output_buffers, Exe, Feed, Outputs,
};
pub use manifest::{Manifest, TensorSpec};

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;

use crate::Result;

/// A PJRT client plus a cache of compiled executables for one model's
/// artifact directory.
pub struct Runtime {
    pub client: xla::PjRtClient,
    dir: PathBuf,
    cache: RefCell<HashMap<String, Rc<Exe>>>,
}

impl Runtime {
    /// CPU client over `artifacts/<model>/`.
    pub fn new(artifact_dir: PathBuf) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| crate::anyhow!("{e}"))?;
        if !artifact_dir.exists() {
            return Err(crate::anyhow!(
                "artifact dir {artifact_dir:?} missing — run `make artifacts`"
            ));
        }
        Ok(Runtime { client, dir: artifact_dir, cache: RefCell::new(HashMap::new()) })
    }

    /// Load + compile an artifact by name (cached).
    pub fn load(&self, name: &str) -> Result<Rc<Exe>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let hlo = self.dir.join(format!("{name}.hlo.txt"));
        let man = self.dir.join(format!("{name}.manifest.json"));
        let manifest = Manifest::load(&man)?;
        let proto = xla::HloModuleProto::from_text_file(
            hlo.to_str().ok_or_else(|| crate::anyhow!("bad path"))?,
        )
        .map_err(|e| crate::anyhow!("parse {hlo:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| crate::anyhow!("compile {name}: {e}"))?;
        let e = Rc::new(Exe { exe, manifest });
        self.cache.borrow_mut().insert(name.to_string(), e.clone());
        Ok(e)
    }

    /// Does an artifact exist (without compiling it)?
    pub fn has(&self, name: &str) -> bool {
        self.dir.join(format!("{name}.hlo.txt")).exists()
    }
}
