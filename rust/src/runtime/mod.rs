//! Pluggable execution runtime: loads artifacts by name and executes them
//! on one of two [`Backend`]s, binding inputs **by name** through the
//! artifact manifest — never by guessed position.
//!
//! * [`CpuBackend`] (default) — a pure-Rust interpreter that builds each
//!   artifact's graph directly from the model preset (`runtime/programs.rs`,
//!   mirroring `python/compile/model.py`) and executes it in-process
//!   (`runtime/interp.rs`). No artifacts on disk, no native dependencies:
//!   `cargo build && cargo test` work on a clean checkout.
//! * `XlaBackend` (`--features pjrt`, `ARA_BACKEND=pjrt`) — compiles the
//!   AOT HLO-text artifacts produced by `python/compile/aot.py` on the
//!   PJRT CPU client and executes them through `execute`/`execute_b`.
//!
//! Both backends serve the same [`Manifest`] name/shape/dtype contract, so
//! every harness above this layer (training, allocation, eval, serving) is
//! backend-agnostic. See DESIGN.md for the backend matrix.

mod cpu;
mod exec;
mod fusion;
mod grad;
mod interp;
mod manifest;
mod programs;
#[cfg(feature = "pjrt")]
mod xla;

pub use cpu::CpuBackend;
pub use exec::{DeviceArg, DeviceBuffer, Exe, Executable, Feed, Outputs, Value};
pub use manifest::{Manifest, TensorSpec};
pub use crate::compress::heuristic_ara_alloc;
pub use programs::{resolve_alloc, resolve_plan};
#[cfg(feature = "pjrt")]
pub use xla::XlaBackend;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use crate::tensor::Tensor;
use crate::Result;

/// An execution backend: owns loading/compiling artifacts and moving data
/// on/off its device.
pub trait Backend {
    fn name(&self) -> &'static str;

    /// Load (and compile) artifact `name` from `dir`.
    fn load(&self, dir: &Path, name: &str) -> Result<Exe>;

    /// Is the artifact available (without loading it)?
    fn has(&self, dir: &Path, name: &str) -> bool;

    /// Upload a host feed to a device-resident buffer.
    fn upload(&self, feed: &Feed) -> Result<DeviceBuffer>;

    /// Download a device-resident buffer to a host tensor.
    fn download(&self, buf: &DeviceBuffer) -> Result<Tensor>;
}

/// A backend plus a cache of loaded executables for one model's artifact
/// directory.
pub struct Runtime {
    backend: Rc<dyn Backend>,
    dir: PathBuf,
    cache: RefCell<HashMap<String, Rc<Exe>>>,
}

impl Runtime {
    /// Runtime over `artifacts/<model>/`, selecting the backend from
    /// `ARA_BACKEND` (`cpu` default; `pjrt`/`xla` with the pjrt feature).
    pub fn new(artifact_dir: PathBuf) -> Result<Runtime> {
        let choice = std::env::var("ARA_BACKEND").unwrap_or_else(|_| "cpu".to_string());
        if choice == "cpu" {
            return Ok(Runtime::with_backend(Rc::new(CpuBackend::new()?), artifact_dir));
        }
        if choice == "pjrt" || choice == "xla" {
            #[cfg(feature = "pjrt")]
            {
                let be = XlaBackend::new(&artifact_dir)?;
                return Ok(Runtime::with_backend(Rc::new(be), artifact_dir));
            }
            #[cfg(not(feature = "pjrt"))]
            {
                return Err(crate::anyhow!(
                    "ARA_BACKEND={choice} requires building with `--features pjrt`"
                ));
            }
        }
        Err(crate::anyhow!("unknown ARA_BACKEND `{choice}` (expected `cpu` or `pjrt`)"))
    }

    /// Runtime over an explicit backend (tests, embedders).
    pub fn with_backend(backend: Rc<dyn Backend>, dir: PathBuf) -> Runtime {
        Runtime { backend, dir, cache: RefCell::new(HashMap::new()) }
    }

    /// The active backend handle (shared with serving engines).
    pub fn backend(&self) -> Rc<dyn Backend> {
        self.backend.clone()
    }

    /// Load an artifact by name (cached per runtime).
    pub fn load(&self, name: &str) -> Result<Rc<Exe>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let e = Rc::new(self.backend.load(&self.dir, name)?);
        self.cache.borrow_mut().insert(name.to_string(), e.clone());
        Ok(e)
    }

    /// Does an artifact exist (without compiling it)?
    pub fn has(&self, name: &str) -> bool {
        self.cache.borrow().contains_key(name) || self.backend.has(&self.dir, name)
    }

    /// Upload a host feed through the active backend.
    pub fn upload(&self, feed: &Feed) -> Result<DeviceBuffer> {
        self.backend.upload(feed)
    }

    /// Download a device buffer through the active backend.
    pub fn download(&self, buf: &DeviceBuffer) -> Result<Tensor> {
        self.backend.download(buf)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Paths;

    #[test]
    fn default_backend_is_cpu_and_needs_no_artifacts() {
        let paths = Paths::discover().unwrap();
        // a directory that definitely has no exported artifacts
        let rt = Runtime::new(paths.artifact_dir("micro-llama")).unwrap();
        assert_eq!(rt.backend().name(), "cpu");
        assert!(rt.has("train_step"));
        assert!(rt.has("score_masked"));
        assert!(!rt.has("not_an_artifact"));
        let exe = rt.load("score_dense").unwrap();
        assert_eq!(exe.manifest().name, "score_dense");
        // cache returns the same handle
        let exe2 = rt.load("score_dense").unwrap();
        assert!(Rc::ptr_eq(&exe, &exe2));
    }

    #[test]
    fn unknown_model_dir_fails_at_load() {
        let paths = Paths::discover().unwrap();
        let rt = Runtime::new(paths.artifact_dir("no-such-model")).unwrap();
        assert!(rt.load("train_step").is_err());
    }
}
