//! ExecPlan-construction-time op fusion for the interpreter hot path.
//!
//! The graph builders in [`super::programs`] emit fixed micro-op chains for
//! rmsnorm, rotary embeddings, and softmax. Executed op-by-op, each chain
//! round-trips every intermediate through the [`super::interp::Arena`] —
//! seven materialized tensors for one rmsnorm. This module pattern-matches
//! those exact chains once at plan time and replaces each with a single
//! [`FusedOp`] executed at the chain's root node; interior nodes are
//! skipped entirely and never materialize.
//!
//! Recognized patterns (priority order — larger first, so a super-pattern
//! claims its sub-pattern's root before the sub-pattern is tried):
//!
//! 1. **RopeScore** — decode-shaped rope + attention-score: a `Bmm(q, k,
//!    ta=false, tb=true)` whose `q` is a reshape chain over a single-token
//!    rope concat. The roped query goes straight into the dot-product
//!    kernel without ever materializing the concat.
//! 2. **RmsNormMatmul** — `Matmul(rmsnorm(x, gain), w)` where the rmsnorm
//!    root has no other consumer: normalized rows are written to one
//!    scratch buffer and fed to the matmul kernel.
//! 3. **Rope** — the standalone 13-node rotary chain rooted at its
//!    `Concat` (prefill, and the decode key path feeding the cache write).
//! 4. **RmsNorm** — the standalone 8-node chain rooted at its final `Mul`
//!    (multi-consumer norms: decode ln1 feeds q/k/v projections).
//! 5. **Softmax** — the 8-node shifted-softmax chain rooted at its `Div`,
//!    computed row-in-place.
//!
//! **Determinism contract:** every fused kernel performs the *same
//! primitive f32 operations in the same order* as the unfused op sequence
//! it replaces, so fused and unfused execution are **bitwise identical**
//! (pinned by the `fused_*` tests in [`super::interp`]). Fusion saves
//! memory traffic and arena churn, never reassociates arithmetic. The
//! `ARA_FUSE` knob (default on) disables fusion process-wide; training
//! graphs de-fuse automatically because gradient nodes consume chain
//! interiors, failing the all-consumers-in-group check.
//!
//! A pattern only fuses when every interior node (a) is consumed
//! exclusively inside the group and (b) is not a graph output — so fusion
//! is invisible to every caller by construction.

use super::exec::Value;
use super::interp::{Graph, Id, Op};

/// One fused op group, executed at the root node id it replaced.
/// Structural parameters and baked scalars are extracted at match time so
/// the executor does no graph walking.
#[derive(Debug, Clone)]
pub(crate) enum FusedOp {
    /// Shifted softmax over the last axis: `rows` rows of length `n`,
    /// row-in-place capable.
    Softmax { x: Id, rows: usize, n: usize },
    /// RMSNorm over the last dim of 2-D `x` (rows, d) with gain (d,).
    RmsNorm { x: Id, gain: Id, rows: usize, d: usize, inv_d: f32, eps: f32 },
    /// RMSNorm feeding a single-consumer `Matmul(·, w, ta=false, tb)`:
    /// normalized rows land in one scratch buffer, then the matmul kernel.
    RmsNormMatmul {
        x: Id,
        gain: Id,
        w: Id,
        tb: bool,
        rows: usize,
        d: usize,
        n: usize,
        inv_d: f32,
        eps: f32,
    },
    /// Rotary embedding of `x` (b, t, h, dh) with angles `ang` (pb, t,
    /// dh/2); `pb` is 1 (broadcast) or `b`. In-place capable.
    Rope { x: Id, ang: Id, b: usize, t: usize, pb: usize, h: usize, dh: usize },
    /// Decode rope + attention score: roped single-token query (b, 1, h,
    /// dh) dotted against `k` (b·h, n, dh) → (b·h, 1, n).
    RopeScore { x: Id, ang: Id, k: Id, b: usize, pb: usize, h: usize, dh: usize, n: usize },
}

/// Fusion decisions for one (graph, outputs) pair.
pub(crate) struct FusionPlan {
    /// Per node: the fused group rooted here, if any.
    pub fused: Vec<Option<FusedOp>>,
    /// Per node: true when the node is a fused-group interior — never
    /// executed, never materialized.
    pub skip: Vec<bool>,
    /// Per node: the root executing it (own id unless skipped). Used to
    /// attribute operand reads at interior nodes to the root's position
    /// when computing effective last-use.
    pub root_of: Vec<Id>,
}

impl FusionPlan {
    /// The no-fusion plan (`ARA_FUSE=0`, explicit `new_with(.., false)`).
    pub fn disabled(n: usize) -> FusionPlan {
        FusionPlan {
            fused: (0..n).map(|_| None).collect(),
            skip: vec![false; n],
            root_of: (0..n).collect(),
        }
    }
}

/// Scalar f32 constant value of node `id`, if it is one.
fn const_scalar(g: &Graph, id: Id) -> Option<f32> {
    match &g.nodes[id].op {
        Op::Const(Value::F32(t)) if t.data.len() == 1 => Some(t.data[0]),
        _ => None,
    }
}

/// A matched pattern: the op to run at the root plus the interior nodes
/// it absorbs.
struct Match {
    op: FusedOp,
    interior: Vec<Id>,
}

/// RMSNorm chain rooted at its final `Mul(xn, gain)` (see
/// `programs.rs::rmsnorm`). Returns the match without checking consumers —
/// validity is the caller's job.
fn match_rmsnorm(g: &Graph, root: Id) -> Option<Match> {
    let &Op::Mul(xn, gain) = &g.nodes[root].op else { return None };
    let &Op::Mul(x, inv) = &g.nodes[xn].op else { return None };
    let &Op::Rsqrt(mse) = &g.nodes[inv].op else { return None };
    let &Op::Add(ms, eps_id) = &g.nodes[mse].op else { return None };
    let eps = const_scalar(g, eps_id)?;
    let &Op::Mul(ssum, invd_id) = &g.nodes[ms].op else { return None };
    let inv_d = const_scalar(g, invd_id)?;
    let Op::Reshape(rs, _) = &g.nodes[ssum].op else { return None };
    let rs = *rs;
    let &Op::ReduceSum(x2, 1) = &g.nodes[rs].op else { return None };
    let &Op::Mul(xa, xb) = &g.nodes[x2].op else { return None };
    if xa != x || xb != x {
        return None;
    }
    let xs = g.nodes[x].shape.as_slice();
    if xs.len() != 2 || g.nodes[gain].shape.as_slice() != [xs[1]] {
        return None;
    }
    Some(Match {
        op: FusedOp::RmsNorm { x, gain, rows: xs[0], d: xs[1], inv_d, eps },
        interior: vec![xn, inv, mse, ms, ssum, rs, x2],
    })
}

/// `Matmul(rmsnorm_root, w, ta=false)` where the rmsnorm root is consumed
/// only by this matmul: the norm's output never materializes.
fn match_rmsnorm_matmul(g: &Graph, root: Id, consumers: &[Vec<Id>]) -> Option<Match> {
    let &Op::Matmul { a, b: w, ta: false, tb } = &g.nodes[root].op else { return None };
    if consumers[a].len() != 1 {
        return None;
    }
    let rms = match_rmsnorm(g, a)?;
    let FusedOp::RmsNorm { x, gain, rows, d, inv_d, eps } = rms.op else { unreachable!() };
    let n = g.nodes[root].shape[1];
    let mut interior = rms.interior;
    interior.push(a);
    Some(Match {
        op: FusedOp::RmsNormMatmul { x, gain, w, tb, rows, d, n, inv_d, eps },
        interior,
    })
}

/// Rotary chain rooted at its `Concat([lo, hi], 3)` (see
/// `programs.rs::rope`). The angle tensor `ang` stays a regular node; the
/// twelve nodes from cos/sin through the concat are absorbed.
fn match_rope(g: &Graph, root: Id) -> Option<Match> {
    let Op::Concat(parts, axis) = &g.nodes[root].op else { return None };
    if *axis != 3 || parts.len() != 2 {
        return None;
    }
    let (lo, hi) = (parts[0], parts[1]);
    let &Op::Sub(a, b) = &g.nodes[lo].op else { return None };
    let &Op::Add(c, d2) = &g.nodes[hi].op else { return None };
    let &Op::Mul(x1, cos4) = &g.nodes[a].op else { return None };
    let &Op::Mul(x2, sin4) = &g.nodes[b].op else { return None };
    let &Op::Mul(x1b, sin4b) = &g.nodes[c].op else { return None };
    let &Op::Mul(x2b, cos4b) = &g.nodes[d2].op else { return None };
    if x1 != x1b || x2 != x2b || sin4 != sin4b || cos4 != cos4b {
        return None;
    }
    let Op::Reshape(cos_id, _) = &g.nodes[cos4].op else { return None };
    let cos_id = *cos_id;
    let &Op::Cos(ang_c) = &g.nodes[cos_id].op else { return None };
    let Op::Reshape(sin_id, _) = &g.nodes[sin4].op else { return None };
    let sin_id = *sin_id;
    let &Op::Sin(ang_s) = &g.nodes[sin_id].op else { return None };
    if ang_c != ang_s {
        return None;
    }
    let ang = ang_c;
    let &Op::Slice { x: xx1, axis: 3, start: 0, len: half } = &g.nodes[x1].op else {
        return None;
    };
    let &Op::Slice { x: xx2, axis: 3, start: st2, len: l2 } = &g.nodes[x2].op else {
        return None;
    };
    if xx1 != xx2 || half == 0 || l2 != half || st2 != half {
        return None;
    }
    let x = xx1;
    let xs = g.nodes[x].shape.as_slice();
    if xs.len() != 4 || xs[3] != 2 * half {
        return None;
    }
    let angs = g.nodes[ang].shape.as_slice();
    if angs.len() != 3 || angs[1] != xs[1] || angs[2] != half {
        return None;
    }
    let pb = angs[0];
    if pb != 1 && pb != xs[0] {
        return None;
    }
    Some(Match {
        op: FusedOp::Rope { x, ang, b: xs[0], t: xs[1], pb, h: xs[2], dh: xs[3] },
        interior: vec![lo, hi, a, b, c, d2, x1, x2, cos4, sin4, cos_id, sin_id],
    })
}

/// Decode attention-score bmm over a reshaped single-token rope: the
/// `Bmm(q, k, ta=false, tb=true)` at `root` with `q` a single-consumer
/// reshape chain down to a rope concat with t == 1.
fn match_rope_score(g: &Graph, root: Id, consumers: &[Vec<Id>]) -> Option<Match> {
    let &Op::Bmm { a: q, b: k, ta: false, tb: true } = &g.nodes[root].op else { return None };
    // walk the reshape chain; every link must feed only the next one
    let mut chain = Vec::new();
    let mut cur = q;
    while let Op::Reshape(next, _) = &g.nodes[cur].op {
        if consumers[cur].len() != 1 {
            return None;
        }
        chain.push(cur);
        cur = *next;
    }
    if chain.is_empty() || consumers[cur].len() != 1 {
        return None;
    }
    let rope = match_rope(g, cur)?;
    let FusedOp::Rope { x, ang, b, t, pb, h, dh } = rope.op else { unreachable!() };
    let out = g.nodes[root].shape.as_slice(); // (bs, m, n)
    if t != 1 || out[1] != 1 || out[0] != b * h {
        return None;
    }
    let mut interior = rope.interior;
    interior.push(cur); // the concat root is absorbed too
    interior.extend(chain);
    Some(Match { op: FusedOp::RopeScore { x, ang, k, b, pb, h, dh, n: out[2] }, interior })
}

/// Shifted-softmax chain rooted at its `Div(e, sum)` (see
/// `programs.rs::softmax3`); accepts any rank with a last-axis reduce.
fn match_softmax(g: &Graph, root: Id) -> Option<Match> {
    let &Op::Div(e, sk) = &g.nodes[root].op else { return None };
    let Op::Reshape(rs, _) = &g.nodes[sk].op else { return None };
    let rs = *rs;
    let &Op::ReduceSum(e2, ax) = &g.nodes[rs].op else { return None };
    if e2 != e {
        return None;
    }
    let &Op::Exp(sh) = &g.nodes[e].op else { return None };
    let &Op::Sub(x, ms) = &g.nodes[sh].op else { return None };
    let &Op::StopGrad(mr) = &g.nodes[ms].op else { return None };
    let Op::Reshape(rm, _) = &g.nodes[mr].op else { return None };
    let rm = *rm;
    let &Op::ReduceMax(x2, ax2) = &g.nodes[rm].op else { return None };
    let xs = g.nodes[x].shape.as_slice();
    if x2 != x || ax2 != ax || xs.is_empty() || ax != xs.len() - 1 {
        return None;
    }
    let n = xs[ax];
    if n == 0 {
        return None;
    }
    let rows: usize = xs[..ax].iter().product();
    Some(Match {
        op: FusedOp::Softmax { x, rows, n },
        interior: vec![e, sk, rs, sh, ms, mr, rm],
    })
}

/// Is the matched group valid: no interior node claimed by another group,
/// none a graph output, and every interior consumed only inside the group?
fn group_ok(m: &Match, root: Id, outputs: &[Id], claimed: &[bool], consumers: &[Vec<Id>]) -> bool {
    for &i in &m.interior {
        if claimed[i] || outputs.contains(&i) {
            return false;
        }
        for &c in &consumers[i] {
            if c != root && !m.interior.contains(&c) {
                return false;
            }
        }
    }
    true
}

/// Match fused groups over the whole graph. Roots are visited in
/// descending id order so super-patterns (RopeScore over a rope concat,
/// RmsNormMatmul over an rmsnorm root) claim their chains before the
/// standalone sub-patterns are tried.
pub(crate) fn plan_fusion(g: &Graph, outputs: &[Id]) -> FusionPlan {
    let n = g.nodes.len();
    let mut consumers: Vec<Vec<Id>> = vec![Vec::new(); n];
    for (id, node) in g.nodes.iter().enumerate() {
        for o in node.op.operands() {
            consumers[o].push(id);
        }
    }
    let mut plan = FusionPlan::disabled(n);
    let mut claimed = vec![false; n];
    for root in (0..n).rev() {
        if claimed[root] {
            continue;
        }
        let candidates = [
            match_rope_score(g, root, &consumers),
            match_rmsnorm_matmul(g, root, &consumers),
            match_rope(g, root),
            match_rmsnorm(g, root),
            match_softmax(g, root),
        ];
        for cand in candidates.into_iter().flatten() {
            if !group_ok(&cand, root, outputs, &claimed, &consumers) {
                continue;
            }
            claimed[root] = true;
            for &i in &cand.interior {
                claimed[i] = true;
                plan.skip[i] = true;
                plan.root_of[i] = root;
            }
            plan.fused[root] = Some(cand.op);
            break;
        }
    }
    plan
}

#[cfg(test)]
mod tests {
    use super::super::exec::{Feed, Value};
    use super::super::interp::{Arena, Arg, DType, ExecPlan, Graph};
    use super::*;
    use crate::tensor::Tensor;

    /// Deterministic pseudo-random fill (same LCG as the kernel tests).
    fn fill(n: usize, seed: u64) -> Vec<f32> {
        let mut s = seed.wrapping_mul(6364136223846793005).wrapping_add(1);
        (0..n)
            .map(|_| {
                s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                ((s >> 33) as f32 / (1u64 << 31) as f32) - 0.5
            })
            .collect()
    }

    /// Execute with fusion explicitly on or off.
    fn run(g: &Graph, outs: &[Id], feeds: &[Feed], fuse: bool) -> (Vec<Value>, usize) {
        let plan = ExecPlan::new_with(g, outs, fuse);
        let n = plan.fused_count();
        let mut args: Vec<Arg> = feeds.iter().map(Arg::from_feed).collect();
        (g.eval_plan(&mut args, &plan, &mut Arena::new()).unwrap(), n)
    }

    fn assert_bitwise_eq(a: &Value, b: &Value) {
        let (Value::F32(a), Value::F32(b)) = (a, b) else { panic!("expected f32 outputs") };
        assert_eq!(a.shape, b.shape);
        for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "elem {i}: fused {x} != unfused {y}");
        }
    }

    /// The exact chain `programs.rs::rmsnorm` emits.
    fn build_rmsnorm(g: &mut Graph, x: Id, gain: Id) -> Id {
        let d = g.shape(x)[1];
        let x2 = g.mul(x, x);
        let ssum = g.reduce_sum_keep(x2, 1);
        let inv_d = g.scalar(1.0 / d as f32);
        let ms = g.mul(ssum, inv_d);
        let eps = g.scalar(1e-6);
        let mse = g.add(ms, eps);
        let inv = g.rsqrt(mse);
        let xn = g.mul(x, inv);
        g.mul(xn, gain)
    }

    /// The exact chain `programs.rs::softmax3` emits.
    fn build_softmax3(g: &mut Graph, x: Id) -> Id {
        let m = g.reduce_max_keep(x, 2);
        let ms = g.stop_grad(m);
        let sh = g.sub(x, ms);
        let e = g.exp(sh);
        let s = g.reduce_sum_keep(e, 2);
        g.div(e, s)
    }

    /// The exact chain `programs.rs::rope` emits (f32 positions `pos`).
    fn build_rope(g: &mut Graph, x: Id, pos: Id) -> Id {
        let dh = *g.shape(x).last().unwrap();
        let half = dh / 2;
        let freqs: Vec<f32> = (0..half)
            .map(|i| 1.0 / 10000f32.powf(i as f32 * 2.0 / dh as f32))
            .collect();
        let fq = g.constant(Tensor::from_vec(&[half], freqs));
        let ps = g.shape(pos).to_vec();
        let p3 = g.reshape(pos, &[ps[0], ps[1], 1]);
        let ang = g.mul(p3, fq);
        let cos = g.cos(ang);
        let sin = g.sin(ang);
        let cos4 = g.reshape(cos, &[ps[0], ps[1], 1, half]);
        let sin4 = g.reshape(sin, &[ps[0], ps[1], 1, half]);
        let x1 = g.slice(x, 3, 0, half);
        let x2 = g.slice(x, 3, half, half);
        let a = g.mul(x1, cos4);
        let b = g.mul(x2, sin4);
        let lo = g.sub(a, b);
        let c = g.mul(x1, sin4);
        let d2 = g.mul(x2, cos4);
        let hi = g.add(c, d2);
        g.concat(&[lo, hi], 3)
    }

    #[test]
    fn rmsnorm_fuses_and_is_bitwise_identical() {
        let mut g = Graph::default();
        let x = g.input(&[3, 8], DType::F32);
        let gain = g.input(&[8], DType::F32);
        let root = build_rmsnorm(&mut g, x, gain);
        let xt = Tensor::from_vec(&[3, 8], fill(24, 1));
        let gt = Tensor::from_vec(&[8], fill(8, 2));
        let feeds = [Feed::F32(&xt), Feed::F32(&gt)];
        let (fused, nf) = run(&g, &[root], &feeds, true);
        let (plain, np) = run(&g, &[root], &feeds, false);
        assert_eq!(nf, 1, "rmsnorm chain should fuse");
        assert_eq!(np, 0);
        assert_bitwise_eq(&fused[0], &plain[0]);
    }

    #[test]
    fn rmsnorm_matmul_fuses_as_one_group() {
        let mut g = Graph::default();
        let x = g.input(&[3, 8], DType::F32);
        let gain = g.input(&[8], DType::F32);
        let w = g.input(&[5, 8], DType::F32);
        let norm = build_rmsnorm(&mut g, x, gain);
        let root = g.matmul(norm, w, false, true);
        let xt = Tensor::from_vec(&[3, 8], fill(24, 3));
        let gt = Tensor::from_vec(&[8], fill(8, 4));
        let wt = Tensor::from_vec(&[5, 8], fill(40, 5));
        let feeds = [Feed::F32(&xt), Feed::F32(&gt), Feed::F32(&wt)];
        let (fused, nf) = run(&g, &[root], &feeds, true);
        let (plain, _) = run(&g, &[root], &feeds, false);
        assert_eq!(nf, 1, "rmsnorm+matmul should fuse into one group");
        assert_bitwise_eq(&fused[0], &plain[0]);
    }

    #[test]
    fn multi_consumer_rmsnorm_fuses_standalone_not_into_matmul() {
        // decode ln1: one norm feeding two projections — the matmuls must
        // not claim it, the standalone rmsnorm still fires
        let mut g = Graph::default();
        let x = g.input(&[2, 8], DType::F32);
        let gain = g.input(&[8], DType::F32);
        let w1 = g.input(&[4, 8], DType::F32);
        let w2 = g.input(&[4, 8], DType::F32);
        let norm = build_rmsnorm(&mut g, x, gain);
        let o1 = g.matmul(norm, w1, false, true);
        let o2 = g.matmul(norm, w2, false, true);
        let xt = Tensor::from_vec(&[2, 8], fill(16, 6));
        let gt = Tensor::from_vec(&[8], fill(8, 7));
        let w1t = Tensor::from_vec(&[4, 8], fill(32, 8));
        let w2t = Tensor::from_vec(&[4, 8], fill(32, 9));
        let feeds = [Feed::F32(&xt), Feed::F32(&gt), Feed::F32(&w1t), Feed::F32(&w2t)];
        let (fused, nf) = run(&g, &[o1, o2], &feeds, true);
        let (plain, _) = run(&g, &[o1, o2], &feeds, false);
        assert_eq!(nf, 1, "standalone rmsnorm should fuse exactly once");
        assert_bitwise_eq(&fused[0], &plain[0]);
        assert_bitwise_eq(&fused[1], &plain[1]);
    }

    #[test]
    fn softmax_fuses_and_is_bitwise_identical() {
        let mut g = Graph::default();
        let x = g.input(&[2, 3, 5], DType::F32);
        let root = build_softmax3(&mut g, x);
        // include mask-scale magnitudes like masked attention scores
        let mut data = fill(30, 10);
        data[4] = -1e30;
        data[17] = -1e30;
        let xt = Tensor::from_vec(&[2, 3, 5], data);
        let feeds = [Feed::F32(&xt)];
        let (fused, nf) = run(&g, &[root], &feeds, true);
        let (plain, _) = run(&g, &[root], &feeds, false);
        assert_eq!(nf, 1, "softmax chain should fuse");
        assert_bitwise_eq(&fused[0], &plain[0]);
    }

    #[test]
    fn softmax_with_interior_output_does_not_fuse() {
        let mut g = Graph::default();
        let x = g.input(&[1, 2, 4], DType::F32);
        let m = g.reduce_max_keep(x, 2);
        let ms = g.stop_grad(m);
        let sh = g.sub(x, ms);
        let e = g.exp(sh);
        let s = g.reduce_sum_keep(e, 2);
        let root = g.div(e, s);
        // `e` escapes the group as a graph output — fusion must back off
        let plan = ExecPlan::new_with(&g, &[root, e], true);
        assert_eq!(plan.fused_count(), 0);
    }

    #[test]
    fn rope_fuses_for_broadcast_and_per_batch_positions() {
        for &pb in &[1usize, 2] {
            let (b, t, h, dh) = (2, 3, 2, 6);
            let mut g = Graph::default();
            let x = g.input(&[b, t, h, dh], DType::F32);
            let pos = g.input(&[pb, t], DType::F32);
            let root = build_rope(&mut g, x, pos);
            let xt = Tensor::from_vec(&[b, t, h, dh], fill(b * t * h * dh, 11));
            let pt = Tensor::from_vec(&[pb, t], (0..pb * t).map(|i| i as f32).collect());
            let feeds = [Feed::F32(&xt), Feed::F32(&pt)];
            let (fused, nf) = run(&g, &[root], &feeds, true);
            let (plain, _) = run(&g, &[root], &feeds, false);
            assert_eq!(nf, 1, "rope chain should fuse (pb = {pb})");
            assert_bitwise_eq(&fused[0], &plain[0]);
        }
    }

    #[test]
    fn decode_rope_score_fuses_through_the_reshape() {
        // decode q-path: rope on a single-token query, reshape to packed
        // heads, dot against the cached keys
        let (b, h, dh, n) = (2, 3, 8, 5);
        let mut g = Graph::default();
        let x = g.input(&[b, 1, h, dh], DType::F32);
        let pos = g.input(&[b, 1], DType::F32);
        let k = g.input(&[b * h, n, dh], DType::F32);
        let roped = build_rope(&mut g, x, pos);
        let q3 = g.reshape(roped, &[b * h, 1, dh]);
        let root = g.bmm(q3, k, false, true);
        let xt = Tensor::from_vec(&[b, 1, h, dh], fill(b * h * dh, 12));
        let pt = Tensor::from_vec(&[b, 1], vec![3.0, 7.0]);
        let kt = Tensor::from_vec(&[b * h, n, dh], fill(b * h * n * dh, 13));
        let feeds = [Feed::F32(&xt), Feed::F32(&pt), Feed::F32(&kt)];
        let (fused, nf) = run(&g, &[root], &feeds, true);
        let (plain, _) = run(&g, &[root], &feeds, false);
        assert_eq!(nf, 1, "rope+score should fuse into one group");
        assert_bitwise_eq(&fused[0], &plain[0]);
    }

    #[test]
    fn disabled_plan_has_no_fusion() {
        let plan = FusionPlan::disabled(4);
        assert!(plan.fused.iter().all(Option::is_none));
        assert!(plan.skip.iter().all(|&s| !s));
        assert_eq!(plan.root_of, vec![0, 1, 2, 3]);
    }
}
