//! The default execution backend: a pure-Rust interpreter over the artifact
//! programs in [`super::programs`]. Zero native dependencies — `cargo test`
//! exercises the full pipeline (train → calibrate → factorize → allocate →
//! eval → serve) on any machine.
//!
//! "Device" memory is host memory here, so the buffer path is move-only:
//! uploads wrap tensors, downloads clone them back, and multi-output
//! executions hand back one buffer per output with no tuple-decompose or
//! literal round-trip (the PJRT path needs both).

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;

use super::exec::{check_feed, DeviceArg, DeviceBuffer, Exe, Executable, Feed, Outputs, Value};
use super::interp::{Arena, Arg};
use super::programs::{build, Program};
use crate::config::{model_by_name, Paths};
use crate::tensor::Tensor;
use crate::Result;

/// Backend facade trait — see [`super::Backend`].
use super::Backend;

pub struct CpuBackend {
    paths: Paths,
}

impl CpuBackend {
    pub fn new() -> Result<CpuBackend> {
        Ok(CpuBackend { paths: Paths::discover()? })
    }

    /// Model preset for an artifact directory `…/artifacts/<model>`.
    fn model_of(&self, dir: &Path) -> Result<crate::config::ModelCfg> {
        let model = dir
            .file_name()
            .and_then(|s| s.to_str())
            .ok_or_else(|| crate::anyhow!("artifact dir {dir:?} has no model name"))?;
        model_by_name(&self.paths.configs, model)
    }
}

impl Backend for CpuBackend {
    fn name(&self) -> &'static str {
        "cpu"
    }

    fn load(&self, dir: &Path, name: &str) -> Result<Exe> {
        let cfg = self.model_of(dir)?;
        let program = build(&cfg, &self.paths, name)?;
        Ok(Exe::new(Box::new(CpuExe { program, arena: RefCell::new(Arena::new()) })))
    }

    fn has(&self, dir: &Path, name: &str) -> bool {
        // name-pattern check only: no graph construction, no allocation
        // resolution side effects for a read-only query
        self.model_of(dir).is_ok() && super::programs::is_known_artifact(name)
    }

    fn upload(&self, feed: &Feed) -> Result<DeviceBuffer> {
        Ok(DeviceBuffer::Host(match feed {
            Feed::F32(t) => Value::F32((*t).clone()),
            Feed::I32(t) => Value::I32((*t).clone()),
            Feed::Q8(t) => Value::Q8((*t).clone()),
        }))
    }

    fn download(&self, buf: &DeviceBuffer) -> Result<Tensor> {
        match buf {
            DeviceBuffer::Host(v) => Ok(v.to_f32_tensor()),
            #[cfg(feature = "pjrt")]
            DeviceBuffer::Pjrt(_) => {
                Err(crate::anyhow!("cpu backend cannot download a pjrt buffer"))
            }
        }
    }
}

/// One interpreted artifact: the program (graph + manifest + cached
/// [`Arena`]. The plan is computed once at load; the arena persists across
/// executions so steady-state serving does no per-step allocation.
pub struct CpuExe {
    program: Program,
    arena: RefCell<Arena>,
}

impl CpuExe {
    fn eval_args(&self, args: &mut [Arg]) -> Result<Vec<Value>> {
        self.program
            .graph
            .eval_plan(args, &self.program.plan, &mut self.arena.borrow_mut())
            .map_err(|e| crate::anyhow!("{}: {e}", self.program.manifest.name))
    }
}

impl Executable for CpuExe {
    fn manifest(&self) -> &super::manifest::Manifest {
        &self.program.manifest
    }

    fn run(&self, feeds: &HashMap<&str, Feed>) -> Result<Outputs> {
        let man = &self.program.manifest;
        let mut args: Vec<Arg> = Vec::with_capacity(man.inputs.len());
        for spec in &man.inputs {
            let feed = feeds.get(spec.name.as_str()).ok_or_else(|| {
                crate::anyhow!("missing input `{}` for {}", spec.name, man.name)
            })?;
            check_feed(feed, spec)?;
            args.push(Arg::from_feed(feed));
        }
        let values = self.eval_args(&mut args)?;
        Ok(Outputs::new(man.outputs.clone(), values))
    }

    fn run_device(&self, args: &[&DeviceBuffer]) -> Result<Vec<DeviceBuffer>> {
        let wrapped: Vec<DeviceArg> = args.iter().map(|&b| DeviceArg::Ref(b)).collect();
        self.run_device_args(wrapped)
    }

    fn run_device_args(&self, args: Vec<DeviceArg>) -> Result<Vec<DeviceBuffer>> {
        let man = &self.program.manifest;
        if args.len() != man.inputs.len() {
            return Err(crate::anyhow!(
                "{}: expected {} buffer args, got {}",
                man.name,
                man.inputs.len(),
                args.len()
            ));
        }
        // Borrowed host values are bound without copying; owned host values
        // are moved into the evaluator so it can recycle them in place.
        let mut bound: Vec<Arg> = Vec::with_capacity(args.len());
        for (darg, spec) in args.into_iter().zip(&man.inputs) {
            match darg.buffer() {
                DeviceBuffer::Host(v) => check_feed(&v.as_feed(), spec)?,
                #[cfg(feature = "pjrt")]
                DeviceBuffer::Pjrt(_) => {
                    return Err(crate::anyhow!(
                        "{}: pjrt buffer passed to the cpu backend",
                        man.name
                    ));
                }
            }
            bound.push(match darg {
                DeviceArg::Ref(DeviceBuffer::Host(v)) => Arg::from_feed(&v.as_feed()),
                DeviceArg::Own(DeviceBuffer::Host(v)) => Arg::from_value(v),
                #[cfg(feature = "pjrt")]
                _ => unreachable!("pjrt buffers rejected above"),
            });
        }
        let values = self.eval_args(&mut bound)?;
        Ok(values.into_iter().map(DeviceBuffer::Host).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model_by_name;
    use crate::data::Rng;
    use crate::model::{init_weights, module_dims};
    use crate::tensor::IntTensor;

    fn setup() -> (crate::config::ModelCfg, CpuBackend) {
        let be = CpuBackend::new().unwrap();
        let cfg = model_by_name(&be.paths.configs, "micro-llama").unwrap();
        (cfg, be)
    }

    fn artifact_dir(be: &CpuBackend, model: &str) -> std::path::PathBuf {
        be.paths.artifact_dir(model)
    }

    #[test]
    fn score_dense_runs_and_nll_is_sane() {
        let (cfg, be) = setup();
        let exe = be.load(&artifact_dir(&be, "micro-llama"), "score_dense").unwrap();
        let ws = init_weights(&cfg, 3);
        let mut rng = Rng::new(5);
        let toks = IntTensor::from_vec(
            &[cfg.batch_eval, cfg.seq_eval],
            (0..cfg.batch_eval * cfg.seq_eval)
                .map(|_| rng.below(cfg.vocab) as i32)
                .collect(),
        );
        let tgts = toks.clone();
        let mut feeds: HashMap<&str, Feed> = HashMap::new();
        for (name, t) in &ws.tensors {
            feeds.insert(name.as_str(), Feed::F32(t));
        }
        feeds.insert("tokens", Feed::I32(&toks));
        feeds.insert("targets", Feed::I32(&tgts));
        let out = exe.run(&feeds).unwrap();
        let nll = out.tensor("nll").unwrap();
        assert_eq!(nll.shape, vec![cfg.batch_eval, cfg.seq_eval]);
        // fresh random weights ⇒ NLL ≈ ln(vocab) per token
        let mean = nll.data.iter().map(|&x| x as f64).sum::<f64>() / nll.data.len() as f64;
        let ln_v = (cfg.vocab as f64).ln();
        assert!(
            (mean - ln_v).abs() < 1.0,
            "mean NLL {mean:.3} far from ln(vocab) {ln_v:.3}"
        );
        assert!(nll.data.iter().all(|x| x.is_finite() && *x >= 0.0));
    }

    /// interpreter-vs-ref.py semantics: a full-rank exact factorization
    /// (u·v = W, all-ones mask) must reproduce the dense NLL bit-tight.
    #[test]
    fn masked_full_rank_identity_matches_dense() {
        let (cfg, be) = setup();
        let dense = be.load(&artifact_dir(&be, "micro-llama"), "score_dense").unwrap();
        let masked = be.load(&artifact_dir(&be, "micro-llama"), "score_masked").unwrap();
        let ws = init_weights(&cfg, 11);
        let mut rng = Rng::new(6);
        let toks = IntTensor::from_vec(
            &[cfg.batch_eval, cfg.seq_eval],
            (0..cfg.batch_eval * cfg.seq_eval)
                .map(|_| rng.below(cfg.vocab) as i32)
                .collect(),
        );
        let tgts = toks.clone();

        let mut feeds: HashMap<&str, Feed> = HashMap::new();
        for (name, t) in &ws.tensors {
            feeds.insert(name.as_str(), Feed::F32(t));
        }
        feeds.insert("tokens", Feed::I32(&toks));
        feeds.insert("targets", Feed::I32(&tgts));
        let nll_dense = dense.run(&feeds).unwrap().tensor("nll").unwrap();

        // exact factors: W (m,n) with r = min(m,n) → u·v = W via identity
        let dims = module_dims(&cfg);
        let mut store: Vec<(String, Tensor)> = Vec::new();
        for d in &dims {
            let w = ws.get(&d.name);
            let r = d.r_full();
            let eye = {
                let mut t = Tensor::zeros(&[r, r]);
                for i in 0..r {
                    t.set2(i, i, 1.0);
                }
                t
            };
            if d.m <= d.n {
                store.push((format!("{}.u", d.name), eye));
                store.push((format!("{}.v", d.name), w.clone()));
            } else {
                store.push((format!("{}.u", d.name), w.clone()));
                store.push((format!("{}.v", d.name), eye));
            }
            store.push((format!("mask:{}", d.name), Tensor::ones(&[r])));
        }
        let mut feeds: HashMap<&str, Feed> = HashMap::new();
        for (name, t) in &ws.tensors {
            if dims.iter().any(|d| &d.name == name) {
                continue; // superseded by factors
            }
            feeds.insert(name.as_str(), Feed::F32(t));
        }
        for (name, t) in &store {
            feeds.insert(name.as_str(), Feed::F32(t));
        }
        feeds.insert("tokens", Feed::I32(&toks));
        feeds.insert("targets", Feed::I32(&tgts));
        let nll_masked = masked.run(&feeds).unwrap().tensor("nll").unwrap();

        for (a, b) in nll_dense.data.iter().zip(&nll_masked.data) {
            assert!((a - b).abs() < 1e-3, "dense {a} vs masked-identity {b}");
        }
    }

    #[test]
    fn train_step_gradients_match_finite_differences() {
        // the end-to-end fwd+bwd consistency check: for the weight
        // coordinates with the largest gradient, a central finite
        // difference of the loss must match the reported gradient
        let (cfg, be) = setup();
        let exe = be.load(&artifact_dir(&be, "micro-llama"), "train_step").unwrap();
        let mut ws = init_weights(&cfg, 7);
        let mut rng = Rng::new(8);
        let toks = IntTensor::from_vec(
            &[cfg.batch_train, cfg.seq_train],
            (0..cfg.batch_train * cfg.seq_train)
                .map(|_| rng.below(cfg.vocab) as i32)
                .collect(),
        );
        let tgts = IntTensor::from_vec(
            &[cfg.batch_train, cfg.seq_train],
            toks.data.iter().map(|&t| (t + 1) % cfg.vocab as i32).collect(),
        );
        let loss_of = |ws: &crate::model::WeightStore| -> f32 {
            let mut feeds: HashMap<&str, Feed> = HashMap::new();
            for (name, t) in &ws.tensors {
                feeds.insert(name.as_str(), Feed::F32(t));
            }
            feeds.insert("tokens", Feed::I32(&toks));
            feeds.insert("targets", Feed::I32(&tgts));
            exe.run(&feeds).unwrap().scalar("loss").unwrap()
        };
        let mut feeds: HashMap<&str, Feed> = HashMap::new();
        for (name, t) in &ws.tensors {
            feeds.insert(name.as_str(), Feed::F32(t));
        }
        feeds.insert("tokens", Feed::I32(&toks));
        feeds.insert("targets", Feed::I32(&tgts));
        let out = exe.run(&feeds).unwrap();
        drop(feeds);
        let loss = out.scalar("loss").unwrap();
        assert!((loss as f64 - (cfg.vocab as f64).ln()).abs() < 1.0, "init loss {loss}");

        for wname in ["head", "embed", "layers.0.mlp.wup", "layers.1.attn.wq"] {
            // directional derivative along the gradient: for unit direction
            // d = g/‖g‖ the finite difference must equal ‖g‖ — much better
            // f32 signal-to-noise than per-coordinate differences
            let g = out.tensor(&format!("grad:{wname}")).unwrap();
            let norm = (g.data.iter().map(|&x| (x as f64).powi(2)).sum::<f64>()).sqrt();
            assert!(norm > 1e-7, "{wname}: zero gradient");
            let h = 2e-2f32;
            let orig = ws.get(wname).data.clone();
            {
                let t = ws.get_mut(wname);
                for (w, gv) in t.data.iter_mut().zip(&g.data) {
                    *w += h * (*gv as f64 / norm) as f32;
                }
            }
            let lp = loss_of(&ws);
            {
                let t = ws.get_mut(wname);
                for ((w, gv), o) in t.data.iter_mut().zip(&g.data).zip(&orig) {
                    *w = o - h * (*gv as f64 / norm) as f32;
                }
            }
            let lm = loss_of(&ws);
            ws.get_mut(wname).data = orig;
            let fd = (lp - lm) as f64 / (2.0 * h as f64);
            assert!(
                (fd - norm).abs() <= 0.1 * norm.max(1e-4),
                "{wname}: directional fd {fd} vs ‖grad‖ {norm}"
            );
        }
    }

    #[test]
    fn decode_step_runs_through_device_buffers() {
        let (cfg, be) = setup();
        let dir = artifact_dir(&be, "micro-llama");
        let exe = be.load(&dir, "decode_dense_b1").unwrap();
        let man = exe.manifest().clone();
        let ws = init_weights(&cfg, 9);
        let mut bufs: Vec<DeviceBuffer> = Vec::new();
        for spec in &man.inputs {
            match spec.name.as_str() {
                "tokens" => {
                    let t = IntTensor::from_vec(&[1], vec![5]);
                    bufs.push(be.upload(&Feed::I32(&t)).unwrap());
                }
                "lens" => {
                    let t = IntTensor::from_vec(&[1], vec![3]);
                    bufs.push(be.upload(&Feed::I32(&t)).unwrap());
                }
                "starts" => {
                    let t = IntTensor::from_vec(&[1], vec![0]);
                    bufs.push(be.upload(&Feed::I32(&t)).unwrap());
                }
                n if n.starts_with("kcache") || n.starts_with("vcache") => {
                    let t = Tensor::zeros(&spec.shape);
                    bufs.push(be.upload(&Feed::F32(&t)).unwrap());
                }
                n => {
                    bufs.push(be.upload(&Feed::F32(ws.get(n))).unwrap());
                }
            }
        }
        let refs: Vec<&DeviceBuffer> = bufs.iter().collect();
        let outs = exe.run_device(&refs).unwrap();
        assert_eq!(outs.len(), man.outputs.len());
        let logits = be.download(&outs[0]).unwrap();
        assert_eq!(logits.shape, vec![1, cfg.vocab]);
        assert!(logits.data.iter().all(|x| x.is_finite()));
        // the cache row at position `lens` must have been written
        let kc = be.download(&outs[1]).unwrap();
        let (nkv, s, dh) = (cfg.n_kv_heads, cfg.max_decode_seq, cfg.head_dim());
        assert_eq!(kc.shape, vec![1, nkv, s, dh]);
        let row = &kc.data[3 * dh..4 * dh]; // head 0, position 3
        assert!(row.iter().any(|&x| x != 0.0), "cache not written at lens");
    }

    #[test]
    fn scalar_on_empty_output_errors_not_panics() {
        let out = Outputs::new(
            vec!["empty".to_string()],
            vec![Value::F32(Tensor::zeros(&[0]))],
        );
        let err = out.scalar("empty").unwrap_err();
        assert!(err.to_string().contains("empty"));
        assert!(out.scalar("missing").is_err());
    }
}
