//! Artifact programs for the CPU backend: builds, per artifact name, the
//! same computation graph `python/compile/model.py` lowers to HLO — same
//! manifest input order, same names, same shapes, same math — expressed in
//! the interpreter IR with gradients from [`append_gradients`].
//!
//! Covered artifacts (see python/compile/aot.py):
//!   train_step, calibrate, score_dense, score_masked, mask_fwd_grad,
//!   lora_step, prefill_<alloc>_b<B>, decode_<alloc>_b<B>,
//!   decode_paged_<alloc>_b<B>_blk<L>x<N>,
//!   decode_verify_<alloc>_b<B>_blk<L>x<N>_k<W>
//!
//! Serving allocations resolve exactly like `aot.py:resolve_alloc`:
//! configs/allocations/<model>.<alloc>.json, then artifacts/allocations/,
//! then computed (dense / uniform-R / paper-shaped ara-R heuristic) with the
//! resolved JSON dumped to artifacts/allocations/ for inspection.

use std::collections::HashMap;

use super::grad::append_gradients;
use super::interp::{DType, ExecPlan, Graph, Id};
use super::manifest::{Manifest, TensorSpec};
use crate::compress::CompressionPlan;
use crate::config::{ModelCfg, Paths};
use crate::model::{aux_param_shapes, module_dims, Allocation, ModuleAlloc};
use crate::tensor::Tensor;
use crate::Result;

/// A compiled-for-the-interpreter artifact. The [`ExecPlan`] (free lists,
/// in-place donors, broadcast/transpose strides) is computed once here and
/// reused by every execution — steady-state serving does no planning work.
pub struct Program {
    pub graph: Graph,
    pub manifest: Manifest,
    pub plan: ExecPlan,
}

/// Build the program for an artifact name.
pub fn build(cfg: &ModelCfg, paths: &Paths, name: &str) -> Result<Program> {
    match name {
        "train_step" => Ok(train_step(cfg)),
        "calibrate" => Ok(calibrate(cfg)),
        "score_dense" => Ok(score(cfg, false)),
        "score_masked" => Ok(score(cfg, true)),
        "mask_fwd_grad" => Ok(mask_fwd_grad(cfg)),
        "lora_step" => Ok(lora_step(cfg)),
        _ => {
            if let Some(rest) = name.strip_prefix("prefill_") {
                let (alloc_name, batch) = parse_serving(rest, name)?;
                let alloc = resolve_alloc(cfg, paths, &alloc_name)?;
                validate_alloc(cfg, &alloc)?;
                Ok(prefill(cfg, &alloc, batch, name))
            } else if let Some(rest) = name.strip_prefix("decode_verify_") {
                let (alloc_name, batch, block_len, num_blocks, window) =
                    parse_verify(rest, name)?;
                let alloc = resolve_alloc(cfg, paths, &alloc_name)?;
                validate_alloc(cfg, &alloc)?;
                Ok(decode_verify(cfg, &alloc, batch, block_len, num_blocks, window, name))
            } else if let Some(rest) = name.strip_prefix("decode_paged_") {
                let (alloc_name, batch, block_len, num_blocks) = parse_paged(rest, name)?;
                let alloc = resolve_alloc(cfg, paths, &alloc_name)?;
                validate_alloc(cfg, &alloc)?;
                Ok(decode_paged(cfg, &alloc, batch, block_len, num_blocks, name))
            } else if let Some(rest) = name.strip_prefix("decode_") {
                let (alloc_name, batch) = parse_serving(rest, name)?;
                let alloc = resolve_alloc(cfg, paths, &alloc_name)?;
                validate_alloc(cfg, &alloc)?;
                Ok(decode(cfg, &alloc, batch, name))
            } else {
                Err(crate::anyhow!("unknown artifact `{name}` (cpu backend)"))
            }
        }
    }
}

/// Every compressible module must have an allocation entry before a serving
/// graph is specialized on it — a proper error beats a builder panic.
fn validate_alloc(cfg: &ModelCfg, alloc: &Allocation) -> Result<()> {
    for d in module_dims(cfg) {
        alloc.try_get(&d.name)?;
    }
    Ok(())
}

/// Cheap name check: would [`build`] recognize this artifact name?
/// (Does not validate that a named allocation actually resolves.)
pub(crate) fn is_known_artifact(name: &str) -> bool {
    matches!(
        name,
        "train_step" | "calibrate" | "score_dense" | "score_masked" | "mask_fwd_grad" | "lora_step"
    ) || if let Some(rest) = name.strip_prefix("decode_verify_") {
        // must not fall through to the plain-decode parse: a malformed
        // verify name would misparse as alloc `verify_…`
        parse_verify(rest, name).is_ok()
    } else if let Some(rest) = name.strip_prefix("decode_paged_") {
        // same trap for a malformed paged name (alloc `paged_…`)
        parse_paged(rest, name).is_ok()
    } else {
        name.strip_prefix("prefill_")
            .or_else(|| name.strip_prefix("decode_"))
            .is_some_and(|rest| parse_serving(rest, name).is_ok())
    }
}

/// Split `"<alloc>_b<B>"` into (alloc, B).
fn parse_serving(rest: &str, full: &str) -> Result<(String, usize)> {
    let pos = rest
        .rfind("_b")
        .ok_or_else(|| crate::anyhow!("bad serving artifact name `{full}`"))?;
    let alloc = rest[..pos].to_string();
    let batch: usize = rest[pos + 2..]
        .parse()
        .map_err(|_| crate::anyhow!("bad batch in artifact name `{full}`"))?;
    if alloc.is_empty() || batch == 0 {
        return Err(crate::anyhow!("bad serving artifact name `{full}`"));
    }
    Ok((alloc, batch))
}

/// Split `"<alloc>_b<B>_blk<L>x<N>"` into (alloc, B, block_len, num_blocks).
fn parse_paged(rest: &str, full: &str) -> Result<(String, usize, usize, usize)> {
    let pos = rest
        .rfind("_blk")
        .ok_or_else(|| crate::anyhow!("bad paged artifact name `{full}` (missing _blk)"))?;
    let (bl_s, nb_s) = rest[pos + 4..]
        .split_once('x')
        .ok_or_else(|| crate::anyhow!("bad pool geometry in artifact name `{full}`"))?;
    let block_len: usize = bl_s
        .parse()
        .map_err(|_| crate::anyhow!("bad block_len in artifact name `{full}`"))?;
    let num_blocks: usize = nb_s
        .parse()
        .map_err(|_| crate::anyhow!("bad num_blocks in artifact name `{full}`"))?;
    let (alloc, batch) = parse_serving(&rest[..pos], full)?;
    if block_len == 0 || num_blocks < 2 {
        // block 0 is the reserved scratch block — a usable pool needs ≥ 2
        return Err(crate::anyhow!("degenerate pool geometry in artifact name `{full}`"));
    }
    Ok((alloc, batch, block_len, num_blocks))
}

/// Split `"<alloc>_b<B>_blk<L>x<N>_k<W>"` into
/// (alloc, B, block_len, num_blocks, window).
fn parse_verify(rest: &str, full: &str) -> Result<(String, usize, usize, usize, usize)> {
    let pos = rest
        .rfind("_k")
        .ok_or_else(|| crate::anyhow!("bad verify artifact name `{full}` (missing _k)"))?;
    let window: usize = rest[pos + 2..]
        .parse()
        .map_err(|_| crate::anyhow!("bad window in artifact name `{full}`"))?;
    let (alloc, batch, block_len, num_blocks) = parse_paged(&rest[..pos], full)?;
    if window < 2 {
        // a 1-token window is just the plain paged decode step
        return Err(crate::anyhow!("degenerate verify window in artifact name `{full}`"));
    }
    Ok((alloc, batch, block_len, num_blocks, window))
}

/// Resolve a serving allocation by name (mirrors aot.py:resolve_alloc),
/// dropping the plan provenance. Precedence: configs/allocations →
/// artifacts/allocations → computed (`dense` / `uniform-R` / `ara-R`
/// heuristic). Files at either location may be versioned
/// `CompressionPlan` documents **or** legacy bare-`Allocation` JSON.
pub fn resolve_alloc(cfg: &ModelCfg, paths: &Paths, alloc_name: &str) -> Result<Allocation> {
    resolve_plan(cfg, paths, alloc_name).map(|p| p.allocation)
}

/// Like [`resolve_alloc`], but keeps the [`CompressionPlan`] wrapper so
/// callers (the serving engine front door) can thread provenance through.
/// Legacy files and computed fallbacks come back as unprovenanced plans
/// (`schema_version` 0).
pub fn resolve_plan(
    cfg: &ModelCfg,
    paths: &Paths,
    alloc_name: &str,
) -> Result<CompressionPlan> {
    let cfg_path = paths
        .configs
        .join("allocations")
        .join(format!("{}.{}.json", cfg.name, alloc_name));
    if cfg_path.exists() {
        return load_plan_with_ratio(cfg, &cfg_path);
    }
    let art_path = paths
        .artifacts
        .join("allocations")
        .join(format!("{}.{}.json", cfg.name, alloc_name));
    if art_path.exists() {
        return load_plan_with_ratio(cfg, &art_path);
    }
    let alloc = match crate::compress::computed_alloc(cfg, alloc_name) {
        Some(a) => a?,
        None => {
            return Err(crate::anyhow!(
                "allocation `{alloc_name}` for {} not found (looked in {:?} and {:?})",
                cfg.name,
                cfg_path,
                art_path
            ));
        }
    };
    // dump the resolved allocation for inspection / reuse (best effort)
    if alloc.save(&art_path).is_err() {
        eprintln!("[programs] could not write {art_path:?} (read-only checkout?)");
    }
    let achieved = crate::model::alloc_ratio(cfg, &alloc);
    Ok(CompressionPlan::legacy("computed", alloc, achieved))
}

/// Load a plan (or legacy allocation) file, backfilling the achieved
/// ratio on legacy wraps now that a `ModelCfg` is at hand.
fn load_plan_with_ratio(cfg: &ModelCfg, path: &std::path::Path) -> Result<CompressionPlan> {
    let mut plan = CompressionPlan::load(path)?;
    if !plan.provenanced() {
        let achieved = crate::model::alloc_ratio(cfg, &plan.allocation);
        plan.achieved = achieved;
        plan.target = achieved;
    }
    Ok(plan)
}

// ---------------------------------------------------------------------------
// Graph construction
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, PartialEq, Eq)]
enum LinearMode {
    /// Dense weights; `y = x·Wᵀ`.
    Dense,
    /// Dense weights, capturing per-module input Grams `H = xᵀx`.
    Calibrate,
    /// Masked full-rank factors (`.u`/`.v` + `mask:`), optional LoRA path.
    Factored { lora: bool },
    /// Allocation-specialized truncated factors or dense (serving graphs).
    Alloc,
}

/// Shared graph-building state for one artifact.
struct Net<'a> {
    cfg: &'a ModelCfg,
    g: Graph,
    specs: Vec<TensorSpec>,
    params: HashMap<String, Id>,
    caps: HashMap<String, Id>,
    gram_memo: HashMap<Id, Id>,
    mode: LinearMode,
}

impl<'a> Net<'a> {
    fn new(cfg: &'a ModelCfg, mode: LinearMode) -> Net<'a> {
        Net {
            cfg,
            g: Graph::default(),
            specs: Vec::new(),
            params: HashMap::new(),
            caps: HashMap::new(),
            gram_memo: HashMap::new(),
            mode,
        }
    }

    fn input_f32(&mut self, name: &str, shape: &[usize]) -> Id {
        let id = self.g.input(shape, DType::F32);
        self.specs.push(TensorSpec {
            name: name.to_string(),
            shape: shape.to_vec(),
            dtype: "f32".to_string(),
        });
        self.params.insert(name.to_string(), id);
        id
    }

    fn input_i32(&mut self, name: &str, shape: &[usize]) -> Id {
        let id = self.g.input(shape, DType::I32);
        self.specs.push(TensorSpec {
            name: name.to_string(),
            shape: shape.to_vec(),
            dtype: "i32".to_string(),
        });
        self.params.insert(name.to_string(), id);
        id
    }

    /// Packed int8 weight input (quantized serving path). The manifest
    /// shape is the same logical (rows, cols) the f32 factor would have;
    /// only the dtype differs, so the weight-upload prefix stays aligned
    /// across the decode/paged/verify graphs of one plan.
    fn input_q8(&mut self, name: &str, shape: &[usize]) -> Id {
        let id = self.g.input(shape, DType::Q8);
        self.specs.push(TensorSpec {
            name: name.to_string(),
            shape: shape.to_vec(),
            dtype: "q8".to_string(),
        });
        self.params.insert(name.to_string(), id);
        id
    }

    fn p(&self, name: &str) -> Id {
        *self
            .params
            .get(name)
            .unwrap_or_else(|| panic!("missing param `{name}` in graph builder"))
    }

    fn add_aux_inputs(&mut self) {
        for (name, shape) in aux_param_shapes(self.cfg) {
            self.input_f32(&name, &shape);
        }
    }

    fn add_dense_module_inputs(&mut self) {
        for d in module_dims(self.cfg) {
            self.input_f32(&d.name, &[d.m, d.n]);
        }
    }

    fn add_factored_module_inputs(&mut self) {
        for d in module_dims(self.cfg) {
            let r = d.r_full();
            self.input_f32(&format!("{}.u", d.name), &[d.m, r]);
            self.input_f32(&format!("{}.v", d.name), &[r, d.n]);
        }
        for d in module_dims(self.cfg) {
            self.input_f32(&format!("mask:{}", d.name), &[d.r_full()]);
        }
    }

    fn add_alloc_module_inputs(&mut self, alloc: &Allocation) {
        for d in module_dims(self.cfg) {
            match alloc.get(&d.name) {
                ModuleAlloc::Dense => {
                    self.input_f32(&d.name, &[d.m, d.n]);
                }
                // only the SVD factors are quantized — dense-kept modules
                // stay f32 (the recipe composes with the rank allocation)
                ModuleAlloc::Rank(k) if alloc.quant.is_some() => {
                    self.input_q8(&format!("{}.u", d.name), &[d.m, k]);
                    self.input_q8(&format!("{}.v", d.name), &[k, d.n]);
                }
                ModuleAlloc::Rank(k) => {
                    self.input_f32(&format!("{}.u", d.name), &[d.m, k]);
                    self.input_f32(&format!("{}.v", d.name), &[k, d.n]);
                }
            }
        }
    }

    /// Apply compressible module `name` to `x` (rows, n) → (rows, m),
    /// mirroring `model.py:_linear` under the current mode.
    fn linear(&mut self, name: &str, x: Id) -> Id {
        match self.mode {
            LinearMode::Dense => {
                let w = self.p(name);
                self.g.matmul(x, w, false, true)
            }
            LinearMode::Calibrate => {
                // wq/wk/wv (and wgate/wup) share the same activation; compute
                // the Gram once per distinct input and alias later captures
                // through stop_grad (a copy) so calibrate's output ids stay
                // unique for the evaluator.
                let memo = self.gram_memo.get(&x).copied();
                let h = match memo {
                    Some(g0) => self.g.stop_grad(g0),
                    None => {
                        let h = self.g.matmul(x, x, true, false);
                        self.gram_memo.insert(x, h);
                        h
                    }
                };
                self.caps.insert(name.to_string(), h);
                let w = self.p(name);
                self.g.matmul(x, w, false, true)
            }
            LinearMode::Factored { lora } => {
                let u = self.p(&format!("{name}.u"));
                let v = self.p(&format!("{name}.v"));
                let m = self.p(&format!("mask:{name}"));
                let t = self.g.matmul(x, v, false, true);
                let tm = self.g.mul(t, m);
                let mut y = self.g.matmul(tm, u, false, true);
                if lora {
                    let a = self.p(&format!("lora_a:{name}"));
                    let b = self.p(&format!("lora_b:{name}"));
                    let xa = self.g.matmul(x, a, false, true);
                    let xab = self.g.matmul(xa, b, false, true);
                    y = self.g.add(y, xab);
                }
                y
            }
            LinearMode::Alloc => {
                if self.params.contains_key(name) {
                    let w = self.p(name);
                    self.g.matmul(x, w, false, true)
                } else {
                    let u = self.p(&format!("{name}.u"));
                    let v = self.p(&format!("{name}.v"));
                    if self.g.dtype(v) == DType::Q8 {
                        // packed factors: both matmuls run the int8 kernel
                        // (x · Wᵀ with W stored (rows_out, k_in)) — bitwise
                        // equal to the f32 pair over dequantized weights
                        let t = self.g.matmul_q(x, v);
                        self.g.matmul_q(t, u)
                    } else {
                        let t = self.g.matmul(x, v, false, true);
                        self.g.matmul(t, u, false, true)
                    }
                }
            }
        }
    }

    /// RMSNorm over the last dim of a 2-D activation (rows, d).
    fn rmsnorm(&mut self, x: Id, gain: Id) -> Id {
        let d = self.g.shape(x)[1];
        let x2 = self.g.mul(x, x);
        let ssum = self.g.reduce_sum_keep(x2, 1);
        let inv_d = self.g.scalar(1.0 / d as f32);
        let ms = self.g.mul(ssum, inv_d);
        let eps = self.g.scalar(1e-6);
        let mse = self.g.add(ms, eps);
        let inv = self.g.rsqrt(mse);
        let xn = self.g.mul(x, inv);
        self.g.mul(xn, gain)
    }

    /// Rotary embeddings on (b, t, h, dh) with positions (pb, t) f32
    /// (pb broadcasts against b).
    fn rope(&mut self, x: Id, pos: Id) -> Id {
        let dh = *self.g.shape(x).last().unwrap();
        let half = dh / 2;
        let theta = self.cfg.rope_theta;
        let freqs: Vec<f32> = (0..half)
            .map(|i| (1.0 / theta.powf(i as f64 * 2.0 / dh as f64)) as f32)
            .collect();
        let fq = self.g.constant(Tensor::from_vec(&[half], freqs));
        let ps = self.g.shape(pos).to_vec();
        let p3 = self.g.reshape(pos, &[ps[0], ps[1], 1]);
        let ang = self.g.mul(p3, fq); // (pb, t, half)
        let cos = self.g.cos(ang);
        let sin = self.g.sin(ang);
        let cos4 = self.g.reshape(cos, &[ps[0], ps[1], 1, half]);
        let sin4 = self.g.reshape(sin, &[ps[0], ps[1], 1, half]);
        let x1 = self.g.slice(x, 3, 0, half);
        let x2 = self.g.slice(x, 3, half, half);
        let a = self.g.mul(x1, cos4);
        let b = self.g.mul(x2, sin4);
        let lo = self.g.sub(a, b);
        let c = self.g.mul(x1, sin4);
        let d2 = self.g.mul(x2, cos4);
        let hi = self.g.add(c, d2);
        self.g.concat(&[lo, hi], 3)
    }

    /// GQA repeat (b, t, nkv, dh) → (b, t, nh, dh) via broadcast.
    fn repeat_heads(&mut self, x: Id, rep: usize) -> Id {
        if rep == 1 {
            return x;
        }
        let s = self.g.shape(x).to_vec(); // (b, t, nkv, dh)
        let r5 = self.g.reshape(x, &[s[0], s[1], s[2], 1, s[3]]);
        let b5 = self.g.broadcast(r5, &[s[0], s[1], s[2], rep, s[3]]);
        self.g.reshape(b5, &[s[0], s[1], s[2] * rep, s[3]])
    }

    /// Softmax over the last axis of a 3-D tensor (stop-grad shifted).
    fn softmax3(&mut self, x: Id) -> Id {
        let m = self.g.reduce_max_keep(x, 2);
        let ms = self.g.stop_grad(m);
        let sh = self.g.sub(x, ms);
        let e = self.g.exp(sh);
        let s = self.g.reduce_sum_keep(e, 2);
        self.g.div(e, s)
    }

    /// Masked fill: x·m + (1-m)·(-1e30), for 0/1 mask `m`.
    fn mask_fill(&mut self, x: Id, m: Id) -> Id {
        let one = self.g.scalar(1.0);
        let inv = self.g.sub(one, m);
        let ninf = self.g.scalar(-1e30);
        let fill = self.g.mul(inv, ninf);
        let keep = self.g.mul(x, m);
        self.g.add(keep, fill)
    }

    /// Attention over packed heads (bh, t, dh) with an explicit 0/1 mask
    /// broadcastable against the (bh, t, t) score matrix.
    fn masked_attention(&mut self, qp: Id, kp: Id, vp: Id, scale: f32, mask: Id) -> Id {
        let raw = self.g.bmm(qp, kp, false, true); // (bh, t, t)
        let sc = self.g.scalar(scale);
        let scores = self.g.mul(raw, sc);
        let masked = self.mask_fill(scores, mask);
        let p = self.softmax3(masked);
        self.g.bmm(p, vp, false, false)
    }

    /// Causal attention over packed heads (bh, t, dh), ref.py semantics.
    fn causal_attention(&mut self, qp: Id, kp: Id, vp: Id, scale: f32) -> Id {
        let t = self.g.shape(qp)[1];
        let mask = self.causal_mask_const(t);
        self.masked_attention(qp, kp, vp, scale, mask)
    }

    /// Baked lower-triangular 0/1 mask (1, t, t).
    fn causal_mask_const(&mut self, t: usize) -> Id {
        let mut tril = Tensor::zeros(&[1, t, t]);
        for i in 0..t {
            for j in 0..=i {
                tril.data[i * t + j] = 1.0;
            }
        }
        self.g.constant(tril)
    }

    /// One transformer block over (b, t, d), mirroring `model.py:_block`.
    fn block(&mut self, layer: usize, h: Id, pos: Id) -> Id {
        let cfg = self.cfg;
        let (b, t, d) = {
            let s = self.g.shape(h);
            (s[0], s[1], s[2])
        };
        let (nh, nkv, dh) = (cfg.n_heads, cfg.n_kv_heads, cfg.head_dim());
        let pfx = format!("layers.{layer}.");

        let h2 = self.g.reshape(h, &[b * t, d]);
        let ln1 = self.p(&format!("{pfx}ln1"));
        let x2 = self.rmsnorm(h2, ln1);
        let q0 = self.linear(&format!("{pfx}attn.wq"), x2);
        let k0 = self.linear(&format!("{pfx}attn.wk"), x2);
        let v0 = self.linear(&format!("{pfx}attn.wv"), x2);
        let mut q = self.g.reshape(q0, &[b, t, nh, dh]);
        let mut k = self.g.reshape(k0, &[b, t, nkv, dh]);
        let v = self.g.reshape(v0, &[b, t, nkv, dh]);
        if cfg.family == "qwen" {
            let qn = self.p(&format!("{pfx}qnorm"));
            let kn = self.p(&format!("{pfx}knorm"));
            let qf = self.g.reshape(q, &[b * t * nh, dh]);
            let qn2 = self.rmsnorm(qf, qn);
            q = self.g.reshape(qn2, &[b, t, nh, dh]);
            let kf = self.g.reshape(k, &[b * t * nkv, dh]);
            let kn2 = self.rmsnorm(kf, kn);
            k = self.g.reshape(kn2, &[b, t, nkv, dh]);
        }
        q = self.rope(q, pos);
        k = self.rope(k, pos);
        let rep = nh / nkv;
        let kr = self.repeat_heads(k, rep);
        let vr = self.repeat_heads(v, rep);
        let qt = self.g.transpose(q, &[0, 2, 1, 3]);
        let kt = self.g.transpose(kr, &[0, 2, 1, 3]);
        let vt = self.g.transpose(vr, &[0, 2, 1, 3]);
        let qp = self.g.reshape(qt, &[b * nh, t, dh]);
        let kp = self.g.reshape(kt, &[b * nh, t, dh]);
        let vp = self.g.reshape(vt, &[b * nh, t, dh]);
        let o = self.causal_attention(qp, kp, vp, (dh as f32).powf(-0.5));
        let o4 = self.g.reshape(o, &[b, nh, t, dh]);
        let ot = self.g.transpose(o4, &[0, 2, 1, 3]);
        let o2 = self.g.reshape(ot, &[b * t, d]);
        let attn = self.linear(&format!("{pfx}attn.wo"), o2);
        let attn3 = self.g.reshape(attn, &[b, t, d]);
        let h = self.g.add(h, attn3);

        let h2 = self.g.reshape(h, &[b * t, d]);
        let ln2 = self.p(&format!("{pfx}ln2"));
        let x2 = self.rmsnorm(h2, ln2);
        let gt = self.linear(&format!("{pfx}mlp.wgate"), x2);
        let up = self.linear(&format!("{pfx}mlp.wup"), x2);
        let sg = self.g.sigmoid(gt);
        let silu = self.g.mul(gt, sg);
        let y = self.g.mul(silu, up);
        let down = self.linear(&format!("{pfx}mlp.wdown"), y);
        let down3 = self.g.reshape(down, &[b, t, d]);
        self.g.add(h, down3)
    }

    /// Full forward: tokens (b, t) i32 → logits (b, t, vocab).
    fn forward(&mut self, tokens: Id) -> Id {
        let cfg = self.cfg;
        let (b, t) = {
            let s = self.g.shape(tokens);
            (s[0], s[1])
        };
        let d = cfg.d_model;
        let embed = self.p("embed");
        let mut h = self.g.gather(embed, tokens); // (b, t, d)
        let it = self.g.iota(t);
        let pos = self.g.reshape(it, &[1, t]); // broadcasts over b
        for layer in 0..cfg.n_layers {
            h = self.block(layer, h, pos);
        }
        let h2 = self.g.reshape(h, &[b * t, d]);
        let nf = self.p("norm_f");
        let hf = self.rmsnorm(h2, nf);
        let head = self.p("head");
        let logits2 = self.g.matmul(hf, head, false, true);
        self.g.reshape(logits2, &[b, t, cfg.vocab])
    }

    /// Per-position NLL (b, t) from logits and targets.
    fn nll(&mut self, logits: Id, targets: Id) -> Id {
        let s = self.g.shape(logits).to_vec(); // (b, t, v)
        let m = self.g.reduce_max_keep(logits, 2);
        let ms = self.g.stop_grad(m);
        let sh = self.g.sub(logits, ms);
        let e = self.g.exp(sh);
        let se = self.g.reduce_sum(e, 2); // (b, t)
        let lg = self.g.log(se);
        let m2 = self.g.reshape(ms, &[s[0], s[1]]);
        let lse = self.g.add(lg, m2);
        let picked = self.g.take_last(logits, targets);
        self.g.sub(lse, picked)
    }

    /// Mean of a (b, t) tensor → scalar.
    fn mean2(&mut self, x: Id) -> Id {
        let s = self.g.shape(x).to_vec();
        let n: usize = s.iter().product();
        let flat = self.g.reshape(x, &[n]);
        let sum = self.g.reduce_sum(flat, 0);
        let inv = self.g.scalar(1.0 / n as f32);
        self.g.mul(sum, inv)
    }

    fn finish(self, name: &str, outputs: Vec<Id>, out_names: Vec<String>) -> Program {
        debug_assert_eq!(outputs.len(), out_names.len());
        let manifest = Manifest {
            name: name.to_string(),
            inputs: self.specs,
            outputs: out_names,
        };
        let plan = ExecPlan::new(&self.g, &outputs);
        Program { graph: self.g, manifest, plan }
    }
}

// ---------------------------------------------------------------------------
// Full-sequence artifacts
// ---------------------------------------------------------------------------

fn train_step(cfg: &ModelCfg) -> Program {
    let mut net = Net::new(cfg, LinearMode::Dense);
    net.add_aux_inputs();
    net.add_dense_module_inputs();
    let weight_ids: Vec<Id> = net.specs.iter().map(|s| net.p(&s.name)).collect();
    let weight_names: Vec<String> = net.specs.iter().map(|s| s.name.clone()).collect();
    let tokens = net.input_i32("tokens", &[cfg.batch_train, cfg.seq_train]);
    let targets = net.input_i32("targets", &[cfg.batch_train, cfg.seq_train]);
    let logits = net.forward(tokens);
    let nll = net.nll(logits, targets);
    let loss = net.mean2(nll);
    let grads = append_gradients(&mut net.g, loss, &weight_ids);
    let mut outputs = vec![loss];
    outputs.extend(grads);
    let mut names = vec!["loss".to_string()];
    names.extend(weight_names.iter().map(|n| format!("grad:{n}")));
    net.finish("train_step", outputs, names)
}

fn calibrate(cfg: &ModelCfg) -> Program {
    let mut net = Net::new(cfg, LinearMode::Calibrate);
    net.add_aux_inputs();
    net.add_dense_module_inputs();
    let tokens = net.input_i32("tokens", &[cfg.batch_eval, cfg.seq_eval]);
    let logits = net.forward(tokens);
    let anchor = net.mean2(logits);
    let mut outputs = Vec::new();
    let mut names = Vec::new();
    for d in module_dims(cfg) {
        outputs.push(net.caps[&d.name]);
        names.push(format!("h:{}", d.name));
    }
    outputs.push(anchor);
    names.push("anchor".to_string());
    net.finish("calibrate", outputs, names)
}

fn score(cfg: &ModelCfg, masked: bool) -> Program {
    let mode = if masked { LinearMode::Factored { lora: false } } else { LinearMode::Dense };
    let mut net = Net::new(cfg, mode);
    net.add_aux_inputs();
    if masked {
        net.add_factored_module_inputs();
    } else {
        net.add_dense_module_inputs();
    }
    let tokens = net.input_i32("tokens", &[cfg.batch_eval, cfg.seq_eval]);
    let targets = net.input_i32("targets", &[cfg.batch_eval, cfg.seq_eval]);
    let logits = net.forward(tokens);
    let nll = net.nll(logits, targets);
    let name = if masked { "score_masked" } else { "score_dense" };
    net.finish(name, vec![nll], vec!["nll".to_string()])
}

fn mask_fwd_grad(cfg: &ModelCfg) -> Program {
    let mut net = Net::new(cfg, LinearMode::Factored { lora: false });
    net.add_aux_inputs();
    net.add_factored_module_inputs();
    let tokens = net.input_i32("tokens", &[cfg.batch_eval, cfg.seq_eval]);
    let targets = net.input_i32("targets", &[cfg.batch_eval, cfg.seq_eval]);
    let mask_ids: Vec<Id> = module_dims(cfg)
        .iter()
        .map(|d| net.p(&format!("mask:{}", d.name)))
        .collect();
    let logits = net.forward(tokens);
    let nll = net.nll(logits, targets);
    let loss = net.mean2(nll);
    let grads = append_gradients(&mut net.g, loss, &mask_ids);
    let mut outputs = vec![loss];
    outputs.extend(grads);
    let mut names = vec!["loss".to_string()];
    names.extend(module_dims(cfg).iter().map(|d| format!("grad:mask:{}", d.name)));
    net.finish("mask_fwd_grad", outputs, names)
}

fn lora_step(cfg: &ModelCfg) -> Program {
    let mut net = Net::new(cfg, LinearMode::Factored { lora: true });
    net.add_aux_inputs();
    net.add_factored_module_inputs();
    let lr = cfg.lora_rank;
    let mut lora_ids = Vec::new();
    let mut lora_names = Vec::new();
    for d in module_dims(cfg) {
        let a = net.input_f32(&format!("lora_a:{}", d.name), &[lr, d.n]);
        let b = net.input_f32(&format!("lora_b:{}", d.name), &[d.m, lr]);
        lora_ids.push(a);
        lora_ids.push(b);
        lora_names.push(format!("lora_a:{}", d.name));
        lora_names.push(format!("lora_b:{}", d.name));
    }
    let tokens = net.input_i32("tokens", &[cfg.batch_train, cfg.seq_train]);
    let targets = net.input_i32("targets", &[cfg.batch_train, cfg.seq_train]);
    let logits = net.forward(tokens);
    let nll = net.nll(logits, targets);
    let loss = net.mean2(nll);
    let grads = append_gradients(&mut net.g, loss, &lora_ids);
    let mut outputs = vec![loss];
    outputs.extend(grads);
    let mut names = vec!["loss".to_string()];
    names.extend(lora_names.iter().map(|n| format!("grad:{n}")));
    net.finish("lora_step", outputs, names)
}

// ---------------------------------------------------------------------------
// Serving artifacts (allocation-specialized, KV-cached)
// ---------------------------------------------------------------------------

fn cache_names(cfg: &ModelCfg) -> Vec<String> {
    let mut out = Vec::new();
    for i in 0..cfg.n_layers {
        out.push(format!("kcache.{i}"));
        out.push(format!("vcache.{i}"));
    }
    out
}

/// Prefill with the **left-pad masking contract**: each prompt occupies the
/// rightmost `lens[i]` slots of its row (slots `[0, t-lens[i])` are
/// padding). Real tokens get rope positions `0..lens[i]`; pad slots are
/// excluded from attention as keys, so row `i`'s outputs depend only on its
/// real tokens. KV caches are written at the padded slot positions — the
/// decode graph masks slots below `starts[i] = t - lens[i]`. The final-slot
/// logits (`t-1`) always belong to the last real token. A full-length
/// prompt (`lens[i] = t`) reproduces the original unmasked prefill math.
fn prefill(cfg: &ModelCfg, alloc: &Allocation, batch: usize, name: &str) -> Program {
    let mut net = Net::new(cfg, LinearMode::Alloc);
    net.add_aux_inputs();
    net.add_alloc_module_inputs(alloc);
    let (b, t) = (batch, cfg.prefill_len);
    let (d, nh, nkv, dh) = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim());
    let s_max = cfg.max_decode_seq;
    let tokens = net.input_i32("tokens", &[b, t]);
    let lens = net.input_i32("lens", &[b]);

    let embed = net.p("embed");
    let mut h = net.g.gather(embed, tokens); // (b, t, d)
    // positions: slot j of row i is token position j - (t - lens[i]); pad
    // slots get negative positions (their rope output is masked out below)
    let it = net.g.iota(t);
    let row = net.g.reshape(it, &[1, t]);
    let lens_f = net.g.cast_f32(lens);
    let lcol = net.g.reshape(lens_f, &[b, 1]);
    let t_s = net.g.scalar(t as f32);
    let off = net.g.sub(lcol, t_s); // (b, 1) = -(pad count)
    let pos = net.g.add(row, off); // (b, t)
    // attention mask: causal AND key slot is a real token (j' ≥ t - lens[i])
    let padf = net.g.sub(t_s, lcol); // (b, 1)
    let ramp = net.g.iota(t);
    let below = net.g.less(ramp, padf); // (b, t): 1 on pad slots
    let one = net.g.scalar(1.0);
    let kvalid = net.g.sub(one, below); // (b, t): 1 on real slots
    let kv3 = net.g.reshape(kvalid, &[b, 1, t]);
    let tril = net.causal_mask_const(t); // (1, t, t)
    let m3 = net.g.mul(tril, kv3); // (b, t, t)
    let m4 = net.g.reshape(m3, &[b, 1, t, t]);
    let mb = net.g.broadcast(m4, &[b, nh, t, t]);
    let mask = net.g.reshape(mb, &[b * nh, t, t]);
    let mut caches = Vec::new();
    for layer in 0..cfg.n_layers {
        let pfx = format!("layers.{layer}.");
        let h2 = net.g.reshape(h, &[b * t, d]);
        let ln1 = net.p(&format!("{pfx}ln1"));
        let x2 = net.rmsnorm(h2, ln1);
        let q0 = net.linear(&format!("{pfx}attn.wq"), x2);
        let k0 = net.linear(&format!("{pfx}attn.wk"), x2);
        let v0 = net.linear(&format!("{pfx}attn.wv"), x2);
        let mut q = net.g.reshape(q0, &[b, t, nh, dh]);
        let mut k = net.g.reshape(k0, &[b, t, nkv, dh]);
        let v = net.g.reshape(v0, &[b, t, nkv, dh]);
        if cfg.family == "qwen" {
            let qn = net.p(&format!("{pfx}qnorm"));
            let kn = net.p(&format!("{pfx}knorm"));
            let qf = net.g.reshape(q, &[b * t * nh, dh]);
            let qn2 = net.rmsnorm(qf, qn);
            q = net.g.reshape(qn2, &[b, t, nh, dh]);
            let kf = net.g.reshape(k, &[b * t * nkv, dh]);
            let kn2 = net.rmsnorm(kf, kn);
            k = net.g.reshape(kn2, &[b, t, nkv, dh]);
        }
        q = net.rope(q, pos);
        k = net.rope(k, pos);
        let rep = nh / nkv;
        let kr = net.repeat_heads(k, rep);
        let vr = net.repeat_heads(v, rep);
        let qt = net.g.transpose(q, &[0, 2, 1, 3]);
        let kt = net.g.transpose(kr, &[0, 2, 1, 3]);
        let vt = net.g.transpose(vr, &[0, 2, 1, 3]);
        let qp = net.g.reshape(qt, &[b * nh, t, dh]);
        let kp = net.g.reshape(kt, &[b * nh, t, dh]);
        let vp = net.g.reshape(vt, &[b * nh, t, dh]);
        let o = net.masked_attention(qp, kp, vp, (dh as f32).powf(-0.5), mask);
        let o4 = net.g.reshape(o, &[b, nh, t, dh]);
        let ot = net.g.transpose(o4, &[0, 2, 1, 3]);
        let o2 = net.g.reshape(ot, &[b * t, d]);
        let attn = net.linear(&format!("{pfx}attn.wo"), o2);
        let attn3 = net.g.reshape(attn, &[b, t, d]);
        h = net.g.add(h, attn3);

        let h2 = net.g.reshape(h, &[b * t, d]);
        let ln2 = net.p(&format!("{pfx}ln2"));
        let x2 = net.rmsnorm(h2, ln2);
        let gt = net.linear(&format!("{pfx}mlp.wgate"), x2);
        let up = net.linear(&format!("{pfx}mlp.wup"), x2);
        let sg = net.g.sigmoid(gt);
        let silu = net.g.mul(gt, sg);
        let y = net.g.mul(silu, up);
        let down = net.linear(&format!("{pfx}mlp.wdown"), y);
        let down3 = net.g.reshape(down, &[b, t, d]);
        h = net.g.add(h, down3);

        // cache k/v (post-rope, pre-repeat): (b,t,nkv,dh) → (b,nkv,S,dh)
        // — pad slots carry garbage rows; decode masks slots below `starts`
        let kc0 = net.g.transpose(k, &[0, 2, 1, 3]);
        let kc = net.g.pad_zero(kc0, 2, 0, s_max);
        let vc0 = net.g.transpose(v, &[0, 2, 1, 3]);
        let vc = net.g.pad_zero(vc0, 2, 0, s_max);
        caches.push(kc);
        caches.push(vc);
    }
    let hl = net.g.slice(h, 1, t - 1, 1); // (b, 1, d)
    let h2 = net.g.reshape(hl, &[b, d]);
    let nf = net.p("norm_f");
    let hf = net.rmsnorm(h2, nf);
    let head = net.p("head");
    let logits = net.g.matmul(hf, head, false, true); // (b, vocab)

    let mut outputs = vec![logits];
    outputs.extend(caches);
    let mut names = vec!["logits".to_string()];
    names.extend(cache_names(cfg));
    net.finish(name, outputs, names)
}

/// One decode step over a slot window per sequence: `lens[i]` is the cache
/// slot the new token is written to (and the highest slot attended), while
/// `starts[i]` is the first valid slot — slots below it hold the prefill's
/// left-pad garbage and are masked out. The token's rope position is the
/// *relative* `lens[i] - starts[i]`, so a request prefilled with `n` real
/// tokens decodes at positions `n, n+1, …` regardless of where its window
/// sits in the cache. `starts = 0` reproduces the original decode math.
fn decode(cfg: &ModelCfg, alloc: &Allocation, batch: usize, name: &str) -> Program {
    let mut net = Net::new(cfg, LinearMode::Alloc);
    net.add_aux_inputs();
    net.add_alloc_module_inputs(alloc);
    let b = batch;
    let (d, nh, nkv, dh) = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim());
    let s_max = cfg.max_decode_seq;
    let mut cache_in = Vec::new();
    for i in 0..cfg.n_layers {
        let kc = net.input_f32(&format!("kcache.{i}"), &[b, nkv, s_max, dh]);
        let vc = net.input_f32(&format!("vcache.{i}"), &[b, nkv, s_max, dh]);
        cache_in.push((kc, vc));
    }
    let tokens = net.input_i32("tokens", &[b]);
    let lens = net.input_i32("lens", &[b]);
    let starts = net.input_i32("starts", &[b]);

    let embed = net.p("embed");
    let mut h = net.g.gather(embed, tokens); // (b, d)
    let lens_f = net.g.cast_f32(lens); // (b,)
    let starts_f = net.g.cast_f32(starts); // (b,)
    let rel = net.g.sub(lens_f, starts_f); // (b,) rope position
    let pos = net.g.reshape(rel, &[b, 1]);
    // valid-slot window, shared by every layer: starts ≤ slot ≤ lens
    let one = net.g.scalar(1.0);
    let plus1 = net.g.add(lens_f, one); // (b,)
    let pl3 = net.g.reshape(plus1, &[b, 1, 1]);
    let ramp = net.g.iota(s_max);
    let hi = net.g.less(ramp, pl3); // (b, 1, s): slot ≤ lens
    let st3 = net.g.reshape(starts_f, &[b, 1, 1]);
    let below = net.g.less(ramp, st3); // (b, 1, s): slot < starts
    let lo = net.g.sub(one, below);
    let valid = net.g.mul(hi, lo); // (b, 1, s)
    let mut caches_out = Vec::new();
    for layer in 0..cfg.n_layers {
        let pfx = format!("layers.{layer}.");
        let ln1 = net.p(&format!("{pfx}ln1"));
        let x = net.rmsnorm(h, ln1); // (b, d)
        let q0 = net.linear(&format!("{pfx}attn.wq"), x);
        let k0 = net.linear(&format!("{pfx}attn.wk"), x);
        let v0 = net.linear(&format!("{pfx}attn.wv"), x);
        let mut q = net.g.reshape(q0, &[b, nh, dh]);
        let mut k = net.g.reshape(k0, &[b, nkv, dh]);
        let v = net.g.reshape(v0, &[b, nkv, dh]);
        if cfg.family == "qwen" {
            let qn = net.p(&format!("{pfx}qnorm"));
            let kn = net.p(&format!("{pfx}knorm"));
            let qf = net.g.reshape(q, &[b * nh, dh]);
            let qn2 = net.rmsnorm(qf, qn);
            q = net.g.reshape(qn2, &[b, nh, dh]);
            let kf = net.g.reshape(k, &[b * nkv, dh]);
            let kn2 = net.rmsnorm(kf, kn);
            k = net.g.reshape(kn2, &[b, nkv, dh]);
        }
        // rope on a singleton time axis at per-sequence position `lens`
        let q4 = net.g.reshape(q, &[b, 1, nh, dh]);
        let q4r = net.rope(q4, pos);
        q = net.g.reshape(q4r, &[b, nh, dh]);
        let k4 = net.g.reshape(k, &[b, 1, nkv, dh]);
        let k4r = net.rope(k4, pos);
        k = net.g.reshape(k4r, &[b, nkv, dh]);

        let (kc_in, vc_in) = cache_in[layer];
        let kc = net.g.update_at(kc_in, k, lens);
        let vc = net.g.update_at(vc_in, v, lens);
        caches_out.push(kc);
        caches_out.push(vc);

        // attend over cached positions ≤ lens
        let rep = nh / nkv;
        let (kr, vr) = if rep == 1 {
            (kc, vc)
        } else {
            let k5 = net.g.reshape(kc, &[b, nkv, 1, s_max, dh]);
            let kb = net.g.broadcast(k5, &[b, nkv, rep, s_max, dh]);
            let kr = net.g.reshape(kb, &[b, nh, s_max, dh]);
            let v5 = net.g.reshape(vc, &[b, nkv, 1, s_max, dh]);
            let vb = net.g.broadcast(v5, &[b, nkv, rep, s_max, dh]);
            let vr = net.g.reshape(vb, &[b, nh, s_max, dh]);
            (kr, vr)
        };
        let q3 = net.g.reshape(q, &[b * nh, 1, dh]);
        let kr3 = net.g.reshape(kr, &[b * nh, s_max, dh]);
        let raw = net.g.bmm(q3, kr3, false, true); // (b·nh, 1, s)
        let raw3 = net.g.reshape(raw, &[b, nh, s_max]);
        let sc = net.g.scalar((dh as f32).powf(-0.5));
        let scores = net.g.mul(raw3, sc);
        let masked = net.mask_fill(scores, valid);
        let p = net.softmax3(masked); // (b, nh, s)
        let p3 = net.g.reshape(p, &[b * nh, 1, s_max]);
        let vr3 = net.g.reshape(vr, &[b * nh, s_max, dh]);
        let o = net.g.bmm(p3, vr3, false, false); // (b·nh, 1, dh)
        let o2 = net.g.reshape(o, &[b, d]);
        let attn = net.linear(&format!("{pfx}attn.wo"), o2);
        h = net.g.add(h, attn);

        let ln2 = net.p(&format!("{pfx}ln2"));
        let x = net.rmsnorm(h, ln2);
        let gt = net.linear(&format!("{pfx}mlp.wgate"), x);
        let up = net.linear(&format!("{pfx}mlp.wup"), x);
        let sg = net.g.sigmoid(gt);
        let silu = net.g.mul(gt, sg);
        let y = net.g.mul(silu, up);
        let down = net.linear(&format!("{pfx}mlp.wdown"), y);
        h = net.g.add(h, down);
    }
    let nf = net.p("norm_f");
    let hf = net.rmsnorm(h, nf);
    let head = net.p("head");
    let logits = net.g.matmul(hf, head, false, true); // (b, vocab)

    let mut outputs = vec![logits];
    outputs.extend(caches_out);
    let mut names = vec!["logits".to_string()];
    names.extend(cache_names(cfg));
    net.finish(name, outputs, names)
}

fn pool_names(cfg: &ModelCfg) -> Vec<String> {
    let mut out = Vec::new();
    for i in 0..cfg.n_layers {
        out.push(format!("kpool.{i}"));
        out.push(format!("vpool.{i}"));
    }
    out
}

/// One decode step over a **block-paged** KV pool (the continuous-batching
/// scheduler's hot path — see `serving/kvpool.rs`). Per layer the pool is a
/// 2-D row table `(num_blocks·block_len, nkv·dh)` whose row `r` holds every
/// kv-head's vector for token slot `r % block_len` of block `r / block_len`.
/// Inputs per slot: the token, `lens[i]` — the slot's **virtual** write
/// position (= number of tokens already in its window; also the highest
/// virtual slot attended, rope position `lens[i]` — the paged layout drops
/// the contiguous path's left-pad, so `starts` is always 0 and is omitted),
/// `rows[i]` — the precomputed physical pool row
/// `btable[i][lens[i]/block_len]·block_len + lens[i]%block_len` the new K/V
/// is written to (`UpdateRows`), and `btable[i]` — the block table the
/// attention window is gathered through (`GatherBlocks`). Virtual slots
/// above `lens[i]` are masked, so stale rows in partially-filled or padded
/// blocks never contribute. With `block_len = max_decode_seq` (one block
/// per sequence) the gathered window is that block verbatim and every
/// token stream is bitwise identical to the contiguous `decode` graph —
/// the degenerate-config parity anchor pinned in `tests/scheduler.rs`.
fn decode_paged(
    cfg: &ModelCfg,
    alloc: &Allocation,
    batch: usize,
    block_len: usize,
    num_blocks: usize,
    name: &str,
) -> Program {
    let mut net = Net::new(cfg, LinearMode::Alloc);
    net.add_aux_inputs();
    net.add_alloc_module_inputs(alloc);
    let b = batch;
    let (d, nh, nkv, dh) = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim());
    let bps = cfg.max_decode_seq.div_ceil(block_len); // blocks per sequence
    let s = bps * block_len; // gathered virtual window length
    let rows = num_blocks * block_len;
    let width = nkv * dh;
    let mut pool_in = Vec::new();
    for i in 0..cfg.n_layers {
        let kp = net.input_f32(&format!("kpool.{i}"), &[rows, width]);
        let vp = net.input_f32(&format!("vpool.{i}"), &[rows, width]);
        pool_in.push((kp, vp));
    }
    let tokens = net.input_i32("tokens", &[b]);
    let lens = net.input_i32("lens", &[b]);
    let wrow = net.input_i32("rows", &[b]);
    let btable = net.input_i32("btable", &[b, bps]);

    let embed = net.p("embed");
    let mut h = net.g.gather(embed, tokens); // (b, d)
    let lens_f = net.g.cast_f32(lens); // (b,) = rope position (starts = 0)
    let pos = net.g.reshape(lens_f, &[b, 1]);
    // valid-slot window, shared by every layer: virtual slot ≤ lens
    let one = net.g.scalar(1.0);
    let plus1 = net.g.add(lens_f, one); // (b,)
    let pl3 = net.g.reshape(plus1, &[b, 1, 1]);
    let ramp = net.g.iota(s);
    let valid = net.g.less(ramp, pl3); // (b, 1, s): slot ≤ lens
    let mut pools_out = Vec::new();
    for layer in 0..cfg.n_layers {
        let pfx = format!("layers.{layer}.");
        let ln1 = net.p(&format!("{pfx}ln1"));
        let x = net.rmsnorm(h, ln1); // (b, d)
        let q0 = net.linear(&format!("{pfx}attn.wq"), x);
        let k0 = net.linear(&format!("{pfx}attn.wk"), x);
        let v0 = net.linear(&format!("{pfx}attn.wv"), x);
        let mut q = net.g.reshape(q0, &[b, nh, dh]);
        let mut k = net.g.reshape(k0, &[b, nkv, dh]);
        let v = net.g.reshape(v0, &[b, nkv, dh]);
        if cfg.family == "qwen" {
            let qn = net.p(&format!("{pfx}qnorm"));
            let kn = net.p(&format!("{pfx}knorm"));
            let qf = net.g.reshape(q, &[b * nh, dh]);
            let qn2 = net.rmsnorm(qf, qn);
            q = net.g.reshape(qn2, &[b, nh, dh]);
            let kf = net.g.reshape(k, &[b * nkv, dh]);
            let kn2 = net.rmsnorm(kf, kn);
            k = net.g.reshape(kn2, &[b, nkv, dh]);
        }
        // rope on a singleton time axis at per-sequence virtual position
        let q4 = net.g.reshape(q, &[b, 1, nh, dh]);
        let q4r = net.rope(q4, pos);
        q = net.g.reshape(q4r, &[b, nh, dh]);
        let k4 = net.g.reshape(k, &[b, 1, nkv, dh]);
        let k4r = net.rope(k4, pos);
        k = net.g.reshape(k4r, &[b, nkv, dh]);

        // write the new k/v into the pool at the block-indexed rows, then
        // gather each slot's window back through its block table
        let (kp_in, vp_in) = pool_in[layer];
        let k2 = net.g.reshape(k, &[b, width]);
        let v2 = net.g.reshape(v, &[b, width]);
        let kp = net.g.update_rows(kp_in, k2, wrow);
        let vp = net.g.update_rows(vp_in, v2, wrow);
        pools_out.push(kp);
        pools_out.push(vp);
        let kc = net.g.gather_blocks(kp, btable, block_len, nkv); // (b,nkv,s,dh)
        let vc = net.g.gather_blocks(vp, btable, block_len, nkv);

        // attend over gathered virtual slots ≤ lens (identical math to the
        // contiguous decode graph from here on)
        let rep = nh / nkv;
        let (kr, vr) = if rep == 1 {
            (kc, vc)
        } else {
            let k5 = net.g.reshape(kc, &[b, nkv, 1, s, dh]);
            let kb = net.g.broadcast(k5, &[b, nkv, rep, s, dh]);
            let kr = net.g.reshape(kb, &[b, nh, s, dh]);
            let v5 = net.g.reshape(vc, &[b, nkv, 1, s, dh]);
            let vb = net.g.broadcast(v5, &[b, nkv, rep, s, dh]);
            let vr = net.g.reshape(vb, &[b, nh, s, dh]);
            (kr, vr)
        };
        let q3 = net.g.reshape(q, &[b * nh, 1, dh]);
        let kr3 = net.g.reshape(kr, &[b * nh, s, dh]);
        let raw = net.g.bmm(q3, kr3, false, true); // (b·nh, 1, s)
        let raw3 = net.g.reshape(raw, &[b, nh, s]);
        let sc = net.g.scalar((dh as f32).powf(-0.5));
        let scores = net.g.mul(raw3, sc);
        let masked = net.mask_fill(scores, valid);
        let p = net.softmax3(masked); // (b, nh, s)
        let p3 = net.g.reshape(p, &[b * nh, 1, s]);
        let vr3 = net.g.reshape(vr, &[b * nh, s, dh]);
        let o = net.g.bmm(p3, vr3, false, false); // (b·nh, 1, dh)
        let o2 = net.g.reshape(o, &[b, d]);
        let attn = net.linear(&format!("{pfx}attn.wo"), o2);
        h = net.g.add(h, attn);

        let ln2 = net.p(&format!("{pfx}ln2"));
        let x = net.rmsnorm(h, ln2);
        let gt = net.linear(&format!("{pfx}mlp.wgate"), x);
        let up = net.linear(&format!("{pfx}mlp.wup"), x);
        let sg = net.g.sigmoid(gt);
        let silu = net.g.mul(gt, sg);
        let y = net.g.mul(silu, up);
        let down = net.linear(&format!("{pfx}mlp.wdown"), y);
        h = net.g.add(h, down);
    }
    let nf = net.p("norm_f");
    let hf = net.rmsnorm(h, nf);
    let head = net.p("head");
    let logits = net.g.matmul(hf, head, false, true); // (b, vocab)

    let mut outputs = vec![logits];
    outputs.extend(pools_out);
    let mut names = vec!["logits".to_string()];
    names.extend(pool_names(cfg));
    net.finish(name, outputs, names)
}

/// Speculative **verify** pass over the paged pool: scores a `(b, W)` token
/// window in one call, where window slot `j` of sequence `i` sits at virtual
/// position `lens[i] + j`. Per layer all `W` new K/V rows are scattered at
/// `rows[i·W + j]` **before** the block-table gather, so within-window
/// attention (slot `j` attending to slots `< j` of the same round) reads the
/// freshly written rows. Per-position masking (`virtual slot ≤ lens[i] + j`)
/// gives each window slot exactly the prefix a sequential one-token decode
/// would see — and because every kernel reduces along axes that are
/// row-independent (matmul/rmsnorm/softmax rows, bmm dot products in fixed
/// block order), `logits[i, j]` is **bitwise identical** to the logits of
/// `decode_paged` fed the same prefix token-by-token. That equality is the
/// whole speculative-decoding contract (DESIGN.md §8): acceptance compares
/// target argmaxes computed here against draft proposals, so the accepted
/// stream can never diverge from plain decode. Non-speculative slots ride
/// along with window slots ≥ 1 writing to scratch rows (block 0) that are
/// never attended and get overwritten by later traffic.
fn decode_verify(
    cfg: &ModelCfg,
    alloc: &Allocation,
    batch: usize,
    block_len: usize,
    num_blocks: usize,
    window: usize,
    name: &str,
) -> Program {
    let mut net = Net::new(cfg, LinearMode::Alloc);
    net.add_aux_inputs();
    net.add_alloc_module_inputs(alloc);
    let (b, w) = (batch, window);
    let (d, nh, nkv, dh) = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim());
    let bps = cfg.max_decode_seq.div_ceil(block_len); // blocks per sequence
    let s = bps * block_len; // gathered virtual window length
    let rows = num_blocks * block_len;
    let width = nkv * dh;
    let mut pool_in = Vec::new();
    for i in 0..cfg.n_layers {
        let kp = net.input_f32(&format!("kpool.{i}"), &[rows, width]);
        let vp = net.input_f32(&format!("vpool.{i}"), &[rows, width]);
        pool_in.push((kp, vp));
    }
    let tokens = net.input_i32("tokens", &[b, w]);
    let lens = net.input_i32("lens", &[b]);
    let wrow = net.input_i32("rows", &[b * w]); // flat row-major (b, w)
    let btable = net.input_i32("btable", &[b, bps]);

    let embed = net.p("embed");
    let mut h = net.g.gather(embed, tokens); // (b, w, d)
    // window slot j of sequence i decodes at virtual position lens[i] + j
    let lens_f = net.g.cast_f32(lens);
    let lcol = net.g.reshape(lens_f, &[b, 1]);
    let it = net.g.iota(w);
    let jrow = net.g.reshape(it, &[1, w]);
    let pos = net.g.add(lcol, jrow); // (b, w)
    // per-position valid window: virtual slot ≤ lens[i] + j — exactly the
    // prefix a sequential one-token decode at that position would attend
    let one = net.g.scalar(1.0);
    let plus1 = net.g.add(pos, one); // (b, w)
    let pl3 = net.g.reshape(plus1, &[b, w, 1]);
    let ramp = net.g.iota(s);
    let valid = net.g.less(ramp, pl3); // (b, w, s)
    let v4 = net.g.reshape(valid, &[b, 1, w, s]);
    let vb = net.g.broadcast(v4, &[b, nh, w, s]);
    let mask = net.g.reshape(vb, &[b * nh, w, s]);
    let mut pools_out = Vec::new();
    for layer in 0..cfg.n_layers {
        let pfx = format!("layers.{layer}.");
        let h2 = net.g.reshape(h, &[b * w, d]);
        let ln1 = net.p(&format!("{pfx}ln1"));
        let x2 = net.rmsnorm(h2, ln1);
        let q0 = net.linear(&format!("{pfx}attn.wq"), x2);
        let k0 = net.linear(&format!("{pfx}attn.wk"), x2);
        let v0 = net.linear(&format!("{pfx}attn.wv"), x2);
        let mut q = net.g.reshape(q0, &[b, w, nh, dh]);
        let mut k = net.g.reshape(k0, &[b, w, nkv, dh]);
        let v = net.g.reshape(v0, &[b, w, nkv, dh]);
        if cfg.family == "qwen" {
            let qn = net.p(&format!("{pfx}qnorm"));
            let kn = net.p(&format!("{pfx}knorm"));
            let qf = net.g.reshape(q, &[b * w * nh, dh]);
            let qn2 = net.rmsnorm(qf, qn);
            q = net.g.reshape(qn2, &[b, w, nh, dh]);
            let kf = net.g.reshape(k, &[b * w * nkv, dh]);
            let kn2 = net.rmsnorm(kf, kn);
            k = net.g.reshape(kn2, &[b, w, nkv, dh]);
        }
        q = net.rope(q, pos);
        k = net.rope(k, pos);

        // scatter all W rows, then gather: write-before-gather makes the
        // within-window prefix visible to later window slots
        let (kp_in, vp_in) = pool_in[layer];
        let k2 = net.g.reshape(k, &[b * w, width]);
        let v2 = net.g.reshape(v, &[b * w, width]);
        let kp = net.g.update_rows(kp_in, k2, wrow);
        let vp = net.g.update_rows(vp_in, v2, wrow);
        pools_out.push(kp);
        pools_out.push(vp);
        let kc = net.g.gather_blocks(kp, btable, block_len, nkv); // (b,nkv,s,dh)
        let vc = net.g.gather_blocks(vp, btable, block_len, nkv);

        let rep = nh / nkv;
        let (kr, vr) = if rep == 1 {
            (kc, vc)
        } else {
            let k5 = net.g.reshape(kc, &[b, nkv, 1, s, dh]);
            let kb = net.g.broadcast(k5, &[b, nkv, rep, s, dh]);
            let kr = net.g.reshape(kb, &[b, nh, s, dh]);
            let v5 = net.g.reshape(vc, &[b, nkv, 1, s, dh]);
            let vb = net.g.broadcast(v5, &[b, nkv, rep, s, dh]);
            let vr = net.g.reshape(vb, &[b, nh, s, dh]);
            (kr, vr)
        };
        let qt = net.g.transpose(q, &[0, 2, 1, 3]); // (b, nh, w, dh)
        let qp = net.g.reshape(qt, &[b * nh, w, dh]);
        let kr3 = net.g.reshape(kr, &[b * nh, s, dh]);
        let vr3 = net.g.reshape(vr, &[b * nh, s, dh]);
        let o = net.masked_attention(qp, kr3, vr3, (dh as f32).powf(-0.5), mask); // (b·nh, w, dh)
        let o4 = net.g.reshape(o, &[b, nh, w, dh]);
        let ot = net.g.transpose(o4, &[0, 2, 1, 3]);
        let o2 = net.g.reshape(ot, &[b * w, d]);
        let attn = net.linear(&format!("{pfx}attn.wo"), o2);
        let attn3 = net.g.reshape(attn, &[b, w, d]);
        h = net.g.add(h, attn3);

        let h2 = net.g.reshape(h, &[b * w, d]);
        let ln2 = net.p(&format!("{pfx}ln2"));
        let x2 = net.rmsnorm(h2, ln2);
        let gt = net.linear(&format!("{pfx}mlp.wgate"), x2);
        let up = net.linear(&format!("{pfx}mlp.wup"), x2);
        let sg = net.g.sigmoid(gt);
        let silu = net.g.mul(gt, sg);
        let y = net.g.mul(silu, up);
        let down = net.linear(&format!("{pfx}mlp.wdown"), y);
        let down3 = net.g.reshape(down, &[b, w, d]);
        h = net.g.add(h, down3);
    }
    let h2 = net.g.reshape(h, &[b * w, d]);
    let nf = net.p("norm_f");
    let hf = net.rmsnorm(h2, nf);
    let head = net.p("head");
    let logits2 = net.g.matmul(hf, head, false, true);
    let logits = net.g.reshape(logits2, &[b, w, cfg.vocab]); // (b, w, vocab)

    let mut outputs = vec![logits];
    outputs.extend(pools_out);
    let mut names = vec!["logits".to_string()];
    names.extend(pool_names(cfg));
    net.finish(name, outputs, names)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{model_by_name, Paths};

    fn cfg(name: &str) -> ModelCfg {
        let paths = Paths::discover().unwrap();
        model_by_name(&paths.configs, name).unwrap()
    }

    /// The contract test previously gated on exported AOT manifests: the
    /// rust topology must match the built manifest exactly.
    #[test]
    fn train_step_manifest_matches_topology() {
        for model in ["micro-llama", "miniqwen-s"] {
            let c = cfg(model);
            let p = train_step(&c);
            for (name, shape) in aux_param_shapes(&c) {
                let spec = p.manifest.input(&name).unwrap_or_else(|| panic!("missing {name}"));
                assert_eq!(spec.shape, shape, "{name}");
                assert_eq!(spec.dtype, "f32");
            }
            for d in module_dims(&c) {
                let spec = p.manifest.input(&d.name).unwrap();
                assert_eq!(spec.shape, vec![d.m, d.n], "{}", d.name);
            }
            let toks = p.manifest.input("tokens").unwrap();
            assert_eq!(toks.dtype, "i32");
            assert_eq!(toks.shape, vec![c.batch_train, c.seq_train]);
            assert_eq!(p.manifest.outputs[0], "loss");
            // one gradient per weight input
            assert_eq!(
                p.manifest.outputs.len(),
                1 + aux_param_shapes(&c).len() + module_dims(&c).len()
            );
        }
    }

    #[test]
    fn mask_fwd_grad_manifest_has_masks_and_grads() {
        let c = cfg("micro-llama");
        let p = mask_fwd_grad(&c);
        for d in module_dims(&c) {
            let u = p.manifest.input(&format!("{}.u", d.name)).unwrap();
            assert_eq!(u.shape, vec![d.m, d.r_full()]);
            let v = p.manifest.input(&format!("{}.v", d.name)).unwrap();
            assert_eq!(v.shape, vec![d.r_full(), d.n]);
            let m = p.manifest.input(&format!("mask:{}", d.name)).unwrap();
            assert_eq!(m.shape, vec![d.r_full()]);
            assert!(p
                .manifest
                .output_index(&format!("grad:mask:{}", d.name))
                .is_some());
        }
    }

    #[test]
    fn serving_manifests_weights_prefix_then_caches() {
        let c = cfg("micro-llama");
        let paths = Paths::discover().unwrap();
        let p = build(&c, &paths, "decode_uniform-80_b2").unwrap();
        // the engine relies on weights being the manifest prefix
        let first_cache = p
            .manifest
            .inputs
            .iter()
            .position(|s| s.name.starts_with("kcache"))
            .unwrap();
        for spec in &p.manifest.inputs[first_cache..p.manifest.inputs.len() - 3] {
            assert!(
                spec.name.starts_with("kcache") || spec.name.starts_with("vcache"),
                "{}",
                spec.name
            );
        }
        let n = p.manifest.inputs.len();
        assert_eq!(p.manifest.inputs[n - 3].name, "tokens");
        assert_eq!(p.manifest.inputs[n - 2].name, "lens");
        assert_eq!(p.manifest.inputs[n - 1].name, "starts");
        assert_eq!(p.manifest.input("starts").unwrap().dtype, "i32");
        assert_eq!(p.manifest.outputs[0], "logits");
        assert_eq!(p.manifest.outputs.len(), 1 + 2 * c.n_layers);

        let pf = build(&c, &paths, "prefill_uniform-80_b2").unwrap();
        let m = pf.manifest.inputs.len();
        assert_eq!(pf.manifest.inputs[m - 2].name, "tokens");
        assert_eq!(pf.manifest.inputs[m - 1].name, "lens");
        assert_eq!(
            pf.manifest.input("tokens").unwrap().shape,
            vec![2, c.prefill_len]
        );
        assert_eq!(pf.manifest.input("lens").unwrap().shape, vec![2]);
    }

    #[test]
    fn unknown_artifact_is_an_error() {
        let c = cfg("micro-llama");
        let paths = Paths::discover().unwrap();
        assert!(build(&c, &paths, "nonexistent_graph").is_err());
        assert!(build(&c, &paths, "decode_bogus").is_err());
    }

    #[test]
    fn paged_decode_manifest_contract() {
        let c = cfg("micro-llama");
        let paths = Paths::discover().unwrap();
        let p = build(&c, &paths, "decode_paged_uniform-80_b2_blk8x19").unwrap();
        let n = p.manifest.inputs.len();
        assert_eq!(p.manifest.inputs[n - 4].name, "tokens");
        assert_eq!(p.manifest.inputs[n - 3].name, "lens");
        assert_eq!(p.manifest.inputs[n - 2].name, "rows");
        assert_eq!(p.manifest.inputs[n - 1].name, "btable");
        let bps = c.max_decode_seq.div_ceil(8);
        assert_eq!(p.manifest.input("btable").unwrap().shape, vec![2, bps]);
        assert_eq!(p.manifest.input("btable").unwrap().dtype, "i32");
        assert_eq!(
            p.manifest.input("kpool.0").unwrap().shape,
            vec![19 * 8, c.n_kv_heads * c.head_dim()]
        );
        assert_eq!(p.manifest.outputs[0], "logits");
        assert_eq!(p.manifest.outputs.len(), 1 + 2 * c.n_layers);

        // the engine shares weight buffers between the contiguous and paged
        // decode executables — their weight prefixes must match exactly
        let dec = build(&c, &paths, "decode_uniform-80_b2").unwrap();
        let wp = p
            .manifest
            .inputs
            .iter()
            .position(|s| s.name.starts_with("kpool"))
            .unwrap();
        let wd = dec
            .manifest
            .inputs
            .iter()
            .position(|s| s.name.starts_with("kcache"))
            .unwrap();
        assert_eq!(wp, wd, "weight prefix lengths differ");
        for (a, b) in p.manifest.inputs[..wp].iter().zip(&dec.manifest.inputs[..wd]) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.shape, b.shape);
        }

        assert!(is_known_artifact("decode_paged_uniform-80_b2_blk8x19"));
        assert!(!is_known_artifact("decode_paged_uniform-80_b2"));
        assert!(!is_known_artifact("decode_paged_uniform-80_b2_blk0x4"));
        assert!(!is_known_artifact("decode_paged_uniform-80_b2_blk8x1"));
    }

    #[test]
    fn verify_decode_manifest_contract() {
        let c = cfg("micro-llama");
        let paths = Paths::discover().unwrap();
        let p = build(&c, &paths, "decode_verify_uniform-80_b2_blk8x19_k3").unwrap();
        let n = p.manifest.inputs.len();
        assert_eq!(p.manifest.inputs[n - 4].name, "tokens");
        assert_eq!(p.manifest.inputs[n - 3].name, "lens");
        assert_eq!(p.manifest.inputs[n - 2].name, "rows");
        assert_eq!(p.manifest.inputs[n - 1].name, "btable");
        // window-shaped token/row inputs: (b, W) tokens, flat (b·W) rows
        assert_eq!(p.manifest.input("tokens").unwrap().shape, vec![2, 3]);
        assert_eq!(p.manifest.input("rows").unwrap().shape, vec![2 * 3]);
        assert_eq!(p.manifest.input("rows").unwrap().dtype, "i32");
        let bps = c.max_decode_seq.div_ceil(8);
        assert_eq!(p.manifest.input("btable").unwrap().shape, vec![2, bps]);
        assert_eq!(
            p.manifest.input("kpool.0").unwrap().shape,
            vec![19 * 8, c.n_kv_heads * c.head_dim()]
        );
        assert_eq!(p.manifest.outputs[0], "logits");
        assert_eq!(p.manifest.outputs.len(), 1 + 2 * c.n_layers);

        // the engine shares weight buffers between the paged and verify
        // executables — their weight prefixes must match exactly
        let paged = build(&c, &paths, "decode_paged_uniform-80_b2_blk8x19").unwrap();
        let wv = p
            .manifest
            .inputs
            .iter()
            .position(|s| s.name.starts_with("kpool"))
            .unwrap();
        let wp = paged
            .manifest
            .inputs
            .iter()
            .position(|s| s.name.starts_with("kpool"))
            .unwrap();
        assert_eq!(wv, wp, "weight prefix lengths differ");
        for (a, b) in p.manifest.inputs[..wv].iter().zip(&paged.manifest.inputs[..wp]) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.shape, b.shape);
        }

        assert!(is_known_artifact("decode_verify_uniform-80_b2_blk8x19_k3"));
        // a 1-token window is plain decode; malformed geometry stays bad
        assert!(!is_known_artifact("decode_verify_uniform-80_b2_blk8x19_k1"));
        assert!(!is_known_artifact("decode_verify_uniform-80_b2_blk8x19"));
        assert!(!is_known_artifact("decode_verify_uniform-80_b2_k3"));
        assert!(!is_known_artifact("decode_verify_uniform-80_b2_blk0x4_k3"));
    }
}
