//! The pure-Rust execution substrate of the default [`CpuBackend`]: a tiny
//! static-shape tensor IR covering the op set the AOT graphs lower to
//! (dot/matmul, elementwise arithmetic, exp/tanh/rsqrt, reductions,
//! broadcast/reshape/transpose, select-style masking, iota, gather/scatter)
//! plus an interpreter that executes a [`Graph`] against name-bound feeds.
//!
//! Semantics mirror `python/compile/kernels/ref.py` / `jax.numpy`:
//! row-major tensors, numpy-style right-aligned broadcasting, f32 compute.
//! Shapes are fully static and inferred at graph-construction time, so
//! every kernel below runs without per-element shape checks.
//!
//! [`CpuBackend`]: super::cpu::CpuBackend

use crate::runtime::exec::{Feed, Value};
use crate::tensor::{IntTensor, Tensor};
use crate::Result;

/// Node id inside one [`Graph`] (ids are topologically ordered by
/// construction: every operand id is smaller than its consumer's).
pub type Id = usize;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

/// One IR operation. Structural parameters (shapes, axes, permutations)
/// are baked in; tensor operands are node ids.
#[derive(Debug, Clone)]
pub enum Op {
    /// Placeholder bound to manifest input `k` at execution time.
    Input(usize),
    /// Baked constant (causal masks, rope frequency tables, scalars).
    Const(Value),

    // ---- unary (f32) ----
    Neg(Id),
    Exp(Id),
    Log(Id),
    Sqrt(Id),
    Rsqrt(Id),
    Tanh(Id),
    Sigmoid(Id),
    Cos(Id),
    Sin(Id),
    /// Identity forward; blocks gradient flow (softmax/logsumexp shifts).
    StopGrad(Id),
    /// i32 → f32 cast (positions, lengths).
    CastF32(Id),

    // ---- binary with numpy broadcasting (f32) ----
    Add(Id, Id),
    Sub(Id, Id),
    Mul(Id, Id),
    Div(Id, Id),
    Maximum(Id, Id),
    /// 1.0 where a < b else 0.0 (mask construction).
    Less(Id, Id),

    // ---- contractions ----
    /// 2-D matmul with transpose flags: C = op(A) · op(B).
    Matmul { a: Id, b: Id, ta: bool, tb: bool },
    /// Batched 3-D matmul over the leading dim.
    Bmm { a: Id, b: Id, ta: bool, tb: bool },

    // ---- structure ----
    Reshape(Id, Vec<usize>),
    Transpose(Id, Vec<usize>),
    /// Numpy-broadcast to an explicit shape.
    Broadcast(Id, Vec<usize>),
    Concat(Vec<Id>, usize),
    Slice { x: Id, axis: usize, start: usize, len: usize },
    /// Embed into zeros along `axis` at `start` (adjoint of `Slice`; also
    /// the static prefill KV-cache write).
    PadZero { x: Id, axis: usize, start: usize, full: usize },

    // ---- reductions (single axis, no keepdims) ----
    ReduceSum(Id, usize),
    ReduceMax(Id, usize),

    // ---- indexing ----
    /// out[j, :] = table[idx[j], :] — embedding lookup.
    Gather { table: Id, idx: Id },
    /// out[j] = x[j, idx[j]] over the last axis — target-logit pick.
    TakeLast { x: Id, idx: Id },
    /// Adjoint of `Gather`: rows of `upd` summed into zeros[rows, d].
    ScatterAddRows { idx: Id, upd: Id, rows: usize },
    /// Adjoint of `TakeLast`: upd[j] written at [j, idx[j]] in zeros[.., n].
    ScatterLast { idx: Id, upd: Id, n: usize },
    /// KV-cache write: cache (b,h,s,d) ← kv (b,h,d) at per-batch position
    /// pos (b,) — the decode-step dynamic-update-slice.
    UpdateAt { cache: Id, kv: Id, pos: Id },
    /// f32 ramp [0, len).
    Iota { len: usize },
}

impl Op {
    /// Tensor operand ids, in order.
    pub fn operands(&self) -> Vec<Id> {
        match self {
            Op::Input(_) | Op::Const(_) | Op::Iota { .. } => vec![],
            Op::Neg(x)
            | Op::Exp(x)
            | Op::Log(x)
            | Op::Sqrt(x)
            | Op::Rsqrt(x)
            | Op::Tanh(x)
            | Op::Sigmoid(x)
            | Op::Cos(x)
            | Op::Sin(x)
            | Op::StopGrad(x)
            | Op::CastF32(x)
            | Op::Reshape(x, _)
            | Op::Transpose(x, _)
            | Op::Broadcast(x, _)
            | Op::Slice { x, .. }
            | Op::PadZero { x, .. }
            | Op::ReduceSum(x, _)
            | Op::ReduceMax(x, _) => vec![*x],
            Op::Add(a, b)
            | Op::Sub(a, b)
            | Op::Mul(a, b)
            | Op::Div(a, b)
            | Op::Maximum(a, b)
            | Op::Less(a, b)
            | Op::Matmul { a, b, .. }
            | Op::Bmm { a, b, .. } => vec![*a, *b],
            Op::Concat(xs, _) => xs.clone(),
            Op::Gather { table, idx } => vec![*table, *idx],
            Op::TakeLast { x, idx } => vec![*x, *idx],
            Op::ScatterAddRows { idx, upd, .. } => vec![*idx, *upd],
            Op::ScatterLast { idx, upd, .. } => vec![*idx, *upd],
            Op::UpdateAt { cache, kv, pos } => vec![*cache, *kv, *pos],
        }
    }
}

#[derive(Debug, Clone)]
pub struct Node {
    pub op: Op,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

/// A static-shape computation graph under construction / execution.
#[derive(Debug, Clone, Default)]
pub struct Graph {
    pub nodes: Vec<Node>,
    /// Number of declared inputs (Input(k) for k < n_inputs).
    pub n_inputs: usize,
}

fn numel(shape: &[usize]) -> usize {
    shape.iter().product()
}

/// Numpy broadcast of two shapes (right-aligned), or None if incompatible.
pub fn broadcast_shapes(a: &[usize], b: &[usize]) -> Option<Vec<usize>> {
    let r = a.len().max(b.len());
    let mut out = vec![0usize; r];
    for i in 0..r {
        let da = if i < r - a.len() { 1 } else { a[i - (r - a.len())] };
        let db = if i < r - b.len() { 1 } else { b[i - (r - b.len())] };
        if da == db || da == 1 || db == 1 {
            out[i] = da.max(db);
        } else {
            return None;
        }
    }
    Some(out)
}

impl Graph {
    pub fn shape(&self, id: Id) -> &[usize] {
        &self.nodes[id].shape
    }

    pub fn dtype(&self, id: Id) -> DType {
        self.nodes[id].dtype
    }

    fn push(&mut self, op: Op, shape: Vec<usize>, dtype: DType) -> Id {
        self.nodes.push(Node { op, shape, dtype });
        self.nodes.len() - 1
    }

    // ---------------- construction API ----------------

    /// Declare the next manifest input (call in manifest order).
    pub fn input(&mut self, shape: &[usize], dtype: DType) -> Id {
        let k = self.n_inputs;
        self.n_inputs += 1;
        self.push(Op::Input(k), shape.to_vec(), dtype)
    }

    pub fn constant(&mut self, t: Tensor) -> Id {
        let shape = t.shape.clone();
        self.push(Op::Const(Value::F32(t)), shape, DType::F32)
    }

    pub fn scalar(&mut self, v: f32) -> Id {
        self.constant(Tensor::from_vec(&[], vec![v]))
    }

    pub fn constant_i32(&mut self, t: IntTensor) -> Id {
        let shape = t.shape.clone();
        self.push(Op::Const(Value::I32(t)), shape, DType::I32)
    }

    fn unary(&mut self, f: impl Fn(Id) -> Op, x: Id) -> Id {
        assert_eq!(self.dtype(x), DType::F32, "unary op on non-f32 node {x}");
        let shape = self.shape(x).to_vec();
        self.push(f(x), shape, DType::F32)
    }

    pub fn neg(&mut self, x: Id) -> Id {
        self.unary(Op::Neg, x)
    }
    pub fn exp(&mut self, x: Id) -> Id {
        self.unary(Op::Exp, x)
    }
    pub fn log(&mut self, x: Id) -> Id {
        self.unary(Op::Log, x)
    }
    pub fn sqrt(&mut self, x: Id) -> Id {
        self.unary(Op::Sqrt, x)
    }
    pub fn rsqrt(&mut self, x: Id) -> Id {
        self.unary(Op::Rsqrt, x)
    }
    pub fn tanh(&mut self, x: Id) -> Id {
        self.unary(Op::Tanh, x)
    }
    pub fn sigmoid(&mut self, x: Id) -> Id {
        self.unary(Op::Sigmoid, x)
    }
    pub fn cos(&mut self, x: Id) -> Id {
        self.unary(Op::Cos, x)
    }
    pub fn sin(&mut self, x: Id) -> Id {
        self.unary(Op::Sin, x)
    }
    pub fn stop_grad(&mut self, x: Id) -> Id {
        self.unary(Op::StopGrad, x)
    }

    pub fn cast_f32(&mut self, x: Id) -> Id {
        let shape = self.shape(x).to_vec();
        self.push(Op::CastF32(x), shape, DType::F32)
    }

    fn binary(&mut self, f: impl Fn(Id, Id) -> Op, a: Id, b: Id) -> Id {
        assert_eq!(self.dtype(a), DType::F32, "binary op lhs must be f32");
        assert_eq!(self.dtype(b), DType::F32, "binary op rhs must be f32");
        let shape = broadcast_shapes(self.shape(a), self.shape(b)).unwrap_or_else(|| {
            panic!("broadcast mismatch: {:?} vs {:?}", self.shape(a), self.shape(b))
        });
        self.push(f(a, b), shape, DType::F32)
    }

    pub fn add(&mut self, a: Id, b: Id) -> Id {
        self.binary(Op::Add, a, b)
    }
    pub fn sub(&mut self, a: Id, b: Id) -> Id {
        self.binary(Op::Sub, a, b)
    }
    pub fn mul(&mut self, a: Id, b: Id) -> Id {
        self.binary(Op::Mul, a, b)
    }
    pub fn div(&mut self, a: Id, b: Id) -> Id {
        self.binary(Op::Div, a, b)
    }
    pub fn maximum(&mut self, a: Id, b: Id) -> Id {
        self.binary(Op::Maximum, a, b)
    }
    pub fn less(&mut self, a: Id, b: Id) -> Id {
        self.binary(Op::Less, a, b)
    }

    pub fn matmul(&mut self, a: Id, b: Id, ta: bool, tb: bool) -> Id {
        let (sa, sb) = (self.shape(a).to_vec(), self.shape(b).to_vec());
        assert_eq!(sa.len(), 2, "matmul lhs must be 2-D, got {sa:?}");
        assert_eq!(sb.len(), 2, "matmul rhs must be 2-D, got {sb:?}");
        let (m, ka) = if ta { (sa[1], sa[0]) } else { (sa[0], sa[1]) };
        let (kb, n) = if tb { (sb[1], sb[0]) } else { (sb[0], sb[1]) };
        assert_eq!(ka, kb, "matmul inner dim: {sa:?} (ta={ta}) vs {sb:?} (tb={tb})");
        self.push(Op::Matmul { a, b, ta, tb }, vec![m, n], DType::F32)
    }

    pub fn bmm(&mut self, a: Id, b: Id, ta: bool, tb: bool) -> Id {
        let (sa, sb) = (self.shape(a).to_vec(), self.shape(b).to_vec());
        assert_eq!(sa.len(), 3, "bmm lhs must be 3-D, got {sa:?}");
        assert_eq!(sb.len(), 3, "bmm rhs must be 3-D, got {sb:?}");
        assert_eq!(sa[0], sb[0], "bmm batch dims differ");
        let (m, ka) = if ta { (sa[2], sa[1]) } else { (sa[1], sa[2]) };
        let (kb, n) = if tb { (sb[2], sb[1]) } else { (sb[1], sb[2]) };
        assert_eq!(ka, kb, "bmm inner dim: {sa:?} (ta={ta}) vs {sb:?} (tb={tb})");
        self.push(Op::Bmm { a, b, ta, tb }, vec![sa[0], m, n], DType::F32)
    }

    pub fn reshape(&mut self, x: Id, shape: &[usize]) -> Id {
        assert_eq!(
            numel(self.shape(x)),
            numel(shape),
            "reshape {:?} -> {shape:?}",
            self.shape(x)
        );
        let dt = self.dtype(x);
        self.push(Op::Reshape(x, shape.to_vec()), shape.to_vec(), dt)
    }

    pub fn transpose(&mut self, x: Id, perm: &[usize]) -> Id {
        let s = self.shape(x).to_vec();
        assert_eq!(perm.len(), s.len(), "transpose perm rank");
        let mut seen = vec![false; s.len()];
        for &p in perm {
            assert!(!seen[p], "transpose perm not a permutation");
            seen[p] = true;
        }
        let shape: Vec<usize> = perm.iter().map(|&p| s[p]).collect();
        let dt = self.dtype(x);
        self.push(Op::Transpose(x, perm.to_vec()), shape, dt)
    }

    pub fn broadcast(&mut self, x: Id, shape: &[usize]) -> Id {
        let got = broadcast_shapes(self.shape(x), shape).unwrap_or_else(|| {
            panic!("cannot broadcast {:?} to {shape:?}", self.shape(x))
        });
        assert_eq!(got, shape, "broadcast of {:?} to {shape:?} would grow", self.shape(x));
        self.push(Op::Broadcast(x, shape.to_vec()), shape.to_vec(), DType::F32)
    }

    pub fn concat(&mut self, xs: &[Id], axis: usize) -> Id {
        assert!(!xs.is_empty());
        let mut shape = self.shape(xs[0]).to_vec();
        for &x in &xs[1..] {
            let s = self.shape(x);
            assert_eq!(s.len(), shape.len(), "concat rank");
            for (d, (&a, &b)) in shape.iter().zip(s.iter()).enumerate() {
                if d != axis {
                    assert_eq!(a, b, "concat non-axis dims must match");
                }
            }
            shape[axis] += s[axis];
        }
        self.push(Op::Concat(xs.to_vec(), axis), shape, DType::F32)
    }

    pub fn slice(&mut self, x: Id, axis: usize, start: usize, len: usize) -> Id {
        let mut shape = self.shape(x).to_vec();
        assert!(start + len <= shape[axis], "slice out of range");
        shape[axis] = len;
        self.push(Op::Slice { x, axis, start, len }, shape, DType::F32)
    }

    pub fn pad_zero(&mut self, x: Id, axis: usize, start: usize, full: usize) -> Id {
        let mut shape = self.shape(x).to_vec();
        assert!(start + shape[axis] <= full, "pad_zero out of range");
        shape[axis] = full;
        self.push(Op::PadZero { x, axis, start, full }, shape, DType::F32)
    }

    pub fn reduce_sum(&mut self, x: Id, axis: usize) -> Id {
        let mut shape = self.shape(x).to_vec();
        assert!(axis < shape.len());
        shape.remove(axis);
        self.push(Op::ReduceSum(x, axis), shape, DType::F32)
    }

    pub fn reduce_max(&mut self, x: Id, axis: usize) -> Id {
        let mut shape = self.shape(x).to_vec();
        assert!(axis < shape.len());
        shape.remove(axis);
        self.push(Op::ReduceMax(x, axis), shape, DType::F32)
    }

    /// Reduce-sum keeping the axis as size 1 (keepdims=True).
    pub fn reduce_sum_keep(&mut self, x: Id, axis: usize) -> Id {
        let mut shape = self.shape(x).to_vec();
        let r = self.reduce_sum(x, axis);
        shape[axis] = 1;
        self.reshape(r, &shape)
    }

    pub fn reduce_max_keep(&mut self, x: Id, axis: usize) -> Id {
        let mut shape = self.shape(x).to_vec();
        let r = self.reduce_max(x, axis);
        shape[axis] = 1;
        self.reshape(r, &shape)
    }

    pub fn gather(&mut self, table: Id, idx: Id) -> Id {
        assert_eq!(self.shape(table).len(), 2, "gather table must be 2-D");
        assert_eq!(self.dtype(idx), DType::I32, "gather index must be i32");
        let d = self.shape(table)[1];
        let mut shape = self.shape(idx).to_vec();
        shape.push(d);
        self.push(Op::Gather { table, idx }, shape, DType::F32)
    }

    pub fn take_last(&mut self, x: Id, idx: Id) -> Id {
        let sx = self.shape(x).to_vec();
        assert!(!sx.is_empty());
        assert_eq!(self.dtype(idx), DType::I32, "take_last index must be i32");
        assert_eq!(&sx[..sx.len() - 1], self.shape(idx), "take_last index shape");
        self.push(Op::TakeLast { x, idx }, sx[..sx.len() - 1].to_vec(), DType::F32)
    }

    pub fn scatter_add_rows(&mut self, idx: Id, upd: Id, rows: usize) -> Id {
        let su = self.shape(upd).to_vec();
        let d = *su.last().expect("scatter_add_rows upd rank");
        assert_eq!(&su[..su.len() - 1], self.shape(idx), "scatter_add_rows shapes");
        self.push(Op::ScatterAddRows { idx, upd, rows }, vec![rows, d], DType::F32)
    }

    pub fn scatter_last(&mut self, idx: Id, upd: Id, n: usize) -> Id {
        assert_eq!(self.shape(idx), self.shape(upd), "scatter_last shapes");
        let mut shape = self.shape(upd).to_vec();
        shape.push(n);
        self.push(Op::ScatterLast { idx, upd, n }, shape, DType::F32)
    }

    pub fn update_at(&mut self, cache: Id, kv: Id, pos: Id) -> Id {
        let sc = self.shape(cache).to_vec();
        let sk = self.shape(kv);
        assert_eq!(sc.len(), 4, "update_at cache must be (b,h,s,d)");
        assert_eq!(sk, &[sc[0], sc[1], sc[3]][..], "update_at kv shape");
        assert_eq!(self.shape(pos), &[sc[0]][..], "update_at pos shape");
        assert_eq!(self.dtype(pos), DType::I32);
        self.push(Op::UpdateAt { cache, kv, pos }, sc, DType::F32)
    }

    pub fn iota(&mut self, len: usize) -> Id {
        self.push(Op::Iota { len }, vec![len], DType::F32)
    }

    // ---------------- execution ----------------

    /// Memory plan: for each node, which earlier values die after it runs.
    pub fn free_plan(&self, outputs: &[Id]) -> Vec<Vec<Id>> {
        let mut last_use = vec![usize::MAX; self.nodes.len()];
        for (id, node) in self.nodes.iter().enumerate() {
            for o in node.op.operands() {
                if last_use[o] == usize::MAX || last_use[o] < id {
                    last_use[o] = id;
                }
            }
        }
        let mut plan = vec![Vec::new(); self.nodes.len()];
        for (o, &lu) in last_use.iter().enumerate() {
            let is_input = matches!(self.nodes[o].op, Op::Input(_));
            let is_output = outputs.contains(&o);
            if lu != usize::MAX && !is_input && !is_output {
                plan[lu].push(o);
            }
        }
        plan
    }

    /// Execute the graph over manifest-ordered feeds, returning the values
    /// of `outputs` in order.
    pub fn eval(&self, inputs: &[Feed], outputs: &[Id], plan: &[Vec<Id>]) -> Result<Vec<Value>> {
        if inputs.len() != self.n_inputs {
            return Err(crate::anyhow!(
                "graph expects {} inputs, got {}",
                self.n_inputs,
                inputs.len()
            ));
        }
        let mut vals: Vec<Option<Value>> = vec![None; self.nodes.len()];
        for id in 0..self.nodes.len() {
            if matches!(self.nodes[id].op, Op::Input(_)) {
                continue; // read through `inputs`, never materialized
            }
            let v = self.exec_node(id, &vals, inputs)?;
            debug_assert_eq!(
                v.shape(),
                self.nodes[id].shape.as_slice(),
                "node {id} ({:?}) produced wrong shape",
                self.nodes[id].op
            );
            vals[id] = Some(v);
            for &f in &plan[id] {
                vals[f] = None;
            }
        }
        let mut out = Vec::with_capacity(outputs.len());
        for &o in outputs {
            match &self.nodes[o].op {
                Op::Input(k) => out.push(match &inputs[*k] {
                    Feed::F32(t) => Value::F32((*t).clone()),
                    Feed::I32(t) => Value::I32((*t).clone()),
                }),
                _ => out.push(
                    vals[o]
                        .take()
                        .ok_or_else(|| crate::anyhow!("output node {o} was freed"))?,
                ),
            }
        }
        Ok(out)
    }

    fn f32_of<'a>(
        &self,
        vals: &'a [Option<Value>],
        inputs: &'a [Feed<'a>],
        id: Id,
    ) -> Result<&'a Tensor> {
        match &self.nodes[id].op {
            Op::Input(k) => match &inputs[*k] {
                Feed::F32(t) => Ok(t),
                Feed::I32(_) => Err(crate::anyhow!("node {id}: expected f32 input")),
            },
            _ => match vals[id].as_ref() {
                Some(Value::F32(t)) => Ok(t),
                Some(Value::I32(_)) => Err(crate::anyhow!("node {id}: expected f32 value")),
                None => Err(crate::anyhow!("node {id}: value missing (freed too early?)")),
            },
        }
    }

    fn i32_of<'a>(
        &self,
        vals: &'a [Option<Value>],
        inputs: &'a [Feed<'a>],
        id: Id,
    ) -> Result<&'a IntTensor> {
        match &self.nodes[id].op {
            Op::Input(k) => match &inputs[*k] {
                Feed::I32(t) => Ok(t),
                Feed::F32(_) => Err(crate::anyhow!("node {id}: expected i32 input")),
            },
            _ => match vals[id].as_ref() {
                Some(Value::I32(t)) => Ok(t),
                Some(Value::F32(_)) => Err(crate::anyhow!("node {id}: expected i32 value")),
                None => Err(crate::anyhow!("node {id}: value missing (freed too early?)")),
            },
        }
    }

    fn exec_node(&self, id: Id, vals: &[Option<Value>], inputs: &[Feed]) -> Result<Value> {
        let node = &self.nodes[id];
        let out_shape = &node.shape;
        let v = match &node.op {
            Op::Input(_) => unreachable!("inputs are not materialized"),
            Op::Const(v) => v.clone(),
            Op::Neg(x) => Value::F32(map1(self.f32_of(vals, inputs, *x)?, |v| -v)),
            Op::Exp(x) => Value::F32(map1(self.f32_of(vals, inputs, *x)?, f32::exp)),
            Op::Log(x) => Value::F32(map1(self.f32_of(vals, inputs, *x)?, f32::ln)),
            Op::Sqrt(x) => Value::F32(map1(self.f32_of(vals, inputs, *x)?, f32::sqrt)),
            Op::Rsqrt(x) => {
                Value::F32(map1(self.f32_of(vals, inputs, *x)?, |v| 1.0 / v.sqrt()))
            }
            Op::Tanh(x) => Value::F32(map1(self.f32_of(vals, inputs, *x)?, f32::tanh)),
            Op::Sigmoid(x) => Value::F32(map1(self.f32_of(vals, inputs, *x)?, |v| {
                1.0 / (1.0 + (-v).exp())
            })),
            Op::Cos(x) => Value::F32(map1(self.f32_of(vals, inputs, *x)?, f32::cos)),
            Op::Sin(x) => Value::F32(map1(self.f32_of(vals, inputs, *x)?, f32::sin)),
            Op::StopGrad(x) => Value::F32(self.f32_of(vals, inputs, *x)?.clone()),
            Op::CastF32(x) => {
                let t = self.i32_of(vals, inputs, *x)?;
                Value::F32(Tensor::from_vec(
                    &t.shape,
                    t.data.iter().map(|&v| v as f32).collect(),
                ))
            }
            Op::Add(a, b) => Value::F32(ew2(
                self.f32_of(vals, inputs, *a)?,
                self.f32_of(vals, inputs, *b)?,
                out_shape,
                |x, y| x + y,
            )),
            Op::Sub(a, b) => Value::F32(ew2(
                self.f32_of(vals, inputs, *a)?,
                self.f32_of(vals, inputs, *b)?,
                out_shape,
                |x, y| x - y,
            )),
            Op::Mul(a, b) => Value::F32(ew2(
                self.f32_of(vals, inputs, *a)?,
                self.f32_of(vals, inputs, *b)?,
                out_shape,
                |x, y| x * y,
            )),
            Op::Div(a, b) => Value::F32(ew2(
                self.f32_of(vals, inputs, *a)?,
                self.f32_of(vals, inputs, *b)?,
                out_shape,
                |x, y| x / y,
            )),
            Op::Maximum(a, b) => Value::F32(ew2(
                self.f32_of(vals, inputs, *a)?,
                self.f32_of(vals, inputs, *b)?,
                out_shape,
                f32::max,
            )),
            Op::Less(a, b) => Value::F32(ew2(
                self.f32_of(vals, inputs, *a)?,
                self.f32_of(vals, inputs, *b)?,
                out_shape,
                |x, y| if x < y { 1.0 } else { 0.0 },
            )),
            Op::Matmul { a, b, ta, tb } => {
                let at = self.f32_of(vals, inputs, *a)?;
                let bt = self.f32_of(vals, inputs, *b)?;
                let (m, n) = (out_shape[0], out_shape[1]);
                let k = if *ta { at.shape[0] } else { at.shape[1] };
                let mut out = vec![0.0f32; m * n];
                mm(&at.data, &bt.data, m, k, n, *ta, *tb, &mut out);
                Value::F32(Tensor::from_vec(out_shape, out))
            }
            Op::Bmm { a, b, ta, tb } => {
                let at = self.f32_of(vals, inputs, *a)?;
                let bt = self.f32_of(vals, inputs, *b)?;
                let (bs, m, n) = (out_shape[0], out_shape[1], out_shape[2]);
                let k = if *ta { at.shape[1] } else { at.shape[2] };
                let (sa, sb) = (at.shape[1] * at.shape[2], bt.shape[1] * bt.shape[2]);
                let mut out = vec![0.0f32; bs * m * n];
                for i in 0..bs {
                    mm(
                        &at.data[i * sa..(i + 1) * sa],
                        &bt.data[i * sb..(i + 1) * sb],
                        m,
                        k,
                        n,
                        *ta,
                        *tb,
                        &mut out[i * m * n..(i + 1) * m * n],
                    );
                }
                Value::F32(Tensor::from_vec(out_shape, out))
            }
            Op::Reshape(x, shape) => match &self.nodes[*x].dtype {
                DType::F32 => {
                    let t = self.f32_of(vals, inputs, *x)?;
                    Value::F32(Tensor::from_vec(shape, t.data.clone()))
                }
                DType::I32 => {
                    let t = self.i32_of(vals, inputs, *x)?;
                    Value::I32(IntTensor::from_vec(shape, t.data.clone()))
                }
            },
            Op::Transpose(x, perm) => {
                let t = self.f32_of(vals, inputs, *x)?;
                Value::F32(transpose(t, perm, out_shape))
            }
            Op::Broadcast(x, shape) => {
                let t = self.f32_of(vals, inputs, *x)?;
                Value::F32(broadcast_to(t, shape))
            }
            Op::Concat(xs, axis) => {
                let mut parts = Vec::with_capacity(xs.len());
                for &x in xs {
                    parts.push(self.f32_of(vals, inputs, x)?);
                }
                Value::F32(concat(&parts, *axis, out_shape))
            }
            Op::Slice { x, axis, start, len } => {
                let t = self.f32_of(vals, inputs, *x)?;
                Value::F32(slice(t, *axis, *start, *len))
            }
            Op::PadZero { x, axis, start, full } => {
                let t = self.f32_of(vals, inputs, *x)?;
                Value::F32(pad_zero(t, *axis, *start, *full))
            }
            Op::ReduceSum(x, axis) => {
                let t = self.f32_of(vals, inputs, *x)?;
                Value::F32(reduce(t, *axis, out_shape, 0.0, |acc, v| acc + v))
            }
            Op::ReduceMax(x, axis) => {
                let t = self.f32_of(vals, inputs, *x)?;
                Value::F32(reduce(t, *axis, out_shape, f32::NEG_INFINITY, f32::max))
            }
            Op::Gather { table, idx } => {
                let tt = self.f32_of(vals, inputs, *table)?;
                let it = self.i32_of(vals, inputs, *idx)?;
                let (v, d) = (tt.shape[0], tt.shape[1]);
                let mut out = Vec::with_capacity(it.data.len() * d);
                for &i in &it.data {
                    let i = i as usize;
                    if i >= v {
                        return Err(crate::anyhow!("gather index {i} out of range (rows {v})"));
                    }
                    out.extend_from_slice(&tt.data[i * d..(i + 1) * d]);
                }
                Value::F32(Tensor::from_vec(out_shape, out))
            }
            Op::TakeLast { x, idx } => {
                let xt = self.f32_of(vals, inputs, *x)?;
                let it = self.i32_of(vals, inputs, *idx)?;
                let n = *xt.shape.last().unwrap();
                let mut out = Vec::with_capacity(it.data.len());
                for (j, &i) in it.data.iter().enumerate() {
                    let i = i as usize;
                    if i >= n {
                        return Err(crate::anyhow!("take_last index {i} out of range ({n})"));
                    }
                    out.push(xt.data[j * n + i]);
                }
                Value::F32(Tensor::from_vec(out_shape, out))
            }
            Op::ScatterAddRows { idx, upd, rows } => {
                let it = self.i32_of(vals, inputs, *idx)?;
                let ut = self.f32_of(vals, inputs, *upd)?;
                let d = *ut.shape.last().unwrap();
                let mut out = vec![0.0f32; rows * d];
                for (j, &i) in it.data.iter().enumerate() {
                    let i = i as usize;
                    if i >= *rows {
                        return Err(crate::anyhow!("scatter index {i} out of range ({rows})"));
                    }
                    let dst = &mut out[i * d..(i + 1) * d];
                    let src = &ut.data[j * d..(j + 1) * d];
                    for (a, b) in dst.iter_mut().zip(src) {
                        *a += b;
                    }
                }
                Value::F32(Tensor::from_vec(out_shape, out))
            }
            Op::ScatterLast { idx, upd, n } => {
                let it = self.i32_of(vals, inputs, *idx)?;
                let ut = self.f32_of(vals, inputs, *upd)?;
                let mut out = vec![0.0f32; ut.data.len() * n];
                for (j, (&i, &u)) in it.data.iter().zip(&ut.data).enumerate() {
                    let i = i as usize;
                    if i >= *n {
                        return Err(crate::anyhow!("scatter index {i} out of range ({n})"));
                    }
                    out[j * n + i] = u;
                }
                Value::F32(Tensor::from_vec(out_shape, out))
            }
            Op::UpdateAt { cache, kv, pos } => {
                let ct = self.f32_of(vals, inputs, *cache)?;
                let kt = self.f32_of(vals, inputs, *kv)?;
                let pt = self.i32_of(vals, inputs, *pos)?;
                let (b, h, s, d) = (ct.shape[0], ct.shape[1], ct.shape[2], ct.shape[3]);
                let mut out = ct.data.clone();
                for bb in 0..b {
                    let p = pt.data[bb] as usize;
                    if p >= s {
                        return Err(crate::anyhow!("update_at position {p} out of range ({s})"));
                    }
                    for hh in 0..h {
                        let dst = (bb * h + hh) * s * d + p * d;
                        let src = (bb * h + hh) * d;
                        out[dst..dst + d].copy_from_slice(&kt.data[src..src + d]);
                    }
                }
                Value::F32(Tensor::from_vec(out_shape, out))
            }
            Op::Iota { len } => {
                Value::F32(Tensor::from_vec(&[*len], (0..*len).map(|i| i as f32).collect()))
            }
        };
        Ok(v)
    }
}

// ---------------------------------------------------------------------------
// Kernels
// ---------------------------------------------------------------------------

fn map1(t: &Tensor, f: impl Fn(f32) -> f32) -> Tensor {
    Tensor::from_vec(&t.shape, t.data.iter().map(|&x| f(x)).collect())
}

/// Right-aligned broadcast strides of `shape` against `out` (0 where the
/// input dimension is 1 or absent).
fn bcast_strides(shape: &[usize], out: &[usize]) -> Vec<usize> {
    let r = out.len();
    let pad = r - shape.len();
    // row-major strides of the (padded) input shape
    let mut strides = vec![0usize; r];
    let mut acc = 1usize;
    for d in (0..shape.len()).rev() {
        strides[pad + d] = if shape[d] == 1 { 0 } else { acc };
        acc *= shape[d];
    }
    // padded leading dims broadcast with stride 0 (already zeroed)
    for (d, s) in strides.iter_mut().enumerate() {
        if out[d] == 1 {
            *s = 0; // degenerate output dim; stride irrelevant
        }
    }
    strides
}

/// Elementwise binary with numpy broadcasting to `out_shape`.
fn ew2(a: &Tensor, b: &Tensor, out_shape: &[usize], f: impl Fn(f32, f32) -> f32) -> Tensor {
    let n = numel(out_shape);
    // fast paths
    if a.shape == b.shape && a.shape.as_slice() == out_shape {
        let data = a.data.iter().zip(&b.data).map(|(&x, &y)| f(x, y)).collect();
        return Tensor::from_vec(out_shape, data);
    }
    if b.data.len() == 1 && a.shape.as_slice() == out_shape {
        let y = b.data[0];
        return Tensor::from_vec(out_shape, a.data.iter().map(|&x| f(x, y)).collect());
    }
    if a.data.len() == 1 && b.shape.as_slice() == out_shape {
        let x = a.data[0];
        return Tensor::from_vec(out_shape, b.data.iter().map(|&y| f(x, y)).collect());
    }
    let r = out_shape.len();
    let sa = bcast_strides(&a.shape, out_shape);
    let sb = bcast_strides(&b.shape, out_shape);
    let mut out = Vec::with_capacity(n);
    let mut idx = vec![0usize; r];
    let (mut oa, mut ob) = (0usize, 0usize);
    for _ in 0..n {
        out.push(f(a.data[oa], b.data[ob]));
        for d in (0..r).rev() {
            idx[d] += 1;
            oa += sa[d];
            ob += sb[d];
            if idx[d] < out_shape[d] {
                break;
            }
            idx[d] = 0;
            oa -= sa[d] * out_shape[d];
            ob -= sb[d] * out_shape[d];
        }
    }
    Tensor::from_vec(out_shape, out)
}

fn broadcast_to(t: &Tensor, out_shape: &[usize]) -> Tensor {
    if t.shape.as_slice() == out_shape {
        return t.clone();
    }
    let n = numel(out_shape);
    let r = out_shape.len();
    let s = bcast_strides(&t.shape, out_shape);
    let mut out = Vec::with_capacity(n);
    let mut idx = vec![0usize; r];
    let mut off = 0usize;
    for _ in 0..n {
        out.push(t.data[off]);
        for d in (0..r).rev() {
            idx[d] += 1;
            off += s[d];
            if idx[d] < out_shape[d] {
                break;
            }
            idx[d] = 0;
            off -= s[d] * out_shape[d];
        }
    }
    Tensor::from_vec(out_shape, out)
}

/// C = op(A)·op(B) into `out` (len m*n, pre-zeroed by the caller).
#[allow(clippy::too_many_arguments)]
fn mm(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, ta: bool, tb: bool, out: &mut [f32]) {
    match (ta, tb) {
        (false, false) => {
            // A (m,k) · B (k,n): ikj with row accumulation
            for i in 0..m {
                let orow = &mut out[i * n..(i + 1) * n];
                for kk in 0..k {
                    let av = a[i * k + kk];
                    if av == 0.0 {
                        continue;
                    }
                    let brow = &b[kk * n..(kk + 1) * n];
                    for j in 0..n {
                        orow[j] += av * brow[j];
                    }
                }
            }
        }
        (true, false) => {
            // A stored (k,m); C = Aᵀ·B: kij with row accumulation
            for kk in 0..k {
                let arow = &a[kk * m..(kk + 1) * m];
                let brow = &b[kk * n..(kk + 1) * n];
                for i in 0..m {
                    let av = arow[i];
                    if av == 0.0 {
                        continue;
                    }
                    let orow = &mut out[i * n..(i + 1) * n];
                    for j in 0..n {
                        orow[j] += av * brow[j];
                    }
                }
            }
        }
        (false, true) => {
            // B stored (n,k); C[i,j] = dot(A row i, B row j)
            for i in 0..m {
                let arow = &a[i * k..(i + 1) * k];
                let orow = &mut out[i * n..(i + 1) * n];
                for j in 0..n {
                    let brow = &b[j * k..(j + 1) * k];
                    let mut acc = 0.0f32;
                    for kk in 0..k {
                        acc += arow[kk] * brow[kk];
                    }
                    orow[j] = acc;
                }
            }
        }
        (true, true) => {
            // A (k,m), B (n,k); C[i,j] = Σ_k A[k,i]·B[j,k]
            for i in 0..m {
                for j in 0..n {
                    let mut acc = 0.0f32;
                    let brow = &b[j * k..(j + 1) * k];
                    for kk in 0..k {
                        acc += a[kk * m + i] * brow[kk];
                    }
                    out[i * n + j] = acc;
                }
            }
        }
    }
}

fn transpose(t: &Tensor, perm: &[usize], out_shape: &[usize]) -> Tensor {
    let r = out_shape.len();
    // row-major strides of the input
    let mut in_strides = vec![1usize; r];
    for d in (0..r.saturating_sub(1)).rev() {
        in_strides[d] = in_strides[d + 1] * t.shape[d + 1];
    }
    // stride of out dim d is the input stride of perm[d]
    let s: Vec<usize> = perm.iter().map(|&p| in_strides[p]).collect();
    let n = numel(out_shape);
    let mut out = Vec::with_capacity(n);
    let mut idx = vec![0usize; r];
    let mut off = 0usize;
    for _ in 0..n {
        out.push(t.data[off]);
        for d in (0..r).rev() {
            idx[d] += 1;
            off += s[d];
            if idx[d] < out_shape[d] {
                break;
            }
            idx[d] = 0;
            off -= s[d] * out_shape[d];
        }
    }
    Tensor::from_vec(out_shape, out)
}

fn reduce(
    t: &Tensor,
    axis: usize,
    out_shape: &[usize],
    init: f32,
    f: impl Fn(f32, f32) -> f32,
) -> Tensor {
    let n = t.shape[axis];
    let outer: usize = t.shape[..axis].iter().product();
    let inner: usize = t.shape[axis + 1..].iter().product();
    let mut out = vec![init; outer * inner];
    for o in 0..outer {
        for kk in 0..n {
            let base = (o * n + kk) * inner;
            let orow = &mut out[o * inner..(o + 1) * inner];
            for i in 0..inner {
                orow[i] = f(orow[i], t.data[base + i]);
            }
        }
    }
    Tensor::from_vec(out_shape, out)
}

fn concat(parts: &[&Tensor], axis: usize, out_shape: &[usize]) -> Tensor {
    let inner: usize = out_shape[axis + 1..].iter().product();
    let outer: usize = out_shape[..axis].iter().product();
    let mut out = Vec::with_capacity(numel(out_shape));
    for o in 0..outer {
        for p in parts {
            let len_p = p.shape[axis];
            let start = o * len_p * inner;
            out.extend_from_slice(&p.data[start..start + len_p * inner]);
        }
    }
    Tensor::from_vec(out_shape, out)
}

fn slice(t: &Tensor, axis: usize, start: usize, len: usize) -> Tensor {
    let n = t.shape[axis];
    let inner: usize = t.shape[axis + 1..].iter().product();
    let outer: usize = t.shape[..axis].iter().product();
    let mut shape = t.shape.clone();
    shape[axis] = len;
    let mut out = Vec::with_capacity(outer * len * inner);
    for o in 0..outer {
        let base = (o * n + start) * inner;
        out.extend_from_slice(&t.data[base..base + len * inner]);
    }
    Tensor::from_vec(&shape, out)
}

fn pad_zero(t: &Tensor, axis: usize, start: usize, full: usize) -> Tensor {
    let len = t.shape[axis];
    let inner: usize = t.shape[axis + 1..].iter().product();
    let outer: usize = t.shape[..axis].iter().product();
    let mut shape = t.shape.clone();
    shape[axis] = full;
    let mut out = vec![0.0f32; outer * full * inner];
    for o in 0..outer {
        let dst = (o * full + start) * inner;
        let src = o * len * inner;
        out[dst..dst + len * inner].copy_from_slice(&t.data[src..src + len * inner]);
    }
    Tensor::from_vec(&shape, out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(shape: &[usize], data: Vec<f32>) -> Tensor {
        Tensor::from_vec(shape, data)
    }

    fn run1(g: &Graph, out: Id, feeds: &[Feed]) -> Tensor {
        let plan = g.free_plan(&[out]);
        match g.eval(feeds, &[out], &plan).unwrap().remove(0) {
            Value::F32(t) => t,
            Value::I32(_) => panic!("expected f32"),
        }
    }

    #[test]
    fn broadcast_shapes_numpy_rules() {
        assert_eq!(broadcast_shapes(&[4, 1], &[3]), Some(vec![4, 3]));
        assert_eq!(broadcast_shapes(&[2, 3], &[2, 3]), Some(vec![2, 3]));
        assert_eq!(broadcast_shapes(&[], &[5]), Some(vec![5]));
        assert_eq!(broadcast_shapes(&[2, 1, 4], &[3, 1]), Some(vec![2, 3, 4]));
        assert_eq!(broadcast_shapes(&[2, 3], &[4]), None);
    }

    #[test]
    fn elementwise_broadcast_matches_manual() {
        let mut g = Graph::default();
        let a = g.input(&[2, 3], DType::F32);
        let b = g.input(&[3], DType::F32);
        let c = g.mul(a, b);
        let at = t(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let bt = t(&[3], vec![10., 100., 1000.]);
        let got = run1(&g, c, &[Feed::F32(&at), Feed::F32(&bt)]);
        assert_eq!(got.data, vec![10., 200., 3000., 40., 500., 6000.]);
    }

    #[test]
    fn matmul_all_transpose_combos() {
        // A (2,3), B (3,2) — compare every flag combo against the plain one
        let a = t(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let b = t(&[3, 2], vec![7., 8., 9., 10., 11., 12.]);
        let expect = a.matmul(&b); // (2,2)

        let mut g = Graph::default();
        let ia = g.input(&[2, 3], DType::F32);
        let ib = g.input(&[3, 2], DType::F32);
        let c0 = g.matmul(ia, ib, false, false);
        assert_eq!(run1(&g, c0, &[Feed::F32(&a), Feed::F32(&b)]).data, expect.data);

        let at = a.transpose2(); // (3,2)
        let mut g = Graph::default();
        let ia = g.input(&[3, 2], DType::F32);
        let ib = g.input(&[3, 2], DType::F32);
        let c1 = g.matmul(ia, ib, true, false);
        assert_eq!(run1(&g, c1, &[Feed::F32(&at), Feed::F32(&b)]).data, expect.data);

        let bt = b.transpose2(); // (2,3)
        let mut g = Graph::default();
        let ia = g.input(&[2, 3], DType::F32);
        let ib = g.input(&[2, 3], DType::F32);
        let c2 = g.matmul(ia, ib, false, true);
        assert_eq!(run1(&g, c2, &[Feed::F32(&a), Feed::F32(&bt)]).data, expect.data);

        let mut g = Graph::default();
        let ia = g.input(&[3, 2], DType::F32);
        let ib = g.input(&[2, 3], DType::F32);
        let c3 = g.matmul(ia, ib, true, true);
        assert_eq!(run1(&g, c3, &[Feed::F32(&at), Feed::F32(&bt)]).data, expect.data);
    }

    #[test]
    fn bmm_matches_per_slice_matmul() {
        let a = t(&[2, 2, 3], (0..12).map(|x| x as f32).collect());
        let b = t(&[2, 3, 2], (0..12).map(|x| (x as f32) * 0.5).collect());
        let mut g = Graph::default();
        let ia = g.input(&[2, 2, 3], DType::F32);
        let ib = g.input(&[2, 3, 2], DType::F32);
        let c = g.bmm(ia, ib, false, false);
        let got = run1(&g, c, &[Feed::F32(&a), Feed::F32(&b)]);
        for s in 0..2 {
            let a2 = t(&[2, 3], a.data[s * 6..(s + 1) * 6].to_vec());
            let b2 = t(&[3, 2], b.data[s * 6..(s + 1) * 6].to_vec());
            let e = a2.matmul(&b2);
            assert_eq!(&got.data[s * 4..(s + 1) * 4], e.data.as_slice(), "slice {s}");
        }
    }

    #[test]
    fn reduce_and_keepdims() {
        let x = t(&[2, 3], vec![1., 5., 2., -1., 0., 4.]);
        let mut g = Graph::default();
        let ix = g.input(&[2, 3], DType::F32);
        let s = g.reduce_sum(ix, 1);
        let m = g.reduce_max(ix, 0);
        let plan = g.free_plan(&[s, m]);
        let out = g.eval(&[Feed::F32(&x)], &[s, m], &plan).unwrap();
        assert_eq!(out[0].to_f32_tensor().data, vec![8., 3.]);
        assert_eq!(out[1].to_f32_tensor().data, vec![1., 5., 4.]);
    }

    #[test]
    fn transpose_reshape_slice_pad_roundtrip() {
        let x = t(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let mut g = Graph::default();
        let ix = g.input(&[2, 3], DType::F32);
        let tr = g.transpose(ix, &[1, 0]);
        let got = run1(&g, tr, &[Feed::F32(&x)]);
        assert_eq!(got.data, vec![1., 4., 2., 5., 3., 6.]);

        let mut g = Graph::default();
        let ix = g.input(&[2, 4], DType::F32);
        let sl = g.slice(ix, 1, 1, 2);
        let pd = g.pad_zero(sl, 1, 1, 4);
        let x = t(&[2, 4], vec![1., 2., 3., 4., 5., 6., 7., 8.]);
        let got = run1(&g, pd, &[Feed::F32(&x)]);
        assert_eq!(got.data, vec![0., 2., 3., 0., 0., 6., 7., 0.]);
    }

    #[test]
    fn gather_take_scatter() {
        let table = t(&[3, 2], vec![1., 2., 3., 4., 5., 6.]);
        let idx = IntTensor::from_vec(&[2, 2], vec![2, 0, 1, 2]);
        let mut g = Graph::default();
        let it = g.input(&[3, 2], DType::F32);
        let ii = g.input(&[2, 2], DType::I32);
        let gat = g.gather(it, ii);
        let got = run1(&g, gat, &[Feed::F32(&table), Feed::I32(&idx)]);
        assert_eq!(got.shape, vec![2, 2, 2]);
        assert_eq!(got.data, vec![5., 6., 1., 2., 3., 4., 5., 6.]);

        // scatter_add_rows is the adjoint: sum of rows per index
        let upd = t(&[2, 2, 2], vec![1.; 8]);
        let mut g = Graph::default();
        let ii = g.input(&[2, 2], DType::I32);
        let iu = g.input(&[2, 2, 2], DType::F32);
        let sc = g.scatter_add_rows(ii, iu, 3);
        let got = run1(&g, sc, &[Feed::I32(&idx), Feed::F32(&upd)]);
        // index 2 hit twice, 0 and 1 once each
        assert_eq!(got.data, vec![1., 1., 1., 1., 2., 2.]);

        // take_last / scatter_last
        let x = t(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let ti = IntTensor::from_vec(&[2], vec![2, 0]);
        let mut g = Graph::default();
        let ix = g.input(&[2, 3], DType::F32);
        let ii = g.input(&[2], DType::I32);
        let tk = g.take_last(ix, ii);
        let got = run1(&g, tk, &[Feed::F32(&x), Feed::I32(&ti)]);
        assert_eq!(got.data, vec![3., 4.]);
    }

    #[test]
    fn update_at_writes_per_batch_position() {
        // cache (2,1,3,2), kv (2,1,2), pos [2,0]
        let cache = t(&[2, 1, 3, 2], vec![0.0; 12]);
        let kv = t(&[2, 1, 2], vec![1., 2., 3., 4.]);
        let pos = IntTensor::from_vec(&[2], vec![2, 0]);
        let mut g = Graph::default();
        let ic = g.input(&[2, 1, 3, 2], DType::F32);
        let ik = g.input(&[2, 1, 2], DType::F32);
        let ip = g.input(&[2], DType::I32);
        let up = g.update_at(ic, ik, ip);
        let got = run1(&g, up, &[Feed::F32(&cache), Feed::F32(&kv), Feed::I32(&pos)]);
        assert_eq!(got.data, vec![0., 0., 0., 0., 1., 2., 3., 4., 0., 0., 0., 0.]);
    }

    #[test]
    fn softmax_composed_from_ops_matches_manual() {
        // softmax over the last axis, composed exactly like the attention graph
        let x = t(&[2, 3], vec![1., 2., 3., 0., 0., 0.]);
        let mut g = Graph::default();
        let ix = g.input(&[2, 3], DType::F32);
        let m = g.reduce_max_keep(ix, 1);
        let ms = g.stop_grad(m);
        let sh = g.sub(ix, ms);
        let e = g.exp(sh);
        let s = g.reduce_sum_keep(e, 1);
        let p = g.div(e, s);
        let got = run1(&g, p, &[Feed::F32(&x)]);
        let z: f32 = (1.0f32).exp() + (2.0f32).exp() + (3.0f32).exp();
        let e1 = (1.0f32).exp() / z;
        assert!((got.data[0] - e1).abs() < 1e-6);
        let row1: f32 = got.data[3..].iter().sum();
        assert!((row1 - 1.0).abs() < 1e-6);
        for v in &got.data[3..] {
            assert!((v - 1.0 / 3.0).abs() < 1e-6);
        }
    }

    #[test]
    fn tanh_rsqrt_maximum_elementwise() {
        let x = t(&[3], vec![0.25, 1.0, 4.0]);
        let y = t(&[3], vec![1.0, -1.0, 5.0]);
        let mut g = Graph::default();
        let ix = g.input(&[3], DType::F32);
        let iy = g.input(&[3], DType::F32);
        let r = g.rsqrt(ix);
        let th = g.tanh(iy);
        let mx = g.maximum(ix, iy);
        let plan = g.free_plan(&[r, th, mx]);
        let out = g
            .eval(&[Feed::F32(&x), Feed::F32(&y)], &[r, th, mx], &plan)
            .unwrap();
        let rt = out[0].to_f32_tensor();
        assert!((rt.data[0] - 2.0).abs() < 1e-6);
        assert!((rt.data[1] - 1.0).abs() < 1e-6);
        assert!((rt.data[2] - 0.5).abs() < 1e-6);
        let tt = out[1].to_f32_tensor();
        assert!((tt.data[0] - (1.0f32).tanh()).abs() < 1e-6);
        assert_eq!(out[2].to_f32_tensor().data, vec![1.0, 1.0, 5.0]);
    }

    #[test]
    fn free_plan_never_frees_outputs_or_inputs() {
        let mut g = Graph::default();
        let a = g.input(&[2], DType::F32);
        let b = g.add(a, a);
        let c = g.mul(b, b);
        let plan = g.free_plan(&[c, b]);
        // b is an output — must never appear in any free list
        for l in &plan {
            assert!(!l.contains(&b));
            assert!(!l.contains(&a));
        }
        let x = t(&[2], vec![1., 2.]);
        let out = g.eval(&[Feed::F32(&x)], &[c, b], &plan).unwrap();
        assert_eq!(out[0].to_f32_tensor().data, vec![4., 16.]);
        assert_eq!(out[1].to_f32_tensor().data, vec![2., 4.]);
    }
}
